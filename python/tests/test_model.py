"""Model forward tests: shapes, decode parity, quantized modes."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.model import (TINY, ModelConfig, forward, forward_decode,
                           init_kv_caches, init_params, nll,
                           prepare_weight_qstate, LINEARS)
from compile.quantizers import WAConfig

MICRO = ModelConfig(vocab=64, d_model=32, n_layers=2, n_heads=4, d_ff=64,
                    max_seq=32)


@pytest.fixture(scope="module")
def params():
    return init_params(MICRO, seed=3)


def test_forward_shapes(params):
    toks = jnp.array(np.random.default_rng(0).integers(0, 64, (3, 10)))
    logits = forward(params, toks, MICRO)
    assert logits.shape == (3, 10, 64)


def test_attention_maps_are_distributions(params):
    toks = jnp.array(np.random.default_rng(1).integers(0, 64, (2, 8)))
    _, attns = forward(params, toks, MICRO, want_attn=True)
    assert len(attns) == MICRO.n_layers
    for a in attns:
        assert a.shape == (2, MICRO.n_heads, 8, 8)
        np.testing.assert_allclose(np.asarray(a.sum(-1)), 1.0, rtol=1e-4)
        # causal: upper triangle zero
        up = np.triu(np.asarray(a[0, 0]), k=1)
        assert np.abs(up).max() < 1e-6


def test_decode_matches_prefill(params):
    """Teacher-forcing parity: step-by-step decode == full prefill."""
    rng = np.random.default_rng(2)
    toks = jnp.array(rng.integers(0, 64, (1, 6)))
    full = forward(params, toks, MICRO)
    kv = init_kv_caches(MICRO, 1)
    outs = []
    for t in range(6):
        logits, kv = forward_decode(params, toks[:, t:t + 1], kv,
                                    jnp.int32(t), MICRO)
        outs.append(logits)
    for t in range(6):
        np.testing.assert_allclose(np.asarray(full[0, t]),
                                   np.asarray(outs[t][0]), rtol=2e-3,
                                   atol=2e-3)


def test_fake_quant_mode_close_at_8bit(params):
    toks = jnp.array(np.random.default_rng(3).integers(0, 64, (2, 8)))
    fp = forward(params, toks, MICRO)
    q = forward(params, toks, MICRO, mode="fake", wa=WAConfig.parse("w8a8"))
    rel = float(jnp.abs(fp - q).max() / jnp.abs(fp).max())
    assert rel < 0.12, rel  # micro model (d=32): relative quant noise is larger


def test_kernel_mode_matches_fake_mode(params):
    """The pallas integer path must agree with the STE fake-quant path
    when driven by the same baked weight state (same codes)."""
    wa = WAConfig.parse("w4a8")
    qstate = []
    for blk in params["blocks"]:
        qstate.append({n: prepare_weight_qstate(blk[n], wa, None)
                       for n in LINEARS})
    toks = jnp.array(np.random.default_rng(4).integers(0, 64, (1, 8)))
    k = forward(params, toks, MICRO, mode="kernel", wa=wa, qstate=qstate)
    f = forward(params, toks, MICRO, mode="fake", wa=wa, qstate=None)
    # same weight codes; act quant differs only in clamping details
    rel = float(jnp.abs(k - f).max() / jnp.abs(f).max())
    assert rel < 0.15, rel


def test_nll_decreases_with_better_params():
    rng = np.random.default_rng(5)
    toks = jnp.array(rng.integers(0, 64, (4, 12)))
    p0 = init_params(MICRO, seed=0)
    loss0 = float(nll(p0, toks, MICRO))
    assert np.isfinite(loss0)
    # one SGD step on this batch should reduce its loss
    g = jax.grad(nll)(p0, toks, MICRO)
    p1 = jax.tree_util.tree_map(lambda a, b: a - 0.5 * b, p0, g)
    loss1 = float(nll(p1, toks, MICRO))
    assert loss1 < loss0


def test_save_load_roundtrip(tmp_path, params):
    from compile.model import load_params, save_params
    path = str(tmp_path / "m.npz")
    save_params(params, path)
    loaded = load_params(path, MICRO)
    toks = jnp.array([[1, 2, 3]])
    np.testing.assert_allclose(np.asarray(forward(params, toks, MICRO)),
                               np.asarray(forward(loaded, toks, MICRO)))
