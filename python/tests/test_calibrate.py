"""Calibration tests: losses behave, calibration improves over RTN on a
micro model (smoke-scale), compensation vectors only on first/last blocks."""
import numpy as np
import jax.numpy as jnp
import pytest

from compile import data
from compile.calibrate import (CalibConfig, akl_loss, calibrate, dlc_loss,
                               mse_loss)
from compile.model import ModelConfig, init_params, perplexity, LINEARS
from compile.quantizers import WAConfig

MICRO = ModelConfig(vocab=64, d_model=32, n_layers=2, n_heads=4, d_ff=64,
                    max_seq=32)


def test_dlc_loss_zero_when_identical():
    rng = np.random.default_rng(0)
    d = jnp.array(rng.normal(size=(2, 8, 16)).astype(np.float32))
    assert float(dlc_loss(d, d, d)) < 1e-4


def test_dlc_loss_positive_when_different():
    rng = np.random.default_rng(1)
    a = jnp.array(rng.normal(size=(2, 8, 16)).astype(np.float32))
    b = jnp.array(rng.normal(size=(2, 8, 16)).astype(np.float32))
    assert float(dlc_loss(a, b, b)) > 0.1


def test_akl_loss_zero_for_same_attention():
    rng = np.random.default_rng(2)
    logits = rng.normal(size=(2, 4, 8, 8))
    attn = jnp.array(np.exp(logits) / np.exp(logits).sum(-1, keepdims=True))
    assert float(akl_loss(attn, attn)) < 1e-5
    # and positive for different maps
    attn2 = jnp.roll(attn, 1, axis=-1)
    assert float(akl_loss(attn, attn2)) > 0.01


def test_mse_loss_basic():
    a = jnp.ones((2, 3))
    b = jnp.zeros((2, 3))
    assert float(mse_loss(a, b)) == pytest.approx(1.0)


@pytest.fixture(scope="module")
def setup():
    params = init_params(MICRO, seed=9)
    calib = data.generate_tokens(8 * 32, seed=7) % 64
    return params, calib.reshape(8, 32)


def test_calibrate_structures(setup):
    params, calib = setup
    wa = WAConfig.parse("w4a4")
    qs = calibrate(params, MICRO, wa, calib, method="abq",
                   cal=CalibConfig(epochs=2, samples=4, seq=16),
                   verbose=False)
    assert len(qs) == MICRO.n_layers
    for i, block_qs in enumerate(qs):
        for name in LINEARS:
            assert "s" in block_qs[name]
            assert "alpha" in block_qs[name]
            # compensation only on down of first/last blocks
            has_comp = "comp_a" in block_qs[name]
            should = name == "down" and i in (0, MICRO.n_layers - 1)
            assert has_comp == should, (i, name)
        # balance vectors positive and finite
        for name in LINEARS:
            s = np.asarray(block_qs[name]["s"])
            assert (s > 0).all() and np.isfinite(s).all()


def test_smoothquant_method_closed_form(setup):
    params, calib = setup
    wa = WAConfig.parse("w4a4")
    qs = calibrate(params, MICRO, wa, calib, method="smoothquant",
                   cal=CalibConfig(samples=4, seq=16), verbose=False)
    for block_qs in qs:
        for name in LINEARS:
            assert set(block_qs[name].keys()) == {"s"}


def test_rtn_method_returns_none_states(setup):
    params, calib = setup
    wa = WAConfig.parse("w4a4")
    qs = calibrate(params, MICRO, wa, calib, method="rtn", verbose=False)
    assert all(q is None for q in qs)
