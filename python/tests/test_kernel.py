"""L1 correctness: Pallas ABQ kernel vs pure-jnp oracle.

The integer path must match *exactly* (both are exact int32 arithmetic);
hypothesis sweeps shapes and bit-width combinations.
"""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.abq_matmul import (
    abq_matmul_fp,
    abq_matmul_int,
    quantize_act_per_token,
    quantized_linear,
)


def _random_case(rng, m, n, k, p_bits, q_bits):
    xq = rng.integers(0, 2 ** p_bits, size=(m, k), dtype=np.int32)
    wq = rng.integers(0, 2 ** q_bits, size=(n, k), dtype=np.int32)
    zx = rng.integers(0, 2 ** p_bits, size=(m,), dtype=np.int32)
    zw = rng.integers(0, 2 ** q_bits, size=(n,), dtype=np.int32)
    return xq, wq, zx, zw


def test_decomposition_algebra_matches_direct():
    """Eq. (8)-(10): the BMMA superposition equals the direct product."""
    rng = np.random.default_rng(0)
    for p, q in [(8, 8), (8, 2), (4, 4), (2, 2), (3, 5), (8, 3)]:
        xq, wq, zx, zw = _random_case(rng, 9, 11, 64, p, q)
        direct = ref.quant_matmul_int(jnp.array(xq), jnp.array(wq),
                                      jnp.array(zx), jnp.array(zw))
        decomp = ref.quant_matmul_decomposed(jnp.array(xq), jnp.array(wq),
                                             jnp.array(zx), jnp.array(zw), p, q)
        np.testing.assert_array_equal(np.asarray(direct), np.asarray(decomp))


@pytest.mark.parametrize("p,q", [(8, 8), (8, 2), (8, 3), (4, 4), (6, 6),
                                 (2, 2), (2, 4), (5, 5), (8, 4), (3, 3)])
def test_kernel_matches_oracle_bit_combos(p, q):
    rng = np.random.default_rng(p * 100 + q)
    xq, wq, zx, zw = _random_case(rng, 17, 33, 128, p, q)
    got = abq_matmul_int(jnp.array(xq), jnp.array(wq), jnp.array(zx),
                         jnp.array(zw), p_bits=p, q_bits=q)
    want = ref.quant_matmul_int(jnp.array(xq), jnp.array(wq),
                                jnp.array(zx), jnp.array(zw))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 40),
    n=st.integers(1, 40),
    k=st.sampled_from([8, 32, 100, 128]),
    p=st.integers(1, 8),
    q=st.integers(1, 8),
    seed=st.integers(0, 2 ** 16),
)
def test_kernel_matches_oracle_hypothesis(m, n, k, p, q, seed):
    rng = np.random.default_rng(seed)
    xq, wq, zx, zw = _random_case(rng, m, n, k, p, q)
    got = abq_matmul_int(jnp.array(xq), jnp.array(wq), jnp.array(zx),
                         jnp.array(zw), p_bits=p, q_bits=q)
    want = ref.quant_matmul_int(jnp.array(xq), jnp.array(wq),
                                jnp.array(zx), jnp.array(zw))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=10, deadline=None)
@given(
    bm=st.sampled_from([8, 16, 64]),
    bn=st.sampled_from([8, 16, 64]),
    seed=st.integers(0, 100),
)
def test_kernel_tile_size_invariance(bm, bn, seed):
    """Output is independent of the BlockSpec tiling (auto-search safety)."""
    rng = np.random.default_rng(seed)
    xq, wq, zx, zw = _random_case(rng, 23, 31, 64, 8, 2)
    a = abq_matmul_int(jnp.array(xq), jnp.array(wq), jnp.array(zx),
                       jnp.array(zw), p_bits=8, q_bits=2, bm=bm, bn=bn)
    b = ref.quant_matmul_int(jnp.array(xq), jnp.array(wq),
                             jnp.array(zx), jnp.array(zw))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fp_dequant_path():
    rng = np.random.default_rng(7)
    xq, wq, zx, zw = _random_case(rng, 5, 9, 32, 8, 4)
    dx = rng.random(5).astype(np.float32) * 0.1
    dw = rng.random(9).astype(np.float32) * 0.01
    got = abq_matmul_fp(jnp.array(xq), jnp.array(wq), jnp.array(zx),
                        jnp.array(zw), jnp.array(dx), jnp.array(dw),
                        p_bits=8, q_bits=4)
    want = ref.quant_matmul_fp(jnp.array(xq), jnp.array(wq), jnp.array(zx),
                               jnp.array(zw), jnp.array(dx), jnp.array(dw))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_act_quantizer_range_and_reconstruction():
    rng = np.random.default_rng(3)
    x = jnp.array(rng.normal(size=(12, 64)).astype(np.float32)) * 3.0
    for bits in (8, 6, 4, 2):
        q, zp, delta = quantize_act_per_token(x, bits)
        assert int(q.min()) >= 0 and int(q.max()) <= (1 << bits) - 1
        xr = (np.asarray(q) - np.asarray(zp)[:, None]) * np.asarray(delta)[:, None]
        err = np.abs(xr - np.asarray(x)).max()
        assert err <= np.asarray(delta).max() * 0.5 + 1e-6


def test_quantized_linear_close_to_fp_at_8bit():
    """W8A8 quantized linear should track the fp matmul closely."""
    rng = np.random.default_rng(11)
    x = jnp.array(rng.normal(size=(4, 64)).astype(np.float32))
    w = jnp.array(rng.normal(size=(32, 64)).astype(np.float32) * 0.05)
    # prepare per-channel weight codes
    lo = jnp.min(w, axis=1)
    hi = jnp.max(w, axis=1)
    delta = (hi - lo) / 255.0
    zw = jnp.clip(jnp.round(-lo / delta), 0, 255).astype(jnp.int32)
    wq = jnp.clip(jnp.round(w / delta[:, None]) + zw[:, None], 0, 255).astype(jnp.int32)
    y = quantized_linear(x, wq, zw, delta, w_bits=8, a_bits=8)
    y_fp = x @ w.T
    rel = np.abs(np.asarray(y) - np.asarray(y_fp)).max() / np.abs(np.asarray(y_fp)).max()
    assert rel < 0.02, rel
