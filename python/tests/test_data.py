"""Corpus generator tests (and the rust-mirror contract)."""
import numpy as np

from compile import data


def test_splitmix_reference_values():
    """These exact values are asserted in rust/src/util/rng.rs — the
    cross-language determinism contract."""
    r = data.SplitMix(42)
    assert r.next_u64() == 13679457532755275413
    assert r.next_u64() == 2949826092126892291
    assert r.next_u64() == 5139283748462763858


def test_generation_deterministic():
    a = data.generate_tokens(300, seed=5)
    b = data.generate_tokens(300, seed=5)
    np.testing.assert_array_equal(a, b)
    c = data.generate_tokens(300, seed=6)
    assert (a != c).any()


def test_bos_anchoring_and_range():
    toks = data.generate_tokens(200, seed=1)
    assert toks[0] == data.BOS
    assert toks[32] == data.BOS  # sentence boundary every 32
    assert toks.min() >= 0 and toks.max() < data.VOCAB


def test_topic_conditioning_changes_distribution():
    """Same current token, different topic → different successor stats
    (the long-range dependency that makes attention necessary)."""
    succ, cum = data.build_transition_table(0xAB9)
    # state for (cur=5, topic=1) vs (cur=5, topic=9)
    s1 = 1 + ((5 - 1) + (1 - 1)) % (data.VOCAB - 1)
    s2 = 1 + ((5 - 1) + (9 - 1)) % (data.VOCAB - 1)
    assert s1 != s2
    assert (succ[s1] != succ[s2]).any()


def test_batches_shape_and_content():
    toks = data.generate_tokens(2 * 3 * 9, seed=2)
    b = data.batches(toks, batch=3, seq=8)
    assert b.shape == (2, 3, 9)
    np.testing.assert_array_equal(b.reshape(-1), toks[: 2 * 3 * 9])


def test_zipfian_unigram_shape():
    """Frequent tokens should be much more frequent than rare ones."""
    toks = data.generate_tokens(20000, seed=3)
    counts = np.bincount(toks, minlength=data.VOCAB)
    top50 = np.sort(counts)[-50:].sum()
    assert top50 > 0.35 * counts.sum(), "heavy-tailed unigram expected"
