"""AOT exporter tests: .abqw format, flattening order, HLO lowering of a
micro model (full-size lowering is exercised by `make artifacts`)."""
import os
import struct

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile.model import ModelConfig, forward, init_params, prepare_weight_qstate, LINEARS
from compile.quantizers import WAConfig

MICRO = ModelConfig(vocab=64, d_model=32, n_layers=1, n_heads=4, d_ff=64,
                    max_seq=32)


def parse_abqw(path):
    """Independent reference parser (mirrors rust weights.rs)."""
    out = {}
    with open(path, "rb") as f:
        assert f.read(6) == b"ABQW1\0"
        (n,) = struct.unpack("<I", f.read(4))
        for _ in range(n):
            (nl,) = struct.unpack("<H", f.read(2))
            name = f.read(nl).decode()
            dtype, ndim = struct.unpack("<BB", f.read(2))
            shape = struct.unpack(f"<{ndim}I", f.read(4 * ndim))
            count = int(np.prod(shape)) if ndim else 1
            npdt = {0: np.float32, 1: np.int32, 2: np.uint8}[dtype]
            data = np.frombuffer(f.read(count * np.dtype(npdt).itemsize),
                                 dtype=npdt).reshape(shape)
            out[name] = data
    return out


def test_abqw_roundtrip(tmp_path):
    tensors = {
        "a": np.arange(6, dtype=np.float32).reshape(2, 3),
        "b": np.array([1, -2, 3], dtype=np.int32),
        "c": np.array([[250, 1], [2, 3]], dtype=np.uint8),
    }
    path = str(tmp_path / "t.abqw")
    aot.write_abqw(path, tensors)
    back = parse_abqw(path)
    for k, v in tensors.items():
        np.testing.assert_array_equal(back[k], v)


def test_flatten_names_stable():
    params = init_params(MICRO, seed=1)
    names1, leaves1, _ = aot.flatten_with_names(params)
    names2, leaves2, _ = aot.flatten_with_names(params)
    assert names1 == names2
    assert len(names1) == len(leaves1)
    assert "tok_emb" in names1
    assert any(n.startswith("blocks.0.") for n in names1)


def test_micro_model_lowers_to_hlo_text():
    params = init_params(MICRO, seed=2)
    wa = WAConfig.parse("w2*a8")
    qstate = [
        {n: prepare_weight_qstate(params["blocks"][0][n], wa, None)
         for n in LINEARS}
    ]
    pspec = jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params)
    qspec = jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), qstate)
    tok = jax.ShapeDtypeStruct((1, 8), jnp.int32)

    def fn(p, q, t):
        return (forward(p, t, MICRO, mode="kernel", wa=wa, qstate=q),)

    lowered = jax.jit(fn).lower(pspec, qspec, tok)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "s32" in text  # integer kernel path present


def test_kernel_artifact_numerics_vs_eager(tmp_path):
    """Lowered+compiled (via jax) output == eager output — the same HLO
    text the rust runtime executes."""
    params = init_params(MICRO, seed=4)
    wa = WAConfig.parse("w4a8")
    qstate = [
        {n: prepare_weight_qstate(params["blocks"][0][n], wa, None)
         for n in LINEARS}
    ]
    toks = jnp.array(np.random.default_rng(0).integers(0, 64, (1, 8)),
                     dtype=jnp.int32)

    def fn(p, q, t):
        return (forward(p, t, MICRO, mode="kernel", wa=wa, qstate=q),)

    eager = fn(params, qstate, toks)[0]
    compiled = jax.jit(fn)(params, qstate, toks)[0]
    np.testing.assert_allclose(np.asarray(eager), np.asarray(compiled),
                               rtol=1e-5, atol=1e-5)
