"""Quantizer unit + property tests (python side)."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import quantizers as Q


def test_waconfig_parse_roundtrip():
    for s in ["w2a8", "w2*a8", "w4a4", "w8a8", "w4a4g128", "fp16", "w6a6"]:
        cfg = Q.WAConfig.parse(s)
        assert cfg.name() == s


def test_waconfig_planes_and_levels():
    cfg = Q.WAConfig.parse("w2*a8")
    assert cfg.weight.n_levels == 5
    assert cfg.weight.planes == 3
    assert cfg.act.planes == 8
    assert Q.WAConfig.parse("w2a8").weight.planes == 2
    assert Q.WAConfig.parse("w3a16").weight.planes == 3


@settings(max_examples=30, deadline=None)
@given(
    rows=st.integers(1, 6),
    cols=st.integers(2, 48),
    bits=st.integers(2, 8),
    seed=st.integers(0, 1000),
)
def test_weight_fake_quant_error_bounded(rows, cols, bits, seed):
    rng = np.random.default_rng(seed)
    w = jnp.array(rng.normal(size=(rows, cols)).astype(np.float32))
    spec = Q.QuantSpec(bits)
    wdq, codes, delta, zp = Q.fake_quant_weight(w, spec)
    assert codes.min() >= 0 and codes.max() <= spec.n_levels - 1
    err = np.abs(np.asarray(wdq - w))
    bound = np.asarray(delta) * 1.5 + 1e-5
    assert (err <= bound).all()


def test_balanced_w2_grid_symmetric():
    spec = Q.QuantSpec(2, balanced=True)
    w = jnp.array(np.linspace(-1, 1, 32, dtype=np.float32)[None, :])
    wdq, codes, delta, zp = Q.fake_quant_weight(w, spec)
    lvls = np.asarray(wdq) / np.asarray(delta)
    assert np.allclose(lvls, np.round(lvls), atol=1e-4)
    assert np.abs(lvls).max() <= 2.0 + 1e-4
    assert float(zp[0, 0]) == 2.0
    # symmetric: -2..2 reachable on symmetric input
    assert lvls.min() <= -1.9 and lvls.max() >= 1.9


def test_plain_w2_grid_asymmetric_on_symmetric_data():
    """The asymmetry the bit-balance strategy fixes (paper §3.3/Fig. 7)."""
    spec = Q.QuantSpec(2)
    w = jnp.array(np.linspace(-1, 1, 64, dtype=np.float32)[None, :])
    wdq, *_ = Q.fake_quant_weight(w, spec)
    dq = np.asarray(wdq)
    skew = abs(dq.max() + dq.min())  # 0 for a symmetric grid
    spec_b = Q.QuantSpec(2, balanced=True)
    wdq_b, *_ = Q.fake_quant_weight(w, spec_b)
    dq_b = np.asarray(wdq_b)
    skew_b = abs(dq_b.max() + dq_b.min())
    assert skew_b < skew, (skew, skew_b)


def test_per_group_quantization_improves_fit():
    rng = np.random.default_rng(0)
    # two groups with very different scales in one row
    w = np.concatenate([rng.normal(size=32) * 0.01, rng.normal(size=32) * 1.0])
    w = jnp.array(w.astype(np.float32)[None, :])
    flat_err = float(jnp.abs(Q.fake_quant_weight(w, Q.QuantSpec(4))[0] - w).mean())
    g_err = float(jnp.abs(Q.fake_quant_weight(w, Q.QuantSpec(4, group=32))[0] - w).mean())
    assert g_err < flat_err


def test_act_quant_per_token_stats():
    rng = np.random.default_rng(1)
    x = jnp.array(rng.normal(size=(5, 64)).astype(np.float32) * 3)
    xdq, q, delta, zp = Q.fake_quant_act(x, Q.QuantSpec(8))
    assert q.shape == x.shape
    assert delta.shape == (5, 1)  # per token
    err = np.abs(np.asarray(xdq - x))
    assert (err <= np.asarray(delta) * 0.75 + 1e-6).all()


def test_smooth_scales_balance_identity():
    rng = np.random.default_rng(2)
    w = jnp.array(rng.normal(size=(8, 16)).astype(np.float32))
    x = jnp.array(rng.normal(size=(4, 16)).astype(np.float32))
    s = Q.smooth_scales(jnp.abs(x).max(0), jnp.abs(w).max(0), 0.5)
    wb, xb = Q.apply_balance(w, x, s)
    np.testing.assert_allclose(np.asarray(x @ w.T), np.asarray(xb @ wb.T),
                               rtol=2e-4, atol=2e-4)


def test_ste_round_gradient_passthrough():
    import jax
    g = jax.grad(lambda x: Q.ste_round(x * 3.0))(1.234)
    assert abs(g - 3.0) < 1e-6
