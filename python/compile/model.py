"""L2: tiny-LLaMA in JAX — the paper's model substrate.

Architecturally a faithful LLaMA block (RMSNorm → MHA with RoPE → residual →
RMSNorm → SwiGLU MLP → residual), scaled down (DESIGN.md §4) so it can be
trained from scratch here and quantized with measurable damage.

Three execution modes per linear layer:
  * 'fp'     — float path
  * 'fake'   — fake-quant (straight-through), used by the calibrator; fully
               differentiable w.r.t. balance vector s, clipping α/β and the
               compensation vectors a, b (paper Eq. 1-3)
  * 'kernel' — integer path through the L1 Pallas kernel (bit-plane BMMA
               superposition); this is what the AOT artifacts contain

The *same* quantization state (per-linear s/α/β/comp + W codes) drives both
the 'fake' and 'kernel' paths, and rust/src/model re-implements 'kernel'
bit-for-bit on the native engine.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import quantizers as Q
from .kernels import abq_matmul as K

LINEARS = ("wq", "wk", "wv", "wo", "gate", "up", "down")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "tiny-llama"
    vocab: int = 512
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 8
    # KV heads (GQA): == n_heads is MHA, 1 is MQA. Mirrors the rust
    # ModelConfig; wk/wv become (kv_dim, d_model) and query head h reads
    # KV head h // (n_heads // n_kv_heads).
    n_kv_heads: int = 8
    d_ff: int = 704          # ~ 8/3 * d, multiple of 64
    max_seq: int = 256
    rope_base: float = 10000.0
    # architecture variant knobs (manifest grammar; the jax trainer only
    # exercises the LLaMA defaults, rust serves the others)
    norm: str = "rmsnorm"            # or "layernorm"
    act: str = "silu"                # or "gelu"
    tied_embeddings: bool = False

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def groups(self) -> int:
        return self.n_heads // self.n_kv_heads

    def param_count(self) -> int:
        d, f, v, kd = self.d_model, self.d_ff, self.vocab, self.kv_dim
        per_block = 2 * d * d + 2 * kd * d + 3 * d * f + 2 * d
        head = 0 if self.tied_embeddings else d * v
        return v * d + self.n_layers * per_block + d + head


TINY = ModelConfig()


# ---------------------------------------------------------------------------
# init / params
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, seed: int = 0) -> dict:
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, cfg.n_layers * 8 + 3)
    i = 0

    def dense(k, shape, scale=None):
        scale = scale or (1.0 / math.sqrt(shape[1]))
        return (jax.random.normal(k, shape) * scale).astype(jnp.float32)

    params: dict[str, Any] = {
        "tok_emb": dense(ks[i], (cfg.vocab, cfg.d_model), 0.02),
        "blocks": [],
        "ln_f": jnp.ones(cfg.d_model, jnp.float32),
    }
    i += 1
    for _ in range(cfg.n_layers):
        d, f = cfg.d_model, cfg.d_ff
        blk = {
            "ln1": jnp.ones(d, jnp.float32),
            "ln2": jnp.ones(d, jnp.float32),
            "wq": dense(ks[i + 0], (d, d)),
            "wk": dense(ks[i + 1], (cfg.kv_dim, d)),
            "wv": dense(ks[i + 2], (cfg.kv_dim, d)),
            "wo": dense(ks[i + 3], (d, d)),
            "gate": dense(ks[i + 4], (f, d)),
            "up": dense(ks[i + 5], (f, d)),
            "down": dense(ks[i + 6], (d, f)),
        }
        i += 7
        params["blocks"].append(blk)
    params["head"] = dense(ks[i], (cfg.vocab, cfg.d_model), 0.02)
    return params


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------

def rmsnorm(x, g, eps=1e-5):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * g


def rope_tables(cfg: ModelConfig, positions):
    """positions: [S] -> (cos, sin) [S, head_dim/2]."""
    hd = cfg.head_dim
    inv = 1.0 / (cfg.rope_base ** (jnp.arange(0, hd, 2) / hd))
    ang = positions[:, None].astype(jnp.float32) * inv[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: [B, S, H, hd]; rotate pairs."""
    x1, x2 = x[..., 0::2], x[..., 1::2]
    c = cos[None, :, None, :]
    s = sin[None, :, None, :]
    out = jnp.stack([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)
    return out.reshape(x.shape)


# ---------------------------------------------------------------------------
# quantized linear (all three modes)
# ---------------------------------------------------------------------------

def _qstate_for(qstate, name):
    return None if qstate is None else qstate.get(name)


def linear(x, w, *, mode="fp", wa: Q.WAConfig | None = None, qs=None):
    """x: [..., K] @ w[N, K].T -> [..., N].

    qs: per-linear calibration state dict with optional keys
        's' [K], 'alpha' [], 'beta' [], 'comp' ([N,K] rank-1 product),
        and (kernel mode, prepared) 'wq', 'zw', 'dw', 'planes'.
    """
    if mode == "fp" or wa is None or (wa.weight.is_fp and wa.act.is_fp):
        return x @ w.T
    lead = x.shape[:-1]
    kdim = x.shape[-1]
    x2 = x.reshape(-1, kdim)

    if mode == "fake":
        s = qs.get("s") if qs else None
        alpha = qs.get("alpha", 1.0) if qs else 1.0
        beta = qs.get("beta", 1.0) if qs else 1.0
        comp = None
        if qs and "comp_a" in qs:
            comp = qs["comp_a"][:, None] * qs["comp_b"][None, :]
        wb, x2b = (w, x2) if s is None else Q.apply_balance(w, x2, s)
        wdq, *_ = Q.fake_quant_weight(wb, wa.weight, alpha=alpha, beta=beta,
                                      comp=comp)
        xdq, *_ = Q.fake_quant_act(x2b, wa.act)
        y = xdq @ wdq.T
        return y.reshape(*lead, -1)

    if mode == "kernel":
        # prepared integer path (artifact path); plane count is static
        # (from the spec), never a traced value — required for jax.jit
        y = K.quantized_linear(
            x2, qs["wq"], qs["zw"], qs["dw"],
            w_bits=wa.weight.bits, a_bits=wa.act.bits,
            balance=qs.get("s"), w_planes=wa.weight.planes,
        )
        return y.reshape(*lead, -1)

    raise ValueError(f"unknown mode {mode}")


def prepare_weight_qstate(w, wa: Q.WAConfig, qs=None):
    """Bake calibrated fake-quant state into integer codes for the kernel
    path / rust export. Returns dict(wq, zw, dw, planes, s?)."""
    qs = qs or {}
    s = qs.get("s")
    alpha = qs.get("alpha", 1.0)
    beta = qs.get("beta", 1.0)
    comp = None
    if "comp_a" in qs:
        comp = qs["comp_a"][:, None] * qs["comp_b"][None, :]
    wb = w if s is None else w * s[None, :]
    if comp is not None:
        wb = wb + comp
    lo = jnp.minimum(beta * jnp.min(wb, axis=1, keepdims=True), 0.0)
    hi = jnp.maximum(alpha * jnp.max(wb, axis=1, keepdims=True), 0.0)
    delta, zp = Q.qparams_minmax(lo, hi, wa.weight)
    codes = Q.quantize_codes(wb, delta, zp, wa.weight)
    # NOTE: no 'planes' entry — the plane count is static (spec-derived);
    # a traced leaf here would break jax.jit lowering of the kernel path.
    out = {
        "wq": codes.astype(jnp.int32),
        "zw": jnp.round(zp[:, 0]).astype(jnp.int32),
        "dw": delta[:, 0].astype(jnp.float32),
    }
    if s is not None:
        out["s"] = s.astype(jnp.float32)
    return out


# ---------------------------------------------------------------------------
# block / model forward
# ---------------------------------------------------------------------------

def block_forward(blk, x, cos, sin, cfg: ModelConfig, *, mode="fp",
                  wa: Q.WAConfig | None = None, qstate=None,
                  mask=None, want_attn=False, kv=None, capture=None):
    """One transformer block.

    x: [B, S, D]. kv: optional (k_cache, v_cache, pos) for decode.
    capture: optional dict; when given, each linear's *input* activations are
    recorded under its name (used by the calibrator for smoothing stats).
    Returns (y, attn_map or None, new_kv).
    """
    B, S, D = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    Hkv = cfg.n_kv_heads

    def lin(name, inp):
        if capture is not None:
            capture[name] = inp
        return linear(inp, blk[name], mode=mode, wa=wa,
                      qs=_qstate_for(qstate, name))

    h = rmsnorm(x, blk["ln1"])
    q = lin("wq", h).reshape(B, S, H, hd)
    k = lin("wk", h).reshape(B, S, Hkv, hd)
    v = lin("wv", h).reshape(B, S, Hkv, hd)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    if kv is not None:
        k_cache, v_cache, pos = kv
        k_cache = jax.lax.dynamic_update_slice(k_cache, k, (0, pos, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(v_cache, v, (0, pos, 0, 0))
        k_all, v_all = k_cache, v_cache
        new_kv = (k_cache, v_cache)
    else:
        k_all, v_all = k, v
        new_kv = None

    if Hkv != H:
        # GQA head-group broadcast: repeat each KV head over its group of
        # query heads (query head h reads KV head h // groups)
        k_all = jnp.repeat(k_all, cfg.groups, axis=2)
        v_all = jnp.repeat(v_all, cfg.groups, axis=2)
    scores = jnp.einsum("bshd,bthd->bhst", q, k_all) / math.sqrt(hd)
    if mask is not None:
        scores = scores + mask
    attn = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhst,bthd->bshd", attn, v_all).reshape(B, S, D)
    x = x + lin("wo", ctx)

    h2 = rmsnorm(x, blk["ln2"])
    gate = lin("gate", h2)
    up = lin("up", h2)
    act = jax.nn.silu(gate) * up
    x = x + lin("down", act)
    return x, (attn if want_attn else None), new_kv


def causal_mask(S):
    m = jnp.tril(jnp.ones((S, S), dtype=bool))
    return jnp.where(m, 0.0, -1e9)[None, None, :, :]


def forward(params, tokens, cfg: ModelConfig, *, mode="fp",
            wa: Q.WAConfig | None = None, qstate=None, want_attn=False):
    """tokens: [B, S] -> logits [B, S, V].

    qstate: list (per block) of dicts (per linear) of calibration state.
    """
    B, S = tokens.shape
    x = params["tok_emb"][tokens]
    cos, sin = rope_tables(cfg, jnp.arange(S))
    mask = causal_mask(S)
    attns = []
    for i, blk in enumerate(params["blocks"]):
        qs = qstate[i] if qstate is not None else None
        x, attn, _ = block_forward(blk, x, cos, sin, cfg, mode=mode, wa=wa,
                                   qstate=qs, mask=mask, want_attn=want_attn)
        if want_attn:
            attns.append(attn)
    x = rmsnorm(x, params["ln_f"])
    logits = x @ params["head"].T
    return (logits, attns) if want_attn else logits


def forward_decode(params, tokens, kv_caches, pos, cfg: ModelConfig, *,
                   mode="fp", wa=None, qstate=None):
    """Single-step decode: tokens [B, 1], kv_caches [L] of ([B,Smax,H,hd]×2).

    Returns (logits [B, V], new_kv_caches). `pos` is a traced scalar.
    """
    B = tokens.shape[0]
    x = params["tok_emb"][tokens]          # [B, 1, D]
    positions = jnp.array([0])[None] + pos  # [1,1]
    cos, sin = rope_tables(cfg, positions.reshape(-1))
    # decode attends to cache positions <= pos
    Smax = kv_caches[0][0].shape[1]
    key_pos = jnp.arange(Smax)
    mask = jnp.where(key_pos[None, None, None, :] <= pos, 0.0, -1e9)
    new_caches = []
    for i, blk in enumerate(params["blocks"]):
        qs = qstate[i] if qstate is not None else None
        x, _, new_kv = block_forward(
            blk, x, cos, sin, cfg, mode=mode, wa=wa, qstate=qs,
            mask=mask, kv=(kv_caches[i][0], kv_caches[i][1], pos))
        new_caches.append(new_kv)
    x = rmsnorm(x, params["ln_f"])
    logits = (x @ params["head"].T)[:, 0, :]
    return logits, new_caches


def init_kv_caches(cfg: ModelConfig, batch: int):
    shape = (batch, cfg.max_seq, cfg.n_kv_heads, cfg.head_dim)
    return [(jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32))
            for _ in range(cfg.n_layers)]


# ---------------------------------------------------------------------------
# loss / perplexity
# ---------------------------------------------------------------------------

def nll(params, batch_tokens, cfg: ModelConfig, **fw):
    """batch_tokens: [B, S+1]; returns mean token NLL."""
    inp = batch_tokens[:, :-1]
    tgt = batch_tokens[:, 1:]
    logits = forward(params, inp, cfg, **fw)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


def perplexity(params, eval_batches, cfg: ModelConfig, **fw) -> float:
    """eval_batches: [num, B, S+1] numpy array."""
    total, count = 0.0, 0
    f = jax.jit(lambda p, b: nll(p, b, cfg, **fw)) if not fw else None
    for b in np.asarray(eval_batches):
        loss = nll(params, jnp.array(b), cfg, **fw) if f is None else f(params, jnp.array(b))
        total += float(loss) * b.shape[0] * (b.shape[1] - 1)
        count += b.shape[0] * (b.shape[1] - 1)
    return math.exp(total / max(count, 1))


def save_params(params, path: str):
    flat = {}
    flat["tok_emb"] = np.asarray(params["tok_emb"])
    flat["ln_f"] = np.asarray(params["ln_f"])
    flat["head"] = np.asarray(params["head"])
    for i, blk in enumerate(params["blocks"]):
        for k, v in blk.items():
            flat[f"blocks.{i}.{k}"] = np.asarray(v)
    np.savez(path, **flat)


def load_params(path: str, cfg: ModelConfig) -> dict:
    z = np.load(path)
    params = {
        "tok_emb": jnp.array(z["tok_emb"]),
        "ln_f": jnp.array(z["ln_f"]),
        "head": jnp.array(z["head"]),
        "blocks": [],
    }
    for i in range(cfg.n_layers):
        blk = {}
        for k in ("ln1", "ln2", *LINEARS):
            blk[k] = jnp.array(z[f"blocks.{i}.{k}"])
        params["blocks"].append(blk)
    return params
