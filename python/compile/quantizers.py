"""Quantizers for ABQ-LLM (python side; rust/src/quant mirrors the semantics).

Conventions (match the paper, §3 / Eq. 3):

  * weights:     per-output-channel asymmetric quantization
                 Wq = clamp(round(W/Δ) + z, 0, 2^n - 1)          (codes u8)
  * activations: per-token asymmetric quantization (dynamic)
  * bit-balance (W2*, §3.3): symmetric 5-level set {-2,-1,0,1,2}; codes are
    stored as unsigned 0..4 with z = 2, which needs 3 bit-planes in the
    engine (the paper's "minimal cost" for the balance strategy).
  * clipping (Eq. 1): W_max = α·max(W), W_min = β·min(W), α/β learnable.
  * compensation (Eq. 3): quantize (W + γ·a·bᵀ) instead of W.

All functions are jax-differentiable via the straight-through estimator so
the calibrator (calibrate.py) can learn s, α, β, a, b.
"""
from __future__ import annotations

from dataclasses import dataclass, replace

import jax
import jax.numpy as jnp


def ste_round(x):
    """Round with straight-through gradient."""
    return x + jax.lax.stop_gradient(jnp.round(x) - x)


@dataclass(frozen=True)
class QuantSpec:
    """One side (W or A) of a WqAp configuration."""
    bits: int                 # nominal bit width (16 = keep fp)
    balanced: bool = False    # bit-balance strategy (only meaningful at 2 bits)
    symmetric: bool = False   # symmetric (z fixed at midpoint) vs asymmetric
    group: int = 0            # per-group size along K (0 = per-channel/token)

    @property
    def is_fp(self) -> bool:
        return self.bits >= 16

    @property
    def n_levels(self) -> int:
        # balanced 2-bit = {-2..2} -> 5 levels; otherwise 2^bits
        if self.balanced and self.bits == 2:
            return 5
        return 2 ** self.bits

    @property
    def planes(self) -> int:
        """Bit planes needed to store unsigned codes 0..n_levels-1."""
        n = self.n_levels - 1
        p = 0
        while n > 0:
            p += 1
            n >>= 1
        return max(p, 1)


@dataclass(frozen=True)
class WAConfig:
    """Full WqAp quantization configuration (e.g. w2*a8)."""
    weight: QuantSpec
    act: QuantSpec

    @staticmethod
    def parse(s: str) -> "WAConfig":
        """Parse 'w2a8', 'w2*a8', 'w4a4g128', 'fp16' style strings."""
        s = s.strip().lower()
        if s in ("fp16", "fp32", "fp"):
            return WAConfig(QuantSpec(16), QuantSpec(16))
        assert s.startswith("w"), s
        a_at = s.index("a")
        wpart, apart = s[1:a_at], s[a_at + 1:]
        balanced = wpart.endswith("*")
        if balanced:
            wpart = wpart[:-1]
        group = 0
        if "g" in apart:
            apart, g = apart.split("g")
            group = int(g)
        return WAConfig(
            QuantSpec(int(wpart), balanced=balanced, group=group),
            QuantSpec(int(apart)),
        )

    def name(self) -> str:
        if self.weight.is_fp and self.act.is_fp:
            return "fp16"
        star = "*" if self.weight.balanced else ""
        g = f"g{self.weight.group}" if self.weight.group else ""
        return f"w{self.weight.bits}{star}a{self.act.bits}{g}"


# ---------------------------------------------------------------------------
# core quantize/dequantize
# ---------------------------------------------------------------------------

def qparams_minmax(lo, hi, spec: QuantSpec):
    """Scale and zero point from (possibly clipped) min/max.

    Returns (delta, zp) with zp float (kept differentiable; rounded for codes).
    """
    n = spec.n_levels
    if spec.balanced and spec.bits == 2:
        # symmetric 5-level grid centred at 0: delta = max(|lo|,|hi|)/2
        absmax = jnp.maximum(jnp.abs(lo), jnp.abs(hi))
        delta = jnp.maximum(absmax / 2.0, 1e-8)
        zp = jnp.full_like(delta, 2.0)
        return delta, zp
    if spec.symmetric:
        absmax = jnp.maximum(jnp.abs(lo), jnp.abs(hi))
        delta = jnp.maximum(2.0 * absmax / (n - 1), 1e-8)
        zp = jnp.full_like(delta, (n - 1) / 2.0)
        return delta, zp
    delta = jnp.maximum((hi - lo) / (n - 1), 1e-8)
    zp = ste_round(-lo / delta)
    zp = jnp.clip(zp, 0, n - 1)
    return delta, zp


def quantize_codes(x, delta, zp, spec: QuantSpec):
    """x -> unsigned integer codes (float dtype carrying integers, STE-grad)."""
    q = ste_round(x / delta + zp)
    return jnp.clip(q, 0, spec.n_levels - 1)


def dequantize(q, delta, zp):
    return (q - zp) * delta


def fake_quant_weight(w, spec: QuantSpec, alpha=1.0, beta=1.0, comp=None):
    """Per-output-channel fake quantization of W [out, in] with learnable
    clipping (alpha, beta) and optional compensation matrix a·bᵀ (Eq. 3).

    Returns (w_dq, codes, delta, zp); codes/delta/zp have out-channel axis 0.
    """
    if spec.is_fp:
        return w, None, None, None
    if comp is not None:
        w = w + comp
    # keep 0 inside the range (degenerate-row safety; mirrored in rust)
    lo = jnp.minimum(beta * jnp.min(w, axis=1, keepdims=True), 0.0)
    hi = jnp.maximum(alpha * jnp.max(w, axis=1, keepdims=True), 0.0)
    if spec.group and spec.group > 0:
        out, inn = w.shape
        g = spec.group
        assert inn % g == 0, (inn, g)
        wg = w.reshape(out, inn // g, g)
        lo = jnp.minimum(beta * jnp.min(wg, axis=2, keepdims=True), 0.0)
        hi = jnp.maximum(alpha * jnp.max(wg, axis=2, keepdims=True), 0.0)
        delta, zp = qparams_minmax(lo, hi, spec)
        q = quantize_codes(wg, delta, zp, spec)
        wdq = dequantize(q, delta, zp).reshape(out, inn)
        return wdq, q.reshape(out, inn), delta, zp
    delta, zp = qparams_minmax(lo, hi, spec)
    q = quantize_codes(w, delta, zp, spec)
    return dequantize(q, delta, zp), q, delta, zp


def fake_quant_act(x, spec: QuantSpec):
    """Per-token (last-axis dynamic) fake quantization of activations.

    x: [..., features]; statistics are computed over the feature axis,
    giving one (delta, zp) per token, as in the paper.
    """
    if spec.is_fp:
        return x, None, None, None
    lo = jnp.min(x, axis=-1, keepdims=True)
    hi = jnp.max(x, axis=-1, keepdims=True)
    lo = jnp.minimum(lo, 0.0)  # keep 0 representable (post-SiLU etc.)
    hi = jnp.maximum(hi, 0.0)
    delta, zp = qparams_minmax(lo, hi, spec)
    q = quantize_codes(x, delta, zp, spec)
    return dequantize(q, delta, zp), q, delta, zp


# ---------------------------------------------------------------------------
# smoothing / balance vectors
# ---------------------------------------------------------------------------

def smooth_scales(act_absmax, w_absmax, migration=0.5):
    """SmoothQuant-style balance vector s (per input-channel):
    s = act^m / w^(1-m). Activations are divided by s, weights multiplied."""
    s = jnp.power(jnp.maximum(act_absmax, 1e-5), migration) / jnp.power(
        jnp.maximum(w_absmax, 1e-5), 1.0 - migration
    )
    return jnp.maximum(s, 1e-5)


def apply_balance(w, x, s):
    """W·X == (W·diag(s)) · (diag(s)^-1·X) — Eq. (1) rewrite."""
    return w * s[None, :], x / s
