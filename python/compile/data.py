"""Synthetic language-like corpus shared between python (training/calibration)
and rust (`eval::corpus` mirrors the same construction and seed).

WikiText2/C4 are not available in this environment; the corpus below is the
documented substitution (DESIGN.md §4). It is a two-level Markov process:

  * a Zipfian unigram backbone (rank-frequency ~ 1/rank), which gives the
    vocabulary the heavy-tailed shape real text has;
  * a sparse first-order transition structure (each token strongly predicts
    a small successor set), which gives a trained model something real to
    learn, so that quantization-induced damage is measurable as a PPL gap;
  * sentence templates (BOS ... EOS) so attention has an anchor token —
    needed to reproduce the paper's attention-sink observation (Fig. 2).

The generator is a deterministic function of (seed, vocab); rust re-implements
it bit-for-bit (splitmix64 + the same construction) so both sides evaluate
perplexity on the same distribution.
"""
from __future__ import annotations

import numpy as np

BOS = 0  # attention-sink anchor, also sentence separator
VOCAB = 512
BRANCH = 4      # successors per token in the sparse transition structure
FOLLOW = 0.92   # probability of following the sparse transition
RESTART_POOL = 64  # sentence-start tokens are drawn from a small pool


def _splitmix64(state: int) -> tuple[int, int]:
    """Deterministic PRNG mirrored in rust/src/eval/corpus.rs."""
    state = (state + 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF
    z = state
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & 0xFFFFFFFFFFFFFFFF
    z = z ^ (z >> 31)
    return state, z


class SplitMix:
    def __init__(self, seed: int):
        self.state = seed & 0xFFFFFFFFFFFFFFFF

    def next_u64(self) -> int:
        self.state, z = _splitmix64(self.state)
        return z

    def next_f64(self) -> float:
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def next_below(self, n: int) -> int:
        return self.next_u64() % n


def build_transition_table(seed: int = 0xAB9, vocab: int = VOCAB,
                           branch: int = BRANCH) -> tuple[np.ndarray, np.ndarray]:
    """Per-token successor sets and their (normalised cumulative) probabilities.

    Successors are drawn Zipf-weighted, so frequent tokens are frequent
    successors too. Returns (succ[vocab, branch] int32, cum[vocab, branch] f64).
    """
    rng = SplitMix(seed)
    zipf = 1.0 / np.arange(1, vocab + 1, dtype=np.float64)
    zipf /= zipf.sum()
    succ = np.zeros((vocab, branch), dtype=np.int32)
    cum = np.zeros((vocab, branch), dtype=np.float64)
    for t in range(vocab):
        probs = np.zeros(branch, dtype=np.float64)
        for b in range(branch):
            # inverse-cdf sample from the zipf backbone, deterministic
            u = rng.next_f64()
            # cheap inverse: zipf cdf ~ log; do linear scan over a coarse grid
            # (vocab is small so exact scan is fine)
            c = 0.0
            pick = vocab - 1
            for v in range(vocab):
                c += zipf[v]
                if u <= c:
                    pick = v
                    break
            succ[t, b] = max(pick, 1)  # successors never BOS
            # heavily skewed successor probabilities (rank^-1.5): keeps the
            # per-token entropy low so a trained model is *sharp* and
            # quantization damage is measurable (DESIGN.md §4)
            probs[b] = (b + 1.0) ** -1.5
        probs /= probs.sum()
        cum[t] = np.cumsum(probs)
    return succ, cum


_TABLE_CACHE: dict[int, tuple[np.ndarray, np.ndarray]] = {}


def _table(seed: int) -> tuple[np.ndarray, np.ndarray]:
    if seed not in _TABLE_CACHE:
        _TABLE_CACHE[seed] = build_transition_table(seed)
    return _TABLE_CACHE[seed]


def generate_tokens(n_tokens: int, seed: int = 1, table_seed: int = 0xAB9,
                    sentence_len: int = 32, vocab: int = VOCAB) -> np.ndarray:
    """Generate a token stream: BOS-anchored sentences over the Markov table.

    Transitions are *topic-conditioned*: the effective table row is
    `1 + (cur-1 + topic-1) mod (vocab-1)` where `topic` is the sentence's
    first token (right after BOS). A bigram model cannot predict this —
    the transformer must attend back to the sentence start, which (a) makes
    the learned function depend on working attention (so quantization
    damage is measurable, unlike a pure-bigram corpus) and (b) reproduces
    the paper's first-token attention-sink structure (Fig. 2).
    """
    succ, cum = _table(table_seed)
    rng = SplitMix(seed)
    out = np.zeros(n_tokens, dtype=np.int32)
    cur = BOS
    topic = 1
    pos_in_sent = 0
    for i in range(n_tokens):
        if pos_in_sent == 0:
            out[i] = BOS
            topic = 1 + rng.next_below(RESTART_POOL)  # sentence topic token
            cur = topic
            pos_in_sent = 1
            continue
        out[i] = cur
        # FOLLOW: sparse topic-conditioned transition; else random restart
        if rng.next_f64() < FOLLOW:
            state = 1 + ((cur - 1) + (topic - 1)) % (vocab - 1)
            u = rng.next_f64()
            row = cum[state]
            b = int(np.searchsorted(row, u))
            b = min(b, row.shape[0] - 1)
            cur = int(succ[state, b])
        else:
            cur = 1 + rng.next_below(vocab - 1)
        pos_in_sent += 1
        if pos_in_sent >= sentence_len:
            pos_in_sent = 0
    return out


def batches(tokens: np.ndarray, batch: int, seq: int) -> np.ndarray:
    """Chop a stream into [num, batch, seq+1] (inputs+targets) blocks."""
    per = batch * (seq + 1)
    num = len(tokens) // per
    return tokens[: num * per].reshape(num, batch, seq + 1)


def train_eval_split(n_train: int, n_eval: int, seq: int, batch: int):
    """The canonical corpus split used by trainer, calibrator and evaluators."""
    train = generate_tokens(n_train, seed=1)
    evalt = generate_tokens(n_eval, seed=999)  # held out stream
    return batches(train, batch, seq), batches(evalt, batch, seq)
