"""AOT compile path (`make artifacts`): python runs ONCE here, never at serve
time.

Produces, under artifacts/:
  tiny_llama.npz              — trained fp params (python-side reuse)
  weights.abqw                — binary weight pack for the rust native engine
                                (fp weights + per-config integer codes/scales
                                + balance vectors), format documented below
  model_<cfg>_prefill.hlo.txt — L2 jax forward lowered to HLO TEXT
  model_<cfg>_decode.hlo.txt  — single-step decode with KV cache params
  manifest.json               — model config, artifact inventory, parameter
                                flattening order, calibration summary

HLO *text* is the interchange format (NOT proto serialize()): jax >= 0.5
emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
parser reassigns ids (see /opt/xla-example/README.md).

.abqw binary format (rust/src/model/weights.rs parses this):
  magic  b"ABQW1\0"
  u32    n_tensors
  repeat n_tensors:
    u16   name_len, name (utf-8)
    u8    dtype: 0=f32 1=i32 2=u8
    u8    ndim
    u32×ndim dims
    data  (little-endian, C order)
"""
from __future__ import annotations

import argparse
import json
import os
import struct
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data
from . import quantizers as Q
from .calibrate import CalibConfig, calibrate
from .model import (TINY, ModelConfig, forward, forward_decode,
                    init_kv_caches, load_params, perplexity,
                    prepare_weight_qstate, LINEARS)

QUANT_CONFIGS = ["w8a8", "w4a4", "w2*a8"]  # + fp16 implicit
PREFILL_SEQ = 128
DECODE_BATCH = 1


# ---------------------------------------------------------------------------
# HLO text lowering
# ---------------------------------------------------------------------------

def to_hlo_text(lowered) -> str:
    """Lower to HLO *text* with constants printed in full.

    Two hard-won gotchas (validated by the python↔rust logit-parity test
    in rust/tests/integration_artifacts.rs):
      * `print_large_constants=True` is REQUIRED: the default printer
        elides big constant arrays as `constant({...})`, which the
        xla_extension 0.5.1 text parser silently turns into garbage —
        the trace-time-folded RoPE cos/sin tables were being destroyed;
      * `compiler_ir("hlo")` (jax's own conversion) is used rather than
        `mlir_module_to_xla_computation`, keeping parameter order and
        tuple-ness identical to what jax.jit traced.
    """
    comp = lowered.compiler_ir("hlo")
    return comp.as_hlo_text(print_large_constants=True)


def flatten_with_names(tree):
    """Deterministic (name, leaf) list matching jax's tracing order."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    names = []
    for path, _ in paths:
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            else:
                parts.append(str(p))
        names.append(".".join(parts))
    return names, leaves, treedef


# ---------------------------------------------------------------------------
# .abqw writer
# ---------------------------------------------------------------------------

_DTYPES = {np.dtype(np.float32): 0, np.dtype(np.int32): 1,
           np.dtype(np.uint8): 2}


def write_abqw(path: str, tensors: dict[str, np.ndarray]):
    with open(path, "wb") as f:
        f.write(b"ABQW1\0")
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors.items():
            arr = np.ascontiguousarray(arr)
            code = _DTYPES[arr.dtype]
            nb = name.encode()
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", code, arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.tobytes())


# ---------------------------------------------------------------------------
# main export
# ---------------------------------------------------------------------------

def ensure_trained(art: str, steps: int) -> dict:
    npz = os.path.join(art, "tiny_llama.npz")
    if not os.path.exists(npz):
        from .train_tiny import train
        print("[aot] training tiny_llama ...", flush=True)
        train(steps=steps, out=npz)
    return load_params(npz, TINY)


def calibrated_qstates(params, art: str):
    """ABQ-calibrate each exported quant config (cached as npz)."""
    calib = data.generate_tokens(16 * 64, seed=7).reshape(16, 64)
    out = {}
    for cfgname in QUANT_CONFIGS:
        wa = Q.WAConfig.parse(cfgname)
        print(f"[aot] calibrating {cfgname} ...", flush=True)
        qs = calibrate(params, TINY, wa, calib, method="abq",
                       cal=CalibConfig(epochs=6), verbose=True)
        out[cfgname] = qs
    return out


def prepared_for_kernel(params, qstates):
    """Bake calibrated states into integer codes per config."""
    prepared = {}
    for cfgname, qs in qstates.items():
        wa = Q.WAConfig.parse(cfgname)
        blocks = []
        for blk, bqs in zip(params["blocks"], qs):
            entry = {}
            for name in LINEARS:
                entry[name] = prepare_weight_qstate(
                    blk[name], wa, bqs.get(name) if bqs else None)
            blocks.append(entry)
        prepared[cfgname] = blocks
    return prepared


def export_weights(art, params, prepared, qstates):
    tensors: dict[str, np.ndarray] = {}
    tensors["tok_emb"] = np.asarray(params["tok_emb"], np.float32)
    tensors["ln_f"] = np.asarray(params["ln_f"], np.float32)
    tensors["head"] = np.asarray(params["head"], np.float32)
    for i, blk in enumerate(params["blocks"]):
        for k in ("ln1", "ln2", *LINEARS):
            tensors[f"blocks.{i}.{k}"] = np.asarray(blk[k], np.float32)
    for cfgname, blocks in prepared.items():
        tag = cfgname.replace("*", "s")
        for i, entry in enumerate(blocks):
            for name, st in entry.items():
                base = f"q.{tag}.{i}.{name}"
                tensors[f"{base}.wq"] = np.asarray(st["wq"], np.int32).astype(np.uint8)
                tensors[f"{base}.zw"] = np.asarray(st["zw"], np.int32)
                tensors[f"{base}.dw"] = np.asarray(st["dw"], np.float32)
                if "s" in st:
                    tensors[f"{base}.s"] = np.asarray(st["s"], np.float32)
    path = os.path.join(art, "weights.abqw")
    write_abqw(path, tensors)
    print(f"[aot] wrote {path} ({os.path.getsize(path)/1e6:.1f} MB, "
          f"{len(tensors)} tensors)")
    return sorted(tensors)


def lower_artifacts(art, params, prepared):
    manifest_art = []

    def dump(name, lowered, in_names):
        text = to_hlo_text(lowered)
        path = os.path.join(art, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest_art.append({
            "name": name, "path": os.path.basename(path),
            "inputs": in_names,
        })
        print(f"[aot] lowered {name} ({len(text)/1e6:.2f} MB text)")

    tok_spec = jax.ShapeDtypeStruct((1, PREFILL_SEQ), jnp.int32)
    tok1_spec = jax.ShapeDtypeStruct((DECODE_BATCH, 1), jnp.int32)
    pos_spec = jax.ShapeDtypeStruct((), jnp.int32)
    params_spec = jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params)
    kv_spec = jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
        init_kv_caches(TINY, DECODE_BATCH))

    # ---- fp16 (f32 on this testbed) ----
    def fp_prefill(p, toks):
        return (forward(p, toks, TINY),)

    def fp_decode(p, toks, kv, pos):
        logits, kvn = forward_decode(p, toks, kv, pos, TINY)
        return (logits, kvn)

    names_p, _, _ = flatten_with_names(params)
    dump("model_fp16_prefill",
         jax.jit(fp_prefill).lower(params_spec, tok_spec),
         ["params:" + n for n in names_p] + ["tokens"])
    names_kv, _, _ = flatten_with_names(init_kv_caches(TINY, DECODE_BATCH))
    dump("model_fp16_decode",
         jax.jit(fp_decode).lower(params_spec, tok1_spec, kv_spec, pos_spec),
         ["params:" + n for n in names_p] + ["tokens"]
         + ["kv:" + n for n in names_kv] + ["pos"])

    # ---- quantized configs: kernel path (L1 pallas inside) ----
    # NOTE: in kernel mode the fp projection weights are unused, and jax
    # drops unused arguments from the lowered HLO signature. The manifest
    # must list the *kept* parameters only (sorted-key flatten order):
    # per block ln1+ln2, then head, ln_f, tok_emb.
    names_p_used = (
        [f"blocks.{i}.{k}" for i in range(TINY.n_layers) for k in ("ln1", "ln2")]
        + ["head", "ln_f", "tok_emb"]
    )
    for cfgname, blocks in prepared.items():
        wa = Q.WAConfig.parse(cfgname)
        tag = cfgname.replace("*", "s")
        qspec = jax.tree_util.tree_map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), blocks)

        def q_prefill(p, qs, toks, wa=wa):
            return (forward(p, toks, TINY, mode="kernel", wa=wa, qstate=qs),)

        def q_decode(p, qs, toks, kv, pos, wa=wa):
            logits, kvn = forward_decode(p, toks, kv, pos, TINY,
                                         mode="kernel", wa=wa, qstate=qs)
            return (logits, kvn)

        names_q, _, _ = flatten_with_names(blocks)
        dump(f"model_{tag}_prefill",
             jax.jit(q_prefill).lower(params_spec, qspec, tok_spec),
             ["params:" + n for n in names_p_used]
             + ["qstate:" + n for n in names_q] + ["tokens"])
        dump(f"model_{tag}_decode",
             jax.jit(q_decode).lower(params_spec, qspec, tok1_spec,
                                     kv_spec, pos_spec),
             ["params:" + n for n in names_p_used]
             + ["qstate:" + n for n in names_q] + ["tokens"]
             + ["kv:" + n for n in names_kv] + ["pos"])
    return manifest_art


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", type=str, default="../artifacts")
    ap.add_argument("--train-steps", type=int, default=400)
    ap.add_argument("--skip-hlo", action="store_true",
                    help="only weights + calibration (fast iteration)")
    args = ap.parse_args()
    art = os.path.abspath(args.out)
    os.makedirs(art, exist_ok=True)
    t0 = time.time()

    params = ensure_trained(art, args.train_steps)
    eval_b = data.batches(data.generate_tokens(8 * 8 * 129, seed=999), 8, 128)
    fp_ppl = perplexity(params, eval_b, TINY)
    print(f"[aot] fp model held-out PPL {fp_ppl:.3f}")

    qstates = calibrated_qstates(params, art)
    prepared = prepared_for_kernel(params, qstates)
    tensor_names = export_weights(art, params, prepared, qstates)

    arts = [] if args.skip_hlo else lower_artifacts(art, params, prepared)

    manifest = {
        "model": {
            "name": TINY.name,
            "vocab": TINY.vocab, "d_model": TINY.d_model,
            "n_layers": TINY.n_layers, "n_heads": TINY.n_heads,
            "n_kv_heads": TINY.n_kv_heads,
            "d_ff": TINY.d_ff, "max_seq": TINY.max_seq,
            "rope_base": TINY.rope_base,
            "norm": TINY.norm, "act": TINY.act,
            "tied_embeddings": TINY.tied_embeddings,
            "param_count": TINY.param_count(),
        },
        "fp_ppl": fp_ppl,
        "quant_configs": [
            {"name": c, "tag": c.replace("*", "s"),
             "w_bits": Q.WAConfig.parse(c).weight.bits,
             "w_planes": Q.WAConfig.parse(c).weight.planes,
             "a_bits": Q.WAConfig.parse(c).act.bits,
             "balanced": Q.WAConfig.parse(c).weight.balanced}
            for c in QUANT_CONFIGS],
        "prefill_seq": PREFILL_SEQ,
        "decode_batch": DECODE_BATCH,
        "artifacts": arts,
        "corpus": {"vocab": data.VOCAB, "table_seed": 0xAB9,
                   "eval_seed": 999, "branch": data.BRANCH},
        "weights": tensor_names,
    }
    with open(os.path.join(art, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"[aot] done in {time.time()-t0:.0f}s -> {art}")


if __name__ == "__main__":
    main()
