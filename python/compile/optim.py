"""Hand-rolled AdamW (optax is not installed in this image; DESIGN.md §4).

Matches Loshchilov & Hutter 2017 exactly: decoupled weight decay, bias
correction. Works over arbitrary pytrees.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adamw_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.int32)}


def adamw_update(grads, state, params, lr, *, b1=0.9, b2=0.999, eps=1e-8,
                 weight_decay=0.0):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g,
                               state["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g,
                               state["v"], grads)
    bc1 = 1 - b1 ** t.astype(jnp.float32)
    bc2 = 1 - b2 ** t.astype(jnp.float32)

    def upd(p, m_, v_):
        step = lr * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
        if weight_decay:
            step = step + lr * weight_decay * p
        return p - step

    new_params = jax.tree_util.tree_map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "t": t}


def clip_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(g * g) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), gn
