"""ABQ-LLM block-wise calibration (paper §3.1-§3.3, Eq. 1-5).

For every transformer block, sequentially:

  1. collect the block's fp input stream  X_fp  (clean)   and the quantized
     input stream X_q (output of already-calibrated quantized blocks);
  2. learn, with AdamW:
       * per-linear balance vectors  s   (init = SmoothQuant rule)
       * per-linear clipping params  α, β (init = 1)
       * distribution-compensation vectors a, b for `down` of the first and
         last block (init a=1, b=0 → a·bᵀ = 0), per Eq. (3)
     against   L = L_DLC + L_AKL            (Eq. 5)
       L_DLC = -log cos(d_q, d_fp) - log cos(d_q, d_fp*)        (Eq. 2)
       L_AKL = KL(attn_q ‖ attn_fp) + KL(attn_fp ‖ attn_q)      (Eq. 4)
  3. advance both streams.

Baselines implemented on the same scaffolding (same data, same quantizers):
  * rtn         — no smoothing, no learning (round-to-nearest)
  * smoothquant — closed-form s (migration 0.5), no learning
  * omniquant   — learnable s + α/β but plain MSE block loss (no DLC/AKL,
                  no compensation): isolates the contribution of our losses
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from . import quantizers as Q
from .model import (ModelConfig, block_forward, causal_mask, rope_tables,
                    rmsnorm, LINEARS)
from .optim import adamw_init, adamw_update

CALIB_SEED = 42


@dataclass
class CalibConfig:
    epochs: int = 8
    lr_s: float = 5e-3        # balance vectors (paper: 5e-3)
    lr_ab: float = 1e-2       # clipping + compensation (paper: 1e-2)
    samples: int = 16         # calibration sequences (paper: 128 × 2048)
    seq: int = 64
    migration: float = 0.5    # smoothquant init exponent
    use_dlc: bool = True
    use_akl: bool = True
    use_comp: bool = True     # compensation vectors on first/last blocks
    quant_attn: bool = False


def _embed(params, tokens):
    return params["tok_emb"][tokens]


def collect_act_stats(blk, x, cos, sin, cfg, mask):
    """Per-linear input abs-max over the calibration stream (for s init)."""
    cap: dict = {}
    block_forward(blk, x, cos, sin, cfg, mode="fp", mask=mask, capture=cap)
    return {name: jnp.max(jnp.abs(v.reshape(-1, v.shape[-1])), axis=0)
            for name, v in cap.items()}


def init_qstate_for_block(blk, stats, wa: Q.WAConfig, cal: CalibConfig,
                          with_comp: bool):
    """Learnable parameter pytree for one block."""
    qs = {}
    for name in LINEARS:
        w = blk[name]
        w_absmax = jnp.max(jnp.abs(w), axis=0)  # per input channel
        s = Q.smooth_scales(stats[name], w_absmax, cal.migration)
        entry = {"s": s, "alpha": jnp.array(1.0), "beta": jnp.array(1.0)}
        if with_comp and name == "down":
            entry["comp_a"] = jnp.ones(w.shape[0], jnp.float32) * 1e-4
            entry["comp_b"] = jnp.zeros(w.shape[1], jnp.float32)
        qs[name] = entry
    return qs


def dlc_loss(d_q, d_fp, d_fp_star):
    """Eq. (2): double log-cosine distribution-correction loss (per token)."""
    def logcos(a, b):
        a2 = a.reshape(-1, a.shape[-1])
        b2 = b.reshape(-1, b.shape[-1])
        num = jnp.sum(a2 * b2, axis=-1)
        den = jnp.linalg.norm(a2, axis=-1) * jnp.linalg.norm(b2, axis=-1)
        cos = jnp.clip(num / jnp.maximum(den, 1e-8), 1e-4, 1.0)
        return -jnp.mean(jnp.log(cos))
    return logcos(d_q, d_fp) + logcos(d_q, d_fp_star)


def akl_loss(attn_q, attn_fp, eps=1e-8):
    """Eq. (4): symmetric attention-map KL."""
    p = attn_fp + eps
    q = attn_q + eps
    kl_pq = jnp.sum(p * (jnp.log(p) - jnp.log(q)), axis=-1)
    kl_qp = jnp.sum(q * (jnp.log(q) - jnp.log(p)), axis=-1)
    return jnp.mean(kl_pq + kl_qp)


def mse_loss(d_q, d_fp):
    return jnp.mean((d_q - d_fp) ** 2)


def calibrate(params, cfg: ModelConfig, wa: Q.WAConfig, calib_tokens,
              method: str = "abq", cal: CalibConfig | None = None,
              verbose: bool = True):
    """Run block-wise calibration.

    calib_tokens: [num_samples, seq] int array.
    Returns qstate: list per block of per-linear dicts (jnp arrays), ready
    for model.forward(mode='fake') or prepare_weight_qstate -> kernel path.
    """
    cal = cal or CalibConfig()
    tokens = jnp.array(np.asarray(calib_tokens)[: cal.samples, : cal.seq])
    S = tokens.shape[1]
    cos, sin = rope_tables(cfg, jnp.arange(S))
    mask = causal_mask(S)

    x_fp = _embed(params, tokens)
    x_q = x_fp
    qstate_out = []
    t0 = time.time()

    for i, blk in enumerate(params["blocks"]):
        if method == "rtn":
            qstate_out.append(None)
            x_fp, _, _ = block_forward(blk, x_fp, cos, sin, cfg, mode="fp",
                                       mask=mask)
            x_q, _, _ = block_forward(blk, x_q, cos, sin, cfg, mode="fake",
                                      wa=wa, qstate=None, mask=mask)
            continue

        stats = collect_act_stats(blk, x_q, cos, sin, cfg, mask)
        with_comp = (cal.use_comp and method == "abq"
                     and i in (0, cfg.n_layers - 1))
        qs = init_qstate_for_block(blk, stats, wa, cal, with_comp)

        if method == "smoothquant":
            # closed-form s only; drop learnables
            qs = {name: {"s": qs[name]["s"]} for name in LINEARS}
            qstate_out.append(qs)
            x_fp, _, _ = block_forward(blk, x_fp, cos, sin, cfg, mode="fp",
                                       mask=mask)
            x_q, _, _ = block_forward(blk, x_q, cos, sin, cfg, mode="fake",
                                      wa=wa, qstate=qs, mask=mask)
            continue

        # targets (constant w.r.t. the learnables)
        d_fp, attn_fp, _ = block_forward(blk, x_fp, cos, sin, cfg, mode="fp",
                                         mask=mask, want_attn=True)
        d_fp_star, _, _ = block_forward(blk, x_q, cos, sin, cfg, mode="fp",
                                        mask=mask)

        def loss_fn(qs_, blk_=blk, x_q_=x_q, d_fp_=d_fp,
                    d_fp_star_=d_fp_star, attn_fp_=attn_fp):
            d_q, attn_q, _ = block_forward(blk_, x_q_, cos, sin, cfg,
                                           mode="fake", wa=wa, qstate=qs_,
                                           mask=mask, want_attn=True)
            if method == "omniquant":
                return mse_loss(d_q, d_fp_)
            loss = 0.0
            if cal.use_dlc:
                loss = loss + dlc_loss(d_q, d_fp_, d_fp_star_)
            else:
                loss = loss + mse_loss(d_q, d_fp_)
            if cal.use_akl:
                loss = loss + akl_loss(attn_q, attn_fp_)
            return loss

        # two AdamW groups: s at lr_s; alpha/beta/comp at lr_ab
        opt = adamw_init(qs)
        grad_fn = jax.jit(jax.value_and_grad(loss_fn))

        def lr_tree(qs_):
            return jax.tree_util.tree_map_with_path(
                lambda path, _: cal.lr_s
                if any(getattr(p, "key", None) == "s" for p in path)
                else cal.lr_ab, qs_)

        lrs = lr_tree(qs)
        last = None
        for ep in range(cal.epochs):
            loss, grads = grad_fn(qs)
            # per-group lr: scale grads so a single adamw lr works
            qs_new, opt = adamw_update(grads, opt, qs, 1.0)
            # adamw_update applied lr=1; rescale step by the group lr
            qs = jax.tree_util.tree_map(
                lambda old, new, lr: old + (new - old) * lr, qs, qs_new, lrs)
            last = float(loss)
        qstate_out.append(qs)
        if verbose:
            print(f"  [calibrate/{method}] block {i}: loss {last:.5f} "
                  f"({time.time()-t0:.1f}s)", flush=True)

        x_fp, _, _ = block_forward(blk, x_fp, cos, sin, cfg, mode="fp",
                                   mask=mask)
        x_q, _, _ = block_forward(blk, x_q, cos, sin, cfg, mode="fake",
                                  wa=wa, qstate=qs, mask=mask)

    return qstate_out


def qstate_stop_gradients(qstate):
    """Detach all learned tensors (post-calibration)."""
    return jax.tree_util.tree_map(jax.lax.stop_gradient, qstate)
