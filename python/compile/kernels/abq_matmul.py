"""L1 Pallas kernel: arbitrary-bit quantized matmul via bit-plane decomposition.

This is the paper's ABQKernel (§3.4, Appendix B) re-thought for TPU:

  * the GPU version packs bit-planes into global memory ([p, M, K] layout)
    and feeds Binary TensorCore BMMA (m8n8k128 AND+popc) per (s, t) plane
    pair, then does Bit Reduction `Y = Σ 2^{s+t} Y^{s,t}` in shared memory;
  * on TPU there is no 1-bit MAC, but the MXU eats int/fp matmuls of {0,1}
    planes at full rate, so the same decomposition maps each (s, t) plane
    pair to one MXU pass over a VMEM-resident tile. BlockSpec expresses the
    HBM→VMEM schedule that threadblock tiling expressed on the GPU; the
    plane loop is unrolled inside the kernel so the Bit Reduction accumulator
    lives in registers/VMEM, exactly like the GPU's c-fragment epilogue.

The kernel is exact integer arithmetic (accumulates in int32), so pytest
asserts bit-identical equality with kernels/ref.py.

Pallas runs with interpret=True: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret mode lowers to plain HLO that both the python
tests and the rust runtime execute. Real-TPU perf is *estimated* in
DESIGN.md §9 from the VMEM footprint / MXU pass count.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile sizes: the "thread block tile" of the paper. On TPU these would be
# MXU-aligned (128); in interpret mode they just bound the VMEM working set.
DEFAULT_BM = 64
DEFAULT_BN = 64


def _abq_kernel(xq_ref, wq_ref, zx_ref, zw_ref, out_ref, *, p_bits, q_bits):
    """One (BM, BN) output tile. K is kept whole per tile (fits VMEM for the
    layer shapes we lower; the BlockSpec index_map streams M/N).

    xq_ref: [BM, K] unsigned activation codes (int32)
    wq_ref: [BN, K] unsigned weight codes (int32)
    zx_ref: [BM, 1] per-token zero points (int32)
    zw_ref: [BN, 1] per-channel zero points (int32)
    out_ref:[BM, BN] int32 integer product
    """
    xq = xq_ref[...]
    wq = wq_ref[...]
    k = xq.shape[-1]

    acc = jnp.zeros(out_ref.shape, dtype=jnp.int32)
    # --- the p×q BMMA superposition (unrolled: p_bits/q_bits are static) ---
    for s in range(p_bits):
        xs = ((xq >> s) & 1).astype(jnp.int32)
        for t in range(q_bits):
            wt = ((wq >> t) & 1).astype(jnp.int32)
            # BMMA(Xs, Wt): {0,1}×{0,1} matmul == popcount(AND) per (m, n).
            bmma = jax.lax.dot_general(
                xs, wt,
                dimension_numbers=(((1,), (1,)), ((), ())),
                preferred_element_type=jnp.int32,
            )
            # --- Bit Reduction: scale by 2^(s+t) while accumulating ---
            acc = acc + (bmma << (s + t))

    # --- zero-point correction (the engine's epilogue) ---
    zx = zx_ref[...]            # [BM, 1]
    zw = zw_ref[...]            # [BN, 1]
    xsum = jnp.sum(xq, axis=1, keepdims=True, dtype=jnp.int32)   # [BM, 1]
    wsum = jnp.sum(wq, axis=1, keepdims=True, dtype=jnp.int32)   # [BN, 1]
    acc = acc - zx * wsum.T - xsum * zw.T + jnp.int32(k) * zx * zw.T
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("p_bits", "q_bits", "bm", "bn"))
def abq_matmul_int(xq, wq, zx, zw, *, p_bits, q_bits,
                   bm=DEFAULT_BM, bn=DEFAULT_BN):
    """Integer ABQ matmul: codes -> int32 product with zero-point correction.

    xq: [M, K] int32 unsigned codes (p_bits wide)
    wq: [N, K] int32 unsigned codes (q_bits wide)
    zx: [M] int32, zw: [N] int32
    returns [M, N] int32
    """
    m, k = xq.shape
    n, _ = wq.shape
    bm = min(bm, m)
    bn = min(bn, n)
    # pad M/N to tile multiples (K stays whole)
    mp = (m + bm - 1) // bm * bm
    np_ = (n + bn - 1) // bn * bn
    xq_p = jnp.pad(xq, ((0, mp - m), (0, 0)))
    wq_p = jnp.pad(wq, ((0, np_ - n), (0, 0)))
    zx_p = jnp.pad(zx.reshape(-1, 1), ((0, mp - m), (0, 0)))
    zw_p = jnp.pad(zw.reshape(-1, 1), ((0, np_ - n), (0, 0)))

    grid = (mp // bm, np_ // bn)
    out = pl.pallas_call(
        functools.partial(_abq_kernel, p_bits=p_bits, q_bits=q_bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, k), lambda i, j: (j, 0)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        interpret=True,
    )(xq_p.astype(jnp.int32), wq_p.astype(jnp.int32),
      zx_p.astype(jnp.int32), zw_p.astype(jnp.int32))
    return out[:m, :n]


def abq_matmul_fp(xq, wq, zx, zw, dx, dw, *, p_bits, q_bits,
                  bm=DEFAULT_BM, bn=DEFAULT_BN):
    """Dequantized ABQ matmul: Y = dx ⊙ Y_int ⊙ dw (per-token × per-channel)."""
    yint = abq_matmul_int(xq, wq, zx, zw, p_bits=p_bits, q_bits=q_bits,
                          bm=bm, bn=bn)
    return yint.astype(jnp.float32) * dx[:, None] * dw[None, :]


def quantize_act_per_token(x, bits):
    """Dynamic per-token activation quantization to unsigned codes.

    Matches quantizers.fake_quant_act but returns the integer pieces the
    kernel consumes: (codes int32 [M,K], zp int32 [M], delta f32 [M]).
    """
    lo = jnp.minimum(jnp.min(x, axis=-1), 0.0)
    hi = jnp.maximum(jnp.max(x, axis=-1), 0.0)
    n = (1 << bits) - 1
    delta = jnp.maximum((hi - lo) / n, 1e-8)
    zp = jnp.clip(jnp.round(-lo / delta), 0, n).astype(jnp.int32)
    q = jnp.clip(jnp.round(x / delta[:, None]) + zp[:, None], 0, n)
    return q.astype(jnp.int32), zp, delta


def quantized_linear(x, wq, zw, dw, *, w_bits, a_bits,
                     balance=None, w_planes=None):
    """Full quantized linear on the artifact path: dynamic per-token act
    quant -> pallas integer kernel -> dequant.

    x: [M, K] f32 activations; wq/zw/dw: prepared weight codes/zps/scales
    balance: optional per-channel balance vector s (activations divided by s
             *before* quantization — the calibrated Eq. (1) rewrite).
    w_planes: stored plane count for balanced weights (3 for w2*).
    """
    if balance is not None:
        x = x / balance[None, :]
    xq, zx, dx = quantize_act_per_token(x, a_bits)
    q_bits = w_planes if w_planes is not None else w_bits
    return abq_matmul_fp(xq, wq, zx, zw, dx, dw,
                         p_bits=a_bits, q_bits=q_bits)
