"""Pure-jnp oracle for the arbitrary-bit quantized matmul.

The ABQ contract (paper Appendix B, Eq. 8-10): given unsigned activation
codes Xq [M, K] (p-bit, zero point zx per token) and unsigned weight codes
Wq [N, K] (q-bit, zero point zw per channel),

    Y_int[m, n] = sum_k (Xq[m,k] - zx[m]) * (Wq[n,k] - zw[n])
    Y_fp  [m,n] = dx[m] * dw[n] * Y_int[m,n]

The engine computes Y_int as a superposition of 1-bit matmuls:

    Y_int = sum_{s<p} sum_{t<q} 2^{s+t} BMMA(Xs, Wt)
            - zx * rowsum(Wq) - zw * rowsum(Xq) + K * zx * zw

This module provides both the *direct* integer reference (used as the
correctness oracle for the Pallas kernel and the rust engine) and the
*decomposed* reference (used to validate the decomposition algebra itself).
"""
from __future__ import annotations

import jax.numpy as jnp


def quant_matmul_int(xq, wq, zx, zw):
    """Direct integer oracle.

    xq: [M, K] unsigned codes (int32); wq: [N, K] unsigned codes (int32)
    zx: [M] per-token zero points;     zw: [N] per-channel zero points
    returns Y_int [M, N] int32
    """
    xq = xq.astype(jnp.int32)
    wq = wq.astype(jnp.int32)
    xc = xq - zx.astype(jnp.int32)[:, None]
    wc = wq - zw.astype(jnp.int32)[:, None]
    return xc @ wc.T


def quant_matmul_decomposed(xq, wq, zx, zw, p_bits, q_bits):
    """Bit-plane decomposed reference — Eq. (8)-(10) executed literally.

    Every plane matmul BMMA(Xs, Wt) is an AND-accumulate over {0,1} planes,
    exactly what a Binary TensorCore computes.
    """
    xq = xq.astype(jnp.int32)
    wq = wq.astype(jnp.int32)
    m, k = xq.shape
    n, _ = wq.shape
    acc = jnp.zeros((m, n), dtype=jnp.int32)
    for s in range(p_bits):
        xs = (xq >> s) & 1
        for t in range(q_bits):
            wt = (wq >> t) & 1
            bmma = xs @ wt.T  # popcount(AND) == dot of {0,1} vectors
            acc = acc + (bmma << (s + t))
    k_ = jnp.int32(k)
    zx_i = zx.astype(jnp.int32)[:, None]
    zw_i = zw.astype(jnp.int32)[None, :]
    xsum = jnp.sum(xq, axis=1, dtype=jnp.int32)[:, None]
    wsum = jnp.sum(wq, axis=1, dtype=jnp.int32)[None, :]
    return acc - zx_i * wsum - zw_i * xsum + k_ * zx_i * zw_i


def quant_matmul_fp(xq, wq, zx, zw, dx, dw):
    """Dequantized output: dx per token [M], dw per channel [N]."""
    yint = quant_matmul_int(xq, wq, zx, zw)
    return yint.astype(jnp.float32) * dx[:, None] * dw[None, :]
