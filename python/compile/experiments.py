"""Accuracy-side experiment harnesses (Figures 1, 2, 7; Tables 1, 2, 5-7).

Each harness prints a paper-vs-measured table and writes
results/<name>.json. Run via `make exp-<name>` or `python -m
compile.experiments all`.

The engine-side experiments (Fig 5, 6; Tables 4, 12, 13, 14) live in the
rust benches (see DESIGN.md §6).
"""
from __future__ import annotations

import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data
from . import quantizers as Q
from .calibrate import CalibConfig, calibrate
from .model import (TINY, causal_mask, forward, init_params, load_params,
                    perplexity, rope_tables, block_forward, LINEARS)

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "results")
ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _save(name, obj):
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, f"{name}.json")
    with open(path, "w") as f:
        json.dump(obj, f, indent=2)
    print(f"[saved] {path}")


def _load_model():
    path = os.path.join(ART, "tiny_llama.npz")
    if not os.path.exists(path):
        raise SystemExit("run `make artifacts` first (trains tiny_llama)")
    return load_params(path, TINY)


def _eval_batches(n=6, batch=8, seq=128):
    toks = data.generate_tokens(n * batch * (seq + 1), seed=999)
    return data.batches(toks, batch, seq)


def _calib_tokens(samples=16, seq=64):
    toks = data.generate_tokens(samples * seq, seed=CALIB_SEED_STREAM)
    return toks.reshape(samples, seq)


CALIB_SEED_STREAM = 7


def _ppl(params, eval_b, **fw):
    return perplexity(params, eval_b, TINY, **fw)


# ---------------------------------------------------------------------------
# Figure 1: per-component quantization sensitivity
# ---------------------------------------------------------------------------

def fig1():
    """Quantize one component class at a time (W4A4 RTN) and measure PPL.

    Paper finding: down_proj (mostly its *activation*) dominates the damage;
    q/k/v/gate/up are mild.
    """
    params = _load_model()
    eval_b = _eval_batches()
    wa = Q.WAConfig.parse("w4a4")
    base = _ppl(params, eval_b)
    rows = {"fp16": base}

    groups = {
        "q_proj": ["wq"], "k_proj": ["wk"], "v_proj": ["wv"],
        "o_proj": ["wo"], "gate_proj": ["gate"], "up_proj": ["up"],
        "down_proj": ["down"], "all": list(LINEARS),
    }
    # selective quantization: wrap forward with per-linear WA override
    for gname, members in groups.items():
        qstate = None
        # monkey-style: use a per-linear wa map through qstate trick —
        # easiest correct route: temporarily zero out quantization for
        # non-members by running a custom forward.
        ppl = _ppl_selective(params, eval_b, wa, members)
        rows[gname] = ppl
        print(f"  fig1: quantize {gname:10s} -> PPL {ppl:9.3f} "
              f"(fp {base:.3f})", flush=True)
    _save("fig1_sensitivity", rows)
    return rows


def _ppl_selective(params, eval_b, wa, members):
    """PPL with only `members` linears quantized (RTN fake-quant)."""
    from .model import ModelConfig, rmsnorm as _rms

    def fw(tokens):
        B, S = tokens.shape
        x = params["tok_emb"][tokens]
        cos, sin = rope_tables(TINY, jnp.arange(S))
        mask = causal_mask(S)
        for blk in params["blocks"]:
            x = _selective_block(blk, x, cos, sin, mask, wa, members)
        x = _rms(x, params["ln_f"])
        return x @ params["head"].T

    total, count = 0.0, 0
    for b in np.asarray(eval_b):
        inp, tgt = jnp.array(b[:, :-1]), jnp.array(b[:, 1:])
        logits = fw(inp)
        logp = jax.nn.log_softmax(logits, axis=-1)
        ll = jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
        total += float(-jnp.mean(ll)) * tgt.size
        count += tgt.size
    return float(np.exp(total / count))


def _selective_block(blk, x, cos, sin, mask, wa, members):
    import math as _m
    from .model import apply_rope, rmsnorm as _rms
    B, S, D = x.shape
    H, hd = TINY.n_heads, TINY.head_dim

    def lin(name, inp):
        mode = "fake" if name in members else "fp"
        from .model import linear
        return linear(inp, blk[name], mode=mode, wa=wa, qs=None)

    h = _rms(x, blk["ln1"])
    q = lin("wq", h).reshape(B, S, H, hd)
    k = lin("wk", h).reshape(B, S, H, hd)
    v = lin("wv", h).reshape(B, S, H, hd)
    q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
    scores = jnp.einsum("bshd,bthd->bhst", q, k) / _m.sqrt(hd)
    attn = jax.nn.softmax(scores + mask, axis=-1)
    ctx = jnp.einsum("bhst,bthd->bshd", attn, v).reshape(B, S, D)
    x = x + lin("wo", ctx)
    h2 = _rms(x, blk["ln2"])
    act = jax.nn.silu(lin("gate", h2)) * lin("up", h2)
    return x + lin("down", act)


# ---------------------------------------------------------------------------
# Figure 2: attention maps / first-token attention sink
# ---------------------------------------------------------------------------

def fig2():
    """First-token ('attention sink') mass, FP vs quantized, first/last block.

    Paper finding: quantization destroys the sink; AKL-calibrated model
    restores it.
    """
    params = _load_model()
    toks = jnp.array(_calib_tokens(4, 64))
    wa = Q.WAConfig.parse("w4a4")

    def sink_mass(mode, qstate=None):
        _, attns = forward(params, toks, TINY, mode=mode, wa=wa,
                           qstate=qstate, want_attn=True)
        # mean attention mass on key position 0, per block (skip query 0)
        return [float(jnp.mean(a[:, :, 1:, 0])) for a in attns]

    fp = sink_mass("fp")
    rtn = sink_mass("fake", None)
    qs = calibrate(params, TINY, wa, _calib_tokens(), method="abq",
                   cal=CalibConfig(epochs=6), verbose=False)
    abq = sink_mass("fake", qs)
    out = {"fp": fp, "rtn_w4a4": rtn, "abq_w4a4": abq}
    for k, v in out.items():
        print(f"  fig2 sink-mass {k:10s}: " +
              " ".join(f"{x:.4f}" for x in v), flush=True)
    _save("fig2_attention_sink", out)
    return out


# ---------------------------------------------------------------------------
# Table 1 / Fig 7: weight-only + bit balance
# ---------------------------------------------------------------------------

def table1():
    """W4A16 / W3A16 / W2A16 / W2*A16 (bit balance rescue) — paper Table 1."""
    params = _load_model()
    eval_b = _eval_batches()
    rows = {"fp16": _ppl(params, eval_b)}
    for cfgname in ("w4a16", "w3a16", "w2a16", "w2*a16"):
        wa = Q.WAConfig.parse(cfgname)
        qs = calibrate(params, TINY, wa, _calib_tokens(), method="abq",
                       cal=CalibConfig(epochs=6), verbose=False)
        rows[cfgname] = _ppl(params, eval_b, mode="fake", wa=wa, qstate=qs)
        print(f"  table1 {cfgname:8s}: PPL {rows[cfgname]:9.3f}", flush=True)
    _save("table1_weight_only", rows)
    print_t1_verdict(rows)
    return rows


def print_t1_verdict(rows):
    ok = rows["w2*a16"] < rows["w2a16"]
    print(f"  table1 verdict: bit-balance W2* {'<' if ok else '!<'} W2 "
          f"({rows['w2*a16']:.2f} vs {rows['w2a16']:.2f}) — paper: 7.50 vs 11.48")


# ---------------------------------------------------------------------------
# Table 2 (+6/7): weight-activation quantization, method comparison
# ---------------------------------------------------------------------------

def table2():
    """ABQ vs RTN vs SmoothQuant vs OmniQuant-lite over WqAp combos."""
    params = _load_model()
    eval_b = _eval_batches()
    calib = _calib_tokens()
    combos = ["w8a8", "w6a6", "w4a8", "w4a4", "w2a8", "w2*a8"]
    methods = ["rtn", "smoothquant", "omniquant", "abq"]
    rows: dict = {"fp16": {"ppl": _ppl(params, eval_b)}}
    for cfgname in combos:
        wa = Q.WAConfig.parse(cfgname)
        rows[cfgname] = {}
        for method in methods:
            if method != "abq" and cfgname == "w2*a8":
                continue  # bit balance is ours
            t0 = time.time()
            qs = calibrate(params, TINY, wa, calib, method=method,
                           cal=CalibConfig(epochs=6), verbose=False)
            ppl = _ppl(params, eval_b, mode="fake", wa=wa, qstate=qs)
            rows[cfgname][method] = ppl
            print(f"  table2 {cfgname:7s} {method:12s}: PPL {ppl:10.3f} "
                  f"({time.time()-t0:.0f}s)", flush=True)
    _save("table2_wa_quant", rows)
    return rows


# ---------------------------------------------------------------------------
# Table 5: per-group quantization
# ---------------------------------------------------------------------------

def table5():
    params = _load_model()
    eval_b = _eval_batches()
    rows = {"fp16": _ppl(params, eval_b)}
    for cfgname in ("w4a4", "w4a4g32"):
        wa = Q.WAConfig.parse(cfgname)
        qs = calibrate(params, TINY, wa, _calib_tokens(), method="abq",
                       cal=CalibConfig(epochs=6), verbose=False)
        rows[cfgname] = _ppl(params, eval_b, mode="fake", wa=wa, qstate=qs)
        print(f"  table5 {cfgname:8s}: PPL {rows[cfgname]:9.3f}", flush=True)
    _save("table5_per_group", rows)
    return rows


# ---------------------------------------------------------------------------
# Figure 7: Q-Q / symmetry of INT2 vs INT2* quantized weights
# ---------------------------------------------------------------------------

def fig7():
    """Skewness of dequantized o_proj weights: fp vs INT2 vs INT2*."""
    params = _load_model()
    out = {}
    for bi in (0, TINY.n_layers - 1):
        w = params["blocks"][bi]["wo"]
        row = {"fp_skew": _skew(w)}
        for name, spec in (("int2", Q.QuantSpec(2)),
                           ("int2*", Q.QuantSpec(2, balanced=True))):
            wdq, *_ = Q.fake_quant_weight(w, spec)
            row[f"{name}_skew"] = _skew(wdq)
            row[f"{name}_err"] = float(jnp.mean(jnp.abs(wdq - w)))
        out[f"block{bi}"] = row
        print(f"  fig7 block{bi}: " +
              " ".join(f"{k}={v:.4f}" for k, v in row.items()), flush=True)
    _save("fig7_qq_symmetry", out)
    return out


def _skew(w):
    w = w.reshape(-1)
    mu = jnp.mean(w)
    sd = jnp.std(w) + 1e-9
    return float(jnp.mean(((w - mu) / sd) ** 3))


# ---------------------------------------------------------------------------

ALL = {"fig1": fig1, "fig2": fig2, "fig7": fig7, "table1": table1,
       "table2": table2, "table5": table5}


def main():
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    t0 = time.time()
    if which == "all":
        for name, fn in ALL.items():
            print(f"=== {name} ===", flush=True)
            fn()
    else:
        ALL[which]()
    print(f"done in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
