"""Train the tiny-LLaMA on the synthetic corpus (build path, `make artifacts`).

A few hundred AdamW steps take the model from PPL≈vocab (512, random) to a
structured-corpus PPL low enough that quantization damage is measurable —
the property every accuracy experiment in the paper depends on.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data
from .model import TINY, ModelConfig, init_params, nll, perplexity, save_params
from .optim import adamw_init, adamw_update, clip_global_norm


def train(cfg: ModelConfig = TINY, steps: int = 400, batch: int = 16,
          seq: int = 128, lr: float = 3e-3, seed: int = 0,
          log_every: int = 50, out: str | None = None):
    train_b, eval_b = data.train_eval_split(
        n_train=steps * batch * (seq + 1) + batch * (seq + 1),
        n_eval=16 * batch * (seq + 1), seq=seq, batch=batch)
    params = init_params(cfg, seed=seed)
    opt = adamw_init(params)

    @jax.jit
    def step_fn(params, opt, tokens, lr_now):
        loss, grads = jax.value_and_grad(nll)(params, tokens, cfg)
        grads, gn = clip_global_norm(grads, 1.0)
        params, opt = adamw_update(grads, opt, params, lr_now,
                                   weight_decay=0.01)
        return params, opt, loss, gn

    t0 = time.time()
    losses = []
    warmup = 20
    for i in range(steps):
        tokens = jnp.array(train_b[i % train_b.shape[0]])
        frac = min(1.0, (i + 1) / warmup)
        decay = 0.5 * (1 + np.cos(np.pi * i / steps))
        lr_now = lr * frac * (0.1 + 0.9 * decay)
        params, opt, loss, gn = step_fn(params, opt, tokens, lr_now)
        losses.append(float(loss))
        if (i + 1) % log_every == 0 or i == 0:
            print(f"step {i+1:4d}  loss {float(loss):.4f}  "
                  f"ppl {np.exp(float(loss)):8.2f}  "
                  f"gnorm {float(gn):6.3f}  {time.time()-t0:5.1f}s",
                  flush=True)

    ppl = perplexity(params, eval_b[:8], cfg)
    print(f"final held-out PPL (fp): {ppl:.3f}")
    if out:
        save_params(params, out)
        print(f"saved params -> {out}")
    return params, ppl, losses


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--out", type=str, default="../artifacts/tiny_llama.npz")
    args = ap.parse_args()
    train(steps=args.steps, out=args.out)
