#!/usr/bin/env sh
# Record a decode hot-path benchmark run into BENCH_decode.json.
#
# Usage: scripts/record_decode_bench.sh <label>
#   e.g.  scripts/record_decode_bench.sh pre    # before a perf change
#         scripts/record_decode_bench.sh post   # after, same machine
#         scripts/record_decode_bench.sh ci     # the CI bench-smoke job
#
# Runs the decode_hotpath bench in release mode with ABQ_RECORD set; the
# bench appends a labelled entry (per-backend tok/s, ms/step,
# ns/projection, unix timestamp) to BENCH_decode.json at the repo root.
# Set ABQ_BENCH_FAST=1 for a short smoke run, ABQ_KV_BITS=8|4 to measure
# the quantized paged-KV read path, ABQ_SPEC=<draft>:<k> for the
# self-speculative rung, ABQ_PREFIX=1 for the prefix-cache rung
# (shared-system-prompt TTFT + admission capacity), ABQ_REPLICAS=N for
# the multi-replica saturation rung (requests/s + p95 TTFT at 1 vs N
# replicas over one shared weight set), ABQ_AUTOPILOT=1 for the
# adaptive-precision overload rung (the same burst served by a fixed
# w6a6 deployment vs the default ladder under an SLA-driven autopilot;
# records req/s for both, the overload gain, and the shift counters —
# docs/SERVING.md §adaptive precision), and
# ABQ_ISA=scalar|avx2|avx512|neon to lower the SIMD dispatch ceiling —
# record a `pre` run with ABQ_ISA=scalar and a `post` run without it for
# a scalar-vs-SIMD pair on the same machine (each entry stores the
# ceiling it ran at in its `isa` field).
set -eu
label="${1:?usage: record_decode_bench.sh <label (e.g. pre|post|ci)>}"
if ! command -v cargo >/dev/null 2>&1; then
    echo "error: cargo not found on PATH — install a rust toolchain" >&2
    echo "       (rustup.rs; the repo pins channel/components in rust-toolchain.toml)." >&2
    echo "       Without it this script records nothing, and BENCH_decode.json" >&2
    echo "       stays empty." >&2
    exit 1
fi
cd "$(dirname "$0")/../rust"
echo "kernel ISA ceiling: ${ABQ_ISA:-auto (detected at runtime; bench prints the resolved ISA)}"
ABQ_RECORD="$label" cargo bench --bench decode_hotpath
