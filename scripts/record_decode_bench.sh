#!/usr/bin/env sh
# Record a decode hot-path benchmark run into BENCH_decode.json.
#
# Usage: scripts/record_decode_bench.sh <label>
#   e.g.  scripts/record_decode_bench.sh pre    # before a perf change
#         scripts/record_decode_bench.sh post   # after, same machine
#
# Runs the decode_hotpath bench in release mode with ABQ_RECORD set; the
# bench appends a labelled entry (per-backend tok/s, ms/step,
# ns/projection, unix timestamp) to BENCH_decode.json at the repo root.
set -eu
label="${1:?usage: record_decode_bench.sh <label (e.g. pre|post)>}"
cd "$(dirname "$0")/../rust"
ABQ_RECORD="$label" cargo bench --bench decode_hotpath
