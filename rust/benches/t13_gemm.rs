//! Tables 13/14 reproduction: GEMM TOPS across WqAp combos × layer shapes
//! (LLaMA-7B/13B dims, M ∈ {1, 4, 8}), ABQ engine vs CUTLASS/cuBLAS
//! stand-ins.
//!
//! Default runs a representative subset; set `ABQ_BENCH_FULL=1` for the
//! full 12-combo × 8-shape sweep of the paper's appendix tables.
//!
//! Expected shape (paper Tables 13/14): ABQ TOPS grow as bits shrink
//! (w2a2 highest), beat the baselines at every combo the baselines can't
//! run natively (w2aX, w3aX, w5+, w6a6...), and the gap narrows toward
//! w8a8 where the padded INT8 unit is at its native precision.

use abq_llm::abq::gemm::gemm_int_into;
use abq_llm::abq::search::best_config;
use abq_llm::abq::{BitPlanes, OptLevel};
use abq_llm::engine::{BackendRegistry, LinearBackend, LinearOp, PrepareCtx};
use abq_llm::util::bench::{write_results, Bencher};
use abq_llm::util::json::{num, obj, s, Json};
use abq_llm::util::rng::SplitMix;

fn main() {
    let full = std::env::var("ABQ_BENCH_FULL").is_ok();
    let bencher = Bencher::default();
    let registry = BackendRegistry::with_defaults();
    let mut rng = SplitMix::new(13);

    // (M, K, N): LLaMA-7B attention + MLP and 13B attention shapes
    let shapes: Vec<(usize, usize, usize)> = if full {
        vec![
            (1, 4096, 4096), (1, 1024, 8192), (1, 11008, 4096), (1, 5120, 5120),
            (1, 4096, 11008), (8, 4096, 4096), (8, 1024, 8192), (8, 11008, 4096),
            (8, 5120, 5120), (8, 4096, 11008), (4, 4096, 4096), (4, 5120, 5120),
        ]
    } else {
        vec![(1, 4096, 4096), (8, 4096, 4096), (1, 4096, 11008), (4, 5120, 5120)]
    };
    let combos: Vec<(usize, usize)> = if full {
        vec![(2, 2), (2, 4), (2, 6), (2, 8), (3, 3), (3, 8), (4, 4), (4, 8), (5, 5), (6, 6), (7, 7), (8, 8)]
    } else {
        vec![(2, 2), (2, 8), (3, 8), (4, 4), (6, 6), (8, 8)]
    };

    let mut out = Vec::new();
    for &(m, k, n) in &shapes {
        println!("\n=== shape ({m},{k})x({k},{n}) ===");
        let wf: Vec<f32> = (0..n * k).map(|_| rng.next_f32_centered() * 0.1).collect();
        let xf: Vec<f32> = (0..m * k).map(|_| rng.next_f32_centered() * 4.0).collect();
        let int8 = registry
            .resolve("int8")
            .unwrap()
            .prepare(&wf, n, k, &PrepareCtx::none())
            .unwrap();
        let int4 = registry
            .resolve("int4")
            .unwrap()
            .prepare(&wf, n, k, &PrepareCtx::none())
            .unwrap();
        let mut y = vec![0f32; m * n];
        let m8 = bencher.run("int8", || {
            int8.forward(&xf, m, &mut y);
            std::hint::black_box(&y);
        });
        let m4 = bencher.run("int4", || {
            int4.forward(&xf, m, &mut y);
            std::hint::black_box(&y);
        });
        println!("  {:<10} {:>8.3} TOPS   {:<10} {:>8.3} TOPS",
                 "CUTLASS8:", m8.tops(m, n, k), "CUTLASS4:", m4.tops(m, n, k));

        print!("  ABQ: ");
        for &(wb, ab) in &combos {
            let xc: Vec<u8> = (0..m * k).map(|_| rng.next_below(1 << ab) as u8).collect();
            let wc: Vec<u8> = (0..n * k).map(|_| rng.next_below(1 << wb) as u8).collect();
            let x = BitPlanes::pack(&xc, m, k, ab);
            let w = BitPlanes::pack(&wc, n, k, wb);
            let zx = vec![1 << (ab - 1); m];
            let zw = vec![1 << (wb - 1); n];
            // searched config + reused accumulator: the serving path
            let cfg = best_config(&x, &w);
            let mut acc = Vec::new();
            let meas = bencher.run("abq", || {
                gemm_int_into(x.view(), w.view(), &zx, &zw, OptLevel::Auto, Some(cfg), &mut acc);
                std::hint::black_box(&acc);
            });
            print!("w{wb}a{ab}={:.3} ", meas.tops(m, n, k));
            out.push(obj(vec![
                ("m", num(m as f64)),
                ("k", num(k as f64)),
                ("n", num(n as f64)),
                ("combo", s(&format!("w{wb}a{ab}"))),
                ("abq_tops", num(meas.tops(m, n, k))),
                ("int8_tops", num(m8.tops(m, n, k))),
                ("int4_tops", num(m4.tops(m, n, k))),
            ]));
        }
        println!();
    }
    write_results("t13_gemm", &Json::Arr(out));
    println!("\n(ABQ_BENCH_FULL=1 for the complete appendix sweep)");
}
