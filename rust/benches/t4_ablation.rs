//! Table 4 reproduction: kernel-optimisation ablation on the W2A8 GEMV
//! (1,4096)×(4096,4096).
//!
//! Paper ladder (RTX 3070):      CUTLASS 49.96us → native 20.05us →
//! +pipeline 14.66us → +GEMV-elim 10.92us → +search 6.68us (7.47× total).
//! Expected shape here: each rung is monotonically faster; the ABQ ladder
//! starts already ahead of the padded INT8 baseline.

use abq_llm::abq::gemm::gemm_int_into;
use abq_llm::abq::search::best_config;
use abq_llm::abq::{gemm_int, isa, BitPlanes, OptLevel, PlaneLayout};
use abq_llm::engine::{BackendRegistry, LinearBackend, LinearOp, PrepareCtx};
use abq_llm::util::bench::{write_results, Bencher};
use abq_llm::util::json::{num, obj, Json};
use abq_llm::util::rng::SplitMix;

/// The retired hand-SWAR popcount, kept **only here** as the reference
/// rung below `count_ones` (the hot crate dispatches through
/// `abq::kernels` now; this is the ladder's historical floor).
fn popcount_swar(mut x: u64) -> u32 {
    x -= (x >> 1) & 0x5555_5555_5555_5555;
    x = (x & 0x3333_3333_3333_3333) + ((x >> 2) & 0x3333_3333_3333_3333);
    x = (x + (x >> 4)) & 0x0F0F_0F0F_0F0F_0F0F;
    (x.wrapping_mul(0x0101_0101_0101_0101) >> 56) as u32
}

fn main() {
    let (m, n, k) = (1usize, 4096usize, 4096usize);
    let (wb, ab) = (2usize, 8usize);
    let bencher = Bencher::default();
    let mut rng = SplitMix::new(4);

    let wf: Vec<f32> = (0..n * k).map(|_| rng.next_f32_centered() * 0.1).collect();
    let xf: Vec<f32> = (0..m * k).map(|_| rng.next_f32_centered() * 4.0).collect();
    let int8 = BackendRegistry::with_defaults()
        .resolve("int8")
        .unwrap()
        .prepare(&wf, n, k, &PrepareCtx::none())
        .unwrap();
    let mut y = vec![0f32; m * n];
    let base = bencher.run("cutlass-sim", || {
        int8.forward(&xf, m, &mut y);
        std::hint::black_box(&y);
    });

    let xc: Vec<u8> = (0..m * k).map(|_| rng.next_below(1 << ab) as u8).collect();
    let wc: Vec<u8> = (0..n * k).map(|_| rng.next_below(1 << wb) as u8).collect();
    let x = BitPlanes::pack(&xc, m, k, ab);
    let w = BitPlanes::pack(&wc, n, k, wb);
    let zx = vec![128i32; m];
    let zw = vec![2i32; n];

    println!("=== Table 4: kernel optimisation ablation, w2a8 (1,4096)x(4096,4096) ===");
    println!("kernel ISA ceiling: {} (detected best: {})", isa::ceiling(), isa::detect_best());
    println!("{:<28} {:>10} {:>8}", "method", "latency", "TOPS");
    println!("{:<28} {:>8.1}us {:>8.3}   (paper: 49.96us / 0.67)", "CUTLASS-sim W8A8 (padded)", base.mean_us(), base.tops(m, n, k));

    let mut rows = vec![obj(vec![
        ("method", abq_llm::util::json::s("cutlass_sim_w8a8")),
        ("latency_us", num(base.mean_us())),
        ("tops", num(base.tops(m, n, k))),
    ])];
    // reference floor below the paper's ladder: the hand-SWAR popcount
    // (no hardware popcnt, no dispatch) — how far the kernel layer has come
    let mut acc_swar = vec![0i64; m * n];
    let meas = bencher.run("SWAR_reference", || {
        for a in acc_swar.iter_mut() {
            *a = 0;
        }
        for mi in 0..m {
            for ni in 0..n {
                let mut a = 0i64;
                for s in 0..ab {
                    let xr = x.plane_row(s, mi);
                    for t in 0..wb {
                        let wr = w.plane_row(t, ni);
                        let d: u32 =
                            xr.iter().zip(wr).map(|(&xw, &ww)| popcount_swar(xw & ww)).sum();
                        a += (d as i64) << (s + t);
                    }
                }
                acc_swar[mi * n + ni] = a;
            }
        }
        std::hint::black_box(&acc_swar);
    });
    println!(
        "{:<28} {:>8.1}us {:>8.3}   (pre-popcnt reference floor)",
        "SWAR popcount (reference)",
        meas.mean_us(),
        meas.tops(m, n, k)
    );
    rows.push(obj(vec![
        ("method", abq_llm::util::json::s("swar_reference")),
        ("latency_us", num(meas.mean_us())),
        ("tops", num(meas.tops(m, n, k))),
    ]));

    let ladder: [(&str, &str, OptLevel); 4] = [
        ("Native_kernel", "20.05us / 1.67", OptLevel::Naive),
        ("+ Pipeline Optimization", "14.66us / 2.28", OptLevel::Pipelined),
        ("+ Eliminate GEMV", "10.92us / 3.07", OptLevel::GemvElim),
        ("+ Auto Kernel Search", "6.68us / 5.01", OptLevel::Auto),
    ];
    for (name, paper, opt) in ladder {
        // Auto uses the searched config (search cost excluded, as in the
        // paper: search happens before operator launch)
        let cfg = if opt == OptLevel::Auto { Some(best_config(&x, &w)) } else { None };
        let meas = bencher.run(name, || {
            std::hint::black_box(gemm_int(&x, &w, &zx, &zw, opt, cfg));
        });
        println!(
            "{:<28} {:>8.1}us {:>8.3}   (paper: {})",
            name,
            meas.mean_us(),
            meas.tops(m, n, k),
            paper
        );
        rows.push(obj(vec![
            ("method", abq_llm::util::json::s(name)),
            ("latency_us", num(meas.mean_us())),
            ("tops", num(meas.tops(m, n, k))),
        ]));
    }

    // extra rung beyond the paper's ladder: interleaved weight layout +
    // scratch accumulator — the layout/arena combination the serving path
    // actually runs after the zero-allocation rework (docs/PERF.md)
    let wi = w.to_layout(PlaneLayout::Interleaved);
    let cfg = best_config(&x, &wi);
    let mut acc = Vec::new();
    let meas = bencher.run("+ Interleaved W layout", || {
        gemm_int_into(x.view(), wi.view(), &zx, &zw, OptLevel::Auto, Some(cfg), &mut acc);
        std::hint::black_box(&acc);
    });
    println!(
        "{:<28} {:>8.1}us {:>8.3}   (beyond paper: word-sliced layout + arena)",
        "+ Interleaved W layout",
        meas.mean_us(),
        meas.tops(m, n, k)
    );
    rows.push(obj(vec![
        ("method", abq_llm::util::json::s("interleaved_layout_arena")),
        ("latency_us", num(meas.mean_us())),
        ("tops", num(meas.tops(m, n, k))),
    ]));

    // per-ISA rungs: the searched config under each pinned ceiling (the
    // search cache keys on the ceiling, so every rung re-races its own
    // candidate grid; all rungs are bit-exact with each other)
    for i in isa::race_set() {
        let label = format!("+ Auto @ {i}");
        let meas = isa::pinned(i, || {
            let cfg = best_config(&x, &w);
            bencher.run(&label, || {
                gemm_int_into(x.view(), w.view(), &zx, &zw, OptLevel::Auto, Some(cfg), &mut acc);
                std::hint::black_box(&acc);
            })
        });
        println!(
            "{:<28} {:>8.1}us {:>8.3}   (ISA ceiling rung)",
            label,
            meas.mean_us(),
            meas.tops(m, n, k)
        );
        rows.push(obj(vec![
            ("method", abq_llm::util::json::s(&format!("auto_isa_{i}"))),
            ("latency_us", num(meas.mean_us())),
            ("tops", num(meas.tops(m, n, k))),
        ]));
    }
    write_results("t4_ablation", &Json::Arr(rows));
}
