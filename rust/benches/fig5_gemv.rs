//! Figure 5 reproduction: GEMV (M=1) speedup of ABQKernel vs the
//! CUTLASS (W4A4/W8A8) and cuBLAS (W8A8) stand-ins, on the LLaMA-7B layer
//! shapes the paper sweeps:
//!   (1,4096)×(4096,4096), (1,4096)×(4096,11008), (1,11008)×(11008,4096)
//!
//! Paper headline: w2a8 ABQ ≈ 7.47× the W8A8 kernels on (1,4096)×(4096,4096).
//! Expected *shape* here: ABQ wins at every low-bit combo and the win grows
//! as bits shrink; the padded baselines waste 87.5% of their work at M=1.

use abq_llm::abq::gemm::gemm_int_into;
use abq_llm::abq::search::{best_config, choose_weight_layout};
use abq_llm::abq::{BitPlanes, OptLevel, PlaneLayout};
use abq_llm::engine::{BackendRegistry, LinearBackend, LinearOp, PrepareCtx};
use abq_llm::util::bench::{write_results, Bencher};
use abq_llm::util::json::{num, obj, s, Json};
use abq_llm::util::rng::SplitMix;

fn main() {
    let bencher = Bencher::default();
    let registry = BackendRegistry::with_defaults();
    let mut rng = SplitMix::new(5);
    let shapes = [(4096usize, 4096usize), (4096, 11008), (11008, 4096)];
    let combos = [(2usize, 8usize), (2, 4), (4, 4), (8, 8)];
    let m = 1usize;

    let mut out = Vec::new();
    println!("=== Fig. 5: GEMV speedups at M=1 (LLaMA-7B shapes) ===");
    for &(k, n) in &shapes {
        let wf: Vec<f32> = (0..n * k).map(|_| rng.next_f32_centered() * 0.1).collect();
        let xf: Vec<f32> = (0..m * k).map(|_| rng.next_f32_centered() * 4.0).collect();
        // baseline engines prepared through the backend registry — the
        // same ops the served model runs on
        let int8 = registry
            .resolve("int8")
            .unwrap()
            .prepare(&wf, n, k, &PrepareCtx::none())
            .unwrap();
        let int4 = registry
            .resolve("int4")
            .unwrap()
            .prepare(&wf, n, k, &PrepareCtx::none())
            .unwrap();
        let mut y = vec![0f32; m * n];
        let m8 = bencher.run("w8a8-sim", || {
            int8.forward(&xf, m, &mut y);
            std::hint::black_box(&y);
        });
        let m4 = bencher.run("w4a4-sim", || {
            int4.forward(&xf, m, &mut y);
            std::hint::black_box(&y);
        });
        println!("\nshape (1,{k})x({k},{n}):");
        println!("  {:<14} {:>10.1} us  {:>7.3} TOPS", "cuBLAS W8A8", m8.mean_us(), m8.tops(m, n, k));
        println!("  {:<14} {:>10.1} us  {:>7.3} TOPS", "CUTLASS W4A4", m4.mean_us(), m4.tops(m, n, k));

        for &(wb, ab) in &combos {
            let xc: Vec<u8> = (0..m * k).map(|_| rng.next_below(1 << ab) as u8).collect();
            let wc: Vec<u8> = (0..n * k).map(|_| rng.next_below(1 << wb) as u8).collect();
            let x = BitPlanes::pack(&xc, m, k, ab);
            // serve the layout the auto-search prefers for this shape,
            // exactly as a prepared QuantizedLinear would
            let w = choose_weight_layout(BitPlanes::pack(&wc, n, k, wb), ab);
            let zx = vec![1 << (ab - 1); m];
            let zw = vec![1 << (wb - 1); n];
            // warm search outside the timed region (the paper's search
            // happens before operator launch) and reuse the accumulator —
            // this measures the zero-allocation serving path
            let cfg = best_config(&x, &w);
            let mut acc = Vec::new();
            let meas = bencher.run("abq", || {
                gemm_int_into(x.view(), w.view(), &zx, &zw, OptLevel::Auto, Some(cfg), &mut acc);
                std::hint::black_box(&acc);
            });
            // the paper compares each combo against the baseline it would
            // have to be up-converted to: ≤4-bit pairs → W4A4, else W8A8
            let (base, base_name) = if wb <= 4 && ab <= 4 { (&m4, "W4A4") } else { (&m8, "W8A8") };
            let speedup = base.mean_ns / meas.mean_ns;
            let vs8 = m8.mean_ns / meas.mean_ns;
            println!(
                "  ABQ w{wb}a{ab}      {:>10.1} us  {:>7.3} TOPS  {:>5.2}x vs {}  {:>5.2}x vs W8A8",
                meas.mean_us(),
                meas.tops(m, n, k),
                speedup,
                base_name,
                vs8
            );
            out.push(obj(vec![
                ("k", num(k as f64)),
                ("n", num(n as f64)),
                ("w_bits", num(wb as f64)),
                ("a_bits", num(ab as f64)),
                ("abq_us", num(meas.mean_us())),
                ("int8_us", num(m8.mean_us())),
                ("int4_us", num(m4.mean_us())),
                ("speedup_vs_w8a8", num(vs8)),
                (
                    "w_layout",
                    s(if w.layout == PlaneLayout::Interleaved { "interleaved" } else { "plane" }),
                ),
            ]));
        }
    }
    write_results("fig5_gemv", &Json::Arr(out));
    println!("\npaper: w2a8 reaches 7.47x vs W8A8 on (1,4096)x(4096,4096)");
}
