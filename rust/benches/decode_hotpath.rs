//! Decode hot-path benchmark: single-token end-to-end block steps on a
//! small-but-real model, per backend. This is the workload the
//! zero-allocation rework targets (persistent worker pool + scratch
//! arenas + word-sliced packing + searched weight layout — docs/PERF.md):
//! one engine session decoding greedily, measured in steady state (warm
//! arena, warm auto-search cache, warm pool).
//!
//! Reports tokens/s and ns per projection (7 projections × n_layers per
//! step). With `ABQ_RECORD=<label>` set, appends a run entry to
//! `../BENCH_decode.json` so the perf trajectory is recorded in-repo —
//! `scripts/record_decode_bench.sh pre|post` wraps this.
//!
//! `ABQ_SPEC=w2*a8:4` adds a self-speculative rung (draft config : k,
//! target `ABQ_SPEC_TARGET`, default `abq:w8a8`): greedy speculative
//! generation measured in tokens/s, with the acceptance rate recorded
//! alongside the vanilla rows so the artifact shows both
//! (`docs/SPECULATIVE.md`). CI's bench-smoke job sets it on every PR.
//!
//! `ABQ_PREFIX=1` adds a prefix-cache rung (`docs/SERVING.md` §prefix
//! cache): TTFT for a shared-system-prompt request cold (full prefill)
//! vs warm (copy-on-write attach + tail prefill), and how many such
//! requests a fixed 4-sequence pool budget admits with sharing off vs
//! on. CI's bench-smoke job sets this too.
//!
//! `ABQ_REPLICAS=N` adds a multi-replica saturation rung
//! (`docs/SERVING.md` §multi-replica): requests/s and p95 TTFT for a
//! fixed burst against 1 replica vs N replicas sharing one weight set,
//! at a fixed per-replica concurrency (the latency-SLO proxy). CI sets
//! `ABQ_REPLICAS=2` on every PR.
//!
//! `ABQ_AUTOPILOT=1` adds an adaptive-precision overload rung
//! (`docs/SERVING.md` §adaptive precision): the same burst against a
//! fixed top-rung config vs the default ladder under an unmeetable TTFT
//! SLO, recording req/s and how many downshifts the autopilot took to
//! shed the load. CI's bench-smoke job sets this too.

use std::time::Instant;

use abq_llm::abq::isa;
use abq_llm::engine::{EngineBuilder, EngineSession, InferenceEngine, KvCacheConfig, SpecConfig};
use abq_llm::model::ModelConfig;
use abq_llm::util::bench::write_results;
use abq_llm::util::json::{num, obj, s, Json};

const BENCH_MODEL: ModelConfig = ModelConfig {
    name: "decode-bench-768d",
    vocab: 2048,
    d_model: 768,
    n_layers: 2,
    n_heads: 12,
    n_kv_heads: 12,
    d_ff: 2048,
    max_seq: 256,
    rope_base: 10000.0,
    arch: abq_llm::model::ArchVariant::LLAMA,
};

const PROMPT: [u32; 8] = [1, 2, 3, 5, 8, 13, 21, 34];

struct Run {
    tok_s: f64,
    ns_per_projection: f64,
    ms_per_step: f64,
}

fn drive(engine: &dyn InferenceEngine, sess: &mut Box<dyn EngineSession>, steps: usize) {
    for i in 0..steps {
        let tok = (i % (BENCH_MODEL.vocab - 1)) as u32;
        let mut refs: [&mut dyn EngineSession; 1] = [sess.as_mut()];
        let logits = engine.decode_step(&[tok], &mut refs).unwrap();
        std::hint::black_box(&logits);
    }
}

fn measure(engine: &dyn InferenceEngine, warm_steps: usize, steps: usize, samples: usize) -> Run {
    let mut sess = engine.new_session().unwrap();
    engine.prefill(&PROMPT, sess.as_mut()).unwrap();
    // warm-up: arena growth, kernel search, worker-pool spin-up
    drive(engine, &mut sess, warm_steps);
    let mut best_secs = f64::INFINITY;
    for _ in 0..samples {
        let t0 = Instant::now();
        drive(engine, &mut sess, steps);
        best_secs = best_secs.min(t0.elapsed().as_secs_f64());
    }
    let per_step = best_secs / steps as f64;
    Run {
        tok_s: 1.0 / per_step,
        ns_per_projection: per_step * 1e9 / (7.0 * BENCH_MODEL.n_layers as f64),
        ms_per_step: per_step * 1e3,
    }
}

fn record(rows: &[Json], steps: usize, kv_bits: u8) {
    let Some(label) = std::env::var("ABQ_RECORD").ok().filter(|l| !l.is_empty()) else {
        return;
    };
    let path = "../BENCH_decode.json";
    let now = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs() as f64)
        .unwrap_or(0.0);
    let entry = obj(vec![
        ("label", s(&label)),
        ("unix_time", num(now)),
        ("model", s(BENCH_MODEL.name)),
        ("prompt_tokens", num(PROMPT.len() as f64)),
        ("steps_per_sample", num(steps as f64)),
        ("kv_bits", num(kv_bits as f64)),
        ("isa", s(isa::ceiling().name())),
        ("results", Json::Arr(rows.to_vec())),
    ]);
    let mut root = match std::fs::read_to_string(path).ok().and_then(|t| Json::parse(&t).ok()) {
        Some(Json::Obj(m)) => m,
        _ => std::collections::BTreeMap::new(),
    };
    let mut runs = match root.remove("runs") {
        Some(Json::Arr(v)) => v,
        _ => Vec::new(),
    };
    runs.push(entry);
    root.insert("runs".to_string(), Json::Arr(runs));
    root.entry("note".to_string()).or_insert_with(|| {
        s("decode hot-path trajectory (tokens/s, single-token steps); see docs/PERF.md")
    });
    match std::fs::write(path, Json::Obj(root).to_string_pretty()) {
        Ok(()) => println!("[recorded] {path} (label: {label})"),
        Err(e) => eprintln!("warn: could not record {path}: {e}"),
    }
}

fn main() {
    let fast = std::env::var("ABQ_BENCH_FAST").is_ok();
    let (warm_steps, steps, samples) = if fast { (4, 8, 2) } else { (16, 64, 3) };
    let backends = ["abq:w2*a8", "abq:w4a4", "abq:w8a8", "int8", "fp32"];
    // ABQ_KV_BITS=8|4 measures the quantized paged-KV read path
    let kv_bits: u8 = std::env::var("ABQ_KV_BITS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(KvCacheConfig::FP32.bits);
    let kv = KvCacheConfig { bits: kv_bits, ..KvCacheConfig::FP32 };

    println!(
        "=== decode hot path: single-token steps, {} (kv {} bits) ===",
        BENCH_MODEL.name, kv_bits
    );
    println!(
        "kernel ISA: {} (detected best: {}; override with ABQ_ISA=scalar|avx2|avx512|neon)",
        isa::ceiling(),
        isa::detect_best()
    );
    println!(
        "{:<12} {:>10} {:>12} {:>16}",
        "backend", "tok/s", "ms/step", "ns/projection"
    );
    let mut rows = Vec::new();
    let mut w2_tok_s = None;
    let mut int8_tok_s = None;
    for spec in backends {
        let engine = EngineBuilder::new()
            .random_weights(BENCH_MODEL, 42)
            .backend(spec)
            .kv_cache(kv)
            .build()
            .unwrap_or_else(|e| panic!("{spec}: {e}"));
        let r = measure(engine.as_ref(), warm_steps, steps, samples);
        println!(
            "{:<12} {:>10.1} {:>12.3} {:>16.0}",
            spec, r.tok_s, r.ms_per_step, r.ns_per_projection
        );
        if spec == "abq:w2*a8" {
            w2_tok_s = Some(r.tok_s);
        }
        if spec == "int8" {
            int8_tok_s = Some(r.tok_s);
        }
        rows.push(obj(vec![
            ("backend", s(spec)),
            ("tok_s", num(r.tok_s)),
            ("ms_per_step", num(r.ms_per_step)),
            ("ns_per_projection", num(r.ns_per_projection)),
        ]));
    }
    if let (Some(w2), Some(i8t)) = (w2_tok_s, int8_tok_s) {
        println!("\nabq:w2*a8 vs int8 (SmoothQuant engine): {:.2}x", w2 / i8t);
    }

    // self-speculative rung: ABQ_SPEC=<draft>:<k> (vanilla target rows
    // above are the baseline the acceptance math compares against)
    if let Some(spec_str) = std::env::var("ABQ_SPEC").ok().filter(|v| !v.is_empty()) {
        let sc: SpecConfig = spec_str
            .parse()
            .unwrap_or_else(|e| panic!("ABQ_SPEC '{spec_str}': {e}"));
        let target =
            std::env::var("ABQ_SPEC_TARGET").unwrap_or_else(|_| "abq:w8a8".to_string());
        let engine = EngineBuilder::new()
            .random_weights(BENCH_MODEL, 42)
            .backend(target.as_str())
            .kv_cache(kv)
            .speculative(sc)
            .build()
            .unwrap_or_else(|e| panic!("{target}+spec: {e}"));
        let (tok_s, stats) = measure_spec(engine.as_ref(), steps, samples);
        let label = format!("{target}+spec({}:{})", sc.draft, sc.k);
        println!(
            "\n{:<28} {:>10.1} tok/s  acceptance {:>5.1}% ({} rounds)",
            label,
            tok_s,
            stats.acceptance_rate() * 100.0,
            stats.rounds
        );
        rows.push(obj(vec![
            ("backend", s(&label)),
            ("tok_s", num(tok_s)),
            ("speculative", Json::Bool(true)),
            ("spec_draft", s(&sc.draft.to_string())),
            ("spec_k", num(sc.k as f64)),
            ("accept_rate", num(stats.acceptance_rate())),
            ("drafted", num(stats.drafted as f64)),
            ("accepted", num(stats.accepted as f64)),
        ]));
    }

    // prefix-cache rung: ABQ_PREFIX=1 (serve-level shared-system-prompt
    // workload — docs/SERVING.md §prefix cache)
    if std::env::var("ABQ_PREFIX").is_ok_and(|v| v == "1") {
        run_prefix_rung(kv, &mut rows);
    }

    // multi-replica saturation rung: ABQ_REPLICAS=N (requests/s at a
    // fixed per-replica concurrency SLO, 1 replica vs N sharing one
    // weight set — docs/SERVING.md §multi-replica). CI sets N=2.
    if let Some(n) = std::env::var("ABQ_REPLICAS").ok().and_then(|v| v.parse::<usize>().ok())
    {
        if n >= 2 {
            run_replica_rung(kv, n, &mut rows);
        }
    }

    // adaptive-precision overload rung: ABQ_AUTOPILOT=1 (fixed top-rung
    // config vs the default ladder under an unmeetable TTFT SLO —
    // docs/SERVING.md §adaptive precision). CI sets this on every PR.
    if std::env::var("ABQ_AUTOPILOT").is_ok_and(|v| v == "1") {
        run_autopilot_rung(&mut rows);
    }

    write_results("decode_hotpath", &Json::Arr(rows.clone()));
    record(&rows, steps, kv_bits);
}

/// The saturation rung: a fixed burst of requests served by one replica
/// and by `n` replicas built over one shared weight set
/// (`EngineBuilder::build_replicas` — replica 1+ report ≈0 incremental
/// weight bytes). The per-replica `max_active` stays fixed (the latency
/// SLO proxy: adding replicas must not just deepen one queue), and each
/// replica gets a small dedicated compute pool so the fleets scale
/// across cores instead of serializing on the global pool's dispatch
/// lock. Records requests/s, p95 TTFT (`server.ttft_us`), and the
/// fleet's incremental weight bytes.
fn run_replica_rung(kv: KvCacheConfig, n: usize, rows: &mut Vec<Json>) {
    use abq_llm::coordinator::{Frontend, FrontendConfig, SubmitRequest};
    use std::sync::Arc;

    let requests = 24usize;
    let max_new = 8usize;
    let run = |replicas: usize| -> (f64, u64, usize) {
        let engines = EngineBuilder::new()
            .random_weights(BENCH_MODEL, 42)
            .backend("abq:w2*a8")
            .kv_cache(kv)
            .build_replicas(replicas)
            .unwrap_or_else(|e| panic!("replica rung: {e}"));
        let incremental: usize = engines
            .iter()
            .skip(1)
            .map(|e| e.memory_report().weight_bytes_incremental)
            .sum();
        let fleet: Vec<(String, Arc<dyn InferenceEngine>)> =
            engines.into_iter().map(|e| ("bench".to_string(), e)).collect();
        let front = Frontend::start(
            fleet,
            FrontendConfig {
                default_tag: "bench".to_string(),
                max_active: 4,
                pool_threads: Some(1),
                ..Default::default()
            },
        )
        .unwrap();
        let t0 = Instant::now();
        let tickets: Vec<_> = (0..requests)
            .map(|i| {
                let mut p = PROMPT.to_vec();
                p.push((i % 50) as u32 + 1);
                front.submit(SubmitRequest::new(p, max_new)).unwrap()
            })
            .collect();
        for t in tickets {
            let r = t.wait().unwrap();
            assert_eq!(r.tokens.len(), max_new, "saturation rung lost tokens");
        }
        let secs = t0.elapsed().as_secs_f64();
        // every request completed above, so the histogram is non-empty
        let p95 = front
            .metrics
            .histogram_quantile_us("server.ttft_us", 0.95)
            .expect("completed burst must have TTFT observations");
        front.shutdown();
        (requests as f64 / secs.max(1e-12), p95, incremental)
    };
    let (rps_1, p95_1, _) = run(1);
    let (rps_n, p95_n, incremental) = run(n);
    let scaling = rps_n / rps_1.max(1e-12);
    println!(
        "\nreplicas: 1 -> {rps_1:.1} req/s (p95 TTFT {p95_1}us); \
         {n} -> {rps_n:.1} req/s (p95 TTFT {p95_n}us); scaling {scaling:.2}x; \
         incremental weight bytes of replicas 1+: {incremental}"
    );
    rows.push(obj(vec![
        ("backend", s("abq:w2*a8+replicas")),
        ("replicas", num(n as f64)),
        ("requests", num(requests as f64)),
        ("req_s_1", num(rps_1)),
        ("req_s_n", num(rps_n)),
        ("scaling", num(scaling)),
        ("p95_ttft_us_1", num(p95_1 as f64)),
        ("p95_ttft_us_n", num(p95_n as f64)),
        ("shared_weight_incremental_bytes", num(incremental as f64)),
    ]));
}

/// The adaptive-precision overload rung: the same burst served by (a) a
/// fixed deployment pinned to the ladder's most precise rung and (b) the
/// default ladder (`w6a6@kv8 → w4a4@kv8 → w2*a8@kv4`) under a TTFT SLO
/// the burst cannot meet, so the autopilot sheds precision for
/// throughput. Records both req/s, the downshift/upshift counts and the
/// rung the pilot settled on — the overload curve `BENCH_decode.json`
/// keeps per commit. Every response is still length-checked: migration
/// must never lose tokens.
fn run_autopilot_rung(rows: &mut Vec<Json>) {
    use abq_llm::coordinator::{AutopilotConfig, Frontend, FrontendConfig, SubmitRequest};
    use abq_llm::engine::Ladder;

    let requests = 24usize;
    let max_new = 8usize;
    let fcfg = || FrontendConfig {
        default_tag: "bench".to_string(),
        max_active: 4,
        pool_threads: Some(1),
        ..Default::default()
    };
    let burst = |front: &Frontend| -> f64 {
        let t0 = Instant::now();
        let tickets: Vec<_> = (0..requests)
            .map(|i| {
                let mut p = PROMPT.to_vec();
                p.push((i % 50) as u32 + 1);
                front.submit(SubmitRequest::new(p, max_new)).unwrap()
            })
            .collect();
        for t in tickets {
            let r = t.wait().unwrap();
            assert_eq!(r.tokens.len(), max_new, "autopilot rung lost tokens");
        }
        requests as f64 / t0.elapsed().as_secs_f64().max(1e-12)
    };

    // fixed baseline: pinned to the ladder's most precise rung
    let fixed = EngineBuilder::new()
        .random_weights(BENCH_MODEL, 42)
        .backend("abq:w6a6")
        .kv_cache(KvCacheConfig { bits: 8, ..KvCacheConfig::FP32 })
        .build_arc()
        .unwrap_or_else(|e| panic!("autopilot rung: {e}"));
    let front = Frontend::start(vec![("bench".to_string(), fixed)], fcfg()).unwrap();
    let rps_fixed = burst(&front);
    front.shutdown();

    let rungs = EngineBuilder::new()
        .random_weights(BENCH_MODEL, 42)
        .build_adaptive(&Ladder::default_ladder())
        .unwrap_or_else(|e| panic!("autopilot rung: {e}"));
    // a 1ms TTFT SLO this model cannot meet → the pilot must walk down
    let pilot = AutopilotConfig {
        slo_ttft_us: 1_000,
        min_dwell_ticks: 0,
        poll_ms: 20,
        ..Default::default()
    };
    let front = Frontend::start_adaptive(rungs, fcfg(), pilot).unwrap();
    let rps_auto = burst(&front);
    let downshifts = front.metrics.counter("server.downshifts");
    let upshifts = front.metrics.counter("server.upshifts");
    let final_rung = front.active_rung().unwrap_or(0);
    front.shutdown();

    println!(
        "\nautopilot overload: fixed w6a6 {rps_fixed:.1} req/s; \
         adaptive {rps_auto:.1} req/s ({:.2}x) with {downshifts} downshift(s), \
         {upshifts} upshift(s), final rung {final_rung}",
        rps_auto / rps_fixed.max(1e-12)
    );
    rows.push(obj(vec![
        ("backend", s("ladder+autopilot")),
        ("autopilot", Json::Bool(true)),
        ("requests", num(requests as f64)),
        ("req_s_fixed", num(rps_fixed)),
        ("req_s_autopilot", num(rps_auto)),
        ("overload_gain", num(rps_auto / rps_fixed.max(1e-12))),
        ("downshifts", num(downshifts as f64)),
        ("upshifts", num(upshifts as f64)),
        ("final_rung", num(final_rung as f64)),
        ("slo_ttft_us", num(pilot.slo_ttft_us as f64)),
    ]));
}

/// The prefix-cache rung: one system prompt shared by every request.
///
/// * **TTFT** — prefill the whole prompt cold, then again warm via
///   `attach_prefix` + tail-only prefill of the last token;
/// * **admission capacity** — at a pool budget of exactly 4 cold
///   sequences, count how many requests a scheduler admits with the
///   prefix cache off vs on (shared whole blocks are billed once, so
///   each extra request only pays its unshared tail).
fn run_prefix_rung(kv: KvCacheConfig, rows: &mut Vec<Json>) {
    use abq_llm::coordinator::{Admission, QueuedRequest, Scheduler, SchedulerConfig, SubmitRequest};

    let build = |budget: Option<usize>| {
        let mut b = EngineBuilder::new()
            .random_weights(BENCH_MODEL, 42)
            .backend("abq:w2*a8")
            .kv_cache(kv);
        if let Some(bytes) = budget {
            b = b.kv_pool_bytes(bytes);
        }
        b.build_arc().unwrap_or_else(|e| panic!("prefix rung: {e}"))
    };

    // 4 whole blocks of system prompt + a 1-token per-request tail
    let sys_len = kv.block_size * 4;
    let mut prompt: Vec<u32> =
        (0..sys_len as u32).map(|i| i % (BENCH_MODEL.vocab as u32 - 1)).collect();
    prompt.push(7);

    let engine = build(None);
    let mut ttft_cold_us = f64::INFINITY;
    let mut donor = engine.new_session().unwrap();
    for _ in 0..2 {
        let mut sess = engine.new_session().unwrap();
        let t0 = Instant::now();
        let logits = engine.prefill(&prompt, sess.as_mut()).unwrap();
        std::hint::black_box(&logits);
        ttft_cold_us = ttft_cold_us.min(t0.elapsed().as_micros() as f64);
        donor = sess;
    }
    let pfx = engine.export_prefix(sys_len, donor.as_mut()).unwrap();
    let mut ttft_warm_us = f64::INFINITY;
    for _ in 0..2 {
        let t0 = Instant::now();
        let mut sess = engine.new_session().unwrap();
        let attached = engine.attach_prefix(pfx.as_ref(), sess.as_mut()).unwrap();
        let logits = engine.prefill(&prompt[attached..], sess.as_mut()).unwrap();
        std::hint::black_box(&logits);
        ttft_warm_us = ttft_warm_us.min(t0.elapsed().as_micros() as f64);
    }

    // admission capacity at a fixed budget of exactly 4 cold sequences
    let st = engine.kv_pool_status().expect("native engine has a pool");
    let per_seq = st.blocks_for(prompt.len() + 1);
    let budget = st.block_bytes * per_seq * 4;
    drop(pfx);
    drop(donor);
    drop(engine);
    let admitted_at = |prefix_cache: bool| -> usize {
        let engine = build(Some(budget));
        let mut sched = Scheduler::new(
            engine,
            SchedulerConfig { max_active: 10_000, prefix_cache },
        );
        let mut n = 0usize;
        for id in 0..64u64 {
            let mut p: Vec<u32> = prompt[..sys_len].to_vec();
            p.push(7 + (id % 50) as u32);
            let qr = QueuedRequest::new(id, SubmitRequest::new(p, 1));
            match sched.admit(qr, id) {
                Ok(Admission::Admitted) => n += 1,
                _ => break,
            }
        }
        n
    };
    let admitted_no_sharing = admitted_at(false);
    let admitted_sharing = admitted_at(true);

    let speedup = ttft_cold_us / ttft_warm_us.max(1.0);
    let ratio = admitted_sharing as f64 / admitted_no_sharing.max(1) as f64;
    println!(
        "\nprefix cache ({} sys tokens): TTFT {:.0}us cold -> {:.0}us warm ({:.2}x); \
         admitted at 4-seq budget: {} cold vs {} shared ({:.2}x)",
        sys_len, ttft_cold_us, ttft_warm_us, speedup, admitted_no_sharing, admitted_sharing,
        ratio
    );
    rows.push(obj(vec![
        ("backend", s("abq:w2*a8+prefix")),
        ("prefix", Json::Bool(true)),
        ("sys_tokens", num(sys_len as f64)),
        ("ttft_cold_us", num(ttft_cold_us)),
        ("ttft_warm_us", num(ttft_warm_us)),
        ("ttft_speedup", num(speedup)),
        ("admitted_no_sharing", num(admitted_no_sharing as f64)),
        ("admitted_sharing", num(admitted_sharing as f64)),
        ("capacity_ratio", num(ratio)),
    ]));
}

/// Speculative counterpart of [`measure`], kept comparable to the
/// vanilla rows: per sample, a fresh session is prefilled and warmed
/// (arena growth, kernel search, draft pool) *outside* the timed
/// region, then only steady-state speculative rounds are timed;
/// tokens/s is the best of `samples`. Acceptance stats aggregate over
/// the timed rounds.
fn measure_spec(
    engine: &dyn InferenceEngine,
    steps: usize,
    samples: usize,
) -> (f64, abq_llm::spec::SpecStats) {
    use abq_llm::model::{Sampler, Sampling};
    let v = engine.spec().model.vocab;
    let mut best_tok_s = 0f64;
    let mut stats = abq_llm::spec::SpecStats::default();
    for _ in 0..samples {
        let mut sess = engine.new_session().unwrap();
        let logits = engine.prefill(&PROMPT, sess.as_mut()).unwrap();
        let mut sampler = Sampler::new(Sampling::Greedy, 0);
        let mut tok = sampler.sample(&logits[(PROMPT.len() - 1) * v..PROMPT.len() * v]);
        let round = |tok: u32, sampler: &mut Sampler, sess: &mut Box<dyn EngineSession>| {
            let mut refs: [&mut dyn EngineSession; 1] = [sess.as_mut()];
            let mut samplers = [&mut *sampler];
            engine.spec_round(&[tok], &mut refs, &mut samplers).unwrap().remove(0)
        };
        // warm-up rounds, untimed
        for _ in 0..2 {
            let o = round(tok, &mut sampler, &mut sess);
            tok = *o.tokens.last().unwrap();
        }
        let t0 = Instant::now();
        let mut emitted = 0usize;
        while emitted < steps {
            let o = round(tok, &mut sampler, &mut sess);
            tok = *o.tokens.last().unwrap();
            emitted += o.tokens.len();
            stats.absorb(&o);
        }
        let secs = t0.elapsed().as_secs_f64();
        best_tok_s = best_tok_s.max(emitted as f64 / secs.max(1e-12));
    }
    (best_tok_s, stats)
}
