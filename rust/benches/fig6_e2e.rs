//! Figure 6 / Table 12 reproduction: end-to-end inference latency and
//! memory, FP16 vs SmoothQuant-W8A8 vs ABQ-W2A8.
//!
//! Two parts:
//!  1. *measured*: the trained tiny-llama served end-to-end on each
//!     backend (fixed prompt 15 tokens, like the paper's fixed input 15),
//!     decode lengths {32, 64, 128}; reports wall latency and resident
//!     weight+KV bytes.
//!  2. *modelled at scale*: the paper's 7B/13B/30B memory table from the
//!     engine's byte-accounting at real LLaMA dims (weights + KV); this is
//!     the part that reproduces "W2A8 runs 30B in 10GB < FP16 7B".
//!
//! Expected shape: latency fp16 > w8a8 > w2a8; memory ratios ≈ paper
//! (4.8× vs FP16, 2.7× vs W8A8 for weights+KV at 30B).

use std::path::Path;

use abq_llm::engine::{generate, EngineBuilder, InferenceEngine};
use abq_llm::eval;
use abq_llm::model::{ModelConfig, LLAMA_13B, LLAMA_30B, LLAMA_7B};
use abq_llm::util::bench::write_results;
use abq_llm::util::json::{num, obj, s, Json};

fn measure_generate(engine: &dyn InferenceEngine, prompt: &[u32], new_tokens: usize) -> f64 {
    let t0 = std::time::Instant::now();
    let out = generate(engine, prompt, new_tokens).unwrap();
    std::hint::black_box(&out);
    t0.elapsed().as_secs_f64() * 1e3
}

fn main() {
    let dir = Path::new("artifacts");
    let mut rows = Vec::new();

    if dir.join("manifest.json").exists() {
        println!("=== measured: tiny-llama end to end (prompt 15 tokens) ===");
        let backends: Vec<(&str, &str)> = vec![
            ("FP16", "fp32"),
            ("W8A8(SmoothQuant)", "int8"),
            ("W2A8(ABQ)", "abq:w2a8"),
            ("W2*A8(ABQ)", "abq:w2*a8"),
        ];
        let table = eval::corpus::build_transition_table(eval::corpus::TABLE_SEED);
        let prompt = eval::corpus::generate_tokens(&table, 15, 77);
        println!(
            "{:<20} {:>10} {:>10} {:>10} {:>12}",
            "engine", "len=32", "len=64", "len=128", "weights(MB)"
        );
        for (name, spec) in backends {
            let engine =
                EngineBuilder::new().weights(dir).backend(spec).build().unwrap();
            // warm-up generate: one-time costs (auto kernel search, worker
            // pool spin-up, scratch-arena growth) stay out of the numbers
            measure_generate(engine.as_ref(), &prompt, 8);
            let mut lat = Vec::new();
            for &len in &[32usize, 64, 128] {
                lat.push(measure_generate(engine.as_ref(), &prompt, len));
            }
            let wmb = engine.memory_report().weight_bytes as f64 / 1e6;
            println!(
                "{:<20} {:>8.1}ms {:>8.1}ms {:>8.1}ms {:>11.2}",
                name, lat[0], lat[1], lat[2], wmb
            );
            rows.push(obj(vec![
                ("engine", s(name)),
                ("lat32_ms", num(lat[0])),
                ("lat64_ms", num(lat[1])),
                ("lat128_ms", num(lat[2])),
                ("weights_mb", num(wmb)),
            ]));
        }
    } else {
        println!("(no artifacts — skipping measured part; run `make artifacts`)");
    }

    println!("\n=== modelled at scale: paper Table 12 memory (weights + KV @ seq 1024) ===");
    println!(
        "{:<12} {:>12} {:>12} {:>12} {:>18}",
        "model", "FP16(GB)", "W8A8(GB)", "W2A8(GB)", "W2A8 vs FP16/W8A8"
    );
    for cfg in [LLAMA_7B, LLAMA_13B, LLAMA_30B] {
        let gb = |bits: f64, c: &ModelConfig| (c.weight_bytes(bits) + c.kv_bytes(1024)) / 1e9;
        let fp16 = gb(16.0, &cfg);
        let w8 = gb(8.0, &cfg);
        let w2 = gb(2.0, &cfg);
        println!(
            "{:<12} {:>12.2} {:>12.2} {:>12.2} {:>10.1}x /{:>4.1}x",
            cfg.name, fp16, w8, w2, fp16 / w2, w8 / w2
        );
        rows.push(obj(vec![
            ("model", s(cfg.name)),
            ("fp16_gb", num(fp16)),
            ("w8a8_gb", num(w8)),
            ("w2a8_gb", num(w2)),
        ]));
    }
    println!("(paper: 4.8x vs FP16, 2.7x vs SmoothQuant W8A8; LLaMA-30B W2A8 ≈ 10GB)");
    write_results("fig6_e2e", &Json::Arr(rows));
}
