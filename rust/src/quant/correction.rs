//! Learned distribution-correction state (paper §3.2, Eq. 4–6 — the DLC
//! half of ABQ's accuracy story). One [`Correction`] per projection holds
//! the per-input-channel **balance scale** `s`, the per-input-channel
//! **shift** `z`, and a scalar weight **clip** ratio. At inference the
//! corrected linear computes
//!
//! ```text
//!   y = Q_w(W·diag(s); clip) · Q_a((x − z) ⊘ s) + W·z
//! ```
//!
//! which is numerically the original `W·x` when quantization is exact:
//! `W·diag(s)·diag(s)⁻¹·(x − z) + W·z = W·x`. Identity parameters
//! (`s = 1, z = 0, clip = 1`) make every step a bit-exact no-op, so the
//! disabled path is indistinguishable from an uncorrected engine
//! (property-tested in `rust/tests/prop_calib.rs`).
//!
//! [`CorrectionSet`] maps `(layer, projection name)` to corrections for
//! one WqAp config (keyed by its filesystem tag, e.g. `w2sa8`) and
//! round-trips through the `.abqw` weight-pack format under
//! `corr.<tag>.<layer>.<name>.{s,z,c}` so the `calibrate` CLI can persist
//! learned vectors next to the exported weights (`docs/CALIBRATION.md`).

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::model::weights::{Tensor, WeightPack};

/// Learned correction vectors for one projection.
#[derive(Clone, Debug, PartialEq)]
pub struct Correction {
    /// per-input-channel balance scale `s` (activations divided by it)
    pub scale: Vec<f32>,
    /// per-input-channel shift `z` (subtracted from activations; the
    /// displaced `W·z` is re-added as a per-output offset)
    pub shift: Vec<f32>,
    /// weight clip ratio applied symmetrically to each row's min/max
    /// before the quantization grid is fit (`1.0` = plain min-max)
    pub clip: f32,
}

impl Correction {
    /// Identity correction for `in_features` channels: bit-exact no-op.
    pub fn identity(in_features: usize) -> Self {
        Correction {
            scale: vec![1.0; in_features],
            shift: vec![0.0; in_features],
            clip: 1.0,
        }
    }

    pub fn in_features(&self) -> usize {
        self.scale.len()
    }

    pub fn is_identity(&self) -> bool {
        self.clip == 1.0
            && self.scale.iter().all(|&s| s == 1.0)
            && self.shift.iter().all(|&z| z == 0.0)
    }

    fn validate(&self) -> Result<()> {
        if self.scale.len() != self.shift.len() {
            bail!(
                "correction scale/shift length mismatch: {} vs {}",
                self.scale.len(),
                self.shift.len()
            );
        }
        if !self.scale.iter().all(|s| s.is_finite() && *s > 0.0) {
            bail!("correction scales must be finite and > 0");
        }
        if !self.shift.iter().all(|z| z.is_finite()) {
            bail!("correction shifts must be finite");
        }
        if !(self.clip.is_finite() && self.clip > 0.0 && self.clip <= 1.0) {
            bail!("correction clip must be in (0, 1], got {}", self.clip);
        }
        Ok(())
    }
}

/// All corrections learned for one WqAp config: `(layer, name)` →
/// [`Correction`].
#[derive(Clone, Debug, Default)]
pub struct CorrectionSet {
    /// filesystem-safe tag of the config the set was learned for
    /// ([`crate::quant::WAConfig::tag`], e.g. `w2sa8`)
    pub tag: String,
    entries: BTreeMap<(usize, String), Correction>,
}

impl CorrectionSet {
    pub fn new(tag: impl Into<String>) -> Self {
        CorrectionSet { tag: tag.into(), entries: BTreeMap::new() }
    }

    pub fn insert(&mut self, layer: usize, name: &str, corr: Correction) {
        self.entries.insert((layer, name.to_string()), corr);
    }

    pub fn get(&self, layer: usize, name: &str) -> Option<&Correction> {
        self.entries.get(&(layer, name.to_string()))
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&(usize, String), &Correction)> {
        self.entries.iter()
    }

    /// Corrections that are not the identity. Persistence stores every
    /// entry (identity included, to keep the set total), but identity
    /// entries are skipped at prepare time — they are mathematical
    /// no-ops, so correction-aware backends fall through to their pack
    /// codes / RTN path.
    pub fn non_identity(&self) -> usize {
        self.entries.values().filter(|c| !c.is_identity()).count()
    }

    fn tensor_base(&self, layer: usize, name: &str) -> String {
        format!("corr.{}.{layer}.{name}", self.tag)
    }

    /// Serialize into a weight pack (`corr.<tag>.<layer>.<name>.{s,z,c}`).
    pub fn to_pack(&self) -> WeightPack {
        let mut pack = WeightPack::default();
        for ((layer, name), c) in &self.entries {
            let base = self.tensor_base(*layer, name);
            let n = c.scale.len();
            pack.tensors
                .insert(format!("{base}.s"), Tensor::F32(c.scale.clone(), vec![n]));
            pack.tensors
                .insert(format!("{base}.z"), Tensor::F32(c.shift.clone(), vec![n]));
            pack.tensors
                .insert(format!("{base}.c"), Tensor::F32(vec![c.clip], vec![1]));
        }
        pack
    }

    /// Load every `corr.<tag>.*` entry from a pack. Unknown tensors are
    /// ignored, so a correction pack can live inside a full weight pack.
    pub fn from_pack(pack: &WeightPack, tag: &str) -> Result<Self> {
        let mut set = CorrectionSet::new(tag);
        let prefix = format!("corr.{tag}.");
        for key in pack.tensors.keys() {
            let Some(rest) = key.strip_prefix(&prefix) else { continue };
            let Some(base) = rest.strip_suffix(".s") else { continue };
            let mut parts = base.splitn(2, '.');
            let (Some(layer_s), Some(name)) = (parts.next(), parts.next()) else {
                bail!("malformed correction tensor name '{key}'");
            };
            let layer: usize = layer_s
                .parse()
                .map_err(|_| anyhow::anyhow!("bad layer index in '{key}'"))?;
            let full = format!("{prefix}{base}");
            let scale = pack.get(&format!("{full}.s"))?.as_f32()?.to_vec();
            let shift = pack.get(&format!("{full}.z"))?.as_f32()?.to_vec();
            let clip = *pack
                .get(&format!("{full}.c"))?
                .as_f32()?
                .first()
                .ok_or_else(|| anyhow::anyhow!("empty clip tensor '{full}.c'"))?;
            let corr = Correction { scale, shift, clip };
            corr.validate()?;
            set.insert(layer, name, corr);
        }
        Ok(set)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_is_identity() {
        let c = Correction::identity(8);
        assert!(c.is_identity());
        let mut c2 = c.clone();
        c2.scale[3] = 2.0;
        assert!(!c2.is_identity());
        let mut c3 = c.clone();
        c3.clip = 0.8;
        assert!(!c3.is_identity());
    }

    #[test]
    fn pack_roundtrip() {
        let mut set = CorrectionSet::new("w2sa8");
        set.insert(0, "wq", Correction {
            scale: vec![1.0, 2.0, 0.5],
            shift: vec![0.0, -0.25, 0.75],
            clip: 0.8,
        });
        set.insert(3, "down", Correction::identity(4));
        let pack = set.to_pack();
        let back = CorrectionSet::from_pack(&pack, "w2sa8").unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back.get(0, "wq"), set.get(0, "wq"));
        assert_eq!(back.get(3, "down"), set.get(3, "down"));
        assert!(back.get(1, "wq").is_none());
        // a different tag sees nothing
        let other = CorrectionSet::from_pack(&pack, "w4a4").unwrap();
        assert!(other.is_empty());
    }

    #[test]
    fn pack_roundtrip_through_bytes() {
        let mut set = CorrectionSet::new("w4a4");
        set.insert(1, "gate", Correction {
            scale: vec![1.5; 6],
            shift: vec![0.1; 6],
            clip: 0.7,
        });
        let bytes = set.to_pack().to_bytes();
        let pack = WeightPack::parse(&bytes).unwrap();
        let back = CorrectionSet::from_pack(&pack, "w4a4").unwrap();
        assert_eq!(back.get(1, "gate"), set.get(1, "gate"));
    }

    #[test]
    fn from_pack_rejects_bad_vectors() {
        let mut set = CorrectionSet::new("w2sa8");
        set.insert(0, "wq", Correction { scale: vec![0.0; 2], shift: vec![0.0; 2], clip: 1.0 });
        assert!(CorrectionSet::from_pack(&set.to_pack(), "w2sa8").is_err(), "zero scale");
        let mut set = CorrectionSet::new("w2sa8");
        set.insert(0, "wq", Correction { scale: vec![1.0; 2], shift: vec![0.0; 2], clip: 1.5 });
        assert!(CorrectionSet::from_pack(&set.to_pack(), "w2sa8").is_err(), "clip > 1");
    }
}
