//! Quantization: WqAp configs, weight/activation quantizers, balance
//! vectors (rust mirror of python `compile/quantizers.py`; DESIGN.md §5).

pub mod config;
pub mod correction;
pub mod quantizer;
pub mod smooth;

pub use config::{QuantSpec, WAConfig};
pub use correction::{Correction, CorrectionSet};
pub use quantizer::{
    dequantize_value, qparams_minmax, quantize_act_per_token, quantize_act_per_token_into,
    quantize_value, quantize_weight_rows, QParams, QuantizedRows,
};
pub use smooth::{
    apply_balance_act, apply_balance_weight, apply_correction_act, correction_output_offset,
    smooth_scales,
};
