//! SmoothQuant-style balance vectors (baseline + the form in which ABQ's
//! *learned* balance vectors are applied at inference).
//!
//! Eq. (1) rewrite: `W·X = (W·diag(s)) · (diag(s)⁻¹·X)`. The calibrator
//! (python) learns `s`; at inference the engine divides the activations by
//! `s` before per-token quantization and the exported weight codes already
//! contain `W·diag(s)`.

/// Closed-form SmoothQuant rule: `s_j = max|X_j|^m / max|W_j|^(1-m)`.
pub fn smooth_scales(act_absmax: &[f32], w_absmax: &[f32], migration: f32) -> Vec<f32> {
    assert_eq!(act_absmax.len(), w_absmax.len());
    act_absmax
        .iter()
        .zip(w_absmax)
        .map(|(&a, &w)| {
            let s = a.max(1e-5).powf(migration) / w.max(1e-5).powf(1.0 - migration);
            s.max(1e-5)
        })
        .collect()
}

/// Divide activations (row-major `[tokens, features]`) by `s` in place.
pub fn apply_balance_act(x: &mut [f32], features: usize, s: &[f32]) {
    assert_eq!(s.len(), features);
    for row in x.chunks_exact_mut(features) {
        for (v, &si) in row.iter_mut().zip(s) {
            *v /= si;
        }
    }
}

/// Multiply weights (row-major `[out, in]`) by `s` per input channel.
pub fn apply_balance_weight(w: &mut [f32], cols: usize, s: &[f32]) {
    assert_eq!(s.len(), cols);
    for row in w.chunks_exact_mut(cols) {
        for (v, &si) in row.iter_mut().zip(s) {
            *v *= si;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balance_preserves_product() {
        let w = vec![1.0f32, 2.0, 3.0, 4.0]; // [2, 2]
        let x = vec![5.0f32, 6.0];           // [1, 2]
        let y0: Vec<f32> = (0..2)
            .map(|r| w[r * 2] * x[0] + w[r * 2 + 1] * x[1])
            .collect();
        let s = smooth_scales(&[5.0, 6.0], &[3.0, 4.0], 0.5);
        let mut wb = w.clone();
        let mut xb = x.clone();
        apply_balance_weight(&mut wb, 2, &s);
        apply_balance_act(&mut xb, 2, &s);
        let y1: Vec<f32> = (0..2)
            .map(|r| wb[r * 2] * xb[0] + wb[r * 2 + 1] * xb[1])
            .collect();
        for (a, b) in y0.iter().zip(&y1) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn migration_extremes() {
        let s0 = smooth_scales(&[8.0], &[2.0], 0.0); // all difficulty to act
        let s1 = smooth_scales(&[8.0], &[2.0], 1.0); // all difficulty to weight
        assert!((s0[0] - 0.5).abs() < 1e-6);
        assert!((s1[0] - 8.0).abs() < 1e-6);
    }
}
