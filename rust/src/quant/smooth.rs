//! SmoothQuant-style balance vectors (baseline + the form in which ABQ's
//! *learned* balance vectors are applied at inference).
//!
//! Eq. (1) rewrite: `W·X = (W·diag(s)) · (diag(s)⁻¹·X)`. The calibrator
//! (python) learns `s`; at inference the engine divides the activations by
//! `s` before per-token quantization and the exported weight codes already
//! contain `W·diag(s)`.

/// Closed-form SmoothQuant rule: `s_j = max|X_j|^m / max|W_j|^(1-m)`.
pub fn smooth_scales(act_absmax: &[f32], w_absmax: &[f32], migration: f32) -> Vec<f32> {
    assert_eq!(act_absmax.len(), w_absmax.len());
    act_absmax
        .iter()
        .zip(w_absmax)
        .map(|(&a, &w)| {
            let s = a.max(1e-5).powf(migration) / w.max(1e-5).powf(1.0 - migration);
            s.max(1e-5)
        })
        .collect()
}

/// Divide activations (row-major `[tokens, features]`) by `s` in place.
pub fn apply_balance_act(x: &mut [f32], features: usize, s: &[f32]) {
    assert_eq!(s.len(), features);
    for row in x.chunks_exact_mut(features) {
        for (v, &si) in row.iter_mut().zip(s) {
            *v /= si;
        }
    }
}

/// Multiply weights (row-major `[out, in]`) by `s` per input channel.
pub fn apply_balance_weight(w: &mut [f32], cols: usize, s: &[f32]) {
    assert_eq!(s.len(), cols);
    for row in w.chunks_exact_mut(cols) {
        for (v, &si) in row.iter_mut().zip(s) {
            *v *= si;
        }
    }
}

/// Full distribution correction on activations (row-major
/// `[tokens, features]`), in place: `x ← (x − z) ⊘ s`. With `s = 1` and
/// `z = 0` every element is bit-identical to the input (`x - 0.0` and
/// `x / 1.0` are exact), which is what makes the identity-initialized
/// correction path indistinguishable from the uncorrected engine.
pub fn apply_correction_act(x: &mut [f32], features: usize, s: &[f32], z: &[f32]) {
    assert_eq!(s.len(), features);
    assert_eq!(z.len(), features);
    for row in x.chunks_exact_mut(features) {
        for i in 0..features {
            row[i] = (row[i] - z[i]) / s[i];
        }
    }
}

/// Per-output offset displaced by the activation shift: `off = W·z` for
/// `w` row-major `[rows, cols]`. Added back after the quantized GEMM so
/// `Q(W·s)·((x−z)/s) + W·z ≈ W·x`.
pub fn correction_output_offset(w: &[f32], rows: usize, cols: usize, z: &[f32]) -> Vec<f32> {
    assert_eq!(w.len(), rows * cols);
    assert_eq!(z.len(), cols);
    (0..rows)
        .map(|r| {
            let row = &w[r * cols..(r + 1) * cols];
            row.iter().zip(z).map(|(a, b)| a * b).sum()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balance_preserves_product() {
        let w = vec![1.0f32, 2.0, 3.0, 4.0]; // [2, 2]
        let x = vec![5.0f32, 6.0];           // [1, 2]
        let y0: Vec<f32> = (0..2)
            .map(|r| w[r * 2] * x[0] + w[r * 2 + 1] * x[1])
            .collect();
        let s = smooth_scales(&[5.0, 6.0], &[3.0, 4.0], 0.5);
        let mut wb = w.clone();
        let mut xb = x.clone();
        apply_balance_weight(&mut wb, 2, &s);
        apply_balance_act(&mut xb, 2, &s);
        let y1: Vec<f32> = (0..2)
            .map(|r| wb[r * 2] * xb[0] + wb[r * 2 + 1] * xb[1])
            .collect();
        for (a, b) in y0.iter().zip(&y1) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn correction_preserves_product() {
        // Q-free algebra: W·diag(s)·((x−z)/s) + W·z == W·x
        let (rows, cols) = (3usize, 4usize);
        let w: Vec<f32> = (0..rows * cols).map(|i| (i as f32 - 5.0) / 3.0).collect();
        let x: Vec<f32> = vec![0.5, -1.25, 2.0, 0.0];
        let s = vec![2.0f32, 0.5, 1.0, 4.0];
        let z = vec![0.25f32, -0.5, 0.0, 1.0];
        let y0: Vec<f32> = (0..rows)
            .map(|r| (0..cols).map(|c| w[r * cols + c] * x[c]).sum())
            .collect();
        let mut wb = w.clone();
        apply_balance_weight(&mut wb, cols, &s);
        let mut xb = x.clone();
        apply_correction_act(&mut xb, cols, &s, &z);
        let off = correction_output_offset(&w, rows, cols, &z);
        let y1: Vec<f32> = (0..rows)
            .map(|r| (0..cols).map(|c| wb[r * cols + c] * xb[c]).sum::<f32>() + off[r])
            .collect();
        for (a, b) in y0.iter().zip(&y1) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn identity_correction_is_bit_exact() {
        let x0: Vec<f32> = vec![1.5, -0.0, 3.25, f32::MIN_POSITIVE];
        let mut x = x0.clone();
        apply_correction_act(&mut x, 4, &[1.0; 4], &[0.0; 4]);
        for (a, b) in x.iter().zip(&x0) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn migration_extremes() {
        let s0 = smooth_scales(&[8.0], &[2.0], 0.0); // all difficulty to act
        let s1 = smooth_scales(&[8.0], &[2.0], 1.0); // all difficulty to weight
        assert!((s0[0] - 0.5).abs() < 1e-6);
        assert!((s1[0] - 8.0).abs() < 1e-6);
    }
}
