//! WqAp quantization configurations (mirrors python `quantizers.QuantSpec` /
//! `WAConfig`; the string grammar is identical: `w2*a8`, `w4a4g128`, `fp16`).

use std::fmt;
use std::str::FromStr;

/// One side (weight or activation) of a quantization configuration.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct QuantSpec {
    /// nominal bit width (16 = keep float)
    pub bits: u8,
    /// bit-balance strategy (paper §3.3): symmetric {-2..2} at 2 bits
    pub balanced: bool,
    /// per-group size along K (0 = per-channel / per-token)
    pub group: u32,
}

impl QuantSpec {
    pub const fn fp() -> Self {
        QuantSpec { bits: 16, balanced: false, group: 0 }
    }

    pub const fn new(bits: u8) -> Self {
        QuantSpec { bits, balanced: false, group: 0 }
    }

    pub fn is_fp(&self) -> bool {
        self.bits >= 16
    }

    /// Number of representable levels (bit balance: 5 at 2 bits).
    pub fn n_levels(&self) -> u32 {
        if self.balanced && self.bits == 2 {
            5
        } else {
            1 << self.bits
        }
    }

    /// Bit planes needed to store unsigned codes `0..n_levels-1`.
    pub fn planes(&self) -> usize {
        let max = self.n_levels() - 1;
        (32 - max.leading_zeros()).max(1) as usize
    }
}

/// Full WqAp configuration.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct WAConfig {
    pub weight: QuantSpec,
    pub act: QuantSpec,
}

impl WAConfig {
    pub const FP16: WAConfig = WAConfig { weight: QuantSpec::fp(), act: QuantSpec::fp() };

    pub fn new(w_bits: u8, a_bits: u8) -> Self {
        WAConfig { weight: QuantSpec::new(w_bits), act: QuantSpec::new(a_bits) }
    }

    pub fn balanced(w_bits: u8, a_bits: u8) -> Self {
        WAConfig {
            weight: QuantSpec { bits: w_bits, balanced: true, group: 0 },
            act: QuantSpec::new(a_bits),
        }
    }

    /// Artifact tag (`*` → `s`, filesystem-safe): `w2*a8` → `w2sa8`.
    pub fn tag(&self) -> String {
        self.to_string().replace('*', "s")
    }

    /// Weight bytes per element ratio vs fp16 (memory-compression model).
    pub fn weight_compression_vs_fp16(&self) -> f64 {
        if self.weight.is_fp() {
            1.0
        } else {
            16.0 / self.weight.planes() as f64
        }
    }
}

impl fmt::Display for WAConfig {
    /// Grammar: `w<bits>[*][g<N>]a<bits>[g<N>]`. A trailing `gN` with no
    /// explicit weight group is the legacy compact form and means *both*
    /// sides share the group (`w4a4g128`); `w4g128a4` is weight-only. The
    /// degenerate act-only case prints an explicit `g0` on the weight side
    /// (`w4g0a4g128`) so parse/print round-trip on every combination.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.weight.is_fp() && self.act.is_fp() {
            return write!(f, "fp16");
        }
        let star = if self.weight.balanced { "*" } else { "" };
        let (wg, ag) = (self.weight.group, self.act.group);
        write!(f, "w{}{}", self.weight.bits, star)?;
        if wg > 0 && wg != ag {
            write!(f, "g{wg}")?;
        } else if wg == 0 && ag > 0 {
            write!(f, "g0")?;
        }
        write!(f, "a{}", self.act.bits)?;
        if ag > 0 {
            write!(f, "g{ag}")?;
        }
        Ok(())
    }
}

#[derive(Debug)]
pub struct ParseError(String);

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "invalid quant config: {}", self.0)
    }
}

impl std::error::Error for ParseError {}

impl FromStr for WAConfig {
    type Err = ParseError;

    /// Grammar: `w<bits>[*|s][g<N>]a<bits>[g<N>]` (the `s` form is the
    /// filesystem-safe balance marker used in artifact tags).
    ///
    /// Group placement: `w4g128a4` sets the *weight* group only; a
    /// trailing `gN` after the act bits sets the act group and — when the
    /// weight part carries no explicit group marker — the weight group
    /// too, so the legacy compact `w4a4g128` means weight+act group 128.
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let s = s.trim().to_lowercase();
        if matches!(s.as_str(), "fp16" | "fp32" | "fp") {
            return Ok(WAConfig::FP16);
        }
        let rest = s.strip_prefix('w').ok_or_else(|| ParseError(s.clone()))?;
        let a_at = rest.find('a').ok_or_else(|| ParseError(s.clone()))?;
        let (wpart, apart) = (&rest[..a_at], &rest[a_at + 1..]);
        // weight part: bits [*|s] [gN]
        let (mut wspec, wg_explicit) = match wpart.find('g') {
            Some(i) => (
                &wpart[..i],
                Some(wpart[i + 1..].parse::<u32>().map_err(|_| ParseError(s.clone()))?),
            ),
            None => (wpart, None),
        };
        let balanced = wspec.ends_with('*') || wspec.ends_with('s');
        if balanced {
            wspec = &wspec[..wspec.len() - 1];
        }
        // act part: bits [gN]
        let (abits_str, ag_explicit) = match apart.find('g') {
            Some(i) => (
                &apart[..i],
                Some(apart[i + 1..].parse::<u32>().map_err(|_| ParseError(s.clone()))?),
            ),
            None => (apart, None),
        };
        let w_bits: u8 = wspec.parse().map_err(|_| ParseError(s.clone()))?;
        let a_bits: u8 = abits_str.parse().map_err(|_| ParseError(s.clone()))?;
        // the engine's plane decomposition covers 1..=8 bits per side;
        // 16 is the explicit keep-float marker, valid only as `w16a16`
        // (≡ `fp16`) — no engine path implements one quantized side
        // against one kept-float side, so mixed specs are rejected
        // rather than silently saturating 16-bit codes into u8
        let bits_ok = |b: u8| (1..=8).contains(&b) || b == 16;
        if !bits_ok(w_bits) || !bits_ok(a_bits) {
            return Err(ParseError(s));
        }
        if (w_bits == 16) != (a_bits == 16) {
            return Err(ParseError(s));
        }
        if balanced && w_bits == 16 {
            return Err(ParseError(s));
        }
        let (w_group, a_group) = match (wg_explicit, ag_explicit) {
            (None, Some(g)) => (g, g), // legacy compact form: both sides
            (Some(wg), Some(ag)) => (wg, ag),
            (Some(wg), None) => (wg, 0), // weight-only form
            (None, None) => (0, 0),
        };
        Ok(WAConfig {
            weight: QuantSpec { bits: w_bits, balanced, group: w_group },
            act: QuantSpec { bits: a_bits, balanced: false, group: a_group },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for s in [
            "w2a8",
            "w2*a8",
            "w4a4",
            "w8a8",
            "w4a4g128",   // compact form: weight+act group
            "w4g128a4",   // weight-only group
            "w4g64a4g128",// explicit, different groups
            "w2*g64a8",   // balance marker composes with a weight group
            "w4g0a4g128", // act-only group (explicit g0 on the weight side)
            "fp16",
            "w6a6",
        ] {
            let cfg: WAConfig = s.parse().unwrap();
            assert_eq!(cfg.to_string(), s, "roundtrip {s}");
            // a printed config re-parses to an identical config
            let back: WAConfig = cfg.to_string().parse().unwrap();
            assert_eq!(back, cfg, "reparse {s}");
        }
    }

    #[test]
    fn group_lands_on_both_sides_symmetrically() {
        // trailing gN with no weight marker ≡ weight+act group
        let both: WAConfig = "w4a4g128".parse().unwrap();
        assert_eq!(both.weight.group, 128);
        assert_eq!(both.act.group, 128);
        // weight-only form
        let wonly: WAConfig = "w4g128a4".parse().unwrap();
        assert_eq!(wonly.weight.group, 128);
        assert_eq!(wonly.act.group, 0);
        // explicit both, different values
        let mixed: WAConfig = "w4g64a4g128".parse().unwrap();
        assert_eq!(mixed.weight.group, 64);
        assert_eq!(mixed.act.group, 128);
        // act-only via explicit g0
        let aonly: WAConfig = "w4g0a4g128".parse().unwrap();
        assert_eq!(aonly.weight.group, 0);
        assert_eq!(aonly.act.group, 128);
    }

    #[test]
    fn tag_is_fs_safe() {
        let cfg: WAConfig = "w2*a8".parse().unwrap();
        assert_eq!(cfg.tag(), "w2sa8");
        let back: WAConfig = "w2sa8".parse().unwrap();
        assert_eq!(back, cfg);
    }

    #[test]
    fn balanced_levels_and_planes() {
        let cfg: WAConfig = "w2*a8".parse().unwrap();
        assert_eq!(cfg.weight.n_levels(), 5);
        assert_eq!(cfg.weight.planes(), 3);
        assert_eq!(cfg.act.planes(), 8);
        let plain: WAConfig = "w2a8".parse().unwrap();
        assert_eq!(plain.weight.n_levels(), 4);
        assert_eq!(plain.weight.planes(), 2);
    }

    #[test]
    fn rejects_garbage() {
        for s in [
            "", "w", "wXa4", "w4", "a8", "w0a4", "w4a0", "w99a99", "w4ga4", "w4a4g",
            "w4gXa4", "w4a4gX",
        ] {
            assert!(s.parse::<WAConfig>().is_err(), "{s}");
        }
    }

    /// Table-driven accept cases: spec → (w_bits, balanced, w_group,
    /// a_bits, a_group), plus the canonical `Display` form each one
    /// normalizes to.
    #[test]
    fn table_driven_accept_and_normalize() {
        #[rustfmt::skip]
        let table: &[(&str, (u8, bool, u32, u8, u32), &str)] = &[
            ("w2a8",          (2, false,   0, 8,   0), "w2a8"),
            ("w2*a8",         (2, true,    0, 8,   0), "w2*a8"),
            ("w2sa8",         (2, true,    0, 8,   0), "w2*a8"),
            ("W2*A8",         (2, true,    0, 8,   0), "w2*a8"),   // case-folded
            (" w4a4 ",        (4, false,   0, 4,   0), "w4a4"),    // trimmed
            ("w1a1",          (1, false,   0, 1,   0), "w1a1"),    // extremes
            ("w8a8",          (8, false,   0, 8,   0), "w8a8"),
            ("w4a4g128",      (4, false, 128, 4, 128), "w4a4g128"),
            ("w4g128a4",      (4, false, 128, 4,   0), "w4g128a4"),
            ("w4g64a4g128",   (4, false,  64, 4, 128), "w4g64a4g128"),
            ("w4g0a4",        (4, false,   0, 4,   0), "w4a4"),    // explicit no-group
            ("w4g0a4g128",    (4, false,   0, 4, 128), "w4g0a4g128"),
            ("w2*g64a8",      (2, true,   64, 8,   0), "w2*g64a8"),
        ];
        for &(spec, (wb, bal, wg, ab, ag), canon) in table {
            let cfg: WAConfig = spec.parse().unwrap_or_else(|e| panic!("{spec}: {e}"));
            assert_eq!(cfg.weight.bits, wb, "{spec} w_bits");
            assert_eq!(cfg.weight.balanced, bal, "{spec} balanced");
            assert_eq!(cfg.weight.group, wg, "{spec} w_group");
            assert_eq!(cfg.act.bits, ab, "{spec} a_bits");
            assert_eq!(cfg.act.group, ag, "{spec} a_group");
            assert_eq!(cfg.to_string(), canon, "{spec} canonical form");
            // every accepted spec re-parses from its Display form to an
            // identical config (print → parse is the identity)
            let back: WAConfig = cfg.to_string().parse().unwrap();
            assert_eq!(back, cfg, "{spec} display round-trip");
        }
        // the fp strings normalize to the FP16 constant
        for fp in ["fp16", "fp32", "fp", "w16a16", "FP16"] {
            let cfg: WAConfig = fp.parse().unwrap();
            assert_eq!(cfg, WAConfig::FP16, "{fp}");
            assert_eq!(cfg.to_string(), "fp16");
        }
    }

    /// Table-driven reject cases (the ISSUE-4 negative list plus edge
    /// grammar): zero/out-of-range bits, doubled balance markers, empty
    /// group digits, trailing garbage.
    #[test]
    fn table_driven_reject() {
        #[rustfmt::skip]
        let table: &[(&str, &str)] = &[
            ("w0a4",       "zero weight bits"),
            ("w4a0",       "zero act bits"),
            ("w9a8",       "9 weight bits exceeds the 8-bit plane engine"),
            ("w4a12",      "12 act bits exceeds the 8-bit plane engine"),
            ("w15a15",     "15 bits is not the fp marker"),
            ("w17a4",      "beyond the fp marker"),
            ("w16a8",      "mixed fp/quantized sides have no engine path"),
            ("w4a16",      "mixed quantized/fp sides have no engine path"),
            ("w2**a8",     "doubled balance marker"),
            ("w2*sa8",     "mixed balance markers"),
            ("w16*a8",     "balance marker on the fp side"),
            ("w4ga4",      "empty weight group digits"),
            ("w4a4g",      "empty act group digits"),
            ("w4g a4",     "whitespace inside the group"),
            ("w4a4x",      "trailing garbage after act bits"),
            ("w4a4g128x",  "trailing garbage after act group"),
            ("w4g128xa4",  "trailing garbage after weight group"),
            ("w4a4 extra", "trailing token"),
            ("w-2a8",      "negative bits"),
            ("w2a8*",      "balance marker on the act side"),
            ("ww2a8",      "doubled prefix"),
            ("w2aa8",      "doubled act marker parses as garbage bits"),
        ];
        for (spec, why) in table {
            assert!(spec.parse::<WAConfig>().is_err(), "'{spec}' must be rejected ({why})");
        }
    }
}
