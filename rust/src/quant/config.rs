//! WqAp quantization configurations (mirrors python `quantizers.QuantSpec` /
//! `WAConfig`; the string grammar is identical: `w2*a8`, `w4a4g128`, `fp16`).

use std::fmt;
use std::str::FromStr;

/// One side (weight or activation) of a quantization configuration.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct QuantSpec {
    /// nominal bit width (16 = keep float)
    pub bits: u8,
    /// bit-balance strategy (paper §3.3): symmetric {-2..2} at 2 bits
    pub balanced: bool,
    /// per-group size along K (0 = per-channel / per-token)
    pub group: u32,
}

impl QuantSpec {
    pub const fn fp() -> Self {
        QuantSpec { bits: 16, balanced: false, group: 0 }
    }

    pub const fn new(bits: u8) -> Self {
        QuantSpec { bits, balanced: false, group: 0 }
    }

    pub fn is_fp(&self) -> bool {
        self.bits >= 16
    }

    /// Number of representable levels (bit balance: 5 at 2 bits).
    pub fn n_levels(&self) -> u32 {
        if self.balanced && self.bits == 2 {
            5
        } else {
            1 << self.bits
        }
    }

    /// Bit planes needed to store unsigned codes `0..n_levels-1`.
    pub fn planes(&self) -> usize {
        let max = self.n_levels() - 1;
        (32 - max.leading_zeros()).max(1) as usize
    }
}

/// Full WqAp configuration.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct WAConfig {
    pub weight: QuantSpec,
    pub act: QuantSpec,
}

impl WAConfig {
    pub const FP16: WAConfig = WAConfig { weight: QuantSpec::fp(), act: QuantSpec::fp() };

    pub fn new(w_bits: u8, a_bits: u8) -> Self {
        WAConfig { weight: QuantSpec::new(w_bits), act: QuantSpec::new(a_bits) }
    }

    pub fn balanced(w_bits: u8, a_bits: u8) -> Self {
        WAConfig {
            weight: QuantSpec { bits: w_bits, balanced: true, group: 0 },
            act: QuantSpec::new(a_bits),
        }
    }

    /// Artifact tag (`*` → `s`, filesystem-safe): `w2*a8` → `w2sa8`.
    pub fn tag(&self) -> String {
        self.to_string().replace('*', "s")
    }

    /// Weight bytes per element ratio vs fp16 (memory-compression model).
    pub fn weight_compression_vs_fp16(&self) -> f64 {
        if self.weight.is_fp() {
            1.0
        } else {
            16.0 / self.weight.planes() as f64
        }
    }
}

impl fmt::Display for WAConfig {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.weight.is_fp() && self.act.is_fp() {
            return write!(f, "fp16");
        }
        let star = if self.weight.balanced { "*" } else { "" };
        let group = if self.weight.group > 0 {
            format!("g{}", self.weight.group)
        } else {
            String::new()
        };
        write!(f, "w{}{}a{}{}", self.weight.bits, star, self.act.bits, group)
    }
}

#[derive(Debug, thiserror::Error)]
#[error("invalid quant config: {0}")]
pub struct ParseError(String);

impl FromStr for WAConfig {
    type Err = ParseError;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let s = s.trim().to_lowercase();
        if matches!(s.as_str(), "fp16" | "fp32" | "fp") {
            return Ok(WAConfig::FP16);
        }
        let rest = s.strip_prefix('w').ok_or_else(|| ParseError(s.clone()))?;
        let a_at = rest.find('a').ok_or_else(|| ParseError(s.clone()))?;
        let (mut wpart, apart) = (&rest[..a_at], &rest[a_at + 1..]);
        let balanced = wpart.ends_with('*') || wpart.ends_with('s');
        if balanced {
            wpart = &wpart[..wpart.len() - 1];
        }
        let (abits_str, group) = match apart.find('g') {
            Some(i) => (
                &apart[..i],
                apart[i + 1..].parse::<u32>().map_err(|_| ParseError(s.clone()))?,
            ),
            None => (apart, 0),
        };
        let w_bits: u8 = wpart.parse().map_err(|_| ParseError(s.clone()))?;
        let a_bits: u8 = abits_str.parse().map_err(|_| ParseError(s.clone()))?;
        if w_bits == 0 || w_bits > 16 || a_bits == 0 || a_bits > 16 {
            return Err(ParseError(s));
        }
        Ok(WAConfig {
            weight: QuantSpec { bits: w_bits, balanced, group },
            act: QuantSpec::new(a_bits),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for s in ["w2a8", "w2*a8", "w4a4", "w8a8", "w4a4g128", "fp16", "w6a6"] {
            let cfg: WAConfig = s.parse().unwrap();
            assert_eq!(cfg.to_string(), s, "roundtrip {s}");
        }
    }

    #[test]
    fn tag_is_fs_safe() {
        let cfg: WAConfig = "w2*a8".parse().unwrap();
        assert_eq!(cfg.tag(), "w2sa8");
        let back: WAConfig = "w2sa8".parse().unwrap();
        assert_eq!(back, cfg);
    }

    #[test]
    fn balanced_levels_and_planes() {
        let cfg: WAConfig = "w2*a8".parse().unwrap();
        assert_eq!(cfg.weight.n_levels(), 5);
        assert_eq!(cfg.weight.planes(), 3);
        assert_eq!(cfg.act.planes(), 8);
        let plain: WAConfig = "w2a8".parse().unwrap();
        assert_eq!(plain.weight.n_levels(), 4);
        assert_eq!(plain.weight.planes(), 2);
    }

    #[test]
    fn rejects_garbage() {
        for s in ["", "w", "wXa4", "w4", "a8", "w0a4", "w4a0", "w99a99"] {
            assert!(s.parse::<WAConfig>().is_err(), "{s}");
        }
    }
}
