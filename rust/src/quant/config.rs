//! WqAp quantization configurations (mirrors python `quantizers.QuantSpec` /
//! `WAConfig`; the string grammar is identical: `w2*a8`, `w4a4g128`, `fp16`).

use std::fmt;
use std::str::FromStr;

/// One side (weight or activation) of a quantization configuration.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct QuantSpec {
    /// nominal bit width (16 = keep float)
    pub bits: u8,
    /// bit-balance strategy (paper §3.3): symmetric {-2..2} at 2 bits
    pub balanced: bool,
    /// per-group size along K (0 = per-channel / per-token)
    pub group: u32,
}

impl QuantSpec {
    pub const fn fp() -> Self {
        QuantSpec { bits: 16, balanced: false, group: 0 }
    }

    pub const fn new(bits: u8) -> Self {
        QuantSpec { bits, balanced: false, group: 0 }
    }

    pub fn is_fp(&self) -> bool {
        self.bits >= 16
    }

    /// Number of representable levels (bit balance: 5 at 2 bits).
    pub fn n_levels(&self) -> u32 {
        if self.balanced && self.bits == 2 {
            5
        } else {
            1 << self.bits
        }
    }

    /// Bit planes needed to store unsigned codes `0..n_levels-1`.
    pub fn planes(&self) -> usize {
        let max = self.n_levels() - 1;
        (32 - max.leading_zeros()).max(1) as usize
    }
}

/// Full WqAp configuration.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct WAConfig {
    pub weight: QuantSpec,
    pub act: QuantSpec,
}

impl WAConfig {
    pub const FP16: WAConfig = WAConfig { weight: QuantSpec::fp(), act: QuantSpec::fp() };

    pub fn new(w_bits: u8, a_bits: u8) -> Self {
        WAConfig { weight: QuantSpec::new(w_bits), act: QuantSpec::new(a_bits) }
    }

    pub fn balanced(w_bits: u8, a_bits: u8) -> Self {
        WAConfig {
            weight: QuantSpec { bits: w_bits, balanced: true, group: 0 },
            act: QuantSpec::new(a_bits),
        }
    }

    /// Artifact tag (`*` → `s`, filesystem-safe): `w2*a8` → `w2sa8`.
    pub fn tag(&self) -> String {
        self.to_string().replace('*', "s")
    }

    /// Weight bytes per element ratio vs fp16 (memory-compression model).
    pub fn weight_compression_vs_fp16(&self) -> f64 {
        if self.weight.is_fp() {
            1.0
        } else {
            16.0 / self.weight.planes() as f64
        }
    }
}

impl fmt::Display for WAConfig {
    /// Grammar: `w<bits>[*][g<N>]a<bits>[g<N>]`. A trailing `gN` with no
    /// explicit weight group is the legacy compact form and means *both*
    /// sides share the group (`w4a4g128`); `w4g128a4` is weight-only. The
    /// degenerate act-only case prints an explicit `g0` on the weight side
    /// (`w4g0a4g128`) so parse/print round-trip on every combination.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.weight.is_fp() && self.act.is_fp() {
            return write!(f, "fp16");
        }
        let star = if self.weight.balanced { "*" } else { "" };
        let (wg, ag) = (self.weight.group, self.act.group);
        write!(f, "w{}{}", self.weight.bits, star)?;
        if wg > 0 && wg != ag {
            write!(f, "g{wg}")?;
        } else if wg == 0 && ag > 0 {
            write!(f, "g0")?;
        }
        write!(f, "a{}", self.act.bits)?;
        if ag > 0 {
            write!(f, "g{ag}")?;
        }
        Ok(())
    }
}

#[derive(Debug)]
pub struct ParseError(String);

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "invalid quant config: {}", self.0)
    }
}

impl std::error::Error for ParseError {}

impl FromStr for WAConfig {
    type Err = ParseError;

    /// Grammar: `w<bits>[*|s][g<N>]a<bits>[g<N>]` (the `s` form is the
    /// filesystem-safe balance marker used in artifact tags).
    ///
    /// Group placement: `w4g128a4` sets the *weight* group only; a
    /// trailing `gN` after the act bits sets the act group and — when the
    /// weight part carries no explicit group marker — the weight group
    /// too, so the legacy compact `w4a4g128` means weight+act group 128.
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let s = s.trim().to_lowercase();
        if matches!(s.as_str(), "fp16" | "fp32" | "fp") {
            return Ok(WAConfig::FP16);
        }
        let rest = s.strip_prefix('w').ok_or_else(|| ParseError(s.clone()))?;
        let a_at = rest.find('a').ok_or_else(|| ParseError(s.clone()))?;
        let (wpart, apart) = (&rest[..a_at], &rest[a_at + 1..]);
        // weight part: bits [*|s] [gN]
        let (mut wspec, wg_explicit) = match wpart.find('g') {
            Some(i) => (
                &wpart[..i],
                Some(wpart[i + 1..].parse::<u32>().map_err(|_| ParseError(s.clone()))?),
            ),
            None => (wpart, None),
        };
        let balanced = wspec.ends_with('*') || wspec.ends_with('s');
        if balanced {
            wspec = &wspec[..wspec.len() - 1];
        }
        // act part: bits [gN]
        let (abits_str, ag_explicit) = match apart.find('g') {
            Some(i) => (
                &apart[..i],
                Some(apart[i + 1..].parse::<u32>().map_err(|_| ParseError(s.clone()))?),
            ),
            None => (apart, None),
        };
        let w_bits: u8 = wspec.parse().map_err(|_| ParseError(s.clone()))?;
        let a_bits: u8 = abits_str.parse().map_err(|_| ParseError(s.clone()))?;
        if w_bits == 0 || w_bits > 16 || a_bits == 0 || a_bits > 16 {
            return Err(ParseError(s));
        }
        let (w_group, a_group) = match (wg_explicit, ag_explicit) {
            (None, Some(g)) => (g, g), // legacy compact form: both sides
            (Some(wg), Some(ag)) => (wg, ag),
            (Some(wg), None) => (wg, 0), // weight-only form
            (None, None) => (0, 0),
        };
        Ok(WAConfig {
            weight: QuantSpec { bits: w_bits, balanced, group: w_group },
            act: QuantSpec { bits: a_bits, balanced: false, group: a_group },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for s in [
            "w2a8",
            "w2*a8",
            "w4a4",
            "w8a8",
            "w4a4g128",   // compact form: weight+act group
            "w4g128a4",   // weight-only group
            "w4g64a4g128",// explicit, different groups
            "w2*g64a8",   // balance marker composes with a weight group
            "w4g0a4g128", // act-only group (explicit g0 on the weight side)
            "fp16",
            "w6a6",
        ] {
            let cfg: WAConfig = s.parse().unwrap();
            assert_eq!(cfg.to_string(), s, "roundtrip {s}");
            // a printed config re-parses to an identical config
            let back: WAConfig = cfg.to_string().parse().unwrap();
            assert_eq!(back, cfg, "reparse {s}");
        }
    }

    #[test]
    fn group_lands_on_both_sides_symmetrically() {
        // trailing gN with no weight marker ≡ weight+act group
        let both: WAConfig = "w4a4g128".parse().unwrap();
        assert_eq!(both.weight.group, 128);
        assert_eq!(both.act.group, 128);
        // weight-only form
        let wonly: WAConfig = "w4g128a4".parse().unwrap();
        assert_eq!(wonly.weight.group, 128);
        assert_eq!(wonly.act.group, 0);
        // explicit both, different values
        let mixed: WAConfig = "w4g64a4g128".parse().unwrap();
        assert_eq!(mixed.weight.group, 64);
        assert_eq!(mixed.act.group, 128);
        // act-only via explicit g0
        let aonly: WAConfig = "w4g0a4g128".parse().unwrap();
        assert_eq!(aonly.weight.group, 0);
        assert_eq!(aonly.act.group, 128);
    }

    #[test]
    fn tag_is_fs_safe() {
        let cfg: WAConfig = "w2*a8".parse().unwrap();
        assert_eq!(cfg.tag(), "w2sa8");
        let back: WAConfig = "w2sa8".parse().unwrap();
        assert_eq!(back, cfg);
    }

    #[test]
    fn balanced_levels_and_planes() {
        let cfg: WAConfig = "w2*a8".parse().unwrap();
        assert_eq!(cfg.weight.n_levels(), 5);
        assert_eq!(cfg.weight.planes(), 3);
        assert_eq!(cfg.act.planes(), 8);
        let plain: WAConfig = "w2a8".parse().unwrap();
        assert_eq!(plain.weight.n_levels(), 4);
        assert_eq!(plain.weight.planes(), 2);
    }

    #[test]
    fn rejects_garbage() {
        for s in [
            "", "w", "wXa4", "w4", "a8", "w0a4", "w4a0", "w99a99", "w4ga4", "w4a4g",
            "w4gXa4", "w4a4gX",
        ] {
            assert!(s.parse::<WAConfig>().is_err(), "{s}");
        }
    }
}
