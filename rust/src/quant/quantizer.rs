//! Quantizers (rust mirror of python `compile/quantizers.py`).
//!
//! * weights: per-output-channel asymmetric min/max, optional clipping
//!   (α, β) and per-group along K; bit-balance 5-level grid at w2*
//! * activations: per-token dynamic asymmetric min/max (0 always
//!   representable)
//!
//! Codes are unsigned (`u8`) with explicit zero points — the form the
//! bit-plane engine consumes.

use super::config::QuantSpec;

/// Quantization parameters for one row (channel or token).
#[derive(Clone, Copy, Debug)]
pub struct QParams {
    pub delta: f32,
    pub zp: i32,
}

/// Compute (delta, zp) from clipped min/max for a spec.
pub fn qparams_minmax(lo: f32, hi: f32, spec: &QuantSpec) -> QParams {
    let n = spec.n_levels() as f32;
    if spec.balanced && spec.bits == 2 {
        // symmetric 5-level grid {-2Δ..2Δ}
        let absmax = lo.abs().max(hi.abs());
        let delta = (absmax / 2.0).max(1e-8);
        return QParams { delta, zp: 2 };
    }
    let delta = ((hi - lo) / (n - 1.0)).max(1e-8);
    let zp = (-lo / delta).round().clamp(0.0, n - 1.0) as i32;
    QParams { delta, zp }
}

#[inline]
pub fn quantize_value(x: f32, p: QParams, spec: &QuantSpec) -> u8 {
    let n = spec.n_levels() as f32;
    ((x / p.delta).round() + p.zp as f32).clamp(0.0, n - 1.0) as u8
}

#[inline]
pub fn dequantize_value(q: u8, p: QParams) -> f32 {
    (q as i32 - p.zp) as f32 * p.delta
}

/// Per-output-channel weight quantization.
///
/// `w`: row-major `[out, in]`. `alpha`/`beta` clip the per-row max/min
/// (paper Eq. 1). Returns codes + per-row params.
pub struct QuantizedRows {
    pub codes: Vec<u8>,
    pub params: Vec<QParams>,
    pub rows: usize,
    pub cols: usize,
}

pub fn quantize_weight_rows(
    w: &[f32],
    rows: usize,
    cols: usize,
    spec: &QuantSpec,
    alpha: f32,
    beta: f32,
) -> QuantizedRows {
    assert_eq!(w.len(), rows * cols);
    let mut codes = vec![0u8; rows * cols];
    let mut params = Vec::with_capacity(rows);
    for r in 0..rows {
        let row = &w[r * cols..(r + 1) * cols];
        let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
        for &v in row {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        // keep 0 inside the range: avoids a degenerate Δ for (near-)
        // constant rows and matches the python exporter's convention
        let lo = (beta * lo).min(0.0);
        let hi = (alpha * hi).max(0.0);
        let p = qparams_minmax(lo, hi, spec);
        for (c, &v) in row.iter().enumerate() {
            codes[r * cols + c] = quantize_value(v, p, spec);
        }
        params.push(p);
    }
    QuantizedRows { codes, params, rows, cols }
}

/// Per-token activation quantization writing codes and per-token params
/// into caller-owned buffers (cleared + resized; with warm capacity the
/// call is allocation-free). The decode hot path
/// ([`crate::abq::QuantizedLinear::forward_scratch`]) quantizes through
/// this form so steady-state single-token decode never touches the heap.
pub fn quantize_act_per_token_into(
    x: &[f32],
    tokens: usize,
    features: usize,
    spec: &QuantSpec,
    codes: &mut Vec<u8>,
    zps: &mut Vec<i32>,
    deltas: &mut Vec<f32>,
) {
    assert_eq!(x.len(), tokens * features);
    codes.clear();
    codes.resize(tokens * features, 0);
    zps.clear();
    deltas.clear();
    for t in 0..tokens {
        let row = &x[t * features..(t + 1) * features];
        let (mut lo, mut hi) = (0f32, 0f32); // keep zero representable
        for &v in row {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        let p = qparams_minmax(lo, hi, spec);
        for (c, &v) in row.iter().enumerate() {
            codes[t * features + c] = quantize_value(v, p, spec);
        }
        zps.push(p.zp);
        deltas.push(p.delta);
    }
}

/// Per-token activation quantization of `x` `[tokens, features]`
/// (allocating wrapper over [`quantize_act_per_token_into`] — one
/// quantization loop, no drift between the two forms).
pub fn quantize_act_per_token(
    x: &[f32],
    tokens: usize,
    features: usize,
    spec: &QuantSpec,
) -> QuantizedRows {
    let mut codes = Vec::new();
    let mut zps = Vec::new();
    let mut deltas = Vec::new();
    quantize_act_per_token_into(x, tokens, features, spec, &mut codes, &mut zps, &mut deltas);
    let params = zps
        .iter()
        .zip(&deltas)
        .map(|(&zp, &delta)| QParams { delta, zp })
        .collect();
    QuantizedRows { codes, params, rows: tokens, cols: features }
}

impl QuantizedRows {
    pub fn zps(&self) -> Vec<i32> {
        self.params.iter().map(|p| p.zp).collect()
    }

    pub fn deltas(&self) -> Vec<f32> {
        self.params.iter().map(|p| p.delta).collect()
    }

    /// Dequantize back to floats (reference / tests).
    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = vec![0f32; self.codes.len()];
        for r in 0..self.rows {
            let p = self.params[r];
            for c in 0..self.cols {
                out[r * self.cols + c] = dequantize_value(self.codes[r * self.cols + c], p);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::config::QuantSpec;

    fn spec(bits: u8) -> QuantSpec {
        QuantSpec::new(bits)
    }

    #[test]
    fn codes_in_range_and_error_bounded() {
        let w: Vec<f32> = (0..4 * 32).map(|i| ((i * 37 % 101) as f32 - 50.0) / 17.0).collect();
        for bits in [2u8, 3, 4, 8] {
            let s = spec(bits);
            let q = quantize_weight_rows(&w, 4, 32, &s, 1.0, 1.0);
            let maxcode = (s.n_levels() - 1) as u8;
            assert!(q.codes.iter().all(|&c| c <= maxcode));
            let dq = q.dequantize();
            for r in 0..4 {
                let d = q.params[r].delta;
                for c in 0..32 {
                    let err = (dq[r * 32 + c] - w[r * 32 + c]).abs();
                    assert!(err <= d * 0.5 + 1e-6, "bits {bits} err {err} delta {d}");
                }
            }
        }
    }

    #[test]
    fn balanced_grid_is_symmetric() {
        let s = QuantSpec { bits: 2, balanced: true, group: 0 };
        let w = vec![-1.0f32, -0.5, 0.0, 0.5, 1.0, 0.77, -0.77, 0.1];
        let q = quantize_weight_rows(&w, 1, 8, &s, 1.0, 1.0);
        assert_eq!(q.params[0].zp, 2);
        let dq = q.dequantize();
        // level set must be symmetric around 0: {-2Δ, -Δ, 0, Δ, 2Δ}
        let d = q.params[0].delta;
        for v in dq {
            let lvl = v / d;
            assert!((lvl.round() - lvl).abs() < 1e-5 && lvl.abs() <= 2.0 + 1e-5);
        }
    }

    #[test]
    fn plain_int2_grid_is_asymmetric() {
        // standard INT2 on symmetric data puts 4 levels over [-1, 1]:
        // the grid cannot contain both -x and +x for the extremes —
        // the asymmetry the bit-balance strategy fixes (paper Fig. 7).
        let s = spec(2);
        let w = vec![-1.0f32, -0.33, 0.33, 1.0];
        let q = quantize_weight_rows(&w, 1, 4, &s, 1.0, 1.0);
        let dq = q.dequantize();
        let has = |x: f32| dq.iter().any(|v| (v - x).abs() < 1e-6);
        assert!(has(-1.0) != has(1.0) || dq.iter().all(|v| (v.abs() - 1.0).abs() > 1e-6));
    }

    #[test]
    fn act_quant_keeps_zero_exact() {
        let s = spec(8);
        let x = vec![0.5f32, 1.5, 3.0, 0.0, 2.0, 7.5, 0.0, 1.0];
        let q = quantize_act_per_token(&x, 2, 4, &s);
        let dq = q.dequantize();
        assert!((dq[3]).abs() < 1e-6);
        assert!((dq[6]).abs() < 1e-6);
    }

    #[test]
    fn clipping_shrinks_range() {
        let s = spec(4);
        let mut w = vec![0.1f32; 64];
        w[0] = 100.0; // outlier
        let q_full = quantize_weight_rows(&w, 1, 64, &s, 1.0, 1.0);
        let q_clip = quantize_weight_rows(&w, 1, 64, &s, 0.05, 1.0);
        assert!(q_clip.params[0].delta < q_full.params[0].delta);
    }
}
