//! The model zoo: a registry of known architectures, the way precision
//! backends are registered in `engine/` (ROADMAP item 1). Each entry is a
//! complete [`ModelConfig`] — servable end-to-end with random weights via
//! `--arch <name>`, or matched against a checkpoint manifest by name —
//! plus the family metadata the CLI reports.
//!
//! Entries span the axes the forward is parametric over:
//!
//! * **attention** — MHA (`n_kv_heads == n_heads`), GQA, MQA
//!   (`n_kv_heads == 1`); GQA divides KV bytes per token by
//!   `group_size()`, which multiplies paged-pool admission capacity on
//!   top of KV quantization (`tests/prop_zoo.rs` pins the floor);
//! * **variant** — RMSNorm/LayerNorm, SwiGLU/GeGLU, tied/untied
//!   unembedding ([`crate::model::ArchVariant`]).
//!
//! Adding an architecture = adding one [`ZooEntry`] here (and, for real
//! checkpoints, emitting the same fields from the Python manifest
//! writer). `docs/ENGINE_API.md` §"Model zoo" walks through it.

use super::config::{ArchVariant, Activation, ModelConfig, Norm};

/// Model family, for reporting and for loader-side expectations (a
/// `NeoxLike` entry has no `head` tensor in its pack, etc.).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Family {
    /// RMSNorm + SwiGLU + untied head (LLaMA, Mistral, …)
    LlamaLike,
    /// LayerNorm + GeGLU + tied embeddings (GPT-NeoX-likes)
    NeoxLike,
}

/// One registry entry: a servable architecture description.
#[derive(Clone, Copy, Debug)]
pub struct ZooEntry {
    pub cfg: ModelConfig,
    pub family: Family,
    /// one-line description for `abq-llm info` / serve banners
    pub description: &'static str,
}

impl ZooEntry {
    pub fn name(&self) -> &'static str {
        self.cfg.name
    }
}

/// Tiny GQA sibling of [`super::config::TINY`]: same residual width and
/// depth, but 8 query heads share 2 KV heads (group factor 4), so KV rows
/// are `kv_dim = 64` instead of 256. Servable end-to-end with random
/// weights; the parity/admission tests in `tests/prop_zoo.rs` run on it.
pub const TINY_GQA: ModelConfig = ModelConfig {
    name: "tiny-gqa",
    vocab: 512,
    d_model: 256,
    n_layers: 4,
    n_heads: 8,
    n_kv_heads: 2,
    d_ff: 704,
    max_seq: 256,
    rope_base: 10000.0,
    arch: ArchVariant::LLAMA,
};

/// Tiny MQA extreme: all 8 query heads share one KV head (`kv_dim = 32`).
pub const TINY_MQA: ModelConfig = ModelConfig {
    name: "tiny-mqa",
    vocab: 512,
    d_model: 256,
    n_layers: 4,
    n_heads: 8,
    n_kv_heads: 1,
    d_ff: 704,
    max_seq: 256,
    rope_base: 10000.0,
    arch: ArchVariant::LLAMA,
};

/// Tiny GPT-NeoX-like: bias-free LayerNorm, GeGLU gate, tied embeddings —
/// the non-LLaMA variant exercising every [`ArchVariant`] axis at once,
/// with GQA attention on top.
pub const TINY_NEOX: ModelConfig = ModelConfig {
    name: "tiny-neox",
    vocab: 512,
    d_model: 256,
    n_layers: 4,
    n_heads: 8,
    n_kv_heads: 2,
    d_ff: 704,
    max_seq: 256,
    rope_base: 10000.0,
    arch: ArchVariant {
        norm: Norm::LayerNorm,
        act: Activation::Gelu,
        tied_embeddings: true,
    },
};

/// LLaMA-2-70B dims (GQA in production: 64 query heads over 8 KV heads) —
/// analytic/bench shapes only, like the other `LLAMA_*` consts.
pub const LLAMA2_70B: ModelConfig = ModelConfig {
    name: "llama2-70b",
    vocab: 32000,
    d_model: 8192,
    n_layers: 80,
    n_heads: 64,
    n_kv_heads: 8,
    d_ff: 28672,
    max_seq: 4096,
    rope_base: 10000.0,
    arch: ArchVariant::LLAMA,
};

/// Every registered architecture. Order is stable (CLI listings).
pub fn entries() -> &'static [ZooEntry] {
    const ENTRIES: &[ZooEntry] = &[
        ZooEntry {
            cfg: super::config::TINY,
            family: Family::LlamaLike,
            description: "tiny trained LLaMA-shape (MHA), the end-to-end checkpoint",
        },
        ZooEntry {
            cfg: TINY_GQA,
            family: Family::LlamaLike,
            description: "tiny GQA: 8 query heads over 2 KV heads (4x KV shrink)",
        },
        ZooEntry {
            cfg: TINY_MQA,
            family: Family::LlamaLike,
            description: "tiny MQA: 8 query heads over 1 KV head (8x KV shrink)",
        },
        ZooEntry {
            cfg: TINY_NEOX,
            family: Family::NeoxLike,
            description: "tiny GPT-NeoX-like: LayerNorm + GeGLU + tied embeddings, GQA",
        },
        ZooEntry {
            cfg: super::config::LLAMA_7B,
            family: Family::LlamaLike,
            description: "LLaMA-7B dims (analytic / bench shapes)",
        },
        ZooEntry {
            cfg: super::config::LLAMA_13B,
            family: Family::LlamaLike,
            description: "LLaMA-13B dims (analytic / bench shapes)",
        },
        ZooEntry {
            cfg: super::config::LLAMA_30B,
            family: Family::LlamaLike,
            description: "LLaMA-30B dims (analytic / bench shapes)",
        },
        ZooEntry {
            cfg: LLAMA2_70B,
            family: Family::LlamaLike,
            description: "LLaMA-2-70B dims with production GQA (64q over 8kv)",
        },
    ];
    ENTRIES
}

/// Look an architecture up by name.
pub fn lookup(name: &str) -> Option<&'static ZooEntry> {
    entries().iter().find(|e| e.cfg.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_validates_and_names_are_unique() {
        let es = entries();
        for e in es {
            e.cfg.validate().unwrap_or_else(|err| panic!("{}: {err}", e.name()));
        }
        for (i, a) in es.iter().enumerate() {
            for b in &es[i + 1..] {
                assert_ne!(a.name(), b.name(), "duplicate zoo name");
            }
        }
    }

    #[test]
    fn lookup_finds_registered_and_rejects_unknown() {
        assert_eq!(lookup("tiny-gqa").unwrap().cfg, TINY_GQA);
        assert_eq!(lookup("tiny-gqa").unwrap().cfg.group_size(), 4);
        assert!(lookup("no-such-model").is_none());
    }

    #[test]
    fn gqa_entries_shrink_kv_by_group_factor() {
        let mha = lookup("tiny-llama").unwrap().cfg;
        let gqa = TINY_GQA;
        let mqa = TINY_MQA;
        assert_eq!(mha.kv_bytes(128) / gqa.kv_bytes(128), 4.0);
        assert_eq!(mha.kv_bytes(128) / mqa.kv_bytes(128), 8.0);
        // llama2-70b: 64/8 = 8x narrower KV than an MHA model of its width
        assert_eq!(LLAMA2_70B.group_size(), 8);
        assert_eq!(LLAMA2_70B.kv_dim(), 1024);
    }

    #[test]
    fn family_matches_variant() {
        for e in entries() {
            match e.family {
                Family::LlamaLike => assert_eq!(e.cfg.arch, ArchVariant::LLAMA, "{}", e.name()),
                Family::NeoxLike => {
                    assert_eq!(e.cfg.arch.norm, Norm::LayerNorm, "{}", e.name());
                    assert!(e.cfg.arch.tied_embeddings, "{}", e.name());
                }
            }
        }
    }
}
