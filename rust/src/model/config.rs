//! Model configurations: the tiny trained model (served end-to-end) and
//! the real LLaMA-family dimensions (used *analytically* and for
//! real-shape kernel benches — Tables 12/13/14 run GEMMs at these shapes).

/// LLaMA-family architecture description.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: &'static str,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub rope_base: f32,
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Parameters in the transformer blocks + embeddings.
    pub fn param_count(&self) -> usize {
        let per_block = 4 * self.d_model * self.d_model + 3 * self.d_model * self.d_ff
            + 2 * self.d_model;
        self.vocab * self.d_model + self.n_layers * per_block + self.d_model
            + self.d_model * self.vocab
    }

    /// Per-layer GEMM shapes (N, K): q/k/v/o + gate/up/down — the shapes
    /// the paper's kernel tables sweep.
    pub fn layer_shapes(&self) -> Vec<(&'static str, usize, usize)> {
        vec![
            ("wq", self.d_model, self.d_model),
            ("wk", self.d_model, self.d_model),
            ("wv", self.d_model, self.d_model),
            ("wo", self.d_model, self.d_model),
            ("gate", self.d_ff, self.d_model),
            ("up", self.d_ff, self.d_model),
            ("down", self.d_model, self.d_ff),
        ]
    }

    /// Weight bytes at `bits_per_weight` (planes for ABQ), for the Table 12
    /// memory model. Embedding + head stay fp16 as in the paper's engine.
    pub fn weight_bytes(&self, block_bits: f64) -> f64 {
        let per_block: usize = self.layer_shapes().iter().map(|(_, n, k)| n * k).sum();
        let block_bytes = self.n_layers as f64 * per_block as f64 * block_bits / 8.0;
        let embed_bytes = (2 * self.vocab * self.d_model + self.d_model) as f64 * 2.0;
        block_bytes + embed_bytes
    }

    /// KV cache bytes for one sequence of `seq` tokens (fp16 cache).
    pub fn kv_bytes(&self, seq: usize) -> f64 {
        (2 * self.n_layers * seq * self.d_model) as f64 * 2.0
    }

    /// Parse the `model` block of an artifacts `manifest.json` (shared by
    /// the native and PJRT loaders in `engine/`).
    pub fn from_manifest(j: &crate::util::json::Json) -> anyhow::Result<Self> {
        use anyhow::Context;
        let need = |field: &'static str| {
            j.at(&["model", field]).and_then(|v| v.as_usize()).context(field)
        };
        Ok(ModelConfig {
            name: "tiny-llama",
            vocab: need("vocab")?,
            d_model: need("d_model")?,
            n_layers: need("n_layers")?,
            n_heads: need("n_heads")?,
            d_ff: need("d_ff")?,
            max_seq: need("max_seq")?,
            rope_base: j
                .at(&["model", "rope_base"])
                .and_then(|v| v.as_f64())
                .context("rope_base")? as f32,
        })
    }
}

/// The tiny model trained by `python/compile/train_tiny.py` (must match
/// `compile/model.py::TINY` and the manifest).
pub const TINY: ModelConfig = ModelConfig {
    name: "tiny-llama",
    vocab: 512,
    d_model: 256,
    n_layers: 4,
    n_heads: 8,
    d_ff: 704,
    max_seq: 256,
    rope_base: 10000.0,
};

/// Real LLaMA dims (analytic / bench shapes only — no checkpoints here).
pub const LLAMA_7B: ModelConfig = ModelConfig {
    name: "llama-7b",
    vocab: 32000,
    d_model: 4096,
    n_layers: 32,
    n_heads: 32,
    d_ff: 11008,
    max_seq: 2048,
    rope_base: 10000.0,
};

pub const LLAMA_13B: ModelConfig = ModelConfig {
    name: "llama-13b",
    vocab: 32000,
    d_model: 5120,
    n_layers: 40,
    n_heads: 40,
    d_ff: 13824,
    max_seq: 2048,
    rope_base: 10000.0,
};

pub const LLAMA_30B: ModelConfig = ModelConfig {
    name: "llama-30b",
    vocab: 32000,
    d_model: 6656,
    n_layers: 60,
    n_heads: 52,
    d_ff: 17920,
    max_seq: 2048,
    rope_base: 10000.0,
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_matches_python() {
        assert_eq!(TINY.param_count(), 3_475_712); // compile/model.py TINY
        assert_eq!(TINY.head_dim(), 32);
    }

    #[test]
    fn llama7b_params_about_7b() {
        let p = LLAMA_7B.param_count() as f64;
        assert!(p > 6.2e9 && p < 7.5e9, "{p}");
    }

    #[test]
    fn memory_model_orders() {
        // fp16 weights of 7B ≈ 13.5 GB (paper Table 12: 13.47 GB total)
        let fp16 = LLAMA_7B.weight_bytes(16.0) / 1e9;
        assert!(fp16 > 12.0 && fp16 < 14.5, "{fp16}");
        // w2 packed ≈ 1/8 of that for the blocks
        let w2 = LLAMA_7B.weight_bytes(2.0);
        assert!(w2 < LLAMA_7B.weight_bytes(16.0) / 6.0);
    }
}
