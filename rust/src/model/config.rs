//! Model configurations: the tiny trained model (served end-to-end) and
//! the real LLaMA-family dimensions (used *analytically* and for
//! real-shape kernel benches — Tables 12/13/14 run GEMMs at these shapes).
//!
//! Since PR 10 a config is no longer implicitly LLaMA-shaped: `n_kv_heads`
//! decouples the K/V projection width from `d_model` (GQA/MQA), and
//! [`ArchVariant`] names the norm / activation / embedding-tying choices
//! that distinguish model families. The registry of known architectures
//! lives in [`crate::model::zoo`].

/// Normalisation used before attention / FFN and at the final layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Norm {
    /// RMSNorm (LLaMA family): `x / rms(x) * g`, no mean subtraction, no bias.
    RmsNorm,
    /// Bias-free LayerNorm (GPT-NeoX-likes): `(x - mean) / std * g`.
    LayerNorm,
}

/// Gate activation of the GLU feed-forward (`down(act(gate(x)) * up(x))`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Activation {
    /// SwiGLU gate: `x * sigmoid(x)`.
    SiLu,
    /// GeGLU gate: tanh-approximated GELU.
    Gelu,
}

/// The architecture knobs that vary across model families but do not
/// change tensor *names* — every variant keeps the seven-projection
/// block layout (`LINEAR_NAMES`), so calibration, precision search, and
/// the `.abqw` grammar apply uniformly.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ArchVariant {
    pub norm: Norm,
    pub act: Activation,
    /// Tied embeddings: the LM head reuses `tok_emb` (no separate `head`
    /// tensor in the pack; `weight_bytes`/`param_count` count it once).
    pub tied_embeddings: bool,
}

impl ArchVariant {
    /// LLaMA-family defaults: RMSNorm + SwiGLU + untied head.
    pub const LLAMA: ArchVariant = ArchVariant {
        norm: Norm::RmsNorm,
        act: Activation::SiLu,
        tied_embeddings: false,
    };
}

/// Architecture description. `Copy` on purpose: configs are tiny and
/// passed by value throughout the engine.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: &'static str,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    /// Number of K/V heads. `== n_heads` is classic MHA, `1` is MQA,
    /// anything in between is GQA: query head `h` attends to KV head
    /// `h / (n_heads / n_kv_heads)`.
    pub n_kv_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub rope_base: f32,
    pub arch: ArchVariant,
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Width of one K (or V) row: `n_kv_heads * head_dim`. Equals
    /// `d_model` for MHA; smaller by the group factor under GQA — this is
    /// the number that sizes KV caches, pool blocks, and `wk`/`wv`.
    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim()
    }

    /// Query heads per KV head (`1` for MHA).
    pub fn group_size(&self) -> usize {
        self.n_heads / self.n_kv_heads
    }

    /// Structural invariants every config must satisfy before it reaches
    /// the engine. Zoo entries and manifest loads both pass through this.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.n_heads > 0 && self.d_model % self.n_heads == 0,
            "{}: d_model {} not divisible by n_heads {}", self.name, self.d_model, self.n_heads);
        anyhow::ensure!(self.n_kv_heads > 0 && self.n_kv_heads <= self.n_heads,
            "{}: n_kv_heads {} out of range (1..={})", self.name, self.n_kv_heads, self.n_heads);
        anyhow::ensure!(self.n_heads % self.n_kv_heads == 0,
            "{}: n_heads {} not divisible by n_kv_heads {} (head groups must be uniform)",
            self.name, self.n_heads, self.n_kv_heads);
        anyhow::ensure!(self.head_dim() % 2 == 0,
            "{}: head_dim {} must be even for RoPE", self.name, self.head_dim());
        Ok(())
    }

    /// Parameters in the transformer blocks + embeddings.
    pub fn param_count(&self) -> usize {
        let kd = self.kv_dim();
        let per_block = 2 * self.d_model * self.d_model   // wq, wo
            + 2 * kd * self.d_model                       // wk, wv (GQA-narrow)
            + 3 * self.d_model * self.d_ff
            + 2 * self.d_model;                           // two norm gains
        let head = if self.arch.tied_embeddings { 0 } else { self.d_model * self.vocab };
        self.vocab * self.d_model + self.n_layers * per_block + self.d_model + head
    }

    /// Per-layer GEMM shapes (N, K): q/k/v/o + gate/up/down — the shapes
    /// the paper's kernel tables sweep. Under GQA `wk`/`wv` are
    /// `kv_dim × d_model`.
    pub fn layer_shapes(&self) -> Vec<(&'static str, usize, usize)> {
        vec![
            ("wq", self.d_model, self.d_model),
            ("wk", self.kv_dim(), self.d_model),
            ("wv", self.kv_dim(), self.d_model),
            ("wo", self.d_model, self.d_model),
            ("gate", self.d_ff, self.d_model),
            ("up", self.d_ff, self.d_model),
            ("down", self.d_model, self.d_ff),
        ]
    }

    /// Weight bytes at `bits_per_weight` (planes for ABQ), for the Table 12
    /// memory model. Embedding + head stay fp16 as in the paper's engine;
    /// a tied head is counted once.
    pub fn weight_bytes(&self, block_bits: f64) -> f64 {
        let per_block: usize = self.layer_shapes().iter().map(|(_, n, k)| n * k).sum();
        let block_bytes = self.n_layers as f64 * per_block as f64 * block_bits / 8.0;
        let embed_params = if self.arch.tied_embeddings {
            self.vocab * self.d_model + self.d_model
        } else {
            2 * self.vocab * self.d_model + self.d_model
        };
        block_bytes + embed_params as f64 * 2.0
    }

    /// KV cache bytes for one sequence of `seq` tokens (fp16 cache).
    /// Rows are `kv_dim` wide, so GQA divides this by the group factor —
    /// which is exactly the admission-capacity multiplier the paged pool
    /// realises on top of KV quantization.
    pub fn kv_bytes(&self, seq: usize) -> f64 {
        (2 * self.n_layers * seq * self.kv_dim()) as f64 * 2.0
    }

    /// Parse the `model` block of an artifacts `manifest.json` (shared by
    /// the native and PJRT loaders in `engine/`). Architecture fields
    /// beyond the LLaMA defaults are optional so old manifests still load.
    pub fn from_manifest(j: &crate::util::json::Json) -> anyhow::Result<Self> {
        use anyhow::Context;
        let need = |field: &'static str| {
            j.at(&["model", field]).and_then(|v| v.as_usize()).context(field)
        };
        // Checkpoint name travels in the manifest; `&'static str` keeps
        // ModelConfig `Copy`, so leak the (one, small) string per load.
        let name: &'static str = match j.at(&["model", "name"]).and_then(|v| v.as_str()) {
            Some(s) => Box::leak(s.to_string().into_boxed_str()),
            None => "tiny-llama", // legacy manifests predate the field
        };
        let n_heads = need("n_heads")?;
        let n_kv_heads = match j.at(&["model", "n_kv_heads"]) {
            Some(v) => v.as_usize().context("n_kv_heads")?,
            None => n_heads, // MHA default
        };
        let norm = match j.at(&["model", "norm"]).and_then(|v| v.as_str()) {
            None | Some("rmsnorm") => Norm::RmsNorm,
            Some("layernorm") => Norm::LayerNorm,
            Some(other) => anyhow::bail!("unknown norm {other:?} in manifest"),
        };
        let act = match j.at(&["model", "act"]).and_then(|v| v.as_str()) {
            None | Some("silu") => Activation::SiLu,
            Some("gelu") => Activation::Gelu,
            Some(other) => anyhow::bail!("unknown act {other:?} in manifest"),
        };
        let tied = j
            .at(&["model", "tied_embeddings"])
            .and_then(|v| v.as_bool())
            .unwrap_or(false);
        let cfg = ModelConfig {
            name,
            vocab: need("vocab")?,
            d_model: need("d_model")?,
            n_layers: need("n_layers")?,
            n_heads,
            n_kv_heads,
            d_ff: need("d_ff")?,
            max_seq: need("max_seq")?,
            rope_base: j
                .at(&["model", "rope_base"])
                .and_then(|v| v.as_f64())
                .context("rope_base")? as f32,
            arch: ArchVariant { norm, act, tied_embeddings: tied },
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

/// The tiny model trained by `python/compile/train_tiny.py` (must match
/// `compile/model.py::TINY` and the manifest).
pub const TINY: ModelConfig = ModelConfig {
    name: "tiny-llama",
    vocab: 512,
    d_model: 256,
    n_layers: 4,
    n_heads: 8,
    n_kv_heads: 8,
    d_ff: 704,
    max_seq: 256,
    rope_base: 10000.0,
    arch: ArchVariant::LLAMA,
};

/// Real LLaMA dims (analytic / bench shapes only — no checkpoints here).
pub const LLAMA_7B: ModelConfig = ModelConfig {
    name: "llama-7b",
    vocab: 32000,
    d_model: 4096,
    n_layers: 32,
    n_heads: 32,
    n_kv_heads: 32,
    d_ff: 11008,
    max_seq: 2048,
    rope_base: 10000.0,
    arch: ArchVariant::LLAMA,
};

pub const LLAMA_13B: ModelConfig = ModelConfig {
    name: "llama-13b",
    vocab: 32000,
    d_model: 5120,
    n_layers: 40,
    n_heads: 40,
    n_kv_heads: 40,
    d_ff: 13824,
    max_seq: 2048,
    rope_base: 10000.0,
    arch: ArchVariant::LLAMA,
};

pub const LLAMA_30B: ModelConfig = ModelConfig {
    name: "llama-30b",
    vocab: 32000,
    d_model: 6656,
    n_layers: 60,
    n_heads: 52,
    n_kv_heads: 52,
    d_ff: 17920,
    max_seq: 2048,
    rope_base: 10000.0,
    arch: ArchVariant::LLAMA,
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_matches_python() {
        assert_eq!(TINY.param_count(), 3_475_712); // compile/model.py TINY
        assert_eq!(TINY.head_dim(), 32);
        assert_eq!(TINY.kv_dim(), TINY.d_model); // MHA: no narrowing
        assert_eq!(TINY.group_size(), 1);
        TINY.validate().unwrap();
    }

    #[test]
    fn llama7b_params_about_7b() {
        let p = LLAMA_7B.param_count() as f64;
        assert!(p > 6.2e9 && p < 7.5e9, "{p}");
    }

    #[test]
    fn memory_model_orders() {
        // fp16 weights of 7B ≈ 13.5 GB (paper Table 12: 13.47 GB total)
        let fp16 = LLAMA_7B.weight_bytes(16.0) / 1e9;
        assert!(fp16 > 12.0 && fp16 < 14.5, "{fp16}");
        // w2 packed ≈ 1/8 of that for the blocks
        let w2 = LLAMA_7B.weight_bytes(2.0);
        assert!(w2 < LLAMA_7B.weight_bytes(16.0) / 6.0);
    }

    #[test]
    fn memory_model_pins_llama7b_and_scales_with_gqa() {
        // Satellite 3 regression: MHA numbers must be *unchanged* by the
        // kv_dim rewrite. 2 (K+V) * 32 layers * 2048 * 4096 * 2 bytes.
        assert_eq!(LLAMA_7B.kv_bytes(2048) as u64, 1_073_741_824);
        // And a GQA sibling divides KV exactly by the group factor while
        // only shrinking wk/wv in the weight model.
        let gqa = ModelConfig { name: "llama-7b-gqa8", n_kv_heads: 8, ..LLAMA_7B };
        gqa.validate().unwrap();
        assert_eq!(gqa.group_size(), 4);
        assert_eq!(gqa.kv_bytes(2048) * 4.0, LLAMA_7B.kv_bytes(2048));
        let shrink = LLAMA_7B.weight_bytes(16.0) - gqa.weight_bytes(16.0);
        let expect = (2 * (LLAMA_7B.d_model - gqa.kv_dim()) * LLAMA_7B.d_model
            * LLAMA_7B.n_layers) as f64 * 2.0;
        assert!((shrink - expect).abs() < 1.0, "{shrink} vs {expect}");
    }

    #[test]
    fn tied_embeddings_counted_once() {
        let tied = ModelConfig {
            arch: ArchVariant { tied_embeddings: true, ..ArchVariant::LLAMA },
            ..TINY
        };
        assert_eq!(TINY.param_count() - tied.param_count(), TINY.d_model * TINY.vocab);
        let diff = TINY.weight_bytes(16.0) - tied.weight_bytes(16.0);
        assert_eq!(diff as u64, (TINY.vocab * TINY.d_model * 2) as u64);
    }

    #[test]
    fn manifest_name_round_trip() {
        // Satellite 1 regression: the name must come from the manifest,
        // not the old hardcoded "tiny-llama".
        let man = r#"{"model": {"name": "tiny-gqa", "vocab": 512, "d_model": 256,
            "n_layers": 4, "n_heads": 8, "n_kv_heads": 2, "d_ff": 704,
            "max_seq": 256, "rope_base": 10000.0, "norm": "rmsnorm",
            "act": "silu", "tied_embeddings": false}}"#;
        let j = crate::util::json::Json::parse(man).unwrap();
        let cfg = ModelConfig::from_manifest(&j).unwrap();
        assert_eq!(cfg.name, "tiny-gqa");
        assert_eq!(cfg.n_kv_heads, 2);
        assert_eq!(cfg.kv_dim(), 64);
        assert_eq!(cfg.arch, ArchVariant::LLAMA);
    }

    #[test]
    fn manifest_legacy_defaults() {
        // Old manifests (no name / n_kv_heads / variant fields) must still
        // load as the MHA LLaMA shape they were written for.
        let man = r#"{"model": {"vocab": 512, "d_model": 256, "n_layers": 4,
            "n_heads": 8, "d_ff": 704, "max_seq": 256, "rope_base": 10000.0}}"#;
        let j = crate::util::json::Json::parse(man).unwrap();
        let cfg = ModelConfig::from_manifest(&j).unwrap();
        assert_eq!(cfg.name, "tiny-llama");
        assert_eq!(cfg, TINY);
    }

    #[test]
    fn manifest_rejects_bad_geometry() {
        let man = r#"{"model": {"vocab": 512, "d_model": 256, "n_layers": 4,
            "n_heads": 8, "n_kv_heads": 3, "d_ff": 704, "max_seq": 256,
            "rope_base": 10000.0}}"#;
        let j = crate::util::json::Json::parse(man).unwrap();
        let err = ModelConfig::from_manifest(&j).unwrap_err().to_string();
        assert!(err.contains("not divisible by n_kv_heads"), "{err}");
    }
}
