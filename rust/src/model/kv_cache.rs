//! Per-sequence KV cache. The coordinator owns a pool of these (one per
//! active request); the transformer fills them at prefill and extends them
//! one position per decode step.

use super::config::ModelConfig;

/// Contiguous K/V storage for one sequence: `[layer][pos][d_model]`.
#[derive(Clone, Debug)]
pub struct KvCache {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub pos: usize,
    pub n_layers: usize,
    pub max_seq: usize,
    pub d_model: usize,
}

impl KvCache {
    pub fn new(cfg: &ModelConfig) -> Self {
        let n = cfg.n_layers * cfg.max_seq * cfg.d_model;
        KvCache {
            k: vec![0.0; n],
            v: vec![0.0; n],
            pos: 0,
            n_layers: cfg.n_layers,
            max_seq: cfg.max_seq,
            d_model: cfg.d_model,
        }
    }

    #[inline]
    pub fn offset(&self, layer: usize, pos: usize) -> usize {
        (layer * self.max_seq + pos) * self.d_model
    }

    /// Write one position's K/V row for a layer.
    pub fn write(&mut self, layer: usize, pos: usize, k_row: &[f32], v_row: &[f32]) {
        debug_assert!(pos < self.max_seq, "kv overflow");
        let off = self.offset(layer, pos);
        self.k[off..off + self.d_model].copy_from_slice(k_row);
        self.v[off..off + self.d_model].copy_from_slice(v_row);
    }

    pub fn k_row(&self, layer: usize, pos: usize) -> &[f32] {
        let off = self.offset(layer, pos);
        &self.k[off..off + self.d_model]
    }

    pub fn v_row(&self, layer: usize, pos: usize) -> &[f32] {
        let off = self.offset(layer, pos);
        &self.v[off..off + self.d_model]
    }

    pub fn reset(&mut self) {
        self.pos = 0;
    }

    pub fn bytes(&self) -> usize {
        (self.k.len() + self.v.len()) * 4
    }

    pub fn remaining(&self) -> usize {
        self.max_seq - self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::TINY;

    #[test]
    fn write_read_roundtrip() {
        let mut c = KvCache::new(&TINY);
        let k: Vec<f32> = (0..TINY.d_model).map(|i| i as f32).collect();
        let v: Vec<f32> = (0..TINY.d_model).map(|i| -(i as f32)).collect();
        c.write(2, 5, &k, &v);
        assert_eq!(c.k_row(2, 5), &k[..]);
        assert_eq!(c.v_row(2, 5), &v[..]);
        assert_eq!(c.k_row(2, 4), vec![0.0; TINY.d_model].as_slice());
    }

    #[test]
    fn capacity_accounting() {
        let mut c = KvCache::new(&TINY);
        assert_eq!(c.remaining(), TINY.max_seq);
        c.pos = 10;
        assert_eq!(c.remaining(), TINY.max_seq - 10);
    }
}
