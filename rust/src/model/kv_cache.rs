//! Sequence-level KV storage abstraction. The transformer is generic over
//! [`KvStore`]: the engine serves through the pool-leased, optionally
//! quantized [`super::kv_pool::PagedKvCache`], while the dense [`KvCache`]
//! here remains the unpaged fp32 reference implementation — tests assert
//! the paged `bits: 32` path is bit-identical to it
//! (`rust/tests/prop_kv.rs`).

use anyhow::{bail, Result};

use super::config::ModelConfig;

/// What the transformer needs from KV storage. Writes happen strictly in
/// position order per layer; reads go through a gather (dequant-into-
/// scratch for quantized pages, plain copy for fp32) so the attention
/// inner loops always run over contiguous rows.
///
/// Implementations may share physical storage between stores (the paged
/// store leases refcounted blocks, shared by `fork` and by prefix-cache
/// attach). The contract is copy-on-write: a write through one store is
/// never observable through another, and `gather_*` results depend only
/// on what was written through *this* store's positions — sharing is an
/// invisible optimization (`docs/SERVING.md` §prefix cache).
pub trait KvStore {
    /// Tokens stored so far (positions `[0, pos)` are valid).
    fn pos(&self) -> usize;

    /// Advance/rewind the valid-position watermark.
    fn set_pos(&mut self, pos: usize);

    /// Positions left before sequence capacity is exhausted.
    fn remaining(&self) -> usize;

    /// Ensure storage for `additional` more positions (paged stores lease
    /// blocks here; fails on pool exhaustion or `max_seq` overflow).
    fn reserve(&mut self, additional: usize) -> Result<()>;

    /// Write one position's K/V row for a layer (storage must have been
    /// reserved).
    fn write_row(&mut self, layer: usize, pos: usize, k_row: &[f32], v_row: &[f32]);

    /// Materialize K rows `[0, upto)` of `layer` into `out` `[upto, kv_dim]`.
    fn gather_k(&self, layer: usize, upto: usize, out: &mut [f32]);

    /// Materialize V rows `[0, upto)` of `layer` into `out` `[upto, kv_dim]`.
    fn gather_v(&self, layer: usize, upto: usize, out: &mut [f32]);

    /// Open a speculative window at the current position: capture whatever
    /// mutable tail state a later [`KvStore::truncate`] back to this
    /// position must restore byte-exactly. Dense fp32 stores need nothing
    /// (rows past the watermark are never gathered and are overwritten in
    /// position order), so the default is a no-op; quantized paged stores
    /// snapshot the partially filled tail block, whose shared per-head
    /// scales can be grown — and its committed rows requantized — by
    /// speculative rows that are later rejected (`docs/SPECULATIVE.md`).
    fn begin_speculation(&mut self) {}

    /// Rewind the valid prefix to `pos` (≤ the current position),
    /// discarding everything written past it: storage beyond `pos` is
    /// released or left to be overwritten, and state captured by
    /// [`KvStore::begin_speculation`] is restored, so the store is
    /// byte-identical to one that never saw the rejected rows. The
    /// default rewinds the watermark, which is exact for dense stores.
    fn truncate(&mut self, pos: usize) {
        debug_assert!(pos <= self.pos());
        self.set_pos(pos);
    }
}

/// Contiguous K/V storage for one sequence: `[layer][pos][kv_dim]`.
/// Rows are `kv_dim = n_kv_heads * head_dim` wide — equal to `d_model`
/// for MHA, narrower by the group factor under GQA.
#[derive(Clone, Debug)]
pub struct KvCache {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub pos: usize,
    pub n_layers: usize,
    pub max_seq: usize,
    pub kv_dim: usize,
}

impl KvCache {
    pub fn new(cfg: &ModelConfig) -> Self {
        let n = cfg.n_layers * cfg.max_seq * cfg.kv_dim();
        KvCache {
            k: vec![0.0; n],
            v: vec![0.0; n],
            pos: 0,
            n_layers: cfg.n_layers,
            max_seq: cfg.max_seq,
            kv_dim: cfg.kv_dim(),
        }
    }

    #[inline]
    pub fn offset(&self, layer: usize, pos: usize) -> usize {
        (layer * self.max_seq + pos) * self.kv_dim
    }

    /// Write one position's K/V row for a layer.
    pub fn write(&mut self, layer: usize, pos: usize, k_row: &[f32], v_row: &[f32]) {
        debug_assert!(pos < self.max_seq, "kv overflow");
        let off = self.offset(layer, pos);
        self.k[off..off + self.kv_dim].copy_from_slice(k_row);
        self.v[off..off + self.kv_dim].copy_from_slice(v_row);
    }

    pub fn k_row(&self, layer: usize, pos: usize) -> &[f32] {
        let off = self.offset(layer, pos);
        &self.k[off..off + self.kv_dim]
    }

    pub fn v_row(&self, layer: usize, pos: usize) -> &[f32] {
        let off = self.offset(layer, pos);
        &self.v[off..off + self.kv_dim]
    }

    pub fn reset(&mut self) {
        self.pos = 0;
    }

    pub fn bytes(&self) -> usize {
        (self.k.len() + self.v.len()) * 4
    }

    pub fn remaining(&self) -> usize {
        self.max_seq - self.pos
    }
}

impl KvStore for KvCache {
    fn pos(&self) -> usize {
        self.pos
    }

    fn set_pos(&mut self, pos: usize) {
        debug_assert!(pos <= self.max_seq);
        self.pos = pos;
    }

    fn remaining(&self) -> usize {
        self.max_seq - self.pos
    }

    fn reserve(&mut self, additional: usize) -> Result<()> {
        if self.pos + additional > self.max_seq {
            bail!(
                "sequence would exceed KV capacity ({} + {additional} > {})",
                self.pos,
                self.max_seq
            );
        }
        Ok(())
    }

    fn write_row(&mut self, layer: usize, pos: usize, k_row: &[f32], v_row: &[f32]) {
        self.write(layer, pos, k_row, v_row);
    }

    fn gather_k(&self, layer: usize, upto: usize, out: &mut [f32]) {
        let base = layer * self.max_seq * self.kv_dim;
        out[..upto * self.kv_dim].copy_from_slice(&self.k[base..base + upto * self.kv_dim]);
    }

    fn gather_v(&self, layer: usize, upto: usize, out: &mut [f32]) {
        let base = layer * self.max_seq * self.kv_dim;
        out[..upto * self.kv_dim].copy_from_slice(&self.v[base..base + upto * self.kv_dim]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::TINY;

    #[test]
    fn write_read_roundtrip() {
        let mut c = KvCache::new(&TINY);
        let k: Vec<f32> = (0..TINY.kv_dim()).map(|i| i as f32).collect();
        let v: Vec<f32> = (0..TINY.kv_dim()).map(|i| -(i as f32)).collect();
        c.write(2, 5, &k, &v);
        assert_eq!(c.k_row(2, 5), &k[..]);
        assert_eq!(c.v_row(2, 5), &v[..]);
        assert_eq!(c.k_row(2, 4), vec![0.0; TINY.kv_dim()].as_slice());
    }

    #[test]
    fn truncate_rewinds_and_rewrites_cleanly() {
        // dense stores: truncate is a pure watermark rewind — rows past it
        // are never gathered and the next writes overwrite them in order
        let mut c = KvCache::new(&TINY);
        let a: Vec<f32> = (0..TINY.kv_dim()).map(|i| i as f32).collect();
        let b: Vec<f32> = (0..TINY.kv_dim()).map(|i| -(i as f32)).collect();
        c.write(0, 0, &a, &a);
        c.set_pos(1);
        c.begin_speculation();
        c.write(0, 1, &b, &b);
        c.set_pos(2);
        c.truncate(1);
        assert_eq!(KvStore::pos(&c), 1);
        let mut out = vec![0f32; TINY.kv_dim()];
        c.gather_k(0, 1, &mut out);
        assert_eq!(out, a);
        // rewrite position 1 with different data, as a real decode would
        c.write(0, 1, &a, &b);
        c.set_pos(2);
        assert_eq!(c.k_row(0, 1), &a[..]);
    }

    #[test]
    fn capacity_accounting() {
        let mut c = KvCache::new(&TINY);
        assert_eq!(c.remaining(), TINY.max_seq);
        c.pos = 10;
        assert_eq!(c.remaining(), TINY.max_seq - 10);
    }
}
