//! Sequence-level KV storage abstraction. The transformer is generic over
//! [`KvStore`]: the engine serves through the pool-leased, optionally
//! quantized [`super::kv_pool::PagedKvCache`], while the dense [`KvCache`]
//! here remains the unpaged fp32 reference implementation — tests assert
//! the paged `bits: 32` path is bit-identical to it
//! (`rust/tests/prop_kv.rs`).

use anyhow::{bail, Result};

use super::config::ModelConfig;

/// What the transformer needs from KV storage. Writes happen strictly in
/// position order per layer; reads go through a gather (dequant-into-
/// scratch for quantized pages, plain copy for fp32) so the attention
/// inner loops always run over contiguous rows.
pub trait KvStore {
    /// Tokens stored so far (positions `[0, pos)` are valid).
    fn pos(&self) -> usize;

    /// Advance/rewind the valid-position watermark.
    fn set_pos(&mut self, pos: usize);

    /// Positions left before sequence capacity is exhausted.
    fn remaining(&self) -> usize;

    /// Ensure storage for `additional` more positions (paged stores lease
    /// blocks here; fails on pool exhaustion or `max_seq` overflow).
    fn reserve(&mut self, additional: usize) -> Result<()>;

    /// Write one position's K/V row for a layer (storage must have been
    /// reserved).
    fn write_row(&mut self, layer: usize, pos: usize, k_row: &[f32], v_row: &[f32]);

    /// Materialize K rows `[0, upto)` of `layer` into `out` `[upto, d_model]`.
    fn gather_k(&self, layer: usize, upto: usize, out: &mut [f32]);

    /// Materialize V rows `[0, upto)` of `layer` into `out` `[upto, d_model]`.
    fn gather_v(&self, layer: usize, upto: usize, out: &mut [f32]);
}

/// Contiguous K/V storage for one sequence: `[layer][pos][d_model]`.
#[derive(Clone, Debug)]
pub struct KvCache {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub pos: usize,
    pub n_layers: usize,
    pub max_seq: usize,
    pub d_model: usize,
}

impl KvCache {
    pub fn new(cfg: &ModelConfig) -> Self {
        let n = cfg.n_layers * cfg.max_seq * cfg.d_model;
        KvCache {
            k: vec![0.0; n],
            v: vec![0.0; n],
            pos: 0,
            n_layers: cfg.n_layers,
            max_seq: cfg.max_seq,
            d_model: cfg.d_model,
        }
    }

    #[inline]
    pub fn offset(&self, layer: usize, pos: usize) -> usize {
        (layer * self.max_seq + pos) * self.d_model
    }

    /// Write one position's K/V row for a layer.
    pub fn write(&mut self, layer: usize, pos: usize, k_row: &[f32], v_row: &[f32]) {
        debug_assert!(pos < self.max_seq, "kv overflow");
        let off = self.offset(layer, pos);
        self.k[off..off + self.d_model].copy_from_slice(k_row);
        self.v[off..off + self.d_model].copy_from_slice(v_row);
    }

    pub fn k_row(&self, layer: usize, pos: usize) -> &[f32] {
        let off = self.offset(layer, pos);
        &self.k[off..off + self.d_model]
    }

    pub fn v_row(&self, layer: usize, pos: usize) -> &[f32] {
        let off = self.offset(layer, pos);
        &self.v[off..off + self.d_model]
    }

    pub fn reset(&mut self) {
        self.pos = 0;
    }

    pub fn bytes(&self) -> usize {
        (self.k.len() + self.v.len()) * 4
    }

    pub fn remaining(&self) -> usize {
        self.max_seq - self.pos
    }
}

impl KvStore for KvCache {
    fn pos(&self) -> usize {
        self.pos
    }

    fn set_pos(&mut self, pos: usize) {
        debug_assert!(pos <= self.max_seq);
        self.pos = pos;
    }

    fn remaining(&self) -> usize {
        self.max_seq - self.pos
    }

    fn reserve(&mut self, additional: usize) -> Result<()> {
        if self.pos + additional > self.max_seq {
            bail!(
                "sequence would exceed KV capacity ({} + {additional} > {})",
                self.pos,
                self.max_seq
            );
        }
        Ok(())
    }

    fn write_row(&mut self, layer: usize, pos: usize, k_row: &[f32], v_row: &[f32]) {
        self.write(layer, pos, k_row, v_row);
    }

    fn gather_k(&self, layer: usize, upto: usize, out: &mut [f32]) {
        let base = layer * self.max_seq * self.d_model;
        out[..upto * self.d_model].copy_from_slice(&self.k[base..base + upto * self.d_model]);
    }

    fn gather_v(&self, layer: usize, upto: usize, out: &mut [f32]) {
        let base = layer * self.max_seq * self.d_model;
        out[..upto * self.d_model].copy_from_slice(&self.v[base..base + upto * self.d_model]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::TINY;

    #[test]
    fn write_read_roundtrip() {
        let mut c = KvCache::new(&TINY);
        let k: Vec<f32> = (0..TINY.d_model).map(|i| i as f32).collect();
        let v: Vec<f32> = (0..TINY.d_model).map(|i| -(i as f32)).collect();
        c.write(2, 5, &k, &v);
        assert_eq!(c.k_row(2, 5), &k[..]);
        assert_eq!(c.v_row(2, 5), &v[..]);
        assert_eq!(c.k_row(2, 4), vec![0.0; TINY.d_model].as_slice());
    }

    #[test]
    fn capacity_accounting() {
        let mut c = KvCache::new(&TINY);
        assert_eq!(c.remaining(), TINY.max_seq);
        c.pos = 10;
        assert_eq!(c.remaining(), TINY.max_seq - 10);
    }
}
