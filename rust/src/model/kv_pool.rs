//! Paged, arbitrary-bit quantized KV cache (the serving-side half of the
//! paper's memory claim): a shared **block pool** from which sequences
//! lease fixed-size blocks on demand — vLLM-style — instead of reserving a
//! dense `n_layers × max_seq × d_model` fp32 slab per session.
//!
//! Two levers convert into admission capacity:
//!
//! * **paging** — a sequence only holds `ceil(pos / block_size)` blocks,
//!   so short sequences stop wasting their whole `max_seq` reservation;
//! * **bit width** — each block stores K/V at [`KvCacheConfig::bits`]
//!   (fp32 passthrough, int8, or nibble-packed int4) with one symmetric
//!   scale per `(layer, head)` per block, reusing the `quant` machinery
//!   ([`QParams`]/[`quantize_value`]/[`dequantize_value`]). int8 KV is
//!   4× the blocks — and therefore ~4× the concurrently active
//!   sequences — at a fixed byte budget (asserted in
//!   `rust/tests/prop_coordinator.rs`).
//!
//! Scales grow monotonically: a block's `(layer, head)` scale is set by
//! the first row written and, when a later row's absmax exceeds it, the
//! already-written rows of that head slab are requantized in code space
//! before the new scale takes effect. Rows are only ever appended in
//! position order, so "already written" is exactly the in-block index.
//!
//! The transformer reads pages through [`KvStore::gather_k`] /
//! [`KvStore::gather_v`] — a dequant-into-scratch view that materializes
//! the `[0, pos)` prefix of one layer into a caller-owned arena buffer, so
//! the steady-state decode loop stays allocation-free (`docs/PERF.md`,
//! `docs/SERVING.md`).

use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::quant::{dequantize_value, quantize_value, QParams, QuantSpec};

use super::config::ModelConfig;
use super::kv_cache::KvStore;

/// KV storage configuration: bit width per element + positions per block.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KvCacheConfig {
    /// 32 (fp32 passthrough), 8 (int8) or 4 (nibble-packed int4)
    pub bits: u8,
    /// positions per leased block
    pub block_size: usize,
}

impl KvCacheConfig {
    pub const FP32: KvCacheConfig = KvCacheConfig { bits: 32, block_size: 16 };

    pub const fn new(bits: u8, block_size: usize) -> Self {
        KvCacheConfig { bits, block_size }
    }

    pub fn validate(&self) -> Result<()> {
        if !matches!(self.bits, 4 | 8 | 32) {
            bail!("KvCacheConfig.bits must be 4, 8 or 32 (got {})", self.bits);
        }
        if self.block_size == 0 {
            bail!("KvCacheConfig.block_size must be > 0");
        }
        Ok(())
    }

    /// KV bytes one *position* costs across all layers (codes + the
    /// amortized per-block scales) — the pool-sizing unit in
    /// `docs/SERVING.md`.
    pub fn bytes_per_position(&self, m: &ModelConfig) -> f64 {
        let layout = KvLayout::from(m, self);
        layout.block_bytes() as f64 / self.block_size as f64
    }
}

impl Default for KvCacheConfig {
    fn default() -> Self {
        KvCacheConfig::FP32
    }
}

/// Derived per-block geometry (internal).
#[derive(Clone, Copy, Debug)]
struct KvLayout {
    n_layers: usize,
    d_model: usize,
    n_heads: usize,
    head_dim: usize,
    block_size: usize,
    bits: u8,
}

impl KvLayout {
    fn from(m: &ModelConfig, kv: &KvCacheConfig) -> Self {
        KvLayout {
            n_layers: m.n_layers,
            d_model: m.d_model,
            n_heads: m.n_heads,
            head_dim: m.head_dim(),
            block_size: kv.block_size,
            bits: kv.bits,
        }
    }

    /// Packed code bytes of one K (or V) row.
    fn row_bytes(&self) -> usize {
        self.d_model * self.bits as usize / 8
    }

    /// Resident bytes of one block: K + V codes plus per-(layer, head)
    /// scales on each side (fp32 blocks carry no scales).
    fn block_bytes(&self) -> usize {
        if self.bits == 32 {
            2 * self.n_layers * self.block_size * self.d_model * 4
        } else {
            2 * self.n_layers * self.block_size * self.row_bytes()
                + 2 * self.n_layers * self.n_heads * 4
        }
    }

    /// Byte offset of row (`layer`, `idx`) inside a codes vec.
    fn row_base(&self, layer: usize, idx: usize) -> usize {
        (layer * self.block_size + idx) * self.row_bytes()
    }
}

#[inline]
fn get_code(codes: &[u8], bits: u8, row_base: usize, col: usize) -> u8 {
    if bits == 8 {
        codes[row_base + col]
    } else {
        let b = codes[row_base + col / 2];
        if col % 2 == 0 {
            b & 0x0F
        } else {
            b >> 4
        }
    }
}

#[inline]
fn set_code(codes: &mut [u8], bits: u8, row_base: usize, col: usize, q: u8) {
    if bits == 8 {
        codes[row_base + col] = q;
    } else {
        let b = &mut codes[row_base + col / 2];
        if col % 2 == 0 {
            *b = (*b & 0xF0) | (q & 0x0F);
        } else {
            *b = (*b & 0x0F) | (q << 4);
        }
    }
}

/// One leased block: `block_size` positions of K/V across all layers.
pub struct KvBlock {
    data: BlockData,
}

enum BlockData {
    /// passthrough, `[n_layers][block_size][d_model]` per side
    F32 { k: Vec<f32>, v: Vec<f32> },
    /// packed codes `[n_layers][block_size][row_bytes]` per side with
    /// symmetric per-(layer, head) scales `[n_layers][n_heads]`
    Quant { k: Vec<u8>, v: Vec<u8>, k_scale: Vec<f32>, v_scale: Vec<f32> },
}

impl KvBlock {
    fn new(l: &KvLayout) -> Self {
        let data = if l.bits == 32 {
            let n = l.n_layers * l.block_size * l.d_model;
            BlockData::F32 { k: vec![0.0; n], v: vec![0.0; n] }
        } else {
            let n = l.n_layers * l.block_size * l.row_bytes();
            let ns = l.n_layers * l.n_heads;
            BlockData::Quant {
                k: vec![0; n],
                v: vec![0; n],
                k_scale: vec![0.0; ns],
                v_scale: vec![0.0; ns],
            }
        };
        KvBlock { data }
    }

    fn copy_from(&mut self, other: &KvBlock) {
        match (&mut self.data, &other.data) {
            (BlockData::F32 { k, v }, BlockData::F32 { k: ok, v: ov }) => {
                k.copy_from_slice(ok);
                v.copy_from_slice(ov);
            }
            (
                BlockData::Quant { k, v, k_scale, v_scale },
                BlockData::Quant { k: ok, v: ov, k_scale: oks, v_scale: ovs },
            ) => {
                k.copy_from_slice(ok);
                v.copy_from_slice(ov);
                k_scale.copy_from_slice(oks);
                v_scale.copy_from_slice(ovs);
            }
            _ => unreachable!("pool never mixes block storage kinds"),
        }
    }

    /// Write one side's row at in-block index `idx`; `idx` is also the
    /// count of rows already valid in this (block, layer), which bounds
    /// the requantize-on-scale-growth sweep.
    fn write_side(
        l: &KvLayout,
        codes: &mut [u8],
        scales: &mut [f32],
        layer: usize,
        idx: usize,
        row: &[f32],
    ) {
        let spec = QuantSpec::new(l.bits);
        let zp = 1i32 << (l.bits - 1);
        let qmax_mag = (zp - 1) as f32;
        for h in 0..l.n_heads {
            let seg = &row[h * l.head_dim..(h + 1) * l.head_dim];
            let absmax = seg.iter().fold(0f32, |m, &x| m.max(x.abs()));
            let si = layer * l.n_heads + h;
            let needed = (absmax / qmax_mag).max(1e-8);
            let delta = if idx == 0 {
                scales[si] = needed;
                needed
            } else if needed > scales[si] {
                // scale grew: requantize the rows already in this head slab
                let old = QParams { delta: scales[si], zp };
                let new = QParams { delta: needed, zp };
                for r in 0..idx {
                    let base = l.row_base(layer, r);
                    for j in 0..l.head_dim {
                        let col = h * l.head_dim + j;
                        let c = get_code(codes, l.bits, base, col);
                        let rq = quantize_value(dequantize_value(c, old), new, &spec);
                        set_code(codes, l.bits, base, col, rq);
                    }
                }
                scales[si] = needed;
                needed
            } else {
                scales[si]
            };
            let p = QParams { delta, zp };
            let base = l.row_base(layer, idx);
            for (j, &x) in seg.iter().enumerate() {
                set_code(codes, l.bits, base, h * l.head_dim + j, quantize_value(x, p, &spec));
            }
        }
    }

    fn write_row(&mut self, l: &KvLayout, layer: usize, idx: usize, k_row: &[f32], v_row: &[f32]) {
        match &mut self.data {
            BlockData::F32 { k, v } => {
                let off = (layer * l.block_size + idx) * l.d_model;
                k[off..off + l.d_model].copy_from_slice(k_row);
                v[off..off + l.d_model].copy_from_slice(v_row);
            }
            BlockData::Quant { k, v, k_scale, v_scale } => {
                Self::write_side(l, k, k_scale, layer, idx, k_row);
                Self::write_side(l, v, v_scale, layer, idx, v_row);
            }
        }
    }

    fn gather_side(
        l: &KvLayout,
        codes: &[u8],
        scales: &[f32],
        layer: usize,
        rows: usize,
        out: &mut [f32],
    ) {
        let zp = 1i32 << (l.bits - 1);
        for r in 0..rows {
            let base = l.row_base(layer, r);
            let orow = &mut out[r * l.d_model..(r + 1) * l.d_model];
            for h in 0..l.n_heads {
                let p = QParams { delta: scales[layer * l.n_heads + h], zp };
                for j in 0..l.head_dim {
                    let col = h * l.head_dim + j;
                    orow[col] = dequantize_value(get_code(codes, l.bits, base, col), p);
                }
            }
        }
    }

    /// Dequantize the first `rows` K rows of `layer` into `out`
    /// `[rows, d_model]`.
    fn gather_k(&self, l: &KvLayout, layer: usize, rows: usize, out: &mut [f32]) {
        match &self.data {
            BlockData::F32 { k, .. } => {
                let off = layer * l.block_size * l.d_model;
                out[..rows * l.d_model].copy_from_slice(&k[off..off + rows * l.d_model]);
            }
            BlockData::Quant { k, k_scale, .. } => {
                Self::gather_side(l, k, k_scale, layer, rows, out)
            }
        }
    }

    fn gather_v(&self, l: &KvLayout, layer: usize, rows: usize, out: &mut [f32]) {
        match &self.data {
            BlockData::F32 { v, .. } => {
                let off = layer * l.block_size * l.d_model;
                out[..rows * l.d_model].copy_from_slice(&v[off..off + rows * l.d_model]);
            }
            BlockData::Quant { v, v_scale, .. } => {
                Self::gather_side(l, v, v_scale, layer, rows, out)
            }
        }
    }
}

/// Point-in-time pool occupancy (what the scheduler's block-aware
/// admission and the serving metrics consume).
#[derive(Clone, Copy, Debug)]
pub struct KvPoolStatus {
    pub total_blocks: usize,
    pub free_blocks: usize,
    pub block_size: usize,
    pub block_bytes: usize,
    pub bits: u8,
}

impl KvPoolStatus {
    pub fn used_blocks(&self) -> usize {
        self.total_blocks - self.free_blocks
    }

    /// Blocks needed to hold `positions` KV rows.
    pub fn blocks_for(&self, positions: usize) -> usize {
        positions.div_ceil(self.block_size)
    }
}

/// The shared block pool: a capacity budget plus a free list of recycled
/// block buffers. Handles are cheap clones of one `Arc`; sessions lease
/// blocks through [`PagedKvCache`] and return them on drop. The lock is
/// touched only at block granularity (once every `block_size` positions
/// per sequence), never per row.
#[derive(Clone)]
pub struct KvPool {
    inner: Arc<PoolShared>,
}

struct PoolShared {
    layout: KvLayout,
    max_seq: usize,
    max_blocks: usize,
    state: Mutex<PoolState>,
}

struct PoolState {
    free: Vec<KvBlock>,
    leased: usize,
}

/// Default pool budget when none is configured: enough blocks for this
/// many full-`max_seq` sequences (block buffers allocate lazily, so an
/// untouched budget costs nothing).
const DEFAULT_POOL_SEQS: usize = 64;

impl KvPool {
    /// `budget_bytes` caps the pool (rounded down to whole blocks, min 1);
    /// `None` defaults to [`DEFAULT_POOL_SEQS`] full sequences.
    pub fn new(m: &ModelConfig, kv: &KvCacheConfig, budget_bytes: Option<usize>) -> Result<Self> {
        kv.validate()?;
        if kv.bits == 4 && m.d_model % 2 != 0 {
            bail!("int4 KV pages need an even d_model (got {})", m.d_model);
        }
        let layout = KvLayout::from(m, kv);
        let blocks_per_seq = m.max_seq.div_ceil(kv.block_size);
        let max_blocks = match budget_bytes {
            Some(b) => (b / layout.block_bytes()).max(1),
            None => blocks_per_seq * DEFAULT_POOL_SEQS,
        };
        Ok(KvPool {
            inner: Arc::new(PoolShared {
                layout,
                max_seq: m.max_seq,
                max_blocks,
                state: Mutex::new(PoolState { free: Vec::new(), leased: 0 }),
            }),
        })
    }

    /// A fresh empty cache leasing from this pool.
    pub fn new_cache(&self) -> PagedKvCache {
        PagedKvCache {
            pool: self.clone(),
            blocks: Vec::new(),
            pos: 0,
            max_seq: self.inner.max_seq,
            snap_pos: None,
            snap_block: None,
            snap_spare: None,
        }
    }

    pub fn status(&self) -> KvPoolStatus {
        let st = self.inner.state.lock().unwrap();
        KvPoolStatus {
            total_blocks: self.inner.max_blocks,
            free_blocks: self.inner.max_blocks - st.leased,
            block_size: self.inner.layout.block_size,
            block_bytes: self.inner.layout.block_bytes(),
            bits: self.inner.layout.bits,
        }
    }

    pub fn block_bytes(&self) -> usize {
        self.inner.layout.block_bytes()
    }

    pub fn blocks_for(&self, positions: usize) -> usize {
        positions.div_ceil(self.inner.layout.block_size)
    }

    fn lease(&self) -> Result<KvBlock> {
        let mut st = self.inner.state.lock().unwrap();
        if st.leased >= self.inner.max_blocks {
            bail!(
                "KV pool exhausted: {}/{} blocks leased",
                st.leased,
                self.inner.max_blocks
            );
        }
        st.leased += 1;
        Ok(st.free.pop().unwrap_or_else(|| KvBlock::new(&self.inner.layout)))
    }

    fn release(&self, block: KvBlock) {
        let mut st = self.inner.state.lock().unwrap();
        debug_assert!(st.leased > 0, "release without lease");
        st.leased -= 1;
        st.free.push(block);
    }
}

/// Per-sequence view over pool-leased blocks: the block table plus the
/// write position. Positions `[0, pos)` are valid; the block covering
/// position `p` is `blocks[p / block_size]`, row `p % block_size`.
pub struct PagedKvCache {
    pool: KvPool,
    blocks: Vec<KvBlock>,
    pos: usize,
    max_seq: usize,
    /// position at which the open speculative window started, if any
    snap_pos: Option<usize>,
    /// copy of the then-partial tail block behind `snap_pos` (`None`
    /// when the window opened on a block boundary); swapped back in by
    /// `truncate` so rejected speculative rows cannot leave grown
    /// quantization scales behind
    snap_block: Option<KvBlock>,
    /// retained snapshot buffer so repeated windows allocate nothing —
    /// session-private scratch, never leased from (or released to) the
    /// pool, so pool accounting is untouched by speculation
    snap_spare: Option<KvBlock>,
}

impl PagedKvCache {
    pub fn pos(&self) -> usize {
        self.pos
    }

    pub fn remaining(&self) -> usize {
        self.max_seq - self.pos
    }

    pub fn leased_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// Resident bytes actually leased (the `kv_bytes` a session reports).
    pub fn bytes(&self) -> usize {
        self.blocks.len() * self.pool.block_bytes()
    }

    /// Deep copy for session forking: leases fresh blocks from the pool
    /// (fails when the pool cannot cover them). Any open speculative
    /// window stays with the original — the fork starts clean.
    pub fn try_clone(&self) -> Result<PagedKvCache> {
        let mut blocks = Vec::with_capacity(self.blocks.len());
        for b in &self.blocks {
            let mut nb = self.pool.lease()?;
            nb.copy_from(b);
            blocks.push(nb);
        }
        Ok(PagedKvCache {
            pool: self.pool.clone(),
            blocks,
            pos: self.pos,
            max_seq: self.max_seq,
            snap_pos: None,
            snap_block: None,
            snap_spare: None,
        })
    }
}

impl KvStore for PagedKvCache {
    fn pos(&self) -> usize {
        self.pos
    }

    fn set_pos(&mut self, pos: usize) {
        debug_assert!(pos <= self.blocks.len() * self.pool.inner.layout.block_size);
        self.pos = pos;
    }

    fn remaining(&self) -> usize {
        self.max_seq - self.pos
    }

    fn reserve(&mut self, additional: usize) -> Result<()> {
        if self.pos + additional > self.max_seq {
            bail!(
                "sequence would exceed KV capacity ({} + {additional} > {})",
                self.pos,
                self.max_seq
            );
        }
        let needed = self.pool.blocks_for(self.pos + additional);
        while self.blocks.len() < needed {
            self.blocks.push(self.pool.lease()?);
        }
        Ok(())
    }

    fn write_row(&mut self, layer: usize, pos: usize, k_row: &[f32], v_row: &[f32]) {
        let l = self.pool.inner.layout;
        let (b, idx) = (pos / l.block_size, pos % l.block_size);
        self.blocks[b].write_row(&l, layer, idx, k_row, v_row);
    }

    fn gather_k(&self, layer: usize, upto: usize, out: &mut [f32]) {
        let l = self.pool.inner.layout;
        let mut p = 0;
        for block in &self.blocks {
            if p >= upto {
                break;
            }
            let rows = (upto - p).min(l.block_size);
            block.gather_k(&l, layer, rows, &mut out[p * l.d_model..(p + rows) * l.d_model]);
            p += rows;
        }
    }

    fn gather_v(&self, layer: usize, upto: usize, out: &mut [f32]) {
        let l = self.pool.inner.layout;
        let mut p = 0;
        for block in &self.blocks {
            if p >= upto {
                break;
            }
            let rows = (upto - p).min(l.block_size);
            block.gather_v(&l, layer, rows, &mut out[p * l.d_model..(p + rows) * l.d_model]);
            p += rows;
        }
    }

    fn begin_speculation(&mut self) {
        let l = self.pool.inner.layout;
        // an abandoned earlier window (nothing was rolled back) recycles
        // its buffer instead of leaking it to the allocator
        if let Some(b) = self.snap_block.take() {
            self.snap_spare = Some(b);
        }
        self.snap_pos = Some(self.pos);
        self.snap_block = if self.pos % l.block_size != 0 {
            // speculative writes into the partial tail block can grow its
            // per-(layer, head) scales and requantize the committed rows;
            // keep a byte copy so `truncate` can undo that exactly
            let src = &self.blocks[self.pos / l.block_size];
            let mut buf = self.snap_spare.take().unwrap_or_else(|| KvBlock::new(&l));
            buf.copy_from(src);
            Some(buf)
        } else {
            None
        };
    }

    fn truncate(&mut self, pos: usize) {
        debug_assert!(pos <= self.pos, "truncate({pos}) beyond pos {}", self.pos);
        let l = self.pool.inner.layout;
        if let Some(sp) = self.snap_pos.take() {
            debug_assert_eq!(
                pos, sp,
                "paged truncate must return to the speculation snapshot position"
            );
            if let Some(buf) = self.snap_block.take() {
                // only restore when rewinding at/under the snapshot — a
                // truncate past it means the window was abandoned
                if pos <= sp {
                    self.blocks[sp / l.block_size].copy_from(&buf);
                }
                self.snap_spare = Some(buf);
            }
        }
        // release whole blocks past the new watermark back to the pool
        let keep = pos.div_ceil(l.block_size);
        while self.blocks.len() > keep {
            let b = self.blocks.pop().expect("len > keep");
            self.pool.release(b);
        }
        self.pos = pos;
    }
}

impl Drop for PagedKvCache {
    fn drop(&mut self) {
        for b in self.blocks.drain(..) {
            self.pool.release(b);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::TINY;

    fn kv(bits: u8, block_size: usize) -> KvCacheConfig {
        KvCacheConfig { bits, block_size }
    }

    fn row(seed: usize, d: usize, scale: f32) -> Vec<f32> {
        (0..d).map(|i| (((i * 31 + seed * 17) % 97) as f32 - 48.0) / 48.0 * scale).collect()
    }

    #[test]
    fn fp32_roundtrip_is_exact() {
        let pool = KvPool::new(&TINY, &kv(32, 8), None).unwrap();
        let mut c = pool.new_cache();
        c.reserve(20).unwrap();
        let d = TINY.d_model;
        for p in 0..20 {
            let (k, v) = (row(p, d, 1.0), row(p + 100, d, 2.0));
            c.write_row(2, p, &k, &v);
        }
        c.set_pos(20);
        let mut out = vec![0f32; 20 * d];
        c.gather_k(2, 20, &mut out);
        for p in 0..20 {
            assert_eq!(&out[p * d..(p + 1) * d], &row(p, d, 1.0)[..], "pos {p}");
        }
        c.gather_v(2, 20, &mut out);
        assert_eq!(&out[..d], &row(100, d, 2.0)[..]);
    }

    #[test]
    fn quantized_roundtrip_error_bounded() {
        for bits in [4u8, 8] {
            let pool = KvPool::new(&TINY, &kv(bits, 8), None).unwrap();
            let mut c = pool.new_cache();
            c.reserve(12).unwrap();
            let d = TINY.d_model;
            // decreasing magnitude: every per-head scale is fixed by row 0,
            // so the error bound is exactly one quantization step
            let base = row(0, d, 1.5);
            let scaled = |p: usize| -> Vec<f32> {
                base.iter().map(|x| x * (1.0 - p as f32 * 0.05)).collect()
            };
            for p in 0..12 {
                let r = scaled(p);
                c.write_row(0, p, &r, &r);
            }
            c.set_pos(12);
            let mut out = vec![0f32; 12 * d];
            c.gather_k(0, 12, &mut out);
            let zp = 1i32 << (bits - 1);
            let hd = TINY.head_dim();
            for p in 0..12 {
                let want = scaled(p);
                for h in 0..TINY.n_heads {
                    let absmax =
                        base[h * hd..(h + 1) * hd].iter().fold(0f32, |m, &x| m.max(x.abs()));
                    let delta = absmax / (zp - 1) as f32;
                    for j in 0..hd {
                        let i = h * hd + j;
                        let err = (out[p * d + i] - want[i]).abs();
                        assert!(err <= delta * 0.51 + 1e-6, "bits {bits} p {p} i {i} err {err}");
                    }
                }
            }
        }
    }

    #[test]
    fn scale_growth_requantizes_earlier_rows() {
        let pool = KvPool::new(&TINY, &kv(8, 16), None).unwrap();
        let mut c = pool.new_cache();
        c.reserve(2).unwrap();
        let d = TINY.d_model;
        let small = vec![0.01f32; d];
        let big = vec![1.0f32; d];
        c.write_row(0, 0, &small, &small);
        c.write_row(0, 1, &big, &big); // scale jumps 100×
        c.set_pos(2);
        let mut out = vec![0f32; 2 * d];
        c.gather_k(0, 2, &mut out);
        // the small row survives the rescale (coarser grid, still ~0.01)
        assert!((out[0] - 0.01).abs() < 1.0 / 127.0 + 1e-4, "{}", out[0]);
        assert!((out[d] - 1.0).abs() < 2.0 / 127.0, "{}", out[d]);
    }

    #[test]
    fn pool_exhaustion_is_an_error_and_release_recycles() {
        let cfg = kv(8, 8);
        let layout = KvLayout::from(&TINY, &cfg);
        let pool = KvPool::new(&TINY, &cfg, Some(layout.block_bytes() * 2)).unwrap();
        assert_eq!(pool.status().total_blocks, 2);
        let mut a = pool.new_cache();
        a.reserve(16).unwrap(); // 2 blocks
        assert_eq!(pool.status().free_blocks, 0);
        let mut b = pool.new_cache();
        assert!(b.reserve(1).is_err(), "lease beyond budget must fail");
        drop(a);
        assert_eq!(pool.status().free_blocks, 2);
        b.reserve(8).unwrap();
        assert_eq!(pool.status().used_blocks(), 1);
    }

    #[test]
    fn fork_copies_blocks_and_leases_independently() {
        let pool = KvPool::new(&TINY, &kv(8, 8), None).unwrap();
        let mut a = pool.new_cache();
        a.reserve(10).unwrap();
        let d = TINY.d_model;
        for p in 0..10 {
            let r = row(p, d, 1.0);
            a.write_row(1, p, &r, &r);
        }
        a.set_pos(10);
        let b = a.try_clone().unwrap();
        assert_eq!(pool.status().used_blocks(), 4);
        let (mut ga, mut gb) = (vec![0f32; 10 * d], vec![0f32; 10 * d]);
        a.gather_k(1, 10, &mut ga);
        b.gather_k(1, 10, &mut gb);
        assert_eq!(ga, gb);
        drop(b);
        assert_eq!(pool.status().used_blocks(), 2);
    }

    #[test]
    fn truncate_releases_blocks_and_restores_quantized_tail_state() {
        // rejected speculative rows must leave no trace: neither leased
        // blocks nor grown tail-block scales (the rollback half of
        // docs/SPECULATIVE.md)
        for bits in [32u8, 8, 4] {
            let pool = KvPool::new(&TINY, &kv(bits, 4), None).unwrap();
            let mut c = pool.new_cache();
            let d = TINY.d_model;
            c.reserve(6).unwrap();
            for p in 0..6 {
                let r = row(p, d, 0.05); // small rows → small scales
                for l in 0..TINY.n_layers {
                    c.write_row(l, p, &r, &r);
                }
            }
            c.set_pos(6);
            let mut before = vec![0f32; 6 * d];
            c.gather_k(0, 6, &mut before);
            let leased_before = c.leased_blocks();

            // speculative window: 5 big rows (scale grows 20×, spills into
            // a fresh block), then reject everything
            c.begin_speculation();
            c.reserve(5).unwrap();
            for p in 6..11 {
                let r = row(p, d, 1.0);
                for l in 0..TINY.n_layers {
                    c.write_row(l, p, &r, &r);
                }
            }
            c.set_pos(11);
            assert!(c.leased_blocks() > leased_before, "window must lease a new block");
            c.truncate(6);

            assert_eq!(c.pos(), 6, "bits {bits}");
            assert_eq!(c.leased_blocks(), leased_before, "bits {bits} block leak");
            let mut after = vec![0f32; 6 * d];
            c.gather_k(0, 6, &mut after);
            assert_eq!(before, after, "bits {bits}: tail state not restored byte-exactly");

            // the window costs the pool nothing once resolved
            drop(c);
            assert_eq!(pool.status().used_blocks(), 0, "bits {bits}");
        }
    }

    #[test]
    fn repeated_speculation_windows_reuse_the_snapshot_buffer() {
        let pool = KvPool::new(&TINY, &kv(8, 4), None).unwrap();
        let mut c = pool.new_cache();
        let d = TINY.d_model;
        c.reserve(3).unwrap();
        for p in 0..3 {
            let r = row(p, d, 0.1);
            c.write_row(0, p, &r, &r);
        }
        c.set_pos(3);
        for round in 0..4 {
            let mut before = vec![0f32; 3 * d];
            c.gather_k(0, 3, &mut before);
            c.begin_speculation();
            c.reserve(2).unwrap();
            let big = row(90 + round, d, 2.0);
            c.write_row(0, 3, &big, &big);
            c.write_row(0, 4, &big, &big);
            c.set_pos(5);
            c.truncate(3);
            let mut after = vec![0f32; 3 * d];
            c.gather_k(0, 3, &mut after);
            assert_eq!(before, after, "round {round}");
        }
        assert_eq!(pool.status().used_blocks(), c.leased_blocks());
    }

    #[test]
    fn block_bytes_compression() {
        let fp = KvLayout::from(&TINY, &kv(32, 16)).block_bytes();
        let i8b = KvLayout::from(&TINY, &kv(8, 16)).block_bytes();
        let i4b = KvLayout::from(&TINY, &kv(4, 16)).block_bytes();
        assert!(i8b * 3 < fp, "int8 block ({i8b}) ≥ fp32/3 ({fp})");
        assert!(i4b * 6 < fp, "int4 block ({i4b}) ≥ fp32/6 ({fp})");
    }
}
