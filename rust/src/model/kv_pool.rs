//! Paged, arbitrary-bit quantized KV cache (the serving-side half of the
//! paper's memory claim): a shared **block pool** from which sequences
//! lease fixed-size blocks on demand — vLLM-style — instead of reserving a
//! dense `n_layers × max_seq × kv_dim` fp32 slab per session.
//!
//! Two levers convert into admission capacity:
//!
//! * **paging** — a sequence only holds `ceil(pos / block_size)` blocks,
//!   so short sequences stop wasting their whole `max_seq` reservation;
//! * **bit width** — each block stores K/V at [`KvCacheConfig::bits`]
//!   (fp32 passthrough, int8, or nibble-packed int4) with one symmetric
//!   scale per `(layer, head)` per block, reusing the `quant` machinery
//!   ([`QParams`]/[`quantize_value`]/[`dequantize_value`]). int8 KV is
//!   4× the blocks — and therefore ~4× the concurrently active
//!   sequences — at a fixed byte budget (asserted in
//!   `rust/tests/prop_coordinator.rs`).
//!
//! A third lever, **sharing**, stacks on top: blocks are leased through
//! refcounted [`BlockRef`] handles, so a session fork or a prefix-cache
//! attach adds *references* to resident blocks instead of copying them.
//! Shared blocks are strictly read-only through the block table — the
//! first write a session directs at one (decode append, in-block
//! requantize on scale growth, speculative rollback) transparently
//! materializes a private copy first (copy-on-write). The pool counts
//! each physical block once no matter how many tables reference it, which
//! is what [`KvPoolStatus`] and the serving metrics report.
//!
//! Scales grow monotonically: a block's `(layer, head)` scale is set by
//! the first row written and, when a later row's absmax exceeds it, the
//! already-written rows of that head slab are requantized in code space
//! before the new scale takes effect. Rows are only ever appended in
//! position order, so "already written" is exactly the in-block index.
//!
//! The transformer reads pages through [`KvStore::gather_k`] /
//! [`KvStore::gather_v`] — a dequant-into-scratch view that materializes
//! the `[0, pos)` prefix of one layer into a caller-owned arena buffer, so
//! the steady-state decode loop stays allocation-free (`docs/PERF.md`,
//! `docs/SERVING.md`).

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::quant::{dequantize_value, quantize_value, QParams, QuantSpec};

use super::config::ModelConfig;
use super::kv_cache::KvStore;

/// KV storage configuration: bit width per element + positions per block.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KvCacheConfig {
    /// 32 (fp32 passthrough), 8 (int8) or 4 (nibble-packed int4)
    pub bits: u8,
    /// positions per leased block
    pub block_size: usize,
}

impl KvCacheConfig {
    pub const FP32: KvCacheConfig = KvCacheConfig { bits: 32, block_size: 16 };

    pub const fn new(bits: u8, block_size: usize) -> Self {
        KvCacheConfig { bits, block_size }
    }

    pub fn validate(&self) -> Result<()> {
        if !matches!(self.bits, 4 | 8 | 32) {
            bail!("KvCacheConfig.bits must be 4, 8 or 32 (got {})", self.bits);
        }
        if self.block_size == 0 {
            bail!("KvCacheConfig.block_size must be > 0");
        }
        Ok(())
    }

    /// KV bytes one *position* costs across all layers (codes + the
    /// amortized per-block scales) — the pool-sizing unit in
    /// `docs/SERVING.md`.
    pub fn bytes_per_position(&self, m: &ModelConfig) -> f64 {
        let layout = KvLayout::from(m, self);
        layout.block_bytes() as f64 / self.block_size as f64
    }
}

impl Default for KvCacheConfig {
    fn default() -> Self {
        KvCacheConfig::FP32
    }
}

/// Derived per-block geometry (internal).
#[derive(Clone, Copy, Debug)]
struct KvLayout {
    n_layers: usize,
    kv_dim: usize,
    n_kv_heads: usize,
    head_dim: usize,
    block_size: usize,
    bits: u8,
}

impl KvLayout {
    fn from(m: &ModelConfig, kv: &KvCacheConfig) -> Self {
        KvLayout {
            n_layers: m.n_layers,
            kv_dim: m.kv_dim(),
            n_kv_heads: m.n_kv_heads,
            head_dim: m.head_dim(),
            block_size: kv.block_size,
            bits: kv.bits,
        }
    }

    /// Packed code bytes of one K (or V) row.
    fn row_bytes(&self) -> usize {
        self.kv_dim * self.bits as usize / 8
    }

    /// Resident bytes of one block: K + V codes plus per-(layer, head)
    /// scales on each side (fp32 blocks carry no scales).
    fn block_bytes(&self) -> usize {
        if self.bits == 32 {
            2 * self.n_layers * self.block_size * self.kv_dim * 4
        } else {
            2 * self.n_layers * self.block_size * self.row_bytes()
                + 2 * self.n_layers * self.n_kv_heads * 4
        }
    }

    /// Byte offset of row (`layer`, `idx`) inside a codes vec.
    fn row_base(&self, layer: usize, idx: usize) -> usize {
        (layer * self.block_size + idx) * self.row_bytes()
    }
}

#[inline]
fn get_code(codes: &[u8], bits: u8, row_base: usize, col: usize) -> u8 {
    if bits == 8 {
        codes[row_base + col]
    } else {
        let b = codes[row_base + col / 2];
        if col % 2 == 0 {
            b & 0x0F
        } else {
            b >> 4
        }
    }
}

#[inline]
fn set_code(codes: &mut [u8], bits: u8, row_base: usize, col: usize, q: u8) {
    if bits == 8 {
        codes[row_base + col] = q;
    } else {
        let b = &mut codes[row_base + col / 2];
        if col % 2 == 0 {
            *b = (*b & 0xF0) | (q & 0x0F);
        } else {
            *b = (*b & 0x0F) | (q << 4);
        }
    }
}

/// One leased block: `block_size` positions of K/V across all layers.
pub struct KvBlock {
    data: BlockData,
}

enum BlockData {
    /// passthrough, `[n_layers][block_size][kv_dim]` per side
    F32 { k: Vec<f32>, v: Vec<f32> },
    /// packed codes `[n_layers][block_size][row_bytes]` per side with
    /// symmetric per-(layer, head) scales `[n_layers][n_kv_heads]`
    Quant { k: Vec<u8>, v: Vec<u8>, k_scale: Vec<f32>, v_scale: Vec<f32> },
}

impl KvBlock {
    fn new(l: &KvLayout) -> Self {
        let data = if l.bits == 32 {
            let n = l.n_layers * l.block_size * l.kv_dim;
            BlockData::F32 { k: vec![0.0; n], v: vec![0.0; n] }
        } else {
            let n = l.n_layers * l.block_size * l.row_bytes();
            let ns = l.n_layers * l.n_kv_heads;
            BlockData::Quant {
                k: vec![0; n],
                v: vec![0; n],
                k_scale: vec![0.0; ns],
                v_scale: vec![0.0; ns],
            }
        };
        KvBlock { data }
    }

    /// Zero-capacity placeholder used when moving the real buffer out of
    /// a dropped [`BlockSlot`]; never enters the pool's free list.
    fn empty() -> Self {
        KvBlock { data: BlockData::F32 { k: Vec::new(), v: Vec::new() } }
    }

    fn copy_from(&mut self, other: &KvBlock) {
        match (&mut self.data, &other.data) {
            (BlockData::F32 { k, v }, BlockData::F32 { k: ok, v: ov }) => {
                k.copy_from_slice(ok);
                v.copy_from_slice(ov);
            }
            (
                BlockData::Quant { k, v, k_scale, v_scale },
                BlockData::Quant { k: ok, v: ov, k_scale: oks, v_scale: ovs },
            ) => {
                k.copy_from_slice(ok);
                v.copy_from_slice(ov);
                k_scale.copy_from_slice(oks);
                v_scale.copy_from_slice(ovs);
            }
            _ => unreachable!("pool never mixes block storage kinds"),
        }
    }

    /// Serialize to the `.abqs` page payload: exact little-endian bit
    /// patterns, `K codes | V codes | K scales | V scales` (fp32 blocks
    /// are `K rows | V rows`). Always [`KvLayout::block_bytes`] long.
    fn to_bytes(&self) -> Vec<u8> {
        match &self.data {
            BlockData::F32 { k, v } => {
                let mut b = Vec::with_capacity((k.len() + v.len()) * 4);
                for x in k.iter().chain(v.iter()) {
                    b.extend_from_slice(&x.to_le_bytes());
                }
                b
            }
            BlockData::Quant { k, v, k_scale, v_scale } => {
                let mut b = Vec::with_capacity(
                    k.len() + v.len() + (k_scale.len() + v_scale.len()) * 4,
                );
                b.extend_from_slice(k);
                b.extend_from_slice(v);
                for x in k_scale.iter().chain(v_scale.iter()) {
                    b.extend_from_slice(&x.to_le_bytes());
                }
                b
            }
        }
    }

    /// Inverse of [`to_bytes`](Self::to_bytes) for this layout; rejects
    /// payloads whose byte count does not match the layout exactly.
    fn from_bytes(l: &KvLayout, buf: &[u8]) -> Result<KvBlock> {
        if buf.len() != l.block_bytes() {
            bail!(
                "KV page payload is {} bytes, layout needs {}",
                buf.len(),
                l.block_bytes()
            );
        }
        let mut block = KvBlock::new(l);
        let mut off = 0usize;
        let take_f32 = |dst: &mut [f32], buf: &[u8], off: &mut usize| {
            for x in dst.iter_mut() {
                *x = f32::from_le_bytes(buf[*off..*off + 4].try_into().unwrap());
                *off += 4;
            }
        };
        match &mut block.data {
            BlockData::F32 { k, v } => {
                take_f32(k, buf, &mut off);
                take_f32(v, buf, &mut off);
            }
            BlockData::Quant { k, v, k_scale, v_scale } => {
                k.copy_from_slice(&buf[off..off + k.len()]);
                off += k.len();
                v.copy_from_slice(&buf[off..off + v.len()]);
                off += v.len();
                take_f32(k_scale, buf, &mut off);
                take_f32(v_scale, buf, &mut off);
            }
        }
        debug_assert_eq!(off, buf.len());
        Ok(block)
    }

    /// Write one side's row at in-block index `idx`; `idx` is also the
    /// count of rows already valid in this (block, layer), which bounds
    /// the requantize-on-scale-growth sweep.
    fn write_side(
        l: &KvLayout,
        codes: &mut [u8],
        scales: &mut [f32],
        layer: usize,
        idx: usize,
        row: &[f32],
    ) {
        let spec = QuantSpec::new(l.bits);
        let zp = 1i32 << (l.bits - 1);
        let qmax_mag = (zp - 1) as f32;
        for h in 0..l.n_kv_heads {
            let seg = &row[h * l.head_dim..(h + 1) * l.head_dim];
            let absmax = seg.iter().fold(0f32, |m, &x| m.max(x.abs()));
            let si = layer * l.n_kv_heads + h;
            let needed = (absmax / qmax_mag).max(1e-8);
            let delta = if idx == 0 {
                scales[si] = needed;
                needed
            } else if needed > scales[si] {
                // scale grew: requantize the rows already in this head slab
                let old = QParams { delta: scales[si], zp };
                let new = QParams { delta: needed, zp };
                for r in 0..idx {
                    let base = l.row_base(layer, r);
                    for j in 0..l.head_dim {
                        let col = h * l.head_dim + j;
                        let c = get_code(codes, l.bits, base, col);
                        let rq = quantize_value(dequantize_value(c, old), new, &spec);
                        set_code(codes, l.bits, base, col, rq);
                    }
                }
                scales[si] = needed;
                needed
            } else {
                scales[si]
            };
            let p = QParams { delta, zp };
            let base = l.row_base(layer, idx);
            for (j, &x) in seg.iter().enumerate() {
                set_code(codes, l.bits, base, h * l.head_dim + j, quantize_value(x, p, &spec));
            }
        }
    }

    fn write_row(&mut self, l: &KvLayout, layer: usize, idx: usize, k_row: &[f32], v_row: &[f32]) {
        match &mut self.data {
            BlockData::F32 { k, v } => {
                let off = (layer * l.block_size + idx) * l.kv_dim;
                k[off..off + l.kv_dim].copy_from_slice(k_row);
                v[off..off + l.kv_dim].copy_from_slice(v_row);
            }
            BlockData::Quant { k, v, k_scale, v_scale } => {
                Self::write_side(l, k, k_scale, layer, idx, k_row);
                Self::write_side(l, v, v_scale, layer, idx, v_row);
            }
        }
    }

    fn gather_side(
        l: &KvLayout,
        codes: &[u8],
        scales: &[f32],
        layer: usize,
        rows: usize,
        out: &mut [f32],
    ) {
        let zp = 1i32 << (l.bits - 1);
        for r in 0..rows {
            let base = l.row_base(layer, r);
            let orow = &mut out[r * l.kv_dim..(r + 1) * l.kv_dim];
            for h in 0..l.n_kv_heads {
                let p = QParams { delta: scales[layer * l.n_kv_heads + h], zp };
                for j in 0..l.head_dim {
                    let col = h * l.head_dim + j;
                    orow[col] = dequantize_value(get_code(codes, l.bits, base, col), p);
                }
            }
        }
    }

    /// Dequantize the first `rows` K rows of `layer` into `out`
    /// `[rows, kv_dim]`.
    fn gather_k(&self, l: &KvLayout, layer: usize, rows: usize, out: &mut [f32]) {
        match &self.data {
            BlockData::F32 { k, .. } => {
                let off = layer * l.block_size * l.kv_dim;
                out[..rows * l.kv_dim].copy_from_slice(&k[off..off + rows * l.kv_dim]);
            }
            BlockData::Quant { k, k_scale, .. } => {
                Self::gather_side(l, k, k_scale, layer, rows, out)
            }
        }
    }

    fn gather_v(&self, l: &KvLayout, layer: usize, rows: usize, out: &mut [f32]) {
        match &self.data {
            BlockData::F32 { v, .. } => {
                let off = layer * l.block_size * l.kv_dim;
                out[..rows * l.kv_dim].copy_from_slice(&v[off..off + rows * l.kv_dim]);
            }
            BlockData::Quant { v, v_scale, .. } => {
                Self::gather_side(l, v, v_scale, layer, rows, out)
            }
        }
    }
}

/// A refcounted lease of one pool block. Clones share the same physical
/// block (and are what `fork` and prefix attach hand out); the buffer
/// returns to the pool's free list when the last reference drops. The
/// block is writable only while the reference is exclusive — writers go
/// through [`PagedKvCache`]'s copy-on-write path, never through a shared
/// handle.
pub struct BlockRef(Arc<BlockSlot>);

struct BlockSlot {
    pool: KvPool,
    block: KvBlock,
}

impl Drop for BlockSlot {
    fn drop(&mut self) {
        // last reference gone: move the real buffer back to the free list
        let block = std::mem::replace(&mut self.block, KvBlock::empty());
        self.pool.release(block);
    }
}

impl BlockRef {
    fn block(&self) -> &KvBlock {
        &self.0.block
    }

    /// No other session or prefix-index entry references this block?
    fn is_exclusive(&self) -> bool {
        Arc::strong_count(&self.0) == 1
    }

    /// Mutable access, granted only while exclusive.
    fn block_mut(&mut self) -> Option<&mut KvBlock> {
        Arc::get_mut(&mut self.0).map(|slot| &mut slot.block)
    }
}

impl Clone for BlockRef {
    fn clone(&self) -> Self {
        self.0.pool.inner.refs.fetch_add(1, Ordering::Relaxed);
        BlockRef(Arc::clone(&self.0))
    }
}

impl Drop for BlockRef {
    fn drop(&mut self) {
        self.0.pool.inner.refs.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Point-in-time pool occupancy (what the scheduler's block-aware
/// admission and the serving metrics consume).
#[derive(Clone, Copy, Debug)]
pub struct KvPoolStatus {
    pub total_blocks: usize,
    pub free_blocks: usize,
    pub block_size: usize,
    pub block_bytes: usize,
    pub bits: u8,
    /// block-table references resolved by sharing instead of a new lease
    /// (0 when nothing is shared; each extra reference to an
    /// already-leased block counts once)
    pub shared_refs: usize,
    /// KV rows written through any session of this pool since
    /// construction — the prefill/decode op counter the tail-only-prefill
    /// tests assert on
    pub rows_written: u64,
    /// shared blocks privatized by a first write (copy-on-write copies)
    pub cow_copies: u64,
}

impl KvPoolStatus {
    /// Unique physical blocks leased; shared blocks count once.
    pub fn used_blocks(&self) -> usize {
        self.total_blocks - self.free_blocks
    }

    /// Blocks needed to hold `positions` KV rows.
    pub fn blocks_for(&self, positions: usize) -> usize {
        positions.div_ceil(self.block_size)
    }

    /// Pool occupancy as a percentage (0 for an empty-capacity pool) —
    /// the pressure signal the precision autopilot compares against its
    /// high/low water marks.
    pub fn occupancy_pct(&self) -> u64 {
        if self.total_blocks == 0 {
            0
        } else {
            (self.used_blocks() * 100 / self.total_blocks) as u64
        }
    }
}

/// The shared block pool: a capacity budget plus a free list of recycled
/// block buffers. Handles are cheap clones of one `Arc`; sessions lease
/// blocks through [`PagedKvCache`] and return them on drop. The lock is
/// touched only at block granularity (once every `block_size` positions
/// per sequence), never per row.
#[derive(Clone)]
pub struct KvPool {
    inner: Arc<PoolShared>,
}

struct PoolShared {
    layout: KvLayout,
    max_seq: usize,
    max_blocks: usize,
    state: Mutex<PoolState>,
    /// total [`BlockRef`] handles alive across all block tables; minus
    /// `leased` this is the sharing win the metrics report
    refs: AtomicUsize,
    rows_written: AtomicU64,
    cow_copies: AtomicU64,
}

struct PoolState {
    free: Vec<KvBlock>,
    /// unique physical blocks out on lease (shared blocks count once)
    leased: usize,
}

/// Default pool budget when none is configured: enough blocks for this
/// many full-`max_seq` sequences (block buffers allocate lazily, so an
/// untouched budget costs nothing).
const DEFAULT_POOL_SEQS: usize = 64;

impl KvPool {
    /// `budget_bytes` caps the pool (rounded down to whole blocks, min 1);
    /// `None` defaults to [`DEFAULT_POOL_SEQS`] full sequences.
    pub fn new(m: &ModelConfig, kv: &KvCacheConfig, budget_bytes: Option<usize>) -> Result<Self> {
        kv.validate()?;
        if kv.bits == 4 && m.kv_dim() % 2 != 0 {
            bail!("int4 KV pages need an even kv_dim (got {})", m.kv_dim());
        }
        let layout = KvLayout::from(m, kv);
        let blocks_per_seq = m.max_seq.div_ceil(kv.block_size);
        let max_blocks = match budget_bytes {
            Some(b) => (b / layout.block_bytes()).max(1),
            None => blocks_per_seq * DEFAULT_POOL_SEQS,
        };
        Ok(KvPool {
            inner: Arc::new(PoolShared {
                layout,
                max_seq: m.max_seq,
                max_blocks,
                state: Mutex::new(PoolState { free: Vec::new(), leased: 0 }),
                refs: AtomicUsize::new(0),
                rows_written: AtomicU64::new(0),
                cow_copies: AtomicU64::new(0),
            }),
        })
    }

    /// A fresh empty cache leasing from this pool.
    pub fn new_cache(&self) -> PagedKvCache {
        PagedKvCache {
            pool: self.clone(),
            blocks: Vec::new(),
            pos: 0,
            max_seq: self.inner.max_seq,
            snap_pos: None,
            snap_block: None,
            snap_spare: None,
        }
    }

    pub fn status(&self) -> KvPoolStatus {
        let st = self.inner.state.lock().unwrap();
        KvPoolStatus {
            total_blocks: self.inner.max_blocks,
            free_blocks: self.inner.max_blocks - st.leased,
            block_size: self.inner.layout.block_size,
            block_bytes: self.inner.layout.block_bytes(),
            bits: self.inner.layout.bits,
            shared_refs: self.inner.refs.load(Ordering::Relaxed).saturating_sub(st.leased),
            rows_written: self.inner.rows_written.load(Ordering::Relaxed),
            cow_copies: self.inner.cow_copies.load(Ordering::Relaxed),
        }
    }

    pub fn block_bytes(&self) -> usize {
        self.inner.layout.block_bytes()
    }

    pub fn blocks_for(&self, positions: usize) -> usize {
        positions.div_ceil(self.inner.layout.block_size)
    }

    /// Two handles on the same physical pool? (Prefix blocks can only be
    /// attached to sessions of the pool that leased them.)
    pub fn same_pool(&self, other: &KvPool) -> bool {
        Arc::ptr_eq(&self.inner, &other.inner)
    }

    /// Serialize a leased block to its `.abqs` page payload.
    pub fn block_to_bytes(&self, b: &BlockRef) -> Vec<u8> {
        b.block().to_bytes()
    }

    /// Lease a fresh block and fill it from an `.abqs` page payload
    /// (byte count must match this pool's layout exactly).
    pub fn block_from_bytes(&self, buf: &[u8]) -> Result<BlockRef> {
        let block = KvBlock::from_bytes(&self.inner.layout, buf)?;
        // adopt the parsed buffer under lease accounting (the free-list
        // buffer a plain lease would have reused stays in the free list)
        {
            let mut st = self.inner.state.lock().unwrap();
            if st.leased >= self.inner.max_blocks {
                bail!(
                    "KV pool exhausted: {}/{} blocks leased",
                    st.leased,
                    self.inner.max_blocks
                );
            }
            st.leased += 1;
        }
        self.inner.refs.fetch_add(1, Ordering::Relaxed);
        Ok(BlockRef(Arc::new(BlockSlot { pool: self.clone(), block })))
    }

    /// Serialized size of one page payload for this pool's layout.
    pub fn page_bytes(&self) -> usize {
        self.inner.layout.block_bytes()
    }

    fn lease(&self) -> Result<KvBlock> {
        let mut st = self.inner.state.lock().unwrap();
        if st.leased >= self.inner.max_blocks {
            bail!(
                "KV pool exhausted: {}/{} blocks leased",
                st.leased,
                self.inner.max_blocks
            );
        }
        st.leased += 1;
        Ok(st.free.pop().unwrap_or_else(|| KvBlock::new(&self.inner.layout)))
    }

    fn lease_ref(&self) -> Result<BlockRef> {
        let block = self.lease()?;
        self.inner.refs.fetch_add(1, Ordering::Relaxed);
        Ok(BlockRef(Arc::new(BlockSlot { pool: self.clone(), block })))
    }

    fn release(&self, block: KvBlock) {
        let mut st = self.inner.state.lock().unwrap();
        debug_assert!(st.leased > 0, "release without lease");
        st.leased -= 1;
        st.free.push(block);
    }
}

/// Per-sequence view over pool-leased blocks: the block table plus the
/// write position. Positions `[0, pos)` are valid; the block covering
/// position `p` is `blocks[p / block_size]`, row `p % block_size`.
///
/// Entries in `blocks` may be shared with other sessions (after a fork)
/// or with the prefix index (after an attach). Reads go straight through;
/// the first write to a shared block materializes a private copy
/// (copy-on-write), so no session ever observes another session's writes.
pub struct PagedKvCache {
    pool: KvPool,
    blocks: Vec<BlockRef>,
    pos: usize,
    max_seq: usize,
    /// position at which the open speculative window started, if any
    snap_pos: Option<usize>,
    /// copy of the then-partial tail block behind `snap_pos` (`None`
    /// when the window opened on a block boundary); swapped back in by
    /// `truncate` so rejected speculative rows cannot leave grown
    /// quantization scales behind
    snap_block: Option<KvBlock>,
    /// retained snapshot buffer so repeated windows allocate nothing —
    /// session-private scratch, never leased from (or released to) the
    /// pool, so pool accounting is untouched by speculation
    snap_spare: Option<KvBlock>,
}

impl PagedKvCache {
    pub fn pos(&self) -> usize {
        self.pos
    }

    pub fn remaining(&self) -> usize {
        self.max_seq - self.pos
    }

    pub fn leased_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// Resident bytes this session's block table references (each sharer
    /// reports shared blocks; pool-level accounting counts them once).
    pub fn bytes(&self) -> usize {
        self.blocks.len() * self.pool.block_bytes()
    }

    /// Copy-on-write fork: shares every block by reference — O(1), no new
    /// leases. The first write either side directs at a shared block
    /// materializes a private copy for that side. Any open speculative
    /// window stays with the original — the fork starts clean.
    ///
    /// Kept fallible for call-site compatibility (it cannot currently
    /// fail; divergence cost is paid later, at first write).
    pub fn try_clone(&self) -> Result<PagedKvCache> {
        Ok(PagedKvCache {
            pool: self.pool.clone(),
            blocks: self.blocks.clone(),
            pos: self.pos,
            max_seq: self.max_seq,
            snap_pos: None,
            snap_block: None,
            snap_spare: None,
        })
    }

    /// Share the leading whole blocks covering at most `upto` positions:
    /// returns the shared position count (a block multiple, possibly 0)
    /// and one reference per shared block. Partial tail blocks are never
    /// shared — their scales are still mutable.
    pub fn share_prefix(&self, upto: usize) -> (usize, Vec<BlockRef>) {
        let bs = self.pool.inner.layout.block_size;
        let n = upto.min(self.pos) / bs;
        (n * bs, self.blocks[..n].to_vec())
    }

    /// Adopt shared prefix blocks into a fresh session and move the write
    /// position past them; prefill then continues from `positions` with
    /// only the unshared tail.
    pub fn attach_prefix(&mut self, blocks: Vec<BlockRef>, positions: usize) -> Result<()> {
        if self.pos != 0 || !self.blocks.is_empty() {
            bail!("prefix attach needs a fresh session (pos {})", self.pos);
        }
        let bs = self.pool.inner.layout.block_size;
        if positions != blocks.len() * bs {
            bail!(
                "prefix covers {positions} positions but {} blocks × {bs} were attached",
                blocks.len()
            );
        }
        if positions > self.max_seq {
            bail!("prefix ({positions} positions) exceeds max_seq {}", self.max_seq);
        }
        self.blocks = blocks;
        self.pos = positions;
        Ok(())
    }

    /// Materialize a private copy of block `i` when it is shared: leases
    /// a fresh block, copies the bytes, and swaps the reference; peers
    /// keep the original (copy-on-write).
    fn privatize(&mut self, i: usize) -> Result<()> {
        if self.blocks[i].is_exclusive() {
            return Ok(());
        }
        let mut fresh = self.pool.lease_ref()?;
        fresh
            .block_mut()
            .expect("fresh lease is exclusive")
            .copy_from(self.blocks[i].block());
        self.pool.inner.cow_copies.fetch_add(1, Ordering::Relaxed);
        self.blocks[i] = fresh;
        Ok(())
    }
}

impl KvStore for PagedKvCache {
    fn pos(&self) -> usize {
        self.pos
    }

    fn set_pos(&mut self, pos: usize) {
        debug_assert!(pos <= self.blocks.len() * self.pool.inner.layout.block_size);
        self.pos = pos;
    }

    fn remaining(&self) -> usize {
        self.max_seq - self.pos
    }

    fn reserve(&mut self, additional: usize) -> Result<()> {
        if self.pos + additional > self.max_seq {
            bail!(
                "sequence would exceed KV capacity ({} + {additional} > {})",
                self.pos,
                self.max_seq
            );
        }
        if additional == 0 {
            return Ok(());
        }
        let needed = self.pool.blocks_for(self.pos + additional);
        // copy-on-write: the coming writes land in [pos, pos+additional),
        // so privatize any shared block that window touches up front —
        // here pool exhaustion is still a clean, recoverable error (in
        // practice only a partial tail left by fork/attach is affected)
        let first = self.pos / self.pool.inner.layout.block_size;
        for i in first..self.blocks.len().min(needed) {
            self.privatize(i)?;
        }
        while self.blocks.len() < needed {
            self.blocks.push(self.pool.lease_ref()?);
        }
        Ok(())
    }

    fn write_row(&mut self, layer: usize, pos: usize, k_row: &[f32], v_row: &[f32]) {
        let l = self.pool.inner.layout;
        let (b, idx) = (pos / l.block_size, pos % l.block_size);
        if !self.blocks[b].is_exclusive() {
            // a write that bypassed `reserve` still honours copy-on-write;
            // exhaustion here is an invariant breach (reserve() is the
            // fallible path that must precede writes)
            self.privatize(b).expect("KV pool exhausted during copy-on-write");
        }
        self.pool.inner.rows_written.fetch_add(1, Ordering::Relaxed);
        self.blocks[b]
            .block_mut()
            .expect("privatized above")
            .write_row(&l, layer, idx, k_row, v_row);
    }

    fn gather_k(&self, layer: usize, upto: usize, out: &mut [f32]) {
        let l = self.pool.inner.layout;
        let mut p = 0;
        for block in &self.blocks {
            if p >= upto {
                break;
            }
            let rows = (upto - p).min(l.block_size);
            block.block().gather_k(&l, layer, rows, &mut out[p * l.kv_dim..(p + rows) * l.kv_dim]);
            p += rows;
        }
    }

    fn gather_v(&self, layer: usize, upto: usize, out: &mut [f32]) {
        let l = self.pool.inner.layout;
        let mut p = 0;
        for block in &self.blocks {
            if p >= upto {
                break;
            }
            let rows = (upto - p).min(l.block_size);
            block.block().gather_v(&l, layer, rows, &mut out[p * l.kv_dim..(p + rows) * l.kv_dim]);
            p += rows;
        }
    }

    fn begin_speculation(&mut self) {
        let l = self.pool.inner.layout;
        // an abandoned earlier window (nothing was rolled back) recycles
        // its buffer instead of leaking it to the allocator
        if let Some(b) = self.snap_block.take() {
            self.snap_spare = Some(b);
        }
        self.snap_pos = Some(self.pos);
        self.snap_block = if self.pos % l.block_size != 0 {
            // speculative writes into the partial tail block can grow its
            // per-(layer, head) scales and requantize the committed rows;
            // keep a byte copy so `truncate` can undo that exactly
            let src = self.blocks[self.pos / l.block_size].block();
            let mut buf = self.snap_spare.take().unwrap_or_else(|| KvBlock::new(&l));
            buf.copy_from(src);
            Some(buf)
        } else {
            None
        };
    }

    fn truncate(&mut self, pos: usize) {
        debug_assert!(pos <= self.pos, "truncate({pos}) beyond pos {}", self.pos);
        let l = self.pool.inner.layout;
        if let Some(sp) = self.snap_pos.take() {
            debug_assert_eq!(
                pos, sp,
                "paged truncate must return to the speculation snapshot position"
            );
            if let Some(buf) = self.snap_block.take() {
                // only restore when rewinding at/under the snapshot — a
                // truncate past it means the window was abandoned
                if pos <= sp {
                    let bi = sp / l.block_size;
                    match self.blocks[bi].block_mut() {
                        Some(b) => b.copy_from(&buf),
                        None => {
                            // still shared ⇒ no speculative row reached this
                            // block (writes privatize first), so its bytes
                            // already equal the snapshot — nothing to undo
                            debug_assert_eq!(
                                self.blocks[bi].block().to_bytes(),
                                buf.to_bytes(),
                                "shared tail diverged from its speculation snapshot"
                            );
                        }
                    }
                }
                self.snap_spare = Some(buf);
            }
        }
        // drop whole blocks past the new watermark (each returns to the
        // pool only when its last sharer lets go)
        let keep = pos.div_ceil(l.block_size);
        self.blocks.truncate(keep);
        self.pos = pos;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::TINY;

    fn kv(bits: u8, block_size: usize) -> KvCacheConfig {
        KvCacheConfig { bits, block_size }
    }

    fn row(seed: usize, d: usize, scale: f32) -> Vec<f32> {
        (0..d).map(|i| (((i * 31 + seed * 17) % 97) as f32 - 48.0) / 48.0 * scale).collect()
    }

    #[test]
    fn fp32_roundtrip_is_exact() {
        let pool = KvPool::new(&TINY, &kv(32, 8), None).unwrap();
        let mut c = pool.new_cache();
        c.reserve(20).unwrap();
        let d = TINY.kv_dim();
        for p in 0..20 {
            let (k, v) = (row(p, d, 1.0), row(p + 100, d, 2.0));
            c.write_row(2, p, &k, &v);
        }
        c.set_pos(20);
        let mut out = vec![0f32; 20 * d];
        c.gather_k(2, 20, &mut out);
        for p in 0..20 {
            assert_eq!(&out[p * d..(p + 1) * d], &row(p, d, 1.0)[..], "pos {p}");
        }
        c.gather_v(2, 20, &mut out);
        assert_eq!(&out[..d], &row(100, d, 2.0)[..]);
    }

    #[test]
    fn quantized_roundtrip_error_bounded() {
        for bits in [4u8, 8] {
            let pool = KvPool::new(&TINY, &kv(bits, 8), None).unwrap();
            let mut c = pool.new_cache();
            c.reserve(12).unwrap();
            let d = TINY.kv_dim();
            // decreasing magnitude: every per-head scale is fixed by row 0,
            // so the error bound is exactly one quantization step
            let base = row(0, d, 1.5);
            let scaled = |p: usize| -> Vec<f32> {
                base.iter().map(|x| x * (1.0 - p as f32 * 0.05)).collect()
            };
            for p in 0..12 {
                let r = scaled(p);
                c.write_row(0, p, &r, &r);
            }
            c.set_pos(12);
            let mut out = vec![0f32; 12 * d];
            c.gather_k(0, 12, &mut out);
            let zp = 1i32 << (bits - 1);
            let hd = TINY.head_dim();
            for p in 0..12 {
                let want = scaled(p);
                for h in 0..TINY.n_heads {
                    let absmax =
                        base[h * hd..(h + 1) * hd].iter().fold(0f32, |m, &x| m.max(x.abs()));
                    let delta = absmax / (zp - 1) as f32;
                    for j in 0..hd {
                        let i = h * hd + j;
                        let err = (out[p * d + i] - want[i]).abs();
                        assert!(err <= delta * 0.51 + 1e-6, "bits {bits} p {p} i {i} err {err}");
                    }
                }
            }
        }
    }

    #[test]
    fn scale_growth_requantizes_earlier_rows() {
        let pool = KvPool::new(&TINY, &kv(8, 16), None).unwrap();
        let mut c = pool.new_cache();
        c.reserve(2).unwrap();
        let d = TINY.kv_dim();
        let small = vec![0.01f32; d];
        let big = vec![1.0f32; d];
        c.write_row(0, 0, &small, &small);
        c.write_row(0, 1, &big, &big); // scale jumps 100×
        c.set_pos(2);
        let mut out = vec![0f32; 2 * d];
        c.gather_k(0, 2, &mut out);
        // the small row survives the rescale (coarser grid, still ~0.01)
        assert!((out[0] - 0.01).abs() < 1.0 / 127.0 + 1e-4, "{}", out[0]);
        assert!((out[d] - 1.0).abs() < 2.0 / 127.0, "{}", out[d]);
    }

    #[test]
    fn pool_exhaustion_is_an_error_and_release_recycles() {
        let cfg = kv(8, 8);
        let layout = KvLayout::from(&TINY, &cfg);
        let pool = KvPool::new(&TINY, &cfg, Some(layout.block_bytes() * 2)).unwrap();
        assert_eq!(pool.status().total_blocks, 2);
        let mut a = pool.new_cache();
        a.reserve(16).unwrap(); // 2 blocks
        assert_eq!(pool.status().free_blocks, 0);
        let mut b = pool.new_cache();
        assert!(b.reserve(1).is_err(), "lease beyond budget must fail");
        drop(a);
        assert_eq!(pool.status().free_blocks, 2);
        b.reserve(8).unwrap();
        assert_eq!(pool.status().used_blocks(), 1);
    }

    #[test]
    fn fork_is_copy_on_write_and_counts_shared_blocks_once() {
        let pool = KvPool::new(&TINY, &kv(8, 8), None).unwrap();
        let mut a = pool.new_cache();
        a.reserve(10).unwrap();
        let d = TINY.kv_dim();
        for p in 0..10 {
            let r = row(p, d, 1.0);
            a.write_row(1, p, &r, &r);
        }
        a.set_pos(10);
        let mut b = a.try_clone().unwrap();
        // O(1) fork: no new physical blocks, 2 extra shared references
        let st = pool.status();
        assert_eq!(st.used_blocks(), 2, "fork must not lease");
        assert_eq!(st.shared_refs, 2);
        let (mut ga, mut gb) = (vec![0f32; 10 * d], vec![0f32; 10 * d]);
        a.gather_k(1, 10, &mut ga);
        b.gather_k(1, 10, &mut gb);
        assert_eq!(ga, gb);

        // first divergent write privatizes exactly the touched tail block
        b.reserve(1).unwrap();
        let burst = row(99, d, 3.0); // grows b's tail scales
        b.write_row(1, 10, &burst, &burst);
        b.set_pos(11);
        let st = pool.status();
        assert_eq!(st.used_blocks(), 3, "one private copy of the shared tail");
        assert_eq!(st.cow_copies, 1);
        // the original never sees the fork's write or its requantization
        let mut ga2 = vec![0f32; 10 * d];
        a.gather_k(1, 10, &mut ga2);
        assert_eq!(ga, ga2, "fork write aliased into the original");

        drop(b);
        assert_eq!(pool.status().used_blocks(), 2);
        assert_eq!(pool.status().shared_refs, 0);
        drop(a);
        assert_eq!(pool.status().used_blocks(), 0, "block leak after COW churn");
    }

    #[test]
    fn prefix_share_and_attach_reuse_whole_blocks() {
        let pool = KvPool::new(&TINY, &kv(8, 4), None).unwrap();
        let d = TINY.kv_dim();
        let mut donor = pool.new_cache();
        donor.reserve(10).unwrap();
        for p in 0..10 {
            let r = row(p, d, 1.0);
            donor.write_row(0, p, &r, &r);
        }
        donor.set_pos(10);
        // only whole blocks are shareable: 10 positions at block 4 → 8
        let (shared, blocks) = donor.share_prefix(10);
        assert_eq!(shared, 8);
        assert_eq!(blocks.len(), 2);

        let mut c = pool.new_cache();
        c.attach_prefix(blocks, shared).unwrap();
        assert_eq!(c.pos(), 8);
        assert_eq!(pool.status().used_blocks(), 3, "attach must not copy");
        // attached prefix reads back the donor's rows…
        let mut out = vec![0f32; 8 * d];
        c.gather_k(0, 8, &mut out);
        let mut want = vec![0f32; 8 * d];
        donor.gather_k(0, 8, &mut want);
        assert_eq!(out, want);
        // …and the continuation write copies, never aliases
        c.reserve(1).unwrap();
        let burst = row(77, d, 4.0);
        c.write_row(0, 8, &burst, &burst);
        c.set_pos(9);
        let mut donor_after = vec![0f32; 8 * d];
        donor.gather_k(0, 8, &mut donor_after);
        assert_eq!(want, donor_after);

        // attach onto a non-fresh session is rejected
        let (s2, b2) = donor.share_prefix(8);
        assert!(c.attach_prefix(b2, s2).is_err());
        drop(c);
        drop(donor);
        assert_eq!(pool.status().used_blocks(), 0);
        assert_eq!(pool.status().shared_refs, 0);
    }

    #[test]
    fn block_serialization_roundtrips_byte_exactly() {
        for bits in [32u8, 8, 4] {
            let pool = KvPool::new(&TINY, &kv(bits, 4), None).unwrap();
            let d = TINY.kv_dim();
            let mut c = pool.new_cache();
            c.reserve(4).unwrap();
            for p in 0..4 {
                let r = row(p, d, 0.8);
                for l in 0..TINY.n_layers {
                    c.write_row(l, p, &r, &r);
                }
            }
            c.set_pos(4);
            let (_, blocks) = c.share_prefix(4);
            let payload = pool.block_to_bytes(&blocks[0]);
            assert_eq!(payload.len(), pool.page_bytes(), "bits {bits}");
            let restored = pool.block_from_bytes(&payload).unwrap();
            assert_eq!(
                pool.block_to_bytes(&restored),
                payload,
                "bits {bits}: page payload not byte-stable"
            );
            assert!(pool.block_from_bytes(&payload[1..]).is_err(), "length check");
        }
    }

    #[test]
    fn truncate_releases_blocks_and_restores_quantized_tail_state() {
        // rejected speculative rows must leave no trace: neither leased
        // blocks nor grown tail-block scales (the rollback half of
        // docs/SPECULATIVE.md)
        for bits in [32u8, 8, 4] {
            let pool = KvPool::new(&TINY, &kv(bits, 4), None).unwrap();
            let mut c = pool.new_cache();
            let d = TINY.kv_dim();
            c.reserve(6).unwrap();
            for p in 0..6 {
                let r = row(p, d, 0.05); // small rows → small scales
                for l in 0..TINY.n_layers {
                    c.write_row(l, p, &r, &r);
                }
            }
            c.set_pos(6);
            let mut before = vec![0f32; 6 * d];
            c.gather_k(0, 6, &mut before);
            let leased_before = c.leased_blocks();

            // speculative window: 5 big rows (scale grows 20×, spills into
            // a fresh block), then reject everything
            c.begin_speculation();
            c.reserve(5).unwrap();
            for p in 6..11 {
                let r = row(p, d, 1.0);
                for l in 0..TINY.n_layers {
                    c.write_row(l, p, &r, &r);
                }
            }
            c.set_pos(11);
            assert!(c.leased_blocks() > leased_before, "window must lease a new block");
            c.truncate(6);

            assert_eq!(c.pos(), 6, "bits {bits}");
            assert_eq!(c.leased_blocks(), leased_before, "bits {bits} block leak");
            let mut after = vec![0f32; 6 * d];
            c.gather_k(0, 6, &mut after);
            assert_eq!(before, after, "bits {bits}: tail state not restored byte-exactly");

            // the window costs the pool nothing once resolved
            drop(c);
            assert_eq!(pool.status().used_blocks(), 0, "bits {bits}");
        }
    }

    #[test]
    fn repeated_speculation_windows_reuse_the_snapshot_buffer() {
        let pool = KvPool::new(&TINY, &kv(8, 4), None).unwrap();
        let mut c = pool.new_cache();
        let d = TINY.kv_dim();
        c.reserve(3).unwrap();
        for p in 0..3 {
            let r = row(p, d, 0.1);
            c.write_row(0, p, &r, &r);
        }
        c.set_pos(3);
        for round in 0..4 {
            let mut before = vec![0f32; 3 * d];
            c.gather_k(0, 3, &mut before);
            c.begin_speculation();
            c.reserve(2).unwrap();
            let big = row(90 + round, d, 2.0);
            c.write_row(0, 3, &big, &big);
            c.write_row(0, 4, &big, &big);
            c.set_pos(5);
            c.truncate(3);
            let mut after = vec![0f32; 3 * d];
            c.gather_k(0, 3, &mut after);
            assert_eq!(before, after, "round {round}");
        }
        assert_eq!(pool.status().used_blocks(), c.leased_blocks());
    }

    #[test]
    fn block_bytes_compression() {
        let fp = KvLayout::from(&TINY, &kv(32, 16)).block_bytes();
        let i8b = KvLayout::from(&TINY, &kv(8, 16)).block_bytes();
        let i4b = KvLayout::from(&TINY, &kv(4, 16)).block_bytes();
        assert!(i8b * 3 < fp, "int8 block ({i8b}) ≥ fp32/3 ({fp})");
        assert!(i4b * 6 < fp, "int4 block ({i4b}) ≥ fp32/6 ({fp})");
    }
}
