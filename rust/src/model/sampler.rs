//! Token sampling for the serving path: greedy, temperature, top-k.

use crate::util::rng::SplitMix;

#[derive(Clone, Copy, Debug)]
pub enum Sampling {
    Greedy,
    /// softmax temperature + top-k truncation
    TopK { k: usize, temperature: f32 },
}

pub struct Sampler {
    pub mode: Sampling,
    rng: SplitMix,
}

impl Sampler {
    pub fn new(mode: Sampling, seed: u64) -> Self {
        Sampler { mode, rng: SplitMix::new(seed) }
    }

    /// The sampler's own random stream — speculative acceptance and
    /// residual resampling (`spec::accept`) draw from the same
    /// per-sequence stream the plain sampling path uses. Greedy decoding
    /// never draws, so speculative greedy leaves the stream untouched.
    pub fn rng_mut(&mut self) -> &mut SplitMix {
        &mut self.rng
    }

    /// Pick the next token from a logits row.
    pub fn sample(&mut self, logits: &[f32]) -> u32 {
        match self.mode {
            Sampling::Greedy => argmax(logits) as u32,
            Sampling::TopK { k, temperature } => {
                let k = k.max(1).min(logits.len());
                let mut idx: Vec<usize> = (0..logits.len()).collect();
                idx.sort_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
                idx.truncate(k);
                let t = temperature.max(1e-3);
                let mx = logits[idx[0]];
                let probs: Vec<f32> =
                    idx.iter().map(|&i| ((logits[i] - mx) / t).exp()).collect();
                let total: f32 = probs.iter().sum();
                let mut u = self.rng.next_f64() as f32 * total;
                for (j, &p) in probs.iter().enumerate() {
                    if u <= p {
                        return idx[j] as u32;
                    }
                    u -= p;
                }
                idx[k - 1] as u32
            }
        }
    }
}

pub fn argmax(row: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = i;
        }
    }
    best
}

/// log-softmax probability of `target` under `logits` (zero-shot scoring).
pub fn log_prob(logits: &[f32], target: usize) -> f32 {
    let mx = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let lse = mx + logits.iter().map(|v| (v - mx).exp()).sum::<f32>().ln();
    logits[target] - lse
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max() {
        let mut s = Sampler::new(Sampling::Greedy, 0);
        assert_eq!(s.sample(&[0.1, 2.0, -1.0, 1.9]), 1);
    }

    #[test]
    fn topk_stays_in_topk() {
        let mut s = Sampler::new(Sampling::TopK { k: 2, temperature: 1.0 }, 42);
        let logits = [5.0f32, 4.8, -10.0, -10.0];
        for _ in 0..50 {
            let t = s.sample(&logits);
            assert!(t == 0 || t == 1, "sampled {t}");
        }
    }

    #[test]
    fn logprob_normalises() {
        let logits = [1.0f32, 2.0, 3.0];
        let total: f32 = (0..3).map(|i| log_prob(&logits, i).exp()).sum();
        assert!((total - 1.0).abs() < 1e-5);
    }
}
