//! Rust-native transformer forward over pluggable GEMM backends.
//! Numerics mirror python `compile/model.py` exactly (RMSNorm eps, RoPE
//! pairing, SwiGLU, causal softmax), so the fp32 path reproduces the jax
//! model's perplexity and the ABQ path reproduces the calibrated
//! quantized model (parity asserted in rust/tests/).
//!
//! Since PR 10 the forward is architecture-parametric, not LLaMA-only:
//! [`ModelConfig::n_kv_heads`] narrows the K/V projections to
//! `kv_dim = n_kv_heads * head_dim` (GQA/MQA — query head `h` attends to
//! KV head `h / group_size`), and [`crate::model::ArchVariant`] selects
//! RMSNorm vs bias-free LayerNorm, SwiGLU vs GeGLU, and tied vs untied
//! unembedding. Registry entries live in [`crate::model::zoo`].
//!
//! Every projection is a [`crate::engine::LinearOp`] prepared by a
//! [`crate::engine::LinearBackend`] from the registry — the axis the
//! end-to-end benches (Fig. 6 / Table 12) sweep. Construction happens
//! through [`crate::engine::EngineBuilder`]; this type is the native
//! execution substrate behind the `InferenceEngine` trait.
//!
//! The forward passes are scratch-threaded: all intermediates (residual,
//! projection outputs, attention scores, RoPE tables, and each backend's
//! per-call working set) live in a [`ForwardScratch`] arena owned by the
//! engine session and reused across layers, projections and steps. A
//! steady-state single-token decode step performs no heap allocation
//! beyond the returned logits (`docs/PERF.md`).

use anyhow::{bail, Result};

use crate::baselines::gemm_fp32_into;
use crate::engine::{LinearBackend, LinearOp, LinearScratch, PrepareCtx};
use crate::quant::CorrectionSet;

use super::config::{Activation, ModelConfig, Norm};
use super::kv_cache::KvStore;
use super::weights::{PackSource, WeightPack};

pub const LINEAR_NAMES: [&str; 7] = ["wq", "wk", "wv", "wo", "gate", "up", "down"];

pub struct Block {
    pub ln1: Vec<f32>,
    pub ln2: Vec<f32>,
    pub wq: Box<dyn LinearOp>,
    pub wk: Box<dyn LinearOp>,
    pub wv: Box<dyn LinearOp>,
    pub wo: Box<dyn LinearOp>,
    pub gate: Box<dyn LinearOp>,
    pub up: Box<dyn LinearOp>,
    pub down: Box<dyn LinearOp>,
}

impl Block {
    pub fn linear(&self, name: &str) -> &dyn LinearOp {
        match name {
            "wq" => self.wq.as_ref(),
            "wk" => self.wk.as_ref(),
            "wv" => self.wv.as_ref(),
            "wo" => self.wo.as_ref(),
            "gate" => self.gate.as_ref(),
            "up" => self.up.as_ref(),
            "down" => self.down.as_ref(),
            _ => panic!("unknown linear {name}"),
        }
    }
}

pub struct Transformer {
    pub cfg: ModelConfig,
    /// canonical spec of the backend the blocks were prepared with
    pub backend_name: String,
    pub tok_emb: Vec<f32>,
    pub blocks: Vec<Block>,
    pub ln_f: Vec<f32>,
    /// unembedding stays fp (paper convention: embeddings not quantized);
    /// empty when `cfg.arch.tied_embeddings` — see [`Transformer::head_weights`]
    pub head: Vec<f32>,
}

// ---------------------------------------------------------------------------
// numerics (mirror compile/model.py)
// ---------------------------------------------------------------------------

pub fn rmsnorm(x: &[f32], g: &[f32], out: &mut [f32]) {
    let d = g.len();
    for (row, orow) in x.chunks_exact(d).zip(out.chunks_exact_mut(d)) {
        let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let r = 1.0 / (ms + 1e-5).sqrt();
        for i in 0..d {
            orow[i] = row[i] * r * g[i];
        }
    }
}

/// Bias-free LayerNorm (GPT-NeoX-likes): mean-subtract, then the same
/// rsqrt + gain shape as [`rmsnorm`] (shared 1e-5 eps).
pub fn layernorm(x: &[f32], g: &[f32], out: &mut [f32]) {
    let d = g.len();
    for (row, orow) in x.chunks_exact(d).zip(out.chunks_exact_mut(d)) {
        let mean: f32 = row.iter().sum::<f32>() / d as f32;
        let var: f32 = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
        let r = 1.0 / (var + 1e-5).sqrt();
        for i in 0..d {
            orow[i] = (row[i] - mean) * r * g[i];
        }
    }
}

/// Dispatch one normalisation over rows of `x` by the config's [`Norm`].
#[inline]
pub fn norm_into(norm: Norm, x: &[f32], g: &[f32], out: &mut [f32]) {
    match norm {
        Norm::RmsNorm => rmsnorm(x, g, out),
        Norm::LayerNorm => layernorm(x, g, out),
    }
}

/// RoPE tables for positions `[pos0, pos0+len)`: (cos, sin) `[len, hd/2]`.
pub fn rope_tables(cfg: &ModelConfig, pos0: usize, len: usize) -> (Vec<f32>, Vec<f32>) {
    let half = cfg.head_dim() / 2;
    let mut cos = vec![0f32; len * half];
    let mut sin = vec![0f32; len * half];
    rope_tables_into(cfg, pos0, len, &mut cos, &mut sin);
    (cos, sin)
}

/// [`rope_tables`] writing the `[len, hd/2]` tables into caller-owned
/// buffers (prefixes of `cos`/`sin`; the decode scratch reuses one pair
/// across sequences and steps).
pub fn rope_tables_into(
    cfg: &ModelConfig,
    pos0: usize,
    len: usize,
    cos: &mut [f32],
    sin: &mut [f32],
) {
    let hd = cfg.head_dim();
    let half = hd / 2;
    debug_assert!(cos.len() >= len * half && sin.len() >= len * half);
    for p in 0..len {
        for i in 0..half {
            let inv = 1.0 / cfg.rope_base.powf(2.0 * i as f32 / hd as f32);
            let ang = (pos0 + p) as f32 * inv;
            cos[p * half + i] = ang.cos();
            sin[p * half + i] = ang.sin();
        }
    }
}

/// Apply RoPE in place to `x` `[len, heads * hd]` seen as `[len, heads, hd]`.
/// `heads` is explicit because Q rows carry `n_heads` heads while K rows
/// carry only `n_kv_heads` under GQA — the row stride follows it.
pub fn apply_rope(
    x: &mut [f32],
    cfg: &ModelConfig,
    cos: &[f32],
    sin: &[f32],
    len: usize,
    heads: usize,
) {
    let hd = cfg.head_dim();
    let d = heads * hd;
    let half = hd / 2;
    for p in 0..len {
        for h in 0..heads {
            let base = p * d + h * hd;
            for i in 0..half {
                let c = cos[p * half + i];
                let s = sin[p * half + i];
                let x1 = x[base + 2 * i];
                let x2 = x[base + 2 * i + 1];
                x[base + 2 * i] = x1 * c - x2 * s;
                x[base + 2 * i + 1] = x1 * s + x2 * c;
            }
        }
    }
}

pub(crate) fn silu(v: f32) -> f32 {
    v / (1.0 + (-v).exp())
}

/// Tanh-approximated GELU (the GeGLU gate of NeoX-style variants).
pub(crate) fn gelu(v: f32) -> f32 {
    0.5 * v * (1.0 + (0.7978845608f32 * (v + 0.044715 * v * v * v)).tanh())
}

/// Dispatch the GLU gate activation by the config's [`Activation`].
#[inline]
pub(crate) fn act_gate(act: Activation, v: f32) -> f32 {
    match act {
        Activation::SiLu => silu(v),
        Activation::Gelu => gelu(v),
    }
}

pub(crate) fn softmax_inplace(row: &mut [f32]) {
    let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0f32;
    for v in row.iter_mut() {
        *v = (*v - mx).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in row.iter_mut() {
        *v *= inv;
    }
}

/// Everything the calibration pipeline needs to reconstruct one block's
/// computation offline: the fp32 activations at every projection
/// boundary plus the pre-softmax attention logits (the paper's
/// attention-consistency term compares these between the quantized and
/// fp32 block). Captured by [`Transformer::prefill_traced`].
#[derive(Clone, Debug, Default)]
pub struct BlockTrace {
    /// residual stream entering the block, `[tokens, d_model]`
    pub input: Vec<f32>,
    /// residual stream leaving the block, `[tokens, d_model]`
    pub output: Vec<f32>,
    /// post-`ln1` activations — the input to `wq`/`wk`/`wv`, `[tokens, d_model]`
    pub ln1_out: Vec<f32>,
    /// attention context — the input to `wo`, `[tokens, d_model]`
    pub attn_ctx: Vec<f32>,
    /// post-`ln2` activations — the input to `gate`/`up`, `[tokens, d_model]`
    pub ln2_out: Vec<f32>,
    /// SwiGLU product — the input to `down`, `[tokens, d_ff]`
    pub ffn_act: Vec<f32>,
    /// pre-softmax scaled attention scores, `[n_heads, tokens, tokens]`
    /// row-major, zero above the causal diagonal
    pub attn_logits: Vec<f32>,
}

impl BlockTrace {
    /// Input to a projection by name (the teacher activations for the
    /// calibration of that projection).
    pub fn proj_input(&self, name: &str) -> &[f32] {
        match name {
            "wq" | "wk" | "wv" => &self.ln1_out,
            "wo" => &self.attn_ctx,
            "gate" | "up" => &self.ln2_out,
            "down" => &self.ffn_act,
            _ => panic!("unknown linear {name}"),
        }
    }
}

/// The block tap: one [`BlockTrace`] per layer for one traced prefill.
#[derive(Clone, Debug, Default)]
pub struct BlockTap {
    /// tokens in the traced sequence
    pub tokens: usize,
    pub blocks: Vec<BlockTrace>,
}

impl BlockTap {
    pub fn new() -> Self {
        Self::default()
    }

    fn reset(&mut self, cfg: &ModelConfig, tokens: usize) {
        self.tokens = tokens;
        self.blocks.clear();
        self.blocks.resize(cfg.n_layers, BlockTrace::default());
        for tr in &mut self.blocks {
            tr.attn_logits = vec![0.0; cfg.n_heads * tokens * tokens];
        }
    }
}

/// Per-forward working memory, owned by the engine session and reused
/// across all layers (and, within a layer, across the 7 block
/// projections), across decode steps, and across the linears' own
/// intermediates (via the embedded [`LinearScratch`]). Buffers grow to
/// the largest (tokens, model) shape seen and are then reused
/// allocation-free.
#[derive(Default)]
pub struct ForwardScratch {
    /// residual stream `[tokens, d]`
    x: Vec<f32>,
    h: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    ctx: Vec<f32>,
    proj: Vec<f32>,
    gate: Vec<f32>,
    up: Vec<f32>,
    act: Vec<f32>,
    /// attention scores for one (token, head) pair, `[max_seq]`
    scores: Vec<f32>,
    /// gathered (dequantized) K/V pages for one (layer, sequence) — the
    /// paged read path materializes here; grown on demand to the largest
    /// attention span seen (≤ `[max_seq, kv_dim]`), not pre-sized
    kpage: Vec<f32>,
    vpage: Vec<f32>,
    /// RoPE tables `[tokens, hd/2]`
    cos: Vec<f32>,
    sin: Vec<f32>,
    /// staged fp32 K/V rows of the last [`Transformer::verify_step`],
    /// `[n_layers, stage_len, kv_dim]` — re-committed into the cache by
    /// [`Transformer::commit_verified`] for the accepted prefix only
    kstage: Vec<f32>,
    vstage: Vec<f32>,
    /// start position and token count of the staged verify window
    stage_pos0: usize,
    stage_len: usize,
    /// backend scratch arena threaded through every projection
    lin: LinearScratch,
}

impl ForwardScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Size every buffer for a `tokens`-row forward of `cfg`. `resize`
    /// sets exact logical lengths; capacity only ever grows, so once the
    /// arena has seen the largest shape this allocates nothing.
    fn ensure(&mut self, tokens: usize, cfg: &ModelConfig) {
        let (d, d_ff) = (cfg.d_model, cfg.d_ff);
        let kd = cfg.kv_dim();
        let half = cfg.head_dim() / 2;
        self.x.resize(tokens * d, 0.0);
        self.h.resize(tokens * d, 0.0);
        self.q.resize(tokens * d, 0.0);
        self.k.resize(tokens * kd, 0.0);
        self.v.resize(tokens * kd, 0.0);
        self.ctx.resize(tokens * d, 0.0);
        self.proj.resize(tokens * d, 0.0);
        self.gate.resize(tokens * d_ff, 0.0);
        self.up.resize(tokens * d_ff, 0.0);
        self.act.resize(tokens * d_ff, 0.0);
        self.scores.resize(cfg.max_seq, 0.0);
        self.cos.resize(tokens.max(1) * half, 0.0);
        self.sin.resize(tokens.max(1) * half, 0.0);
    }
}

// ---------------------------------------------------------------------------
// construction
// ---------------------------------------------------------------------------

impl Transformer {
    /// Build from a weight pack, preparing every projection with
    /// `backend`. Backends that load calibrated state (the ABQ engine)
    /// receive the pack through the [`PrepareCtx`].
    pub fn from_pack(
        pack: &WeightPack,
        cfg: ModelConfig,
        backend: &dyn LinearBackend,
    ) -> Result<Self> {
        Self::from_pack_corrected(pack, cfg, backend, None)
    }

    /// [`Transformer::from_pack`] with learned distribution corrections:
    /// each projection's [`crate::quant::Correction`] (when the set has
    /// one) is resolved into its [`PrepareCtx`] so correction-aware
    /// backends requantize with it (`docs/CALIBRATION.md`).
    pub fn from_pack_corrected(
        pack: &WeightPack,
        cfg: ModelConfig,
        backend: &dyn LinearBackend,
        corrections: Option<&CorrectionSet>,
    ) -> Result<Self> {
        Self::from_source_corrected(PackSource::Owned(pack), cfg, backend, corrections)
    }

    /// [`Transformer::from_pack_corrected`] generalized over a
    /// [`PackSource`]: an owned pack or a zero-copy mmap-backed
    /// [`crate::model::PackView`]. With a view, float tensors are
    /// borrowed straight from the mapping while the backend packs them
    /// (aligned data never touches the heap until the prepared form),
    /// so N replicas can be built off one mapping without N
    /// deserialization copies.
    pub fn from_source_corrected(
        src: PackSource<'_>,
        cfg: ModelConfig,
        backend: &dyn LinearBackend,
        corrections: Option<&CorrectionSet>,
    ) -> Result<Self> {
        cfg.validate()?;
        let tok_emb = src.f32("tok_emb")?.into_owned();
        let ln_f = src.f32("ln_f")?.into_owned();
        // tied-embedding packs carry no `head` tensor; the unembedding
        // reads `tok_emb` through `head_weights()`
        let head = if cfg.arch.tied_embeddings {
            Vec::new()
        } else {
            src.f32("head")?.into_owned()
        };
        let mut blocks = Vec::with_capacity(cfg.n_layers);
        for i in 0..cfg.n_layers {
            let get_lin = |name: &str| -> Result<Box<dyn LinearOp>> {
                let full = format!("blocks.{i}.{name}");
                let shape = src.shape(&full)?;
                if shape.len() != 2 {
                    bail!("linear {name} must be 2-D");
                }
                let (out_f, in_f) = (shape[0], shape[1]);
                backend.prepare(
                    &src.f32(&full)?,
                    out_f,
                    in_f,
                    &PrepareCtx {
                        pack: Some(src),
                        layer: i,
                        name,
                        correction: corrections.and_then(|cs| cs.get(i, name)),
                    },
                )
            };
            blocks.push(Block {
                ln1: src.f32(&format!("blocks.{i}.ln1"))?.into_owned(),
                ln2: src.f32(&format!("blocks.{i}.ln2"))?.into_owned(),
                wq: get_lin("wq")?,
                wk: get_lin("wk")?,
                wv: get_lin("wv")?,
                wo: get_lin("wo")?,
                gate: get_lin("gate")?,
                up: get_lin("up")?,
                down: get_lin("down")?,
            });
        }
        Ok(Transformer {
            cfg,
            backend_name: backend.name(),
            tok_emb,
            blocks,
            ln_f,
            head,
        })
    }

    /// Random-weight model (benches at real LLaMA layer shapes).
    pub fn random(cfg: ModelConfig, backend: &dyn LinearBackend, seed: u64) -> Result<Self> {
        Self::random_corrected(cfg, backend, seed, None)
    }

    /// [`Transformer::random`] with learned distribution corrections
    /// resolved per projection (calibration tests drive random models
    /// through the same correction-aware prepare path as packed ones).
    pub fn random_corrected(
        cfg: ModelConfig,
        backend: &dyn LinearBackend,
        seed: u64,
        corrections: Option<&CorrectionSet>,
    ) -> Result<Self> {
        cfg.validate()?;
        let rng = std::cell::RefCell::new(crate::util::rng::SplitMix::new(seed));
        let d = cfg.d_model;
        let kd = cfg.kv_dim();
        let dense = |out_f: usize, in_f: usize| -> Vec<f32> {
            let scale = 1.0 / (in_f as f32).sqrt();
            let mut r = rng.borrow_mut();
            (0..out_f * in_f).map(|_| r.next_f32_centered() * 2.0 * scale).collect()
        };
        let tok_emb: Vec<f32> = dense(cfg.vocab, d).iter().map(|v| v * 0.08).collect();
        let head: Vec<f32> = if cfg.arch.tied_embeddings {
            Vec::new()
        } else {
            dense(cfg.vocab, d).iter().map(|v| v * 0.08).collect()
        };
        let mut blocks = Vec::with_capacity(cfg.n_layers);
        for li in 0..cfg.n_layers {
            let mk = |w: Vec<f32>, out_f: usize, in_f: usize, name: &str| -> Result<Box<dyn LinearOp>> {
                backend.prepare(
                    &w,
                    out_f,
                    in_f,
                    &PrepareCtx {
                        pack: None,
                        layer: li,
                        name,
                        correction: corrections.and_then(|cs| cs.get(li, name)),
                    },
                )
            };
            blocks.push(Block {
                ln1: vec![1.0; d],
                ln2: vec![1.0; d],
                wq: mk(dense(d, d), d, d, "wq")?,
                wk: mk(dense(kd, d), kd, d, "wk")?,
                wv: mk(dense(kd, d), kd, d, "wv")?,
                wo: mk(dense(d, d), d, d, "wo")?,
                gate: mk(dense(cfg.d_ff, d), cfg.d_ff, d, "gate")?,
                up: mk(dense(cfg.d_ff, d), cfg.d_ff, d, "up")?,
                down: mk(dense(d, cfg.d_ff), d, cfg.d_ff, "down")?,
            });
        }
        Ok(Transformer {
            cfg,
            backend_name: backend.name(),
            tok_emb,
            blocks,
            ln_f: vec![1.0; d],
            head,
        })
    }

    // -----------------------------------------------------------------------
    // forward
    // -----------------------------------------------------------------------

    fn embed_into(&self, tokens: &[u32], x: &mut [f32]) {
        let d = self.cfg.d_model;
        debug_assert_eq!(x.len(), tokens.len() * d);
        for (t, &tok) in tokens.iter().enumerate() {
            let off = tok as usize * d;
            x[t * d..(t + 1) * d].copy_from_slice(&self.tok_emb[off..off + d]);
        }
    }

    /// Prefill one sequence, filling `cache` and returning logits `[S, V]`
    /// (fresh scratch; sessions use [`Transformer::prefill_scratch`]).
    /// The cache need not be fresh: prefill continues from `cache.pos()`
    /// (positions/RoPE angles follow the watermark), which is what lets
    /// prefix-cache attach feed only the unshared prompt tail and makes a
    /// continuation bit-identical to one uninterrupted prefill.
    pub fn prefill<C: KvStore>(&self, tokens: &[u32], cache: &mut C) -> Result<Vec<f32>> {
        let mut scratch = ForwardScratch::new();
        self.prefill_scratch(tokens, cache, &mut scratch)
    }

    /// [`Transformer::prefill`] over a caller-owned scratch arena.
    pub fn prefill_scratch<C: KvStore>(
        &self,
        tokens: &[u32],
        cache: &mut C,
        s: &mut ForwardScratch,
    ) -> Result<Vec<f32>> {
        self.prefill_impl(tokens, cache, s, None)
    }

    /// The calibration block tap: a prefill that runs the *same* code
    /// path as [`Transformer::prefill_scratch`] while capturing, per
    /// block, the residual stream in/out, every projection's input
    /// activations, and the pre-softmax attention logits
    /// (`docs/CALIBRATION.md`). Requires a fresh cache (`pos() == 0`) so
    /// each logit matrix is the full `[tokens, tokens]` causal triangle.
    pub fn prefill_traced<C: KvStore>(
        &self,
        tokens: &[u32],
        cache: &mut C,
        s: &mut ForwardScratch,
        tap: &mut BlockTap,
    ) -> Result<Vec<f32>> {
        if cache.pos() != 0 {
            bail!("prefill_traced needs a fresh cache (pos 0), got {}", cache.pos());
        }
        tap.reset(&self.cfg, tokens.len());
        self.prefill_impl(tokens, cache, s, Some(tap))
    }

    fn prefill_impl<C: KvStore>(
        &self,
        tokens: &[u32],
        cache: &mut C,
        s: &mut ForwardScratch,
        mut tap: Option<&mut BlockTap>,
    ) -> Result<Vec<f32>> {
        let s_len = tokens.len();
        // reserve is the single capacity check (max_seq + pool coverage)
        cache.reserve(s_len)?;
        let (d, hd, nh) = (self.cfg.d_model, self.cfg.head_dim(), self.cfg.n_heads);
        let (kd, group) = (self.cfg.kv_dim(), self.cfg.group_size());
        let norm = self.cfg.arch.norm;
        let pos0 = cache.pos();
        s.ensure(s_len, &self.cfg);
        rope_tables_into(&self.cfg, pos0, s_len, &mut s.cos, &mut s.sin);
        self.embed_into(tokens, &mut s.x);
        let scale = 1.0 / (hd as f32).sqrt();

        for (li, blk) in self.blocks.iter().enumerate() {
            if let Some(tp) = tap.as_deref_mut() {
                let tr = &mut tp.blocks[li];
                tr.input.clear();
                tr.input.extend_from_slice(&s.x);
            }
            norm_into(norm, &s.x, &blk.ln1, &mut s.h);
            if let Some(tp) = tap.as_deref_mut() {
                let tr = &mut tp.blocks[li];
                tr.ln1_out.clear();
                tr.ln1_out.extend_from_slice(&s.h);
            }
            blk.wq.forward_scratch(&s.h, s_len, &mut s.lin, &mut s.q);
            blk.wk.forward_scratch(&s.h, s_len, &mut s.lin, &mut s.k);
            blk.wv.forward_scratch(&s.h, s_len, &mut s.lin, &mut s.v);
            apply_rope(&mut s.q, &self.cfg, &s.cos, &s.sin, s_len, nh);
            apply_rope(&mut s.k, &self.cfg, &s.cos, &s.sin, s_len, self.cfg.n_kv_heads);
            for t in 0..s_len {
                cache.write_row(li, pos0 + t, &s.k[t * kd..(t + 1) * kd], &s.v[t * kd..(t + 1) * kd]);
            }
            // causal attention over the gathered pages [0, pos0+t] —
            // quantized K/V round-trips through the page codes here, so
            // attention sees exactly what the cache retains
            let keys_all = pos0 + s_len;
            if s.kpage.len() < keys_all * kd {
                s.kpage.resize(keys_all * kd, 0.0);
                s.vpage.resize(keys_all * kd, 0.0);
            }
            cache.gather_k(li, keys_all, &mut s.kpage[..keys_all * kd]);
            cache.gather_v(li, keys_all, &mut s.vpage[..keys_all * kd]);
            s.ctx.fill(0.0);
            for t in 0..s_len {
                let keys = pos0 + t + 1;
                for hh in 0..nh {
                    // GQA head-group broadcast: query head hh reads KV head hh/group
                    let kvh = hh / group;
                    let qv = &s.q[t * d + hh * hd..t * d + (hh + 1) * hd];
                    let scores = &mut s.scores[..keys];
                    for (kp, sc) in scores.iter_mut().enumerate() {
                        let kv = &s.kpage[kp * kd + kvh * hd..kp * kd + (kvh + 1) * hd];
                        *sc = qv.iter().zip(kv).map(|(a, b)| a * b).sum::<f32>() * scale;
                    }
                    if let Some(tp) = tap.as_deref_mut() {
                        // pos0 == 0 when tapped, so keys <= s_len
                        let tr = &mut tp.blocks[li];
                        let base = (hh * s_len + t) * s_len;
                        tr.attn_logits[base..base + keys].copy_from_slice(scores);
                    }
                    softmax_inplace(scores);
                    let crow = &mut s.ctx[t * d + hh * hd..t * d + (hh + 1) * hd];
                    for (kp, &a) in scores.iter().enumerate() {
                        let vv = &s.vpage[kp * kd + kvh * hd..kp * kd + (kvh + 1) * hd];
                        for i in 0..hd {
                            crow[i] += a * vv[i];
                        }
                    }
                }
            }
            if let Some(tp) = tap.as_deref_mut() {
                let tr = &mut tp.blocks[li];
                tr.attn_ctx.clear();
                tr.attn_ctx.extend_from_slice(&s.ctx);
            }
            blk.wo.forward_scratch(&s.ctx, s_len, &mut s.lin, &mut s.proj);
            for i in 0..s.x.len() {
                s.x[i] += s.proj[i];
            }
            norm_into(norm, &s.x, &blk.ln2, &mut s.h);
            if let Some(tp) = tap.as_deref_mut() {
                let tr = &mut tp.blocks[li];
                tr.ln2_out.clear();
                tr.ln2_out.extend_from_slice(&s.h);
            }
            blk.gate.forward_scratch(&s.h, s_len, &mut s.lin, &mut s.gate);
            blk.up.forward_scratch(&s.h, s_len, &mut s.lin, &mut s.up);
            for i in 0..s.act.len() {
                s.act[i] = act_gate(self.cfg.arch.act, s.gate[i]) * s.up[i];
            }
            if let Some(tp) = tap.as_deref_mut() {
                let tr = &mut tp.blocks[li];
                tr.ffn_act.clear();
                tr.ffn_act.extend_from_slice(&s.act);
            }
            blk.down.forward_scratch(&s.act, s_len, &mut s.lin, &mut s.proj);
            for i in 0..s.x.len() {
                s.x[i] += s.proj[i];
            }
            if let Some(tp) = tap.as_deref_mut() {
                let tr = &mut tp.blocks[li];
                tr.output.clear();
                tr.output.extend_from_slice(&s.x);
            }
        }
        cache.set_pos(pos0 + s_len);
        norm_into(norm, &s.x, &self.ln_f, &mut s.h);
        let mut logits = vec![0f32; s_len * self.cfg.vocab];
        gemm_fp32_into(&s.h, self.head_weights(), s_len, self.cfg.vocab, d, &mut logits);
        Ok(logits)
    }

    /// One decode step for a batch of sequences (fresh scratch; sessions
    /// use [`Transformer::decode_step_scratch`]). `tokens[i]` extends
    /// `caches[i]`. Returns logits `[B, V]`.
    pub fn decode_step<C: KvStore>(
        &self,
        tokens: &[u32],
        caches: &mut [&mut C],
    ) -> Result<Vec<f32>> {
        let mut scratch = ForwardScratch::new();
        self.decode_step_scratch(tokens, caches, &mut scratch)
    }

    /// One decode step over a caller-owned scratch arena — the hot path.
    /// Linears are batched over B (the GEMM-vs-GEMV axis the engine
    /// benches sweep). Steady state allocates only the returned logits
    /// (leasing a fresh KV block every `block_size` steps is the one
    /// amortized exception on the paged path).
    pub fn decode_step_scratch<C: KvStore>(
        &self,
        tokens: &[u32],
        caches: &mut [&mut C],
        s: &mut ForwardScratch,
    ) -> Result<Vec<f32>> {
        let b = tokens.len();
        if b != caches.len() {
            bail!("batch size mismatch");
        }
        let (d, hd, nh) = (self.cfg.d_model, self.cfg.head_dim(), self.cfg.n_heads);
        let (kd, group) = (self.cfg.kv_dim(), self.cfg.group_size());
        let norm = self.cfg.arch.norm;
        let half = hd / 2;
        let scale = 1.0 / (hd as f32).sqrt();
        s.ensure(b, &self.cfg);
        for cache in caches.iter_mut() {
            cache.reserve(1)?;
        }
        self.embed_into(tokens, &mut s.x);
        // per-sequence RoPE tables at each sequence's own position —
        // positions are fixed for the whole step, so build once here, not
        // once per layer
        for (bi, cache) in caches.iter().enumerate() {
            rope_tables_into(
                &self.cfg,
                cache.pos(),
                1,
                &mut s.cos[bi * half..(bi + 1) * half],
                &mut s.sin[bi * half..(bi + 1) * half],
            );
        }

        for (li, blk) in self.blocks.iter().enumerate() {
            norm_into(norm, &s.x, &blk.ln1, &mut s.h);
            blk.wq.forward_scratch(&s.h, b, &mut s.lin, &mut s.q);
            blk.wk.forward_scratch(&s.h, b, &mut s.lin, &mut s.k);
            blk.wv.forward_scratch(&s.h, b, &mut s.lin, &mut s.v);
            for bi in 0..b {
                let (cos, sin) =
                    (&s.cos[bi * half..(bi + 1) * half], &s.sin[bi * half..(bi + 1) * half]);
                apply_rope(&mut s.q[bi * d..(bi + 1) * d], &self.cfg, cos, sin, 1, nh);
                apply_rope(
                    &mut s.k[bi * kd..(bi + 1) * kd],
                    &self.cfg,
                    cos,
                    sin,
                    1,
                    self.cfg.n_kv_heads,
                );
            }
            s.ctx.fill(0.0);
            for (bi, cache) in caches.iter_mut().enumerate() {
                let pos = cache.pos();
                cache.write_row(li, pos, &s.k[bi * kd..(bi + 1) * kd], &s.v[bi * kd..(bi + 1) * kd]);
                let keys = pos + 1;
                if s.kpage.len() < keys * kd {
                    s.kpage.resize(keys * kd, 0.0);
                    s.vpage.resize(keys * kd, 0.0);
                }
                cache.gather_k(li, keys, &mut s.kpage[..keys * kd]);
                cache.gather_v(li, keys, &mut s.vpage[..keys * kd]);
                for hh in 0..nh {
                    let kvh = hh / group;
                    let qv = &s.q[bi * d + hh * hd..bi * d + (hh + 1) * hd];
                    let scores = &mut s.scores[..keys];
                    for (kp, sc) in scores.iter_mut().enumerate() {
                        let kv = &s.kpage[kp * kd + kvh * hd..kp * kd + (kvh + 1) * hd];
                        *sc = qv.iter().zip(kv).map(|(a, b)| a * b).sum::<f32>() * scale;
                    }
                    softmax_inplace(scores);
                    let crow = &mut s.ctx[bi * d + hh * hd..bi * d + (hh + 1) * hd];
                    for (kp, &a) in scores.iter().enumerate() {
                        let vv = &s.vpage[kp * kd + kvh * hd..kp * kd + (kvh + 1) * hd];
                        for i in 0..hd {
                            crow[i] += a * vv[i];
                        }
                    }
                }
            }
            blk.wo.forward_scratch(&s.ctx, b, &mut s.lin, &mut s.proj);
            for i in 0..s.x.len() {
                s.x[i] += s.proj[i];
            }
            norm_into(norm, &s.x, &blk.ln2, &mut s.h);
            blk.gate.forward_scratch(&s.h, b, &mut s.lin, &mut s.gate);
            blk.up.forward_scratch(&s.h, b, &mut s.lin, &mut s.up);
            for i in 0..s.act.len() {
                s.act[i] = act_gate(self.cfg.arch.act, s.gate[i]) * s.up[i];
            }
            blk.down.forward_scratch(&s.act, b, &mut s.lin, &mut s.proj);
            for i in 0..s.x.len() {
                s.x[i] += s.proj[i];
            }
        }
        for cache in caches.iter_mut() {
            let p = cache.pos();
            cache.set_pos(p + 1);
        }
        norm_into(norm, &s.x, &self.ln_f, &mut s.h);
        let mut logits = vec![0f32; b * self.cfg.vocab];
        gemm_fp32_into(&s.h, self.head_weights(), b, self.cfg.vocab, d, &mut logits);
        Ok(logits)
    }

    /// Multi-token speculative scoring for one sequence: append `tokens`
    /// (the pending token followed by the draft proposals) in one
    /// prefill-style pass and return logits at every position `[S, vocab]`
    /// — row `j` is the next-token distribution after `tokens[..=j]`.
    ///
    /// The pass is **bit-identical to feeding the same tokens one
    /// [`Transformer::decode_step`] at a time**: K/V rows are written to
    /// the cache one position at a time and each token's attention
    /// gathers pages only up to its own position, so quantized page
    /// scales evolve exactly as in sequential decode. (Projection rows
    /// are independent of the batch shape on every backend: the integer
    /// GEMMs are exact and the fp32 path accumulates each output element
    /// in a fixed k-order.)
    ///
    /// The cache is left advanced by `tokens.len()` positions with a
    /// speculation window open ([`KvStore::begin_speculation`]); the
    /// caller **must** follow with [`Transformer::commit_verified`] to
    /// keep the accepted prefix and roll the rejected suffix back
    /// (`docs/SPECULATIVE.md`).
    pub fn verify_step<C: KvStore>(
        &self,
        tokens: &[u32],
        cache: &mut C,
        s: &mut ForwardScratch,
    ) -> Result<Vec<f32>> {
        let s_len = tokens.len();
        if s_len == 0 {
            bail!("verify_step needs at least one token");
        }
        cache.reserve(s_len)?;
        cache.begin_speculation();
        let (d, hd, nh) = (self.cfg.d_model, self.cfg.head_dim(), self.cfg.n_heads);
        let (kd, group) = (self.cfg.kv_dim(), self.cfg.group_size());
        let norm = self.cfg.arch.norm;
        let pos0 = cache.pos();
        s.ensure(s_len, &self.cfg);
        s.kstage.resize(self.cfg.n_layers * s_len * kd, 0.0);
        s.vstage.resize(self.cfg.n_layers * s_len * kd, 0.0);
        s.stage_pos0 = pos0;
        s.stage_len = s_len;
        rope_tables_into(&self.cfg, pos0, s_len, &mut s.cos, &mut s.sin);
        self.embed_into(tokens, &mut s.x);
        let scale = 1.0 / (hd as f32).sqrt();

        for (li, blk) in self.blocks.iter().enumerate() {
            norm_into(norm, &s.x, &blk.ln1, &mut s.h);
            blk.wq.forward_scratch(&s.h, s_len, &mut s.lin, &mut s.q);
            blk.wk.forward_scratch(&s.h, s_len, &mut s.lin, &mut s.k);
            blk.wv.forward_scratch(&s.h, s_len, &mut s.lin, &mut s.v);
            apply_rope(&mut s.q, &self.cfg, &s.cos, &s.sin, s_len, nh);
            apply_rope(&mut s.k, &self.cfg, &s.cos, &s.sin, s_len, self.cfg.n_kv_heads);
            let keys_all = pos0 + s_len;
            if s.kpage.len() < keys_all * kd {
                s.kpage.resize(keys_all * kd, 0.0);
                s.vpage.resize(keys_all * kd, 0.0);
            }
            s.ctx.fill(0.0);
            for t in 0..s_len {
                // write row t *before* gathering, then gather only up to
                // its own position — the exact write/read interleaving of
                // sequential decode, so quantized page scales grow (and
                // requantize) identically
                let krow = &s.k[t * kd..(t + 1) * kd];
                let vrow = &s.v[t * kd..(t + 1) * kd];
                let stg = (li * s_len + t) * kd;
                s.kstage[stg..stg + kd].copy_from_slice(krow);
                s.vstage[stg..stg + kd].copy_from_slice(vrow);
                cache.write_row(li, pos0 + t, krow, vrow);
                let keys = pos0 + t + 1;
                cache.gather_k(li, keys, &mut s.kpage[..keys * kd]);
                cache.gather_v(li, keys, &mut s.vpage[..keys * kd]);
                for hh in 0..nh {
                    let kvh = hh / group;
                    let qv = &s.q[t * d + hh * hd..t * d + (hh + 1) * hd];
                    let scores = &mut s.scores[..keys];
                    for (kp, sc) in scores.iter_mut().enumerate() {
                        let kv = &s.kpage[kp * kd + kvh * hd..kp * kd + (kvh + 1) * hd];
                        *sc = qv.iter().zip(kv).map(|(a, b)| a * b).sum::<f32>() * scale;
                    }
                    softmax_inplace(scores);
                    let crow = &mut s.ctx[t * d + hh * hd..t * d + (hh + 1) * hd];
                    for (kp, &a) in scores.iter().enumerate() {
                        let vv = &s.vpage[kp * kd + kvh * hd..kp * kd + (kvh + 1) * hd];
                        for i in 0..hd {
                            crow[i] += a * vv[i];
                        }
                    }
                }
            }
            blk.wo.forward_scratch(&s.ctx, s_len, &mut s.lin, &mut s.proj);
            for i in 0..s.x.len() {
                s.x[i] += s.proj[i];
            }
            norm_into(norm, &s.x, &blk.ln2, &mut s.h);
            blk.gate.forward_scratch(&s.h, s_len, &mut s.lin, &mut s.gate);
            blk.up.forward_scratch(&s.h, s_len, &mut s.lin, &mut s.up);
            for i in 0..s.act.len() {
                s.act[i] = act_gate(self.cfg.arch.act, s.gate[i]) * s.up[i];
            }
            blk.down.forward_scratch(&s.act, s_len, &mut s.lin, &mut s.proj);
            for i in 0..s.x.len() {
                s.x[i] += s.proj[i];
            }
        }
        cache.set_pos(pos0 + s_len);
        norm_into(norm, &s.x, &self.ln_f, &mut s.h);
        let mut logits = vec![0f32; s_len * self.cfg.vocab];
        gemm_fp32_into(&s.h, self.head_weights(), s_len, self.cfg.vocab, d, &mut logits);
        Ok(logits)
    }

    /// Resolve the speculative window opened by
    /// [`Transformer::verify_step`]: roll the cache back to the window
    /// start — restoring quantized tail-block state byte-exactly — and
    /// re-commit the first `accepted` staged rows through the normal
    /// sequential write path. The cache ends byte-identical to one that
    /// decoded exactly those `accepted` tokens one step at a time and
    /// never saw the rejected suffix; the suffix's blocks return to the
    /// pool through the ordinary lease machinery (`KvStore::truncate`).
    pub fn commit_verified<C: KvStore>(
        &self,
        cache: &mut C,
        s: &ForwardScratch,
        accepted: usize,
    ) -> Result<()> {
        let (pos0, slen) = (s.stage_pos0, s.stage_len);
        if accepted > slen {
            bail!("commit_verified: accepted {accepted} > staged window of {slen}");
        }
        if cache.pos() != pos0 + slen {
            bail!(
                "commit_verified: cache at {} does not match the staged window [{pos0}, {})",
                cache.pos(),
                pos0 + slen
            );
        }
        let kd = self.cfg.kv_dim();
        cache.truncate(pos0);
        cache.reserve(accepted)?;
        for t in 0..accepted {
            // per position, layers in order — the exact write order of one
            // sequential decode step
            for li in 0..self.cfg.n_layers {
                let off = (li * slen + t) * kd;
                cache.write_row(li, pos0 + t, &s.kstage[off..off + kd], &s.vstage[off..off + kd]);
            }
        }
        cache.set_pos(pos0 + accepted);
        Ok(())
    }

    /// The unembedding matrix `[vocab, d_model]`: the dedicated `head`
    /// tensor, or `tok_emb` when the architecture ties them.
    #[inline]
    pub fn head_weights(&self) -> &[f32] {
        if self.cfg.arch.tied_embeddings {
            &self.tok_emb
        } else {
            &self.head
        }
    }

    /// Total block-weight bytes (Table 12 memory accounting). A tied
    /// embedding is counted once (`head` is empty then).
    pub fn weight_bytes(&self) -> usize {
        let blocks: usize = self
            .blocks
            .iter()
            .map(|b| {
                LINEAR_NAMES.iter().map(|n| b.linear(n).weight_bytes()).sum::<usize>()
                    + (b.ln1.len() + b.ln2.len()) * 4
            })
            .sum();
        blocks + (self.tok_emb.len() + self.head.len() + self.ln_f.len()) * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{AbqBackend, Fp32Backend};
    use crate::model::config::ModelConfig;
    use crate::model::KvCache;
    use crate::quant::WAConfig;

    const MICRO: ModelConfig = ModelConfig {
        name: "micro",
        vocab: 32,
        d_model: 16,
        n_layers: 2,
        n_heads: 2,
        n_kv_heads: 2,
        d_ff: 32,
        max_seq: 16,
        rope_base: 10000.0,
        arch: crate::model::config::ArchVariant::LLAMA,
    };

    #[test]
    fn prefill_then_decode_matches_prefill_of_longer_seq() {
        // teacher-forcing consistency: prefill(t0..t3) then decode(t4)
        // must give the same final-position logits as prefill(t0..t4)
        let m = Transformer::random(MICRO, &Fp32Backend, 7).unwrap();
        let toks = [1u32, 5, 9, 13, 21];
        let mut c1 = KvCache::new(&MICRO);
        let logits_full = m.prefill(&toks, &mut c1).unwrap();
        let last_full = &logits_full[4 * MICRO.vocab..5 * MICRO.vocab];

        let mut c2 = KvCache::new(&MICRO);
        m.prefill(&toks[..4], &mut c2).unwrap();
        let mut caches = [&mut c2];
        let logits_step = m.decode_step(&[toks[4]], &mut caches).unwrap();
        for (a, b) in last_full.iter().zip(&logits_step) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn batched_decode_matches_individual() {
        let m = Transformer::random(MICRO, &Fp32Backend, 3).unwrap();
        let seq_a = [2u32, 4, 6];
        let seq_b = [1u32, 3];
        let mut ca = KvCache::new(&MICRO);
        let mut cb = KvCache::new(&MICRO);
        m.prefill(&seq_a, &mut ca).unwrap();
        m.prefill(&seq_b, &mut cb).unwrap();
        // batched step
        let mut ca2 = ca.clone();
        let mut cb2 = cb.clone();
        let mut batch = [&mut ca2, &mut cb2];
        let batched = m.decode_step(&[7, 8], &mut batch).unwrap();
        // individual steps
        let mut one_a = [&mut ca];
        let la = m.decode_step(&[7], &mut one_a).unwrap();
        let mut one_b = [&mut cb];
        let lb = m.decode_step(&[8], &mut one_b).unwrap();
        for i in 0..MICRO.vocab {
            assert!((batched[i] - la[i]).abs() < 1e-4);
            assert!((batched[MICRO.vocab + i] - lb[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn reused_scratch_matches_fresh_scratch() {
        // one arena across prefill + many decode steps must be
        // bit-identical to fresh scratch every call
        let m = Transformer::random(MICRO, &Fp32Backend, 5).unwrap();
        let toks = [3u32, 1, 4];
        let mut shared = ForwardScratch::new();
        let mut c1 = KvCache::new(&MICRO);
        let mut c2 = KvCache::new(&MICRO);
        let l1 = m.prefill_scratch(&toks, &mut c1, &mut shared).unwrap();
        let l2 = m.prefill(&toks, &mut c2).unwrap();
        assert_eq!(l1, l2);
        for step in 0..4u32 {
            let mut b1 = [&mut c1];
            let s1 = m.decode_step_scratch(&[step + 7], &mut b1, &mut shared).unwrap();
            let mut b2 = [&mut c2];
            let s2 = m.decode_step(&[step + 7], &mut b2).unwrap();
            assert_eq!(s1, s2, "step {step}");
        }
    }

    #[test]
    fn traced_prefill_matches_untapped_and_captures_consistently() {
        let m = Transformer::random(MICRO, &Fp32Backend, 13).unwrap();
        let toks = [2u32, 9, 4, 17, 1];
        let t = toks.len();
        let mut c1 = KvCache::new(&MICRO);
        let plain = m.prefill(&toks, &mut c1).unwrap();
        let mut c2 = KvCache::new(&MICRO);
        let mut scratch = ForwardScratch::new();
        let mut tap = BlockTap::new();
        let traced = m.prefill_traced(&toks, &mut c2, &mut scratch, &mut tap).unwrap();
        assert_eq!(plain, traced, "tap must not perturb the forward");
        assert_eq!(tap.blocks.len(), MICRO.n_layers);
        assert_eq!(tap.tokens, t);
        let d = MICRO.d_model;
        for (li, tr) in tap.blocks.iter().enumerate() {
            assert_eq!(tr.input.len(), t * d, "block {li} input");
            assert_eq!(tr.output.len(), t * d);
            assert_eq!(tr.ln1_out.len(), t * d);
            assert_eq!(tr.attn_ctx.len(), t * d);
            assert_eq!(tr.ln2_out.len(), t * d);
            assert_eq!(tr.ffn_act.len(), t * MICRO.d_ff);
            assert_eq!(tr.attn_logits.len(), MICRO.n_heads * t * t);
            // the causal upper triangle stays zero
            for h in 0..MICRO.n_heads {
                for q in 0..t {
                    for k in (q + 1)..t {
                        assert_eq!(tr.attn_logits[(h * t + q) * t + k], 0.0);
                    }
                }
            }
            if li + 1 < tap.blocks.len() {
                assert_eq!(tr.output, tap.blocks[li + 1].input, "residual chain {li}");
            }
        }
        // a traced cache is as usable as an untapped one
        assert_eq!(c2.pos, t);
        // non-fresh cache is rejected
        assert!(m.prefill_traced(&toks, &mut c2, &mut scratch, &mut tap).is_err());
    }

    #[test]
    fn verify_step_is_bitwise_sequential_decode_on_dense_kv() {
        // the lossless-speculation cornerstone: a k-token verify pass must
        // reproduce k sequential decode steps bit-for-bit (logits AND
        // cache state), for both the fp comparator and a quantized engine
        let abq = AbqBackend::new(WAConfig::new(8, 8));
        let backends: [&dyn crate::engine::LinearBackend; 2] = [&Fp32Backend, &abq];
        for backend in backends {
            let m = Transformer::random(MICRO, backend, 17).unwrap();
            let prompt = [2u32, 9, 4];
            let steps = [7u32, 1, 12];
            let mut seq_cache = KvCache::new(&MICRO);
            m.prefill(&prompt, &mut seq_cache).unwrap();
            let mut ver_cache = seq_cache.clone();
            // sequential reference
            let mut seq_rows = Vec::new();
            for &tok in &steps {
                let mut b = [&mut seq_cache];
                seq_rows.push(m.decode_step(&[tok], &mut b).unwrap());
            }
            // one verify pass + full commit
            let mut scratch = ForwardScratch::new();
            let logits = m.verify_step(&steps, &mut ver_cache, &mut scratch).unwrap();
            for (j, want) in seq_rows.iter().enumerate() {
                let row = &logits[j * MICRO.vocab..(j + 1) * MICRO.vocab];
                assert_eq!(row, &want[..], "row {j}");
            }
            m.commit_verified(&mut ver_cache, &scratch, steps.len()).unwrap();
            assert_eq!(ver_cache.pos, seq_cache.pos);
            assert_eq!(ver_cache.k, seq_cache.k, "committed K state must match");
            assert_eq!(ver_cache.v, seq_cache.v);
            // both caches keep decoding identically
            let mut b1 = [&mut seq_cache];
            let a = m.decode_step(&[3], &mut b1).unwrap();
            let mut b2 = [&mut ver_cache];
            let b = m.decode_step(&[3], &mut b2).unwrap();
            assert_eq!(a, b);
        }
    }

    #[test]
    fn partial_commit_equals_never_having_speculated() {
        let m = Transformer::random(MICRO, &Fp32Backend, 23).unwrap();
        let prompt = [1u32, 6, 11];
        let mut plain = KvCache::new(&MICRO);
        m.prefill(&prompt, &mut plain).unwrap();
        let mut spec = plain.clone();
        // speculate 4 tokens, keep only 2
        let mut scratch = ForwardScratch::new();
        m.verify_step(&[5, 8, 2, 9], &mut spec, &mut scratch).unwrap();
        m.commit_verified(&mut spec, &scratch, 2).unwrap();
        // vanilla path decodes the same 2 kept tokens
        for &tok in &[5u32, 8] {
            let mut b = [&mut plain];
            m.decode_step(&[tok], &mut b).unwrap();
        }
        assert_eq!(spec.pos, plain.pos);
        // logits after the next shared token must be bit-identical
        let mut b1 = [&mut plain];
        let a = m.decode_step(&[4], &mut b1).unwrap();
        let mut b2 = [&mut spec];
        let b = m.decode_step(&[4], &mut b2).unwrap();
        assert_eq!(a, b, "rejected suffix left a trace in the cache");
        // stale commit / oversized accept are hard errors
        assert!(m.commit_verified(&mut spec, &scratch, 1).is_err());
    }

    #[test]
    fn abq_backend_runs_and_tracks_fp() {
        let fp = Transformer::random(MICRO, &Fp32Backend, 11).unwrap();
        let q8 =
            Transformer::random(MICRO, &AbqBackend::new(WAConfig::new(8, 8)), 11).unwrap();
        let toks = [3u32, 7, 11, 2];
        let mut c1 = KvCache::new(&MICRO);
        let mut c2 = KvCache::new(&MICRO);
        let lf = fp.prefill(&toks, &mut c1).unwrap();
        let lq = q8.prefill(&toks, &mut c2).unwrap();
        let max_abs = lf.iter().map(|v| v.abs()).fold(0f32, f32::max);
        let max_err = lf.iter().zip(&lq).map(|(a, b)| (a - b).abs()).fold(0f32, f32::max);
        assert!(max_err / max_abs < 0.25, "w8a8 rel err {}", max_err / max_abs);
    }

    #[test]
    fn weight_bytes_compression() {
        let fp = Transformer::random(MICRO, &Fp32Backend, 1).unwrap();
        let w2 =
            Transformer::random(MICRO, &AbqBackend::new(WAConfig::new(2, 8)), 1).unwrap();
        assert!(w2.weight_bytes() < fp.weight_bytes() / 2);
    }
}
