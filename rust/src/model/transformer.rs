//! Rust-native LLaMA-family transformer forward over pluggable GEMM
//! backends. Numerics mirror python `compile/model.py` exactly (RMSNorm
//! eps, RoPE pairing, SwiGLU, causal softmax), so the fp32 path reproduces
//! the jax model's perplexity and the ABQ path reproduces the calibrated
//! quantized model (parity asserted in rust/tests/).
//!
//! Every projection is a [`LinearOp`]: fp32 baseline, padded INT8/INT4
//! TensorCore stand-ins, or the ABQ bit-plane engine — the axis the
//! end-to-end benches (Fig. 6 / Table 12) sweep.

use anyhow::{bail, Context, Result};

use crate::abq::{OptLevel, QuantizedLinear};
use crate::baselines::{gemm_fp32, Int4Gemm, Int8Gemm};
use crate::quant::WAConfig;

use super::config::ModelConfig;
use super::kv_cache::KvCache;
use super::weights::WeightPack;

pub const LINEAR_NAMES: [&str; 7] = ["wq", "wk", "wv", "wo", "gate", "up", "down"];

/// Execution backend for the block linears.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Backend {
    /// fp32 GEMM ("FP16" row of Fig. 6)
    Fp32,
    /// padded INT8 GEMM ("SmoothQuant W8A8" row)
    Int8,
    /// padded INT4 GEMM ("CUTLASS W4A4" row)
    Int4,
    /// the ABQ engine at an arbitrary WqAp config
    Abq(WAConfig),
}

/// One projection, prepared for its backend.
pub enum LinearOp {
    Fp32 { w: Vec<f32>, out_f: usize, in_f: usize },
    Int8(Int8Gemm),
    Int4(Int4Gemm),
    Abq(QuantizedLinear),
}

impl LinearOp {
    pub fn forward(&self, x: &[f32], tokens: usize) -> Vec<f32> {
        match self {
            LinearOp::Fp32 { w, out_f, in_f } => gemm_fp32(x, w, tokens, *out_f, *in_f),
            LinearOp::Int8(g) => g.forward(x, tokens),
            LinearOp::Int4(g) => g.forward(x, tokens),
            LinearOp::Abq(q) => q.forward(x, tokens, OptLevel::Auto),
        }
    }

    pub fn weight_bytes(&self) -> usize {
        match self {
            LinearOp::Fp32 { w, .. } => w.len() * 4,
            LinearOp::Int8(g) => g.weight_bytes(),
            LinearOp::Int4(g) => g.weight_bytes(),
            LinearOp::Abq(q) => q.weight_bytes(),
        }
    }
}

pub struct Block {
    pub ln1: Vec<f32>,
    pub ln2: Vec<f32>,
    pub wq: LinearOp,
    pub wk: LinearOp,
    pub wv: LinearOp,
    pub wo: LinearOp,
    pub gate: LinearOp,
    pub up: LinearOp,
    pub down: LinearOp,
}

impl Block {
    pub fn linear(&self, name: &str) -> &LinearOp {
        match name {
            "wq" => &self.wq,
            "wk" => &self.wk,
            "wv" => &self.wv,
            "wo" => &self.wo,
            "gate" => &self.gate,
            "up" => &self.up,
            "down" => &self.down,
            _ => panic!("unknown linear {name}"),
        }
    }
}

pub struct Transformer {
    pub cfg: ModelConfig,
    pub backend: Backend,
    pub tok_emb: Vec<f32>,
    pub blocks: Vec<Block>,
    pub ln_f: Vec<f32>,
    /// unembedding stays fp (paper convention: embeddings not quantized)
    pub head: Vec<f32>,
}

// ---------------------------------------------------------------------------
// numerics (mirror compile/model.py)
// ---------------------------------------------------------------------------

pub fn rmsnorm(x: &[f32], g: &[f32], out: &mut [f32]) {
    let d = g.len();
    for (row, orow) in x.chunks_exact(d).zip(out.chunks_exact_mut(d)) {
        let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let r = 1.0 / (ms + 1e-5).sqrt();
        for i in 0..d {
            orow[i] = row[i] * r * g[i];
        }
    }
}

/// RoPE tables for positions `[pos0, pos0+len)`: (cos, sin) `[len, hd/2]`.
pub fn rope_tables(cfg: &ModelConfig, pos0: usize, len: usize) -> (Vec<f32>, Vec<f32>) {
    let hd = cfg.head_dim();
    let half = hd / 2;
    let mut cos = vec![0f32; len * half];
    let mut sin = vec![0f32; len * half];
    for p in 0..len {
        for i in 0..half {
            let inv = 1.0 / cfg.rope_base.powf(2.0 * i as f32 / hd as f32);
            let ang = (pos0 + p) as f32 * inv;
            cos[p * half + i] = ang.cos();
            sin[p * half + i] = ang.sin();
        }
    }
    (cos, sin)
}

/// Apply RoPE in place to `x` `[len, d_model]` seen as `[len, H, hd]`.
pub fn apply_rope(x: &mut [f32], cfg: &ModelConfig, cos: &[f32], sin: &[f32], len: usize) {
    let (d, hd) = (cfg.d_model, cfg.head_dim());
    let half = hd / 2;
    for p in 0..len {
        for h in 0..cfg.n_heads {
            let base = p * d + h * hd;
            for i in 0..half {
                let c = cos[p * half + i];
                let s = sin[p * half + i];
                let x1 = x[base + 2 * i];
                let x2 = x[base + 2 * i + 1];
                x[base + 2 * i] = x1 * c - x2 * s;
                x[base + 2 * i + 1] = x1 * s + x2 * c;
            }
        }
    }
}

fn silu(v: f32) -> f32 {
    v / (1.0 + (-v).exp())
}

fn softmax_inplace(row: &mut [f32]) {
    let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0f32;
    for v in row.iter_mut() {
        *v = (*v - mx).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in row.iter_mut() {
        *v *= inv;
    }
}

// ---------------------------------------------------------------------------
// construction
// ---------------------------------------------------------------------------

impl Transformer {
    /// Build from a weight pack. For `Backend::Abq`, calibrated codes for
    /// the config's tag are used when present in the pack (falling back to
    /// RTN from the fp weights otherwise, e.g. for sweep configs that were
    /// not calibrated offline).
    pub fn from_pack(pack: &WeightPack, cfg: ModelConfig, backend: Backend) -> Result<Self> {
        let tok_emb = pack.f32("tok_emb")?;
        let ln_f = pack.f32("ln_f")?;
        let head = pack.f32("head")?;
        let mut blocks = Vec::with_capacity(cfg.n_layers);
        for i in 0..cfg.n_layers {
            let get_lin = |name: &str| -> Result<LinearOp> {
                let wt = pack.get(&format!("blocks.{i}.{name}"))?;
                let shape = wt.shape().to_vec();
                if shape.len() != 2 {
                    bail!("linear {name} must be 2-D");
                }
                let (out_f, in_f) = (shape[0], shape[1]);
                let w = wt.as_f32()?.to_vec();
                Ok(match backend {
                    Backend::Fp32 => LinearOp::Fp32 { w, out_f, in_f },
                    Backend::Int8 => LinearOp::Int8(Int8Gemm::from_weights(&w, out_f, in_f)),
                    Backend::Int4 => LinearOp::Int4(Int4Gemm::from_weights(&w, out_f, in_f)),
                    Backend::Abq(wa) => {
                        let base = format!("q.{}.{i}.{name}", wa.tag());
                        if let Ok(codes_t) = pack.get(&format!("{base}.wq")) {
                            let codes = codes_t.as_u8()?;
                            let zw = pack.get(&format!("{base}.zw"))?.as_i32()?.to_vec();
                            let dw = pack.get(&format!("{base}.dw"))?.as_f32()?.to_vec();
                            let balance = pack
                                .get(&format!("{base}.s"))
                                .ok()
                                .and_then(|t| t.as_f32().ok().map(|v| v.to_vec()));
                            LinearOp::Abq(QuantizedLinear::from_codes(
                                codes, out_f, in_f, zw, dw, balance, wa,
                            ))
                        } else {
                            LinearOp::Abq(QuantizedLinear::from_weights_rtn(&w, out_f, in_f, wa))
                        }
                    }
                })
            };
            blocks.push(Block {
                ln1: pack.f32(&format!("blocks.{i}.ln1"))?,
                ln2: pack.f32(&format!("blocks.{i}.ln2"))?,
                wq: get_lin("wq")?,
                wk: get_lin("wk")?,
                wv: get_lin("wv")?,
                wo: get_lin("wo")?,
                gate: get_lin("gate")?,
                up: get_lin("up")?,
                down: get_lin("down")?,
            });
        }
        Ok(Transformer { cfg, backend, tok_emb, blocks, ln_f, head })
    }

    /// Random-weight model (benches at real LLaMA layer shapes).
    pub fn random(cfg: ModelConfig, backend: Backend, seed: u64) -> Self {
        let rng = std::cell::RefCell::new(crate::util::rng::SplitMix::new(seed));
        let d = cfg.d_model;
        let dense = |out_f: usize, in_f: usize| -> Vec<f32> {
            let scale = 1.0 / (in_f as f32).sqrt();
            let mut r = rng.borrow_mut();
            (0..out_f * in_f).map(|_| r.next_f32_centered() * 2.0 * scale).collect()
        };
        let tok_emb: Vec<f32> = dense(cfg.vocab, d).iter().map(|v| v * 0.08).collect();
        let head: Vec<f32> = dense(cfg.vocab, d).iter().map(|v| v * 0.08).collect();
        let mut blocks = Vec::with_capacity(cfg.n_layers);
        for _ in 0..cfg.n_layers {
            let mk = |w: Vec<f32>, out_f: usize, in_f: usize| match backend {
                Backend::Fp32 => LinearOp::Fp32 { w, out_f, in_f },
                Backend::Int8 => LinearOp::Int8(Int8Gemm::from_weights(&w, out_f, in_f)),
                Backend::Int4 => LinearOp::Int4(Int4Gemm::from_weights(&w, out_f, in_f)),
                Backend::Abq(wa) => {
                    LinearOp::Abq(QuantizedLinear::from_weights_rtn(&w, out_f, in_f, wa))
                }
            };
            blocks.push(Block {
                ln1: vec![1.0; d],
                ln2: vec![1.0; d],
                wq: mk(dense(d, d), d, d),
                wk: mk(dense(d, d), d, d),
                wv: mk(dense(d, d), d, d),
                wo: mk(dense(d, d), d, d),
                gate: mk(dense(cfg.d_ff, d), cfg.d_ff, d),
                up: mk(dense(cfg.d_ff, d), cfg.d_ff, d),
                down: mk(dense(d, cfg.d_ff), d, cfg.d_ff),
            });
        }
        Transformer { cfg, backend, tok_emb, blocks, ln_f: vec![1.0; d], head }
    }

    // -----------------------------------------------------------------------
    // forward
    // -----------------------------------------------------------------------

    fn embed(&self, tokens: &[u32]) -> Vec<f32> {
        let d = self.cfg.d_model;
        let mut x = vec![0f32; tokens.len() * d];
        for (t, &tok) in tokens.iter().enumerate() {
            let off = tok as usize * d;
            x[t * d..(t + 1) * d].copy_from_slice(&self.tok_emb[off..off + d]);
        }
        x
    }

    /// Prefill one sequence, filling `cache` and returning logits `[S, V]`.
    pub fn prefill(&self, tokens: &[u32], cache: &mut KvCache) -> Result<Vec<f32>> {
        let s_len = tokens.len();
        if s_len > cache.remaining() {
            bail!("sequence longer than KV capacity");
        }
        let (d, hd, nh) = (self.cfg.d_model, self.cfg.head_dim(), self.cfg.n_heads);
        let pos0 = cache.pos;
        let (cos, sin) = rope_tables(&self.cfg, pos0, s_len);
        let mut x = self.embed(tokens);
        let mut h = vec![0f32; s_len * d];
        let scale = 1.0 / (hd as f32).sqrt();

        for (li, blk) in self.blocks.iter().enumerate() {
            rmsnorm(&x, &blk.ln1, &mut h);
            let mut q = blk.wq.forward(&h, s_len);
            let mut k = blk.wk.forward(&h, s_len);
            let v = blk.wv.forward(&h, s_len);
            apply_rope(&mut q, &self.cfg, &cos, &sin, s_len);
            apply_rope(&mut k, &self.cfg, &cos, &sin, s_len);
            for t in 0..s_len {
                cache.write(li, pos0 + t, &k[t * d..(t + 1) * d], &v[t * d..(t + 1) * d]);
            }
            // causal attention over cache [0, pos0+t]
            let mut ctx = vec![0f32; s_len * d];
            for t in 0..s_len {
                let keys = pos0 + t + 1;
                for hh in 0..nh {
                    let qv = &q[t * d + hh * hd..t * d + (hh + 1) * hd];
                    let mut scores = vec![0f32; keys];
                    for kp in 0..keys {
                        let kr = cache.k_row(li, kp);
                        let kv = &kr[hh * hd..(hh + 1) * hd];
                        scores[kp] = qv.iter().zip(kv).map(|(a, b)| a * b).sum::<f32>() * scale;
                    }
                    softmax_inplace(&mut scores);
                    let crow = &mut ctx[t * d + hh * hd..t * d + (hh + 1) * hd];
                    for kp in 0..keys {
                        let vr = cache.v_row(li, kp);
                        let vv = &vr[hh * hd..(hh + 1) * hd];
                        let a = scores[kp];
                        for i in 0..hd {
                            crow[i] += a * vv[i];
                        }
                    }
                }
            }
            let attn_out = blk.wo.forward(&ctx, s_len);
            for i in 0..x.len() {
                x[i] += attn_out[i];
            }
            rmsnorm(&x, &blk.ln2, &mut h);
            let g = blk.gate.forward(&h, s_len);
            let u = blk.up.forward(&h, s_len);
            let act: Vec<f32> = g.iter().zip(&u).map(|(a, b)| silu(*a) * b).collect();
            let mlp_out = blk.down.forward(&act, s_len);
            for i in 0..x.len() {
                x[i] += mlp_out[i];
            }
        }
        cache.pos = pos0 + s_len;
        rmsnorm(&x.clone(), &self.ln_f, &mut x);
        Ok(gemm_fp32(&x, &self.head, s_len, self.cfg.vocab, d))
    }

    /// One decode step for a batch of sequences (linears batched over B —
    /// the GEMM-vs-GEMV axis the engine benches sweep). `tokens[i]` extends
    /// `caches[i]`. Returns logits `[B, V]`.
    pub fn decode_step(&self, tokens: &[u32], caches: &mut [&mut KvCache]) -> Result<Vec<f32>> {
        let b = tokens.len();
        if b != caches.len() {
            bail!("batch size mismatch");
        }
        let (d, hd, nh) = (self.cfg.d_model, self.cfg.head_dim(), self.cfg.n_heads);
        let scale = 1.0 / (hd as f32).sqrt();
        let mut x = self.embed(tokens);
        let mut h = vec![0f32; b * d];

        for (li, blk) in self.blocks.iter().enumerate() {
            rmsnorm(&x, &blk.ln1, &mut h);
            let mut q = blk.wq.forward(&h, b);
            let mut k = blk.wk.forward(&h, b);
            let v = blk.wv.forward(&h, b);
            // per-sequence rope at its own position
            for (bi, cache) in caches.iter().enumerate() {
                let (cos, sin) = rope_tables(&self.cfg, cache.pos, 1);
                apply_rope(&mut q[bi * d..(bi + 1) * d], &self.cfg, &cos, &sin, 1);
                apply_rope(&mut k[bi * d..(bi + 1) * d], &self.cfg, &cos, &sin, 1);
            }
            let mut ctx = vec![0f32; b * d];
            for (bi, cache) in caches.iter_mut().enumerate() {
                let pos = cache.pos;
                cache.write(li, pos, &k[bi * d..(bi + 1) * d], &v[bi * d..(bi + 1) * d]);
                let keys = pos + 1;
                for hh in 0..nh {
                    let qv = &q[bi * d + hh * hd..bi * d + (hh + 1) * hd];
                    let mut scores = vec![0f32; keys];
                    for kp in 0..keys {
                        let kr = cache.k_row(li, kp);
                        let kv = &kr[hh * hd..(hh + 1) * hd];
                        scores[kp] = qv.iter().zip(kv).map(|(a, b)| a * b).sum::<f32>() * scale;
                    }
                    softmax_inplace(&mut scores);
                    let crow = &mut ctx[bi * d + hh * hd..bi * d + (hh + 1) * hd];
                    for kp in 0..keys {
                        let vr = cache.v_row(li, kp);
                        let vv = &vr[hh * hd..(hh + 1) * hd];
                        let a = scores[kp];
                        for i in 0..hd {
                            crow[i] += a * vv[i];
                        }
                    }
                }
            }
            let attn_out = blk.wo.forward(&ctx, b);
            for i in 0..x.len() {
                x[i] += attn_out[i];
            }
            rmsnorm(&x, &blk.ln2, &mut h);
            let g = blk.gate.forward(&h, b);
            let u = blk.up.forward(&h, b);
            let act: Vec<f32> = g.iter().zip(&u).map(|(a, b)| silu(*a) * b).collect();
            let mlp_out = blk.down.forward(&act, b);
            for i in 0..x.len() {
                x[i] += mlp_out[i];
            }
        }
        for cache in caches.iter_mut() {
            cache.pos += 1;
        }
        rmsnorm(&x.clone(), &self.ln_f, &mut x);
        Ok(gemm_fp32(&x, &self.head, b, self.cfg.vocab, d))
    }

    /// Total block-weight bytes (Table 12 memory accounting).
    pub fn weight_bytes(&self) -> usize {
        let blocks: usize = self
            .blocks
            .iter()
            .map(|b| {
                LINEAR_NAMES.iter().map(|n| b.linear(n).weight_bytes()).sum::<usize>()
                    + (b.ln1.len() + b.ln2.len()) * 4
            })
            .sum();
        blocks + (self.tok_emb.len() + self.head.len() + self.ln_f.len()) * 4
    }

    /// Load the pack + manifest from an artifacts directory.
    pub fn load_artifacts(dir: &std::path::Path, backend: Backend) -> Result<Self> {
        let pack = WeightPack::load(&dir.join("weights.abqw"))?;
        let manifest = std::fs::read_to_string(dir.join("manifest.json"))
            .context("read manifest.json")?;
        let j = crate::util::json::Json::parse(&manifest)
            .map_err(|e| anyhow::anyhow!("manifest parse: {e}"))?;
        let cfg = super::config::ModelConfig {
            name: "tiny-llama",
            vocab: j.at(&["model", "vocab"]).and_then(|v| v.as_usize()).context("vocab")?,
            d_model: j.at(&["model", "d_model"]).and_then(|v| v.as_usize()).context("d_model")?,
            n_layers: j.at(&["model", "n_layers"]).and_then(|v| v.as_usize()).context("n_layers")?,
            n_heads: j.at(&["model", "n_heads"]).and_then(|v| v.as_usize()).context("n_heads")?,
            d_ff: j.at(&["model", "d_ff"]).and_then(|v| v.as_usize()).context("d_ff")?,
            max_seq: j.at(&["model", "max_seq"]).and_then(|v| v.as_usize()).context("max_seq")?,
            rope_base: j.at(&["model", "rope_base"]).and_then(|v| v.as_f64()).context("rope_base")?
                as f32,
        };
        Self::from_pack(&pack, cfg, backend)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;

    const MICRO: ModelConfig = ModelConfig {
        name: "micro",
        vocab: 32,
        d_model: 16,
        n_layers: 2,
        n_heads: 2,
        d_ff: 32,
        max_seq: 16,
        rope_base: 10000.0,
    };

    #[test]
    fn prefill_then_decode_matches_prefill_of_longer_seq() {
        // teacher-forcing consistency: prefill(t0..t3) then decode(t4)
        // must give the same final-position logits as prefill(t0..t4)
        let m = Transformer::random(MICRO, Backend::Fp32, 7);
        let toks = [1u32, 5, 9, 13, 21];
        let mut c1 = KvCache::new(&MICRO);
        let logits_full = m.prefill(&toks, &mut c1).unwrap();
        let last_full = &logits_full[4 * MICRO.vocab..5 * MICRO.vocab];

        let mut c2 = KvCache::new(&MICRO);
        m.prefill(&toks[..4], &mut c2).unwrap();
        let mut caches = [&mut c2];
        let logits_step = m.decode_step(&[toks[4]], &mut caches).unwrap();
        for (a, b) in last_full.iter().zip(&logits_step) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn batched_decode_matches_individual() {
        let m = Transformer::random(MICRO, Backend::Fp32, 3);
        let seq_a = [2u32, 4, 6];
        let seq_b = [1u32, 3];
        let mut ca = KvCache::new(&MICRO);
        let mut cb = KvCache::new(&MICRO);
        m.prefill(&seq_a, &mut ca).unwrap();
        m.prefill(&seq_b, &mut cb).unwrap();
        // batched step
        let mut ca2 = ca.clone();
        let mut cb2 = cb.clone();
        let mut batch = [&mut ca2, &mut cb2];
        let batched = m.decode_step(&[7, 8], &mut batch).unwrap();
        // individual steps
        let mut one_a = [&mut ca];
        let la = m.decode_step(&[7], &mut one_a).unwrap();
        let mut one_b = [&mut cb];
        let lb = m.decode_step(&[8], &mut one_b).unwrap();
        for i in 0..MICRO.vocab {
            assert!((batched[i] - la[i]).abs() < 1e-4);
            assert!((batched[MICRO.vocab + i] - lb[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn abq_backend_runs_and_tracks_fp() {
        let fp = Transformer::random(MICRO, Backend::Fp32, 11);
        let q8 = Transformer::random(MICRO, Backend::Abq(WAConfig::new(8, 8)), 11);
        let toks = [3u32, 7, 11, 2];
        let mut c1 = KvCache::new(&MICRO);
        let mut c2 = KvCache::new(&MICRO);
        let lf = fp.prefill(&toks, &mut c1).unwrap();
        let lq = q8.prefill(&toks, &mut c2).unwrap();
        let max_abs = lf.iter().map(|v| v.abs()).fold(0f32, f32::max);
        let max_err = lf.iter().zip(&lq).map(|(a, b)| (a - b).abs()).fold(0f32, f32::max);
        assert!(max_err / max_abs < 0.25, "w8a8 rel err {}", max_err / max_abs);
    }

    #[test]
    fn weight_bytes_compression() {
        let fp = Transformer::random(MICRO, Backend::Fp32, 1);
        let w2 = Transformer::random(MICRO, Backend::Abq(WAConfig::new(2, 8)), 1);
        assert!(w2.weight_bytes() < fp.weight_bytes() / 2);
    }
}
