//! `.abqw` weight-pack parser (format written by python `compile/aot.py`):
//!
//! ```text
//! magic  b"ABQW1\0"
//! u32    n_tensors
//! repeat n_tensors:
//!   u16   name_len, name (utf-8)
//!   u8    dtype: 0=f32 1=i32 2=u8
//!   u8    ndim
//!   u32×ndim dims
//!   data  (little-endian, C order)
//! ```
//!
//! Contains the fp weights (`tok_emb`, `blocks.i.*`, `ln_f`, `head`) plus,
//! per exported quant config, the calibrated integer codes and scales
//! (`q.<tag>.<block>.<linear>.{wq,zw,dw,s}`).

use std::collections::HashMap;
use std::io::Read;
use std::path::Path;

use anyhow::{bail, Context, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
    U8(Vec<u8>, Vec<usize>),
}

impl Tensor {
    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32(_, s) | Tensor::I32(_, s) | Tensor::U8(_, s) => s,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32(v, _) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32(v, _) => Ok(v),
            _ => bail!("tensor is not i32"),
        }
    }

    pub fn as_u8(&self) -> Result<&[u8]> {
        match self {
            Tensor::U8(v, _) => Ok(v),
            _ => bail!("tensor is not u8"),
        }
    }

    pub fn len(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// The parsed weight pack.
#[derive(Debug, Default)]
pub struct WeightPack {
    pub tensors: HashMap<String, Tensor>,
}

impl WeightPack {
    pub fn load(path: &Path) -> Result<Self> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("open weight pack {path:?}"))?;
        let mut buf = Vec::new();
        f.read_to_end(&mut buf)?;
        Self::parse(&buf)
    }

    pub fn parse(buf: &[u8]) -> Result<Self> {
        let mut pos = 0usize;
        let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
            if *pos + n > buf.len() {
                bail!("truncated weight pack at byte {}", *pos);
            }
            let s = &buf[*pos..*pos + n];
            *pos += n;
            Ok(s)
        };
        if take(&mut pos, 6)? != b"ABQW1\0" {
            bail!("bad magic");
        }
        let n_tensors = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize;
        let mut tensors = HashMap::with_capacity(n_tensors);
        for _ in 0..n_tensors {
            let name_len = u16::from_le_bytes(take(&mut pos, 2)?.try_into()?) as usize;
            let name = String::from_utf8(take(&mut pos, name_len)?.to_vec())?;
            let dtype = take(&mut pos, 1)?[0];
            let ndim = take(&mut pos, 1)?[0] as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize);
            }
            let count: usize = shape.iter().product();
            let t = match dtype {
                0 => {
                    let raw = take(&mut pos, count * 4)?;
                    let v = raw
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                        .collect();
                    Tensor::F32(v, shape)
                }
                1 => {
                    let raw = take(&mut pos, count * 4)?;
                    let v = raw
                        .chunks_exact(4)
                        .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                        .collect();
                    Tensor::I32(v, shape)
                }
                2 => Tensor::U8(take(&mut pos, count)?.to_vec(), shape),
                d => bail!("unknown dtype {d} for {name}"),
            };
            tensors.insert(name, t);
        }
        Ok(WeightPack { tensors })
    }

    /// Serialize to the `.abqw` wire format (tensors in sorted name
    /// order so the bytes are deterministic for a given content).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut b: Vec<u8> = b"ABQW1\0".to_vec();
        b.extend((self.tensors.len() as u32).to_le_bytes());
        let mut names: Vec<&String> = self.tensors.keys().collect();
        names.sort();
        for name in names {
            let t = &self.tensors[name];
            b.extend((name.len() as u16).to_le_bytes());
            b.extend(name.as_bytes());
            let (dtype, shape): (u8, &[usize]) = match t {
                Tensor::F32(_, s) => (0, s),
                Tensor::I32(_, s) => (1, s),
                Tensor::U8(_, s) => (2, s),
            };
            b.push(dtype);
            b.push(shape.len() as u8);
            for &d in shape {
                b.extend((d as u32).to_le_bytes());
            }
            match t {
                Tensor::F32(v, _) => v.iter().for_each(|x| b.extend(x.to_le_bytes())),
                Tensor::I32(v, _) => v.iter().for_each(|x| b.extend(x.to_le_bytes())),
                Tensor::U8(v, _) => b.extend(v),
            }
        }
        b
    }

    /// Write the pack to disk in the `.abqw` format (what
    /// `WeightPack::load` reads back).
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_bytes())
            .with_context(|| format!("write weight pack {path:?}"))
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .with_context(|| format!("missing tensor '{name}'"))
    }

    pub fn f32(&self, name: &str) -> Result<Vec<f32>> {
        Ok(self.get(name)?.as_f32()?.to_vec())
    }

    /// Names of quant configs present (tags like `w2sa8`).
    pub fn quant_tags(&self) -> Vec<String> {
        let mut tags: Vec<String> = self
            .tensors
            .keys()
            .filter_map(|k| k.strip_prefix("q."))
            .filter_map(|k| k.split('.').next())
            .map(|s| s.to_string())
            .collect();
        tags.sort();
        tags.dedup();
        tags
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_pack() -> Vec<u8> {
        let mut b: Vec<u8> = b"ABQW1\0".to_vec();
        b.extend((2u32).to_le_bytes());
        // f32 tensor "a" shape [2,2]
        b.extend((1u16).to_le_bytes());
        b.extend(b"a");
        b.push(0);
        b.push(2);
        b.extend((2u32).to_le_bytes());
        b.extend((2u32).to_le_bytes());
        for v in [1.0f32, 2.0, 3.0, 4.5] {
            b.extend(v.to_le_bytes());
        }
        // u8 tensor "q.w2sa8.0.wq" shape [3]
        let name = b"q.w2sa8.0.wq";
        b.extend((name.len() as u16).to_le_bytes());
        b.extend(name);
        b.push(2);
        b.push(1);
        b.extend((3u32).to_le_bytes());
        b.extend([7u8, 8, 9]);
        b
    }

    #[test]
    fn parse_sample() {
        let p = WeightPack::parse(&sample_pack()).unwrap();
        assert_eq!(p.get("a").unwrap().as_f32().unwrap(), &[1.0, 2.0, 3.0, 4.5]);
        assert_eq!(p.get("a").unwrap().shape(), &[2, 2]);
        assert_eq!(p.get("q.w2sa8.0.wq").unwrap().as_u8().unwrap(), &[7, 8, 9]);
        assert_eq!(p.quant_tags(), vec!["w2sa8".to_string()]);
    }

    #[test]
    fn save_roundtrips_every_dtype() {
        let mut p = WeightPack::default();
        p.tensors.insert("f".into(), Tensor::F32(vec![1.5, -2.25, 0.0], vec![3]));
        p.tensors.insert("i".into(), Tensor::I32(vec![-7, 0, 1 << 20], vec![3, 1]));
        p.tensors.insert("u".into(), Tensor::U8(vec![0, 255, 17, 3], vec![2, 2]));
        let back = WeightPack::parse(&p.to_bytes()).unwrap();
        assert_eq!(back.tensors.len(), 3);
        assert_eq!(back.get("f").unwrap(), p.get("f").unwrap());
        assert_eq!(back.get("i").unwrap(), p.get("i").unwrap());
        assert_eq!(back.get("u").unwrap(), p.get("u").unwrap());
        // deterministic bytes
        assert_eq!(p.to_bytes(), back.to_bytes());
    }

    #[test]
    fn rejects_bad_magic_and_truncation() {
        assert!(WeightPack::parse(b"NOPE").is_err());
        let mut good = sample_pack();
        good.truncate(good.len() - 2);
        assert!(WeightPack::parse(&good).is_err());
    }
}
