//! `.abqw` weight-pack parser (format written by python `compile/aot.py`):
//!
//! ```text
//! magic  b"ABQW1\0"
//! u32    n_tensors
//! repeat n_tensors:
//!   u16   name_len, name (utf-8)
//!   u8    dtype: 0=f32 1=i32 2=u8
//!   u8    ndim
//!   u32×ndim dims
//!   data  (little-endian, C order)
//! ```
//!
//! Contains the fp weights (`tok_emb`, `blocks.i.*`, `ln_f`, `head`) plus,
//! per exported quant config, the calibrated integer codes and scales
//! (`q.<tag>.<block>.<linear>.{wq,zw,dw,s}`).

use std::borrow::Cow;
use std::collections::HashMap;
use std::io::Read;
use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::runtime::mmap::MappedBytes;

#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
    U8(Vec<u8>, Vec<usize>),
}

impl Tensor {
    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32(_, s) | Tensor::I32(_, s) | Tensor::U8(_, s) => s,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32(v, _) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32(v, _) => Ok(v),
            _ => bail!("tensor is not i32"),
        }
    }

    pub fn as_u8(&self) -> Result<&[u8]> {
        match self {
            Tensor::U8(v, _) => Ok(v),
            _ => bail!("tensor is not u8"),
        }
    }

    pub fn len(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// The parsed weight pack.
#[derive(Debug, Default)]
pub struct WeightPack {
    pub tensors: HashMap<String, Tensor>,
}

impl WeightPack {
    pub fn load(path: &Path) -> Result<Self> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("open weight pack {path:?}"))?;
        let mut buf = Vec::new();
        f.read_to_end(&mut buf)?;
        Self::parse(&buf)
    }

    pub fn parse(buf: &[u8]) -> Result<Self> {
        let mut pos = 0usize;
        let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
            if *pos + n > buf.len() {
                bail!("truncated weight pack at byte {}", *pos);
            }
            let s = &buf[*pos..*pos + n];
            *pos += n;
            Ok(s)
        };
        if take(&mut pos, 6)? != b"ABQW1\0" {
            bail!("bad magic");
        }
        let n_tensors = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize;
        let mut tensors = HashMap::with_capacity(n_tensors);
        for _ in 0..n_tensors {
            let name_len = u16::from_le_bytes(take(&mut pos, 2)?.try_into()?) as usize;
            let name = String::from_utf8(take(&mut pos, name_len)?.to_vec())?;
            let dtype = take(&mut pos, 1)?[0];
            let ndim = take(&mut pos, 1)?[0] as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize);
            }
            let count: usize = shape.iter().product();
            let t = match dtype {
                0 => {
                    let raw = take(&mut pos, count * 4)?;
                    let v = raw
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                        .collect();
                    Tensor::F32(v, shape)
                }
                1 => {
                    let raw = take(&mut pos, count * 4)?;
                    let v = raw
                        .chunks_exact(4)
                        .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                        .collect();
                    Tensor::I32(v, shape)
                }
                2 => Tensor::U8(take(&mut pos, count)?.to_vec(), shape),
                d => bail!("unknown dtype {d} for {name}"),
            };
            tensors.insert(name, t);
        }
        Ok(WeightPack { tensors })
    }

    /// Serialize to the `.abqw` wire format (tensors in sorted name
    /// order so the bytes are deterministic for a given content).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut b: Vec<u8> = b"ABQW1\0".to_vec();
        b.extend((self.tensors.len() as u32).to_le_bytes());
        let mut names: Vec<&String> = self.tensors.keys().collect();
        names.sort();
        for name in names {
            let t = &self.tensors[name];
            b.extend((name.len() as u16).to_le_bytes());
            b.extend(name.as_bytes());
            let (dtype, shape): (u8, &[usize]) = match t {
                Tensor::F32(_, s) => (0, s),
                Tensor::I32(_, s) => (1, s),
                Tensor::U8(_, s) => (2, s),
            };
            b.push(dtype);
            b.push(shape.len() as u8);
            for &d in shape {
                b.extend((d as u32).to_le_bytes());
            }
            match t {
                Tensor::F32(v, _) => v.iter().for_each(|x| b.extend(x.to_le_bytes())),
                Tensor::I32(v, _) => v.iter().for_each(|x| b.extend(x.to_le_bytes())),
                Tensor::U8(v, _) => b.extend(v),
            }
        }
        b
    }

    /// Write the pack to disk in the `.abqw` format (what
    /// `WeightPack::load` reads back).
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_bytes())
            .with_context(|| format!("write weight pack {path:?}"))
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .with_context(|| format!("missing tensor '{name}'"))
    }

    pub fn f32(&self, name: &str) -> Result<Vec<f32>> {
        Ok(self.get(name)?.as_f32()?.to_vec())
    }

    /// Names of quant configs present (tags like `w2sa8`).
    pub fn quant_tags(&self) -> Vec<String> {
        let mut tags: Vec<String> = self
            .tensors
            .keys()
            .filter_map(|k| k.strip_prefix("q."))
            .filter_map(|k| k.split('.').next())
            .map(|s| s.to_string())
            .collect();
        tags.sort();
        tags.dedup();
        tags
    }
}

/// Raw dtype tag of an indexed tensor (mirrors the wire encoding).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum RawDtype {
    F32,
    I32,
    U8,
}

#[derive(Clone, Debug)]
struct RawEntry {
    dtype: RawDtype,
    shape: Vec<usize>,
    /// byte offset of the tensor data inside the backing buffer
    offset: usize,
}

/// A zero-copy view over an `.abqw` buffer: the header is indexed once
/// (name → dtype/shape/offset), tensor data stays in the backing
/// [`MappedBytes`] and is borrowed on access.
///
/// The wire format does not align tensor data, so `f32`/`i32` accessors
/// return [`Cow`]: a borrowed slice when the data happens to sit on a
/// 4-byte boundary of the mapping, a decoded copy otherwise. `u8`
/// tensors always borrow. Cloning a `PackView` is cheap on the data side
/// (the `Arc<MappedBytes>` is shared; only the index is copied), so one
/// mapping can back any number of replica preparations — the lifetime
/// contract is documented in `docs/ENGINE_API.md` §mmap'd artifacts.
#[derive(Clone, Debug)]
pub struct PackView {
    bytes: Arc<MappedBytes>,
    entries: HashMap<String, RawEntry>,
}

impl PackView {
    /// mmap `path` (heap-read fallback off Linux) and index its header.
    pub fn open(path: &Path) -> Result<Self> {
        let bytes = Arc::new(MappedBytes::open(path)?);
        Self::index(bytes).with_context(|| format!("index weight pack {path:?}"))
    }

    /// Index an in-memory buffer (tests; in-memory packs).
    pub fn from_vec(buf: Vec<u8>) -> Result<Self> {
        Self::index(Arc::new(MappedBytes::from_vec(buf)))
    }

    fn index(bytes: Arc<MappedBytes>) -> Result<Self> {
        let buf: &[u8] = &bytes;
        let mut pos = 0usize;
        let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
            if *pos + n > buf.len() {
                bail!("truncated weight pack at byte {}", *pos);
            }
            let s = &buf[*pos..*pos + n];
            *pos += n;
            Ok(s)
        };
        if take(&mut pos, 6)? != b"ABQW1\0" {
            bail!("bad magic");
        }
        let n_tensors = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize;
        let mut entries = HashMap::with_capacity(n_tensors);
        for _ in 0..n_tensors {
            let name_len = u16::from_le_bytes(take(&mut pos, 2)?.try_into()?) as usize;
            let name = String::from_utf8(take(&mut pos, name_len)?.to_vec())?;
            let dtype = match take(&mut pos, 1)?[0] {
                0 => RawDtype::F32,
                1 => RawDtype::I32,
                2 => RawDtype::U8,
                d => bail!("unknown dtype {d} for {name}"),
            };
            let ndim = take(&mut pos, 1)?[0] as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize);
            }
            let count: usize = shape.iter().product();
            let elem = match dtype {
                RawDtype::F32 | RawDtype::I32 => 4,
                RawDtype::U8 => 1,
            };
            let offset = pos;
            take(&mut pos, count * elem)?; // bounds-check the data region
            entries.insert(name, RawEntry { dtype, shape, offset });
        }
        Ok(PackView { bytes, entries })
    }

    fn entry(&self, name: &str) -> Result<&RawEntry> {
        self.entries.get(name).with_context(|| format!("missing tensor '{name}'"))
    }

    pub fn contains(&self, name: &str) -> bool {
        self.entries.contains_key(name)
    }

    pub fn shape(&self, name: &str) -> Result<&[usize]> {
        Ok(&self.entry(name)?.shape)
    }

    /// Borrow the tensor's f32 data when 4-byte aligned in the backing
    /// buffer; decode a copy otherwise.
    pub fn f32(&self, name: &str) -> Result<Cow<'_, [f32]>> {
        let e = self.entry(name)?;
        if e.dtype != RawDtype::F32 {
            bail!("tensor '{name}' is not f32");
        }
        Ok(self.word_slice::<f32>(e, |c| f32::from_le_bytes(c.try_into().unwrap())))
    }

    /// Borrow the tensor's i32 data when aligned; decode otherwise.
    pub fn i32v(&self, name: &str) -> Result<Cow<'_, [i32]>> {
        let e = self.entry(name)?;
        if e.dtype != RawDtype::I32 {
            bail!("tensor '{name}' is not i32");
        }
        Ok(self.word_slice::<i32>(e, |c| i32::from_le_bytes(c.try_into().unwrap())))
    }

    /// u8 data always borrows straight out of the mapping.
    pub fn u8v(&self, name: &str) -> Result<&[u8]> {
        let e = self.entry(name)?;
        if e.dtype != RawDtype::U8 {
            bail!("tensor '{name}' is not u8");
        }
        let count: usize = e.shape.iter().product();
        Ok(&self.bytes[e.offset..e.offset + count])
    }

    fn word_slice<T: Copy>(&self, e: &RawEntry, decode: fn(&[u8]) -> T) -> Cow<'_, [T]> {
        let count: usize = e.shape.iter().product();
        let raw = &self.bytes[e.offset..e.offset + count * 4];
        let ptr = raw.as_ptr();
        if (ptr as usize) % std::mem::align_of::<T>() == 0 {
            // Safety: alignment just checked, length bounds-checked at
            // index time, every bit pattern is a valid f32/i32, and the
            // borrow is tied to `&self`, which keeps the Arc alive.
            Cow::Borrowed(unsafe { std::slice::from_raw_parts(ptr as *const T, count) })
        } else {
            Cow::Owned(raw.chunks_exact(4).map(decode).collect())
        }
    }

    /// Names of quant configs present (tags like `w2sa8`).
    pub fn quant_tags(&self) -> Vec<String> {
        let mut tags: Vec<String> = self
            .entries
            .keys()
            .filter_map(|k| k.strip_prefix("q."))
            .filter_map(|k| k.split('.').next())
            .map(|s| s.to_string())
            .collect();
        tags.sort();
        tags.dedup();
        tags
    }

    /// Total bytes of the backing buffer (the whole `.abqw` file).
    pub fn mapped_len(&self) -> usize {
        self.bytes.len()
    }

    /// Whether the backing buffer is a kernel mapping (shared page-cache
    /// pages) rather than a private heap read.
    pub fn is_mapped(&self) -> bool {
        self.bytes.is_mapped()
    }

    /// Another handle onto the same mapping (Arc clone + index copy).
    pub fn share(&self) -> Self {
        self.clone()
    }
}

/// Either an owned [`WeightPack`] or a zero-copy [`PackView`] — the one
/// argument type `Transformer::from_source_corrected` and backend
/// `prepare` hooks consume, so model construction is identical for
/// in-memory packs (calibration, tests) and mmap'd artifacts (serving).
#[derive(Clone, Copy)]
pub enum PackSource<'a> {
    Owned(&'a WeightPack),
    View(&'a PackView),
}

impl<'a> PackSource<'a> {
    pub fn contains(&self, name: &str) -> bool {
        match self {
            PackSource::Owned(p) => p.tensors.contains_key(name),
            PackSource::View(v) => v.contains(name),
        }
    }

    pub fn shape(&self, name: &str) -> Result<Vec<usize>> {
        match self {
            PackSource::Owned(p) => Ok(p.get(name)?.shape().to_vec()),
            PackSource::View(v) => Ok(v.shape(name)?.to_vec()),
        }
    }

    pub fn f32(&self, name: &str) -> Result<Cow<'a, [f32]>> {
        match self {
            PackSource::Owned(p) => Ok(Cow::Borrowed(p.get(name)?.as_f32()?)),
            PackSource::View(v) => v.f32(name),
        }
    }

    pub fn i32v(&self, name: &str) -> Result<Cow<'a, [i32]>> {
        match self {
            PackSource::Owned(p) => Ok(Cow::Borrowed(p.get(name)?.as_i32()?)),
            PackSource::View(v) => v.i32v(name),
        }
    }

    pub fn u8v(&self, name: &str) -> Result<&'a [u8]> {
        match self {
            PackSource::Owned(p) => p.get(name)?.as_u8(),
            PackSource::View(v) => v.u8v(name),
        }
    }

    pub fn quant_tags(&self) -> Vec<String> {
        match self {
            PackSource::Owned(p) => p.quant_tags(),
            PackSource::View(v) => v.quant_tags(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_pack() -> Vec<u8> {
        let mut b: Vec<u8> = b"ABQW1\0".to_vec();
        b.extend((2u32).to_le_bytes());
        // f32 tensor "a" shape [2,2]
        b.extend((1u16).to_le_bytes());
        b.extend(b"a");
        b.push(0);
        b.push(2);
        b.extend((2u32).to_le_bytes());
        b.extend((2u32).to_le_bytes());
        for v in [1.0f32, 2.0, 3.0, 4.5] {
            b.extend(v.to_le_bytes());
        }
        // u8 tensor "q.w2sa8.0.wq" shape [3]
        let name = b"q.w2sa8.0.wq";
        b.extend((name.len() as u16).to_le_bytes());
        b.extend(name);
        b.push(2);
        b.push(1);
        b.extend((3u32).to_le_bytes());
        b.extend([7u8, 8, 9]);
        b
    }

    #[test]
    fn parse_sample() {
        let p = WeightPack::parse(&sample_pack()).unwrap();
        assert_eq!(p.get("a").unwrap().as_f32().unwrap(), &[1.0, 2.0, 3.0, 4.5]);
        assert_eq!(p.get("a").unwrap().shape(), &[2, 2]);
        assert_eq!(p.get("q.w2sa8.0.wq").unwrap().as_u8().unwrap(), &[7, 8, 9]);
        assert_eq!(p.quant_tags(), vec!["w2sa8".to_string()]);
    }

    #[test]
    fn save_roundtrips_every_dtype() {
        let mut p = WeightPack::default();
        p.tensors.insert("f".into(), Tensor::F32(vec![1.5, -2.25, 0.0], vec![3]));
        p.tensors.insert("i".into(), Tensor::I32(vec![-7, 0, 1 << 20], vec![3, 1]));
        p.tensors.insert("u".into(), Tensor::U8(vec![0, 255, 17, 3], vec![2, 2]));
        let back = WeightPack::parse(&p.to_bytes()).unwrap();
        assert_eq!(back.tensors.len(), 3);
        assert_eq!(back.get("f").unwrap(), p.get("f").unwrap());
        assert_eq!(back.get("i").unwrap(), p.get("i").unwrap());
        assert_eq!(back.get("u").unwrap(), p.get("u").unwrap());
        // deterministic bytes
        assert_eq!(p.to_bytes(), back.to_bytes());
    }

    #[test]
    fn rejects_bad_magic_and_truncation() {
        assert!(WeightPack::parse(b"NOPE").is_err());
        let mut good = sample_pack();
        good.truncate(good.len() - 2);
        assert!(WeightPack::parse(&good).is_err());
    }

    #[test]
    fn view_matches_owned_parse() {
        let bytes = sample_pack();
        let owned = WeightPack::parse(&bytes).unwrap();
        let view = PackView::from_vec(bytes).unwrap();
        assert_eq!(&*view.f32("a").unwrap(), owned.get("a").unwrap().as_f32().unwrap());
        assert_eq!(view.shape("a").unwrap(), owned.get("a").unwrap().shape());
        assert_eq!(
            view.u8v("q.w2sa8.0.wq").unwrap(),
            owned.get("q.w2sa8.0.wq").unwrap().as_u8().unwrap()
        );
        assert_eq!(view.quant_tags(), owned.quant_tags());
        assert!(view.contains("a") && !view.contains("nope"));
        assert!(view.f32("q.w2sa8.0.wq").is_err(), "dtype mismatch must error");
    }

    #[test]
    fn view_decodes_misaligned_words_correctly() {
        // Every dtype through the view must match the owned parse even
        // when the unaligned wire layout forces the Cow::Owned path —
        // exercised with name lengths that shift data off 4-byte
        // boundaries.
        let mut p = WeightPack::default();
        p.tensors.insert("x".into(), Tensor::F32(vec![0.5, -1.25, 3.75], vec![3]));
        p.tensors.insert("yy".into(), Tensor::I32(vec![-9, 1 << 24], vec![2]));
        p.tensors.insert("zzz".into(), Tensor::U8(vec![3, 1, 4, 1, 5], vec![5]));
        let view = PackView::from_vec(p.to_bytes()).unwrap();
        assert_eq!(&*view.f32("x").unwrap(), p.get("x").unwrap().as_f32().unwrap());
        assert_eq!(&*view.i32v("yy").unwrap(), p.get("yy").unwrap().as_i32().unwrap());
        assert_eq!(view.u8v("zzz").unwrap(), p.get("zzz").unwrap().as_u8().unwrap());
    }

    #[test]
    fn view_open_maps_file_and_shares() {
        let dir = std::env::temp_dir().join("abq_packview_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("sample.abqw");
        std::fs::write(&path, sample_pack()).unwrap();
        let view = PackView::open(&path).unwrap();
        #[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
        assert!(view.is_mapped());
        let twin = view.share();
        assert_eq!(&*twin.f32("a").unwrap(), &*view.f32("a").unwrap());
        assert_eq!(twin.mapped_len(), view.mapped_len());
    }

    #[test]
    fn pack_source_unifies_owned_and_view() {
        let bytes = sample_pack();
        let owned = WeightPack::parse(&bytes).unwrap();
        let view = PackView::from_vec(bytes).unwrap();
        for src in [PackSource::Owned(&owned), PackSource::View(&view)] {
            assert_eq!(&*src.f32("a").unwrap(), &[1.0, 2.0, 3.0, 4.5]);
            assert_eq!(src.shape("a").unwrap(), vec![2, 2]);
            assert_eq!(src.u8v("q.w2sa8.0.wq").unwrap(), &[7, 8, 9]);
            assert_eq!(src.quant_tags(), vec!["w2sa8".to_string()]);
            assert!(src.contains("a") && !src.contains("nope"));
        }
    }
}
