//! Model substrate: architecture configs and the registry of known
//! families (`zoo`), weight loading, the rust-native transformer over
//! pluggable GEMM backends, KV cache and sampling (DESIGN.md §5).

pub mod config;
pub mod kv_cache;
pub mod kv_pool;
pub mod sampler;
pub mod transformer;
pub mod weights;
pub mod zoo;

pub use config::{Activation, ArchVariant, ModelConfig, Norm, LLAMA_13B, LLAMA_30B, LLAMA_7B, TINY};
pub use kv_cache::{KvCache, KvStore};
pub use kv_pool::{BlockRef, KvCacheConfig, KvPool, KvPoolStatus, PagedKvCache};
pub use sampler::{argmax, log_prob, Sampler, Sampling};
pub use transformer::{Block, BlockTap, BlockTrace, ForwardScratch, Transformer, LINEAR_NAMES};
pub use weights::{PackSource, PackView, Tensor, WeightPack};
