//! Runtime artifacts: the PJRT executor for AOT HLO artifacts (the jax
//! L2 model with the pallas L1 kernel lowered in — see
//! /opt/xla-example/README.md for the HLO-text interchange rationale),
//! plus the on-disk artifact formats shared with the native path: the
//! artifact manifest grammar and the `.abqs` prefix session files.

// The manifest grammar (artifacts, quant configs, calibration
// corrections) is shared with the pure-Rust native path, so it compiles
// unconditionally; only the executor binds to xla-rs.
pub mod artifacts;
#[cfg(feature = "pjrt")]
pub mod engine;
pub mod mmap;
pub mod session;

pub use artifacts::{ArtifactManifest, CorrectionEntry, InputKind};
pub use mmap::MappedBytes;
pub use session::{SessionFile, SessionFingerprint};
#[cfg(feature = "pjrt")]
pub use engine::{KvState, PjrtEngine, Program};

/// Quick health check used by `abq-llm info`.
#[cfg(feature = "pjrt")]
pub fn pjrt_cpu_ok() -> bool {
    xla::PjRtClient::cpu().is_ok()
}
