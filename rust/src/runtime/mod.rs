//! PJRT runtime: load + execute the AOT HLO artifacts (the jax L2 model
//! with the pallas L1 kernel lowered in). See /opt/xla-example/README.md
//! for the HLO-text interchange rationale.

pub mod artifacts;
pub mod engine;

pub use artifacts::{ArtifactManifest, InputKind};
pub use engine::{KvState, PjrtEngine, Program};

/// Quick health check used by `abq-llm info`.
pub fn pjrt_cpu_ok() -> bool {
    xla::PjRtClient::cpu().is_ok()
}
