//! Artifact manifest parsing (`artifacts/manifest.json`, written by
//! `python/compile/aot.py`) and the input-name grammar mapping manifest
//! input names to weight-pack tensors / runtime values:
//!
//!   `params:<pack name>`   — fp weight tensor (static)
//!   `qstate:<i>.<lin>.<f>` — quantized code/scale tensor (static); maps to
//!                            pack tensor `q.<tag>.<i>.<lin>.<f>`
//!   `tokens`               — token ids (dynamic)
//!   `kv:<layer>.<0|1>`     — KV cache array (dynamic, device-chained)
//!   `pos`                  — decode position scalar (dynamic)

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    pub path: PathBuf,
    pub inputs: Vec<String>,
}

#[derive(Clone, Debug)]
pub struct QuantConfigEntry {
    pub name: String,
    pub tag: String,
    pub w_bits: u8,
    pub w_planes: usize,
    pub a_bits: u8,
    pub balanced: bool,
}

#[derive(Clone, Debug)]
pub struct ArtifactManifest {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub prefill_seq: usize,
    pub decode_batch: usize,
    pub fp_ppl: f64,
    pub quant_configs: Vec<QuantConfigEntry>,
    pub artifacts: Vec<ArtifactEntry>,
}

impl ArtifactManifest {
    pub fn from_json(j: &Json, dir: &Path) -> Result<Self> {
        let need = |path: &[&str]| -> Result<f64> {
            j.at(path)
                .and_then(|v| v.as_f64())
                .with_context(|| format!("manifest missing {path:?}"))
        };
        let mut artifacts = Vec::new();
        if let Some(arr) = j.get("artifacts").and_then(|a| a.as_arr()) {
            for e in arr {
                let name = e
                    .get("name")
                    .and_then(|v| v.as_str())
                    .context("artifact name")?
                    .to_string();
                let rel = e.get("path").and_then(|v| v.as_str()).context("artifact path")?;
                let inputs = e
                    .get("inputs")
                    .and_then(|v| v.as_arr())
                    .context("artifact inputs")?
                    .iter()
                    .map(|i| i.as_str().unwrap_or_default().to_string())
                    .collect();
                artifacts.push(ArtifactEntry { name, path: dir.join(rel), inputs });
            }
        }
        let mut quant_configs = Vec::new();
        if let Some(arr) = j.get("quant_configs").and_then(|a| a.as_arr()) {
            for e in arr {
                quant_configs.push(QuantConfigEntry {
                    name: e.get("name").and_then(|v| v.as_str()).unwrap_or("").to_string(),
                    tag: e.get("tag").and_then(|v| v.as_str()).unwrap_or("").to_string(),
                    w_bits: e.get("w_bits").and_then(|v| v.as_f64()).unwrap_or(0.0) as u8,
                    w_planes: e.get("w_planes").and_then(|v| v.as_usize()).unwrap_or(0),
                    a_bits: e.get("a_bits").and_then(|v| v.as_f64()).unwrap_or(0.0) as u8,
                    balanced: e.get("balanced").and_then(|v| v.as_bool()).unwrap_or(false),
                });
            }
        }
        Ok(ArtifactManifest {
            vocab: need(&["model", "vocab"])? as usize,
            d_model: need(&["model", "d_model"])? as usize,
            n_layers: need(&["model", "n_layers"])? as usize,
            n_heads: need(&["model", "n_heads"])? as usize,
            d_ff: need(&["model", "d_ff"])? as usize,
            max_seq: need(&["model", "max_seq"])? as usize,
            prefill_seq: need(&["prefill_seq"])? as usize,
            decode_batch: need(&["decode_batch"])? as usize,
            fp_ppl: need(&["fp_ppl"]).unwrap_or(0.0),
            quant_configs,
            artifacts,
        })
    }

    /// Which quant tag an artifact name refers to (e.g. `model_w2sa8_decode`
    /// → `w2sa8`); fp16 artifacts return None.
    pub fn tag_of_artifact(name: &str) -> Option<&str> {
        let rest = name.strip_prefix("model_")?;
        let tag = rest.split('_').next()?;
        if tag == "fp16" {
            None
        } else {
            Some(tag)
        }
    }
}

/// Classified artifact input.
#[derive(Clone, Debug, PartialEq)]
pub enum InputKind {
    Param { pack_name: String },
    QState { pack_name: String },
    Tokens { shape: Vec<usize> },
    Kv { shape: Vec<usize> },
    Pos,
}

/// Classify one manifest input name for an artifact. The artifact's quant
/// tag is inferred from the surrounding artifact name at program load, so
/// `qstate:` names get resolved with `resolve_qstate_tag` first; here we
/// thread the tag through the manifest-driven loader.
pub fn input_spec_with_tag(
    input: &str,
    m: &ArtifactManifest,
    tag: Option<&str>,
    is_prefill: bool,
) -> Result<InputKind> {
    if let Some(rest) = input.strip_prefix("params:") {
        return Ok(InputKind::Param { pack_name: rest.to_string() });
    }
    if let Some(rest) = input.strip_prefix("qstate:") {
        let tag = tag.ok_or_else(|| anyhow!("qstate input in fp16 artifact: {input}"))?;
        return Ok(InputKind::QState { pack_name: format!("q.{tag}.{rest}") });
    }
    match input {
        "tokens" => {
            let shape = if is_prefill {
                vec![1, m.prefill_seq]
            } else {
                vec![m.decode_batch, 1]
            };
            Ok(InputKind::Tokens { shape })
        }
        "pos" => Ok(InputKind::Pos),
        other => {
            if other.strip_prefix("kv:").is_some() {
                Ok(InputKind::Kv {
                    shape: vec![
                        m.decode_batch,
                        m.max_seq,
                        m.n_heads,
                        m.d_model / m.n_heads,
                    ],
                })
            } else {
                bail!("unknown artifact input '{other}'")
            }
        }
    }
}

/// Convenience used by `engine.rs`: infer tag/prefill-ness by scanning the
/// manifest for the artifact that lists this exact input string. The
/// engine resolves per-artifact, so this thin wrapper keeps its call sites
/// simple — it requires the input string to be unambiguous, which holds
/// for the artifacts aot.py emits (qstate names embed nothing fp16).
pub fn input_spec(input: &str, m: &ArtifactManifest) -> Result<InputKind> {
    for art in &m.artifacts {
        if art.inputs.iter().any(|i| i == input) {
            let tag = ArtifactManifest::tag_of_artifact(&art.name);
            let is_prefill = art.name.ends_with("prefill");
            return input_spec_with_tag(input, m, tag, is_prefill);
        }
    }
    bail!("input '{input}' not found in any artifact")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> ArtifactManifest {
        let j = Json::parse(
            r#"{
            "model": {"vocab": 512, "d_model": 256, "n_layers": 4,
                      "n_heads": 8, "d_ff": 704, "max_seq": 256,
                      "rope_base": 10000.0},
            "prefill_seq": 128, "decode_batch": 1, "fp_ppl": 10.0,
            "quant_configs": [{"name": "w2*a8", "tag": "w2sa8",
                               "w_bits": 2, "w_planes": 3, "a_bits": 8,
                               "balanced": true}],
            "artifacts": [
              {"name": "model_fp16_prefill", "path": "a.hlo.txt",
               "inputs": ["params:tok_emb", "tokens"]},
              {"name": "model_w2sa8_decode", "path": "b.hlo.txt",
               "inputs": ["params:tok_emb", "qstate:0.down.wq",
                          "tokens", "kv:0.0", "pos"]}
            ]
        }"#,
        )
        .unwrap();
        ArtifactManifest::from_json(&j, Path::new("/tmp/art")).unwrap()
    }

    #[test]
    fn parses_model_dims() {
        let m = manifest();
        assert_eq!(m.vocab, 512);
        assert_eq!(m.n_layers, 4);
        assert_eq!(m.artifacts.len(), 2);
        assert_eq!(m.quant_configs[0].w_planes, 3);
    }

    #[test]
    fn classifies_inputs() {
        let m = manifest();
        assert_eq!(
            input_spec("params:tok_emb", &m).unwrap(),
            InputKind::Param { pack_name: "tok_emb".into() }
        );
        assert_eq!(
            input_spec("qstate:0.down.wq", &m).unwrap(),
            InputKind::QState { pack_name: "q.w2sa8.0.down.wq".into() }
        );
        assert!(matches!(input_spec("kv:0.0", &m).unwrap(), InputKind::Kv { .. }));
        assert_eq!(input_spec("pos", &m).unwrap(), InputKind::Pos);
        // tokens in the fp16 prefill artifact → prefill shape
        assert_eq!(
            input_spec("tokens", &m).unwrap(),
            InputKind::Tokens { shape: vec![1, 128] }
        );
    }

    #[test]
    fn tag_inference() {
        assert_eq!(ArtifactManifest::tag_of_artifact("model_fp16_prefill"), None);
        assert_eq!(
            ArtifactManifest::tag_of_artifact("model_w2sa8_decode"),
            Some("w2sa8")
        );
    }
}
