//! Artifact manifest parsing (`artifacts/manifest.json`, written by
//! `python/compile/aot.py`) and the input-name grammar mapping manifest
//! input names to weight-pack tensors / runtime values:
//!
//!   `params:<pack name>`   — fp weight tensor (static)
//!   `qstate:<i>.<lin>.<f>` — quantized code/scale tensor (static); maps to
//!                            pack tensor `q.<tag>.<i>.<lin>.<f>`
//!   `tokens`               — token ids (dynamic)
//!   `kv:<layer>.<0|1>`     — KV cache array (dynamic, device-chained)
//!   `pos`                  — decode position scalar (dynamic)

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    pub path: PathBuf,
    pub inputs: Vec<String>,
}

#[derive(Clone, Debug)]
pub struct QuantConfigEntry {
    pub name: String,
    pub tag: String,
    pub w_bits: u8,
    pub w_planes: usize,
    pub a_bits: u8,
    pub balanced: bool,
}

/// One learned distribution-correction pack registered in the manifest
/// (written by `abq-llm calibrate`; see `docs/CALIBRATION.md`). The pack
/// at `path` holds `corr.<tag>.<layer>.<name>.{s,z,c}` tensors that
/// correction-aware backends apply at prepare time.
#[derive(Clone, Debug, PartialEq)]
pub struct CorrectionEntry {
    /// WqAp config the set was learned for (display form, e.g. `w2*a8`)
    pub config: String,
    /// filesystem-safe tag (`w2sa8`) — the lookup key
    pub tag: String,
    /// correction pack, resolved against the manifest directory
    pub path: PathBuf,
    /// calibration corpus provenance
    pub seed: u64,
    pub seqs: usize,
    pub seq_len: usize,
}

#[derive(Clone, Debug)]
pub struct ArtifactManifest {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub prefill_seq: usize,
    pub decode_batch: usize,
    pub fp_ppl: f64,
    pub quant_configs: Vec<QuantConfigEntry>,
    pub artifacts: Vec<ArtifactEntry>,
    pub corrections: Vec<CorrectionEntry>,
}

impl ArtifactManifest {
    pub fn from_json(j: &Json, dir: &Path) -> Result<Self> {
        let need = |path: &[&str]| -> Result<f64> {
            j.at(path)
                .and_then(|v| v.as_f64())
                .with_context(|| format!("manifest missing {path:?}"))
        };
        let mut artifacts = Vec::new();
        if let Some(arr) = j.get("artifacts").and_then(|a| a.as_arr()) {
            for e in arr {
                let name = e
                    .get("name")
                    .and_then(|v| v.as_str())
                    .context("artifact name")?
                    .to_string();
                let rel = e.get("path").and_then(|v| v.as_str()).context("artifact path")?;
                let inputs = e
                    .get("inputs")
                    .and_then(|v| v.as_arr())
                    .context("artifact inputs")?
                    .iter()
                    .map(|i| i.as_str().unwrap_or_default().to_string())
                    .collect();
                artifacts.push(ArtifactEntry { name, path: dir.join(rel), inputs });
            }
        }
        let mut quant_configs = Vec::new();
        if let Some(arr) = j.get("quant_configs").and_then(|a| a.as_arr()) {
            for e in arr {
                quant_configs.push(QuantConfigEntry {
                    name: e.get("name").and_then(|v| v.as_str()).unwrap_or("").to_string(),
                    tag: e.get("tag").and_then(|v| v.as_str()).unwrap_or("").to_string(),
                    w_bits: e.get("w_bits").and_then(|v| v.as_f64()).unwrap_or(0.0) as u8,
                    w_planes: e.get("w_planes").and_then(|v| v.as_usize()).unwrap_or(0),
                    a_bits: e.get("a_bits").and_then(|v| v.as_f64()).unwrap_or(0.0) as u8,
                    balanced: e.get("balanced").and_then(|v| v.as_bool()).unwrap_or(false),
                });
            }
        }
        let mut corrections = Vec::new();
        if let Some(arr) = j.get("corrections").and_then(|a| a.as_arr()) {
            for e in arr {
                let rel = e
                    .get("path")
                    .and_then(|v| v.as_str())
                    .context("correction path")?;
                corrections.push(CorrectionEntry {
                    config: e.get("config").and_then(|v| v.as_str()).unwrap_or("").to_string(),
                    tag: e
                        .get("tag")
                        .and_then(|v| v.as_str())
                        .context("correction tag")?
                        .to_string(),
                    path: dir.join(rel),
                    seed: e.get("seed").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64,
                    seqs: e.get("seqs").and_then(|v| v.as_usize()).unwrap_or(0),
                    seq_len: e.get("seq_len").and_then(|v| v.as_usize()).unwrap_or(0),
                });
            }
        }
        Ok(ArtifactManifest {
            vocab: need(&["model", "vocab"])? as usize,
            d_model: need(&["model", "d_model"])? as usize,
            n_layers: need(&["model", "n_layers"])? as usize,
            n_heads: need(&["model", "n_heads"])? as usize,
            d_ff: need(&["model", "d_ff"])? as usize,
            max_seq: need(&["model", "max_seq"])? as usize,
            prefill_seq: need(&["prefill_seq"])? as usize,
            decode_batch: need(&["decode_batch"])? as usize,
            fp_ppl: need(&["fp_ppl"]).unwrap_or(0.0),
            quant_configs,
            artifacts,
            corrections,
        })
    }

    /// The manifest's correction entry for a config tag, when one exists.
    pub fn correction_for_tag(&self, tag: &str) -> Option<&CorrectionEntry> {
        self.corrections.iter().find(|c| c.tag == tag)
    }

    /// Which quant tag an artifact name refers to (e.g. `model_w2sa8_decode`
    /// → `w2sa8`); fp16 artifacts return None.
    pub fn tag_of_artifact(name: &str) -> Option<&str> {
        let rest = name.strip_prefix("model_")?;
        let tag = rest.split('_').next()?;
        if tag == "fp16" {
            None
        } else {
            Some(tag)
        }
    }
}

/// Insert or replace the `corrections` manifest entry for `entry.tag` in
/// a parsed manifest object, storing `rel_path` as the pack path (the
/// `calibrate` CLI rewrites `manifest.json` through this, leaving every
/// other field untouched). No-op on a non-object root.
pub fn upsert_correction(manifest: &mut Json, entry: &CorrectionEntry, rel_path: &str) {
    let Json::Obj(m) = manifest else { return };
    let arr = m
        .entry("corrections".to_string())
        .or_insert_with(|| Json::Arr(Vec::new()));
    let Json::Arr(a) = arr else { return };
    a.retain(|e| e.get("tag").and_then(|v| v.as_str()) != Some(entry.tag.as_str()));
    a.push(crate::util::json::obj(vec![
        ("config", crate::util::json::s(&entry.config)),
        ("tag", crate::util::json::s(&entry.tag)),
        ("path", crate::util::json::s(rel_path)),
        ("seed", crate::util::json::num(entry.seed as f64)),
        ("seqs", crate::util::json::num(entry.seqs as f64)),
        ("seq_len", crate::util::json::num(entry.seq_len as f64)),
    ]));
}

/// Classified artifact input.
#[derive(Clone, Debug, PartialEq)]
pub enum InputKind {
    Param { pack_name: String },
    QState { pack_name: String },
    Tokens { shape: Vec<usize> },
    Kv { shape: Vec<usize> },
    Pos,
}

/// Classify one manifest input name for an artifact. The artifact's quant
/// tag is inferred from the surrounding artifact name at program load, so
/// `qstate:` names get resolved with `resolve_qstate_tag` first; here we
/// thread the tag through the manifest-driven loader.
pub fn input_spec_with_tag(
    input: &str,
    m: &ArtifactManifest,
    tag: Option<&str>,
    is_prefill: bool,
) -> Result<InputKind> {
    if let Some(rest) = input.strip_prefix("params:") {
        return Ok(InputKind::Param { pack_name: rest.to_string() });
    }
    if let Some(rest) = input.strip_prefix("qstate:") {
        let tag = tag.ok_or_else(|| anyhow!("qstate input in fp16 artifact: {input}"))?;
        return Ok(InputKind::QState { pack_name: format!("q.{tag}.{rest}") });
    }
    match input {
        "tokens" => {
            let shape = if is_prefill {
                vec![1, m.prefill_seq]
            } else {
                vec![m.decode_batch, 1]
            };
            Ok(InputKind::Tokens { shape })
        }
        "pos" => Ok(InputKind::Pos),
        other => {
            if other.strip_prefix("kv:").is_some() {
                Ok(InputKind::Kv {
                    shape: vec![
                        m.decode_batch,
                        m.max_seq,
                        m.n_heads,
                        m.d_model / m.n_heads,
                    ],
                })
            } else {
                bail!("unknown artifact input '{other}'")
            }
        }
    }
}

/// Convenience used by `engine.rs`: infer tag/prefill-ness by scanning the
/// manifest for the artifact that lists this exact input string. The
/// engine resolves per-artifact, so this thin wrapper keeps its call sites
/// simple — it requires the input string to be unambiguous, which holds
/// for the artifacts aot.py emits (qstate names embed nothing fp16).
pub fn input_spec(input: &str, m: &ArtifactManifest) -> Result<InputKind> {
    for art in &m.artifacts {
        if art.inputs.iter().any(|i| i == input) {
            let tag = ArtifactManifest::tag_of_artifact(&art.name);
            let is_prefill = art.name.ends_with("prefill");
            return input_spec_with_tag(input, m, tag, is_prefill);
        }
    }
    bail!("input '{input}' not found in any artifact")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> ArtifactManifest {
        let j = Json::parse(
            r#"{
            "model": {"vocab": 512, "d_model": 256, "n_layers": 4,
                      "n_heads": 8, "d_ff": 704, "max_seq": 256,
                      "rope_base": 10000.0},
            "prefill_seq": 128, "decode_batch": 1, "fp_ppl": 10.0,
            "quant_configs": [{"name": "w2*a8", "tag": "w2sa8",
                               "w_bits": 2, "w_planes": 3, "a_bits": 8,
                               "balanced": true}],
            "artifacts": [
              {"name": "model_fp16_prefill", "path": "a.hlo.txt",
               "inputs": ["params:tok_emb", "tokens"]},
              {"name": "model_w2sa8_decode", "path": "b.hlo.txt",
               "inputs": ["params:tok_emb", "qstate:0.down.wq",
                          "tokens", "kv:0.0", "pos"]}
            ]
        }"#,
        )
        .unwrap();
        ArtifactManifest::from_json(&j, Path::new("/tmp/art")).unwrap()
    }

    #[test]
    fn parses_model_dims() {
        let m = manifest();
        assert_eq!(m.vocab, 512);
        assert_eq!(m.n_layers, 4);
        assert_eq!(m.artifacts.len(), 2);
        assert_eq!(m.quant_configs[0].w_planes, 3);
    }

    #[test]
    fn classifies_inputs() {
        let m = manifest();
        assert_eq!(
            input_spec("params:tok_emb", &m).unwrap(),
            InputKind::Param { pack_name: "tok_emb".into() }
        );
        assert_eq!(
            input_spec("qstate:0.down.wq", &m).unwrap(),
            InputKind::QState { pack_name: "q.w2sa8.0.down.wq".into() }
        );
        assert!(matches!(input_spec("kv:0.0", &m).unwrap(), InputKind::Kv { .. }));
        assert_eq!(input_spec("pos", &m).unwrap(), InputKind::Pos);
        // tokens in the fp16 prefill artifact → prefill shape
        assert_eq!(
            input_spec("tokens", &m).unwrap(),
            InputKind::Tokens { shape: vec![1, 128] }
        );
    }

    #[test]
    fn corrections_parse_and_upsert_roundtrip() {
        // a manifest without the section parses to an empty list
        let m = manifest();
        assert!(m.corrections.is_empty());
        assert!(m.correction_for_tag("w2sa8").is_none());
        // upsert into the raw json, reparse, find it
        let text = r#"{
            "model": {"vocab": 512, "d_model": 256, "n_layers": 4,
                      "n_heads": 8, "d_ff": 704, "max_seq": 256,
                      "rope_base": 10000.0},
            "prefill_seq": 128, "decode_batch": 1, "fp_ppl": 10.0
        }"#;
        let mut j = Json::parse(text).unwrap();
        let entry = CorrectionEntry {
            config: "w2*a8".into(),
            tag: "w2sa8".into(),
            path: PathBuf::new(),
            seed: 7,
            seqs: 8,
            seq_len: 64,
        };
        upsert_correction(&mut j, &entry, "corrections.w2sa8.abqw");
        // replacing the same tag does not duplicate
        upsert_correction(&mut j, &entry, "corrections.w2sa8.abqw");
        let reparsed = Json::parse(&j.to_string_pretty()).unwrap();
        let m2 = ArtifactManifest::from_json(&reparsed, Path::new("/tmp/art")).unwrap();
        assert_eq!(m2.corrections.len(), 1);
        let got = m2.correction_for_tag("w2sa8").unwrap();
        assert_eq!(got.config, "w2*a8");
        assert_eq!(got.seed, 7);
        assert_eq!(got.seqs, 8);
        assert_eq!(got.seq_len, 64);
        assert_eq!(got.path, Path::new("/tmp/art").join("corrections.w2sa8.abqw"));
    }

    #[test]
    fn tag_inference() {
        assert_eq!(ArtifactManifest::tag_of_artifact("model_fp16_prefill"), None);
        assert_eq!(
            ArtifactManifest::tag_of_artifact("model_w2sa8_decode"),
            Some("w2sa8")
        );
    }
}
