//! PJRT executor for the AOT artifacts: loads the HLO *text* lowered by
//! `python/compile/aot.py` (the L2 jax model with the L1 pallas kernel
//! inlined), compiles it on the PJRT CPU client, and runs prefill/decode
//! from the rust request path. Python is never involved here.
//!
//! Weights (and quantized code tensors) are uploaded to device buffers
//! once at load; per step only tokens/position (and the KV chain, which
//! stays device-resident as output→input buffers) move.

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};
use xla::{ElementType, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

use crate::model::WeightPack;
use crate::util::json::Json;

use super::artifacts::{input_spec_with_tag, ArtifactManifest, InputKind};

pub struct PjrtEngine {
    pub client: PjRtClient,
    pub manifest: ArtifactManifest,
}

/// One compiled model program (prefill or decode) with its device-resident
/// static inputs.
pub struct Program {
    exe: PjRtLoadedExecutable,
    /// static (weight/qstate) buffers, in manifest input order prefix
    static_bufs: Vec<PjRtBuffer>,
    /// host literals backing `static_bufs` — PJRT host→device transfers
    /// are asynchronous, so the source literal must outlive the buffer's
    /// first use (dropping it early is a use-after-free)
    _static_lits: Vec<Literal>,
    /// kinds of the dynamic tail (tokens/kv/pos), in order
    dynamic: Vec<InputKind>,
    /// total bytes of the uploaded static (weight/qstate) buffers
    static_bytes: usize,
    pub name: String,
}

impl PjrtEngine {
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest_text = std::fs::read_to_string(dir.join("manifest.json"))
            .context("read manifest.json")?;
        let j = Json::parse(&manifest_text).map_err(|e| anyhow!("manifest: {e}"))?;
        let manifest = ArtifactManifest::from_json(&j, dir)?;
        let client = PjRtClient::cpu()?;
        Ok(PjrtEngine { client, manifest })
    }

    /// Compile one artifact by name (e.g. "model_fp16_prefill") and upload
    /// its static inputs from the weight pack.
    pub fn program(&self, name: &str, pack: &WeightPack) -> Result<Program> {
        let art = self
            .manifest
            .artifacts
            .iter()
            .find(|a| a.name == name)
            .with_context(|| format!("artifact '{name}' not in manifest"))?;
        let proto = xla::HloModuleProto::from_text_file(
            art.path
                .to_str()
                .context("artifact path not utf-8")?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;

        let tag = ArtifactManifest::tag_of_artifact(&art.name);
        let is_prefill = art.name.ends_with("prefill");
        let mut static_bufs = Vec::new();
        let mut static_lits = Vec::new();
        let mut static_bytes = 0usize;
        let mut dynamic = Vec::new();
        let mut seen_dynamic = false;
        for input in &art.inputs {
            let kind = input_spec_with_tag(input, &self.manifest, tag, is_prefill)?;
            match kind {
                InputKind::Param { .. } | InputKind::QState { .. } => {
                    if seen_dynamic {
                        bail!("static input '{input}' after dynamic inputs");
                    }
                    let (lit, bytes) = self.literal_for_static(&kind, pack)?;
                    static_bytes += bytes;
                    static_bufs.push(self.client.buffer_from_host_literal(None, &lit)?);
                    static_lits.push(lit);
                }
                _ => {
                    seen_dynamic = true;
                    dynamic.push(kind);
                }
            }
        }
        Ok(Program {
            exe,
            static_bufs,
            _static_lits: static_lits,
            dynamic,
            static_bytes,
            name: name.to_string(),
        })
    }

    fn literal_for_static(&self, kind: &InputKind, pack: &WeightPack) -> Result<(Literal, usize)> {
        match kind {
            InputKind::Param { pack_name } => {
                let t = pack.get(pack_name)?;
                let data = t.as_f32()?;
                let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
                let n = bytes.len();
                Ok((
                    Literal::create_from_shape_and_untyped_data(
                        ElementType::F32,
                        t.shape(),
                        &bytes,
                    )?,
                    n,
                ))
            }
            InputKind::QState { pack_name } => {
                let t = pack.get(pack_name)?;
                match t {
                    crate::model::Tensor::U8(v, shape) => {
                        // codes stored u8 in the pack, i32 in the HLO
                        let bytes: Vec<u8> =
                            v.iter().flat_map(|&c| (c as i32).to_le_bytes()).collect();
                        let n = bytes.len();
                        Ok((
                            Literal::create_from_shape_and_untyped_data(
                                ElementType::S32,
                                shape,
                                &bytes,
                            )?,
                            n,
                        ))
                    }
                    crate::model::Tensor::I32(v, shape) => {
                        let bytes: Vec<u8> =
                            v.iter().flat_map(|x| x.to_le_bytes()).collect();
                        let n = bytes.len();
                        Ok((
                            Literal::create_from_shape_and_untyped_data(
                                ElementType::S32,
                                shape,
                                &bytes,
                            )?,
                            n,
                        ))
                    }
                    crate::model::Tensor::F32(v, shape) => {
                        let bytes: Vec<u8> =
                            v.iter().flat_map(|x| x.to_le_bytes()).collect();
                        let n = bytes.len();
                        Ok((
                            Literal::create_from_shape_and_untyped_data(
                                ElementType::F32,
                                shape,
                                &bytes,
                            )?,
                            n,
                        ))
                    }
                }
            }
            _ => bail!("not a static input"),
        }
    }
}

/// Device-resident KV state chained between decode steps. The backing
/// host literals are kept alive alongside the buffers (async transfers).
pub struct KvState {
    pub bufs: Vec<PjRtBuffer>,
    lits: Vec<Literal>,
    pub pos: i32,
}

impl Program {
    /// Bytes of the device-resident static (weight/qstate) inputs — the
    /// PJRT side of the Table 12 memory accounting.
    pub fn static_bytes(&self) -> usize {
        self.static_bytes
    }

    fn tokens_literal(&self, tokens: &[i32], shape: &[usize]) -> Result<Literal> {
        let count: usize = shape.iter().product();
        if tokens.len() != count {
            bail!("tokens len {} != artifact shape {:?}", tokens.len(), shape);
        }
        let bytes: Vec<u8> = tokens.iter().flat_map(|v| v.to_le_bytes()).collect();
        Ok(Literal::create_from_shape_and_untyped_data(
            ElementType::S32,
            shape,
            &bytes,
        )?)
    }

    /// Run prefill: `tokens` must match the artifact's [B, S]. Returns
    /// logits as a flat f32 vec `[B*S*V]` (prefill has no KV outputs in the
    /// exported graph — serving decode re-prefills through the decode
    /// artifact's cache inputs).
    pub fn prefill(&self, client: &PjRtClient, tokens: &[i32]) -> Result<Vec<f32>> {
        let mut args: Vec<&PjRtBuffer> = self.static_bufs.iter().collect();
        let (tok_shape,) = match &self.dynamic[..] {
            [InputKind::Tokens { shape }] => (shape.clone(),),
            other => bail!("prefill artifact has unexpected dynamic inputs: {other:?}"),
        };
        let tok_lit = self.tokens_literal(tokens, &tok_shape)?;
        let tok_buf = client.buffer_from_host_literal(None, &tok_lit)?;
        args.push(&tok_buf);
        let out = self.exe.execute_b(&args)?;
        // single-output programs lower to a bare array root (no tuple)
        let result = out[0][0].to_literal_sync()?;
        match result.to_tuple() {
            Ok(mut parts) if !parts.is_empty() => Ok(parts.remove(0).to_vec::<f32>()?),
            _ => Ok(out[0][0].to_literal_sync()?.to_vec::<f32>()?),
        }
    }

    /// Initialise a zeroed device KV state matching the decode artifact.
    pub fn init_kv(&self, client: &PjRtClient) -> Result<KvState> {
        let mut bufs = Vec::new();
        let mut lits = Vec::new();
        for kind in &self.dynamic {
            if let InputKind::Kv { shape } = kind {
                let count: usize = shape.iter().product();
                let lit = Literal::create_from_shape_and_untyped_data(
                    ElementType::F32,
                    shape,
                    &vec![0u8; count * 4],
                )?;
                bufs.push(client.buffer_from_host_literal(None, &lit)?);
                lits.push(lit);
            }
        }
        if bufs.is_empty() {
            bail!("decode artifact has no KV inputs");
        }
        Ok(KvState { bufs, lits, pos: 0 })
    }

    /// One decode step: feeds tokens + device KV + pos, returns logits
    /// `[B*V]` and replaces the KV buffers with the step's outputs.
    pub fn decode_step(
        &self,
        client: &PjRtClient,
        tokens: &[i32],
        kv: &mut KvState,
    ) -> Result<Vec<f32>> {
        let mut args: Vec<&PjRtBuffer> = self.static_bufs.iter().collect();
        let mut kv_cursor = 0usize;
        let mut tok_buf_holder = None;
        let mut pos_buf_holder = None;
        for kind in &self.dynamic {
            match kind {
                InputKind::Tokens { shape } => {
                    let lit = self.tokens_literal(tokens, shape)?;
                    tok_buf_holder = Some(client.buffer_from_host_literal(None, &lit)?);
                }
                InputKind::Kv { .. } => {
                    kv_cursor += 1;
                }
                InputKind::Pos => {
                    let lit = Literal::scalar(kv.pos);
                    pos_buf_holder = Some(client.buffer_from_host_literal(None, &lit)?);
                }
                _ => bail!("unexpected dynamic input in decode artifact"),
            }
        }
        if kv_cursor != kv.bufs.len() {
            bail!("kv arity mismatch: artifact {kv_cursor}, state {}", kv.bufs.len());
        }
        // assemble in manifest order
        let mut kv_iter = kv.bufs.iter();
        for kind in &self.dynamic {
            match kind {
                InputKind::Tokens { .. } => args.push(tok_buf_holder.as_ref().unwrap()),
                InputKind::Kv { .. } => args.push(kv_iter.next().unwrap()),
                InputKind::Pos => args.push(pos_buf_holder.as_ref().unwrap()),
                _ => unreachable!(),
            }
        }
        let out = self.exe.execute_b(&args)?;
        let row = &out[0][0];
        let result = row.to_literal_sync()?;
        let mut parts = result.to_tuple()?;
        if parts.len() != 1 + kv.bufs.len() {
            bail!("decode output arity {} != 1 + {}", parts.len(), kv.bufs.len());
        }
        let logits = parts.remove(0).to_vec::<f32>()?;
        // re-upload KV outputs as next-step inputs (host hop; the compiled
        // graph returns literals — buffer donation would remove this, see
        // EXPERIMENTS.md §Perf). Literals stay alive in `kv.lits` until
        // replaced: transfers are async.
        let mut new_bufs = Vec::with_capacity(parts.len());
        let mut new_lits = Vec::with_capacity(parts.len());
        for lit in parts {
            new_bufs.push(client.buffer_from_host_literal(None, &lit)?);
            new_lits.push(lit);
        }
        kv.bufs = new_bufs;
        kv.lits = new_lits;
        kv.pos += 1;
        Ok(logits)
    }
}
