//! `.abqs` session files: a prefix's quantized KV pages persisted to
//! disk (llama.cpp-style), so a warm system-prompt cache survives a
//! server restart. Reader/writer live beside the `.abqw` weight pack and
//! follow the same conventions: little-endian wire format, deterministic
//! `to_bytes`, strict magic/truncation checks.
//!
//! ```text
//! magic  b"ABQS2\0"
//! u16    model_len, model name (utf-8)
//! u32    vocab, d_model, n_layers, n_heads, n_kv_heads, d_ff, max_seq
//! f32    rope_base
//! u16    tag_len, backend tag (utf-8, e.g. "w2sa8")
//! u8     kv_bits
//! u32    kv_block (positions per page)
//! u32    n_tokens, u32×n_tokens prefix token ids
//! u32    n_pages, u32 page_bytes, n_pages × page payloads
//! ```
//!
//! The header up to `kv_block` is the **fingerprint**: a session file is
//! only loadable into an engine whose model config, backend tag and KV
//! cache config match it exactly — pages are raw quantized bytes, so any
//! mismatch would silently corrupt attention. Token/page consistency
//! (`n_tokens == n_pages × kv_block`, i.e. whole pages only) is a format
//! invariant enforced by the parser.
//!
//! Version history: `ABQS1` predates GQA and has no `n_kv_heads` field —
//! its page geometry is ambiguous for any model with `n_kv_heads <
//! n_heads`, so v1 files are rejected with an explicit version error
//! (re-export the prefix to upgrade) rather than guessed at.

use std::io::Read;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::model::{KvCacheConfig, ModelConfig};

/// Everything that must match between the writing and the reading engine
/// before `.abqs` pages may be attached (`docs/SERVING.md`).
#[derive(Clone, Debug, PartialEq)]
pub struct SessionFingerprint {
    pub model: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    /// KV heads (GQA): sizes the page rows (`kv_dim = n_kv_heads * head_dim`),
    /// so two checkpoints differing only here must never false-match
    pub n_kv_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub rope_base: f32,
    /// serving tag of the quant config that produced the pages
    pub backend_tag: String,
    pub kv_bits: u8,
    pub kv_block: usize,
}

impl SessionFingerprint {
    pub fn of(m: &ModelConfig, backend_tag: &str, kv: &KvCacheConfig) -> Self {
        SessionFingerprint {
            model: m.name.to_string(),
            vocab: m.vocab,
            d_model: m.d_model,
            n_layers: m.n_layers,
            n_heads: m.n_heads,
            n_kv_heads: m.n_kv_heads,
            d_ff: m.d_ff,
            max_seq: m.max_seq,
            rope_base: m.rope_base,
            backend_tag: backend_tag.to_string(),
            kv_bits: kv.bits,
            kv_block: kv.block_size,
        }
    }
}

/// One persisted prefix: fingerprint + the token ids the pages encode +
/// the raw page payloads (whole blocks only, in position order).
#[derive(Clone, Debug, PartialEq)]
pub struct SessionFile {
    pub fingerprint: SessionFingerprint,
    pub tokens: Vec<u32>,
    pub pages: Vec<Vec<u8>>,
}

impl SessionFile {
    pub fn load(path: &Path) -> Result<Self> {
        let mut f =
            std::fs::File::open(path).with_context(|| format!("open session file {path:?}"))?;
        let mut buf = Vec::new();
        f.read_to_end(&mut buf)?;
        Self::parse(&buf)
    }

    pub fn parse(buf: &[u8]) -> Result<Self> {
        let mut pos = 0usize;
        let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
            if *pos + n > buf.len() {
                bail!("truncated session file at byte {}", *pos);
            }
            let s = &buf[*pos..*pos + n];
            *pos += n;
            Ok(s)
        };
        let take_u32 = |pos: &mut usize| -> Result<u32> {
            Ok(u32::from_le_bytes(take(pos, 4)?.try_into()?))
        };
        let take_str = |pos: &mut usize| -> Result<String> {
            let n = u16::from_le_bytes(take(pos, 2)?.try_into()?) as usize;
            Ok(String::from_utf8(take(pos, n)?.to_vec())?)
        };
        let magic = take(&mut pos, 6)?;
        if magic == b"ABQS1\0" {
            bail!(
                "old .abqs version ABQS1 (pre-GQA, no n_kv_heads in the fingerprint): \
                 re-export the session with this engine to upgrade"
            );
        }
        if magic != b"ABQS2\0" {
            bail!("bad magic (not an .abqs session file)");
        }
        let model = take_str(&mut pos)?;
        let vocab = take_u32(&mut pos)? as usize;
        let d_model = take_u32(&mut pos)? as usize;
        let n_layers = take_u32(&mut pos)? as usize;
        let n_heads = take_u32(&mut pos)? as usize;
        let n_kv_heads = take_u32(&mut pos)? as usize;
        let d_ff = take_u32(&mut pos)? as usize;
        let max_seq = take_u32(&mut pos)? as usize;
        let rope_base = f32::from_le_bytes(take(&mut pos, 4)?.try_into()?);
        let backend_tag = take_str(&mut pos)?;
        let kv_bits = take(&mut pos, 1)?[0];
        let kv_block = take_u32(&mut pos)? as usize;
        let fingerprint = SessionFingerprint {
            model,
            vocab,
            d_model,
            n_layers,
            n_heads,
            n_kv_heads,
            d_ff,
            max_seq,
            rope_base,
            backend_tag,
            kv_bits,
            kv_block,
        };
        let n_tokens = take_u32(&mut pos)? as usize;
        let mut tokens = Vec::with_capacity(n_tokens);
        for _ in 0..n_tokens {
            tokens.push(take_u32(&mut pos)?);
        }
        let n_pages = take_u32(&mut pos)? as usize;
        let page_bytes = take_u32(&mut pos)? as usize;
        if kv_block == 0 || n_tokens != n_pages * kv_block {
            bail!(
                "inconsistent session file: {n_tokens} tokens vs {n_pages} pages × {kv_block} \
                 positions (prefixes persist whole pages only)"
            );
        }
        let mut pages = Vec::with_capacity(n_pages);
        for _ in 0..n_pages {
            pages.push(take(&mut pos, page_bytes)?.to_vec());
        }
        if pos != buf.len() {
            bail!("trailing garbage after session file payload ({} bytes)", buf.len() - pos);
        }
        Ok(SessionFile { fingerprint, tokens, pages })
    }

    /// Serialize to the `.abqs` wire format (byte-deterministic for a
    /// given content — the round-trip tests compare these bytes).
    pub fn to_bytes(&self) -> Vec<u8> {
        let fp = &self.fingerprint;
        let mut b: Vec<u8> = b"ABQS2\0".to_vec();
        let put_str = |b: &mut Vec<u8>, s: &str| {
            b.extend((s.len() as u16).to_le_bytes());
            b.extend(s.as_bytes());
        };
        put_str(&mut b, &fp.model);
        for d in [fp.vocab, fp.d_model, fp.n_layers, fp.n_heads, fp.n_kv_heads, fp.d_ff, fp.max_seq] {
            b.extend((d as u32).to_le_bytes());
        }
        b.extend(fp.rope_base.to_le_bytes());
        put_str(&mut b, &fp.backend_tag);
        b.push(fp.kv_bits);
        b.extend((fp.kv_block as u32).to_le_bytes());
        b.extend((self.tokens.len() as u32).to_le_bytes());
        for t in &self.tokens {
            b.extend(t.to_le_bytes());
        }
        b.extend((self.pages.len() as u32).to_le_bytes());
        let page_bytes = self.pages.first().map_or(0, Vec::len);
        b.extend((page_bytes as u32).to_le_bytes());
        for p in &self.pages {
            debug_assert_eq!(p.len(), page_bytes, "pages of one layout are same-sized");
            b.extend_from_slice(p);
        }
        b
    }

    /// Write the session to disk (what [`SessionFile::load`] reads back).
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_bytes())
            .with_context(|| format!("write session file {path:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::TINY;

    fn sample() -> SessionFile {
        let kv = KvCacheConfig { bits: 8, block_size: 4 };
        SessionFile {
            fingerprint: SessionFingerprint::of(&TINY, "w2sa8", &kv),
            tokens: vec![5, 6, 7, 8, 9, 10, 11, 12],
            pages: vec![vec![1u8; 24], vec![2u8; 24]],
        }
    }

    #[test]
    fn roundtrip_is_byte_exact_and_deterministic() {
        let s = sample();
        let bytes = s.to_bytes();
        let back = SessionFile::parse(&bytes).unwrap();
        assert_eq!(back, s);
        assert_eq!(back.to_bytes(), bytes);
    }

    #[test]
    fn rejects_old_version_with_explicit_error() {
        // a v1 file (same layout minus n_kv_heads) must fail on its magic
        // with a message naming the version, not a generic parse error
        let mut v1 = sample().to_bytes();
        v1[..6].copy_from_slice(b"ABQS1\0");
        let err = SessionFile::parse(&v1).unwrap_err().to_string();
        assert!(err.contains("ABQS1"), "{err}");
        assert!(err.contains("re-export"), "{err}");
    }

    #[test]
    fn fingerprint_distinguishes_kv_heads() {
        // two checkpoints differing only in n_kv_heads write different
        // page geometry — they must never false-match
        let kv = KvCacheConfig { bits: 8, block_size: 4 };
        let mha = SessionFingerprint::of(&TINY, "w2sa8", &kv);
        let mut gqa_cfg = TINY;
        gqa_cfg.n_kv_heads = 2;
        let gqa = SessionFingerprint::of(&gqa_cfg, "w2sa8", &kv);
        assert_ne!(mha, gqa);
    }

    #[test]
    fn rejects_bad_magic_truncation_and_trailing_garbage() {
        assert!(SessionFile::parse(b"ABQW1\0rest").is_err(), "weight-pack magic");
        let bytes = sample().to_bytes();
        assert!(SessionFile::parse(&bytes[..bytes.len() - 3]).is_err(), "truncation");
        let mut long = bytes.clone();
        long.push(0);
        assert!(SessionFile::parse(&long).is_err(), "trailing garbage");
    }

    #[test]
    fn rejects_token_page_mismatch() {
        let mut s = sample();
        s.tokens.pop(); // 7 tokens can't cover 2 whole 4-position pages
        assert!(SessionFile::parse(&s.to_bytes()).is_err());
    }

    #[test]
    fn fingerprint_equality_is_field_exact() {
        let kv = KvCacheConfig { bits: 8, block_size: 4 };
        let a = SessionFingerprint::of(&TINY, "w2sa8", &kv);
        assert_eq!(a, SessionFingerprint::of(&TINY, "w2sa8", &kv));
        assert_ne!(a, SessionFingerprint::of(&TINY, "w4a4", &kv));
        assert_ne!(
            a,
            SessionFingerprint::of(&TINY, "w2sa8", &KvCacheConfig { bits: 4, block_size: 4 })
        );
        let mut other = TINY;
        other.n_layers += 1;
        assert_ne!(a, SessionFingerprint::of(&other, "w2sa8", &kv));
    }
}
