//! Read-only memory-mapped byte buffers for zero-copy artifact loading.
//!
//! [`MappedBytes`] maps a file with `mmap(2)` on Linux (x86_64/aarch64,
//! via raw syscalls — the crate carries no libc binding) and falls back
//! to an ordinary heap read everywhere else. Either way the result
//! derefs to `&[u8]`, is `Send + Sync`, and lives until dropped, so an
//! `Arc<MappedBytes>` can back any number of borrowed tensor views
//! (`model::PackView`) across replica threads without copying the
//! underlying weight artifact.
//!
//! Lifetime contract (docs/ENGINE_API.md §mmap'd artifacts): every view
//! holds its own `Arc`, so the mapping outlives all borrows by
//! construction; `munmap` happens only when the last `Arc` drops.

use std::fs::File;
use std::ops::Deref;
use std::path::Path;

use anyhow::{Context, Result};

enum Backing {
    /// mmap'd region: base pointer + mapped length (page-rounded len is
    /// what munmap needs; `len` below is the file length we expose).
    #[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
    Mapped { ptr: *const u8, map_len: usize },
    Heap(Vec<u8>),
}

/// An immutable byte buffer backed by an mmap'd file when the platform
/// supports it, or a heap copy otherwise. Dereferences to `&[u8]`.
pub struct MappedBytes {
    backing: Backing,
    len: usize,
}

// The mapped region is read-only (PROT_READ, MAP_PRIVATE) and never
// remapped after construction, so shared references are safe to send.
unsafe impl Send for MappedBytes {}
unsafe impl Sync for MappedBytes {}

impl MappedBytes {
    /// Map `path` read-only. Empty files and non-Linux platforms use a
    /// heap buffer; mapping failures fall back to a heap read too, so
    /// `open` only errors when the file itself is unreadable.
    pub fn open(path: &Path) -> Result<Self> {
        let file =
            File::open(path).with_context(|| format!("open {} for mapping", path.display()))?;
        let len = file
            .metadata()
            .with_context(|| format!("stat {}", path.display()))?
            .len() as usize;
        if len == 0 {
            return Ok(Self { backing: Backing::Heap(Vec::new()), len: 0 });
        }
        #[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
        {
            if let Some(mapped) = Self::try_map(&file, len) {
                return Ok(mapped);
            }
        }
        drop(file);
        let bytes =
            std::fs::read(path).with_context(|| format!("read {} (mmap fallback)", path.display()))?;
        let len = bytes.len();
        Ok(Self { backing: Backing::Heap(bytes), len })
    }

    /// Wrap an owned buffer (used by in-memory packs and tests so both
    /// backings go through the same view types).
    pub fn from_vec(bytes: Vec<u8>) -> Self {
        let len = bytes.len();
        Self { backing: Backing::Heap(bytes), len }
    }

    /// Whether this buffer is an actual kernel mapping (false for the
    /// heap fallback). `MemoryReport` uses this to decide whether weight
    /// bytes are shared page-cache pages or private allocations.
    pub fn is_mapped(&self) -> bool {
        match &self.backing {
            #[cfg(all(
                target_os = "linux",
                any(target_arch = "x86_64", target_arch = "aarch64")
            ))]
            Backing::Mapped { .. } => true,
            Backing::Heap(_) => false,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
    fn try_map(file: &File, len: usize) -> Option<Self> {
        use std::os::fd::AsRawFd;
        let fd = file.as_raw_fd();
        // page-round the mapping length; the tail of the last page reads
        // as zeros, which we never expose (self.len caps the slice)
        let page = 4096usize;
        let map_len = len.div_ceil(page) * page;
        let addr = unsafe { sys_mmap(map_len, fd) };
        // MAP_FAILED is -1; any address in the top page is an errno
        if addr == usize::MAX || addr == 0 || addr > usize::MAX - page {
            return None;
        }
        Some(Self { backing: Backing::Mapped { ptr: addr as *const u8, map_len }, len })
    }
}

impl Deref for MappedBytes {
    type Target = [u8];

    fn deref(&self) -> &[u8] {
        match &self.backing {
            #[cfg(all(
                target_os = "linux",
                any(target_arch = "x86_64", target_arch = "aarch64")
            ))]
            Backing::Mapped { ptr, .. } => unsafe {
                std::slice::from_raw_parts(*ptr, self.len)
            },
            Backing::Heap(v) => v,
        }
    }
}

impl Drop for MappedBytes {
    fn drop(&mut self) {
        match &self.backing {
            #[cfg(all(
                target_os = "linux",
                any(target_arch = "x86_64", target_arch = "aarch64")
            ))]
            Backing::Mapped { ptr, map_len } => unsafe {
                sys_munmap(*ptr as usize, *map_len);
            },
            Backing::Heap(_) => {}
        }
    }
}

impl std::fmt::Debug for MappedBytes {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MappedBytes")
            .field("len", &self.len)
            .field("mapped", &self.is_mapped())
            .finish()
    }
}

// -- raw syscalls (no libc dependency) -----------------------------------
//
// mmap(addr=0, len, PROT_READ, MAP_PRIVATE, fd, offset=0) and
// munmap(addr, len). Only compiled on linux x86_64/aarch64; everything
// else takes the heap path above.

#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
unsafe fn sys_mmap(len: usize, fd: i32) -> usize {
    const SYS_MMAP: usize = 9;
    const PROT_READ: usize = 1;
    const MAP_PRIVATE: usize = 2;
    let ret: usize;
    std::arch::asm!(
        "syscall",
        inlateout("rax") SYS_MMAP => ret,
        in("rdi") 0usize,
        in("rsi") len,
        in("rdx") PROT_READ,
        in("r10") MAP_PRIVATE,
        in("r8") fd as usize,
        in("r9") 0usize,
        lateout("rcx") _,
        lateout("r11") _,
        options(nostack),
    );
    ret
}

#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
unsafe fn sys_munmap(addr: usize, len: usize) {
    const SYS_MUNMAP: usize = 11;
    let _ret: usize;
    std::arch::asm!(
        "syscall",
        inlateout("rax") SYS_MUNMAP => _ret,
        in("rdi") addr,
        in("rsi") len,
        lateout("rcx") _,
        lateout("r11") _,
        options(nostack),
    );
}

#[cfg(all(target_os = "linux", target_arch = "aarch64"))]
unsafe fn sys_mmap(len: usize, fd: i32) -> usize {
    const SYS_MMAP: usize = 222;
    const PROT_READ: usize = 1;
    const MAP_PRIVATE: usize = 2;
    let ret: usize;
    std::arch::asm!(
        "svc 0",
        inlateout("x0") 0usize => ret,
        in("x1") len,
        in("x2") PROT_READ,
        in("x3") MAP_PRIVATE,
        in("x4") fd as usize,
        in("x5") 0usize,
        in("x8") SYS_MMAP,
        options(nostack),
    );
    ret
}

#[cfg(all(target_os = "linux", target_arch = "aarch64"))]
unsafe fn sys_munmap(addr: usize, len: usize) {
    const SYS_MUNMAP: usize = 215;
    let _ret: usize;
    std::arch::asm!(
        "svc 0",
        inlateout("x0") addr => _ret,
        in("x1") len,
        in("x8") SYS_MUNMAP,
        options(nostack),
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str, bytes: &[u8]) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("abq_mmap_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(name);
        std::fs::write(&p, bytes).unwrap();
        p
    }

    #[test]
    fn roundtrips_file_contents() {
        let data: Vec<u8> = (0..10_000u32).flat_map(|i| i.to_le_bytes()).collect();
        let p = tmp("roundtrip.bin", &data);
        let m = MappedBytes::open(&p).unwrap();
        assert_eq!(m.len(), data.len());
        assert_eq!(&m[..], &data[..]);
        #[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
        assert!(m.is_mapped(), "linux builds should take the mmap path");
    }

    #[test]
    fn empty_file_is_heap_backed() {
        let p = tmp("empty.bin", &[]);
        let m = MappedBytes::open(&p).unwrap();
        assert!(m.is_empty());
        assert!(!m.is_mapped());
        assert_eq!(&m[..], &[] as &[u8]);
    }

    #[test]
    fn from_vec_wraps_without_copy_semantics_change() {
        let m = MappedBytes::from_vec(vec![1, 2, 3]);
        assert_eq!(&m[..], &[1, 2, 3]);
        assert!(!m.is_mapped());
    }

    #[test]
    fn survives_many_concurrent_readers() {
        let data: Vec<u8> = (0..4096u32).flat_map(|i| i.to_le_bytes()).collect();
        let p = tmp("shared.bin", &data);
        let m = std::sync::Arc::new(MappedBytes::open(&p).unwrap());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let m = m.clone();
                let want = data.clone();
                std::thread::spawn(move || assert_eq!(&m[..], &want[..]))
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }
}
