//! Sensitivity-ranked per-layer bit allocation under a global weight-byte
//! budget (the FineQuant-style half of the precision autopilot).
//!
//! The signal is the calibration subsystem's block-tap machinery
//! (`crate::calib`): the fp32 model runs the deterministic calibration
//! corpus once, tapping every block's residual input/output; each block
//! is then re-run through the scalar reference linears at every
//! candidate WqAp config (identity corrections — this ranks *bit
//! widths*, calibration then tunes whichever config ships), and the
//! block-output MSE against the fp32 tap is that layer's sensitivity at
//! that width. Everything is deterministic given the seed.
//!
//! [`allocate_under_budget`] is a greedy marginal-utility ascent: start
//! every layer at the cheapest candidate and repeatedly buy the upgrade
//! with the best MSE-reduction-per-byte until the budget is spent —
//! sensitive layers (attention projections of early blocks, typically)
//! climb to high bits first, tolerant layers stay low. A larger budget
//! only extends the upgrade sequence, so predicted MSE is monotone
//! non-increasing in the budget (property-tested below).
//!
//! [`plan_ladder`] turns a descending budget series into a serving
//! [`super::Ladder`]: each budget's allocation is projected to the
//! cheapest *uniform* operating point that dominates it (the engine
//! currently instantiates one backend for all layers; the per-layer
//! allocation ships in the report and is the prepare target once
//! per-layer backends land). KV follows the ROADMAP shape: every rung
//! serves 8-bit KV except the tightest, which drops to 4-bit.

use anyhow::{bail, Context, Result};

use crate::calib::optimize::{block_forward, RefLinear};
use crate::calib::{block_weights, calibration_tokens};
use crate::engine::Fp32Backend;
use crate::model::{BlockTap, ForwardScratch, KvCache, ModelConfig, Transformer, WeightPack};
use crate::quant::{Correction, WAConfig};

use super::{Ladder, OperatingPoint};

/// Search hyper-parameters. Defaults profile the tiny models in
/// milliseconds; everything is deterministic given `seed`.
#[derive(Clone, Debug)]
pub struct SearchOptions {
    /// calibration sequences drawn from the synthetic corpus
    pub seqs: usize,
    /// tokens per sequence
    pub seq_len: usize,
    /// corpus seed (the only randomness in the search)
    pub seed: u64,
    /// candidate WqAp configs, any order (sorted by weight bits inside
    /// [`sensitivity_profile`])
    pub candidates: Vec<WAConfig>,
}

impl Default for SearchOptions {
    fn default() -> Self {
        SearchOptions {
            seqs: 4,
            seq_len: 16,
            seed: 0xB17_A110C,
            candidates: ["w2*a8", "w4a4", "w6a6", "w8a8"]
                .iter()
                .map(|s| s.parse().expect("built-in candidates parse"))
                .collect(),
        }
    }
}

/// One layer's sensitivity curve: block-output MSE vs the fp32 tap and
/// modelled packed weight bytes, indexed by candidate (same order as
/// [`SensitivityProfile::candidates`]).
#[derive(Clone, Debug)]
pub struct LayerSensitivity {
    pub layer: usize,
    pub mse: Vec<f64>,
    pub bytes: Vec<usize>,
}

/// The full per-layer × per-candidate sensitivity table.
#[derive(Clone, Debug)]
pub struct SensitivityProfile {
    /// candidates sorted ascending by weight bits (allocation order)
    pub candidates: Vec<WAConfig>,
    pub layers: Vec<LayerSensitivity>,
}

impl SensitivityProfile {
    /// Total packed weight bytes of a *uniform* deployment at candidate
    /// `ci` (the budget anchors `plan_ladder` budgets come from).
    pub fn uniform_bytes(&self, ci: usize) -> usize {
        self.layers.iter().map(|l| l.bytes[ci]).sum()
    }
}

/// A per-layer bit assignment under one budget.
#[derive(Clone, Debug)]
pub struct Allocation {
    /// candidate index per layer (into the profile's candidate list)
    pub per_layer: Vec<usize>,
    pub total_bytes: usize,
    /// summed predicted block-output MSE of the assignment
    pub total_mse: f64,
    pub budget_bytes: usize,
}

impl Allocation {
    /// The assignment as WqAp configs.
    pub fn configs<'a>(&self, profile: &'a SensitivityProfile) -> Vec<&'a WAConfig> {
        self.per_layer.iter().map(|&ci| &profile.candidates[ci]).collect()
    }

    /// Index of the most precise candidate any layer uses — the uniform
    /// config that dominates this allocation.
    pub fn uniform_projection(&self) -> usize {
        self.per_layer.iter().copied().max().unwrap_or(0)
    }
}

/// Modelled packed size of one linear at `bits`: bit-plane rows
/// (`bits` planes of ⌈in/8⌉ bytes each) plus the per-row dequant
/// parameters (delta + zero point). A modelling convention shared by
/// every candidate, not an exact allocator account.
fn packed_linear_bytes(out_f: usize, in_f: usize, bits: u8) -> usize {
    out_f * bits as usize * in_f.div_ceil(8) + out_f * 8
}

/// Tap the fp32 model once, then score every block at every candidate
/// config (see module docs). Layers are scored independently — the
/// block's fp32 input is replayed through quantized projections, so a
/// layer's MSE is its own sensitivity, not an accumulation of upstream
/// error.
pub fn sensitivity_profile(
    pack: &WeightPack,
    cfg: &ModelConfig,
    opts: &SearchOptions,
) -> Result<SensitivityProfile> {
    if opts.candidates.is_empty() {
        bail!("sensitivity_profile: need at least one candidate config");
    }
    if opts.seq_len + 1 > cfg.max_seq {
        bail!("sensitivity seq_len {} exceeds max_seq {}", opts.seq_len, cfg.max_seq);
    }
    let mut candidates = opts.candidates.clone();
    candidates.sort_by_key(|c| (c.weight.bits, c.act.bits));
    candidates.dedup();
    for c in &candidates {
        if c.weight.is_fp() {
            bail!("sensitivity_profile ranks quantized candidates; drop '{c}'");
        }
    }

    let fp = Transformer::from_pack(pack, *cfg, &Fp32Backend)
        .context("the sensitivity search needs the fp32 weights in the pack")?;
    let tokens = calibration_tokens(cfg.vocab, opts.seqs * opts.seq_len, opts.seed);
    let mut taps: Vec<BlockTap> = Vec::with_capacity(opts.seqs);
    let mut scratch = ForwardScratch::new();
    for q in 0..opts.seqs {
        let seq = &tokens[q * opts.seq_len..(q + 1) * opts.seq_len];
        let mut cache = KvCache::new(cfg);
        let mut tap = BlockTap::new();
        fp.prefill_traced(seq, &mut cache, &mut scratch, &mut tap)?;
        taps.push(tap);
    }

    let mut layers = Vec::with_capacity(cfg.n_layers);
    for li in 0..cfg.n_layers {
        let bw = block_weights(pack, li)?;
        let mut mse = Vec::with_capacity(candidates.len());
        let mut bytes = Vec::with_capacity(candidates.len());
        for wa in &candidates {
            let ops_vec: Vec<RefLinear> = (0..7)
                .map(|pi| {
                    let (ref w, out_f, in_f) = bw.linears[pi];
                    RefLinear::new(w, out_f, in_f, *wa, &Correction::identity(in_f))
                })
                .collect();
            let ops: [&RefLinear; 7] = std::array::from_fn(|pi| &ops_vec[pi]);
            let mut sum = 0f64;
            for tap in &taps {
                let tr = &tap.blocks[li];
                let (out, _attn) = block_forward(cfg, &bw, &ops, &tr.input, tap.tokens);
                sum += mse64(&out, &tr.output);
            }
            mse.push(sum / taps.len().max(1) as f64);
            bytes.push(
                bw.linears
                    .iter()
                    .map(|&(_, out_f, in_f)| packed_linear_bytes(out_f, in_f, wa.weight.bits))
                    .sum(),
            );
        }
        layers.push(LayerSensitivity { layer: li, mse, bytes });
    }
    Ok(SensitivityProfile { candidates, layers })
}

/// Greedy marginal-utility allocation (see module docs). Starts at the
/// cheapest candidate everywhere — so an infeasibly small budget still
/// returns the floor assignment (with `total_bytes > budget_bytes`,
/// visible to the caller) instead of failing.
pub fn allocate_under_budget(profile: &SensitivityProfile, budget_bytes: usize) -> Allocation {
    let n_layers = profile.layers.len();
    let cheapest = |l: &LayerSensitivity| -> usize {
        (0..l.bytes.len()).min_by_key(|&ci| (l.bytes[ci], ci)).unwrap_or(0)
    };
    let mut per_layer: Vec<usize> = profile.layers.iter().map(cheapest).collect();
    let mut total_bytes: usize =
        profile.layers.iter().enumerate().map(|(li, l)| l.bytes[per_layer[li]]).sum();
    loop {
        // best single-layer upgrade by MSE reduction per extra byte;
        // ties break on fewer extra bytes, then lower layer/candidate
        // index — fully deterministic
        let mut best: Option<(f64, usize, usize, usize)> = None; // (gain, extra, li, ci)
        for li in 0..n_layers {
            let l = &profile.layers[li];
            let cur = per_layer[li];
            for ci in 0..profile.candidates.len() {
                if l.bytes[ci] <= l.bytes[cur] || l.mse[ci] >= l.mse[cur] {
                    continue;
                }
                let extra = l.bytes[ci] - l.bytes[cur];
                if total_bytes + extra > budget_bytes {
                    continue;
                }
                let gain = (l.mse[cur] - l.mse[ci]) / extra as f64;
                let better = match &best {
                    None => true,
                    Some(&(g, e, bl, bc)) => {
                        (gain, std::cmp::Reverse(extra), std::cmp::Reverse(li), std::cmp::Reverse(ci))
                            > (g, std::cmp::Reverse(e), std::cmp::Reverse(bl), std::cmp::Reverse(bc))
                    }
                };
                if better {
                    best = Some((gain, extra, li, ci));
                }
            }
        }
        let Some((_, extra, li, ci)) = best else { break };
        per_layer[li] = ci;
        total_bytes += extra;
    }
    let total_mse =
        profile.layers.iter().enumerate().map(|(li, l)| l.mse[per_layer[li]]).sum();
    Allocation { per_layer, total_bytes, total_mse, budget_bytes }
}

/// Project a descending budget series into a serving [`Ladder`] (see
/// module docs). Consecutive budgets that project to the same operating
/// point collapse into one rung. Returns the ladder alongside the raw
/// per-budget allocations (the mixed-precision evidence behind each
/// rung).
pub fn plan_ladder(
    profile: &SensitivityProfile,
    budgets_desc: &[usize],
) -> Result<(Ladder, Vec<Allocation>)> {
    if budgets_desc.is_empty() {
        bail!("plan_ladder: need at least one budget");
    }
    let allocations: Vec<Allocation> =
        budgets_desc.iter().map(|&b| allocate_under_budget(profile, b)).collect();
    let mut rungs: Vec<OperatingPoint> = Vec::new();
    for (i, alloc) in allocations.iter().enumerate() {
        let wa = &profile.candidates[alloc.uniform_projection()];
        let kv_bits = if i + 1 == allocations.len() && allocations.len() > 1 { 4 } else { 8 };
        let point = OperatingPoint::parse(&format!("{wa}@kv{kv_bits}"))?;
        if rungs.last() != Some(&point) {
            rungs.push(point);
        }
    }
    let ladder = Ladder { rungs };
    ladder.validate()?;
    Ok((ladder, allocations))
}

/// Human-readable allocation table (the `precision` CLI report).
pub fn report_text(profile: &SensitivityProfile, allocations: &[Allocation]) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    let _ = writeln!(
        out,
        "{:<12} {:>12} {:>12} {:>10}  per-layer bits",
        "budget", "bytes", "pred. MSE", "uniform"
    );
    for a in allocations {
        let per: Vec<String> =
            a.configs(profile).iter().map(|c| c.to_string()).collect();
        let _ = writeln!(
            out,
            "{:<12} {:>12} {:>12.4e} {:>10}  [{}]",
            a.budget_bytes,
            a.total_bytes,
            a.total_mse,
            profile.candidates[a.uniform_projection()].to_string(),
            per.join(" ")
        );
    }
    out
}

fn mse64(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| ((x - y) as f64).powi(2)).sum::<f64>() / a.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::synthetic::synthetic_trained;

    fn profile() -> SensitivityProfile {
        let sm = synthetic_trained(32, 2, 5);
        let opts = SearchOptions { seqs: 2, seq_len: 8, ..Default::default() };
        sensitivity_profile(&sm.pack, &sm.cfg, &opts).unwrap()
    }

    #[test]
    fn profile_is_deterministic_and_bytes_grow_with_bits() {
        let sm = synthetic_trained(32, 2, 5);
        let opts = SearchOptions { seqs: 2, seq_len: 8, ..Default::default() };
        let a = sensitivity_profile(&sm.pack, &sm.cfg, &opts).unwrap();
        let b = sensitivity_profile(&sm.pack, &sm.cfg, &opts).unwrap();
        for (la, lb) in a.layers.iter().zip(&b.layers) {
            assert_eq!(la.mse, lb.mse, "same pack + options must give identical MSE");
            assert_eq!(la.bytes, lb.bytes);
        }
        // candidates sorted by weight bits → bytes strictly increase
        for l in &a.layers {
            assert!(l.bytes.windows(2).all(|w| w[0] < w[1]), "bytes monotone in bits");
        }
        assert!(a.layers.iter().all(|l| l.mse.iter().all(|m| m.is_finite())));
    }

    #[test]
    fn allocation_respects_budget_and_mse_is_monotone_in_budget() {
        let p = profile();
        let lo = p.uniform_bytes(0);
        let hi = p.uniform_bytes(p.candidates.len() - 1);
        let mut prev_mse = f64::INFINITY;
        for budget in [lo, (lo + hi) / 2, hi, hi * 2] {
            let a = allocate_under_budget(&p, budget);
            assert!(
                a.total_bytes <= budget,
                "feasible budget {budget} must be respected (used {})",
                a.total_bytes
            );
            assert!(
                a.total_mse <= prev_mse + 1e-12,
                "more bytes must never predict worse MSE"
            );
            prev_mse = a.total_mse;
        }
        // an unlimited budget buys the most precise candidate everywhere
        let max = allocate_under_budget(&p, usize::MAX);
        assert!(max.per_layer.iter().all(|&ci| ci == p.candidates.len() - 1));
        // an infeasible budget returns the floor instead of failing
        let floor = allocate_under_budget(&p, 0);
        assert!(floor.per_layer.iter().all(|&ci| ci == 0));
        assert!(floor.total_bytes > 0);
    }

    #[test]
    fn planned_ladder_is_ordered_named_and_deduped() {
        let p = profile();
        let budgets = [
            p.uniform_bytes(p.candidates.len() - 1),
            p.uniform_bytes(1),
            p.uniform_bytes(0),
        ];
        let (ladder, allocs) = plan_ladder(&p, &budgets).unwrap();
        assert_eq!(allocs.len(), budgets.len());
        assert!(!ladder.is_empty());
        // rung 0 dominates the tail: uniform projections never get more
        // precise as budgets shrink
        let projections: Vec<usize> = allocs.iter().map(|a| a.uniform_projection()).collect();
        assert!(projections.windows(2).all(|w| w[0] >= w[1]));
        // the tightest rung drops KV to 4 bits, the rest serve 8
        assert_eq!(ladder.rungs.last().unwrap().kv.bits, 4);
        for r in &ladder.rungs[..ladder.len() - 1] {
            assert_eq!(r.kv.bits, 8);
        }
        assert!(!report_text(&p, &allocs).is_empty());
    }
}
