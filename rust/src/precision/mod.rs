//! Runtime precision policy — the layer that turns the paper's
//! "arbitrary-bit" freedom into a *serving* degree of freedom instead of
//! a build-time constant.
//!
//! Two halves:
//!
//! * [`Ladder`] / [`OperatingPoint`] — an ordered list of named
//!   operating points (backend spec + KV bit width), rung 0 the most
//!   precise. The serving autopilot walks down this ladder under load
//!   (pool pressure or latency-SLO violation) and back up when load
//!   drops ([`crate::coordinator::AutopilotConfig`],
//!   `docs/SERVING.md` §adaptive precision). `EngineBuilder::
//!   build_adaptive` prepares every rung from **one** artifacts read,
//!   de-duplicating prepared weights across rungs that share a backend.
//! * [`search`] — a sensitivity-ranked per-layer bit-allocation search
//!   under a global weight-byte budget, scored by the calibration
//!   subsystem's block-tap MSE machinery (`docs/CALIBRATION.md`):
//!   [`search::sensitivity_profile`] measures each block's output MSE at
//!   every candidate WqAp config against the fp32 taps,
//!   [`search::allocate_under_budget`] greedily spends bytes where they
//!   buy the most MSE, and [`search::plan_ladder`] projects a descending
//!   budget series into a [`Ladder`] (FineQuant-style fine-grained
//!   allocation, uniform-rung projection for the current engine).

pub mod search;

use anyhow::{bail, Result};

use crate::model::KvCacheConfig;
use crate::quant::WAConfig;

pub use search::{
    allocate_under_budget, plan_ladder, sensitivity_profile, Allocation, LayerSensitivity,
    SearchOptions, SensitivityProfile,
};

/// One rung of the precision ladder: a backend spec the engine registry
/// resolves, the KV cache storage config the rung serves at, and the
/// name it routes/gauges under (unique within a ladder).
#[derive(Clone, Debug, PartialEq)]
pub struct OperatingPoint {
    /// routing tag + gauge label, e.g. `w4a4-kv8` (unique per ladder)
    pub name: String,
    /// registry spec, e.g. `abq:w4a4` or `fp32`
    pub backend: String,
    /// KV page storage for this rung (bits 32/8/4 + block size)
    pub kv: KvCacheConfig,
}

impl OperatingPoint {
    /// Build a rung from a `<config>[@kv<bits>]` fragment: `w6a6@kv8`,
    /// `abq:w2*a8@kv4`, `fp32@kv32`. Omitted KV defaults to 8-bit.
    pub fn parse(s: &str) -> Result<Self> {
        let s = s.trim();
        let (cfg_part, kv_part) = match s.split_once('@') {
            Some((c, k)) => (c.trim(), Some(k.trim())),
            None => (s, None),
        };
        let kv_bits: u8 = match kv_part {
            None => 8,
            Some(k) => {
                let digits = k.strip_prefix("kv").unwrap_or(k);
                digits
                    .parse()
                    .map_err(|e| anyhow::anyhow!("operating point '{s}': kv bits: {e}"))?
            }
        };
        if !matches!(kv_bits, 32 | 8 | 4) {
            bail!("operating point '{s}': kv bits must be 32, 8 or 4");
        }
        let (backend, tag) = match cfg_part {
            "fp32" | "fp16" | "fp" => ("fp32".to_string(), "fp16".to_string()),
            other => {
                let bare = other.strip_prefix("abq:").unwrap_or(other);
                let wa: WAConfig = bare
                    .parse()
                    .map_err(|e| anyhow::anyhow!("operating point '{s}': {e}"))?;
                (format!("abq:{wa}"), wa.tag())
            }
        };
        Ok(OperatingPoint {
            name: format!("{tag}-kv{kv_bits}"),
            backend,
            kv: KvCacheConfig { bits: kv_bits, block_size: KvCacheConfig::FP32.block_size },
        })
    }
}

/// An ordered precision ladder: rung 0 is the most precise operating
/// point (where the autopilot starts and returns to), the last rung the
/// cheapest the deployment is willing to degrade to.
#[derive(Clone, Debug, PartialEq)]
pub struct Ladder {
    pub rungs: Vec<OperatingPoint>,
}

impl Ladder {
    /// Parse a comma-separated rung list, most precise first:
    /// `w6a6@kv8,w4a4@kv8,w2*a8@kv4` (the `--ladder` flag format).
    pub fn parse(spec: &str) -> Result<Self> {
        let rungs: Vec<OperatingPoint> = spec
            .split(',')
            .filter(|s| !s.trim().is_empty())
            .map(OperatingPoint::parse)
            .collect::<Result<_>>()?;
        let ladder = Ladder { rungs };
        ladder.validate()?;
        Ok(ladder)
    }

    /// The ROADMAP's default degradation ladder:
    /// w6a6 (KV 8) → w4a4 (KV 8) → w2*a8 (KV 4).
    pub fn default_ladder() -> Self {
        Ladder::parse("w6a6@kv8,w4a4@kv8,w2*a8@kv4")
            .expect("the built-in default ladder must parse")
    }

    pub fn validate(&self) -> Result<()> {
        if self.rungs.is_empty() {
            bail!("a precision ladder needs at least one rung");
        }
        for (i, r) in self.rungs.iter().enumerate() {
            if self.rungs[..i].iter().any(|p| p.name == r.name) {
                bail!("duplicate ladder rung '{}' — rung names route traffic", r.name);
            }
        }
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.rungs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rungs.is_empty()
    }

    pub fn names(&self) -> Vec<&str> {
        self.rungs.iter().map(|r| r.name.as_str()).collect()
    }

    /// Override the KV block size on every rung (the `--kv-block` flag
    /// applies fleet-wide; bits stay per-rung).
    pub fn set_block_size(&mut self, block_size: usize) {
        for r in &mut self.rungs {
            r.kv.block_size = block_size;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_rungs_with_and_without_kv() {
        let p = OperatingPoint::parse("w6a6@kv8").unwrap();
        assert_eq!(p.name, "w6a6-kv8");
        assert_eq!(p.backend, "abq:w6a6");
        assert_eq!(p.kv.bits, 8);
        let q = OperatingPoint::parse("abq:w2*a8@kv4").unwrap();
        assert_eq!(q.name, "w2sa8-kv4");
        assert_eq!(q.backend, "abq:w2*a8");
        assert_eq!(q.kv.bits, 4);
        let r = OperatingPoint::parse("w4a4").unwrap();
        assert_eq!(r.kv.bits, 8, "omitted kv defaults to 8");
        let fp = OperatingPoint::parse("fp32@kv32").unwrap();
        assert_eq!(fp.name, "fp16-kv32");
        assert_eq!(fp.backend, "fp32");
        assert!(OperatingPoint::parse("w4a4@kv7").is_err(), "kv bits are 32/8/4");
        assert!(OperatingPoint::parse("w99a99").is_err());
    }

    #[test]
    fn default_ladder_matches_the_roadmap_shape() {
        let l = Ladder::default_ladder();
        assert_eq!(l.names(), vec!["w6a6-kv8", "w4a4-kv8", "w2sa8-kv4"]);
        assert_eq!(l.rungs[0].backend, "abq:w6a6");
        assert_eq!(l.rungs[2].kv.bits, 4);
    }

    #[test]
    fn duplicate_rung_names_are_rejected() {
        assert!(Ladder::parse("w4a4@kv8,w4a4@kv8").is_err());
        // same config at different KV widths is two distinct rungs
        assert!(Ladder::parse("w4a4@kv8,w4a4@kv4").is_ok());
        assert!(Ladder::parse("").is_err());
    }

    #[test]
    fn block_size_override_applies_to_every_rung() {
        let mut l = Ladder::default_ladder();
        l.set_block_size(32);
        assert!(l.rungs.iter().all(|r| r.kv.block_size == 32));
        assert_eq!(l.rungs[2].kv.bits, 4, "bits stay per-rung");
    }
}
