//! abq-llm — CLI for the ABQ-LLM reproduction.
//!
//! Subcommands:
//!   info                         artifact + engine health report
//!   run      [--prompt 1,2,3]    greedy generation from a token prompt
//!   serve    [--addr HOST:PORT]  TCP line-protocol serving (JSON in/out)
//!            [--replicas N]      N workers over one shared weight set
//!            [--autopilot]       SLA-driven adaptive precision: serve a
//!            [--ladder SPEC]     ladder of operating points (default
//!            [--slo-ttft-ms N]   w6a6@kv8,w4a4@kv8,w2*a8@kv4), walking
//!                                down under SLO/pool pressure and back
//!                                up when load drops
//!   precision [--budget-mb A,B]  sensitivity-ranked per-layer bit
//!                                allocation search → ladder plan
//!   eval     [--config w2*a8]    perplexity on the held-out corpus
//!   zeroshot [--config w2*a8]    synthetic zero-shot task suite
//!   calibrate [--config w2*a8]   learn distribution corrections (DLC)
//!                                and report before/after perplexity
//!   gemm     [--m --n --k --w --a] one arbitrary-bit GEMM timing
//!   pjrt     [--artifact NAME]   run a PJRT artifact end to end
//!
//! Backends: `--backend fp32|int8|int4|abq` (abq takes `--config`), or a
//! full registry spec directly: `--backend abq:w3a8`. All model
//! construction goes through `engine::EngineBuilder`; calibrated
//! corrections registered in the manifest are applied automatically
//! (disable with `--no-correction`).
//!
//! Self-speculative decoding (`run` and `serve`, docs/SPECULATIVE.md):
//! `--spec-draft w2*a8 --spec-k 4` drafts 4 tokens per round with a
//! w2*a8 instantiation of the same weights and verifies them in one
//! target-precision pass — lossless under greedy decoding.
//!
//! Prefix cache (`serve`, docs/SERVING.md §prefix cache):
//! `--prefix-cache` shares the KV of common prompt prefixes across
//! requests via copy-on-write block attach; `--session-dir DIR` also
//! persists them as `.abqs` session files, warm across restarts.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpListener;
use std::path::PathBuf;
use std::sync::Arc;

use anyhow::Result;

use abq_llm::abq::{BitPlanes, OptLevel};
use abq_llm::coordinator::{AutopilotConfig, Frontend, FrontendConfig, SubmitRequest};
use abq_llm::engine::{
    backend_tag, EngineBuilder, InferenceEngine, KvCacheConfig, Ladder, SpecConfig,
};
use abq_llm::eval;
use abq_llm::quant::WAConfig;
use abq_llm::util::cli::Args;
use abq_llm::util::json::{self, Json};

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get_or("artifacts", "artifacts"))
}

/// `--backend`/`--config` → registry spec string (`fp32`, `abq:w2*a8`, ...).
fn backend_spec(args: &Args) -> Result<String> {
    let backend = args.get_or("backend", "abq");
    Ok(match backend.as_str() {
        "fp32" | "fp16" => "fp32".to_string(),
        "int8" => "int8".to_string(),
        "int4" => "int4".to_string(),
        "abq" => format!("abq:{}", args.get_or("config", "w2*a8")),
        // anything else is a full spec already ("abq:w3a8", "w2sa8", ...)
        other => other.to_string(),
    })
}

fn builder_from(args: &Args) -> Result<EngineBuilder> {
    // --arch <zoo name> serves a registry architecture with random
    // weights (seeded by --seed) instead of loading artifacts — how
    // GQA/variant entries run end-to-end before a checkpoint exists
    let mut b = match args.get("arch") {
        Some(name) => {
            let entry = abq_llm::model::zoo::lookup(&name).ok_or_else(|| {
                anyhow::anyhow!(
                    "--arch {name:?} is not in the model zoo (known: {})",
                    abq_llm::model::zoo::entries()
                        .iter()
                        .map(|e| e.name())
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })?;
            let seed = args.get_usize("seed", 7) as u64;
            EngineBuilder::new().random_weights(entry.cfg, seed)
        }
        None => EngineBuilder::new().weights(artifacts_dir(args)),
    }
    .backend(backend_spec(args)?);
    if let Some(n) = args.get("threads").and_then(|v| v.parse::<usize>().ok()) {
        b = b.threads(n);
    }
    // paged KV storage: --kv-bits 32|8|4 [--kv-block N] [--kv-pool-mb M]
    if let Some(bits) = args.get("kv-bits").and_then(|v| v.parse::<u8>().ok()) {
        let block_size = args
            .get("kv-block")
            .and_then(|v| v.parse::<usize>().ok())
            .unwrap_or(KvCacheConfig::FP32.block_size);
        b = b.kv_cache(KvCacheConfig { bits, block_size });
    }
    if let Some(mb) = args.get("kv-pool-mb").and_then(|v| v.parse::<usize>().ok()) {
        b = b.kv_pool_bytes(mb * 1024 * 1024);
    }
    if args.has_flag("no-correction") {
        b = b.correction_off();
    }
    // self-speculative decoding: --spec-draft w2*a8 [--spec-k 4]
    if let Some(draft) = args.get("spec-draft") {
        let wa: WAConfig =
            draft.parse().map_err(|e| anyhow::anyhow!("--spec-draft: {e}"))?;
        let k = args.get_usize("spec-k", 4);
        b = b.speculative(SpecConfig::new(wa, k));
    }
    Ok(b)
}

fn load_engine(args: &Args) -> Result<Box<dyn InferenceEngine>> {
    builder_from(args)?.build()
}

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.subcommand.as_deref() {
        Some("info") => cmd_info(&args),
        Some("run") => cmd_run(&args),
        Some("serve") => cmd_serve(&args),
        Some("eval") => cmd_eval(&args),
        Some("zeroshot") => cmd_zeroshot(&args),
        Some("calibrate") => cmd_calibrate(&args),
        Some("precision") => cmd_precision(&args),
        Some("gemm") => cmd_gemm(&args),
        Some("pjrt") => cmd_pjrt(&args),
        _ => {
            eprintln!(
                "usage: abq-llm <info|run|serve|eval|zeroshot|calibrate|precision|gemm|pjrt> \
                 [--artifacts DIR | --arch ZOO_NAME [--seed N]] \
                 [--backend fp32|int8|int4|abq] [--config w2*a8] \
                 [--threads N] [--no-correction] \
                 [--spec-draft w2*a8 --spec-k 4] \
                 [--prefix-cache [--session-dir DIR]] [--replicas N] \
                 [--autopilot [--ladder SPEC] [--slo-ttft-ms N]] ..."
            );
            Ok(())
        }
    }
}

/// Greedy generation from a token prompt, with optional self-speculative
/// decoding (`--spec-draft w2*a8 --spec-k 4`). Prints the committed
/// stream, tokens/s, and — when speculating — the acceptance rate.
fn cmd_run(args: &Args) -> Result<()> {
    let engine = load_engine(args)?;
    let prompt: Vec<u32> = args
        .get_or("prompt", "1,2,3,4")
        .split(',')
        .filter(|s| !s.trim().is_empty())
        .map(|s| s.trim().parse::<u32>().map_err(|e| anyhow::anyhow!("--prompt: {e}")))
        .collect::<Result<_>>()?;
    let max_new = args.get_usize("max-new", 32);
    let t0 = std::time::Instant::now();
    let (tokens, stats) = match engine.spec_config() {
        Some(_) => {
            let (toks, stats) = abq_llm::spec::generate_speculative(engine.as_ref(), &prompt, max_new)?;
            (toks, Some(stats))
        }
        None => (abq_llm::engine::generate(engine.as_ref(), &prompt, max_new)?, None),
    };
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "backend={} prompt={} tokens -> {} new tokens in {:.3}s ({:.1} tok/s)",
        engine.spec().backend,
        prompt.len(),
        tokens.len(),
        secs,
        tokens.len() as f64 / secs.max(1e-9)
    );
    if let (Some(stats), Some(sc)) = (stats, engine.spec_config()) {
        println!(
            "speculative: draft={} k={} rounds={} drafted={} accepted={} ({:.1}% acceptance)",
            sc.draft,
            sc.k,
            stats.rounds,
            stats.drafted,
            stats.accepted,
            stats.acceptance_rate() * 100.0
        );
    }
    println!(
        "tokens: {}",
        tokens.iter().map(|t| t.to_string()).collect::<Vec<_>>().join(" ")
    );
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    println!("abq-llm — arbitrary-bit quantized inference (ABQ-LLM reproduction)");
    #[cfg(feature = "pjrt")]
    println!(
        "pjrt cpu client: {}",
        if abq_llm::runtime::pjrt_cpu_ok() { "ok" } else { "UNAVAILABLE" }
    );
    #[cfg(not(feature = "pjrt"))]
    println!("pjrt cpu client: disabled (rebuild with --features pjrt)");
    println!(
        "registered backends: {}",
        abq_llm::engine::BackendRegistry::with_defaults().families().join(", ")
    );
    println!("model zoo (serve any with --arch NAME):");
    for e in abq_llm::model::zoo::entries() {
        let c = &e.cfg;
        println!(
            "  - {}: {:.1}M params, {}L x {}d, {}q/{}kv heads (kv_dim {}), {:?} — {}",
            c.name,
            c.param_count() as f64 / 1e6,
            c.n_layers,
            c.d_model,
            c.n_heads,
            c.n_kv_heads,
            c.kv_dim(),
            e.family,
            e.description
        );
    }
    let dir = artifacts_dir(args);
    match std::fs::read_to_string(dir.join("manifest.json")) {
        Ok(text) => {
            let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
            println!("artifacts dir: {dir:?}");
            if let Some(p) = j.get("fp_ppl").and_then(|v| v.as_f64()) {
                println!("fp model held-out PPL: {p:.3}");
            }
            if let Some(arr) = j.get("artifacts").and_then(|a| a.as_arr()) {
                println!("compiled artifacts:");
                for a in arr {
                    println!("  - {}", a.get("name").and_then(|v| v.as_str()).unwrap_or("?"));
                }
            }
            if let Some(arr) = j.get("quant_configs").and_then(|a| a.as_arr()) {
                println!("calibrated quant configs:");
                for a in arr {
                    println!("  - {}", a.get("name").and_then(|v| v.as_str()).unwrap_or("?"));
                }
            }
        }
        Err(_) => println!("no artifacts at {dir:?} (run `make artifacts`)"),
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let engine = load_engine(args)?;
    let n = args.get_usize("seqs", 16);
    let len = args.get_usize("seq-len", 128);
    let ppl = eval::perplexity(engine.as_ref(), n, len, eval::corpus::EVAL_SEED)?;
    println!(
        "backend={} held-out perplexity over {n}x{len} tokens: {ppl:.3}",
        engine.spec().backend
    );
    Ok(())
}

fn cmd_zeroshot(args: &Args) -> Result<()> {
    let engine = load_engine(args)?;
    let n = args.get_usize("items", 50);
    println!("zero-shot suite, backend={}, {n} items/task", engine.spec().backend);
    let mut total = 0.0;
    for task in eval::ALL_TASKS {
        let acc = eval::accuracy(engine.as_ref(), task, n, 11)?;
        total += acc;
        println!("  {:<18} {:5.1}%", eval::task_name(task), acc * 100.0);
    }
    println!(
        "  {:<18} {:5.1}%",
        "average",
        total / eval::ALL_TASKS.len() as f64 * 100.0
    );
    Ok(())
}

/// Learn distribution corrections for one WqAp config against the fp32
/// weights in the artifacts directory, persist them (correction pack +
/// manifest entry), and report per-block MSE plus before/after held-out
/// perplexity (`docs/CALIBRATION.md`).
fn cmd_calibrate(args: &Args) -> Result<()> {
    use abq_llm::calib::{calibrate, CalibOptions};
    use abq_llm::model::{ModelConfig, WeightPack};
    use abq_llm::quant::WAConfig;
    use abq_llm::runtime::artifacts::{upsert_correction, CorrectionEntry};

    let dir = artifacts_dir(args);
    let config = args.get_or("config", "w2*a8");
    let wa: WAConfig = config.parse().map_err(|e| anyhow::anyhow!("--config: {e}"))?;
    let opts = CalibOptions {
        seqs: args.get_usize("seqs", CalibOptions::default().seqs),
        seq_len: args.get_usize("seq-len", CalibOptions::default().seq_len),
        seed: args.get_usize("seed", 0xCA11B) as u64,
        lambda_attn: args.get_f64("lambda", CalibOptions::default().lambda_attn),
        refine_channels: args
            .get_usize("refine-channels", CalibOptions::default().refine_channels),
        max_eval_rows: args.get_usize("eval-rows", CalibOptions::default().max_eval_rows),
        rounds: args.get_usize("rounds", CalibOptions::default().rounds),
    };
    if let Some(n) = args.get("threads").and_then(|v| v.parse::<usize>().ok()) {
        abq_llm::util::par::set_threads(n);
    }

    let pack = WeightPack::load(&dir.join("weights.abqw"))?;
    let manifest_path = dir.join("manifest.json");
    let manifest_text = std::fs::read_to_string(&manifest_path)?;
    let mut manifest =
        Json::parse(&manifest_text).map_err(|e| anyhow::anyhow!("manifest parse: {e}"))?;
    let cfg = ModelConfig::from_manifest(&manifest)?;

    println!(
        "calibrating {config} on {} seqs x {} tokens (seed {:#x}, lambda {})",
        opts.seqs, opts.seq_len, opts.seed, opts.lambda_attn
    );
    let result = calibrate(&pack, &cfg, wa, &opts)?;
    print!("{}", result.report_text());

    // persist: correction pack next to the weights + manifest entry
    let rel = format!("corrections.{}.abqw", wa.tag());
    result.set.to_pack().save(&dir.join(&rel))?;
    let entry = CorrectionEntry {
        config: config.clone(),
        tag: wa.tag(),
        path: dir.join(&rel),
        seed: opts.seed,
        seqs: opts.seqs,
        seq_len: opts.seq_len,
    };
    upsert_correction(&mut manifest, &entry, &rel);
    std::fs::write(&manifest_path, manifest.to_string_pretty())?;
    println!(
        "saved {} corrections ({} non-identity) to {rel} + manifest entry",
        result.set.len(),
        result.set.non_identity()
    );

    // eval-integrated before/after report on the held-out corpus
    let n = args.get_usize("eval-seqs", 8);
    let len = args.get_usize("eval-seq-len", 64);
    let spec = format!("abq:{config}");
    let before = EngineBuilder::new()
        .weights(&dir)
        .backend(&spec)
        .correction_off()
        .build()?;
    let ppl_before = eval::perplexity(before.as_ref(), n, len, eval::corpus::EVAL_SEED)?;
    let after = EngineBuilder::new()
        .weights(&dir)
        .backend(&spec)
        .correction(result.set.clone())
        .build()?;
    let ppl_after = eval::perplexity(after.as_ref(), n, len, eval::corpus::EVAL_SEED)?;
    println!(
        "held-out perplexity ({n}x{len}): uncalibrated {ppl_before:.3} -> calibrated {ppl_after:.3}"
    );
    Ok(())
}

/// Sensitivity-ranked per-layer bit-allocation search
/// (docs/SERVING.md §adaptive precision): measure each block's output
/// MSE at every candidate WqAp config against fp32 block taps, greedily
/// spend a descending byte-budget series where the bytes buy the most
/// MSE, and print the allocation table plus the projected serving
/// ladder (`--ladder` input for `serve --autopilot`).
fn cmd_precision(args: &Args) -> Result<()> {
    use abq_llm::model::{ModelConfig, WeightPack};
    use abq_llm::precision::{plan_ladder, sensitivity_profile, SearchOptions};

    let dir = artifacts_dir(args);
    let pack = WeightPack::load(&dir.join("weights.abqw"))?;
    let manifest_text = std::fs::read_to_string(dir.join("manifest.json"))?;
    let manifest =
        Json::parse(&manifest_text).map_err(|e| anyhow::anyhow!("manifest parse: {e}"))?;
    let cfg = ModelConfig::from_manifest(&manifest)?;
    if let Some(n) = args.get("threads").and_then(|v| v.parse::<usize>().ok()) {
        abq_llm::util::par::set_threads(n);
    }
    let defaults = SearchOptions::default();
    let opts = SearchOptions {
        seqs: args.get_usize("seqs", defaults.seqs),
        seq_len: args.get_usize("seq-len", defaults.seq_len),
        ..defaults
    };
    println!(
        "profiling per-layer sensitivity on {} seqs x {} tokens ({} candidates)",
        opts.seqs,
        opts.seq_len,
        opts.candidates.len()
    );
    let profile = sensitivity_profile(&pack, &cfg, &opts)?;
    // budget series: --budget-mb A,B,C (descending), or the uniform cost
    // of every candidate config, densest first
    let budgets: Vec<usize> = match args.get("budget-mb") {
        Some(spec) => spec
            .split(',')
            .filter(|s| !s.trim().is_empty())
            .map(|s| {
                s.trim()
                    .parse::<usize>()
                    .map(|mb| mb * 1024 * 1024)
                    .map_err(|e| anyhow::anyhow!("--budget-mb: {e}"))
            })
            .collect::<Result<_>>()?,
        None => (0..profile.candidates.len()).rev().map(|ci| profile.uniform_bytes(ci)).collect(),
    };
    let (ladder, allocations) = plan_ladder(&profile, &budgets)?;
    print!("{}", abq_llm::precision::search::report_text(&profile, &allocations));
    println!("ladder: {}", ladder.names().join(" → "));
    println!("(pass the rung list to `serve --autopilot --ladder ...`)");
    Ok(())
}

fn cmd_gemm(args: &Args) -> Result<()> {
    let m = args.get_usize("m", 1);
    let n = args.get_usize("n", 4096);
    let k = args.get_usize("k", 4096);
    let wb = args.get_usize("w", 2);
    let ab = args.get_usize("a", 8);
    let mut rng = abq_llm::util::rng::SplitMix::new(1);
    let xc: Vec<u8> = (0..m * k).map(|_| rng.next_below(1 << ab) as u8).collect();
    let wc: Vec<u8> = (0..n * k).map(|_| rng.next_below(1 << wb) as u8).collect();
    let x = BitPlanes::pack(&xc, m, k, ab);
    let w = BitPlanes::pack(&wc, n, k, wb);
    let zx = vec![1 << (ab - 1); m];
    let zw = vec![1 << (wb - 1); n];
    let b = abq_llm::util::bench::Bencher::default();
    for (label, opt) in [
        ("naive", OptLevel::Naive),
        ("pipelined", OptLevel::Pipelined),
        ("gemv-elim", OptLevel::GemvElim),
        ("auto", OptLevel::Auto),
    ] {
        let meas = b.run(label, || {
            let out = abq_llm::abq::gemm_int(&x, &w, &zx, &zw, opt, None);
            std::hint::black_box(&out);
        });
        println!(
            "w{wb}a{ab} {m}x{n}x{k} {label:<10} {:10.1} us  {:6.3} TOPS",
            meas.mean_us(),
            meas.tops(m, n, k)
        );
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_pjrt(args: &Args) -> Result<()> {
    use anyhow::Context as _;
    let dir = artifacts_dir(args);
    let name = args.get_or("artifact", "model_fp16_prefill");
    let steps = args.get_usize("steps", 8);
    let summary = abq_llm::engine::pjrt::run_artifact(&dir, &name, steps)
        .with_context(|| format!("run PJRT artifact '{name}'"))?;
    print!("{summary}");
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_pjrt(_args: &Args) -> Result<()> {
    anyhow::bail!("this build has no PJRT support (rebuild with `--features pjrt`)")
}

/// TCP line-protocol server: one JSON object per line.
/// Request:  `{"prompt": [1,2,3], "max_new": 16, "config": "w2sa8",
///            "affinity": 42}` (`affinity` optional — sticky routing)
/// Response: `{"id": 1, "tokens": [...], "queue_us": .., "prefill_us": ..,
///            "decode_us": ..}`
fn cmd_serve(args: &Args) -> Result<()> {
    let addr = args.get_or("addr", "127.0.0.1:7070");
    // prefix cache: --prefix-cache [--session-dir DIR]
    // (docs/SERVING.md §prefix cache)
    let prefix_cache = args.has_flag("prefix-cache");
    let session_dir = args.get("session-dir").map(PathBuf::from);
    if session_dir.is_some() && !prefix_cache {
        eprintln!("note: --session-dir has no effect without --prefix-cache");
    }

    let server = if args.has_flag("autopilot") {
        // adaptive precision (docs/SERVING.md §adaptive precision): one
        // worker per ladder rung, all rungs prepared from one artifacts
        // read; the autopilot walks the ladder against the TTFT SLO and
        // KV pool occupancy. Replaces the fixed-config fleet (including
        // the fp16 A/B replica — add an fp rung to the ladder instead).
        let mut ladder = match args.get("ladder") {
            Some(spec) => Ladder::parse(&spec)?,
            None => Ladder::default_ladder(),
        };
        if let Some(bs) = args.get("kv-block").and_then(|v| v.parse::<usize>().ok()) {
            ladder.set_block_size(bs);
        }
        let rungs = builder_from(args)?.build_adaptive(&ladder)?;
        let pilot = AutopilotConfig {
            slo_ttft_us: args.get_usize("slo-ttft-ms", 250) as u64 * 1000,
            poll_ms: args.get_usize("autopilot-poll-ms", 200) as u64,
            ..Default::default()
        };
        println!(
            "serving adaptive ladder {} on {addr} (TTFT SLO p95 ≤ {} ms, poll {} ms)",
            ladder.names().join(" → "),
            pilot.slo_ttft_us / 1000,
            pilot.poll_ms
        );
        for (op, engine) in &rungs {
            let mem = engine.memory_report();
            println!(
                "  rung {}: {:.2} MB weights ({:.2} MB incremental), KV {} bits",
                op.name,
                mem.weight_bytes as f64 / 1e6,
                mem.weight_bytes_incremental as f64 / 1e6,
                op.kv.bits
            );
        }
        Frontend::start_adaptive(
            rungs,
            FrontendConfig { prefix_cache, session_dir, ..Default::default() },
            pilot,
        )?
    } else {
        // load requested replicas: default = the requested backend + fp16
        // for A/B. Backends without a WqAp artifact tag (int8, int4)
        // route under their spec string. `--replicas N` runs N copies of
        // the primary config over one shared weight set (zero-copy mmap
        // on artifact engines — docs/SERVING.md §multi-replica).
        let mut replicas: Vec<(String, Arc<dyn InferenceEngine>)> = Vec::new();
        let primary_spec = backend_spec(args)?;
        let primary_tag = backend_tag(&primary_spec).unwrap_or_else(|_| primary_spec.clone());
        let n_replicas = args.get_usize("replicas", 1).max(1);
        if n_replicas > 1 {
            for engine in builder_from(args)?.build_replicas(n_replicas)? {
                replicas.push((primary_tag.clone(), engine));
            }
        } else {
            replicas.push((primary_tag.clone(), builder_from(args)?.build_arc()?));
        }
        if !args.has_flag("no-fp16") && primary_tag != "fp16" {
            let fp = builder_from(args)?.backend("fp32").build_arc()?;
            replicas.push(("fp16".to_string(), fp));
        }
        let default_tag = replicas[0].0.clone();
        let m = replicas[0].1.spec().model;
        println!(
            "serving {} [{} heads over {} kv, kv_dim {}] — {} on {addr} (default config {default_tag})",
            m.name,
            m.n_heads,
            m.n_kv_heads,
            m.kv_dim(),
            replicas.iter().map(|(t, _)| t.as_str()).collect::<Vec<_>>().join(", ")
        );
        for (tag, engine) in &replicas {
            let mem = engine.memory_report();
            println!(
                "  replica {tag}: {:.2} MB weights ({:.2} MB incremental), {:.2} MB KV/session (full)",
                mem.weight_bytes as f64 / 1e6,
                mem.weight_bytes_incremental as f64 / 1e6,
                mem.kv_bytes_per_session as f64 / 1e6
            );
            if let Some(st) = engine.kv_pool_status() {
                println!(
                    "    KV pool: {} blocks × {} positions @ {} bits ({:.2} MB budget)",
                    st.total_blocks,
                    st.block_size,
                    st.bits,
                    (st.total_blocks * st.block_bytes) as f64 / 1e6
                );
            }
            if let Some(sc) = engine.spec_config() {
                println!(
                    "    speculative: draft {} × k {} ({:.2} MB draft weights + {:.2} MB draft pool)",
                    sc.draft,
                    sc.k,
                    mem.spec_draft_weight_bytes as f64 / 1e6,
                    mem.spec_draft_pool_bytes as f64 / 1e6
                );
            }
        }
        Frontend::start(
            replicas,
            FrontendConfig { default_tag, prefix_cache, session_dir, ..Default::default() },
        )?
    };
    println!(
        "  kernel ISA: {} (detected best: {}; override with ABQ_ISA)",
        abq_llm::abq::isa::ceiling(),
        abq_llm::abq::isa::detect_best()
    );
    if prefix_cache {
        match args.get("session-dir") {
            Some(d) => println!("  prefix cache: on (sessions persisted under {d:?})"),
            None => println!("  prefix cache: on (in-memory only)"),
        }
    }

    let listener = TcpListener::bind(&addr)?;
    for stream in listener.incoming() {
        let mut stream = stream?;
        let peer = stream.peer_addr()?;
        let reader = BufReader::new(stream.try_clone()?);
        for line in reader.lines() {
            let line = line?;
            if line.trim().is_empty() {
                continue;
            }
            let j = match Json::parse(&line) {
                Ok(j) => j,
                Err(e) => {
                    writeln!(stream, "{{\"error\": \"parse: {e}\"}}")?;
                    continue;
                }
            };
            let prompt: Vec<u32> = j
                .get("prompt")
                .and_then(|p| p.as_arr())
                .map(|a| a.iter().filter_map(|v| v.as_f64()).map(|v| v as u32).collect())
                .unwrap_or_default();
            if prompt.is_empty() {
                writeln!(stream, "{{\"error\": \"empty prompt\"}}")?;
                continue;
            }
            let max_new = j.get("max_new").and_then(|v| v.as_usize()).unwrap_or(16);
            let mut req = SubmitRequest::new(prompt, max_new);
            if let Some(c) = j.get("config").and_then(|v| v.as_str()) {
                req.config_tag = c.to_string();
            }
            if let Some(fp) = j.get("affinity").and_then(|v| v.as_f64()) {
                req.session_affinity = Some(fp as u64);
            }
            let ticket = match server.submit(req) {
                Ok(t) => t,
                Err(e) => {
                    writeln!(stream, "{{\"error\": \"{e}\"}}")?;
                    continue;
                }
            };
            match ticket.rx.recv() {
                Ok(resp) => {
                    let out = json::obj(vec![
                        ("id", json::num(resp.id as f64)),
                        (
                            "tokens",
                            Json::Arr(
                                resp.tokens.iter().map(|&t| json::num(t as f64)).collect(),
                            ),
                        ),
                        ("queue_us", json::num(resp.timing.queue_us as f64)),
                        ("prefill_us", json::num(resp.timing.prefill_us as f64)),
                        ("decode_us", json::num(resp.timing.decode_us as f64)),
                    ]);
                    let mut text = out.to_string_pretty();
                    text.retain(|c| c != '\n');
                    writeln!(stream, "{text}")?;
                }
                Err(_) => writeln!(stream, "{{\"error\": \"request dropped\"}}")?,
            }
        }
        println!("client {peer} disconnected; metrics:\n{}", server.metrics.snapshot());
    }
    Ok(())
}
