//! Synthetic zero-shot task suite (the paper's PIQA/ARC/BoolQ/HellaSwag/
//! Winogrande substitution — DESIGN.md §4). Same scoring mechanism as
//! lm-evaluation-harness: per choice, the (length-normalised) logprob of
//! the continuation given the context; accuracy = argmax matches gold.
//!
//! Tasks are built from the corpus ground truth (the transition table), so
//! a model that learned the distribution scores far above chance and a
//! quantization-damaged model drops toward chance — the same signal the
//! paper's Tables 3/8-11 measure.

use anyhow::Result;

use crate::engine::{EngineSession, InferenceEngine};
use crate::util::rng::SplitMix;

use super::corpus::{self, TransitionTable, BOS, BRANCH, RESTART_POOL, VOCAB};

#[derive(Clone, Debug)]
pub struct TaskItem {
    pub context: Vec<u32>,
    pub choices: Vec<Vec<u32>>,
    pub gold: usize,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    /// pick the true next token among 3 corpus-frequency distractors
    NextToken,
    /// pick the valid 2-token transition chain vs corrupted chains
    ChainCompletion,
    /// pick the continuation consistent with the sentence's topic token
    TopicConsistency,
    /// detect the sentence boundary (BOS) vs random tokens
    BoundaryDetect,
    /// rank the high-probability successor branch above the lowest one
    FreqPlausibility,
    /// NextToken with distractors drawn from a *different* state's
    /// successors (near-miss distractors — the hard variant)
    NearMiss,
}

pub const ALL_TASKS: [Task; 6] = [
    Task::NextToken,
    Task::ChainCompletion,
    Task::TopicConsistency,
    Task::BoundaryDetect,
    Task::FreqPlausibility,
    Task::NearMiss,
];

pub fn task_name(t: Task) -> &'static str {
    match t {
        Task::NextToken => "next_token",
        Task::ChainCompletion => "chain_completion",
        Task::TopicConsistency => "topic_consistency",
        Task::BoundaryDetect => "boundary_detect",
        Task::FreqPlausibility => "freq_plausibility",
        Task::NearMiss => "near_miss",
    }
}

fn state_of(cur: u32, topic: u32) -> usize {
    (1 + ((cur as u64 - 1) + (topic as u64 - 1)) % (VOCAB as u64 - 1)) as usize
}

fn walk(table: &TransitionTable, topic: u32, start: u32, len: usize, rng: &mut SplitMix) -> Vec<u32> {
    // deterministic most-likely walk with a bit of branch noise
    let mut out = vec![BOS, topic];
    let mut cur = start;
    for _ in 0..len {
        out.push(cur);
        let st = state_of(cur, topic);
        let b = if rng.next_f64() < 0.7 { 0 } else { rng.next_below(BRANCH as u64) as usize };
        cur = table.succ[st * BRANCH + b];
    }
    out
}

fn succ_of(table: &TransitionTable, cur: u32, topic: u32, branch: usize) -> u32 {
    table.succ[state_of(cur, topic) * BRANCH + branch]
}

/// Generate `n` items for a task (deterministic per seed).
pub fn generate_items(table: &TransitionTable, task: Task, n: usize, seed: u64) -> Vec<TaskItem> {
    let mut rng = SplitMix::new(seed ^ 0xD15C0);
    let mut items = Vec::with_capacity(n);
    while items.len() < n {
        let topic = 1 + rng.next_below(RESTART_POOL) as u32;
        let start = topic;
        let ctx_len = 6 + rng.next_below(10) as usize;
        let context = walk(table, topic, start, ctx_len, &mut rng);
        let cur = *context.last().unwrap();
        let gold_tok = succ_of(table, cur, topic, 0);
        let item = match task {
            Task::NextToken => {
                let mut choices = vec![vec![gold_tok]];
                while choices.len() < 4 {
                    let d = 1 + rng.next_below(VOCAB as u64 - 1) as u32;
                    if d != gold_tok {
                        choices.push(vec![d]);
                    }
                }
                shuffle_gold(choices, &mut rng)
            }
            Task::ChainCompletion => {
                let second = succ_of(table, gold_tok, topic, 0);
                let valid = vec![gold_tok, second];
                let mut choices = vec![valid];
                while choices.len() < 4 {
                    let a = 1 + rng.next_below(VOCAB as u64 - 1) as u32;
                    let b = 1 + rng.next_below(VOCAB as u64 - 1) as u32;
                    if a != gold_tok {
                        choices.push(vec![a, b]);
                    }
                }
                shuffle_gold(choices, &mut rng)
            }
            Task::TopicConsistency => {
                let mut wrong_topic = 1 + rng.next_below(RESTART_POOL) as u32;
                while wrong_topic == topic {
                    wrong_topic = 1 + rng.next_below(RESTART_POOL) as u32;
                }
                let wrong_tok = succ_of(table, cur, wrong_topic, 0);
                if wrong_tok == gold_tok {
                    continue; // degenerate, resample
                }
                shuffle_gold(vec![vec![gold_tok], vec![wrong_tok]], &mut rng)
            }
            Task::BoundaryDetect => {
                // context runs to a sentence boundary: next true token is BOS
                let mut ctx = walk(table, topic, start, 30, &mut rng);
                ctx.truncate(32); // sentence_len boundary
                let mut choices = vec![vec![BOS]];
                while choices.len() < 4 {
                    let d = 1 + rng.next_below(VOCAB as u64 - 1) as u32;
                    choices.push(vec![d]);
                }
                let (choices, gold) = shuffle_gold_pair(choices, &mut rng);
                items.push(TaskItem { context: ctx, choices, gold });
                continue;
            }
            Task::FreqPlausibility => {
                let lo = succ_of(table, cur, topic, BRANCH - 1);
                if lo == gold_tok {
                    continue;
                }
                shuffle_gold(vec![vec![gold_tok], vec![lo]], &mut rng)
            }
            Task::NearMiss => {
                let mut choices = vec![vec![gold_tok]];
                let mut tries = 0;
                while choices.len() < 4 && tries < 32 {
                    tries += 1;
                    let other_cur = 1 + rng.next_below(VOCAB as u64 - 1) as u32;
                    let d = succ_of(table, other_cur, topic, 0);
                    if d != gold_tok && !choices.iter().any(|c| c[0] == d) {
                        choices.push(vec![d]);
                    }
                }
                if choices.len() < 4 {
                    continue;
                }
                shuffle_gold(choices, &mut rng)
            }
        };
        let (choices, gold) = item;
        items.push(TaskItem { context, choices, gold });
    }
    items
}

fn shuffle_gold(mut choices: Vec<Vec<u32>>, rng: &mut SplitMix) -> (Vec<Vec<u32>>, usize) {
    // gold starts at index 0; fisher-yates and track it
    let mut gold = 0usize;
    for i in (1..choices.len()).rev() {
        let j = rng.next_below(i as u64 + 1) as usize;
        choices.swap(i, j);
        if gold == i {
            gold = j;
        } else if gold == j {
            gold = i;
        }
    }
    (choices, gold)
}

fn shuffle_gold_pair(choices: Vec<Vec<u32>>, rng: &mut SplitMix) -> (Vec<Vec<u32>>, usize) {
    shuffle_gold(choices, rng)
}

/// Score one item: length-normalised continuation logprob per choice.
pub fn score_item(engine: &dyn InferenceEngine, item: &TaskItem) -> Result<usize> {
    let mut session = engine.new_session()?;
    let logits = engine.prefill(&item.context, session.as_mut())?;
    let v = engine.spec().model.vocab;
    let last = &logits[(item.context.len() - 1) * v..item.context.len() * v];
    let mut best = (f64::NEG_INFINITY, 0usize);
    for (ci, choice) in item.choices.iter().enumerate() {
        let mut lp = crate::model::log_prob(last, choice[0] as usize) as f64;
        if choice.len() > 1 {
            // teacher-force the rest with a forked session (engines whose
            // KV is device-resident may not support this — surface it)
            let mut s2 = session.fork()?;
            let mut prev = choice[0];
            for &tok in &choice[1..] {
                let mut refs: [&mut dyn EngineSession; 1] = [s2.as_mut()];
                let step = engine.decode_step(&[prev], &mut refs)?;
                lp += crate::model::log_prob(&step[..v], tok as usize) as f64;
                prev = tok;
            }
        }
        let norm = lp / choice.len() as f64;
        if norm > best.0 {
            best = (norm, ci);
        }
    }
    Ok(best.1)
}

/// Accuracy of an engine on one task.
pub fn accuracy(engine: &dyn InferenceEngine, task: Task, n: usize, seed: u64) -> Result<f64> {
    let table = corpus::build_transition_table(corpus::TABLE_SEED);
    let items = generate_items(&table, task, n, seed);
    let mut correct = 0usize;
    for item in &items {
        if score_item(engine, item)? == item.gold {
            correct += 1;
        }
    }
    Ok(correct as f64 / items.len() as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn items_have_valid_gold_and_unique_choices() {
        let table = corpus::build_transition_table(corpus::TABLE_SEED);
        for task in ALL_TASKS {
            let items = generate_items(&table, task, 10, 7);
            assert_eq!(items.len(), 10);
            for it in items {
                assert!(it.gold < it.choices.len());
                assert!(!it.context.is_empty());
                assert_eq!(it.context[0], BOS);
            }
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let table = corpus::build_transition_table(corpus::TABLE_SEED);
        let a = generate_items(&table, Task::NextToken, 5, 3);
        let b = generate_items(&table, Task::NextToken, 5, 3);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.context, y.context);
            assert_eq!(x.choices, y.choices);
            assert_eq!(x.gold, y.gold);
        }
    }
}
