//! Synthetic corpus generator — bit-for-bit mirror of python
//! `compile/data.py` (same SplitMix64 stream, same table construction,
//! same topic-conditioned Markov walk), so rust evaluates perplexity on
//! exactly the distribution the model was trained on.

use crate::util::rng::SplitMix;

pub const BOS: u32 = 0;
pub const VOCAB: usize = 512;
pub const BRANCH: usize = 4;
pub const FOLLOW: f64 = 0.92;
pub const RESTART_POOL: u64 = 64;
pub const TABLE_SEED: u64 = 0xAB9;
pub const EVAL_SEED: u64 = 999;

/// Per-token successor sets + cumulative probabilities.
pub struct TransitionTable {
    pub succ: Vec<u32>, // [vocab * branch]
    pub cum: Vec<f64>,  // [vocab * branch]
}

pub fn build_transition_table(seed: u64) -> TransitionTable {
    let vocab = VOCAB;
    let branch = BRANCH;
    let mut rng = SplitMix::new(seed);
    // zipf backbone
    let mut zipf: Vec<f64> = (1..=vocab).map(|r| 1.0 / r as f64).collect();
    let total: f64 = zipf.iter().sum();
    for z in zipf.iter_mut() {
        *z /= total;
    }
    let mut succ = vec![0u32; vocab * branch];
    let mut cum = vec![0f64; vocab * branch];
    for t in 0..vocab {
        let mut probs = [0f64; BRANCH];
        for b in 0..branch {
            let u = rng.next_f64();
            let mut c = 0f64;
            let mut pick = vocab - 1;
            for (v, &z) in zipf.iter().enumerate() {
                c += z;
                if u <= c {
                    pick = v;
                    break;
                }
            }
            succ[t * branch + b] = pick.max(1) as u32; // successors never BOS
            probs[b] = ((b + 1) as f64).powf(-1.5);
        }
        let psum: f64 = probs.iter().sum();
        let mut acc = 0f64;
        for b in 0..branch {
            acc += probs[b] / psum;
            cum[t * branch + b] = acc;
        }
    }
    TransitionTable { succ, cum }
}

/// Generate a token stream (mirror of `data.generate_tokens`).
pub fn generate_tokens(table: &TransitionTable, n_tokens: usize, seed: u64) -> Vec<u32> {
    let sentence_len = 32usize;
    let vocab = VOCAB as u64;
    let mut rng = SplitMix::new(seed);
    let mut out = vec![0u32; n_tokens];
    let mut cur: u32 = BOS;
    let mut topic: u32 = 1;
    let mut pos_in_sent = 0usize;
    for o in out.iter_mut() {
        if pos_in_sent == 0 {
            *o = BOS;
            topic = 1 + rng.next_below(RESTART_POOL) as u32;
            cur = topic;
            pos_in_sent = 1;
            continue;
        }
        *o = cur;
        if rng.next_f64() < FOLLOW {
            let state =
                1 + ((cur as u64 - 1) + (topic as u64 - 1)) % (vocab - 1);
            let u = rng.next_f64();
            let row = &table.cum[state as usize * BRANCH..(state as usize + 1) * BRANCH];
            // searchsorted-left equivalent
            let mut b = row.iter().position(|&c| u <= c).unwrap_or(BRANCH - 1);
            if b >= BRANCH {
                b = BRANCH - 1;
            }
            cur = table.succ[state as usize * BRANCH + b];
        } else {
            cur = 1 + rng.next_below(vocab - 1) as u32;
        }
        pos_in_sent += 1;
        if pos_in_sent >= sentence_len {
            pos_in_sent = 0;
        }
    }
    out
}

/// Chop a stream into `[num][batch][seq+1]` blocks (mirror `data.batches`).
pub fn batches(tokens: &[u32], batch: usize, seq: usize) -> Vec<Vec<Vec<u32>>> {
    let per = batch * (seq + 1);
    let num = tokens.len() / per;
    (0..num)
        .map(|n| {
            (0..batch)
                .map(|b| {
                    let off = n * per + b * (seq + 1);
                    tokens[off..off + seq + 1].to_vec()
                })
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_bos_anchored() {
        let t = build_transition_table(TABLE_SEED);
        let a = generate_tokens(&t, 200, 5);
        let b = generate_tokens(&t, 200, 5);
        assert_eq!(a, b);
        assert_eq!(a[0], BOS);
        assert_eq!(a[32], BOS); // sentence boundary
        assert!(a.iter().all(|&x| (x as usize) < VOCAB));
    }

    #[test]
    fn different_seeds_differ() {
        let t = build_transition_table(TABLE_SEED);
        assert_ne!(generate_tokens(&t, 100, 1), generate_tokens(&t, 100, 2));
    }

    #[test]
    fn batches_shape() {
        let t = build_transition_table(TABLE_SEED);
        let toks = generate_tokens(&t, 2 * 3 * 9, 1);
        let b = batches(&toks, 3, 8);
        assert_eq!(b.len(), 2);
        assert_eq!(b[0].len(), 3);
        assert_eq!(b[0][0].len(), 9);
    }
}
