//! Evaluation substrate: the synthetic corpus (WikiText2/C4 substitution),
//! the perplexity harness, and the zero-shot task suite (DESIGN.md §4/§6).

pub mod corpus;
pub mod perplexity;
pub mod tasks;

pub use perplexity::{perplexity, sequence_nll};
pub use tasks::{accuracy, task_name, Task, ALL_TASKS};
