//! Perplexity harness (the paper's WikiText2/C4 PPL metric, on the
//! substituted corpus — DESIGN.md §4). Teacher-forced NLL over held-out
//! token streams through any [`InferenceEngine`] — the native transformer
//! or the PJRT artifact path, selected at engine build time.

use anyhow::Result;

use crate::engine::InferenceEngine;

use super::corpus;

/// Mean token NLL of `seq` (teacher-forced); `seq` includes the target
/// shift, i.e. `len >= 2`.
pub fn sequence_nll(engine: &dyn InferenceEngine, seq: &[u32]) -> Result<f64> {
    assert!(seq.len() >= 2);
    let mut session = engine.new_session()?;
    let inputs = &seq[..seq.len() - 1];
    let logits = engine.prefill(inputs, session.as_mut())?;
    let v = engine.spec().model.vocab;
    let mut total = 0f64;
    for t in 0..inputs.len() {
        let row = &logits[t * v..(t + 1) * v];
        let target = seq[t + 1] as usize;
        total -= crate::model::log_prob(row, target) as f64;
    }
    Ok(total / inputs.len() as f64)
}

/// Perplexity over `n_seqs` held-out sequences of length `seq_len`.
pub fn perplexity(
    engine: &dyn InferenceEngine,
    n_seqs: usize,
    seq_len: usize,
    seed: u64,
) -> Result<f64> {
    let table = corpus::build_transition_table(corpus::TABLE_SEED);
    let tokens = corpus::generate_tokens(&table, n_seqs * (seq_len + 1), seed);
    let mut total = 0f64;
    let mut count = 0usize;
    for s in 0..n_seqs {
        let seq = &tokens[s * (seq_len + 1)..(s + 1) * (seq_len + 1)];
        total += sequence_nll(engine, seq)? * (seq_len as f64);
        count += seq_len;
    }
    Ok((total / count as f64).exp())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::EngineBuilder;
    use crate::model::ModelConfig;

    const MICRO: ModelConfig = ModelConfig {
        name: "micro",
        vocab: 512,
        d_model: 16,
        n_layers: 1,
        n_heads: 2,
        n_kv_heads: 2,
        d_ff: 32,
        max_seq: 64,
        rope_base: 10000.0,
        arch: crate::model::ArchVariant::LLAMA,
    };

    #[test]
    fn random_model_ppl_near_vocab() {
        // an untrained model must be near the uniform bound (vocab=512);
        // random-logit models land within a small factor of it
        let engine =
            EngineBuilder::new().random_weights(MICRO, 9).backend("fp32").build().unwrap();
        let ppl = perplexity(engine.as_ref(), 2, 32, 123).unwrap();
        assert!(ppl > 150.0 && ppl < 1500.0, "ppl {ppl}");
    }
}
