//! Perplexity harness (the paper's WikiText2/C4 PPL metric, on the
//! substituted corpus — DESIGN.md §4). Teacher-forced NLL over held-out
//! token streams through the rust-native transformer.

use anyhow::Result;

use crate::model::{KvCache, Transformer};

use super::corpus;

/// Mean token NLL of `seq` (teacher-forced); `seq` includes the target
/// shift, i.e. `len >= 2`.
pub fn sequence_nll(model: &Transformer, seq: &[u32]) -> Result<f64> {
    assert!(seq.len() >= 2);
    let mut cache = KvCache::new(&model.cfg);
    let inputs = &seq[..seq.len() - 1];
    let logits = model.prefill(inputs, &mut cache)?;
    let v = model.cfg.vocab;
    let mut total = 0f64;
    for t in 0..inputs.len() {
        let row = &logits[t * v..(t + 1) * v];
        let target = seq[t + 1] as usize;
        total -= crate::model::log_prob(row, target) as f64;
    }
    Ok(total / inputs.len() as f64)
}

/// Perplexity over `n_seqs` held-out sequences of length `seq_len`.
pub fn perplexity(model: &Transformer, n_seqs: usize, seq_len: usize, seed: u64) -> Result<f64> {
    let table = corpus::build_transition_table(corpus::TABLE_SEED);
    let tokens = corpus::generate_tokens(&table, n_seqs * (seq_len + 1), seed);
    let mut total = 0f64;
    let mut count = 0usize;
    for s in 0..n_seqs {
        let seq = &tokens[s * (seq_len + 1)..(s + 1) * (seq_len + 1)];
        total += sequence_nll(model, seq)? * (seq_len as f64);
        count += seq_len;
    }
    Ok((total / count as f64).exp())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Backend, ModelConfig, Transformer};

    const MICRO: ModelConfig = ModelConfig {
        name: "micro",
        vocab: 512,
        d_model: 16,
        n_layers: 1,
        n_heads: 2,
        d_ff: 32,
        max_seq: 64,
        rope_base: 10000.0,
    };

    #[test]
    fn random_model_ppl_near_vocab() {
        // an untrained model must be near the uniform bound (vocab=512);
        // random-logit models land within a small factor of it
        let m = Transformer::random(MICRO, Backend::Fp32, 9);
        let ppl = perplexity(&m, 2, 32, 123).unwrap();
        assert!(ppl > 150.0 && ppl < 1500.0, "ppl {ppl}");
    }
}
