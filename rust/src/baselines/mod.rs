//! Baseline GEMM engines standing in for the paper's comparators
//! (DESIGN.md §3/§4): `fp32` ≙ FastTransformer FP16, `int8` ≙
//! cuBLAS/CUTLASS W8A8 (SmoothQuant's engine), `int4` ≙ CUTLASS W4A4.
//!
//! The crucial *behavioural* property carried over from the GPU: integer
//! TensorCore MMA has an M granularity of 8 (m8n8k16/m8n8k32), so a GEMV
//! (M=1) pays for 8 rows — 87.5 % padding waste (paper Fig. 8). The
//! baselines reproduce that by physically computing the padded rows, which
//! is exactly what the GPU does. The ABQ engine avoids it via GEMV
//! elimination; benches `fig5_gemv` / `t4_ablation` measure the gap.

pub mod fp32;
pub mod int4;
pub mod int8;

pub use fp32::{gemm_fp32, gemm_fp32_into};
pub use int4::{Int4Gemm, Int4Scratch};
pub use int8::{Int8Gemm, Int8Scratch};

/// MMA M-granularity all integer-TensorCore baselines pad to.
pub const MMA_M: usize = 8;

/// Pad M up to the MMA granularity (the padding the paper's Fig. 8 shows).
pub fn padded_m(m: usize) -> usize {
    m.div_ceil(MMA_M) * MMA_M
}

#[cfg(test)]
mod tests {
    #[test]
    fn padding_rule() {
        assert_eq!(super::padded_m(1), 8);
        assert_eq!(super::padded_m(8), 8);
        assert_eq!(super::padded_m(9), 16);
    }
}
