//! FP32 GEMM — the "FastTransformer FP16" baseline of Fig. 6 / Table 12.
//!
//! Blocked + worker-parallel so the end-to-end comparison against the ABQ
//! engine is against a *competent* float path, not a strawman. Pool
//! workers write their column ranges straight into the output buffer, so
//! `gemm_fp32_into` performs no heap allocation at all — it needs no
//! scratch arena.

use crate::util::par::{self, SendPtr};

/// `y[m,n] = Σ_k x[m,k] · w[n,k]` — x `[m,k]` row-major, w `[n,k]` row-major
/// (weights stored transposed, as in the model).
pub fn gemm_fp32(x: &[f32], w: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    let mut out = vec![0f32; m * n];
    gemm_fp32_into(x, w, m, n, k, &mut out);
    out
}

/// [`gemm_fp32`] writing into a caller-provided buffer; allocation-free.
pub fn gemm_fp32_into(x: &[f32], w: &[f32], m: usize, n: usize, k: usize, out: &mut [f32]) {
    assert_eq!(x.len(), m * k);
    assert_eq!(w.len(), n * k);
    assert_eq!(out.len(), m * n);
    let ptr = SendPtr(out.as_mut_ptr());
    // parallel over output rows of w (n dimension)
    par::par_for_ranges(n, |n0, n1| {
        for ni in n0..n1 {
            let wrow = &w[ni * k..(ni + 1) * k];
            for mi in 0..m {
                let xrow = &x[mi * k..(mi + 1) * k];
                // 4-way unrolled dot
                let chunks = k / 4;
                let (mut a0, mut a1, mut a2, mut a3) = (0f32, 0f32, 0f32, 0f32);
                for c in 0..chunks {
                    let j = c * 4;
                    a0 += xrow[j] * wrow[j];
                    a1 += xrow[j + 1] * wrow[j + 1];
                    a2 += xrow[j + 2] * wrow[j + 2];
                    a3 += xrow[j + 3] * wrow[j + 3];
                }
                let mut acc = a0 + a1 + a2 + a3;
                for j in chunks * 4..k {
                    acc += xrow[j] * wrow[j];
                }
                // Safety: column ni belongs exclusively to this worker's
                // range; `out` outlives the parallel region.
                unsafe { *ptr.0.add(mi * n + ni) = acc };
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_naive() {
        let (m, n, k) = (3, 5, 71);
        let x: Vec<f32> = (0..m * k).map(|i| (i % 7) as f32 - 3.0).collect();
        let w: Vec<f32> = (0..n * k).map(|i| (i % 5) as f32 - 2.0).collect();
        let got = gemm_fp32(&x, &w, m, n, k);
        for mi in 0..m {
            for ni in 0..n {
                let want: f32 = (0..k).map(|ki| x[mi * k + ki] * w[ni * k + ki]).sum();
                assert!((got[mi * n + ni] - want).abs() < 1e-3);
            }
        }
    }
}
