//! INT4 GEMM baseline — "CUTLASS W4A4" (m8n8k32 IMMA.S4): nibble-packed
//! weights, i32 accumulation, pad-M-to-8 GEMV waste. The paper's point
//! (§1, §4.4) is that configurations like W2A8 must be *up-converted* to
//! W4A4/W8A8 to run on these units — the conversion cost and padding are
//! what the ABQ engine eliminates.

use crate::util::par;

use super::padded_m;

/// Nibble-packed INT4 weights `[n, k/2]` (two codes per byte).
pub struct Int4Gemm {
    pub w_packed: Vec<u8>,
    pub zw: Vec<i32>,
    pub dw: Vec<f32>,
    pub n: usize,
    pub k: usize,
}

impl Int4Gemm {
    pub fn from_weights(wf: &[f32], n: usize, k: usize) -> Self {
        assert!(k % 2 == 0, "int4 pack needs even K");
        let q = crate::quant::quantize_weight_rows(
            wf, n, k, &crate::quant::QuantSpec::new(4), 1.0, 1.0);
        let mut w_packed = vec![0u8; n * k / 2];
        for i in 0..n * k / 2 {
            w_packed[i] = (q.codes[2 * i] & 0xF) | (q.codes[2 * i + 1] << 4);
        }
        Int4Gemm { w_packed, zw: q.zps(), dw: q.deltas(), n, k }
    }

    /// Integer kernel on 4-bit activation codes (`x` unsigned 0..15).
    pub fn gemm_int(&self, x: &[u8], m: usize, zx: &[i32]) -> Vec<i32> {
        assert_eq!(x.len(), m * self.k);
        let mp = padded_m(m);
        let k = self.k;
        let mut xp = vec![0u8; mp * k];
        xp[..m * k].copy_from_slice(x);
        let cols: Vec<Vec<i32>> = par::par_map_indexed(self.n, |ni| {
                let wrow = &self.w_packed[ni * k / 2..(ni + 1) * k / 2];
                let mut col = vec![0i32; mp];
                for mi in 0..mp {
                    let xrow = &xp[mi * k..(mi + 1) * k];
                    let mut acc = 0i32;
                    for b in 0..k / 2 {
                        let w0 = (wrow[b] & 0xF) as i32;
                        let w1 = (wrow[b] >> 4) as i32;
                        acc += xrow[2 * b] as i32 * w0 + xrow[2 * b + 1] as i32 * w1;
                    }
                    col[mi] = acc;
                }
                col
        });
        let mut out = vec![0i32; m * self.n];
        let wsums: Vec<i32> = (0..self.n)
            .map(|ni| {
                self.w_packed[ni * k / 2..(ni + 1) * k / 2]
                    .iter()
                    .map(|&b| (b & 0xF) as i32 + (b >> 4) as i32)
                    .sum()
            })
            .collect();
        let xsums: Vec<i32> = (0..m)
            .map(|mi| x[mi * k..(mi + 1) * k].iter().map(|&v| v as i32).sum())
            .collect();
        for mi in 0..m {
            for ni in 0..self.n {
                out[mi * self.n + ni] = cols[ni][mi] - zx[mi] * wsums[ni]
                    - self.zw[ni] * xsums[mi]
                    + (k as i32) * zx[mi] * self.zw[ni];
            }
        }
        out
    }

    /// Full forward from float activations (dynamic per-token 4-bit quant).
    pub fn forward(&self, x: &[f32], m: usize) -> Vec<f32> {
        let mut out = vec![0f32; m * self.n];
        self.forward_into(x, m, &mut out);
        out
    }

    /// [`Int4Gemm::forward`] writing into a caller-provided scratch buffer.
    pub fn forward_into(&self, x: &[f32], m: usize, out: &mut [f32]) {
        assert_eq!(out.len(), m * self.n);
        let q = crate::quant::quantize_act_per_token(
            x, m, self.k, &crate::quant::QuantSpec::new(4));
        let zx = q.zps();
        let yint = self.gemm_int(&q.codes, m, &zx);
        let dx = q.deltas();
        for mi in 0..m {
            for ni in 0..self.n {
                out[mi * self.n + ni] = yint[mi * self.n + ni] as f32 * dx[mi] * self.dw[ni];
            }
        }
    }

    pub fn weight_bytes(&self) -> usize {
        self.w_packed.len() + self.zw.len() * 4 + self.dw.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn int4_int_kernel_matches_naive() {
        let (n, k, m) = (5usize, 32usize, 2usize);
        let wf: Vec<f32> = (0..n * k).map(|i| ((i % 15) as f32 - 7.0) / 20.0).collect();
        let g = Int4Gemm::from_weights(&wf, n, k);
        let x: Vec<u8> = (0..m * k).map(|i| (i % 16) as u8).collect();
        let zx = vec![7i32, 3];
        let got = g.gemm_int(&x, m, &zx);
        // unpack codes and compute naively
        for mi in 0..m {
            for ni in 0..n {
                let mut want = 0i32;
                for ki in 0..k {
                    let b = g.w_packed[ni * k / 2 + ki / 2];
                    let wq = if ki % 2 == 0 { b & 0xF } else { b >> 4 } as i32;
                    want += (x[mi * k + ki] as i32 - zx[mi]) * (wq - g.zw[ni]);
                }
                assert_eq!(got[mi * n + ni], want);
            }
        }
    }
}
