//! INT4 GEMM baseline — "CUTLASS W4A4" (m8n8k32 IMMA.S4): nibble-packed
//! weights, i32 accumulation, pad-M-to-8 GEMV waste. The paper's point
//! (§1, §4.4) is that configurations like W2A8 must be *up-converted* to
//! W4A4/W8A8 to run on these units — the conversion cost and padding are
//! what the ABQ engine eliminates.
//!
//! Like the INT8 baseline, the `forward_scratch` path keeps all per-call
//! working memory in a reusable [`Int4Scratch`] and lets pool workers
//! write the accumulator in place (allocation-free once warm).

use crate::util::par::{self, SendPtr};

use super::padded_m;

/// Reusable working memory for [`Int4Gemm::forward_scratch`].
#[derive(Default)]
pub struct Int4Scratch {
    codes: Vec<u8>,
    /// padded unsigned activation buffer `[padded_m, k]`
    xp: Vec<u8>,
    zx: Vec<i32>,
    dx: Vec<f32>,
    xsums: Vec<i32>,
    yint: Vec<i32>,
}

impl Int4Scratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// Nibble-packed INT4 weights `[n, k/2]` (two codes per byte).
pub struct Int4Gemm {
    pub w_packed: Vec<u8>,
    pub zw: Vec<i32>,
    pub dw: Vec<f32>,
    /// per-output-channel code sums (precomputed once for the zero-point
    /// correction)
    pub wsum: Vec<i32>,
    pub n: usize,
    pub k: usize,
}

impl Int4Gemm {
    pub fn from_weights(wf: &[f32], n: usize, k: usize) -> Self {
        assert!(k % 2 == 0, "int4 pack needs even K");
        let q = crate::quant::quantize_weight_rows(
            wf, n, k, &crate::quant::QuantSpec::new(4), 1.0, 1.0);
        let mut w_packed = vec![0u8; n * k / 2];
        for i in 0..n * k / 2 {
            w_packed[i] = (q.codes[2 * i] & 0xF) | (q.codes[2 * i + 1] << 4);
        }
        let wsum: Vec<i32> = (0..n)
            .map(|ni| {
                w_packed[ni * k / 2..(ni + 1) * k / 2]
                    .iter()
                    .map(|&b| (b & 0xF) as i32 + (b >> 4) as i32)
                    .sum()
            })
            .collect();
        Int4Gemm { w_packed, zw: q.zps(), dw: q.deltas(), wsum, n, k }
    }

    /// Integer kernel on 4-bit activation codes (`x` unsigned 0..15).
    pub fn gemm_int(&self, x: &[u8], m: usize, zx: &[i32]) -> Vec<i32> {
        assert_eq!(x.len(), m * self.k);
        let mp = padded_m(m);
        let k = self.k;
        let mut xp = vec![0u8; mp * k];
        xp[..m * k].copy_from_slice(x);
        let mut out = vec![0i32; m * self.n];
        self.gemm_int_core(&xp, m, mp, &mut out);
        let xsums: Vec<i32> = (0..m)
            .map(|mi| x[mi * k..(mi + 1) * k].iter().map(|&v| v as i32).sum())
            .collect();
        self.correct(&mut out, m, zx, &xsums);
        out
    }

    /// Padded IMMA.S4 sweep: parallel over output channels, direct
    /// accumulator writes; padded rows computed and discarded.
    fn gemm_int_core(&self, xp: &[u8], m: usize, mp: usize, out: &mut [i32]) {
        let k = self.k;
        let n = self.n;
        debug_assert_eq!(xp.len(), mp * k);
        debug_assert_eq!(out.len(), m * n);
        let ptr = SendPtr(out.as_mut_ptr());
        par::par_for_ranges(n, |n0, n1| {
            for ni in n0..n1 {
                let wrow = &self.w_packed[ni * k / 2..(ni + 1) * k / 2];
                for mi in 0..mp {
                    let xrow = &xp[mi * k..(mi + 1) * k];
                    let mut acc = 0i32;
                    for (b, &packed) in wrow.iter().enumerate() {
                        let w0 = (packed & 0xF) as i32;
                        let w1 = (packed >> 4) as i32;
                        acc += xrow[2 * b] as i32 * w0 + xrow[2 * b + 1] as i32 * w1;
                    }
                    if mi < m {
                        // Safety: column ni belongs to this worker's range.
                        unsafe { *ptr.0.add(mi * n + ni) = acc };
                    } else {
                        std::hint::black_box(acc);
                    }
                }
            }
        });
    }

    fn correct(&self, out: &mut [i32], m: usize, zx: &[i32], xsums: &[i32]) {
        let (n, k) = (self.n, self.k);
        for mi in 0..m {
            for ni in 0..n {
                out[mi * n + ni] += -zx[mi] * self.wsum[ni] - self.zw[ni] * xsums[mi]
                    + (k as i32) * zx[mi] * self.zw[ni];
            }
        }
    }

    /// Full forward from float activations (dynamic per-token 4-bit quant).
    pub fn forward(&self, x: &[f32], m: usize) -> Vec<f32> {
        let mut out = vec![0f32; m * self.n];
        self.forward_into(x, m, &mut out);
        out
    }

    /// [`Int4Gemm::forward`] writing into a caller-provided buffer
    /// (fresh scratch per call).
    pub fn forward_into(&self, x: &[f32], m: usize, out: &mut [f32]) {
        let mut s = Int4Scratch::new();
        self.forward_scratch(x, m, &mut s, out);
    }

    /// Arena-backed forward: allocation-free once `s` is warm.
    pub fn forward_scratch(&self, x: &[f32], m: usize, s: &mut Int4Scratch, out: &mut [f32]) {
        assert_eq!(x.len(), m * self.k);
        assert_eq!(out.len(), m * self.n);
        let (n, k) = (self.n, self.k);
        crate::quant::quantize_act_per_token_into(
            x, m, k, &crate::quant::QuantSpec::new(4), &mut s.codes, &mut s.zx, &mut s.dx,
        );
        let mp = padded_m(m);
        s.xp.clear();
        s.xp.resize(mp * k, 0);
        s.xp[..m * k].copy_from_slice(&s.codes);
        s.xsums.clear();
        for mi in 0..m {
            s.xsums.push(s.xp[mi * k..(mi + 1) * k].iter().map(|&v| v as i32).sum());
        }
        s.yint.clear();
        s.yint.resize(m * n, 0);
        self.gemm_int_core(&s.xp, m, mp, &mut s.yint);
        self.correct(&mut s.yint, m, &s.zx, &s.xsums);
        for mi in 0..m {
            let dxm = s.dx[mi];
            for ni in 0..n {
                out[mi * n + ni] = s.yint[mi * n + ni] as f32 * dxm * self.dw[ni];
            }
        }
    }

    pub fn weight_bytes(&self) -> usize {
        self.w_packed.len() + self.zw.len() * 4 + self.dw.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn int4_int_kernel_matches_naive() {
        let (n, k, m) = (5usize, 32usize, 2usize);
        let wf: Vec<f32> = (0..n * k).map(|i| ((i % 15) as f32 - 7.0) / 20.0).collect();
        let g = Int4Gemm::from_weights(&wf, n, k);
        let x: Vec<u8> = (0..m * k).map(|i| (i % 16) as u8).collect();
        let zx = vec![7i32, 3];
        let got = g.gemm_int(&x, m, &zx);
        // unpack codes and compute naively
        for mi in 0..m {
            for ni in 0..n {
                let mut want = 0i32;
                for ki in 0..k {
                    let b = g.w_packed[ni * k / 2 + ki / 2];
                    let wq = if ki % 2 == 0 { b & 0xF } else { b >> 4 } as i32;
                    want += (x[mi * k + ki] as i32 - zx[mi]) * (wq - g.zw[ni]);
                }
                assert_eq!(got[mi * n + ni], want);
            }
        }
    }

    #[test]
    fn scratch_forward_matches_fresh() {
        let (n, k) = (9usize, 40usize);
        let wf: Vec<f32> = (0..n * k).map(|i| ((i % 11) as f32 - 5.0) / 25.0).collect();
        let g = Int4Gemm::from_weights(&wf, n, k);
        let mut s = Int4Scratch::new();
        for m in [1usize, 4, 10] {
            let x: Vec<f32> = (0..m * k).map(|i| ((i % 7) as f32) / 2.0).collect();
            let want = g.forward(&x, m);
            let mut got = vec![0f32; m * n];
            g.forward_scratch(&x, m, &mut s, &mut got);
            assert_eq!(got, want, "m {m}");
        }
    }
}
