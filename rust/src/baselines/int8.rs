//! INT8 GEMM baseline — "cuBLAS / CUTLASS W8A8", the engine SmoothQuant
//! deploys on. Computes with i8 operands and i32 accumulation like the
//! m8n8k16 IMMA path, **including the pad-M-to-8 GEMV waste** (Fig. 8):
//! when M < 8 the padded rows are physically computed, because that is
//! what the TensorCore does.
//!
//! The `forward_scratch` path mirrors the ABQ engine's arena discipline:
//! all per-call working memory lives in a reusable [`Int8Scratch`], and
//! pool workers write the integer accumulator in place, so steady-state
//! decode on this baseline allocates nothing either — the Fig. 6
//! comparison measures kernel schedules, not allocator traffic.

use crate::util::par::{self, SendPtr};

use super::padded_m;

/// Reusable working memory for [`Int8Gemm::forward_scratch`].
#[derive(Default)]
pub struct Int8Scratch {
    /// unsigned per-token activation codes
    codes: Vec<u8>,
    /// signed, padded activation buffer `[padded_m, k]`
    xp: Vec<i8>,
    zx: Vec<i32>,
    dx: Vec<f32>,
    /// per-token signed code sums
    xsums: Vec<i32>,
    /// integer accumulator `[m, n]`
    yint: Vec<i32>,
}

impl Int8Scratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// Prepared INT8 weight (codes + per-channel dequant), `[n, k]` row-major.
pub struct Int8Gemm {
    pub w: Vec<i8>,
    pub zw: Vec<i32>,
    pub dw: Vec<f32>,
    /// per-output-channel signed code sums (precomputed once; the
    /// zero-point correction needs them every call)
    pub wsum: Vec<i32>,
    pub n: usize,
    pub k: usize,
}

impl Int8Gemm {
    pub fn from_weights(wf: &[f32], n: usize, k: usize) -> Self {
        let q = crate::quant::quantize_weight_rows(
            wf, n, k, &crate::quant::QuantSpec::new(8), 1.0, 1.0);
        // shift unsigned codes to signed i8 (z - 128), standard IMMA form
        let w: Vec<i8> = q.codes.iter().map(|&c| (c as i32 - 128) as i8).collect();
        let zw: Vec<i32> = q.params.iter().map(|p| p.zp - 128).collect();
        let dw: Vec<f32> = q.params.iter().map(|p| p.delta).collect();
        let wsum: Vec<i32> = (0..n)
            .map(|ni| w[ni * k..(ni + 1) * k].iter().map(|&v| v as i32).sum())
            .collect();
        Int8Gemm { w, zw, dw, wsum, n, k }
    }

    /// Integer kernel on already-quantized activations.
    /// `x` `[m, k]` signed codes with per-token `zx`. Pads M to the MMA
    /// granularity and computes the padded rows (the modelled waste).
    pub fn gemm_int(&self, x: &[i8], m: usize, zx: &[i32]) -> Vec<i32> {
        assert_eq!(x.len(), m * self.k);
        let mp = padded_m(m);
        let k = self.k;
        let n = self.n;
        // physical padded activation buffer (zeros) — the wasted rows
        let mut xp = vec![0i8; mp * k];
        xp[..m * k].copy_from_slice(x);
        let mut out = vec![0i32; m * n];
        self.gemm_int_core(&xp, m, mp, &mut out);
        // zero-point correction: (x - zx)·(w - zw)
        let xsums: Vec<i32> = (0..m)
            .map(|mi| x[mi * k..(mi + 1) * k].iter().map(|&v| v as i32).sum())
            .collect();
        self.correct(&mut out, m, zx, &xsums);
        out
    }

    /// Padded IMMA sweep: parallel over output channels, workers write
    /// their column ranges of `out` `[m, n]` in place. Padded rows are
    /// computed and discarded (the modelled TensorCore waste).
    fn gemm_int_core(&self, xp: &[i8], m: usize, mp: usize, out: &mut [i32]) {
        let k = self.k;
        let n = self.n;
        debug_assert_eq!(xp.len(), mp * k);
        debug_assert_eq!(out.len(), m * n);
        let ptr = SendPtr(out.as_mut_ptr());
        par::par_for_ranges(n, |n0, n1| {
            for ni in n0..n1 {
                let wrow = &self.w[ni * k..(ni + 1) * k];
                for mi in 0..mp {
                    let xrow = &xp[mi * k..(mi + 1) * k];
                    let mut acc = 0i32;
                    for ki in 0..k {
                        acc += xrow[ki] as i32 * wrow[ki] as i32;
                    }
                    if mi < m {
                        // Safety: column ni belongs to this worker's range.
                        unsafe { *ptr.0.add(mi * n + ni) = acc };
                    } else {
                        // padded row: physically computed, then discarded
                        std::hint::black_box(acc);
                    }
                }
            }
        });
    }

    fn correct(&self, out: &mut [i32], m: usize, zx: &[i32], xsums: &[i32]) {
        let (n, k) = (self.n, self.k);
        for mi in 0..m {
            for ni in 0..n {
                out[mi * n + ni] += -zx[mi] * self.wsum[ni] - self.zw[ni] * xsums[mi]
                    + (k as i32) * zx[mi] * self.zw[ni];
            }
        }
    }

    /// Full forward from float activations (dynamic per-token quant).
    pub fn forward(&self, x: &[f32], m: usize) -> Vec<f32> {
        let mut out = vec![0f32; m * self.n];
        self.forward_into(x, m, &mut out);
        out
    }

    /// [`Int8Gemm::forward`] writing into a caller-provided buffer
    /// (fresh scratch per call).
    pub fn forward_into(&self, x: &[f32], m: usize, out: &mut [f32]) {
        let mut s = Int8Scratch::new();
        self.forward_scratch(x, m, &mut s, out);
    }

    /// Arena-backed forward: allocation-free once `s` is warm.
    pub fn forward_scratch(&self, x: &[f32], m: usize, s: &mut Int8Scratch, out: &mut [f32]) {
        assert_eq!(x.len(), m * self.k);
        assert_eq!(out.len(), m * self.n);
        let (n, k) = (self.n, self.k);
        crate::quant::quantize_act_per_token_into(
            x, m, k, &crate::quant::QuantSpec::new(8), &mut s.codes, &mut s.zx, &mut s.dx,
        );
        let mp = padded_m(m);
        s.xp.clear();
        s.xp.resize(mp * k, 0);
        for (dst, &c) in s.xp[..m * k].iter_mut().zip(&s.codes) {
            *dst = (c as i32 - 128) as i8;
        }
        for z in s.zx.iter_mut() {
            *z -= 128;
        }
        s.xsums.clear();
        for mi in 0..m {
            s.xsums.push(s.xp[mi * k..(mi + 1) * k].iter().map(|&v| v as i32).sum());
        }
        s.yint.clear();
        s.yint.resize(m * n, 0);
        self.gemm_int_core(&s.xp, m, mp, &mut s.yint);
        self.correct(&mut s.yint, m, &s.zx, &s.xsums);
        for mi in 0..m {
            let dxm = s.dx[mi];
            for ni in 0..n {
                out[mi * n + ni] = s.yint[mi * n + ni] as f32 * dxm * self.dw[ni];
            }
        }
    }

    pub fn weight_bytes(&self) -> usize {
        self.w.len() + self.zw.len() * 4 + self.dw.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn int8_tracks_fp() {
        let (n, k, m) = (16usize, 64usize, 3usize);
        let w: Vec<f32> = (0..n * k).map(|i| ((i % 23) as f32 - 11.0) / 50.0).collect();
        let x: Vec<f32> = (0..m * k).map(|i| ((i % 19) as f32 - 9.0) / 3.0).collect();
        let g = Int8Gemm::from_weights(&w, n, k);
        let y = g.forward(&x, m);
        for mi in 0..m {
            for ni in 0..n {
                let want: f32 = (0..k).map(|ki| x[mi * k + ki] * w[ni * k + ki]).sum();
                let got = y[mi * n + ni];
                assert!((got - want).abs() < 0.05 * want.abs().max(1.0),
                        "m{mi} n{ni} got {got} want {want}");
            }
        }
    }

    #[test]
    fn scratch_forward_matches_fresh() {
        let (n, k) = (12usize, 48usize);
        let w: Vec<f32> = (0..n * k).map(|i| ((i % 13) as f32 - 6.0) / 30.0).collect();
        let g = Int8Gemm::from_weights(&w, n, k);
        let mut s = Int8Scratch::new();
        for m in [1usize, 3, 9] {
            let x: Vec<f32> = (0..m * k).map(|i| ((i % 17) as f32 - 8.0) / 4.0).collect();
            let want = g.forward(&x, m);
            let mut got = vec![0f32; m * n];
            g.forward_scratch(&x, m, &mut s, &mut got);
            assert_eq!(got, want, "m {m}");
        }
    }
}
