//! INT8 GEMM baseline — "cuBLAS / CUTLASS W8A8", the engine SmoothQuant
//! deploys on. Computes with i8 operands and i32 accumulation like the
//! m8n8k16 IMMA path, **including the pad-M-to-8 GEMV waste** (Fig. 8):
//! when M < 8 the padded rows are physically computed, because that is
//! what the TensorCore does.

use crate::util::par;

use super::padded_m;

/// Prepared INT8 weight (codes + per-channel dequant), `[n, k]` row-major.
pub struct Int8Gemm {
    pub w: Vec<i8>,
    pub zw: Vec<i32>,
    pub dw: Vec<f32>,
    pub n: usize,
    pub k: usize,
}

impl Int8Gemm {
    pub fn from_weights(wf: &[f32], n: usize, k: usize) -> Self {
        let q = crate::quant::quantize_weight_rows(
            wf, n, k, &crate::quant::QuantSpec::new(8), 1.0, 1.0);
        // shift unsigned codes to signed i8 (z - 128), standard IMMA form
        let w: Vec<i8> = q.codes.iter().map(|&c| (c as i32 - 128) as i8).collect();
        let zw: Vec<i32> = q.params.iter().map(|p| p.zp - 128).collect();
        let dw: Vec<f32> = q.params.iter().map(|p| p.delta).collect();
        Int8Gemm { w, zw, dw, n, k }
    }

    /// Integer kernel on already-quantized activations.
    /// `x` `[m, k]` signed codes with per-token `zx`. Pads M to the MMA
    /// granularity and computes the padded rows (the modelled waste).
    pub fn gemm_int(&self, x: &[i8], m: usize, zx: &[i32]) -> Vec<i32> {
        assert_eq!(x.len(), m * self.k);
        let mp = padded_m(m);
        let k = self.k;
        // physical padded activation buffer (zeros) — the wasted rows
        let mut xp = vec![0i8; mp * k];
        xp[..m * k].copy_from_slice(x);
        let cols: Vec<Vec<i32>> = par::par_map_indexed(self.n, |ni| {
                let wrow = &self.w[ni * k..(ni + 1) * k];
                let mut col = vec![0i32; mp];
                for mi in 0..mp {
                    let xrow = &xp[mi * k..(mi + 1) * k];
                    let mut acc = 0i32;
                    for ki in 0..k {
                        acc += xrow[ki] as i32 * wrow[ki] as i32;
                    }
                    col[mi] = acc;
                }
                col
        });
        // correction + trim padding
        let mut out = vec![0i32; m * self.n];
        for (ni, col) in cols.iter().enumerate() {
            for mi in 0..m {
                out[mi * self.n + ni] = col[mi];
            }
        }
        // zero-point correction: (x - zx)·(w - zw)
        let wsums: Vec<i32> = (0..self.n)
            .map(|ni| self.w[ni * k..(ni + 1) * k].iter().map(|&v| v as i32).sum())
            .collect();
        let xsums: Vec<i32> = (0..m)
            .map(|mi| x[mi * k..(mi + 1) * k].iter().map(|&v| v as i32).sum())
            .collect();
        for mi in 0..m {
            for ni in 0..self.n {
                out[mi * self.n + ni] += -zx[mi] * wsums[ni] - self.zw[ni] * xsums[mi]
                    + (k as i32) * zx[mi] * self.zw[ni];
            }
        }
        out
    }

    /// Full forward from float activations (dynamic per-token quant).
    pub fn forward(&self, x: &[f32], m: usize) -> Vec<f32> {
        let mut out = vec![0f32; m * self.n];
        self.forward_into(x, m, &mut out);
        out
    }

    /// [`Int8Gemm::forward`] writing into a caller-provided scratch buffer.
    pub fn forward_into(&self, x: &[f32], m: usize, out: &mut [f32]) {
        assert_eq!(out.len(), m * self.n);
        let q = crate::quant::quantize_act_per_token(
            x, m, self.k, &crate::quant::QuantSpec::new(8));
        let xs: Vec<i8> = q.codes.iter().map(|&c| (c as i32 - 128) as i8).collect();
        let zx: Vec<i32> = q.params.iter().map(|p| p.zp - 128).collect();
        let yint = self.gemm_int(&xs, m, &zx);
        let dx: Vec<f32> = q.params.iter().map(|p| p.delta).collect();
        for mi in 0..m {
            for ni in 0..self.n {
                out[mi * self.n + ni] = yint[mi * self.n + ni] as f32 * dx[mi] * self.dw[ni];
            }
        }
    }

    pub fn weight_bytes(&self) -> usize {
        self.w.len() + self.zw.len() * 4 + self.dw.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn int8_tracks_fp() {
        let (n, k, m) = (16usize, 64usize, 3usize);
        let w: Vec<f32> = (0..n * k).map(|i| ((i % 23) as f32 - 11.0) / 50.0).collect();
        let x: Vec<f32> = (0..m * k).map(|i| ((i % 19) as f32 - 9.0) / 3.0).collect();
        let g = Int8Gemm::from_weights(&w, n, k);
        let y = g.forward(&x, m);
        for mi in 0..m {
            for ni in 0..n {
                let want: f32 = (0..k).map(|ki| x[mi * k + ki] * w[ni * k + ki]).sum();
                let got = y[mi * n + ni];
                assert!((got - want).abs() < 0.05 * want.abs().max(1.0),
                        "m{mi} n{ni} got {got} want {want}");
            }
        }
    }
}
