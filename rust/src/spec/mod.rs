//! Self-speculative decoding (docs/SPECULATIVE.md): draft `k` tokens per
//! round with a cheap low-bit instantiation of the *same* weights, then
//! verify all of them in one multi-token pass on the target-precision
//! model — converting the arbitrary-bit engine's bit-width gap directly
//! into decode tokens/s, in the spirit of draft-free self-speculation
//! over one weight pack.
//!
//! The pieces:
//!
//! * [`SpecConfig`] — draft WqAp config + draft length `k` + policy,
//!   handed to `EngineBuilder::speculative`, which instantiates the draft
//!   from the same pack/corrections load as the target;
//! * [`accept`] — the acceptance rule: exact argmax agreement under
//!   greedy decoding (the stream is bit-identical to vanilla greedy —
//!   asserted in `rust/tests/prop_spec.rs`), rejection + residual
//!   resampling at temperature > 0 (the emitted marginal is exactly the
//!   target distribution);
//! * `InferenceEngine::spec_round` (implemented by the native engine) —
//!   one batched draft loop + per-sequence verify/commit with KV rollback
//!   of the rejected suffix;
//! * [`generate_speculative`] — the single-sequence driver used by the
//!   CLI `run` command, the `decode_hotpath` bench rung and the tests.
//!   The continuous-batching scheduler has its own multi-sequence driver.

pub mod accept;

use anyhow::{anyhow, bail, Result};

use crate::engine::{EngineSession, InferenceEngine};
use crate::model::{Sampler, Sampling};
use crate::quant::WAConfig;

pub use accept::{bonus_token, draft_token, verify_token, Verdict};

/// How rejected draft tokens are resolved.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SpecPolicy {
    /// Exactness-preserving acceptance: greedy streams are bit-identical
    /// to vanilla greedy decode; stochastic sampling keeps the target
    /// distribution via rejection + residual resampling.
    #[default]
    Lossless,
}

/// Speculative-decoding configuration (`EngineBuilder::speculative`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SpecConfig {
    /// WqAp config of the draft instantiation; it shares the target's
    /// weight pack (and, when calibrated, its own tag's corrections)
    pub draft: WAConfig,
    /// draft tokens proposed per round (the verify pass scores k + 1)
    pub k: usize,
    pub policy: SpecPolicy,
}

/// Hard ceiling on `k` — far past the point where acceptance decay makes
/// longer drafts useless, and it bounds the verify window's KV lookahead.
pub const MAX_SPEC_K: usize = 32;

impl SpecConfig {
    pub fn new(draft: WAConfig, k: usize) -> Self {
        SpecConfig { draft, k, policy: SpecPolicy::Lossless }
    }

    pub fn validate(&self) -> Result<()> {
        if self.k == 0 || self.k > MAX_SPEC_K {
            bail!("SpecConfig.k must be in 1..={MAX_SPEC_K} (got {})", self.k);
        }
        Ok(())
    }
}

impl std::str::FromStr for SpecConfig {
    type Err = anyhow::Error;

    /// `"w2*a8:4"` → draft config + k (k defaults to 4) — the grammar the
    /// CLI flags and the bench's `ABQ_SPEC` env var share.
    fn from_str(s: &str) -> Result<Self> {
        let (cfg_str, k) = match s.split_once(':') {
            Some((c, kk)) => {
                (c, kk.trim().parse::<usize>().map_err(|_| anyhow!("bad spec k '{kk}'"))?)
            }
            None => (s, 4),
        };
        let draft: WAConfig =
            cfg_str.trim().parse().map_err(|e| anyhow!("bad draft config '{cfg_str}': {e}"))?;
        let sc = SpecConfig::new(draft, k);
        sc.validate()?;
        Ok(sc)
    }
}

/// What one sequence got out of one speculative round.
#[derive(Clone, Debug)]
pub struct SpecOutcome {
    /// tokens committed this round: the accepted draft prefix plus the
    /// closing target token (correction or bonus) — never empty
    pub tokens: Vec<u32>,
    /// draft tokens accepted (0..=drafted)
    pub accepted: usize,
    /// draft tokens proposed this round (≤ `SpecConfig.k`; clamped near
    /// the KV capacity edge)
    pub drafted: usize,
}

/// Running acceptance accounting (bench rung, CLI `run`, tests).
#[derive(Clone, Copy, Debug, Default)]
pub struct SpecStats {
    pub rounds: u64,
    pub drafted: u64,
    pub accepted: u64,
}

impl SpecStats {
    pub fn absorb(&mut self, o: &SpecOutcome) {
        self.rounds += 1;
        self.drafted += o.drafted as u64;
        self.accepted += o.accepted as u64;
    }

    /// Fraction of proposed draft tokens the target accepted.
    pub fn acceptance_rate(&self) -> f64 {
        if self.drafted == 0 {
            0.0
        } else {
            self.accepted as f64 / self.drafted as f64
        }
    }
}

/// Greedy speculative generation over an engine built with
/// `EngineBuilder::speculative`: prefill the prompt, then run speculative
/// rounds until `max_new` tokens are produced or KV capacity runs out.
/// The token stream is bit-identical to [`crate::engine::generate`] on
/// the same engine's target path (asserted in `rust/tests/prop_spec.rs`);
/// the stats say how much drafting paid for it.
pub fn generate_speculative(
    engine: &dyn InferenceEngine,
    prompt: &[u32],
    max_new: usize,
) -> Result<(Vec<u32>, SpecStats)> {
    if prompt.is_empty() {
        bail!("generate_speculative needs a non-empty prompt");
    }
    let mut stats = SpecStats::default();
    if max_new == 0 {
        return Ok((Vec::new(), stats));
    }
    let mut session = engine.new_session()?;
    let v = engine.spec().model.vocab;
    let logits = engine.prefill(prompt, session.as_mut())?;
    let mut sampler = Sampler::new(Sampling::Greedy, 0);
    let mut tok = sampler.sample(&logits[(prompt.len() - 1) * v..prompt.len() * v]);
    let mut out = vec![tok];
    while out.len() < max_new && session.remaining() > 1 {
        let mut refs: [&mut dyn EngineSession; 1] = [session.as_mut()];
        let mut samplers = [&mut sampler];
        let outcomes = engine.spec_round(&[tok], &mut refs, &mut samplers)?;
        let o = &outcomes[0];
        stats.absorb(o);
        for &t in &o.tokens {
            if out.len() < max_new {
                out.push(t);
            }
        }
        tok = *o.tokens.last().expect("spec_round always commits at least one token");
    }
    Ok((out, stats))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_config_parses_the_cli_grammar() {
        let sc: SpecConfig = "w2*a8:4".parse().unwrap();
        assert_eq!(sc.draft.to_string(), "w2*a8");
        assert_eq!(sc.k, 4);
        assert_eq!(sc.policy, SpecPolicy::Lossless);
        let default_k: SpecConfig = "w4a4".parse().unwrap();
        assert_eq!(default_k.k, 4);
        let sc8: SpecConfig = "w2sa8:8".parse().unwrap();
        assert_eq!(sc8.draft, "w2*a8".parse::<WAConfig>().unwrap());
        assert_eq!(sc8.k, 8);
        for bad in ["", "w2*a8:", "w2*a8:0", "w2*a8:99", "w0a4:2", ":4"] {
            assert!(bad.parse::<SpecConfig>().is_err(), "{bad}");
        }
    }

    #[test]
    fn stats_accounting() {
        let mut s = SpecStats::default();
        s.absorb(&SpecOutcome { tokens: vec![1, 2, 3], accepted: 2, drafted: 4 });
        s.absorb(&SpecOutcome { tokens: vec![9], accepted: 0, drafted: 4 });
        assert_eq!(s.rounds, 2);
        assert_eq!((s.drafted, s.accepted), (8, 2));
        assert!((s.acceptance_rate() - 0.25).abs() < 1e-12);
        assert_eq!(SpecStats::default().acceptance_rate(), 0.0);
    }
}
