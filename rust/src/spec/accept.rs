//! The speculative acceptance rule (draft-token verification).
//!
//! For each draft token the target model scored, [`verify_token`] decides
//! accept vs reject under the sequence's [`Sampling`] mode:
//!
//! * **Greedy** — accept iff the draft token is the target argmax;
//!   otherwise reject and emit the argmax. No randomness is consumed, and
//!   the emitted stream is *exactly* vanilla greedy decode (the
//!   lossless-greedy guarantee `rust/tests/prop_spec.rs` asserts
//!   bit-for-bit).
//! * **TopK { k, temperature }** — classic speculative rejection sampling
//!   (Leviathan et al. / Chen et al.) over the temperature-`T`, top-`k`
//!   truncated distributions the plain [`crate::model::Sampler`] would
//!   sample from: accept the draft token `d` with probability
//!   `min(1, p(d)/q(d))`; on rejection, resample from the normalized
//!   residual `max(p − q, 0)`. Marginally the emitted token is
//!   distributed exactly as `p` — in particular a token with zero target
//!   probability can never be emitted (unit-tested below).
//!
//! [`bonus_token`] samples the free extra token of an all-accepted round
//! and [`draft_token`] draws the proposal from the draft distribution, so
//! the whole rule set lives in one place.

use crate::model::{argmax, Sampling};
use crate::util::rng::SplitMix;

/// Outcome of verifying one draft token.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Verdict {
    /// the draft token is kept; verification moves to the next position
    Accepted,
    /// the draft token is rejected; the carried token (argmax or residual
    /// resample) is emitted instead and the round ends
    Rejected(u32),
}

/// The target's sampling distribution as the plain sampler would build
/// it: softmax at `temperature` over the `top_k` highest logits, zero
/// elsewhere. Entries at `-inf` stay exactly zero even inside the top-k.
fn topk_probs(logits: &[f32], top_k: usize, temperature: f32) -> Vec<f32> {
    let top_k = top_k.max(1).min(logits.len());
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    idx.sort_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
    idx.truncate(top_k);
    let t = temperature.max(1e-3);
    let mx = logits[idx[0]];
    let mut p = vec![0f32; logits.len()];
    let mut total = 0f32;
    for &i in &idx {
        let e = ((logits[i] - mx) / t).exp();
        p[i] = e;
        total += e;
    }
    let inv = 1.0 / total;
    for v in &mut p {
        *v *= inv;
    }
    p
}

/// Sample an index from non-negative weights `w` summing to `total`.
/// Always lands on a strictly positive weight (the last positive entry
/// absorbs floating-point remainder), so zero-weight tokens are
/// unreachable.
fn sample_weighted(w: &[f32], total: f32, rng: &mut SplitMix) -> u32 {
    let mut u = rng.next_f64() as f32 * total;
    let mut last = None;
    for (i, &wi) in w.iter().enumerate() {
        if wi > 0.0 {
            last = Some(i);
            if u < wi {
                return i as u32;
            }
            u -= wi;
        }
    }
    last.expect("sample_weighted needs at least one positive weight") as u32
}

/// Decide one draft token's fate against the target's logits row at the
/// same position. `draft_row` is the draft model's logits at that
/// position; greedy verification never looks at it (`None` is fine), the
/// stochastic rule needs it for `q`.
pub fn verify_token(
    target_row: &[f32],
    draft_row: Option<&[f32]>,
    draft_tok: u32,
    mode: Sampling,
    rng: &mut SplitMix,
) -> Verdict {
    match mode {
        Sampling::Greedy => {
            let best = argmax(target_row) as u32;
            if best == draft_tok {
                Verdict::Accepted
            } else {
                Verdict::Rejected(best)
            }
        }
        Sampling::TopK { k, temperature } => {
            let p = topk_probs(target_row, k, temperature);
            let q = topk_probs(
                draft_row.expect("stochastic verification needs the draft distribution"),
                k,
                temperature,
            );
            let d = draft_tok as usize;
            let (pd, qd) = (p[d], q[d]);
            // accept with probability min(1, pd/qd); the strict `<` makes
            // pd == 0 unacceptable even at u == 0
            if rng.next_f64() as f32 * qd < pd {
                return Verdict::Accepted;
            }
            // resample from the residual max(p − q, 0); when the residual
            // vanishes (q covers p), fall back to p itself — either way
            // only tokens with pd > 0 carry weight
            let mut total = 0f32;
            let residual: Vec<f32> = p
                .iter()
                .zip(&q)
                .map(|(&pi, &qi)| {
                    let r = (pi - qi).max(0.0);
                    total += r;
                    r
                })
                .collect();
            if total > 0.0 {
                Verdict::Rejected(sample_weighted(&residual, total, rng))
            } else {
                Verdict::Rejected(sample_weighted(&p, 1.0, rng))
            }
        }
    }
}

/// The extra token of an all-accepted round (and the k = 0 degenerate
/// round, which is exactly one vanilla step): sample the target's
/// distribution at the position after the last accepted token.
pub fn bonus_token(target_row: &[f32], mode: Sampling, rng: &mut SplitMix) -> u32 {
    match mode {
        Sampling::Greedy => argmax(target_row) as u32,
        Sampling::TopK { k, temperature } => {
            let p = topk_probs(target_row, k, temperature);
            sample_weighted(&p, 1.0, rng)
        }
    }
}

/// The draft model's proposal from its own logits row.
pub fn draft_token(draft_row: &[f32], mode: Sampling, rng: &mut SplitMix) -> u32 {
    match mode {
        Sampling::Greedy => argmax(draft_row) as u32,
        Sampling::TopK { k, temperature } => {
            let q = topk_probs(draft_row, k, temperature);
            sample_weighted(&q, 1.0, rng)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const NEG: f32 = f32::NEG_INFINITY;

    #[test]
    fn greedy_degenerates_to_exact_argmax_agreement() {
        // satellite: at temperature 0 (greedy) the rule is exactly "draft
        // == target argmax", the reject token is the argmax, and no
        // randomness is consumed
        let mut rng = SplitMix::new(1);
        let before = rng.clone();
        let row = [0.3f32, 2.5, -1.0, 2.4];
        assert_eq!(verify_token(&row, None, 1, Sampling::Greedy, &mut rng), Verdict::Accepted);
        assert_eq!(
            verify_token(&row, None, 3, Sampling::Greedy, &mut rng),
            Verdict::Rejected(1)
        );
        assert_eq!(bonus_token(&row, Sampling::Greedy, &mut rng), 1);
        assert_eq!(draft_token(&row, Sampling::Greedy, &mut rng), 1);
        // the stream is untouched — greedy speculation cannot perturb a
        // sequence's sampler state across preemption/resume
        assert_eq!(rng.next_u64(), before.clone().next_u64());
    }

    #[test]
    fn identical_distributions_always_accept() {
        let mode = Sampling::TopK { k: 4, temperature: 0.8 };
        let row = [1.0f32, 0.5, -0.5, 2.0, -3.0];
        let mut rng = SplitMix::new(7);
        for _ in 0..200 {
            let d = draft_token(&row, mode, &mut rng);
            assert_eq!(verify_token(&row, Some(&row), d, mode, &mut rng), Verdict::Accepted);
        }
    }

    #[test]
    fn never_emits_a_token_with_zero_target_probability() {
        // satellite: seeded stochastic verification — the draft loves
        // tokens the target gives exactly zero probability (−inf logits);
        // neither acceptance nor residual resampling may emit one
        let mode = Sampling::TopK { k: 4, temperature: 1.0 };
        // target: only tokens 0 and 1 are possible
        let target = [2.0f32, 1.5, NEG, NEG, NEG];
        // draft: loves the impossible tokens (but proposes the possible
        // ones often enough that both verdicts are exercised)
        let draft = [0.0f32, -1.0, 3.0, 2.5, 2.0];
        let mut rng = SplitMix::new(0xACCE57);
        let mut accepted_any = false;
        let mut rejected_any = false;
        for _ in 0..500 {
            let d = draft_token(&draft, mode, &mut rng);
            let emitted = match verify_token(&target, Some(&draft), d, mode, &mut rng) {
                Verdict::Accepted => {
                    accepted_any = true;
                    d
                }
                Verdict::Rejected(t) => {
                    rejected_any = true;
                    t
                }
            };
            assert!(emitted <= 1, "emitted token {emitted} has zero target probability");
            let bonus = bonus_token(&target, mode, &mut rng);
            assert!(bonus <= 1, "bonus token {bonus} has zero target probability");
        }
        // the test has teeth: both branches were exercised
        assert!(rejected_any, "the draft's impossible proposals must be rejected");
        assert!(accepted_any, "some possible proposals should be accepted");
    }

    #[test]
    fn rejection_resamples_only_where_target_exceeds_draft() {
        // residual = max(p − q, 0): when the draft under-proposes token 0
        // and over-proposes token 2, every rejection must land on 0 or 1
        let mode = Sampling::TopK { k: 3, temperature: 1.0 };
        let target = [3.0f32, 1.0, -2.0];
        let draft = [-2.0f32, 1.0, 3.0];
        let mut rng = SplitMix::new(42);
        let mut saw_reject = false;
        for _ in 0..300 {
            match verify_token(&target, Some(&draft), 2, mode, &mut rng) {
                Verdict::Accepted => {}
                Verdict::Rejected(t) => {
                    saw_reject = true;
                    assert!(t != 2, "resample landed on the over-proposed token");
                }
            }
        }
        assert!(saw_reject);
    }
}
