//! String-keyed backend registry: `"fp32"`, `"int8"`, `"int4"`,
//! `"abq:w2*a8"` → a [`LinearBackend`] factory. A spec is
//! `<family>[:<arg>]`; the arg (for `abq`, a WqAp string in the
//! [`WAConfig`] grammar) is passed to the family's factory. Bare WqAp
//! strings (`"w2*a8"`, `"w2sa8"`) are sugar for `abq:<spec>` so serving
//! request tags resolve directly.
//!
//! Adding a precision engine is one registration:
//!
//! ```
//! use std::sync::Arc;
//! use abq_llm::engine::{BackendRegistry, Fp32Backend, LinearBackend};
//!
//! let mut reg = BackendRegistry::with_defaults();
//! reg.register("my-engine", |_arg, _opts| {
//!     Ok(Arc::new(Fp32Backend) as Arc<dyn LinearBackend>)
//! });
//! assert!(reg.resolve("my-engine").is_ok());
//! ```

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::abq::OptLevel;
use crate::quant::WAConfig;

use super::linear::{AbqBackend, Fp32Backend, Int4Backend, Int8Backend, LinearBackend};

/// Options threaded from the [`super::EngineBuilder`] into factories.
#[derive(Clone, Copy, Debug)]
pub struct BackendOptions {
    /// Table-4 kernel variant for backends that honour it (the ABQ engine).
    pub opt_level: OptLevel,
}

impl Default for BackendOptions {
    fn default() -> Self {
        BackendOptions { opt_level: OptLevel::Auto }
    }
}

/// Factory for one backend family: `(arg-after-colon, options) → backend`.
pub type BackendFactory =
    Arc<dyn Fn(Option<&str>, &BackendOptions) -> Result<Arc<dyn LinearBackend>> + Send + Sync>;

#[derive(Clone, Default)]
pub struct BackendRegistry {
    factories: BTreeMap<String, BackendFactory>,
}

impl BackendRegistry {
    /// An empty registry (no families).
    pub fn empty() -> Self {
        Self::default()
    }

    /// The in-tree families: `fp32` (aliases `fp16`, `fp`), `int8`,
    /// `int4`, and `abq:<WqAp>`.
    pub fn with_defaults() -> Self {
        let mut r = Self::default();
        let fp32: BackendFactory =
            Arc::new(|_arg, _opts| Ok(Arc::new(Fp32Backend) as Arc<dyn LinearBackend>));
        r.factories.insert("fp32".to_string(), fp32.clone());
        r.factories.insert("fp16".to_string(), fp32.clone());
        r.factories.insert("fp".to_string(), fp32);
        r.register("int8", |_arg, _opts| Ok(Arc::new(Int8Backend) as Arc<dyn LinearBackend>));
        r.register("int4", |_arg, _opts| Ok(Arc::new(Int4Backend) as Arc<dyn LinearBackend>));
        r.register("abq", |arg, opts| {
            let spec = arg
                .ok_or_else(|| anyhow!("abq backend needs a config, e.g. `abq:w2*a8`"))?;
            let cfg: WAConfig = spec.parse().map_err(|e| anyhow!("{e}"))?;
            Ok(Arc::new(AbqBackend { cfg, opt: opts.opt_level }) as Arc<dyn LinearBackend>)
        });
        r
    }

    /// Register (or replace) a backend family.
    pub fn register<F>(&mut self, family: &str, f: F)
    where
        F: Fn(Option<&str>, &BackendOptions) -> Result<Arc<dyn LinearBackend>>
            + Send
            + Sync
            + 'static,
    {
        self.factories.insert(family.to_string(), Arc::new(f));
    }

    /// Registered family names.
    pub fn families(&self) -> Vec<String> {
        self.factories.keys().cloned().collect()
    }

    pub fn resolve(&self, spec: &str) -> Result<Arc<dyn LinearBackend>> {
        self.resolve_with(spec, &BackendOptions::default())
    }

    /// Resolve `<family>[:<arg>]` to a prepared backend.
    pub fn resolve_with(
        &self,
        spec: &str,
        opts: &BackendOptions,
    ) -> Result<Arc<dyn LinearBackend>> {
        let spec = spec.trim();
        let (family, arg) = match spec.split_once(':') {
            Some((f, a)) => (f, Some(a)),
            None => (spec, None),
        };
        if let Some(factory) = self.factories.get(family) {
            return (factory.as_ref())(arg, opts);
        }
        // sugar: a bare WqAp string is an abq config ("w2sa8" request tags)
        if arg.is_none() && spec.parse::<WAConfig>().is_ok() {
            if let Some(factory) = self.factories.get("abq") {
                return (factory.as_ref())(Some(spec), opts);
            }
        }
        bail!(
            "unknown backend '{spec}' (registered families: {})",
            self.families().join(", ")
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_resolve() {
        let r = BackendRegistry::with_defaults();
        for spec in ["fp32", "fp16", "int8", "int4", "abq:w2*a8", "abq:w2sa8", "w4a4"] {
            assert!(r.resolve(spec).is_ok(), "{spec}");
        }
        assert_eq!(r.resolve("abq:w2*a8").unwrap().name(), "abq:w2*a8");
        // bare WqAp sugar routes to the abq family
        assert_eq!(r.resolve("w4a4").unwrap().name(), "abq:w4a4");
    }

    #[test]
    fn unknown_and_malformed_specs_error() {
        let r = BackendRegistry::with_defaults();
        assert!(r.resolve("cuda").is_err());
        assert!(r.resolve("abq").is_err()); // config required
        assert!(r.resolve("abq:w99a99").is_err());
    }

    #[test]
    fn custom_family_registers() {
        let mut r = BackendRegistry::empty();
        assert!(r.resolve("fp32").is_err());
        r.register("fp32", |_a, _o| Ok(Arc::new(Fp32Backend) as Arc<dyn LinearBackend>));
        assert!(r.resolve("fp32").is_ok());
    }
}
