//! [`InferenceEngine`] over the rust-native [`Transformer`]: paged,
//! optionally quantized host-resident KV (one shared block pool per
//! engine), batched decode across sessions in a single GEMM (the
//! GEMM-vs-GEMV axis the ABQ engine optimises).
//!
//! Each session owns a [`PagedKvCache`] leased from the engine's
//! [`KvPool`] plus a [`ForwardScratch`] arena; prefill and decode thread
//! both into the model so the steady-state decode loop reuses one set of
//! buffers across the 7 block projections, all layers, and all steps
//! (`docs/PERF.md`). Batched decode borrows the first session's arena for
//! the whole batch. `kv_bytes`/`memory_report` report *real* pooled
//! usage — blocks actually leased, not the dense `max_seq` reservation
//! (`docs/SERVING.md`).

use std::any::Any;

use anyhow::{anyhow, Result};

use crate::model::{
    ForwardScratch, KvCacheConfig, KvPool, KvPoolStatus, PagedKvCache, Transformer,
};

use super::api::{EngineSession, EngineSpec, Execution, InferenceEngine, MemoryReport};

pub struct NativeEngine {
    model: Transformer,
    spec: EngineSpec,
    pool: KvPool,
}

impl NativeEngine {
    /// Engine with the default KV configuration (fp32 passthrough pages).
    pub fn new(model: Transformer) -> Self {
        Self::with_kv(model, KvCacheConfig::default(), None)
            .expect("default KV configuration is valid")
    }

    /// Engine with an explicit KV configuration and optional pool budget
    /// in bytes (`None` = a generous default; see [`KvPool::new`]).
    pub fn with_kv(
        model: Transformer,
        kv: KvCacheConfig,
        pool_budget_bytes: Option<usize>,
    ) -> Result<Self> {
        let pool = KvPool::new(&model.cfg, &kv, pool_budget_bytes)?;
        let spec = EngineSpec {
            model: model.cfg,
            backend: model.backend_name.clone(),
            execution: Execution::Native,
            kv,
        };
        Ok(NativeEngine { model, spec, pool })
    }

    /// Escape hatch to the underlying transformer (engine-internal tools).
    pub fn model(&self) -> &Transformer {
        &self.model
    }
}

struct NativeSession {
    cache: PagedKvCache,
    /// per-session forward arena, reused across prefill and decode steps
    scratch: ForwardScratch,
}

impl EngineSession for NativeSession {
    fn pos(&self) -> usize {
        self.cache.pos()
    }

    fn remaining(&self) -> usize {
        self.cache.remaining()
    }

    fn kv_bytes(&self) -> usize {
        self.cache.bytes()
    }

    fn fork(&self) -> Result<Box<dyn EngineSession>> {
        // the fork gets copies of the leased blocks and its own (cold)
        // arena; it warms on first use
        Ok(Box::new(NativeSession {
            cache: self.cache.try_clone()?,
            scratch: ForwardScratch::new(),
        }))
    }

    fn as_any_mut(&mut self) -> &mut dyn Any {
        self
    }
}

fn downcast<'a>(s: &'a mut dyn EngineSession) -> Result<&'a mut NativeSession> {
    s.as_any_mut()
        .downcast_mut::<NativeSession>()
        .ok_or_else(|| anyhow!("session does not belong to a native engine"))
}

impl InferenceEngine for NativeEngine {
    fn spec(&self) -> &EngineSpec {
        &self.spec
    }

    fn new_session(&self) -> Result<Box<dyn EngineSession>> {
        Ok(Box::new(NativeSession {
            cache: self.pool.new_cache(),
            scratch: ForwardScratch::new(),
        }))
    }

    fn prefill(&self, tokens: &[u32], session: &mut dyn EngineSession) -> Result<Vec<f32>> {
        let NativeSession { cache, scratch } = downcast(session)?;
        self.model.prefill_scratch(tokens, cache, scratch)
    }

    fn decode_step(
        &self,
        tokens: &[u32],
        sessions: &mut [&mut dyn EngineSession],
    ) -> Result<Vec<f32>> {
        // split each session into (cache, scratch); the batch runs on the
        // first session's arena
        let mut caches: Vec<&mut PagedKvCache> = Vec::with_capacity(sessions.len());
        let mut scratch: Option<&mut ForwardScratch> = None;
        for s in sessions.iter_mut() {
            let NativeSession { cache, scratch: sc } = downcast(&mut **s)?;
            caches.push(cache);
            if scratch.is_none() {
                scratch = Some(sc);
            }
        }
        match scratch {
            Some(sc) => self.model.decode_step_scratch(tokens, &mut caches, sc),
            None => self.model.decode_step(tokens, &mut caches),
        }
    }

    fn memory_report(&self) -> MemoryReport {
        let st = self.pool.status();
        MemoryReport {
            weight_bytes: self.model.weight_bytes(),
            kv_bytes_per_session: self.pool.blocks_for(self.model.cfg.max_seq) * st.block_bytes,
            kv_pool_bytes: st.total_blocks * st.block_bytes,
            kv_pool_used_bytes: st.used_blocks() * st.block_bytes,
        }
    }

    fn kv_pool_status(&self) -> Option<KvPoolStatus> {
        Some(self.pool.status())
    }
}
