//! [`InferenceEngine`] over the rust-native [`Transformer`]: paged,
//! optionally quantized host-resident KV (one shared block pool per
//! engine), batched decode across sessions in a single GEMM (the
//! GEMM-vs-GEMV axis the ABQ engine optimises).
//!
//! Each session owns a [`PagedKvCache`] leased from the engine's
//! [`KvPool`] plus a [`ForwardScratch`] arena; prefill and decode thread
//! both into the model so the steady-state decode loop reuses one set of
//! buffers across the 7 block projections, all layers, and all steps
//! (`docs/PERF.md`). Batched decode borrows the first session's arena for
//! the whole batch. `kv_bytes`/`memory_report` report *real* pooled
//! usage — blocks actually leased, not the dense `max_seq` reservation
//! (`docs/SERVING.md`).
//!
//! Engines built with `EngineBuilder::speculative` additionally carry a
//! low-bit **draft instantiation** of the same weights with its own KV
//! pool; [`InferenceEngine::spec_round`] runs the batched draft loop +
//! per-sequence verify/commit described in `docs/SPECULATIVE.md`.

use std::any::Any;
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::model::{
    BlockRef, ForwardScratch, KvCacheConfig, KvPool, KvPoolStatus, KvStore, PagedKvCache,
    Sampler, Transformer,
};
use crate::runtime::{SessionFile, SessionFingerprint};
use crate::spec::{bonus_token, draft_token, verify_token, SpecConfig, SpecOutcome, Verdict};

use super::api::{
    EngineSession, EngineSpec, Execution, InferenceEngine, KvPrefix, MemoryReport,
};
use super::builder::session_tag;

/// The low-bit draft half of a speculative engine: a second
/// instantiation of the same weights plus its own block pool (draft KV
/// is real sequence state, but isolated so target-pool accounting stays
/// exactly what vanilla decode would lease).
struct DraftEngine {
    cfg: SpecConfig,
    model: Arc<Transformer>,
    pool: KvPool,
}

pub struct NativeEngine {
    model: Arc<Transformer>,
    spec: EngineSpec,
    pool: KvPool,
    draft: Option<DraftEngine>,
    /// whether this engine's `MemoryReport` bills the (possibly shared)
    /// model weights as bytes it added: true for solo engines and for
    /// replica 0 of a `build_replicas` fleet, false for joiners that
    /// only hold another `Arc` onto a model a sibling already billed
    weights_owner: bool,
}

impl NativeEngine {
    /// Engine with the default KV configuration (fp32 passthrough pages).
    pub fn new(model: Transformer) -> Self {
        Self::with_kv(model, KvCacheConfig::default(), None)
            .expect("default KV configuration is valid")
    }

    /// Engine with an explicit KV configuration and optional pool budget
    /// in bytes (`None` = a generous default; see [`KvPool::new`]).
    pub fn with_kv(
        model: Transformer,
        kv: KvCacheConfig,
        pool_budget_bytes: Option<usize>,
    ) -> Result<Self> {
        Self::with_kv_speculative(model, kv, pool_budget_bytes, None)
    }

    /// [`NativeEngine::with_kv`] plus a speculative draft instantiation.
    /// The draft gets its own pool with the same budget and KV config, so
    /// one committed position costs the same blocks on both sides and a
    /// target-pool admission check covers the draft too.
    pub fn with_kv_speculative(
        model: Transformer,
        kv: KvCacheConfig,
        pool_budget_bytes: Option<usize>,
        speculative: Option<(SpecConfig, Transformer)>,
    ) -> Result<Self> {
        Self::shared(
            Arc::new(model),
            kv,
            pool_budget_bytes,
            speculative.map(|(sc, d)| (sc, Arc::new(d))),
            true,
        )
    }

    /// Engine over an **already-shared** model (and draft): the caller
    /// holds the `Arc<Transformer>` and may hand clones of it to any
    /// number of sibling engines — each gets a private `KvPool`, the
    /// prepared weights exist once. `weights_owner` selects which
    /// sibling bills the shared weights in its
    /// [`MemoryReport::weight_bytes_incremental`] (exactly one should).
    pub fn shared(
        model: Arc<Transformer>,
        kv: KvCacheConfig,
        pool_budget_bytes: Option<usize>,
        speculative: Option<(SpecConfig, Arc<Transformer>)>,
        weights_owner: bool,
    ) -> Result<Self> {
        let pool = KvPool::new(&model.cfg, &kv, pool_budget_bytes)?;
        let draft = match speculative {
            Some((cfg, draft_model)) => {
                cfg.validate()?;
                if draft_model.cfg != model.cfg {
                    bail!(
                        "draft model architecture '{}' does not match target '{}'",
                        draft_model.cfg.name,
                        model.cfg.name
                    );
                }
                let dpool = KvPool::new(&draft_model.cfg, &kv, pool_budget_bytes)?;
                Some(DraftEngine { cfg, model: draft_model, pool: dpool })
            }
            None => None,
        };
        let spec = EngineSpec {
            model: model.cfg,
            backend: model.backend_name.clone(),
            execution: Execution::Native,
            kv,
        };
        Ok(NativeEngine { model, spec, pool, draft, weights_owner })
    }

    /// Escape hatch to the underlying transformer (engine-internal tools).
    pub fn model(&self) -> &Transformer {
        &self.model
    }

    /// What `.abqs` files written by this engine carry, and what loaded
    /// files must match exactly.
    fn session_fingerprint(&self) -> SessionFingerprint {
        SessionFingerprint::of(&self.spec.model, &session_tag(&self.spec.backend), &self.spec.kv)
    }

    fn reject_draft(&self, what: &str) -> Result<()> {
        if self.draft.is_some() {
            bail!(
                "{what} is not supported on speculative engines \
                 (the draft pool holds no shareable prefix, so an attached \
                 target prefix would desynchronize the draft cache)"
            );
        }
        Ok(())
    }
}

/// Refcount-pinned whole blocks of one session's cache (see
/// [`KvPrefix`]). Holding this keeps the blocks leased; sessions attach
/// them by reference and copy-on-write on divergence.
struct NativePrefix {
    pool: KvPool,
    blocks: Vec<BlockRef>,
    tokens: usize,
}

impl KvPrefix for NativePrefix {
    fn token_count(&self) -> usize {
        self.tokens
    }

    fn block_count(&self) -> usize {
        self.blocks.len()
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
}

/// Draft-side sequence state of a speculative session.
struct DraftSession {
    cache: PagedKvCache,
    scratch: ForwardScratch,
    /// committed token the draft cache has not ingested yet — an
    /// all-accepted round leaves the draft exactly one position behind
    /// the target (it never fed its own last proposal)
    catchup: Option<u32>,
}

struct NativeSession {
    cache: PagedKvCache,
    /// per-session forward arena, reused across prefill and decode steps
    scratch: ForwardScratch,
    draft: Option<DraftSession>,
}

impl EngineSession for NativeSession {
    fn pos(&self) -> usize {
        self.cache.pos()
    }

    fn remaining(&self) -> usize {
        self.cache.remaining()
    }

    fn kv_bytes(&self) -> usize {
        self.cache.bytes()
    }

    fn fork(&self) -> Result<Box<dyn EngineSession>> {
        // O(1): the fork shares the leased blocks by reference and only
        // copies a block when one side first writes to it (COW); the fork
        // gets its own (cold) arena that warms on first use
        Ok(Box::new(NativeSession {
            cache: self.cache.try_clone()?,
            scratch: ForwardScratch::new(),
            draft: match &self.draft {
                Some(d) => Some(DraftSession {
                    cache: d.cache.try_clone()?,
                    scratch: ForwardScratch::new(),
                    catchup: d.catchup,
                }),
                None => None,
            },
        }))
    }

    fn as_any_mut(&mut self) -> &mut dyn Any {
        self
    }
}

fn downcast<'a>(s: &'a mut dyn EngineSession) -> Result<&'a mut NativeSession> {
    s.as_any_mut()
        .downcast_mut::<NativeSession>()
        .ok_or_else(|| anyhow!("session does not belong to a native engine"))
}

impl InferenceEngine for NativeEngine {
    fn spec(&self) -> &EngineSpec {
        &self.spec
    }

    fn new_session(&self) -> Result<Box<dyn EngineSession>> {
        Ok(Box::new(NativeSession {
            cache: self.pool.new_cache(),
            scratch: ForwardScratch::new(),
            draft: self.draft.as_ref().map(|d| DraftSession {
                cache: d.pool.new_cache(),
                scratch: ForwardScratch::new(),
                catchup: None,
            }),
        }))
    }

    fn prefill(&self, tokens: &[u32], session: &mut dyn EngineSession) -> Result<Vec<f32>> {
        let NativeSession { cache, scratch, draft } = downcast(session)?;
        let logits = self.model.prefill_scratch(tokens, cache, scratch)?;
        if let (Some(de), Some(ds)) = (&self.draft, draft.as_mut()) {
            // the draft instantiation ingests the same prompt so both
            // caches describe the same committed prefix
            de.model.prefill_scratch(tokens, &mut ds.cache, &mut ds.scratch)?;
            ds.catchup = None;
        }
        Ok(logits)
    }

    fn decode_step(
        &self,
        tokens: &[u32],
        sessions: &mut [&mut dyn EngineSession],
    ) -> Result<Vec<f32>> {
        // split each session into (cache, scratch); the batch runs on the
        // first session's arena. Vanilla decode advances only the target
        // side — `spec_round` detects and rejects a stale draft.
        let mut caches: Vec<&mut PagedKvCache> = Vec::with_capacity(sessions.len());
        let mut scratch: Option<&mut ForwardScratch> = None;
        for s in sessions.iter_mut() {
            let NativeSession { cache, scratch: sc, .. } = downcast(&mut **s)?;
            caches.push(cache);
            if scratch.is_none() {
                scratch = Some(sc);
            }
        }
        match scratch {
            Some(sc) => self.model.decode_step_scratch(tokens, &mut caches, sc),
            None => self.model.decode_step(tokens, &mut caches),
        }
    }

    fn memory_report(&self) -> MemoryReport {
        let st = self.pool.status();
        let (dw, dp) = match &self.draft {
            Some(d) => {
                let ds = d.pool.status();
                (d.model.weight_bytes(), ds.total_blocks * ds.block_bytes)
            }
            None => (0, 0),
        };
        MemoryReport {
            weight_bytes: self.model.weight_bytes(),
            weight_bytes_incremental: if self.weights_owner {
                self.model.weight_bytes() + dw
            } else {
                0
            },
            kv_bytes_per_session: self.pool.blocks_for(self.model.cfg.max_seq) * st.block_bytes,
            kv_pool_bytes: st.total_blocks * st.block_bytes,
            kv_pool_used_bytes: st.used_blocks() * st.block_bytes,
            spec_draft_weight_bytes: dw,
            spec_draft_pool_bytes: dp,
        }
    }

    fn kv_pool_status(&self) -> Option<KvPoolStatus> {
        Some(self.pool.status())
    }

    fn supports_prefix_cache(&self) -> bool {
        // speculative engines are excluded: an attached target prefix has
        // no draft-side KV, so the first spec_round would be out of sync
        self.draft.is_none()
    }

    fn export_prefix(
        &self,
        upto_tokens: usize,
        session: &mut dyn EngineSession,
    ) -> Result<Arc<dyn KvPrefix>> {
        self.reject_draft("prefix export")?;
        let s = downcast(session)?;
        let (tokens, blocks) = s.cache.share_prefix(upto_tokens);
        Ok(Arc::new(NativePrefix { pool: self.pool.clone(), blocks, tokens }))
    }

    fn attach_prefix(
        &self,
        prefix: &dyn KvPrefix,
        session: &mut dyn EngineSession,
    ) -> Result<usize> {
        self.reject_draft("prefix attach")?;
        let p = prefix
            .as_any()
            .downcast_ref::<NativePrefix>()
            .ok_or_else(|| anyhow!("prefix does not belong to a native engine"))?;
        if !self.pool.same_pool(&p.pool) {
            bail!("prefix belongs to a different engine's KV pool");
        }
        let s = downcast(session)?;
        s.cache.attach_prefix(p.blocks.clone(), p.tokens)?;
        Ok(p.tokens)
    }

    fn save_prefix(&self, tokens: &[u32], prefix: &dyn KvPrefix) -> Result<SessionFile> {
        let p = prefix
            .as_any()
            .downcast_ref::<NativePrefix>()
            .ok_or_else(|| anyhow!("prefix does not belong to a native engine"))?;
        if tokens.len() != p.tokens {
            bail!(
                "token stream ({}) does not cover the prefix ({} positions)",
                tokens.len(),
                p.tokens
            );
        }
        Ok(SessionFile {
            fingerprint: self.session_fingerprint(),
            tokens: tokens.to_vec(),
            pages: p.blocks.iter().map(|b| self.pool.block_to_bytes(b)).collect(),
        })
    }

    fn restore_prefix(&self, file: &SessionFile) -> Result<(Vec<u32>, Arc<dyn KvPrefix>)> {
        self.reject_draft("prefix restore")?;
        let want = self.session_fingerprint();
        if file.fingerprint != want {
            bail!(
                "session file fingerprint mismatch:\n  file:   {:?}\n  engine: {:?}",
                file.fingerprint,
                want
            );
        }
        let mut blocks = Vec::with_capacity(file.pages.len());
        for page in &file.pages {
            blocks.push(self.pool.block_from_bytes(page)?);
        }
        let prefix = NativePrefix { pool: self.pool.clone(), blocks, tokens: file.tokens.len() };
        Ok((file.tokens.clone(), Arc::new(prefix)))
    }

    fn spec_config(&self) -> Option<&SpecConfig> {
        self.draft.as_ref().map(|d| &d.cfg)
    }

    fn spec_draft_pool_status(&self) -> Option<KvPoolStatus> {
        self.draft.as_ref().map(|d| d.pool.status())
    }

    fn verify_step(
        &self,
        tokens: &[u32],
        session: &mut dyn EngineSession,
    ) -> Result<Vec<f32>> {
        let NativeSession { cache, scratch, .. } = downcast(session)?;
        self.model.verify_step(tokens, cache, scratch)
    }

    fn commit_verified(&self, accepted: usize, session: &mut dyn EngineSession) -> Result<()> {
        let NativeSession { cache, scratch, .. } = downcast(session)?;
        self.model.commit_verified(cache, scratch, accepted)
    }

    fn spec_round(
        &self,
        tokens: &[u32],
        sessions: &mut [&mut dyn EngineSession],
        samplers: &mut [&mut Sampler],
    ) -> Result<Vec<SpecOutcome>> {
        let Some(de) = &self.draft else {
            bail!("engine was not built for speculative decoding (EngineBuilder::speculative)")
        };
        let b = tokens.len();
        if sessions.len() != b || samplers.len() != b {
            bail!("spec_round: tokens/sessions/samplers length mismatch");
        }
        if b == 0 {
            return Ok(Vec::new());
        }
        let mut parts: Vec<&mut NativeSession> = Vec::with_capacity(b);
        for s in sessions.iter_mut() {
            parts.push(downcast(&mut **s)?);
        }
        // sync check: the draft cache (plus its stored catch-up token)
        // must describe exactly the target's committed prefix
        for p in parts.iter() {
            let ds = p
                .draft
                .as_ref()
                .ok_or_else(|| anyhow!("session was created before .speculative was set"))?;
            let have = ds.cache.pos() + usize::from(ds.catchup.is_some());
            if have != p.cache.pos() {
                bail!(
                    "speculative session out of sync (draft covers {have}, target at {}); \
                     do not mix decode_step and spec_round on one session",
                    p.cache.pos()
                );
            }
        }
        // clamp the draft length near the capacity edge: a round commits
        // up to k+1 positions, and the sequence must stop exactly where
        // vanilla decode stops (pos ≤ max_seq − 1, i.e. remaining ≥ 1
        // afterwards) or capacity-bound speculative streams would emit
        // more tokens than `engine::generate`. k = 0 degenerates to a
        // vanilla step (verify the pending token only).
        let min_rem = parts.iter().map(|p| p.cache.remaining()).min().expect("b > 0");
        let k = de.cfg.k.min(min_rem.saturating_sub(2));
        let vocab = self.model.cfg.vocab;

        // -- catch-up: draft sessions left one behind by an all-accepted
        // round ingest that token first (batched over the subset) --------
        {
            let mut cu_toks: Vec<u32> = Vec::new();
            let mut cu_caches: Vec<&mut PagedKvCache> = Vec::new();
            let mut cu_scratch: Option<&mut ForwardScratch> = None;
            for p in parts.iter_mut() {
                let ds = p.draft.as_mut().expect("checked above");
                if let Some(t) = ds.catchup.take() {
                    cu_toks.push(t);
                    cu_caches.push(&mut ds.cache);
                    if cu_scratch.is_none() {
                        cu_scratch = Some(&mut ds.scratch);
                    }
                }
            }
            if !cu_toks.is_empty() {
                let sc = cu_scratch.expect("non-empty catch-up batch");
                de.model.decode_step_scratch(&cu_toks, &mut cu_caches, sc)?;
            }
        }

        // -- draft loop: k batched GEMV steps over all sequences ---------
        // proposals[j] holds each sequence's (j+1)-th draft token;
        // draft_logits[j] the draft's full logits rows at that step (the
        // stochastic acceptance rule needs q; greedy ignores them)
        let mut proposals: Vec<Vec<u32>> = vec![Vec::with_capacity(k); b];
        let mut draft_logits: Vec<Vec<f32>> = Vec::with_capacity(k);
        {
            let mut dcaches: Vec<&mut PagedKvCache> = Vec::with_capacity(b);
            let mut dscratch: Option<&mut ForwardScratch> = None;
            for p in parts.iter_mut() {
                let ds = p.draft.as_mut().expect("checked above");
                dcaches.push(&mut ds.cache);
                if dscratch.is_none() {
                    dscratch = Some(&mut ds.scratch);
                }
            }
            let sc = dscratch.expect("b > 0");
            if k == 0 {
                // degenerate round: keep the draft in sync by feeding the
                // pending token, propose nothing
                de.model.decode_step_scratch(tokens, &mut dcaches, sc)?;
            } else {
                // snapshot each draft cache: rejected proposals written
                // into a quantized page could otherwise grow its tail-
                // block scales for good (the same pollution the target
                // rolls back), leaving draft quality path-dependent
                for c in dcaches.iter_mut() {
                    c.begin_speculation();
                }
                let mut cur: Vec<u32> = tokens.to_vec();
                for _ in 0..k {
                    let dl = de.model.decode_step_scratch(&cur, &mut dcaches, sc)?;
                    for (i, c) in cur.iter_mut().enumerate() {
                        let row = &dl[i * vocab..(i + 1) * vocab];
                        *c = draft_token(row, samplers[i].mode, samplers[i].rng_mut());
                        proposals[i].push(*c);
                    }
                    draft_logits.push(dl);
                }
            }
        }

        // -- verify + commit, per sequence -------------------------------
        let mut outcomes = Vec::with_capacity(b);
        for (i, p) in parts.iter_mut().enumerate() {
            let NativeSession { cache, scratch, draft } = &mut **p;
            let pos0 = cache.pos();
            let mut vtoks = Vec::with_capacity(k + 1);
            vtoks.push(tokens[i]);
            vtoks.extend_from_slice(&proposals[i]);
            let logits = self.model.verify_step(&vtoks, cache, scratch)?;
            let mode = samplers[i].mode;
            let mut accepted = 0usize;
            let mut carried: Option<u32> = None;
            for (j, &d) in proposals[i].iter().enumerate() {
                let trow = &logits[j * vocab..(j + 1) * vocab];
                let drow = &draft_logits[j][i * vocab..(i + 1) * vocab];
                match verify_token(trow, Some(drow), d, mode, samplers[i].rng_mut()) {
                    Verdict::Accepted => accepted += 1,
                    Verdict::Rejected(t) => {
                        carried = Some(t);
                        break;
                    }
                }
            }
            let closing = match carried {
                Some(t) => t,
                None => {
                    let trow = &logits[k * vocab..(k + 1) * vocab];
                    bonus_token(trow, mode, samplers[i].rng_mut())
                }
            };
            self.model.commit_verified(cache, scratch, accepted + 1)?;

            // resolve the draft cache against what was committed
            let ds = draft.as_mut().expect("checked above");
            if k == 0 {
                ds.catchup = None; // draft already ingested the pending token
            } else if accepted < k {
                // roll the draft back to its snapshot (restoring the tail
                // block byte-exactly) and replay the kept tokens through
                // the normal write path, so the draft cache is identical
                // to one that never saw the rejected proposals
                ds.cache.truncate(pos0);
                let mut replay = Vec::with_capacity(accepted + 1);
                replay.push(tokens[i]);
                replay.extend_from_slice(&proposals[i][..accepted]);
                for &t in &replay {
                    let mut one = [&mut ds.cache];
                    de.model.decode_step_scratch(&[t], &mut one, &mut ds.scratch)?;
                }
                ds.catchup = None;
            } else {
                // all accepted: every draft write is a committed token, so
                // the cache is already clean; the draft just never fed its
                // last proposal — ingest it at the start of the next round
                ds.catchup = Some(proposals[i][k - 1]);
            }

            let mut committed = Vec::with_capacity(accepted + 1);
            committed.extend_from_slice(&proposals[i][..accepted]);
            committed.push(closing);
            outcomes.push(SpecOutcome { tokens: committed, accepted, drafted: k });
        }
        Ok(outcomes)
    }
}
