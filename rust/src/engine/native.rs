//! [`InferenceEngine`] over the rust-native [`Transformer`]: host-resident
//! KV caches, batched decode across sessions in a single GEMM (the
//! GEMM-vs-GEMV axis the ABQ engine optimises).

use std::any::Any;

use anyhow::{anyhow, Result};

use crate::model::{KvCache, Transformer};

use super::api::{EngineSession, EngineSpec, Execution, InferenceEngine, MemoryReport};

pub struct NativeEngine {
    model: Transformer,
    spec: EngineSpec,
}

impl NativeEngine {
    pub fn new(model: Transformer) -> Self {
        let spec = EngineSpec {
            model: model.cfg,
            backend: model.backend_name.clone(),
            execution: Execution::Native,
        };
        NativeEngine { model, spec }
    }

    /// Escape hatch to the underlying transformer (engine-internal tools).
    pub fn model(&self) -> &Transformer {
        &self.model
    }
}

struct NativeSession {
    cache: KvCache,
}

impl EngineSession for NativeSession {
    fn pos(&self) -> usize {
        self.cache.pos
    }

    fn remaining(&self) -> usize {
        self.cache.remaining()
    }

    fn kv_bytes(&self) -> usize {
        self.cache.bytes()
    }

    fn fork(&self) -> Result<Box<dyn EngineSession>> {
        Ok(Box::new(NativeSession { cache: self.cache.clone() }))
    }

    fn as_any_mut(&mut self) -> &mut dyn Any {
        self
    }
}

fn downcast<'a>(s: &'a mut dyn EngineSession) -> Result<&'a mut NativeSession> {
    s.as_any_mut()
        .downcast_mut::<NativeSession>()
        .ok_or_else(|| anyhow!("session does not belong to a native engine"))
}

impl InferenceEngine for NativeEngine {
    fn spec(&self) -> &EngineSpec {
        &self.spec
    }

    fn new_session(&self) -> Result<Box<dyn EngineSession>> {
        Ok(Box::new(NativeSession { cache: KvCache::new(&self.model.cfg) }))
    }

    fn prefill(&self, tokens: &[u32], session: &mut dyn EngineSession) -> Result<Vec<f32>> {
        self.model.prefill(tokens, &mut downcast(session)?.cache)
    }

    fn decode_step(
        &self,
        tokens: &[u32],
        sessions: &mut [&mut dyn EngineSession],
    ) -> Result<Vec<f32>> {
        let mut caches: Vec<&mut KvCache> = Vec::with_capacity(sessions.len());
        for s in sessions.iter_mut() {
            caches.push(&mut downcast(&mut **s)?.cache);
        }
        self.model.decode_step(tokens, &mut caches)
    }

    fn memory_report(&self) -> MemoryReport {
        let c = &self.model.cfg;
        MemoryReport {
            weight_bytes: self.model.weight_bytes(),
            kv_bytes_per_session: 2 * c.n_layers * c.max_seq * c.d_model * 4,
        }
    }
}
