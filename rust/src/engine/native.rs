//! [`InferenceEngine`] over the rust-native [`Transformer`]: host-resident
//! KV caches, batched decode across sessions in a single GEMM (the
//! GEMM-vs-GEMV axis the ABQ engine optimises).
//!
//! Each session owns a [`ForwardScratch`] arena alongside its KV cache;
//! prefill and decode thread it into the model so the steady-state decode
//! loop reuses one set of buffers across the 7 block projections, all
//! layers, and all steps (`docs/PERF.md`). Batched decode borrows the
//! first session's arena for the whole batch.

use std::any::Any;

use anyhow::{anyhow, Result};

use crate::model::{ForwardScratch, KvCache, Transformer};

use super::api::{EngineSession, EngineSpec, Execution, InferenceEngine, MemoryReport};

pub struct NativeEngine {
    model: Transformer,
    spec: EngineSpec,
}

impl NativeEngine {
    pub fn new(model: Transformer) -> Self {
        let spec = EngineSpec {
            model: model.cfg,
            backend: model.backend_name.clone(),
            execution: Execution::Native,
        };
        NativeEngine { model, spec }
    }

    /// Escape hatch to the underlying transformer (engine-internal tools).
    pub fn model(&self) -> &Transformer {
        &self.model
    }
}

struct NativeSession {
    cache: KvCache,
    /// per-session forward arena, reused across prefill and decode steps
    scratch: ForwardScratch,
}

impl EngineSession for NativeSession {
    fn pos(&self) -> usize {
        self.cache.pos
    }

    fn remaining(&self) -> usize {
        self.cache.remaining()
    }

    fn kv_bytes(&self) -> usize {
        self.cache.bytes()
    }

    fn fork(&self) -> Result<Box<dyn EngineSession>> {
        // the fork gets its own (cold) arena; it warms on first use
        Ok(Box::new(NativeSession { cache: self.cache.clone(), scratch: ForwardScratch::new() }))
    }

    fn as_any_mut(&mut self) -> &mut dyn Any {
        self
    }
}

fn downcast<'a>(s: &'a mut dyn EngineSession) -> Result<&'a mut NativeSession> {
    s.as_any_mut()
        .downcast_mut::<NativeSession>()
        .ok_or_else(|| anyhow!("session does not belong to a native engine"))
}

impl InferenceEngine for NativeEngine {
    fn spec(&self) -> &EngineSpec {
        &self.spec
    }

    fn new_session(&self) -> Result<Box<dyn EngineSession>> {
        Ok(Box::new(NativeSession {
            cache: KvCache::new(&self.model.cfg),
            scratch: ForwardScratch::new(),
        }))
    }

    fn prefill(&self, tokens: &[u32], session: &mut dyn EngineSession) -> Result<Vec<f32>> {
        let NativeSession { cache, scratch } = downcast(session)?;
        self.model.prefill_scratch(tokens, cache, scratch)
    }

    fn decode_step(
        &self,
        tokens: &[u32],
        sessions: &mut [&mut dyn EngineSession],
    ) -> Result<Vec<f32>> {
        // split each session into (cache, scratch); the batch runs on the
        // first session's arena
        let mut caches: Vec<&mut KvCache> = Vec::with_capacity(sessions.len());
        let mut scratch: Option<&mut ForwardScratch> = None;
        for s in sessions.iter_mut() {
            let NativeSession { cache, scratch: sc } = downcast(&mut **s)?;
            caches.push(cache);
            if scratch.is_none() {
                scratch = Some(sc);
            }
        }
        match scratch {
            Some(sc) => self.model.decode_step_scratch(tokens, &mut caches, sc),
            None => self.model.decode_step(tokens, &mut caches),
        }
    }

    fn memory_report(&self) -> MemoryReport {
        let c = &self.model.cfg;
        MemoryReport {
            weight_bytes: self.model.weight_bytes(),
            kv_bytes_per_session: 2 * c.n_layers * c.max_seq * c.d_model * 4,
        }
    }
}
