//! The model-level abstraction of the unified engine API: one object-safe
//! [`InferenceEngine`] interface consumed by the serving coordinator, the
//! perplexity / zero-shot eval harnesses, and the end-to-end benches —
//! regardless of whether the model executes on the rust-native transformer
//! or through the PJRT artifact path.
//!
//! Sequence state (the KV cache, host- or device-resident) lives in an
//! opaque [`EngineSession`]; engines downcast their own sessions
//! internally, so callers never see the concrete cache type.

use std::any::Any;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::model::{KvCacheConfig, KvPoolStatus, ModelConfig, Sampler};
use crate::runtime::SessionFile;
use crate::spec::{SpecConfig, SpecOutcome};

/// Which execution path an engine runs on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Execution {
    /// the rust-native transformer over pluggable GEMM backends
    Native,
    /// the AOT HLO artifacts on the PJRT CPU client
    Pjrt,
}

/// Static description of a built engine.
#[derive(Clone, Debug)]
pub struct EngineSpec {
    pub model: ModelConfig,
    /// canonical backend spec string (`fp32`, `abq:w2*a8`, ...)
    pub backend: String,
    pub execution: Execution,
    /// KV storage configuration (paged native path; PJRT reports the
    /// fp32 default)
    pub kv: KvCacheConfig,
}

/// Resident-memory accounting (the Table 12 axis).
#[derive(Clone, Copy, Debug, Default)]
pub struct MemoryReport {
    /// packed weights (+ quant scales / zero points / balance vectors)
    pub weight_bytes: usize,
    /// weight bytes this engine *added* to the process: equal to
    /// `weight_bytes` (+ draft weights) for a solo engine or the
    /// designated weights owner of a shared-model replica fleet, and 0
    /// for the other replicas, which only hold another `Arc` onto the
    /// owner's model. Summing reports across replicas therefore counts a
    /// shared model once (docs/SERVING.md §multi-replica).
    pub weight_bytes_incremental: usize,
    /// KV cache bytes one session holds at full capacity
    pub kv_bytes_per_session: usize,
    /// total KV pool budget (0 when the engine has no block pool)
    pub kv_pool_bytes: usize,
    /// KV pool bytes currently leased by live sessions
    pub kv_pool_used_bytes: usize,
    /// packed weights of the speculative draft instantiation (0 when the
    /// engine was not built with `EngineBuilder::speculative`)
    pub spec_draft_weight_bytes: usize,
    /// total budget of the draft's own KV pool (0 without speculation)
    pub spec_draft_pool_bytes: usize,
}

impl MemoryReport {
    pub fn total_bytes(&self, sessions: usize) -> usize {
        self.weight_bytes + sessions * self.kv_bytes_per_session
    }
}

/// Per-sequence state: position + KV storage, owned by the engine that
/// created it. Sessions are not interchangeable across engines.
pub trait EngineSession: Send {
    /// Tokens consumed so far.
    fn pos(&self) -> usize;

    /// Positions left before KV capacity is exhausted.
    fn remaining(&self) -> usize;

    /// Resident KV bytes of this session.
    fn kv_bytes(&self) -> usize;

    /// Clone the sequence state (teacher-forced multi-choice scoring).
    /// Engines whose state is device-resident may not support this.
    fn fork(&self) -> Result<Box<dyn EngineSession>>;

    /// Downcast hook for the owning engine.
    fn as_any_mut(&mut self) -> &mut dyn Any;
}

/// An engine-owned handle to a shared, immutable KV prefix: whole blocks
/// of some session's cache, pinned by refcount. The prefix index holds
/// these; attaching one to a fresh session makes prefill skip the covered
/// positions entirely (`docs/SERVING.md` §prefix cache). Dropping the
/// handle unpins the blocks — they return to the pool once no session
/// references them either.
pub trait KvPrefix: Send + Sync {
    /// Positions the prefix covers (always a whole-block multiple).
    fn token_count(&self) -> usize;

    /// Blocks pinned by this handle.
    fn block_count(&self) -> usize;

    /// Downcast hook for the owning engine.
    fn as_any(&self) -> &dyn Any;
}

/// A built inference engine: the only interface the coordinator, the eval
/// harnesses, and the benches consume. Construct via
/// [`super::EngineBuilder`].
pub trait InferenceEngine: Send + Sync {
    fn spec(&self) -> &EngineSpec;

    /// Fresh sequence state (empty KV at position 0).
    fn new_session(&self) -> Result<Box<dyn EngineSession>>;

    /// Prefill one sequence, filling the session and returning logits
    /// `[tokens, vocab]` (row t = next-token logits after `tokens[..=t]`).
    fn prefill(&self, tokens: &[u32], session: &mut dyn EngineSession) -> Result<Vec<f32>>;

    /// One decode step for a batch of sequences: `tokens[i]` extends
    /// `sessions[i]`. Returns logits `[batch, vocab]`.
    fn decode_step(
        &self,
        tokens: &[u32],
        sessions: &mut [&mut dyn EngineSession],
    ) -> Result<Vec<f32>>;

    fn memory_report(&self) -> MemoryReport;

    /// Occupancy of the engine's shared KV block pool, when it has one.
    /// The scheduler's block-aware admission and preemption consult this;
    /// engines without a host-side pool (PJRT) return `None` and the
    /// coordinator falls back to slot-only admission.
    fn kv_pool_status(&self) -> Option<KvPoolStatus> {
        None
    }

    // -- prefix cache (docs/SERVING.md §prefix cache) ----------------------

    /// Whether this engine can share KV prefixes across sessions. The
    /// scheduler only builds its radix index when this is true; engines
    /// that can't (PJRT device caches, speculative engines whose draft
    /// cache would fall out of sync with an attached target prefix)
    /// silently degrade to full prefill.
    fn supports_prefix_cache(&self) -> bool {
        false
    }

    /// Pin the leading whole blocks of `session`'s cache covering at most
    /// `upto_tokens` positions as a shareable prefix. The handle may
    /// cover 0 tokens (prompt shorter than one block) — callers skip
    /// registering those.
    fn export_prefix(
        &self,
        upto_tokens: usize,
        session: &mut dyn EngineSession,
    ) -> Result<Arc<dyn KvPrefix>> {
        let _ = (upto_tokens, session);
        bail!("engine '{}' has no prefix cache support", self.spec().backend)
    }

    /// Attach a previously exported prefix to a *fresh* session by
    /// reference (copy-on-write — no blocks are copied) and return the
    /// number of positions now resident; prefill the remaining prompt
    /// tail only. Fails if the prefix belongs to another engine's pool.
    fn attach_prefix(
        &self,
        prefix: &dyn KvPrefix,
        session: &mut dyn EngineSession,
    ) -> Result<usize> {
        let _ = (prefix, session);
        bail!("engine '{}' has no prefix cache support", self.spec().backend)
    }

    /// Serialize a prefix (with `tokens`, the ids its pages encode) into
    /// an `.abqs` session file carrying this engine's fingerprint.
    fn save_prefix(&self, tokens: &[u32], prefix: &dyn KvPrefix) -> Result<SessionFile> {
        let _ = (tokens, prefix);
        bail!("engine '{}' has no prefix cache support", self.spec().backend)
    }

    /// Load an `.abqs` session file back into pool blocks, returning the
    /// prefix tokens and an attachable handle. Rejects files whose
    /// fingerprint (model config, backend tag, KV config) does not match
    /// this engine exactly.
    fn restore_prefix(&self, file: &SessionFile) -> Result<(Vec<u32>, Arc<dyn KvPrefix>)> {
        let _ = file;
        bail!("engine '{}' has no prefix cache support", self.spec().backend)
    }

    // -- speculative decoding (docs/SPECULATIVE.md) ------------------------

    /// The speculative-decoding configuration, when the engine was built
    /// with a low-bit draft (`EngineBuilder::speculative`). The scheduler
    /// keys its step shape (draft batch + verify) off this.
    fn spec_config(&self) -> Option<&SpecConfig> {
        None
    }

    /// Multi-token scoring: append `tokens` to the session speculatively
    /// and return target logits at every position `[tokens.len(), vocab]`
    /// (row `j` = next-token distribution after `tokens[..=j]`). Must be
    /// followed by [`InferenceEngine::commit_verified`] on the same
    /// session to resolve the open speculation window.
    fn verify_step(
        &self,
        tokens: &[u32],
        session: &mut dyn EngineSession,
    ) -> Result<Vec<f32>> {
        let _ = (tokens, session);
        bail!("engine '{}' has no speculative verification path", self.spec().backend)
    }

    /// Keep the first `accepted` positions of the last
    /// [`InferenceEngine::verify_step`] window and roll the rest back
    /// (releasing their KV blocks), leaving the session byte-identical to
    /// one that decoded only the accepted tokens.
    fn commit_verified(&self, accepted: usize, session: &mut dyn EngineSession) -> Result<()> {
        let _ = (accepted, session);
        bail!("engine '{}' has no speculative verification path", self.spec().backend)
    }

    /// One full speculative round for a batch: `tokens[i]` is sequence
    /// `i`'s pending token. Drafts up to `SpecConfig.k` tokens per
    /// sequence with the low-bit instantiation (one batched draft GEMV
    /// step per proposal), verifies each sequence's proposals in one
    /// multi-token target pass, commits accepted prefixes and rolls back
    /// the rest. `samplers[i]` drives sequence `i`'s acceptance /
    /// resampling (greedy consumes no randomness). Every outcome commits
    /// at least one token.
    fn spec_round(
        &self,
        tokens: &[u32],
        sessions: &mut [&mut dyn EngineSession],
        samplers: &mut [&mut Sampler],
    ) -> Result<Vec<SpecOutcome>> {
        let _ = (tokens, sessions, samplers);
        bail!(
            "engine '{}' was not built for speculative decoding \
             (EngineBuilder::speculative)",
            self.spec().backend
        )
    }

    /// Occupancy of the draft instantiation's own KV pool, when the
    /// engine runs one (leak checks and serving dashboards).
    fn spec_draft_pool_status(&self) -> Option<KvPoolStatus> {
        None
    }
}

/// Greedy generation helper over any engine (examples / benches): prefill
/// the prompt, then argmax-decode until `max_new` tokens are produced or
/// the session runs out of KV capacity.
pub fn generate(
    engine: &dyn InferenceEngine,
    prompt: &[u32],
    max_new: usize,
) -> Result<Vec<u32>> {
    if prompt.is_empty() {
        anyhow::bail!("generate needs a non-empty prompt");
    }
    if max_new == 0 {
        return Ok(Vec::new());
    }
    let mut session = engine.new_session()?;
    let v = engine.spec().model.vocab;
    let logits = engine.prefill(prompt, session.as_mut())?;
    let last = &logits[(prompt.len() - 1) * v..prompt.len() * v];
    let mut tok = crate::model::argmax(last) as u32;
    let mut out = vec![tok];
    while out.len() < max_new && session.remaining() > 1 {
        let mut refs: [&mut dyn EngineSession; 1] = [session.as_mut()];
        let step = engine.decode_step(&[tok], &mut refs)?;
        tok = crate::model::argmax(&step[..v]) as u32;
        out.push(tok);
    }
    Ok(out)
}
