//! [`EngineBuilder`] — the single construction entry point for inference
//! engines. Used by `main.rs`, the serving examples, the benches and the
//! test suites; nothing outside `engine/` constructs a model directly.
//!
//! ```
//! use abq_llm::engine::{EngineBuilder, InferenceEngine};
//! use abq_llm::model::ModelConfig;
//!
//! # fn main() -> anyhow::Result<()> {
//! const MICRO: ModelConfig = ModelConfig {
//!     name: "micro", vocab: 32, d_model: 16, n_layers: 1, n_heads: 2,
//!     n_kv_heads: 2, d_ff: 32, max_seq: 16, rope_base: 10000.0,
//!     arch: abq_llm::model::ArchVariant::LLAMA,
//! };
//! let engine = EngineBuilder::new()
//!     .random_weights(MICRO, 7)   // or .weights("artifacts")
//!     .backend("abq:w4a8")
//!     .build()?;
//! let mut session = engine.new_session()?;
//! let logits = engine.prefill(&[1, 2, 3], session.as_mut())?;
//! assert_eq!(logits.len(), 3 * engine.spec().model.vocab);
//! # Ok(()) }
//! ```

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

use crate::abq::OptLevel;
use crate::model::{KvCacheConfig, ModelConfig, PackSource, PackView, Transformer, WeightPack};
use crate::precision::{Ladder, OperatingPoint};
use crate::quant::{CorrectionSet, WAConfig};
use crate::runtime::artifacts::ArtifactManifest;
use crate::spec::SpecConfig;
use crate::util::json::Json;
use crate::util::par;

use super::api::{Execution, InferenceEngine};
use super::native::NativeEngine;
use super::registry::{BackendOptions, BackendRegistry};

pub struct EngineBuilder {
    weights: Option<PathBuf>,
    backend: String,
    opt_level: OptLevel,
    threads: Option<usize>,
    execution: Execution,
    registry: BackendRegistry,
    random: Option<(ModelConfig, u64)>,
    kv: KvCacheConfig,
    kv_pool_bytes: Option<usize>,
    correction: Option<CorrectionSet>,
    auto_correction: bool,
    speculative: Option<SpecConfig>,
}

impl Default for EngineBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl EngineBuilder {
    pub fn new() -> Self {
        EngineBuilder {
            weights: None,
            backend: "fp32".to_string(),
            opt_level: OptLevel::Auto,
            threads: None,
            execution: Execution::Native,
            registry: BackendRegistry::with_defaults(),
            random: None,
            kv: KvCacheConfig::default(),
            kv_pool_bytes: None,
            correction: None,
            auto_correction: true,
            speculative: None,
        }
    }

    /// Self-speculative decoding (`docs/SPECULATIVE.md`): draft
    /// `cfg.k` tokens per round with a low-bit instantiation of the
    /// *same* weights at `cfg.draft`, verified in one multi-token pass on
    /// the target backend. Both instantiations come from one artifacts
    /// load; the draft resolves its own config tag's calibrated
    /// corrections (an explicitly set [`EngineBuilder::correction`] set
    /// is shared by both). Native execution only.
    pub fn speculative(mut self, cfg: SpecConfig) -> Self {
        self.speculative = Some(cfg);
        self
    }

    /// Learned distribution corrections to apply at prepare time
    /// (`docs/CALIBRATION.md`). Explicitly set corrections win over the
    /// auto-loaded ones from the artifacts manifest.
    pub fn correction(mut self, set: CorrectionSet) -> Self {
        self.correction = Some(set);
        self
    }

    /// Disable corrections entirely — including the automatic load of a
    /// manifest-registered correction pack for the backend's config tag
    /// (before/after comparisons, `--no-correction`).
    pub fn correction_off(mut self) -> Self {
        self.correction = None;
        self.auto_correction = false;
        self
    }

    /// KV page storage: bit width (32/8/4) + positions per pool block
    /// (native path; see `docs/SERVING.md` for the bits-vs-capacity math).
    pub fn kv_cache(mut self, kv: KvCacheConfig) -> Self {
        self.kv = kv;
        self
    }

    /// Byte budget of the shared KV block pool (defaults to a generous
    /// multiple of `max_seq`; the serving deployment sets this to the
    /// machine's KV memory budget).
    pub fn kv_pool_bytes(mut self, bytes: usize) -> Self {
        self.kv_pool_bytes = Some(bytes);
        self
    }

    /// Artifacts directory holding `weights.abqw` + `manifest.json`.
    pub fn weights(mut self, dir: impl AsRef<Path>) -> Self {
        self.weights = Some(dir.as_ref().to_path_buf());
        self
    }

    /// Backend spec (`fp32`, `int8`, `int4`, `abq:w2*a8`, or a bare WqAp
    /// string), resolved through the registry at build time.
    pub fn backend(mut self, spec: impl Into<String>) -> Self {
        self.backend = spec.into();
        self
    }

    /// Kernel-variant ladder position for backends that honour it.
    pub fn opt_level(mut self, opt: OptLevel) -> Self {
        self.opt_level = opt;
        self
    }

    /// Worker-thread count for the data-parallel GEMM helpers.
    ///
    /// Note: the worker pool is **process-global** (it backs every engine
    /// and the raw kernel API alike, like `ABQ_THREADS`); the last built
    /// engine's setting wins for the whole process.
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = Some(n);
        self
    }

    /// Execution path: rust-native transformer (default) or PJRT artifacts.
    pub fn execution(mut self, e: Execution) -> Self {
        self.execution = e;
        self
    }

    /// Replace the backend registry wholesale.
    pub fn registry(mut self, r: BackendRegistry) -> Self {
        self.registry = r;
        self
    }

    /// Mutable access to the registry (register custom families in place).
    pub fn registry_mut(&mut self) -> &mut BackendRegistry {
        &mut self.registry
    }

    /// Register one custom backend family (builder-chaining form).
    pub fn register_backend<F>(mut self, family: &str, f: F) -> Self
    where
        F: Fn(
                Option<&str>,
                &BackendOptions,
            ) -> Result<Arc<dyn super::linear::LinearBackend>>
            + Send
            + Sync
            + 'static,
    {
        self.registry.register(family, f);
        self
    }

    /// Random-weight model at `cfg` (tests / benches at real layer shapes;
    /// mutually exclusive with `.weights()`).
    pub fn random_weights(mut self, cfg: ModelConfig, seed: u64) -> Self {
        self.random = Some((cfg, seed));
        self
    }

    pub fn build(self) -> Result<Box<dyn InferenceEngine>> {
        if let Some(n) = self.threads {
            par::set_threads(n);
        }
        if self.speculative.is_some() && self.execution != Execution::Native {
            anyhow::bail!("speculative decoding runs on the native execution path only");
        }
        match self.execution {
            Execution::Native => self.build_native(),
            Execution::Pjrt => self.build_pjrt(),
        }
    }

    /// `build()` wrapped into an `Arc` (the form the serving layer holds).
    pub fn build_arc(self) -> Result<Arc<dyn InferenceEngine>> {
        Ok(Arc::from(self.build()?))
    }

    /// Prepare the target (and, when configured, draft) instantiations
    /// once — the step `build_native` and `build_replicas` share. With
    /// artifacts weights this goes through the mmap'd [`PackView`], so
    /// float tensors are borrowed from the mapping while backends pack
    /// them; the mapping drops when this returns (the prepared model
    /// owns only packed state).
    fn prepare_models(&self) -> Result<(Transformer, Option<(SpecConfig, Transformer)>)> {
        let opts = BackendOptions { opt_level: self.opt_level };
        let backend = self
            .registry
            .resolve_with(&self.backend, &opts)
            .with_context(|| format!("resolve backend '{}'", self.backend))?;
        // the draft instantiation of a speculative engine resolves its
        // own backend spec through the same registry/options
        let draft_plan = match &self.speculative {
            Some(sc) => {
                sc.validate()?;
                let spec_str = draft_backend_spec(sc);
                let be = self
                    .registry
                    .resolve_with(&spec_str, &opts)
                    .with_context(|| format!("resolve draft backend '{spec_str}'"))?;
                Some((*sc, spec_str, be))
            }
            None => None,
        };
        if let Some((cfg, seed)) = self.random {
            let m =
                Transformer::random_corrected(cfg, backend.as_ref(), seed, self.correction.as_ref())?;
            let d = match &draft_plan {
                Some((sc, _, be)) => Some((
                    *sc,
                    Transformer::random_corrected(cfg, be.as_ref(), seed, self.correction.as_ref())?,
                )),
                None => None,
            };
            Ok((m, d))
        } else {
            let dir = self.weights.as_ref().ok_or_else(|| {
                anyhow!("EngineBuilder: set .weights(dir) or .random_weights(cfg, seed)")
            })?;
            // one mmap + manifest read serves both instantiations
            let art = read_artifacts(dir)
                .with_context(|| format!("load artifacts from {dir:?} (run `make artifacts`)"))?;
            let m = prepare_from_artifacts(
                &art,
                dir,
                backend.as_ref(),
                self.correction.as_ref(),
                self.auto_correction,
                &self.backend,
            )?;
            let d = match &draft_plan {
                Some((sc, spec_str, be)) => Some((
                    *sc,
                    prepare_from_artifacts(
                        &art,
                        dir,
                        be.as_ref(),
                        self.correction.as_ref(),
                        self.auto_correction,
                        spec_str,
                    )?,
                )),
                None => None,
            };
            Ok((m, d))
        }
    }

    fn build_native(self) -> Result<Box<dyn InferenceEngine>> {
        let (model, draft) = self.prepare_models()?;
        Ok(Box::new(NativeEngine::with_kv_speculative(
            model,
            self.kv,
            self.kv_pool_bytes,
            draft,
        )?))
    }

    /// Build `n` native engines that **share one prepared model** (and
    /// draft, when speculative): weights are prepared once — off a
    /// single mmap'd artifact view when `.weights(dir)` is set — and
    /// held behind `Arc<Transformer>`, while each replica gets its own
    /// private `KvPool` sized by the builder's `kv_pool_bytes`. Replica
    /// 0 is the *weights owner*: its [`super::MemoryReport`] bills the
    /// full weight bytes under `weight_bytes_incremental`; replicas 1..
    /// report ≈ 0 incremental weight bytes, so summing the reports
    /// counts the shared model once (docs/SERVING.md §multi-replica).
    pub fn build_replicas(self, n: usize) -> Result<Vec<Arc<dyn InferenceEngine>>> {
        if n == 0 {
            anyhow::bail!("build_replicas: need at least one replica");
        }
        if let Some(t) = self.threads {
            par::set_threads(t);
        }
        if self.execution != Execution::Native {
            anyhow::bail!("multi-replica serving runs on the native execution path only");
        }
        let (model, draft) = self.prepare_models()?;
        let model = Arc::new(model);
        let draft = draft.map(|(sc, d)| (sc, Arc::new(d)));
        (0..n)
            .map(|i| {
                let engine = NativeEngine::shared(
                    Arc::clone(&model),
                    self.kv,
                    self.kv_pool_bytes,
                    draft.as_ref().map(|(sc, d)| (*sc, Arc::clone(d))),
                    i == 0,
                )?;
                Ok(Arc::new(engine) as Arc<dyn InferenceEngine>)
            })
            .collect()
    }

    /// Build one engine per rung of a precision [`Ladder`] — the
    /// adaptive-serving form (`Frontend::start_adaptive`). Every rung is
    /// prepared from **one** artifacts read; rungs that share a backend
    /// spec (the same WqAp at two KV widths, say) share one prepared
    /// `Arc<Transformer>` outright. The first rung to use each prepared
    /// model is its *weights owner*, so summing the engines'
    /// [`super::MemoryReport`]s bills every distinct weight pack exactly
    /// once (`weight_bytes_incremental` ≈ 0 on the sharing rungs).
    /// Native execution only; speculative decoding does not compose with
    /// the ladder yet.
    pub fn build_adaptive(
        self,
        ladder: &Ladder,
    ) -> Result<Vec<(OperatingPoint, Arc<dyn InferenceEngine>)>> {
        ladder.validate()?;
        if let Some(t) = self.threads {
            par::set_threads(t);
        }
        if self.execution != Execution::Native {
            anyhow::bail!("adaptive serving runs on the native execution path only");
        }
        if self.speculative.is_some() {
            anyhow::bail!("adaptive serving and speculative decoding do not compose yet");
        }
        let opts = BackendOptions { opt_level: self.opt_level };
        // one artifacts read serves every rung (None on the random path)
        let art = match (&self.random, &self.weights) {
            (Some(_), _) => None,
            (None, Some(dir)) => {
                let loaded = read_artifacts(dir).with_context(|| {
                    format!("load artifacts from {dir:?} (run `make artifacts`)")
                })?;
                Some((loaded, dir.clone()))
            }
            (None, None) => anyhow::bail!(
                "EngineBuilder: set .weights(dir) or .random_weights(cfg, seed)"
            ),
        };
        let mut prepared: HashMap<String, Arc<Transformer>> = HashMap::new();
        let mut out = Vec::new();
        for rung in &ladder.rungs {
            let (model, owner) = match prepared.get(&rung.backend) {
                Some(m) => (Arc::clone(m), false),
                None => {
                    let backend = self
                        .registry
                        .resolve_with(&rung.backend, &opts)
                        .with_context(|| format!("resolve backend '{}'", rung.backend))?;
                    let m = if let Some((cfg, seed)) = self.random {
                        Transformer::random_corrected(
                            cfg,
                            backend.as_ref(),
                            seed,
                            self.correction.as_ref(),
                        )?
                    } else {
                        let (loaded, dir) = art.as_ref().expect("checked above");
                        prepare_from_artifacts(
                            loaded,
                            dir,
                            backend.as_ref(),
                            self.correction.as_ref(),
                            self.auto_correction,
                            &rung.backend,
                        )?
                    };
                    let m = Arc::new(m);
                    prepared.insert(rung.backend.clone(), Arc::clone(&m));
                    (m, true)
                }
            };
            let engine =
                NativeEngine::shared(model, rung.kv, self.kv_pool_bytes, None, owner)?;
            out.push((rung.clone(), Arc::new(engine) as Arc<dyn InferenceEngine>));
        }
        Ok(out)
    }

    #[cfg(feature = "pjrt")]
    fn build_pjrt(self) -> Result<Box<dyn InferenceEngine>> {
        let dir = self.weights.ok_or_else(|| {
            anyhow!("EngineBuilder: the PJRT path needs .weights(artifacts_dir)")
        })?;
        let tag = backend_tag(&self.backend)?;
        Ok(Box::new(super::pjrt::PjrtInferenceEngine::load(&dir, &tag, &self.backend)?))
    }

    #[cfg(not(feature = "pjrt"))]
    fn build_pjrt(self) -> Result<Box<dyn InferenceEngine>> {
        anyhow::bail!("this build has no PJRT support (rebuild with `--features pjrt`)")
    }
}

/// The backend spec string a speculative draft resolves to: the fp
/// marker routes to the float comparator, everything else to the
/// arbitrary-bit engine at the draft's WqAp config.
fn draft_backend_spec(sc: &SpecConfig) -> String {
    if sc.draft == WAConfig::FP16 {
        "fp32".to_string()
    } else {
        format!("abq:{}", sc.draft)
    }
}

/// One artifacts-directory read: an mmap'd zero-copy view of the weight
/// pack + parsed manifest + model config. A speculative build prepares
/// two instantiations from this single mapping; `build_replicas`
/// prepares once and shares the result across N engines.
struct LoadedArtifacts {
    view: PackView,
    manifest: Json,
    cfg: ModelConfig,
}

fn read_artifacts(dir: &Path) -> Result<LoadedArtifacts> {
    let view = PackView::open(&dir.join("weights.abqw"))?;
    let manifest =
        std::fs::read_to_string(dir.join("manifest.json")).context("read manifest.json")?;
    let j = Json::parse(&manifest).map_err(|e| anyhow!("manifest parse: {e}"))?;
    let cfg = ModelConfig::from_manifest(&j)?;
    Ok(LoadedArtifacts { view, manifest: j, cfg })
}

/// Prepare every projection of one instantiation with `backend` (the
/// native-path loading step, kept inside `engine/` so model construction
/// has a single home). Correction resolution is explicit set > manifest
/// auto-load for the spec's tag (when enabled) > none.
fn prepare_from_artifacts(
    art: &LoadedArtifacts,
    dir: &Path,
    backend: &dyn super::linear::LinearBackend,
    explicit: Option<&CorrectionSet>,
    auto_correction: bool,
    backend_spec: &str,
) -> Result<Transformer> {
    let auto_set;
    let correction = match explicit {
        Some(set) => Some(set),
        None if auto_correction => {
            auto_set = load_correction_set(&art.manifest, dir, backend_spec)?;
            auto_set.as_ref()
        }
        None => None,
    };
    Transformer::from_source_corrected(PackSource::View(&art.view), art.cfg, backend, correction)
}

/// The auto-load half of correction resolution: when the (already
/// parsed) artifacts manifest registers a correction pack for the
/// backend spec's config tag (written by `abq-llm calibrate`), load it.
/// Backends without an artifact tag (`int8`, `fp32`, custom families)
/// and manifests without a `corrections` section resolve to `None`
/// rather than erroring, so the builder stays usable on uncalibrated
/// artifacts.
fn load_correction_set(
    manifest: &Json,
    dir: &Path,
    backend_spec: &str,
) -> Result<Option<CorrectionSet>> {
    let Ok(tag) = backend_tag(backend_spec) else { return Ok(None) };
    let m = ArtifactManifest::from_json(manifest, dir)?;
    let Some(entry) = m.correction_for_tag(&tag) else { return Ok(None) };
    let pack = WeightPack::load(&entry.path)
        .with_context(|| format!("correction pack for tag '{tag}'"))?;
    let set = CorrectionSet::from_pack(&pack, &tag)?;
    Ok(if set.is_empty() { None } else { Some(set) })
}

/// Map a backend spec to its artifact / routing tag: `fp32`/`fp16`/`fp` →
/// `fp16`; `abq:w2*a8` (or a bare WqAp string) → the filesystem-safe
/// config tag (`w2sa8`).
pub fn backend_tag(spec: &str) -> Result<String> {
    match spec.trim() {
        "fp32" | "fp16" | "fp" => Ok("fp16".to_string()),
        s => {
            let cfg_str = s.strip_prefix("abq:").unwrap_or(s);
            let cfg: WAConfig = cfg_str
                .parse()
                .map_err(|e| anyhow!("backend '{s}' has no artifact tag: {e}"))?;
            Ok(cfg.tag())
        }
    }
}

/// [`backend_tag`] with a total fallback: backends without an artifact
/// tag (`int8`, `fp32`-family-free custom specs) use the trimmed spec
/// string itself. This is the tag `.abqs` session-file fingerprints
/// carry — it only has to be *stable and distinct* per quant config, not
/// filesystem-pretty.
pub fn session_tag(spec: &str) -> String {
    backend_tag(spec).unwrap_or_else(|_| spec.trim().to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_tags() {
        assert_eq!(backend_tag("fp32").unwrap(), "fp16");
        assert_eq!(backend_tag("abq:w2*a8").unwrap(), "w2sa8");
        assert_eq!(backend_tag("w2sa8").unwrap(), "w2sa8");
        assert!(backend_tag("int8").is_err());
    }

    #[test]
    fn session_tags_are_total() {
        assert_eq!(session_tag("abq:w2*a8"), "w2sa8");
        assert_eq!(session_tag("fp32"), "fp16");
        assert_eq!(session_tag("int8"), "int8");
    }

    #[test]
    fn build_requires_a_weight_source() {
        assert!(EngineBuilder::new().build().is_err());
    }

    #[test]
    fn speculative_build_exposes_config_draft_pool_and_memory() {
        const MICRO: ModelConfig = ModelConfig {
            name: "micro",
            vocab: 32,
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            n_kv_heads: 2,
            d_ff: 32,
            max_seq: 16,
            rope_base: 10000.0,
            arch: crate::model::ArchVariant::LLAMA,
        };
        let engine = EngineBuilder::new()
            .random_weights(MICRO, 3)
            .backend("abq:w8a8")
            .speculative("w2*a8:2".parse().unwrap())
            .build()
            .unwrap();
        let sc = engine.spec_config().expect("speculative engine must expose its config");
        assert_eq!(sc.k, 2);
        assert_eq!(sc.draft.to_string(), "w2*a8");
        let dp = engine.spec_draft_pool_status().expect("draft pool");
        assert_eq!(dp.used_blocks(), 0);
        let mem = engine.memory_report();
        assert!(mem.spec_draft_weight_bytes > 0, "draft weights must be accounted");
        assert!(
            mem.spec_draft_weight_bytes < mem.weight_bytes,
            "a w2* draft must be smaller than the w8 target"
        );
        assert!(mem.spec_draft_pool_bytes > 0);
        // a vanilla engine reports neither
        let plain =
            EngineBuilder::new().random_weights(MICRO, 3).backend("abq:w8a8").build().unwrap();
        assert!(plain.spec_config().is_none());
        assert_eq!(plain.memory_report().spec_draft_weight_bytes, 0);
    }

    #[test]
    fn build_adaptive_shares_one_pack_across_rungs_with_the_same_backend() {
        const MICRO: ModelConfig = ModelConfig {
            name: "micro",
            vocab: 32,
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            n_kv_heads: 2,
            d_ff: 32,
            max_seq: 16,
            rope_base: 10000.0,
            arch: crate::model::ArchVariant::LLAMA,
        };
        // same WqAp at two KV widths: one prepared pack, two engines
        let ladder = Ladder::parse("w4a4@kv8,w4a4@kv4").unwrap();
        let rungs =
            EngineBuilder::new().random_weights(MICRO, 3).build_adaptive(&ladder).unwrap();
        assert_eq!(rungs.len(), 2);
        assert_eq!(rungs[0].0.name, "w4a4-kv8");
        let owner = rungs[0].1.memory_report();
        let sharer = rungs[1].1.memory_report();
        assert!(owner.weight_bytes_incremental > 0, "rung 0 owns the pack");
        assert_eq!(
            sharer.weight_bytes_incremental, 0,
            "a rung sharing the backend must not re-bill the pack"
        );
        assert_eq!(owner.weight_bytes, sharer.weight_bytes);
        // the KV width stays per-rung even though the weights are shared
        assert_eq!(rungs[0].1.kv_pool_status().unwrap().bits, 8);
        assert_eq!(rungs[1].1.kv_pool_status().unwrap().bits, 4);
    }

    #[test]
    fn build_adaptive_prepares_every_default_ladder_rung() {
        const MICRO: ModelConfig = ModelConfig {
            name: "micro",
            vocab: 32,
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            n_kv_heads: 2,
            d_ff: 32,
            max_seq: 16,
            rope_base: 10000.0,
            arch: crate::model::ArchVariant::LLAMA,
        };
        let rungs = EngineBuilder::new()
            .random_weights(MICRO, 3)
            .build_adaptive(&Ladder::default_ladder())
            .unwrap();
        assert_eq!(rungs.len(), 3);
        for (op, engine) in &rungs {
            let mut s = engine.new_session().unwrap();
            let logits = engine.prefill(&[1, 2], s.as_mut()).unwrap();
            assert_eq!(logits.len(), 2 * MICRO.vocab, "{}", op.name);
        }
        // distinct backends → each rung owns its own pack
        assert!(rungs.iter().all(|(_, e)| e.memory_report().weight_bytes_incremental > 0));
    }

    #[test]
    fn random_micro_builds_on_every_default_family() {
        const MICRO: ModelConfig = ModelConfig {
            name: "micro",
            vocab: 32,
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            n_kv_heads: 2,
            d_ff: 32,
            max_seq: 16,
            rope_base: 10000.0,
            arch: crate::model::ArchVariant::LLAMA,
        };
        for spec in ["fp32", "int8", "int4", "abq:w8a8"] {
            let engine = EngineBuilder::new()
                .random_weights(MICRO, 3)
                .backend(spec)
                .build()
                .unwrap_or_else(|e| panic!("{spec}: {e}"));
            let mut s = engine.new_session().unwrap();
            let logits = engine.prefill(&[1, 2], s.as_mut()).unwrap();
            assert_eq!(logits.len(), 2 * MICRO.vocab, "{spec}");
            assert_eq!(s.pos(), 2);
        }
    }
}
