//! [`EngineBuilder`] — the single construction entry point for inference
//! engines. Used by `main.rs`, the serving examples, the benches and the
//! test suites; nothing outside `engine/` constructs a model directly.
//!
//! ```
//! use abq_llm::engine::{EngineBuilder, InferenceEngine};
//! use abq_llm::model::ModelConfig;
//!
//! # fn main() -> anyhow::Result<()> {
//! const MICRO: ModelConfig = ModelConfig {
//!     name: "micro", vocab: 32, d_model: 16, n_layers: 1, n_heads: 2,
//!     d_ff: 32, max_seq: 16, rope_base: 10000.0,
//! };
//! let engine = EngineBuilder::new()
//!     .random_weights(MICRO, 7)   // or .weights("artifacts")
//!     .backend("abq:w4a8")
//!     .build()?;
//! let mut session = engine.new_session()?;
//! let logits = engine.prefill(&[1, 2, 3], session.as_mut())?;
//! assert_eq!(logits.len(), 3 * engine.spec().model.vocab);
//! # Ok(()) }
//! ```

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

use crate::abq::OptLevel;
use crate::model::{KvCacheConfig, ModelConfig, Transformer, WeightPack};
use crate::quant::{CorrectionSet, WAConfig};
use crate::runtime::artifacts::ArtifactManifest;
use crate::util::json::Json;
use crate::util::par;

use super::api::{Execution, InferenceEngine};
use super::native::NativeEngine;
use super::registry::{BackendOptions, BackendRegistry};

pub struct EngineBuilder {
    weights: Option<PathBuf>,
    backend: String,
    opt_level: OptLevel,
    threads: Option<usize>,
    execution: Execution,
    registry: BackendRegistry,
    random: Option<(ModelConfig, u64)>,
    kv: KvCacheConfig,
    kv_pool_bytes: Option<usize>,
    correction: Option<CorrectionSet>,
    auto_correction: bool,
}

impl Default for EngineBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl EngineBuilder {
    pub fn new() -> Self {
        EngineBuilder {
            weights: None,
            backend: "fp32".to_string(),
            opt_level: OptLevel::Auto,
            threads: None,
            execution: Execution::Native,
            registry: BackendRegistry::with_defaults(),
            random: None,
            kv: KvCacheConfig::default(),
            kv_pool_bytes: None,
            correction: None,
            auto_correction: true,
        }
    }

    /// Learned distribution corrections to apply at prepare time
    /// (`docs/CALIBRATION.md`). Explicitly set corrections win over the
    /// auto-loaded ones from the artifacts manifest.
    pub fn correction(mut self, set: CorrectionSet) -> Self {
        self.correction = Some(set);
        self
    }

    /// Disable corrections entirely — including the automatic load of a
    /// manifest-registered correction pack for the backend's config tag
    /// (before/after comparisons, `--no-correction`).
    pub fn correction_off(mut self) -> Self {
        self.correction = None;
        self.auto_correction = false;
        self
    }

    /// KV page storage: bit width (32/8/4) + positions per pool block
    /// (native path; see `docs/SERVING.md` for the bits-vs-capacity math).
    pub fn kv_cache(mut self, kv: KvCacheConfig) -> Self {
        self.kv = kv;
        self
    }

    /// Byte budget of the shared KV block pool (defaults to a generous
    /// multiple of `max_seq`; the serving deployment sets this to the
    /// machine's KV memory budget).
    pub fn kv_pool_bytes(mut self, bytes: usize) -> Self {
        self.kv_pool_bytes = Some(bytes);
        self
    }

    /// Artifacts directory holding `weights.abqw` + `manifest.json`.
    pub fn weights(mut self, dir: impl AsRef<Path>) -> Self {
        self.weights = Some(dir.as_ref().to_path_buf());
        self
    }

    /// Backend spec (`fp32`, `int8`, `int4`, `abq:w2*a8`, or a bare WqAp
    /// string), resolved through the registry at build time.
    pub fn backend(mut self, spec: impl Into<String>) -> Self {
        self.backend = spec.into();
        self
    }

    /// Kernel-variant ladder position for backends that honour it.
    pub fn opt_level(mut self, opt: OptLevel) -> Self {
        self.opt_level = opt;
        self
    }

    /// Worker-thread count for the data-parallel GEMM helpers.
    ///
    /// Note: the worker pool is **process-global** (it backs every engine
    /// and the raw kernel API alike, like `ABQ_THREADS`); the last built
    /// engine's setting wins for the whole process.
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = Some(n);
        self
    }

    /// Execution path: rust-native transformer (default) or PJRT artifacts.
    pub fn execution(mut self, e: Execution) -> Self {
        self.execution = e;
        self
    }

    /// Replace the backend registry wholesale.
    pub fn registry(mut self, r: BackendRegistry) -> Self {
        self.registry = r;
        self
    }

    /// Mutable access to the registry (register custom families in place).
    pub fn registry_mut(&mut self) -> &mut BackendRegistry {
        &mut self.registry
    }

    /// Register one custom backend family (builder-chaining form).
    pub fn register_backend<F>(mut self, family: &str, f: F) -> Self
    where
        F: Fn(
                Option<&str>,
                &BackendOptions,
            ) -> Result<Arc<dyn super::linear::LinearBackend>>
            + Send
            + Sync
            + 'static,
    {
        self.registry.register(family, f);
        self
    }

    /// Random-weight model at `cfg` (tests / benches at real layer shapes;
    /// mutually exclusive with `.weights()`).
    pub fn random_weights(mut self, cfg: ModelConfig, seed: u64) -> Self {
        self.random = Some((cfg, seed));
        self
    }

    pub fn build(self) -> Result<Box<dyn InferenceEngine>> {
        if let Some(n) = self.threads {
            par::set_threads(n);
        }
        match self.execution {
            Execution::Native => self.build_native(),
            Execution::Pjrt => self.build_pjrt(),
        }
    }

    /// `build()` wrapped into an `Arc` (the form the serving layer holds).
    pub fn build_arc(self) -> Result<Arc<dyn InferenceEngine>> {
        Ok(Arc::from(self.build()?))
    }

    fn build_native(self) -> Result<Box<dyn InferenceEngine>> {
        let opts = BackendOptions { opt_level: self.opt_level };
        let backend = self
            .registry
            .resolve_with(&self.backend, &opts)
            .with_context(|| format!("resolve backend '{}'", self.backend))?;
        let model = if let Some((cfg, seed)) = self.random {
            Transformer::random_corrected(cfg, backend.as_ref(), seed, self.correction.as_ref())?
        } else {
            let dir = self.weights.as_ref().ok_or_else(|| {
                anyhow!("EngineBuilder: set .weights(dir) or .random_weights(cfg, seed)")
            })?;
            load_artifacts(
                dir,
                backend.as_ref(),
                self.correction.as_ref(),
                self.auto_correction,
                &self.backend,
            )
            .with_context(|| format!("load artifacts from {dir:?} (run `make artifacts`)"))?
        };
        Ok(Box::new(NativeEngine::with_kv(model, self.kv, self.kv_pool_bytes)?))
    }

    #[cfg(feature = "pjrt")]
    fn build_pjrt(self) -> Result<Box<dyn InferenceEngine>> {
        let dir = self.weights.ok_or_else(|| {
            anyhow!("EngineBuilder: the PJRT path needs .weights(artifacts_dir)")
        })?;
        let tag = backend_tag(&self.backend)?;
        Ok(Box::new(super::pjrt::PjrtInferenceEngine::load(&dir, &tag, &self.backend)?))
    }

    #[cfg(not(feature = "pjrt"))]
    fn build_pjrt(self) -> Result<Box<dyn InferenceEngine>> {
        anyhow::bail!("this build has no PJRT support (rebuild with `--features pjrt`)")
    }
}

/// Load pack + manifest from an artifacts directory and prepare every
/// projection with `backend` (the native-path loading step, kept inside
/// `engine/` so model construction has a single home). The manifest is
/// read and parsed exactly once; correction resolution is explicit set >
/// manifest auto-load (when enabled) > none.
fn load_artifacts(
    dir: &Path,
    backend: &dyn super::linear::LinearBackend,
    explicit: Option<&CorrectionSet>,
    auto_correction: bool,
    backend_spec: &str,
) -> Result<Transformer> {
    let pack = WeightPack::load(&dir.join("weights.abqw"))?;
    let manifest =
        std::fs::read_to_string(dir.join("manifest.json")).context("read manifest.json")?;
    let j = Json::parse(&manifest).map_err(|e| anyhow!("manifest parse: {e}"))?;
    let cfg = ModelConfig::from_manifest(&j)?;
    let auto_set;
    let correction = match explicit {
        Some(set) => Some(set),
        None if auto_correction => {
            auto_set = load_correction_set(&j, dir, backend_spec)?;
            auto_set.as_ref()
        }
        None => None,
    };
    Transformer::from_pack_corrected(&pack, cfg, backend, correction)
}

/// The auto-load half of correction resolution: when the (already
/// parsed) artifacts manifest registers a correction pack for the
/// backend spec's config tag (written by `abq-llm calibrate`), load it.
/// Backends without an artifact tag (`int8`, `fp32`, custom families)
/// and manifests without a `corrections` section resolve to `None`
/// rather than erroring, so the builder stays usable on uncalibrated
/// artifacts.
fn load_correction_set(
    manifest: &Json,
    dir: &Path,
    backend_spec: &str,
) -> Result<Option<CorrectionSet>> {
    let Ok(tag) = backend_tag(backend_spec) else { return Ok(None) };
    let m = ArtifactManifest::from_json(manifest, dir)?;
    let Some(entry) = m.correction_for_tag(&tag) else { return Ok(None) };
    let pack = WeightPack::load(&entry.path)
        .with_context(|| format!("correction pack for tag '{tag}'"))?;
    let set = CorrectionSet::from_pack(&pack, &tag)?;
    Ok(if set.is_empty() { None } else { Some(set) })
}

/// Map a backend spec to its artifact / routing tag: `fp32`/`fp16`/`fp` →
/// `fp16`; `abq:w2*a8` (or a bare WqAp string) → the filesystem-safe
/// config tag (`w2sa8`).
pub fn backend_tag(spec: &str) -> Result<String> {
    match spec.trim() {
        "fp32" | "fp16" | "fp" => Ok("fp16".to_string()),
        s => {
            let cfg_str = s.strip_prefix("abq:").unwrap_or(s);
            let cfg: WAConfig = cfg_str
                .parse()
                .map_err(|e| anyhow!("backend '{s}' has no artifact tag: {e}"))?;
            Ok(cfg.tag())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_tags() {
        assert_eq!(backend_tag("fp32").unwrap(), "fp16");
        assert_eq!(backend_tag("abq:w2*a8").unwrap(), "w2sa8");
        assert_eq!(backend_tag("w2sa8").unwrap(), "w2sa8");
        assert!(backend_tag("int8").is_err());
    }

    #[test]
    fn build_requires_a_weight_source() {
        assert!(EngineBuilder::new().build().is_err());
    }

    #[test]
    fn random_micro_builds_on_every_default_family() {
        const MICRO: ModelConfig = ModelConfig {
            name: "micro",
            vocab: 32,
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            d_ff: 32,
            max_seq: 16,
            rope_base: 10000.0,
        };
        for spec in ["fp32", "int8", "int4", "abq:w8a8"] {
            let engine = EngineBuilder::new()
                .random_weights(MICRO, 3)
                .backend(spec)
                .build()
                .unwrap_or_else(|e| panic!("{spec}: {e}"));
            let mut s = engine.new_session().unwrap();
            let logits = engine.prefill(&[1, 2], s.as_mut()).unwrap();
            assert_eq!(logits.len(), 2 * MICRO.vocab, "{spec}");
            assert_eq!(s.pos(), 2);
        }
    }
}
