//! The projection-level abstraction of the unified engine API.
//!
//! A [`LinearBackend`] turns float weights (plus, optionally, calibrated
//! quantized codes from the weight pack) into a prepared [`LinearOp`] —
//! the runtime form of one `nn.Linear` in the served model. The four
//! in-tree backends mirror the paper's comparison set: `fp32`
//! (FastTransformer FP16 stand-in), `int8` (cuBLAS/CUTLASS W8A8), `int4`
//! (CUTLASS W4A4) and `abq:<WqAp>` (the arbitrary-bit bit-plane engine).
//!
//! New precision engines implement these two traits and register a
//! factory in [`super::registry::BackendRegistry`] — no enum to extend,
//! no call sites to edit (see `docs/ENGINE_API.md`).

use anyhow::{bail, Result};

use crate::abq::{AbqScratch, OptLevel, QuantizedLinear};
use crate::baselines::{gemm_fp32_into, Int4Gemm, Int4Scratch, Int8Gemm, Int8Scratch};
use crate::model::PackSource;
use crate::quant::{Correction, WAConfig};

/// Backend-agnostic scratch arena threaded through
/// [`LinearOp::forward_scratch`]. One instance per engine session serves
/// every projection of every layer and step: each backend family owns the
/// sub-arena it needs and ignores the rest, so a model can even mix
/// backends over a single arena. Buffers grow to the largest shape seen
/// and are then reused allocation-free (see `docs/PERF.md`).
#[derive(Default)]
pub struct LinearScratch {
    /// the ABQ engine's arena (codes, packed planes, i64 accumulator, …)
    pub abq: AbqScratch,
    /// INT8 baseline working set
    pub int8: Int8Scratch,
    /// INT4 baseline working set
    pub int4: Int4Scratch,
}

impl LinearScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// One projection, prepared for its backend.
///
/// `forward` writes into a caller-provided output buffer so the decode
/// hot loop can reuse one allocation across the 7 block projections
/// instead of allocating a fresh `Vec` per projection per step;
/// `forward_scratch` extends that discipline to every *intermediate* the
/// projection computes.
pub trait LinearOp: Send + Sync {
    /// `out[tokens, out_features] = x[tokens, in_features] · Wᵀ`.
    ///
    /// Must overwrite every element of `out[..tokens * out_features]`.
    fn forward(&self, x: &[f32], tokens: usize, out: &mut [f32]);

    fn out_features(&self) -> usize;

    fn in_features(&self) -> usize;

    /// Packed weight footprint in bytes (Table 12 memory accounting).
    fn weight_bytes(&self) -> usize;

    /// [`LinearOp::forward`] with a caller-owned scratch arena for all
    /// per-call working state (activation quantization, packing, integer
    /// accumulators). The decode hot loop calls this with one arena per
    /// session; implementations should be allocation-free once the arena
    /// is warm. Backends whose `forward` needs no intermediate storage
    /// (or that wrap an external runtime with its own memory manager)
    /// simply inherit this default, which ignores the arena — see
    /// `docs/ENGINE_API.md` for the implement-vs-inherit guidance.
    fn forward_scratch(
        &self,
        x: &[f32],
        tokens: usize,
        scratch: &mut LinearScratch,
        out: &mut [f32],
    ) {
        let _ = scratch;
        self.forward(x, tokens, out);
    }

    /// Allocating convenience wrapper around [`LinearOp::forward`].
    fn forward_alloc(&self, x: &[f32], tokens: usize) -> Vec<f32> {
        let mut out = vec![0f32; tokens * self.out_features()];
        self.forward(x, tokens, &mut out);
        out
    }
}

/// Where a projection's weights come from: the float tensor is always
/// available; backends that load offline-calibrated state (the ABQ
/// engine's exported codes) additionally get the pack and the
/// `blocks.<layer>.<name>` coordinates to look their tensors up.
pub struct PrepareCtx<'a> {
    /// weight source holding calibrated quantized codes, when available —
    /// either an owned [`crate::model::WeightPack`] or a zero-copy
    /// mmap-backed [`crate::model::PackView`]
    pub pack: Option<PackSource<'a>>,
    /// block index of the projection being prepared
    pub layer: usize,
    /// projection name (`wq`, `wk`, `wv`, `wo`, `gate`, `up`, `down`)
    pub name: &'a str,
    /// learned distribution correction for this projection, already
    /// resolved by the model loader from the engine's
    /// [`crate::quant::CorrectionSet`] (see `docs/CALIBRATION.md`).
    /// Backends that quantize from the float weights apply it; backends
    /// with no quantization grid to correct (fp32) ignore it.
    pub correction: Option<&'a Correction>,
}

impl PrepareCtx<'_> {
    /// Context for weights with no pack behind them (random init, tests).
    pub fn none() -> PrepareCtx<'static> {
        PrepareCtx { pack: None, layer: 0, name: "", correction: None }
    }

    /// [`PrepareCtx::none`] with a resolved correction (calibration /
    /// tests that drive a backend without a full model around it).
    pub fn with_correction(corr: &Correction) -> PrepareCtx<'_> {
        PrepareCtx { pack: None, layer: 0, name: "", correction: Some(corr) }
    }
}

/// A precision engine: prepares projections for execution.
pub trait LinearBackend: Send + Sync {
    /// Canonical spec string (`fp32`, `int8`, `abq:w2*a8`, ...).
    fn name(&self) -> String;

    /// Prepare one projection from float weights `[out_features, in_features]`
    /// (row-major, transposed storage as in the model).
    fn prepare(
        &self,
        w: &[f32],
        out_features: usize,
        in_features: usize,
        ctx: &PrepareCtx,
    ) -> Result<Box<dyn LinearOp>>;
}

// ---------------------------------------------------------------------------
// fp32 — the float comparator ("FP16" rows of Fig. 6 / Table 12)
// ---------------------------------------------------------------------------

pub struct Fp32Backend;

struct Fp32Op {
    w: Vec<f32>,
    out_f: usize,
    in_f: usize,
}

impl LinearOp for Fp32Op {
    fn forward(&self, x: &[f32], tokens: usize, out: &mut [f32]) {
        gemm_fp32_into(x, &self.w, tokens, self.out_f, self.in_f, out);
    }

    fn out_features(&self) -> usize {
        self.out_f
    }

    fn in_features(&self) -> usize {
        self.in_f
    }

    fn weight_bytes(&self) -> usize {
        self.w.len() * 4
    }
}

impl LinearBackend for Fp32Backend {
    fn name(&self) -> String {
        "fp32".to_string()
    }

    fn prepare(
        &self,
        w: &[f32],
        out_features: usize,
        in_features: usize,
        _ctx: &PrepareCtx,
    ) -> Result<Box<dyn LinearOp>> {
        if w.len() != out_features * in_features {
            bail!("fp32 prepare: weight len {} != {out_features}x{in_features}", w.len());
        }
        Ok(Box::new(Fp32Op { w: w.to_vec(), out_f: out_features, in_f: in_features }))
    }
}

// ---------------------------------------------------------------------------
// int8 — padded IMMA stand-in (SmoothQuant's W8A8 engine)
// ---------------------------------------------------------------------------

pub struct Int8Backend;

struct Int8Op(Int8Gemm);

impl LinearOp for Int8Op {
    fn forward(&self, x: &[f32], tokens: usize, out: &mut [f32]) {
        self.0.forward_into(x, tokens, out);
    }

    fn forward_scratch(
        &self,
        x: &[f32],
        tokens: usize,
        scratch: &mut LinearScratch,
        out: &mut [f32],
    ) {
        self.0.forward_scratch(x, tokens, &mut scratch.int8, out);
    }

    fn out_features(&self) -> usize {
        self.0.n
    }

    fn in_features(&self) -> usize {
        self.0.k
    }

    fn weight_bytes(&self) -> usize {
        self.0.weight_bytes()
    }
}

impl LinearBackend for Int8Backend {
    fn name(&self) -> String {
        "int8".to_string()
    }

    fn prepare(
        &self,
        w: &[f32],
        out_features: usize,
        in_features: usize,
        _ctx: &PrepareCtx,
    ) -> Result<Box<dyn LinearOp>> {
        Ok(Box::new(Int8Op(Int8Gemm::from_weights(w, out_features, in_features))))
    }
}

// ---------------------------------------------------------------------------
// int4 — padded IMMA.S4 stand-in (CUTLASS W4A4)
// ---------------------------------------------------------------------------

pub struct Int4Backend;

struct Int4Op(Int4Gemm);

impl LinearOp for Int4Op {
    fn forward(&self, x: &[f32], tokens: usize, out: &mut [f32]) {
        self.0.forward_into(x, tokens, out);
    }

    fn forward_scratch(
        &self,
        x: &[f32],
        tokens: usize,
        scratch: &mut LinearScratch,
        out: &mut [f32],
    ) {
        self.0.forward_scratch(x, tokens, &mut scratch.int4, out);
    }

    fn out_features(&self) -> usize {
        self.0.n
    }

    fn in_features(&self) -> usize {
        self.0.k
    }

    fn weight_bytes(&self) -> usize {
        self.0.weight_bytes()
    }
}

impl LinearBackend for Int4Backend {
    fn name(&self) -> String {
        "int4".to_string()
    }

    fn prepare(
        &self,
        w: &[f32],
        out_features: usize,
        in_features: usize,
        _ctx: &PrepareCtx,
    ) -> Result<Box<dyn LinearOp>> {
        if in_features % 2 != 0 {
            bail!("int4 backend needs even in_features (nibble packing), got {in_features}");
        }
        Ok(Box::new(Int4Op(Int4Gemm::from_weights(w, out_features, in_features))))
    }
}

// ---------------------------------------------------------------------------
// abq — the arbitrary-bit engine at a WqAp config
// ---------------------------------------------------------------------------

pub struct AbqBackend {
    pub cfg: WAConfig,
    /// Table-4 kernel variant; serving uses `OptLevel::Auto`.
    pub opt: OptLevel,
}

impl AbqBackend {
    pub fn new(cfg: WAConfig) -> Self {
        AbqBackend { cfg, opt: OptLevel::Auto }
    }
}

struct AbqOp {
    lin: QuantizedLinear,
    opt: OptLevel,
}

impl LinearOp for AbqOp {
    fn forward(&self, x: &[f32], tokens: usize, out: &mut [f32]) {
        self.lin.forward_into(x, tokens, self.opt, out);
    }

    fn forward_scratch(
        &self,
        x: &[f32],
        tokens: usize,
        scratch: &mut LinearScratch,
        out: &mut [f32],
    ) {
        self.lin.forward_scratch(x, tokens, self.opt, &mut scratch.abq, out);
    }

    fn out_features(&self) -> usize {
        self.lin.out_features
    }

    fn in_features(&self) -> usize {
        self.lin.in_features
    }

    fn weight_bytes(&self) -> usize {
        self.lin.weight_bytes()
    }
}

impl LinearBackend for AbqBackend {
    fn name(&self) -> String {
        format!("abq:{}", self.cfg)
    }

    /// Weight-state precedence, highest first:
    ///
    /// 1. a resolved **non-identity** [`Correction`] in the context —
    ///    requantize from the float weights with the learned
    ///    scale/shift/clip (corrections are learned against exactly this
    ///    requantization, so they supersede any offline-exported codes).
    ///    Identity corrections are a mathematical no-op, so they fall
    ///    through: this keeps projections the calibrator rejected on
    ///    their offline pack codes, and keeps the decode hot path free
    ///    of the (x − 0) / 1 + 0 busywork;
    /// 2. calibrated codes for the config's tag in the weight pack;
    /// 3. RTN from the fp weights (sweep configs never calibrated).
    fn prepare(
        &self,
        w: &[f32],
        out_features: usize,
        in_features: usize,
        ctx: &PrepareCtx,
    ) -> Result<Box<dyn LinearOp>> {
        if let Some(corr) = ctx.correction {
            if corr.in_features() != in_features {
                bail!(
                    "correction for layer {} '{}' has {} channels, projection has {in_features}",
                    ctx.layer,
                    ctx.name,
                    corr.in_features()
                );
            }
            if !corr.is_identity() {
                let lin = QuantizedLinear::from_weights_corrected(
                    w, out_features, in_features, self.cfg, corr,
                );
                return Ok(Box::new(AbqOp { lin, opt: self.opt }));
            }
        }
        if let Some(src) = ctx.pack {
            let base = format!("q.{}.{}.{}", self.cfg.tag(), ctx.layer, ctx.name);
            if src.contains(&format!("{base}.wq")) {
                let codes = src.u8v(&format!("{base}.wq"))?;
                let zw = src.i32v(&format!("{base}.zw"))?.into_owned();
                let dw = src.f32(&format!("{base}.dw"))?.into_owned();
                let balance = src.f32(&format!("{base}.s")).ok().map(|v| v.into_owned());
                let lin = QuantizedLinear::from_codes(
                    codes, out_features, in_features, zw, dw, balance, self.cfg,
                );
                return Ok(Box::new(AbqOp { lin, opt: self.opt }));
            }
        }
        let lin = QuantizedLinear::from_weights_rtn(w, out_features, in_features, self.cfg);
        Ok(Box::new(AbqOp { lin, opt: self.opt }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fp32_op_forward_matches_alloc() {
        let (out_f, in_f, tokens) = (3usize, 8usize, 2usize);
        let w: Vec<f32> = (0..out_f * in_f).map(|i| i as f32 * 0.1).collect();
        let x: Vec<f32> = (0..tokens * in_f).map(|i| (i % 5) as f32 - 2.0).collect();
        let op = Fp32Backend.prepare(&w, out_f, in_f, &PrepareCtx::none()).unwrap();
        let mut out = vec![7f32; tokens * out_f];
        op.forward(&x, tokens, &mut out);
        assert_eq!(out, op.forward_alloc(&x, tokens));
        assert_eq!(op.weight_bytes(), out_f * in_f * 4);
    }

    #[test]
    fn backend_names_are_canonical() {
        assert_eq!(Fp32Backend.name(), "fp32");
        assert_eq!(Int8Backend.name(), "int8");
        assert_eq!(Int4Backend.name(), "int4");
        let abq = AbqBackend::new("w2*a8".parse().unwrap());
        assert_eq!(abq.name(), "abq:w2*a8");
    }

    #[test]
    fn int4_rejects_odd_k() {
        let w = vec![0.0f32; 4 * 7];
        assert!(Int4Backend.prepare(&w, 4, 7, &PrepareCtx::none()).is_err());
    }

    #[test]
    fn abq_prepare_applies_ctx_correction() {
        let (out_f, in_f, tokens) = (8usize, 24usize, 2usize);
        let w: Vec<f32> = (0..out_f * in_f).map(|i| ((i % 13) as f32 - 6.0) / 19.0).collect();
        let x: Vec<f32> = (0..tokens * in_f).map(|i| ((i % 7) as f32 - 3.0) / 2.0).collect();
        let be = AbqBackend::new("w2*a8".parse().unwrap());
        let plain = be.prepare(&w, out_f, in_f, &PrepareCtx::none()).unwrap();
        // identity correction through the ctx: bit-identical to plain RTN,
        // and short-circuited — no balance/shift/offset vectors resident
        let ident = Correction::identity(in_f);
        let op_id = be.prepare(&w, out_f, in_f, &PrepareCtx::with_correction(&ident)).unwrap();
        assert_eq!(plain.forward_alloc(&x, tokens), op_id.forward_alloc(&x, tokens));
        assert_eq!(plain.weight_bytes(), op_id.weight_bytes());
        // a non-trivial correction changes the op (it is actually applied)
        let corr = Correction {
            scale: (0..in_f).map(|i| 1.0 + (i % 3) as f32).collect(),
            shift: vec![0.0; in_f],
            clip: 0.7,
        };
        let op_c = be.prepare(&w, out_f, in_f, &PrepareCtx::with_correction(&corr)).unwrap();
        assert_ne!(plain.forward_alloc(&x, tokens), op_c.forward_alloc(&x, tokens));
        // width mismatch is a hard error, not a silent skip
        let narrow = Correction::identity(in_f - 1);
        assert!(be.prepare(&w, out_f, in_f, &PrepareCtx::with_correction(&narrow)).is_err());
    }

    #[test]
    fn forward_scratch_matches_forward_on_every_default_backend() {
        let (out_f, in_f) = (12usize, 32usize);
        let w: Vec<f32> = (0..out_f * in_f).map(|i| ((i % 19) as f32 - 9.0) / 40.0).collect();
        let backends: Vec<Box<dyn LinearBackend>> = vec![
            Box::new(Fp32Backend),
            Box::new(Int8Backend),
            Box::new(Int4Backend),
            Box::new(AbqBackend::new("w2*a8".parse().unwrap())),
            Box::new(AbqBackend::new("w4a4".parse().unwrap())),
        ];
        let mut scratch = LinearScratch::new();
        for be in &backends {
            let op = be.prepare(&w, out_f, in_f, &PrepareCtx::none()).unwrap();
            for tokens in [1usize, 3] {
                let x: Vec<f32> =
                    (0..tokens * in_f).map(|i| ((i % 7) as f32 - 3.0) / 2.0).collect();
                let want = op.forward_alloc(&x, tokens);
                let mut got = vec![0f32; tokens * out_f];
                op.forward_scratch(&x, tokens, &mut scratch, &mut got);
                assert_eq!(got, want, "backend {} tokens {tokens}", be.name());
            }
        }
    }
}
