//! [`InferenceEngine`] over the PJRT artifact path: the AOT HLO programs
//! (jax L2 model with the pallas L1 kernel inlined) compiled on the PJRT
//! CPU client, with device-resident KV chained between decode steps.
//!
//! Built with `--features pjrt`; the [`super::EngineBuilder`] selects this
//! path via `.execution(Execution::Pjrt)`. The decode artifact has a fixed
//! compiled batch, so sessions are stepped independently (each owns one
//! device KV state) and prefill teacher-forces through the decode program
//! so the session's KV is valid for subsequent decoding. Tags without a
//! decode artifact (e.g. `model_fp16_prefill` only) still serve one-shot
//! prefill logits through the prefill program.

use std::any::Any;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::model::{KvCacheConfig, ModelConfig, WeightPack};
use crate::runtime::{KvState, PjrtEngine, Program};

use super::api::{EngineSession, EngineSpec, Execution, InferenceEngine, MemoryReport};

pub struct PjrtInferenceEngine {
    engine: PjrtEngine,
    prefill_prog: Option<Program>,
    decode_prog: Option<Program>,
    spec: EngineSpec,
    weight_bytes: usize,
    kv_bytes_per_session: usize,
}

impl PjrtInferenceEngine {
    /// Load the artifacts for one quant `tag` (`fp16`, `w2sa8`, ...),
    /// compiling whichever of `model_<tag>_prefill` / `model_<tag>_decode`
    /// the manifest lists.
    pub fn load(dir: &Path, tag: &str, backend_name: &str) -> Result<Self> {
        let engine = PjrtEngine::load(dir)?;
        let pack = WeightPack::load(&dir.join("weights.abqw"))?;
        let prefill_name = format!("model_{tag}_prefill");
        let decode_name = format!("model_{tag}_decode");
        let has = |n: &str| engine.manifest.artifacts.iter().any(|a| a.name == n);
        let decode_prog =
            if has(&decode_name) { Some(engine.program(&decode_name, &pack)?) } else { None };
        // prefill teacher-forces through the decode program when one
        // exists (the KV must end up device-resident for decoding), so the
        // one-shot prefill artifact is only compiled — and its weights
        // only uploaded — when it is the sole execution path for the tag
        let prefill_prog = if decode_prog.is_none() && has(&prefill_name) {
            Some(engine.program(&prefill_name, &pack)?)
        } else {
            None
        };
        if prefill_prog.is_none() && decode_prog.is_none() {
            bail!(
                "no PJRT artifacts for tag '{tag}' in {dir:?} \
                 (looked for {prefill_name} / {decode_name})"
            );
        }
        let manifest_text = std::fs::read_to_string(dir.join("manifest.json"))
            .context("read manifest.json")?;
        let j = crate::util::json::Json::parse(&manifest_text)
            .map_err(|e| anyhow!("manifest parse: {e}"))?;
        let model = ModelConfig::from_manifest(&j)?;
        let m = &engine.manifest;
        // KV state: one [B, S, H, hd] f32 buffer per kv input of the decode
        // artifact (2 per layer: K and V)
        let kv_inputs = engine
            .manifest
            .artifacts
            .iter()
            .find(|a| a.name == decode_name)
            .map(|a| a.inputs.iter().filter(|i| i.starts_with("kv:")).count())
            .unwrap_or(0);
        let kv_buf_elems = m.decode_batch * m.max_seq * m.n_heads * (m.d_model / m.n_heads);
        let weight_bytes = prefill_prog
            .iter()
            .chain(decode_prog.iter())
            .map(|p| p.static_bytes())
            .max()
            .unwrap_or(0);
        let spec = EngineSpec {
            model,
            backend: backend_name.to_string(),
            execution: Execution::Pjrt,
            // device KV is fp32 and unpaged; no host pool on this path
            kv: KvCacheConfig::FP32,
        };
        Ok(PjrtInferenceEngine {
            engine,
            prefill_prog,
            decode_prog,
            spec,
            weight_bytes,
            kv_bytes_per_session: kv_inputs * kv_buf_elems * 4,
        })
    }
}

// SAFETY: the PJRT CPU client is documented thread-safe (PJRT's C API is
// used behind locks), and the engine's compiled executables / device
// buffers are opaque handles that the wrapper types never alias mutably.
// The xla-rs newtypes don't derive Send/Sync, so we assert it here — the
// same contract the serving layer relied on for the native path.
unsafe impl Send for PjrtInferenceEngine {}
unsafe impl Sync for PjrtInferenceEngine {}

struct PjrtSession {
    /// device KV (present when the tag has a decode artifact)
    kv: Option<KvState>,
    pos: usize,
    max_seq: usize,
    kv_bytes: usize,
}

// SAFETY: see PjrtInferenceEngine — device buffer handles are owned,
// never shared, and only touched from one thread at a time through
// `&mut self` methods.
unsafe impl Send for PjrtSession {}

impl EngineSession for PjrtSession {
    fn pos(&self) -> usize {
        self.pos
    }

    fn remaining(&self) -> usize {
        self.max_seq.saturating_sub(self.pos)
    }

    fn kv_bytes(&self) -> usize {
        self.kv_bytes
    }

    fn fork(&self) -> Result<Box<dyn EngineSession>> {
        bail!("fork is not supported on the PJRT execution path (device-resident KV)")
    }

    fn as_any_mut(&mut self) -> &mut dyn Any {
        self
    }
}

fn downcast<'a>(s: &'a mut dyn EngineSession) -> Result<&'a mut PjrtSession> {
    s.as_any_mut()
        .downcast_mut::<PjrtSession>()
        .ok_or_else(|| anyhow!("session does not belong to a PJRT engine"))
}

impl InferenceEngine for PjrtInferenceEngine {
    fn spec(&self) -> &EngineSpec {
        &self.spec
    }

    fn new_session(&self) -> Result<Box<dyn EngineSession>> {
        let kv = match &self.decode_prog {
            Some(p) => Some(p.init_kv(&self.engine.client)?),
            None => None,
        };
        Ok(Box::new(PjrtSession {
            kv,
            pos: 0,
            max_seq: self.spec.model.max_seq,
            kv_bytes: self.kv_bytes_per_session,
        }))
    }

    fn prefill(&self, tokens: &[u32], session: &mut dyn EngineSession) -> Result<Vec<f32>> {
        let sess = downcast(session)?;
        if sess.pos != 0 {
            bail!("PJRT prefill requires a fresh session (pos {})", sess.pos);
        }
        let v = self.spec.model.vocab;
        if let Some(dec) = &self.decode_prog {
            // teacher-force through the decode program so the session's
            // device KV is valid for subsequent decode_step calls; row t of
            // the result is the next-token logits after tokens[..=t]
            let batch = self.engine.manifest.decode_batch;
            let kv = sess.kv.as_mut().ok_or_else(|| anyhow!("session missing device KV"))?;
            let mut out = Vec::with_capacity(tokens.len() * v);
            for &t in tokens {
                let toks = vec![t as i32; batch];
                let logits = dec.decode_step(&self.engine.client, &toks, kv)?;
                out.extend_from_slice(&logits[..v]);
            }
            sess.pos = kv.pos as usize;
            return Ok(out);
        }
        let prog = self
            .prefill_prog
            .as_ref()
            .ok_or_else(|| anyhow!("engine has neither prefill nor decode program"))?;
        let seq = self.engine.manifest.prefill_seq;
        if tokens.len() > seq {
            bail!("prefill length {} exceeds artifact sequence {seq}", tokens.len());
        }
        let mut padded: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
        padded.resize(seq, 0); // causal: padding after the real tokens is inert
        let logits = prog.prefill(&self.engine.client, &padded)?;
        sess.pos = tokens.len();
        Ok(logits[..tokens.len() * v].to_vec())
    }

    fn decode_step(
        &self,
        tokens: &[u32],
        sessions: &mut [&mut dyn EngineSession],
    ) -> Result<Vec<f32>> {
        if tokens.len() != sessions.len() {
            bail!("batch size mismatch: {} tokens, {} sessions", tokens.len(), sessions.len());
        }
        let dec = self
            .decode_prog
            .as_ref()
            .ok_or_else(|| anyhow!("no decode artifact for this tag (prefill-only engine)"))?;
        let v = self.spec.model.vocab;
        let batch = self.engine.manifest.decode_batch;
        let mut out = Vec::with_capacity(tokens.len() * v);
        for (i, s) in sessions.iter_mut().enumerate() {
            let sess = downcast(&mut **s)?;
            let kv = sess
                .kv
                .as_mut()
                .ok_or_else(|| anyhow!("session has no device KV (was prefilled one-shot)"))?;
            let toks = vec![tokens[i] as i32; batch];
            let logits = dec.decode_step(&self.engine.client, &toks, kv)?;
            out.extend_from_slice(&logits[..v]);
            sess.pos = kv.pos as usize;
        }
        Ok(out)
    }

    fn memory_report(&self) -> MemoryReport {
        MemoryReport {
            weight_bytes: self.weight_bytes,
            kv_bytes_per_session: self.kv_bytes_per_session,
            ..Default::default()
        }
    }
}

/// Run one named artifact end to end (the `abq-llm pjrt` subcommand) and
/// return a human-readable summary. Lives here so the raw
/// [`PjrtEngine::program`] API stays encapsulated inside `engine/`.
pub fn run_artifact(dir: &Path, name: &str, steps: usize) -> Result<String> {
    let engine = PjrtEngine::load(dir)?;
    let pack = WeightPack::load(&dir.join("weights.abqw"))?;
    let prog = engine.program(name, &pack)?;
    let mut out = format!("compiled artifact '{name}'\n");
    if name.ends_with("prefill") {
        let s = engine.manifest.prefill_seq;
        let table = crate::eval::corpus::build_transition_table(crate::eval::corpus::TABLE_SEED);
        let toks = crate::eval::corpus::generate_tokens(&table, s, 42);
        let toks_i32: Vec<i32> = toks.iter().map(|&t| t as i32).collect();
        let t0 = std::time::Instant::now();
        let logits = prog.prefill(&engine.client, &toks_i32)?;
        out.push_str(&format!(
            "prefill [{s} tokens] -> {} logits in {:.1} ms\n",
            logits.len(),
            t0.elapsed().as_secs_f64() * 1e3
        ));
    } else {
        let mut kv = prog.init_kv(&engine.client)?;
        let t0 = std::time::Instant::now();
        let v = engine.manifest.vocab;
        let mut tok = vec![1i32; engine.manifest.decode_batch];
        for _ in 0..steps {
            let logits = prog.decode_step(&engine.client, &tok, &mut kv)?;
            for (b, t) in tok.iter_mut().enumerate() {
                *t = crate::model::argmax(&logits[b * v..(b + 1) * v]) as i32;
            }
        }
        out.push_str(&format!(
            "{steps} decode steps in {:.1} ms ({:.1} ms/step)\n",
            t0.elapsed().as_secs_f64() * 1e3,
            t0.elapsed().as_secs_f64() * 1e3 / steps.max(1) as f64
        ));
    }
    Ok(out)
}
