//! The unified engine API (DESIGN: one reconstruction framework, many
//! precision engines — the paper's core claim, surfaced as the crate's
//! construction surface).
//!
//! Three abstractions:
//!
//! * [`LinearBackend`] / [`LinearOp`] — a precision engine at the
//!   projection level. The in-tree set (`fp32`, `int8`, `int4`,
//!   `abq:<WqAp>`) is registered in a string-keyed [`BackendRegistry`];
//!   adding an engine is **one registration**, not an enum sweep.
//! * [`InferenceEngine`] / [`EngineSession`] — a built model behind one
//!   object-safe interface, implemented by both the rust-native
//!   transformer path and the PJRT artifact path. The serving
//!   coordinator, the eval harnesses and the benches all consume this.
//! * [`EngineBuilder`] — the single construction entry point:
//!
//! ```no_run
//! use abq_llm::engine::{EngineBuilder, OptLevel};
//!
//! # fn main() -> anyhow::Result<()> {
//! let engine = EngineBuilder::new()
//!     .weights("artifacts")
//!     .backend("abq:w2*a8")
//!     .opt_level(OptLevel::Auto)
//!     .threads(8)
//!     .build()?;
//! # Ok(()) }
//! ```
//!
//! See `docs/ENGINE_API.md` for the migration table from the old
//! `Backend` enum API and a worked "add your own backend" example.

pub mod api;
pub mod builder;
pub mod linear;
pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod registry;

pub use api::{
    generate, EngineSession, EngineSpec, Execution, InferenceEngine, KvPrefix, MemoryReport,
};
pub use builder::{backend_tag, session_tag, EngineBuilder};
// KV paging configuration is part of the construction surface
pub use crate::model::{KvCacheConfig, KvPoolStatus};
// `.abqs` prefix session files travel through the engine's
// save_prefix/restore_prefix (see docs/SERVING.md §prefix cache)
pub use crate::runtime::{SessionFile, SessionFingerprint};
// learned distribution corrections travel through the builder and
// `PrepareCtx` (see docs/CALIBRATION.md)
pub use crate::quant::{Correction, CorrectionSet};
// the precision ladder rides through `EngineBuilder::build_adaptive`
// into `Frontend::start_adaptive` (see docs/SERVING.md §adaptive)
pub use crate::precision::{Ladder, OperatingPoint};
// self-speculative decoding configuration travels through the builder;
// the round outcome/stats types surface through `spec_round`
// (see docs/SPECULATIVE.md)
pub use crate::spec::{SpecConfig, SpecOutcome, SpecPolicy, SpecStats};
pub use linear::{
    AbqBackend, Fp32Backend, Int4Backend, Int8Backend, LinearBackend, LinearOp, LinearScratch,
    PrepareCtx,
};
pub use native::NativeEngine;
pub use registry::{BackendFactory, BackendOptions, BackendRegistry};

// the kernel-variant ladder is part of the public construction surface
pub use crate::abq::OptLevel;
