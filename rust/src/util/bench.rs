//! Micro-benchmark harness (in-tree criterion substitute; offline build).
//!
//! Adaptive sampling: warm up, pick an iteration count targeting a fixed
//! measurement window, report mean/median/p95. Benches print paper-style
//! rows and also write results/<name>.json via `util::json`.

use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl Measurement {
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }

    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    /// Effective TOPS for an `m×n×k` MAC count (2 ops per MAC), the unit
    /// of paper Tables 13/14.
    pub fn tops(&self, m: usize, n: usize, k: usize) -> f64 {
        let ops = 2.0 * m as f64 * n as f64 * k as f64;
        ops / self.mean_ns
    }
}

pub struct Bencher {
    /// target measurement window per benchmark
    pub window: Duration,
    /// number of timed samples
    pub samples: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        // ABQ_BENCH_FAST=1 shrinks the window for CI-style smoke runs
        let fast = std::env::var("ABQ_BENCH_FAST").is_ok();
        Bencher {
            window: if fast { Duration::from_millis(60) } else { Duration::from_millis(400) },
            samples: if fast { 5 } else { 15 },
        }
    }
}

impl Bencher {
    /// Time `f`, returning aggregate stats.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> Measurement {
        // warmup + calibration: how many iters fit in window/samples?
        f();
        let t0 = Instant::now();
        f();
        let once = t0.elapsed().max(Duration::from_nanos(50));
        let per_sample = self.window.as_nanos() as f64 / self.samples as f64;
        let iters = ((per_sample / once.as_nanos() as f64).ceil() as usize).clamp(1, 1_000_000);

        let mut times = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..iters {
                f();
            }
            times.push(t.elapsed().as_nanos() as f64 / iters as f64);
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        Measurement {
            name: name.to_string(),
            iters,
            mean_ns: mean,
            median_ns: times[times.len() / 2],
            p95_ns: times[((times.len() as f64 * 0.95) as usize).min(times.len() - 1)],
            min_ns: times[0],
        }
    }
}

/// Right-pad helper for table printing.
pub fn pad(s: &str, w: usize) -> String {
    format!("{s:<w$}")
}

/// Write a results JSON file under results/.
pub fn write_results(name: &str, j: &crate::util::json::Json) {
    let dir = std::path::Path::new("results");
    let _ = std::fs::create_dir_all(dir);
    let path = dir.join(format!("{name}.json"));
    if let Err(e) = std::fs::write(&path, j.to_string_pretty()) {
        eprintln!("warn: could not write {path:?}: {e}");
    } else {
        println!("[saved] {}", path.display());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bencher { window: Duration::from_millis(20), samples: 3 };
        let mut x = 0u64;
        let m = b.run("spin", || {
            for i in 0..1000u64 {
                x = x.wrapping_add(i * i);
            }
            std::hint::black_box(x);
        });
        assert!(m.mean_ns > 0.0);
        assert!(m.median_ns <= m.p95_ns + 1.0);
    }
}
