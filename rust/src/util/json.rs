//! Minimal JSON parser/writer (in-tree serde_json substitute; offline
//! build — DESIGN.md §5). Covers the full JSON grammar needed for
//! `artifacts/manifest.json` and results output: objects, arrays, strings
//! (with escapes), f64 numbers, bools, null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Path lookup: `j.at(&["model", "vocab"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in path {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    // -- writer -----------------------------------------------------------
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    v.write(out, indent + 1, pretty);
                }
                if !a.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builders.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|t| t.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| self.err("bad \\u"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| self.err("bad \\u"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a full utf-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|_| self.err("bad utf8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like() {
        let src = r#"{
            "model": {"vocab": 512, "d_model": 256, "rope_base": 10000.0},
            "artifacts": [{"name": "m", "inputs": ["a", "b"]}],
            "ok": true, "none": null, "neg": -1.5e2
        }"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.at(&["model", "vocab"]).unwrap().as_usize(), Some(512));
        assert_eq!(j.at(&["neg"]).unwrap().as_f64(), Some(-150.0));
        assert_eq!(
            j.at(&["artifacts"]).unwrap().as_arr().unwrap()[0]
                .get("inputs")
                .unwrap()
                .as_arr()
                .unwrap()
                .len(),
            2
        );
    }

    #[test]
    fn roundtrip() {
        let j = obj(vec![
            ("a", num(1.0)),
            ("b", Json::Arr(vec![num(2.5), s("x \"y\"\n")])),
            ("c", Json::Bool(false)),
        ]);
        let text = j.to_string_pretty();
        assert_eq!(Json::parse(&text).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["{", "[1,]", "{\"a\" 1}", "tru", "1 2"] {
            assert!(Json::parse(bad).is_err(), "{bad}");
        }
    }
}
