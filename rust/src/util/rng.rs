//! SplitMix64 PRNG — bit-for-bit the generator in python `compile/data.py`,
//! so the rust corpus (`eval::corpus`) reproduces the exact token streams
//! the model was trained and calibrated on.

#[derive(Clone, Debug)]
pub struct SplitMix {
    state: u64,
}

impl SplitMix {
    pub fn new(seed: u64) -> Self {
        SplitMix { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1), 53-bit mantissa — same construction as python.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        self.next_u64() % n
    }

    /// f32 in [-0.5, 0.5) (weight/test data helper).
    #[inline]
    pub fn next_f32_centered(&mut self) -> f32 {
        self.next_f64() as f32 - 0.5
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_sequence_matches_python() {
        // python: SplitMix(42).next_u64() three times
        let mut r = SplitMix::new(42);
        let vals = [r.next_u64(), r.next_u64(), r.next_u64()];
        // reference values computed from compile/data.py
        assert_eq!(vals[0], 13679457532755275413);
        assert_eq!(vals[1], 2949826092126892291);
        assert_eq!(vals[2], 5139283748462763858);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = SplitMix::new(7);
        for _ in 0..1000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }
}
