//! In-tree substrates replacing unavailable third-party crates (the build
//! is fully offline; DESIGN.md §5): thread pool, JSON, CLI, bench harness,
//! property testing, deterministic RNG.

pub mod bench;
pub mod cli;
pub mod json;
pub mod par;
pub mod prop;
pub mod rng;
