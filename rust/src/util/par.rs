//! Minimal data-parallel helpers (in-tree rayon substitute; the build is
//! offline — DESIGN.md §5). Scoped threads over contiguous index ranges:
//! deterministic work assignment, no work stealing, no allocator churn in
//! the hot loop.

use std::sync::atomic::{AtomicUsize, Ordering};

static CACHED: AtomicUsize = AtomicUsize::new(0);

/// Number of worker threads (overridable via `ABQ_THREADS` or
/// [`set_threads`]).
pub fn num_threads() -> usize {
    let c = CACHED.load(Ordering::Relaxed);
    if c != 0 {
        return c;
    }
    let n = std::env::var("ABQ_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&v| v >= 1)
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4)
        });
    CACHED.store(n, Ordering::Relaxed);
    n
}

/// Override the worker count (the `EngineBuilder::threads` hook). Wins
/// over `ABQ_THREADS`; values < 1 are ignored.
pub fn set_threads(n: usize) {
    if n >= 1 {
        CACHED.store(n, Ordering::Relaxed);
    }
}

/// Map `f` over `0..n` in parallel; results returned in index order.
///
/// Work is split into `num_threads()` contiguous ranges. `f` must be
/// `Sync` (called concurrently from several threads).
pub fn par_map_indexed<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = num_threads().min(n.max(1));
    if workers <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let per = n.div_ceil(workers);
    let mut parts: Vec<Vec<T>> = Vec::with_capacity(workers);
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let lo = w * per;
            let hi = ((w + 1) * per).min(n);
            let f = &f;
            handles.push(scope.spawn(move || (lo..hi).map(f).collect::<Vec<T>>()));
        }
        for h in handles {
            parts.push(h.join().expect("par worker panicked"));
        }
    });
    let mut out = Vec::with_capacity(n);
    for p in parts {
        out.extend(p);
    }
    out
}

/// Run `f(lo, hi)` over disjoint chunks of `0..n` in parallel, collecting
/// per-chunk results in chunk order. `chunk` is the target chunk length.
pub fn par_map_chunks<T, F>(n: usize, chunk: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, usize) -> T + Sync,
{
    let chunk = chunk.max(1);
    let n_chunks = n.div_ceil(chunk);
    par_map_indexed(n_chunks, |c| {
        let lo = c * chunk;
        let hi = ((c + 1) * chunk).min(n);
        f(lo, hi)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordered_results() {
        let out = par_map_indexed(1000, |i| i * 2);
        assert_eq!(out, (0..1000).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn chunked_covers_all() {
        let out = par_map_chunks(103, 10, |lo, hi| (lo, hi));
        assert_eq!(out.first(), Some(&(0, 10)));
        assert_eq!(out.last(), Some(&(100, 103)));
        let total: usize = out.iter().map(|(lo, hi)| hi - lo).sum();
        assert_eq!(total, 103);
    }

    #[test]
    fn empty_and_single() {
        assert!(par_map_indexed(0, |i| i).is_empty());
        assert_eq!(par_map_indexed(1, |i| i + 5), vec![5]);
    }
}
