//! Minimal data-parallel helpers (in-tree rayon substitute; the build is
//! offline — DESIGN.md §5) backed by a **persistent worker pool**.
//!
//! The original implementation spawned fresh scoped threads on every
//! invocation — a per-GEMM cost of several microseconds of thread setup
//! plus one heap-allocated result `Vec` per worker, paid once per
//! projection per decode step. The decode hot path (see `docs/PERF.md`)
//! requires steady-state execution with **zero heap allocations and no
//! thread churn**, so workers are now spawned once (lazily, on first use),
//! parked on a condvar, and handed lifetime-erased range jobs:
//!
//! * deterministic work assignment — slot `s` always receives the
//!   contiguous range `[s·per, (s+1)·per)`, as before; no work stealing;
//! * dispatch allocates nothing: the job is a borrowed closure published
//!   through a fixed slot under a mutex, and only the workers a job
//!   actually needs are waited on;
//! * one job owns the pool at a time; a concurrent dispatcher computes
//!   its ranges inline on its own core instead of blocking idle, and a
//!   worker that itself calls into `par` (nested parallelism) runs the
//!   nested job inline, so the pool can never deadlock on itself.
//!
//! Besides the process-global pool there are **dedicated pools**
//! ([`dedicated_pool`]): a serving replica thread binds one with
//! [`PoolHandle::bind_current_thread`] so its GEMM dispatches never
//! contend with sibling replicas on the global dispatch lock (contention
//! would silently degrade a whole replica to inline execution). Binding
//! is per-thread and reversible; a retired replica shuts its pool down
//! ([`PoolHandle::shutdown`]) so the worker threads exit instead of
//! parking forever.

use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, OnceLock};

static CACHED: AtomicUsize = AtomicUsize::new(0);

/// Number of worker threads (overridable via `ABQ_THREADS` or
/// [`set_threads`]).
pub fn num_threads() -> usize {
    let c = CACHED.load(Ordering::Relaxed);
    if c != 0 {
        return c;
    }
    let n = std::env::var("ABQ_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&v| v >= 1)
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4)
        });
    CACHED.store(n, Ordering::Relaxed);
    n
}

/// Override the worker count (the `EngineBuilder::threads` hook). Wins
/// over `ABQ_THREADS`; values < 1 are ignored.
///
/// The pool itself is sized from `num_threads()` at the moment of its
/// first parallel call; raising the count afterwards is capped at the
/// pool size, lowering it simply leaves the extra workers idle.
pub fn set_threads(n: usize) {
    if n >= 1 {
        CACHED.store(n, Ordering::Relaxed);
    }
}

/// Raw-pointer wrapper that may cross thread boundaries. Used by the GEMM
/// kernels to let pool workers write *disjoint* regions of one shared
/// output buffer without per-worker result allocations. Safety is the
/// caller's obligation: regions touched by different workers must not
/// overlap for the duration of the parallel call.
pub struct SendPtr<T>(pub *mut T);

// manual impls: the pointer is Copy regardless of T (derive would bound T)
impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}

impl<T> Copy for SendPtr<T> {}

unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// A published job: lifetime-erased pointer to the dispatcher's closure.
/// Workers call it with their slot index while the dispatcher is blocked
/// inside [`run_job`], which is what keeps the borrow alive.
#[derive(Clone, Copy)]
struct Job {
    f: *const (dyn Fn(usize) + Sync),
    /// number of participating slots (dispatcher is slot 0); workers with
    /// `slot >= slots` skip the job and are not waited on
    slots: usize,
}

unsafe impl Send for Job {}

struct State {
    epoch: u64,
    job: Option<Job>,
    remaining: usize,
    panicked: bool,
    /// set by [`PoolHandle::shutdown`]: workers exit their loop, new
    /// dispatches fall back to inline execution
    shutdown: bool,
}

struct Pool {
    state: Mutex<State>,
    work_cv: Condvar,
    done_cv: Condvar,
    /// serializes dispatchers (one job in flight at a time)
    dispatch: Mutex<()>,
    /// parked worker threads, excluding the dispatching thread (slot 0)
    workers: usize,
}

static POOL: OnceLock<&'static Pool> = OnceLock::new();

thread_local! {
    /// True on pool workers, and on a dispatcher thread while it executes
    /// its own slot of a job. Any nested `par` call made while set runs
    /// inline — the pool never waits on itself.
    static IN_PAR_REGION: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };

    /// The dedicated pool bound to this thread, when any. `None` routes
    /// dispatches to the process-global pool.
    static BOUND_POOL: std::cell::Cell<Option<&'static Pool>> =
        const { std::cell::Cell::new(None) };
}

fn in_par_region() -> bool {
    IN_PAR_REGION.with(|f| f.get())
}

fn spawn_pool(workers: usize, name_prefix: String) -> &'static Pool {
    let p: &'static Pool = Box::leak(Box::new(Pool {
        state: Mutex::new(State {
            epoch: 0,
            job: None,
            remaining: 0,
            panicked: false,
            shutdown: false,
        }),
        work_cv: Condvar::new(),
        done_cv: Condvar::new(),
        dispatch: Mutex::new(()),
        workers,
    }));
    for slot in 1..=workers {
        std::thread::Builder::new()
            .name(format!("{name_prefix}-{slot}"))
            .spawn(move || worker_loop(p, slot))
            .expect("spawn abq par worker");
    }
    p
}

fn pool() -> &'static Pool {
    *POOL.get_or_init(|| spawn_pool(num_threads().saturating_sub(1), "abq-par".to_string()))
}

/// The pool a dispatch on this thread should use: the bound dedicated
/// pool when one is set, the process-global pool otherwise.
fn current_pool() -> &'static Pool {
    BOUND_POOL.with(|b| b.get()).unwrap_or_else(pool)
}

/// Handle to a dedicated worker pool created by [`dedicated_pool`].
/// Copyable; the pool itself is `'static` (its small control block is
/// intentionally leaked — worker threads exit on [`PoolHandle::shutdown`],
/// which is the resource that matters).
#[derive(Clone, Copy)]
pub struct PoolHandle {
    pool: &'static Pool,
}

impl PoolHandle {
    /// Route this thread's `par_*` dispatches through this pool instead
    /// of the process-global one (until [`unbind_current_thread`] or a
    /// later bind). A serving replica thread binds its own pool once at
    /// startup.
    pub fn bind_current_thread(&self) {
        BOUND_POOL.with(|b| b.set(Some(self.pool)));
    }

    /// Stop the pool's workers. Threads currently mid-job finish it
    /// first; afterwards any dispatch through a thread still bound to
    /// this pool simply runs inline. Idempotent.
    pub fn shutdown(&self) {
        let mut g = self.pool.state.lock().unwrap();
        g.shutdown = true;
        self.pool.work_cv.notify_all();
    }
}

/// Create a dedicated pool with `workers` parked worker threads (the
/// dispatcher's own slot comes on top, so `workers = n - 1` gives
/// `n`-way parallelism). `workers = 0` is valid: every dispatch through
/// it runs inline — useful when replicas should not oversubscribe cores.
pub fn dedicated_pool(workers: usize, name: &str) -> PoolHandle {
    PoolHandle { pool: spawn_pool(workers, format!("abq-par-{name}")) }
}

/// Unbind any dedicated pool from this thread, restoring dispatch to the
/// process-global pool.
pub fn unbind_current_thread() {
    BOUND_POOL.with(|b| b.set(None));
}

fn worker_loop(p: &'static Pool, slot: usize) {
    IN_PAR_REGION.with(|f| f.set(true));
    let mut seen = 0u64;
    loop {
        let job = {
            let mut g = p.state.lock().unwrap();
            loop {
                if g.shutdown {
                    return;
                }
                if g.epoch != seen {
                    if let Some(j) = g.job {
                        seen = g.epoch;
                        break j;
                    }
                }
                g = p.work_cv.wait(g).unwrap();
            }
        };
        if slot >= job.slots {
            // not needed for this job; the dispatcher is not waiting on us
            continue;
        }
        let res = std::panic::catch_unwind(AssertUnwindSafe(|| unsafe { (&*job.f)(slot) }));
        let mut g = p.state.lock().unwrap();
        if res.is_err() {
            g.panicked = true;
        }
        g.remaining -= 1;
        if g.remaining == 0 {
            p.done_cv.notify_one();
        }
    }
}

/// Publish `f` to the pool, run slot 0 on the calling thread, wait for
/// the `slots - 1` participating workers to finish. Allocation-free on
/// the dispatch path. Returns false without running anything when another
/// dispatcher currently owns the pool — the caller then computes inline
/// on its own core instead of blocking idle (concurrent engine threads
/// each make progress; the pool accelerates the uncontended case).
fn run_job(p: &'static Pool, f: &(dyn Fn(usize) + Sync), slots: usize) -> bool {
    let guard = match p.dispatch.try_lock() {
        Ok(g) => g,
        Err(_) => return false,
    };
    if p.state.lock().unwrap().shutdown {
        // a retired dedicated pool: its workers are gone, so publishing a
        // job would hang — the caller computes every range inline instead
        return false;
    }
    // Erase the borrow lifetime (fat pointer reinterpret): workers only
    // dereference while this function is blocked below, so `f` strictly
    // outlives every use.
    let job = Job {
        f: unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), *const (dyn Fn(usize) + Sync)>(f)
        },
        slots,
    };
    {
        let mut g = p.state.lock().unwrap();
        g.epoch = g.epoch.wrapping_add(1);
        g.job = Some(job);
        g.remaining = slots - 1;
        g.panicked = false;
        p.work_cv.notify_all();
    }
    IN_PAR_REGION.with(|c| c.set(true));
    let caller = std::panic::catch_unwind(AssertUnwindSafe(|| f(0)));
    IN_PAR_REGION.with(|c| c.set(false));
    let worker_panicked = {
        let mut g = p.state.lock().unwrap();
        while g.remaining != 0 {
            g = p.done_cv.wait(g).unwrap();
        }
        g.job = None;
        g.panicked
    };
    drop(guard);
    match caller {
        Err(e) => std::panic::resume_unwind(e),
        Ok(()) if worker_panicked => panic!("par worker panicked"),
        Ok(()) => true,
    }
}

/// Run `f(lo, hi)` over disjoint contiguous ranges covering `0..n`, in
/// parallel on the persistent pool. The zero-allocation primitive every
/// GEMM variant dispatches through: `f` writes its results straight into
/// caller-owned storage (disjointness is the caller's contract).
///
/// Deterministic assignment: with `s` slots, slot `i` receives
/// `[i·⌈n/s⌉, min((i+1)·⌈n/s⌉, n))`. Nested calls from inside a pool
/// worker run `f(0, n)` inline.
pub fn par_for_ranges<F>(n: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    if n == 0 {
        return;
    }
    let threads = num_threads();
    if threads <= 1 || n == 1 || in_par_region() {
        f(0, n);
        return;
    }
    let p = current_pool();
    let slots = (p.workers + 1).min(threads).min(n);
    if slots <= 1 {
        f(0, n);
        return;
    }
    let per = n.div_ceil(slots);
    let run = move |slot: usize| {
        let lo = slot * per;
        if lo >= n {
            return;
        }
        let hi = (lo + per).min(n);
        f(lo, hi);
    };
    if !run_job(p, &run, slots) {
        // pool owned by a concurrent dispatcher: cover every range inline
        for slot in 0..slots {
            run(slot);
        }
    }
}

/// Map `f` over `0..n` in parallel; results returned in index order.
///
/// Work is split into contiguous ranges on the persistent pool. `f` must
/// be `Sync` (called concurrently from several threads). One allocation:
/// the result `Vec` itself — workers write elements in place, there are
/// no per-worker partial vectors.
pub fn par_map_indexed<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    use std::mem::{ManuallyDrop, MaybeUninit};
    if n == 0 {
        return Vec::new();
    }
    let mut out: Vec<MaybeUninit<T>> = Vec::with_capacity(n);
    // Safety: MaybeUninit elements need no initialization.
    unsafe { out.set_len(n) };
    let ptr = SendPtr(out.as_mut_ptr() as *mut T);
    par_for_ranges(n, |lo, hi| {
        // drop-guard: if `f` panics mid-range, destruct this range's
        // already-written elements (elements of ranges that completed
        // before the panic are abandoned undropped — the process is
        // unwinding through `run_job`'s re-raise at that point)
        struct Partial<U> {
            base: SendPtr<U>,
            lo: usize,
            cur: usize,
        }
        impl<U> Drop for Partial<U> {
            fn drop(&mut self) {
                for j in self.lo..self.cur {
                    unsafe { std::ptr::drop_in_place(self.base.0.add(j)) };
                }
            }
        }
        let mut part = Partial { base: ptr, lo, cur: lo };
        for i in lo..hi {
            // Safety: each index is written exactly once (ranges are
            // disjoint and cover 0..n) within the Vec's capacity.
            unsafe { part.base.0.add(i).write(f(i)) };
            part.cur = i + 1;
        }
        std::mem::forget(part);
    });
    // Safety: every element was initialized above; reinterpret the
    // storage as Vec<T> without dropping the MaybeUninit shell.
    let mut shell = ManuallyDrop::new(out);
    unsafe { Vec::from_raw_parts(shell.as_mut_ptr() as *mut T, n, shell.capacity()) }
}

/// Run `f(lo, hi)` over disjoint chunks of `0..n` in parallel, collecting
/// per-chunk results in chunk order. `chunk` is the target chunk length.
pub fn par_map_chunks<T, F>(n: usize, chunk: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, usize) -> T + Sync,
{
    let chunk = chunk.max(1);
    let n_chunks = n.div_ceil(chunk);
    par_map_indexed(n_chunks, |c| {
        let lo = c * chunk;
        let hi = ((c + 1) * chunk).min(n);
        f(lo, hi)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordered_results() {
        let out = par_map_indexed(1000, |i| i * 2);
        assert_eq!(out, (0..1000).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn chunked_covers_all() {
        let out = par_map_chunks(103, 10, |lo, hi| (lo, hi));
        assert_eq!(out.first(), Some(&(0, 10)));
        assert_eq!(out.last(), Some(&(100, 103)));
        let total: usize = out.iter().map(|(lo, hi)| hi - lo).sum();
        assert_eq!(total, 103);
    }

    #[test]
    fn empty_and_single() {
        assert!(par_map_indexed(0, |i| i).is_empty());
        assert_eq!(par_map_indexed(1, |i| i + 5), vec![5]);
    }

    #[test]
    fn ranges_cover_exactly_once() {
        use std::sync::atomic::AtomicU8;
        let hits: Vec<AtomicU8> = (0..517).map(|_| AtomicU8::new(0)).collect();
        par_for_ranges(517, |lo, hi| {
            for i in lo..hi {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn pool_is_reused_across_many_calls() {
        // steady-state dispatch must not spawn threads or lose results
        for round in 0..200 {
            let out = par_map_indexed(64, |i| i + round);
            assert_eq!(out[63], 63 + round);
        }
    }

    #[test]
    fn nested_parallel_calls_run_inline() {
        let out = par_map_indexed(8, |i| {
            // nested: runs sequentially inside a pool worker, no deadlock
            let inner: usize = par_map_indexed(16, |j| j).into_iter().sum();
            inner + i
        });
        let want: usize = (0..16).sum();
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, want + i);
        }
    }

    #[test]
    fn dedicated_pool_binds_and_computes_correctly() {
        let h = dedicated_pool(2, "test-ded");
        let t = std::thread::spawn(move || {
            h.bind_current_thread();
            let out = par_map_indexed(300, |i| i * 3);
            unbind_current_thread();
            out
        });
        assert_eq!(t.join().unwrap(), (0..300).map(|i| i * 3).collect::<Vec<_>>());
        h.shutdown();
    }

    #[test]
    fn shutdown_pool_falls_back_inline() {
        let h = dedicated_pool(1, "test-shut");
        h.shutdown();
        let t = std::thread::spawn(move || {
            h.bind_current_thread();
            // workers are gone; dispatch must fall back inline, not hang
            let out = par_map_indexed(64, |i| i + 1);
            unbind_current_thread();
            out
        });
        assert_eq!(t.join().unwrap(), (1..=64).collect::<Vec<_>>());
    }

    #[test]
    fn dedicated_pools_are_isolated_across_threads() {
        // two bound threads dispatch concurrently; with separate pools
        // neither falls back due to the *other's* dispatch lock, and both
        // results are exact either way
        let a = dedicated_pool(1, "test-iso-a");
        let b = dedicated_pool(1, "test-iso-b");
        let run = |h: PoolHandle, mult: usize| {
            std::thread::spawn(move || {
                h.bind_current_thread();
                let mut sum = 0usize;
                for _ in 0..50 {
                    sum = par_map_indexed(200, |i| i * mult).iter().sum();
                }
                unbind_current_thread();
                sum
            })
        };
        let (ta, tb) = (run(a, 2), run(b, 3));
        let base: usize = (0..200).sum();
        assert_eq!(ta.join().unwrap(), base * 2);
        assert_eq!(tb.join().unwrap(), base * 3);
        a.shutdown();
        b.shutdown();
    }

    #[test]
    fn concurrent_dispatchers_stay_correct() {
        // whichever dispatcher owns the pool, the others fall back to
        // inline execution — results must be identical either way
        let handles: Vec<_> = (0..4)
            .map(|t| {
                std::thread::spawn(move || {
                    let out = par_map_indexed(200, move |i| i * (t + 1));
                    out.iter().sum::<usize>()
                })
            })
            .collect();
        let base: usize = (0..200).sum();
        for (t, h) in handles.into_iter().enumerate() {
            assert_eq!(h.join().unwrap(), base * (t + 1));
        }
    }
}
