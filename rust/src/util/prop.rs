//! Minimal property-testing harness (in-tree proptest substitute; offline
//! build). No shrinking — on failure it reports the failing case number and
//! seed so the case can be replayed deterministically.

use std::sync::OnceLock;

use super::rng::SplitMix;

pub const DEFAULT_CASES: usize = 64;

/// Case-count override from `ABQ_PROP_CASES`: when the variable holds a
/// positive integer, every [`check`] runs that many cases instead of its
/// compiled-in default (unset / unparsable → defaults unchanged). CI's
/// deep-property job sets it high; local `cargo test` stays fast.
fn case_override() -> Option<usize> {
    static OVERRIDE: OnceLock<Option<usize>> = OnceLock::new();
    *OVERRIDE.get_or_init(|| {
        std::env::var("ABQ_PROP_CASES")
            .ok()
            .and_then(|v| v.trim().parse::<usize>().ok())
            .filter(|&n| n > 0)
    })
}

/// Run `f(rng)` for `cases` deterministic cases (or the `ABQ_PROP_CASES`
/// override); panic with seed on failure.
pub fn check<F: FnMut(&mut SplitMix)>(name: &str, cases: usize, mut f: F) {
    let cases = case_override().unwrap_or(cases);
    for case in 0..cases {
        let seed = 0x5EED_0000u64 + case as u64;
        let mut rng = SplitMix::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut rng);
        }));
        if let Err(e) = result {
            eprintln!("property '{name}' failed at case {case} (seed {seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}

/// Generators.
pub fn usize_in(rng: &mut SplitMix, lo: usize, hi: usize) -> usize {
    lo + rng.next_below((hi - lo + 1) as u64) as usize
}

pub fn f32_in(rng: &mut SplitMix, lo: f32, hi: f32) -> f32 {
    lo + rng.next_f64() as f32 * (hi - lo)
}

pub fn vec_f32(rng: &mut SplitMix, n: usize, lo: f32, hi: f32) -> Vec<f32> {
    (0..n).map(|_| f32_in(rng, lo, hi)).collect()
}

pub fn vec_codes(rng: &mut SplitMix, n: usize, bits: usize) -> Vec<u8> {
    (0..n).map(|_| rng.next_below(1 << bits) as u8).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_runs() {
        let mut first = Vec::new();
        check("gen", 3, |rng| first.push(rng.next_u64()));
        let mut second = Vec::new();
        check("gen", 3, |rng| second.push(rng.next_u64()));
        assert_eq!(first, second);
    }

    #[test]
    fn ranges_respected() {
        check("ranges", 20, |rng| {
            let u = usize_in(rng, 3, 9);
            assert!((3..=9).contains(&u));
            let f = f32_in(rng, -1.0, 1.0);
            assert!((-1.0..=1.0).contains(&f));
            let c = vec_codes(rng, 10, 3);
            assert!(c.iter().all(|&v| v < 8));
        });
    }
}
