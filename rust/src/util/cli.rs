//! Tiny CLI argument parser (in-tree clap substitute; offline build).
//!
//! Grammar: `binary <subcommand> [--key value]... [--flag]... [positional]...`

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Self {
        let mut out = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.options.insert(key.to_string(), v);
                } else {
                    out.flags.push(key.to_string());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn subcommand_options_flags() {
        // note: `--key value` is greedy — a bare word after `--flag` would
        // be taken as its value, so flags go last by convention
        let a = parse("serve extra1 extra2 --port 8080 --config w2*a8 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.get("port"), Some("8080"));
        assert_eq!(a.get("config"), Some("w2*a8"));
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["extra1", "extra2"]);
    }

    #[test]
    fn equals_form_and_defaults() {
        let a = parse("bench --n=128");
        assert_eq!(a.get_usize("n", 0), 128);
        assert_eq!(a.get_usize("missing", 7), 7);
        assert_eq!(a.get_f64("missing", 0.5), 0.5);
    }

    #[test]
    fn trailing_flag() {
        let a = parse("run --fast");
        assert!(a.has_flag("fast"));
        assert!(a.get("fast").is_none());
    }
}
