//! Continuous-batching scheduler: prefill-then-decode with **block-aware**
//! KV admission against the engine's paged KV pool (the serving pattern
//! the paper's engine integrates into). Runs against any
//! [`InferenceEngine`] — native transformer or PJRT artifacts — through
//! the unified engine API; engines without a pool
//! ([`InferenceEngine::kv_pool_status`] `= None`) fall back to slot-only
//! admission.
//!
//! Policy:
//!   * new requests are admitted when a decode slot is free
//!     (`max_active`) **and** the pool can cover the prompt plus one
//!     decode step of headroom; otherwise [`Scheduler::admit`] hands the
//!     request back as [`Admission::Deferred`] (no panic — the server
//!     requeues it);
//!   * admitted requests are prefilled immediately (prefill priority —
//!     keeps the decode batch full, the same reasoning as Orca/vLLM);
//!   * all active sequences then advance one token per engine step in a
//!     single batched GEMM (M = active batch — exactly the GEMM/GEMV
//!     regime the ABQ engine optimises);
//!   * when the pool cannot cover the blocks the next step needs, the
//!     **youngest** sequence is preempted: its session (and blocks) are
//!     released and the sequence is requeued internally, to be resumed by
//!     re-prefilling `prompt ++ generated` once blocks free up;
//!   * finished sequences release their blocks back to the pool;
//!   * with [`SchedulerConfig::prefix_cache`] on (and an engine that
//!     supports it), admitted prompts are matched against a radix
//!     [`PrefixIndex`] of resident prefix KV: matched whole blocks are
//!     *attached* by reference (copy-on-write — `docs/SERVING.md`
//!     §prefix cache) and only the unshared tail is prefilled. Cold
//!     index entries are evicted before live sequences are preempted.
//!
//! Invariants (property-tested): active ≤ max_active; every admitted
//! request completes with exactly `max_new` tokens (or capacity
//! truncation) even across preemption churn; pool blocks never leak;
//! prefix sharing never changes a greedy stream.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::engine::{EngineSession, InferenceEngine, KvPrefix};
use crate::model::Sampler;
use crate::prefix::{PrefixIndex, PrefixStats, SessionStore};

use super::request::{Admission, QueuedRequest, Response, SubmitRequest, Timing};

/// One active sequence.
struct Active {
    id: u64,
    /// the original submission (prompt, sampling, tag, affinity) — kept
    /// whole so a drained sequence can be re-homed with full fidelity
    req: SubmitRequest,
    prompt_len: usize,
    generated: Vec<u32>,
    max_new: usize,
    session: Box<dyn EngineSession>,
    sampler: Sampler,
    last_token: u32,
    timing: Timing,
    started: Instant,
    /// monotone admission stamp — preemption picks the youngest (highest)
    admitted_seq: u64,
}

/// A sequence detached from its session mid-generation: the portable form
/// a preempted sequence waits in, and the unit [`Scheduler::drain_inflight`]
/// hands to the frontend when a replica retires. Resuming (on this
/// scheduler or another replica's, via [`Scheduler::inject`]) re-prefills
/// `req.prompt ++ generated` into a fresh session and continues decoding —
/// with the sampler state carried along, the resumed greedy/sampled stream
/// is bit-identical to an uninterrupted run.
pub struct InFlight {
    pub id: u64,
    pub req: SubmitRequest,
    pub prompt_len: usize,
    pub generated: Vec<u32>,
    pub max_new: usize,
    pub sampler: Sampler,
    pub timing: Timing,
    pub started: Instant,
    /// original admission stamp, restored on resume so a resumed veteran
    /// does not become the preferred preemption victim
    pub admitted_seq: u64,
}

impl Active {
    /// Drop the engine session (releasing its KV blocks back to the pool)
    /// and keep the portable replay state.
    fn detach(a: Active) -> InFlight {
        InFlight {
            id: a.id,
            req: a.req,
            prompt_len: a.prompt_len,
            generated: a.generated,
            max_new: a.max_new,
            sampler: a.sampler,
            timing: a.timing,
            started: a.started,
            admitted_seq: a.admitted_seq,
        }
    }
}

/// Sequence `i`'s share of a batched step's `total` µs: the integer
/// division plus one distributed-remainder microsecond for the first
/// `total % n` sequences, so the shares always sum to exactly `total`
/// (the old `total / n` for everyone dropped up to `n − 1` µs per step).
fn decode_share_us(total: u64, n: u64, i: usize) -> u64 {
    total / n + u64::from((i as u64) < total % n)
}

/// Sequence `i`'s share of a speculative step's `total` µs, proportional
/// to `weights[i]` — the tokens that sequence actually *committed* this
/// step, so a sequence whose drafts were all rejected is not billed as if
/// it had decoded k + 1 tokens. Exact-sum preserving via cumulative
/// rounding: `share_i = ⌊total·W_{≤i}/W⌋ − ⌊total·W_{<i}/W⌋`, which
/// telescopes back to `total`. A zero total weight falls back to the
/// uniform [`decode_share_us`] split.
fn decode_share_weighted_us(total: u64, weights: &[u64], i: usize) -> u64 {
    let w: u64 = weights.iter().sum();
    if w == 0 {
        return decode_share_us(total, weights.len().max(1) as u64, i);
    }
    let before: u64 = weights[..i].iter().sum();
    let upto = before + weights[i];
    // u128: total · W can overflow u64 at large token counts
    ((total as u128 * upto as u128 / w as u128) - (total as u128 * before as u128 / w as u128))
        as u64
}

pub struct SchedulerConfig {
    pub max_active: usize,
    /// Enable the prefix cache (radix index + copy-on-write attach).
    /// Silently inert on engines without prefix support — speculative
    /// engines and engines without a paged pool.
    pub prefix_cache: bool,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig { max_active: 8, prefix_cache: false }
    }
}

/// Synchronous continuous-batching loop around one engine.
pub struct Scheduler {
    engine: Arc<dyn InferenceEngine>,
    cfg: SchedulerConfig,
    active: Vec<Active>,
    preempted: VecDeque<InFlight>,
    finished: Vec<Response>,
    admit_counter: u64,
    preemptions: u64,
    /// draft tokens proposed / accepted across all speculative rounds
    /// (serving metrics: the acceptance-rate gauges)
    spec_drafted: u64,
    spec_accepted: u64,
    /// radix index over resident prefix KV (`Some` iff the config asks
    /// for it and the engine supports attach)
    prefix: Option<PrefixIndex>,
    /// session-file directory fresh prefixes are persisted to
    store: Option<SessionStore>,
}

impl Scheduler {
    pub fn new(engine: Arc<dyn InferenceEngine>, cfg: SchedulerConfig) -> Self {
        let prefix =
            (cfg.prefix_cache && engine.supports_prefix_cache()).then(PrefixIndex::new);
        Scheduler {
            engine,
            cfg,
            active: Vec::new(),
            preempted: VecDeque::new(),
            finished: Vec::new(),
            admit_counter: 0,
            preemptions: 0,
            spec_drafted: 0,
            spec_accepted: 0,
            prefix,
            store: None,
        }
    }

    /// A decode slot is free. (Block availability is checked per-request
    /// in [`Scheduler::admit`], since it depends on the prompt length.)
    pub fn has_capacity(&self) -> bool {
        self.active.len() < self.cfg.max_active
    }

    pub fn n_active(&self) -> usize {
        self.active.len()
    }

    /// Sequences evicted from the pool and waiting to resume.
    pub fn n_preempted(&self) -> usize {
        self.preempted.len()
    }

    /// Total preemption events so far (serving metrics).
    pub fn preemption_count(&self) -> u64 {
        self.preemptions
    }

    /// `(drafted, accepted)` totals across all speculative rounds (zero
    /// on non-speculative engines).
    pub fn spec_counters(&self) -> (u64, u64) {
        (self.spec_drafted, self.spec_accepted)
    }

    /// Prefix-cache gauges; `None` when the cache is disabled or the
    /// engine cannot attach prefixes.
    pub fn prefix_stats(&self) -> Option<PrefixStats> {
        self.prefix.as_ref().map(|ix| ix.stats())
    }

    /// Warm the prefix index from a `.abqs` session directory and keep
    /// the store around so freshly registered prefixes persist into it.
    /// Returns how many session files were restored (0 when the prefix
    /// cache is disabled — the store is then dropped, not kept).
    pub fn attach_session_store(&mut self, store: SessionStore) -> usize {
        if self.prefix.is_none() {
            return 0;
        }
        let (restored, _skipped) = store.load_all(self.engine.as_ref());
        let n = restored.len();
        for (tokens, pfx) in restored {
            self.prefix.as_mut().expect("prefix checked above").insert(&tokens, pfx);
        }
        self.store = Some(store);
        n
    }

    /// Make `needed` blocks free, evicting cold prefix entries if that
    /// is what it takes (an entry still shared by a live session frees
    /// nothing, so the loop keeps evicting until the bill is covered or
    /// the index drains). Engines without a pool trivially cover any
    /// bill. Returns whether `needed` blocks are now free.
    fn free_blocks_for(&mut self, needed: usize) -> bool {
        loop {
            let Some(st) = self.engine.kv_pool_status() else { return true };
            if needed <= st.free_blocks {
                return true;
            }
            let Some(ix) = self.prefix.as_mut() else { return false };
            if !ix.evict_lru() {
                return false;
            }
        }
    }

    /// Admit + prefill one request, or hand it back as
    /// [`Admission::Deferred`] when a slot or the pool cannot cover it
    /// right now. Errors are reserved for requests that can *never* run
    /// (prompt alone exceeds the whole pool) and real engine failures.
    pub fn admit(&mut self, qr: QueuedRequest, seed: u64) -> Result<Admission> {
        if !self.has_capacity() {
            return Ok(Admission::Deferred(qr));
        }
        // preempted sequences have first claim on freed blocks: admitting
        // fresh work past them would burn a prefill just to be evicted
        // again (and starve the resume queue)
        if !self.preempted.is_empty() {
            return Ok(Admission::Deferred(qr));
        }
        // one real index lookup (LRU-bumping) per admission attempt: the
        // match both discounts the block bill below and rides into
        // `activate` as the attach hint, so an eviction between the two
        // cannot invalidate it — the Arc pins the pages
        let cap = qr.req.prompt.len().saturating_sub(1);
        let hint = match self.prefix.as_mut() {
            Some(ix) => ix.lookup(&qr.req.prompt, cap),
            None => None,
        };
        if let Some(st) = self.engine.kv_pool_status() {
            // blocks to start a sequence: prompt plus one decode step of
            // headroom. On speculative engines the draft prefill leases
            // the same count from its own equal-budget pool, so this one
            // check covers both.
            let needed = st.blocks_for(qr.req.prompt.len() + 1);
            if needed > st.total_blocks {
                bail!(
                    "request {} needs {needed} KV blocks but the pool holds only {}",
                    qr.id,
                    st.total_blocks
                );
            }
            // matched whole blocks are already resident — the request
            // only bills the unshared tail
            let matched = hint.as_ref().map_or(0, |(n, _)| *n);
            let discounted = needed.saturating_sub(st.blocks_for(matched));
            if !self.free_blocks_for(discounted) {
                return Ok(Admission::Deferred(qr));
            }
        }
        let now = Instant::now();
        let queue_us = now.duration_since(qr.arrived).as_micros() as u64;
        // clamp generation to KV capacity
        let max_seq = self.engine.spec().model.max_seq;
        let max_new = qr.req.max_new.min(max_seq.saturating_sub(qr.req.prompt.len() + 1));
        let prompt_len = qr.req.prompt.len();
        self.admit_counter += 1;
        let stamp = self.admit_counter;
        let sampler = Sampler::new(qr.req.sampling, seed);
        self.activate(
            InFlight {
                id: qr.id,
                req: qr.req,
                prompt_len,
                generated: Vec::new(),
                max_new,
                sampler,
                timing: Timing { queue_us, prefill_us: 0, decode_us: 0 },
                started: now,
                admitted_seq: stamp,
            },
            hint,
        )?;
        Ok(Admission::Admitted)
    }

    /// Shared activation path for fresh admissions (`generated` empty) and
    /// preemption / drain resumes (`generated` carried): attach any
    /// matched prefix by reference, prefill the unshared tail of
    /// `prompt ++ generated` into a fresh session, sample the next token,
    /// and push the sequence onto the active batch. Fresh admissions
    /// carry the admit-time match as `hint`; resumes pass `None` and
    /// re-match here, so replay-after-preemption rides the same path.
    fn activate(
        &mut self,
        f: InFlight,
        hint: Option<(usize, Arc<dyn KvPrefix>)>,
    ) -> Result<()> {
        let InFlight {
            id,
            req,
            prompt_len,
            mut generated,
            max_new,
            mut sampler,
            mut timing,
            started,
            admitted_seq,
        } = f;
        let mut session = self.engine.new_session()?;
        let t0 = Instant::now();
        let mut feed = req.prompt.clone();
        feed.extend_from_slice(&generated);
        let hint = hint.or_else(|| match self.prefix.as_mut() {
            Some(ix) => ix.lookup(&feed, feed.len().saturating_sub(1)),
            None => None,
        });
        let mut attached = 0usize;
        if let Some((_, pfx)) = &hint {
            attached = self.engine.attach_prefix(pfx.as_ref(), session.as_mut())?;
        }
        let logits = self.engine.prefill(&feed[attached..], session.as_mut())?;
        timing.prefill_us += t0.elapsed().as_micros() as u64;
        let v = self.engine.spec().model.vocab;
        let fed = feed.len() - attached;
        let last = &logits[(fed - 1) * v..fed * v];
        let tok = sampler.sample(last);
        // a freshly prefilled prompt is the next request's prefix
        if generated.is_empty() {
            self.register_prefix(&req.prompt, session.as_mut());
        }
        generated.push(tok);
        self.active.push(Active {
            id,
            req,
            prompt_len,
            generated,
            max_new,
            session,
            sampler,
            last_token: tok,
            timing,
            started,
            admitted_seq,
        });
        Ok(())
    }

    /// Register the session's whole-block coverage of `prompt` in the
    /// index (and the session store, when one is attached and the path
    /// is fresh). Best-effort: a failure only means the next identical
    /// prompt re-prefills.
    fn register_prefix(&mut self, prompt: &[u32], session: &mut dyn EngineSession) {
        if self.prefix.is_none() {
            return;
        }
        let Ok(pfx) = self.engine.export_prefix(prompt.len(), session) else { return };
        let shared = pfx.token_count();
        if shared == 0 {
            return;
        }
        let fresh = self
            .prefix
            .as_mut()
            .expect("prefix checked above")
            .insert(&prompt[..shared], Arc::clone(&pfx));
        if fresh {
            if let Some(store) = &self.store {
                if let Err(e) =
                    store.persist(self.engine.as_ref(), &prompt[..shared], pfx.as_ref())
                {
                    eprintln!("[prefix] failed to persist session file: {e:#}");
                }
            }
        }
    }

    /// One batched step over all active sequences (resuming preempted
    /// ones first when blocks allow, preempting when they don't): a
    /// single-token decode on plain engines, a full speculative round
    /// (draft batch + verify) on engines built with
    /// `EngineBuilder::speculative`.
    pub fn step(&mut self) -> Result<()> {
        self.resume_preempted()?;
        if self.active.is_empty() {
            return Ok(());
        }
        // retire sequences that already have enough tokens
        self.retire();
        // a speculative round writes up to k + 1 positions per sequence
        // before rolling back, so its headroom lookahead is k + 1
        let lookahead = self.engine.spec_config().map_or(1, |sc| sc.k + 1);
        self.ensure_step_headroom(lookahead);
        if self.active.is_empty() {
            return Ok(());
        }
        if self.engine.spec_config().is_some() {
            self.spec_step()
        } else {
            self.vanilla_step()
        }
    }

    fn vanilla_step(&mut self) -> Result<()> {
        let engine = self.engine.clone();
        let t0 = Instant::now();
        let tokens: Vec<u32> = self.active.iter().map(|a| a.last_token).collect();
        let mut sessions: Vec<&mut dyn EngineSession> =
            self.active.iter_mut().map(|a| a.session.as_mut()).collect();
        let logits = engine.decode_step(&tokens, &mut sessions)?;
        drop(sessions);
        let step_us = t0.elapsed().as_micros() as u64;
        let v = engine.spec().model.vocab;
        let n = self.active.len() as u64;
        for (bi, a) in self.active.iter_mut().enumerate() {
            let row = &logits[bi * v..(bi + 1) * v];
            let tok = a.sampler.sample(row);
            a.generated.push(tok);
            a.last_token = tok;
            a.timing.decode_us += decode_share_us(step_us, n, bi);
        }
        self.retire();
        Ok(())
    }

    /// One speculative round: every active sequence drafts, verifies and
    /// commits 1..=k+1 tokens. Step time is attributed by *committed*
    /// tokens per sequence, not uniformly, preserving the exact-sum
    /// invariant ([`decode_share_weighted_us`]).
    fn spec_step(&mut self) -> Result<()> {
        let engine = self.engine.clone();
        let t0 = Instant::now();
        let tokens: Vec<u32> = self.active.iter().map(|a| a.last_token).collect();
        let mut sessions: Vec<&mut dyn EngineSession> = Vec::with_capacity(self.active.len());
        let mut samplers: Vec<&mut Sampler> = Vec::with_capacity(self.active.len());
        for a in self.active.iter_mut() {
            sessions.push(a.session.as_mut());
            samplers.push(&mut a.sampler);
        }
        let outcomes = engine.spec_round(&tokens, &mut sessions, &mut samplers)?;
        drop(sessions);
        drop(samplers);
        let step_us = t0.elapsed().as_micros() as u64;
        let weights: Vec<u64> = outcomes.iter().map(|o| o.tokens.len() as u64).collect();
        for (bi, (a, o)) in self.active.iter_mut().zip(&outcomes).enumerate() {
            self.spec_drafted += o.drafted as u64;
            self.spec_accepted += o.accepted as u64;
            // a round can overshoot max_new by up to k; keep the prefix so
            // the emitted stream is exactly vanilla's
            for &tok in &o.tokens {
                if a.generated.len() < a.max_new {
                    a.generated.push(tok);
                }
            }
            a.last_token = *o.tokens.last().expect("spec_round commits at least one token");
            a.timing.decode_us += decode_share_weighted_us(step_us, &weights, bi);
        }
        self.retire();
        Ok(())
    }

    /// Resume preempted sequences (oldest first) while a slot and enough
    /// free blocks exist: re-prefill `prompt ++ generated` into a fresh
    /// session, then continue decoding. A preempted sequence whose
    /// replayed length can no longer fit the pool at all is finished with
    /// the tokens it has (capacity truncation).
    fn resume_preempted(&mut self) -> Result<()> {
        loop {
            if self.active.len() >= self.cfg.max_active {
                break;
            }
            // the replay's admission math gets the same whole-block
            // prefix discount a fresh prompt would (stateless peek; the
            // LRU-bumping match happens in `activate`)
            let Some((replay_len, matched)) = self.preempted.front().map(|front| {
                let replay_len = front.req.prompt.len() + front.generated.len();
                let matched = match &self.prefix {
                    Some(ix) => {
                        let mut replay = front.req.prompt.clone();
                        replay.extend_from_slice(&front.generated);
                        ix.peek_len(&replay, replay.len().saturating_sub(1))
                    }
                    None => 0,
                };
                (replay_len, matched)
            }) else {
                break;
            };
            if let Some(st) = self.engine.kv_pool_status() {
                let needed = st.blocks_for(replay_len + 1);
                if needed > st.total_blocks {
                    let p = self.preempted.pop_front().unwrap();
                    self.finished.push(Response {
                        id: p.id,
                        prompt_len: p.prompt_len,
                        tokens: p.generated,
                        timing: p.timing,
                    });
                    continue;
                }
                let discounted = needed.saturating_sub(st.blocks_for(matched));
                if !self.free_blocks_for(discounted) {
                    break;
                }
            }
            let p = self.preempted.pop_front().unwrap();
            self.activate(p, None)?;
        }
        Ok(())
    }

    /// Make sure the pool can cover every active sequence advancing
    /// `lookahead` positions (1 for vanilla decode; k + 1 for a
    /// speculative round, whose verify pass transiently writes the whole
    /// window); preempt the youngest sequence (releasing its blocks)
    /// until it can. A sole sequence that still cannot get a block is
    /// finished with what it has. The draft pool needs no separate
    /// check: it has the same budget and block geometry, a draft cache
    /// never runs ahead of its target cache, and the draft writes at
    /// most `lookahead` rows per round too.
    fn ensure_step_headroom(&mut self, lookahead: usize) {
        if self.engine.kv_pool_status().is_none() {
            return;
        }
        loop {
            // one status read per iteration (free_blocks changes as
            // preempted sessions drop their blocks)
            let Some(st) = self.engine.kv_pool_status() else { return };
            let needed: usize = self
                .active
                .iter()
                .map(|a| {
                    let pos = a.session.pos();
                    st.blocks_for(pos + lookahead) - st.blocks_for(pos)
                })
                .sum();
            if needed <= st.free_blocks {
                return;
            }
            // cold prefix entries go before live sequences: evicting one
            // may free whole blocks without losing any computed tokens
            if self.prefix.as_mut().is_some_and(|ix| ix.evict_lru()) {
                continue;
            }
            if self.active.len() <= 1 {
                // nothing left to evict: finish the lone sequence early
                if let Some(a) = self.active.pop() {
                    self.finished.push(Response {
                        id: a.id,
                        prompt_len: a.prompt_len,
                        tokens: a.generated,
                        timing: a.timing,
                    });
                }
                return;
            }
            let youngest = self
                .active
                .iter()
                .enumerate()
                .max_by_key(|(_, a)| a.admitted_seq)
                .map(|(i, _)| i)
                .expect("active is non-empty");
            let a = self.active.swap_remove(youngest);
            // dropping the session releases its leased blocks to the pool
            self.preemptions += 1;
            self.preempted.push_back(Active::detach(a));
        }
    }

    fn retire(&mut self) {
        let mut i = 0;
        while i < self.active.len() {
            let done = self.active[i].generated.len() >= self.active[i].max_new
                || self.active[i].session.remaining() <= 1;
            if done {
                let a = self.active.swap_remove(i);
                let _ = a.started;
                self.finished.push(Response {
                    id: a.id,
                    prompt_len: a.prompt_len,
                    tokens: a.generated,
                    timing: a.timing,
                });
            } else {
                i += 1;
            }
        }
    }

    /// Detach every in-flight sequence — active (sessions dropped, their
    /// blocks returned to the pool) and preempted alike — and hand them
    /// back in admission order, for the frontend to re-home when this
    /// replica retires or dies. The scheduler is left with no sequence
    /// state; already-finished responses stay collectable via
    /// [`Scheduler::take_finished`].
    pub fn drain_inflight(&mut self) -> Vec<InFlight> {
        let mut out: Vec<InFlight> =
            self.active.drain(..).map(Active::detach).collect();
        out.extend(self.preempted.drain(..));
        out.sort_by_key(|f| f.admitted_seq);
        out
    }

    /// Adopt a sequence drained from another replica: it joins the resume
    /// queue (which has first claim on freed blocks over fresh
    /// admissions) and is re-stamped into this scheduler's admission
    /// order. A sequence that already has all its tokens finishes
    /// immediately.
    pub fn inject(&mut self, mut f: InFlight) {
        if f.generated.len() >= f.max_new {
            self.finished.push(Response {
                id: f.id,
                prompt_len: f.prompt_len,
                tokens: f.generated,
                timing: f.timing,
            });
            return;
        }
        self.admit_counter += 1;
        f.admitted_seq = self.admit_counter;
        self.preempted.push_back(f);
    }

    pub fn take_finished(&mut self) -> Vec<Response> {
        std::mem::take(&mut self.finished)
    }

    pub fn idle(&self) -> bool {
        self.active.is_empty() && self.preempted.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::SubmitRequest;
    use crate::engine::EngineBuilder;
    use crate::model::{KvCacheConfig, ModelConfig};

    const MICRO: ModelConfig = ModelConfig {
        name: "micro",
        vocab: 64,
        d_model: 16,
        n_layers: 1,
        n_heads: 2,
        n_kv_heads: 2,
        d_ff: 32,
        max_seq: 32,
        rope_base: 10000.0,
        arch: crate::model::ArchVariant::LLAMA,
    };

    fn micro_engine(seed: u64) -> Arc<dyn InferenceEngine> {
        EngineBuilder::new().random_weights(MICRO, seed).backend("fp32").build_arc().unwrap()
    }

    fn run_all(s: &mut Scheduler) {
        for _ in 0..200 {
            if s.idle() {
                break;
            }
            s.step().unwrap();
        }
    }

    #[test]
    fn generates_exact_token_counts() {
        let mut s =
            Scheduler::new(micro_engine(1), SchedulerConfig { max_active: 4, ..Default::default() });
        for id in 0..3u64 {
            let adm = s
                .admit(
                    QueuedRequest::new(id, SubmitRequest::new(vec![1, 2, 3], 5)),
                    id,
                )
                .unwrap();
            assert!(matches!(adm, Admission::Admitted));
        }
        run_all(&mut s);
        let mut done = s.take_finished();
        done.sort_by_key(|r| r.id);
        assert_eq!(done.len(), 3);
        for r in &done {
            assert_eq!(r.tokens.len(), 5);
            assert_eq!(r.prompt_len, 3);
        }
    }

    #[test]
    fn drain_and_inject_replay_bit_identically() {
        // the drain path a retiring replica rides: interrupt mid-stream,
        // move every in-flight sequence to a second scheduler over an
        // identically-weighted engine, and the streams must match an
        // uninterrupted run token for token
        let run_uninterrupted = || {
            let mut s = Scheduler::new(
                micro_engine(31),
                SchedulerConfig { max_active: 4, ..Default::default() },
            );
            for id in 0..3u64 {
                s.admit(QueuedRequest::new(id, SubmitRequest::new(vec![1, 2, 3 + id as u32], 6)), id)
                    .unwrap();
            }
            run_all(&mut s);
            let mut done = s.take_finished();
            done.sort_by_key(|r| r.id);
            done
        };
        let expected = run_uninterrupted();

        let mut a = Scheduler::new(
            micro_engine(31),
            SchedulerConfig { max_active: 4, ..Default::default() },
        );
        for id in 0..3u64 {
            a.admit(QueuedRequest::new(id, SubmitRequest::new(vec![1, 2, 3 + id as u32], 6)), id)
                .unwrap();
        }
        // a couple of decode steps, then the replica "dies"
        a.step().unwrap();
        a.step().unwrap();
        let moved = a.drain_inflight();
        assert!(a.idle(), "drained scheduler holds no sequence state");
        let mut b = Scheduler::new(
            micro_engine(31),
            SchedulerConfig { max_active: 4, ..Default::default() },
        );
        for f in moved {
            b.inject(f);
        }
        run_all(&mut b);
        let mut done = a.take_finished();
        done.extend(b.take_finished());
        done.sort_by_key(|r| r.id);
        assert_eq!(done.len(), expected.len());
        for (d, e) in done.iter().zip(&expected) {
            assert_eq!(d.id, e.id);
            assert_eq!(d.tokens, e.tokens, "request {} stream must survive the move", d.id);
            assert_eq!(d.prompt_len, e.prompt_len);
        }
    }

    #[test]
    fn respects_kv_capacity() {
        let mut s = Scheduler::new(micro_engine(2), SchedulerConfig::default());
        // prompt 20 + request 100 new > max_seq 32 → truncated
        s.admit(
            QueuedRequest::new(9, SubmitRequest::new((0..20).map(|i| i as u32 % 64).collect(), 100)),
            0,
        )
        .unwrap();
        run_all(&mut s);
        let done = s.take_finished();
        assert_eq!(done.len(), 1);
        assert!(done[0].tokens.len() <= 32 - 20);
        assert!(!done[0].tokens.is_empty());
    }

    #[test]
    fn capacity_bound() {
        let mut s =
            Scheduler::new(micro_engine(3), SchedulerConfig { max_active: 2, ..Default::default() });
        for id in 0..2u64 {
            s.admit(
                QueuedRequest::new(id, SubmitRequest::new(vec![1], 3)),
                id,
            )
            .unwrap();
        }
        assert!(!s.has_capacity());
    }

    #[test]
    fn admit_without_capacity_defers_instead_of_panicking() {
        let mut s =
            Scheduler::new(micro_engine(4), SchedulerConfig { max_active: 1, ..Default::default() });
        s.admit(
            QueuedRequest::new(0, SubmitRequest::new(vec![1], 2)),
            0,
        )
        .unwrap();
        // second admit: no slot — the request must come back intact
        let adm = s
            .admit(
                QueuedRequest::new(7, SubmitRequest::new(vec![1, 2], 2)),
                1,
            )
            .unwrap();
        match adm {
            Admission::Deferred(qr) => {
                assert_eq!(qr.id, 7);
                assert_eq!(qr.req.prompt, vec![1, 2]);
            }
            Admission::Admitted => panic!("must defer when at max_active"),
            Admission::Routed(_) => unreachable!("schedulers never route"),
        }
    }

    #[test]
    fn unadmittable_prompt_is_an_error() {
        // pool of 1 block (8 positions) can never hold a 20-token prompt
        let engine = EngineBuilder::new()
            .random_weights(MICRO, 5)
            .backend("fp32")
            .kv_cache(KvCacheConfig { bits: 32, block_size: 8 })
            .kv_pool_bytes(1)
            .build_arc()
            .unwrap();
        assert_eq!(engine.kv_pool_status().unwrap().total_blocks, 1);
        let mut s = Scheduler::new(engine, SchedulerConfig::default());
        let r = s.admit(
            QueuedRequest::new(0, SubmitRequest::new((0..20).map(|i| i % 60).collect(), 4)),
            0,
        );
        assert!(r.is_err(), "a prompt larger than the whole pool can never run");
    }

    #[test]
    fn weighted_decode_timing_sums_exactly_and_tracks_accepted_tokens() {
        // satellite: verify-step time is split by committed tokens per
        // sequence, never uniformly, and the shares always sum back to
        // the step's wall time exactly
        let cases: &[(u64, &[u64])] = &[
            (0, &[1, 1, 1]),
            (7, &[1]),
            (100, &[5, 1, 1]),
            (99, &[2, 3, 4]),
            (12345, &[1, 0, 7, 2]),
            (17, &[0, 0, 0]), // degenerate: falls back to the uniform split
            (u64::MAX / 3, &[3, 5]), // u128 path: no overflow
        ];
        for &(total, weights) in cases {
            let shares: Vec<u64> = (0..weights.len())
                .map(|i| decode_share_weighted_us(total, weights, i))
                .collect();
            assert_eq!(
                shares.iter().sum::<u64>(),
                total,
                "shares of {total}µs over {weights:?} must sum back"
            );
            if weights.iter().sum::<u64>() > 0 {
                // proportionality: a zero-weight sequence pays nothing and
                // a strictly heavier sequence never pays less
                for (i, &w) in weights.iter().enumerate() {
                    if w == 0 {
                        assert_eq!(shares[i], 0, "zero-commit sequence billed in {shares:?}");
                    }
                }
                // exact proportionality up to 1µs of rounding, checked in
                // integers: |share_i·W − total·w_i| < W
                let w: u64 = weights.iter().sum();
                for (i, &wi) in weights.iter().enumerate() {
                    let lhs = shares[i] as u128 * w as u128;
                    let rhs = total as u128 * wi as u128;
                    assert!(
                        lhs + w as u128 > rhs && rhs + w as u128 > lhs,
                        "share {} of {shares:?} drifts from total·w/W",
                        shares[i]
                    );
                }
            }
        }
    }

    #[test]
    fn speculative_scheduler_emits_exact_counts_and_counts_acceptance() {
        // a speculative engine behind the scheduler: same request
        // behavior as vanilla (exact token counts), acceptance counters
        // move, and the streams match a vanilla engine at the same seed
        let spec_engine: Arc<dyn InferenceEngine> = EngineBuilder::new()
            .random_weights(MICRO, 11)
            .backend("fp32")
            .speculative("w2*a8:2".parse().unwrap())
            .build_arc()
            .unwrap();
        let vanilla: Arc<dyn InferenceEngine> =
            EngineBuilder::new().random_weights(MICRO, 11).backend("fp32").build_arc().unwrap();
        let run = |engine: Arc<dyn InferenceEngine>| -> (Vec<Response>, (u64, u64)) {
            let mut s =
                Scheduler::new(engine, SchedulerConfig { max_active: 3, ..Default::default() });
            for id in 0..3u64 {
                let adm = s
                    .admit(
                        QueuedRequest::new(id, SubmitRequest::new(vec![1, 2, 3 + id as u32], 6)),
                        id,
                    )
                    .unwrap();
                assert!(matches!(adm, Admission::Admitted));
            }
            run_all(&mut s);
            let mut done = s.take_finished();
            done.sort_by_key(|r| r.id);
            (done, s.spec_counters())
        };
        let (spec_done, (drafted, accepted)) = run(spec_engine);
        let (van_done, (v_drafted, _)) = run(vanilla);
        assert_eq!(spec_done.len(), 3);
        for (sr, vr) in spec_done.iter().zip(&van_done) {
            assert_eq!(sr.tokens.len(), 6, "exact token count under speculation");
            assert_eq!(sr.tokens, vr.tokens, "greedy stream must match vanilla (id {})", sr.id);
        }
        assert!(drafted > 0, "speculative steps must draft");
        assert!(accepted <= drafted);
        assert_eq!(v_drafted, 0, "vanilla engine never drafts");
    }

    #[test]
    fn prefix_cache_reuses_shared_prompts_without_changing_streams() {
        // three requests sharing an 8-token system prompt: the first
        // registers it, the next two attach it (two hits, 8 positions
        // reused each) — and every greedy stream matches the cold run
        let build = || {
            EngineBuilder::new()
                .random_weights(MICRO, 21)
                .backend("fp32")
                .kv_cache(KvCacheConfig { bits: 32, block_size: 4 })
                .build_arc()
                .unwrap()
        };
        let sys: Vec<u32> = (0..8u32).map(|i| i % 60).collect();
        let run = |prefix_cache: bool| {
            let mut s = Scheduler::new(
                build(),
                SchedulerConfig { max_active: 4, prefix_cache },
            );
            for id in 0..3u64 {
                let mut prompt = sys.clone();
                prompt.push(60 + id as u32);
                let adm = s
                    .admit(
                        QueuedRequest::new(id, SubmitRequest::new(prompt, 4)),
                        id,
                    )
                    .unwrap();
                assert!(matches!(adm, Admission::Admitted));
            }
            run_all(&mut s);
            let mut done = s.take_finished();
            done.sort_by_key(|r| r.id);
            (done, s.prefix_stats())
        };
        let (shared, stats) = run(true);
        let (cold, cold_stats) = run(false);
        assert!(cold_stats.is_none(), "disabled cache must report no stats");
        let stats = stats.expect("prefix cache enabled");
        assert_eq!(stats.hits, 2, "requests 2 and 3 hit the registered prefix");
        assert_eq!(stats.tokens_reused, 16, "8 whole-block positions each");
        assert!(stats.entries >= 1);
        assert_eq!(shared.len(), 3);
        for (sr, cr) in shared.iter().zip(&cold) {
            assert_eq!(sr.tokens, cr.tokens, "sharing must not change stream {}", sr.id);
        }
    }

    #[test]
    fn decode_timing_keeps_the_remainder() {
        // the per-sequence shares of a step's wall time must sum to it
        // exactly — the old `step_us / n` for everyone dropped up to
        // n−1 µs per step
        for (total, n) in [(0u64, 1u64), (7, 1), (7, 3), (9, 3), (100, 7), (5, 8)] {
            let sum: u64 = (0..n as usize).map(|i| decode_share_us(total, n, i)).sum();
            assert_eq!(sum, total, "shares of {total}µs across {n} must sum back");
            // and the split is fair to within 1µs
            let shares: Vec<u64> =
                (0..n as usize).map(|i| decode_share_us(total, n, i)).collect();
            let (mn, mx) = (shares.iter().min().unwrap(), shares.iter().max().unwrap());
            assert!(mx - mn <= 1, "unfair split {shares:?}");
        }
    }
}
