//! Continuous-batching scheduler: prefill-then-decode with KV-aware
//! admission (the serving pattern the paper's engine integrates into).
//! Runs against any [`InferenceEngine`] — native transformer or PJRT
//! artifacts — through the unified engine API.
//!
//! Policy:
//!   * new requests are admitted when a KV slot is free and the decode
//!     batch has room (`max_active`);
//!   * admitted requests are prefilled immediately (prefill priority —
//!     keeps the decode batch full, the same reasoning as Orca/vLLM);
//!   * all active sequences then advance one token per engine step in a
//!     single batched GEMM (M = active batch — exactly the GEMM/GEMV
//!     regime the ABQ engine optimises);
//!   * finished sequences release their KV slot to the pool.
//!
//! Invariants (property-tested): active ≤ max_active; every admitted
//! request completes with exactly `max_new_tokens` tokens (or capacity
//! truncation); KV slots never leak.

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::engine::{EngineSession, InferenceEngine};
use crate::model::Sampler;

use super::request::{QueuedRequest, Response, Timing};

/// One active sequence.
struct Active {
    id: u64,
    prompt_len: usize,
    generated: Vec<u32>,
    max_new: usize,
    session: Box<dyn EngineSession>,
    sampler: Sampler,
    last_token: u32,
    timing: Timing,
    started: Instant,
}

pub struct SchedulerConfig {
    pub max_active: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig { max_active: 8 }
    }
}

/// Synchronous continuous-batching loop around one engine.
pub struct Scheduler {
    engine: Arc<dyn InferenceEngine>,
    cfg: SchedulerConfig,
    active: Vec<Active>,
    finished: Vec<Response>,
}

impl Scheduler {
    pub fn new(engine: Arc<dyn InferenceEngine>, cfg: SchedulerConfig) -> Self {
        Scheduler { engine, cfg, active: Vec::new(), finished: Vec::new() }
    }

    pub fn has_capacity(&self) -> bool {
        self.active.len() < self.cfg.max_active
    }

    pub fn n_active(&self) -> usize {
        self.active.len()
    }

    /// Admit + prefill one request.
    pub fn admit(&mut self, qr: QueuedRequest, seed: u64) -> Result<()> {
        assert!(self.has_capacity(), "admit called without capacity");
        let now = Instant::now();
        let queue_us = now.duration_since(qr.arrived).as_micros() as u64;
        let mut session = self.engine.new_session()?;
        // clamp generation to KV capacity
        let max_seq = self.engine.spec().model.max_seq;
        let max_new = qr
            .req
            .max_new_tokens
            .min(max_seq.saturating_sub(qr.req.prompt.len() + 1));
        let t0 = Instant::now();
        let logits = self.engine.prefill(&qr.req.prompt, session.as_mut())?;
        let prefill_us = t0.elapsed().as_micros() as u64;
        let v = self.engine.spec().model.vocab;
        let last = &logits[(qr.req.prompt.len() - 1) * v..qr.req.prompt.len() * v];
        let mut sampler = Sampler::new(qr.req.sampling, seed);
        let first = sampler.sample(last);
        self.active.push(Active {
            id: qr.req.id,
            prompt_len: qr.req.prompt.len(),
            generated: vec![first],
            max_new,
            session,
            sampler,
            last_token: first,
            timing: Timing { queue_us, prefill_us, decode_us: 0 },
            started: now,
        });
        Ok(())
    }

    /// One batched decode step over all active sequences.
    pub fn step(&mut self) -> Result<()> {
        if self.active.is_empty() {
            return Ok(());
        }
        // retire sequences that already have enough tokens
        self.retire();
        if self.active.is_empty() {
            return Ok(());
        }
        let engine = self.engine.clone();
        let t0 = Instant::now();
        let tokens: Vec<u32> = self.active.iter().map(|a| a.last_token).collect();
        let mut sessions: Vec<&mut dyn EngineSession> =
            self.active.iter_mut().map(|a| a.session.as_mut()).collect();
        let logits = engine.decode_step(&tokens, &mut sessions)?;
        drop(sessions);
        let step_us = t0.elapsed().as_micros() as u64;
        let v = engine.spec().model.vocab;
        let per_seq_us = step_us / self.active.len() as u64;
        for (bi, a) in self.active.iter_mut().enumerate() {
            let row = &logits[bi * v..(bi + 1) * v];
            let tok = a.sampler.sample(row);
            a.generated.push(tok);
            a.last_token = tok;
            a.timing.decode_us += per_seq_us;
        }
        self.retire();
        Ok(())
    }

    fn retire(&mut self) {
        let mut i = 0;
        while i < self.active.len() {
            let done = self.active[i].generated.len() >= self.active[i].max_new
                || self.active[i].session.remaining() <= 1;
            if done {
                let a = self.active.swap_remove(i);
                let _ = a.started;
                self.finished.push(Response {
                    id: a.id,
                    prompt_len: a.prompt_len,
                    tokens: a.generated,
                    timing: a.timing,
                });
            } else {
                i += 1;
            }
        }
    }

    pub fn take_finished(&mut self) -> Vec<Response> {
        std::mem::take(&mut self.finished)
    }

    pub fn idle(&self) -> bool {
        self.active.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Request;
    use crate::engine::EngineBuilder;
    use crate::model::ModelConfig;

    const MICRO: ModelConfig = ModelConfig {
        name: "micro",
        vocab: 64,
        d_model: 16,
        n_layers: 1,
        n_heads: 2,
        d_ff: 32,
        max_seq: 32,
        rope_base: 10000.0,
    };

    fn micro_engine(seed: u64) -> Arc<dyn InferenceEngine> {
        EngineBuilder::new().random_weights(MICRO, seed).backend("fp32").build_arc().unwrap()
    }

    fn run_all(s: &mut Scheduler) {
        for _ in 0..200 {
            if s.idle() {
                break;
            }
            s.step().unwrap();
        }
    }

    #[test]
    fn generates_exact_token_counts() {
        let mut s = Scheduler::new(micro_engine(1), SchedulerConfig { max_active: 4 });
        for id in 0..3u64 {
            s.admit(
                QueuedRequest {
                    req: Request::new(id, vec![1, 2, 3], 5),
                    arrived: Instant::now(),
                },
                id,
            )
            .unwrap();
        }
        run_all(&mut s);
        let mut done = s.take_finished();
        done.sort_by_key(|r| r.id);
        assert_eq!(done.len(), 3);
        for r in &done {
            assert_eq!(r.tokens.len(), 5);
            assert_eq!(r.prompt_len, 3);
        }
    }

    #[test]
    fn respects_kv_capacity() {
        let mut s = Scheduler::new(micro_engine(2), SchedulerConfig::default());
        // prompt 20 + request 100 new > max_seq 32 → truncated
        s.admit(
            QueuedRequest {
                req: Request::new(9, (0..20).map(|i| i as u32 % 64).collect(), 100),
                arrived: Instant::now(),
            },
            0,
        )
        .unwrap();
        run_all(&mut s);
        let done = s.take_finished();
        assert_eq!(done.len(), 1);
        assert!(done[0].tokens.len() <= 32 - 20);
        assert!(!done[0].tokens.is_empty());
    }

    #[test]
    fn capacity_bound() {
        let mut s = Scheduler::new(micro_engine(3), SchedulerConfig { max_active: 2 });
        for id in 0..2u64 {
            s.admit(
                QueuedRequest {
                    req: Request::new(id, vec![1], 3),
                    arrived: Instant::now(),
                },
                id,
            )
            .unwrap();
        }
        assert!(!s.has_capacity());
    }
}
