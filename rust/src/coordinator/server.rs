//! The serving front end: dispatcher thread (router) + one worker thread
//! per engine replica (batcher + continuous-batching scheduler). Rust owns
//! the whole event loop; python never appears on this path.
//!
//! ```text
//! client ──submit()──► dispatcher ──route──► worker[replica]
//!                                             ├─ Batcher (size/deadline)
//!                                             ├─ Scheduler (prefill+decode)
//!                                             └─ responses ──► client rx
//! ```

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::engine::InferenceEngine;
use crate::prefix::SessionStore;

use super::batcher::{Batcher, BatcherConfig};
use super::metrics::Metrics;
use super::request::{QueuedRequest, Request, Response};
use super::router::Router;
use super::scheduler::{Admission, Scheduler, SchedulerConfig};

enum WorkerMsg {
    Req(QueuedRequest, Sender<Response>),
    Shutdown,
}

enum FrontMsg {
    Req(Request, Sender<Response>),
    Shutdown,
}

pub struct ServerConfig {
    pub batcher: BatcherConfig,
    pub max_active: usize,
    pub default_tag: String,
    /// Enable the per-worker prefix cache (`--prefix-cache`); inert on
    /// engines without prefix support.
    pub prefix_cache: bool,
    /// Directory for persistent `.abqs` session files
    /// (`--session-dir`); each worker uses a per-tag subdirectory so
    /// replicas with different configs never collide. Implies nothing
    /// unless `prefix_cache` is on.
    pub session_dir: Option<PathBuf>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            batcher: BatcherConfig::default(),
            max_active: 8,
            default_tag: "fp16".to_string(),
            prefix_cache: false,
            session_dir: None,
        }
    }
}

/// Per-worker slice of [`ServerConfig`] (bundled so the worker entry
/// point keeps a short signature).
struct WorkerOpts {
    bcfg: BatcherConfig,
    max_active: usize,
    prefix_cache: bool,
    session_dir: Option<PathBuf>,
}

/// A running server over one or more engine replicas.
pub struct Server {
    front_tx: Sender<FrontMsg>,
    handles: Vec<JoinHandle<()>>,
    next_id: AtomicU64,
    pub metrics: Arc<Metrics>,
}

impl Server {
    /// Start with `(tag, engine)` replicas — any [`InferenceEngine`]
    /// (native or PJRT), built through `engine::EngineBuilder`.
    pub fn start(
        replicas: Vec<(String, Arc<dyn InferenceEngine>)>,
        cfg: ServerConfig,
    ) -> Result<Self> {
        assert!(!replicas.is_empty());
        let metrics = Arc::new(Metrics::new());
        let mut router = Router::new(&cfg.default_tag);
        let mut worker_txs = Vec::new();
        let mut handles = Vec::new();

        for (idx, (tag, model)) in replicas.into_iter().enumerate() {
            router.register(&tag, idx);
            let (tx, rx) = channel::<WorkerMsg>();
            worker_txs.push(tx);
            let m = metrics.clone();
            let opts = WorkerOpts {
                bcfg: cfg.batcher,
                max_active: cfg.max_active,
                prefix_cache: cfg.prefix_cache,
                session_dir: cfg.session_dir.clone(),
            };
            let tag_owned = tag.clone();
            handles.push(std::thread::spawn(move || {
                worker_loop(model, rx, opts, m, &tag_owned);
            }));
        }

        // dispatcher
        let (front_tx, front_rx) = channel::<FrontMsg>();
        let m2 = metrics.clone();
        handles.push(std::thread::spawn(move || {
            dispatcher_loop(front_rx, router, worker_txs, m2);
        }));

        Ok(Server { front_tx, handles, next_id: AtomicU64::new(1), metrics })
    }

    /// Submit a request; returns a receiver for its response.
    pub fn submit(&self, mut req: Request) -> Receiver<Response> {
        if req.id == 0 {
            req.id = self.next_id.fetch_add(1, Ordering::Relaxed);
        }
        let (tx, rx) = channel();
        let _ = self.front_tx.send(FrontMsg::Req(req, tx));
        rx
    }

    /// Stop all threads (in-flight requests are dropped).
    pub fn shutdown(self) {
        let _ = self.front_tx.send(FrontMsg::Shutdown);
        for h in self.handles {
            let _ = h.join();
        }
    }
}

fn dispatcher_loop(
    rx: Receiver<FrontMsg>,
    mut router: Router,
    worker_txs: Vec<Sender<WorkerMsg>>,
    metrics: Arc<Metrics>,
) {
    while let Ok(msg) = rx.recv() {
        match msg {
            FrontMsg::Req(req, resp_tx) => {
                metrics.incr("router.requests", 1);
                match router.route(&req.config) {
                    Ok(idx) => {
                        let qr = QueuedRequest { req, arrived: Instant::now() };
                        let _ = worker_txs[idx].send(WorkerMsg::Req(qr, resp_tx));
                    }
                    Err(_) => {
                        metrics.incr("router.unroutable", 1);
                        // drop resp_tx: client sees a disconnected channel
                    }
                }
            }
            FrontMsg::Shutdown => break,
        }
    }
    for tx in worker_txs {
        let _ = tx.send(WorkerMsg::Shutdown);
    }
}

fn worker_loop(
    model: Arc<dyn InferenceEngine>,
    rx: Receiver<WorkerMsg>,
    opts: WorkerOpts,
    metrics: Arc<Metrics>,
    tag: &str,
) {
    let max_active = opts.max_active;
    let mut batcher = Batcher::new(opts.bcfg);
    // the worker keeps its own handle for pool-occupancy gauges (3b)
    let mut scheduler = Scheduler::new(
        model.clone(),
        SchedulerConfig { max_active, prefix_cache: opts.prefix_cache },
    );
    // warm the prefix index from persisted session files (per-tag
    // subdirectory: replicas with different configs never collide)
    if let Some(dir) = &opts.session_dir {
        match SessionStore::new(dir.join(tag)) {
            Ok(store) => {
                let restored = scheduler.attach_session_store(store);
                if restored > 0 {
                    println!("[{tag}] prefix cache warmed from {restored} session file(s)");
                }
            }
            Err(e) => eprintln!("[{tag}] session dir unavailable: {e:#}"),
        }
    }
    let mut pending: HashMap<u64, Sender<Response>> = HashMap::new();
    let mut seed = 0xC0FFEEu64;
    let mut shutdown = false;

    loop {
        // 1. pull new work (block briefly only when fully idle)
        loop {
            let msg = if scheduler.idle() && batcher.is_empty() {
                match rx.recv_timeout(Duration::from_millis(20)) {
                    Ok(m) => m,
                    Err(std::sync::mpsc::RecvTimeoutError::Timeout) => break,
                    Err(_) => {
                        shutdown = true;
                        break;
                    }
                }
            } else {
                match rx.try_recv() {
                    Ok(m) => m,
                    Err(std::sync::mpsc::TryRecvError::Empty) => break,
                    Err(_) => {
                        shutdown = true;
                        break;
                    }
                }
            };
            match msg {
                WorkerMsg::Req(qr, resp_tx) => {
                    pending.insert(qr.req.id, resp_tx);
                    batcher.push(qr);
                    metrics.incr(&format!("worker.{tag}.queued"), 1);
                }
                WorkerMsg::Shutdown => {
                    shutdown = true;
                    break;
                }
            }
        }
        if shutdown && scheduler.idle() && batcher.is_empty() {
            break;
        }

        // 2. admit when the batcher says ready (or we're draining);
        // requests deferred by block-aware admission go back to the head
        // of the queue and we stop admitting until blocks free up
        let now = Instant::now();
        if (batcher.ready(now) || shutdown) && scheduler.has_capacity() {
            let room = max_active - scheduler.n_active();
            let mut drained = batcher.drain(room);
            let mut deferred: Vec<_> = Vec::new();
            let mut drained_iter = drained.drain(..);
            for qr in drained_iter.by_ref() {
                seed = seed.wrapping_add(1);
                let qid = qr.req.id;
                let t0 = Instant::now();
                match scheduler.admit(qr, seed) {
                    Ok(Admission::Admitted) => {
                        metrics.observe_us(
                            &format!("worker.{tag}.prefill_us"),
                            t0.elapsed().as_micros() as u64,
                        );
                    }
                    Ok(Admission::Deferred(qr)) => {
                        metrics.incr(&format!("worker.{tag}.admit_deferred"), 1);
                        deferred.push(qr);
                        break;
                    }
                    Err(e) => {
                        // unadmittable (e.g. prompt larger than the whole
                        // pool): drop its channel so the client sees a
                        // disconnect instead of hanging
                        metrics.incr(&format!("worker.{tag}.admit_errors"), 1);
                        pending.remove(&qid);
                        eprintln!("admit error: {e}");
                    }
                }
            }
            deferred.extend(drained_iter);
            for qr in deferred.into_iter().rev() {
                batcher.requeue_front(qr);
            }
        }

        // 3. advance all active sequences one token
        if !scheduler.idle() {
            let t0 = Instant::now();
            if let Err(e) = scheduler.step() {
                eprintln!("step error: {e}");
            }
            metrics.observe_us(
                &format!("worker.{tag}.step_us"),
                t0.elapsed().as_micros() as u64,
            );
        }

        // 3b. export KV pool occupancy + preemption state
        if let Some(st) = model.kv_pool_status() {
            metrics.set_gauge(&format!("worker.{tag}.kv_blocks_used"), st.used_blocks() as u64);
            metrics.set_gauge(&format!("worker.{tag}.kv_blocks_total"), st.total_blocks as u64);
            // extra handles onto leased blocks (prefix/fork sharing) —
            // each physical block is billed once in kv_blocks_used
            metrics.set_gauge(&format!("worker.{tag}.kv_blocks_shared"), st.shared_refs as u64);
            metrics.set_gauge(
                &format!("worker.{tag}.kv_preempted_waiting"),
                scheduler.n_preempted() as u64,
            );
            metrics.set_gauge(&format!("worker.{tag}.preemptions"), scheduler.preemption_count());
        }
        // 3c. speculative-decoding acceptance gauges
        if model.spec_config().is_some() {
            let (drafted, accepted) = scheduler.spec_counters();
            metrics.set_gauge(&format!("worker.{tag}.spec_drafted"), drafted);
            metrics.set_gauge(&format!("worker.{tag}.spec_accepted"), accepted);
            metrics.set_gauge(
                &format!("worker.{tag}.spec_accept_rate_pct"),
                if drafted > 0 { accepted * 100 / drafted } else { 0 },
            );
            if let Some(dp) = model.spec_draft_pool_status() {
                metrics.set_gauge(
                    &format!("worker.{tag}.spec_draft_blocks_used"),
                    dp.used_blocks() as u64,
                );
            }
        }

        // 3d. prefix-cache gauges (present only when the cache is live)
        if let Some(ps) = scheduler.prefix_stats() {
            metrics.set_gauge(&format!("worker.{tag}.prefix_hits"), ps.hits);
            metrics.set_gauge(&format!("worker.{tag}.prefix_tokens_reused"), ps.tokens_reused);
            metrics.set_gauge(&format!("worker.{tag}.prefix_entries"), ps.entries as u64);
            metrics.set_gauge(&format!("worker.{tag}.prefix_evictions"), ps.evictions);
        }

        // 4. deliver finished responses
        for resp in scheduler.take_finished() {
            metrics.incr(&format!("worker.{tag}.completed"), 1);
            metrics.observe_us(
                &format!("worker.{tag}.e2e_us"),
                resp.timing.total_us(),
            );
            if let Some(tx) = pending.remove(&resp.id) {
                let _ = tx.send(resp);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::EngineBuilder;
    use crate::model::ModelConfig;

    const MICRO: ModelConfig = ModelConfig {
        name: "micro",
        vocab: 64,
        d_model: 16,
        n_layers: 1,
        n_heads: 2,
        d_ff: 32,
        max_seq: 32,
        rope_base: 10000.0,
    };

    fn micro_engine(seed: u64) -> Arc<dyn InferenceEngine> {
        EngineBuilder::new().random_weights(MICRO, seed).backend("fp32").build_arc().unwrap()
    }

    #[test]
    fn end_to_end_serving() {
        let server = Server::start(
            vec![("fp16".to_string(), micro_engine(5))],
            ServerConfig::default(),
        )
        .unwrap();
        let mut rxs = Vec::new();
        for i in 0..6 {
            let mut req = Request::new(0, vec![1, 2, (i % 30) as u32], 4);
            req.config = "fp16".to_string();
            rxs.push(server.submit(req));
        }
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(30)).expect("response");
            assert_eq!(resp.tokens.len(), 4);
        }
        assert_eq!(server.metrics.counter("worker.fp16.completed"), 6);
        // the native engine has a KV pool, so occupancy gauges must exist
        assert!(server.metrics.gauge("worker.fp16.kv_blocks_total") > 0);
        assert_eq!(server.metrics.gauge("worker.fp16.kv_blocks_used"), 0);
        server.shutdown();
    }

    #[test]
    fn speculative_replica_serves_and_exports_acceptance_gauges() {
        let engine = EngineBuilder::new()
            .random_weights(MICRO, 9)
            .backend("fp32")
            .speculative("w2*a8:2".parse().unwrap())
            .build_arc()
            .unwrap();
        let server = Server::start(
            vec![("fp16".to_string(), engine)],
            ServerConfig::default(),
        )
        .unwrap();
        let mut rxs = Vec::new();
        for i in 0..4 {
            let mut req = Request::new(0, vec![1, 2, (i % 30) as u32], 5);
            req.config = "fp16".to_string();
            rxs.push(server.submit(req));
        }
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(30)).expect("response");
            assert_eq!(resp.tokens.len(), 5);
        }
        assert_eq!(server.metrics.counter("worker.fp16.completed"), 4);
        assert!(server.metrics.gauge("worker.fp16.spec_drafted") > 0);
        assert!(
            server.metrics.gauge("worker.fp16.spec_accepted")
                <= server.metrics.gauge("worker.fp16.spec_drafted")
        );
        server.shutdown();
    }

    #[test]
    fn prefix_cache_serves_shared_system_prompts_and_exports_gauges() {
        // one system prompt shared by every request: after the first
        // prefill the rest attach its blocks, so the hit/reuse gauges
        // move and the shared-refs gauge is exported alongside occupancy
        let server = Server::start(
            vec![("fp16".to_string(), micro_engine(13))],
            ServerConfig { prefix_cache: true, ..Default::default() },
        )
        .unwrap();
        // one whole block at the default 16-position block size
        let sys: Vec<u32> = (0..16u32).map(|i| i % 60).collect();
        let mut rxs = Vec::new();
        for i in 0..5u32 {
            let mut prompt = sys.clone();
            prompt.push(60 + (i % 3));
            let mut req = Request::new(0, prompt, 4);
            req.config = "fp16".to_string();
            rxs.push(server.submit(req));
        }
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(30)).expect("response");
            assert_eq!(resp.tokens.len(), 4);
        }
        assert_eq!(server.metrics.counter("worker.fp16.completed"), 5);
        assert!(
            server.metrics.gauge("worker.fp16.prefix_hits") >= 4,
            "every request after the first shares the system prompt"
        );
        assert!(server.metrics.gauge("worker.fp16.prefix_tokens_reused") >= 4 * 16);
        assert!(server.metrics.gauge("worker.fp16.prefix_entries") >= 1);
        server.shutdown();
    }

    #[test]
    fn unroutable_config_drops_channel() {
        let server = Server::start(
            vec![("fp16".to_string(), micro_engine(5))],
            ServerConfig::default(),
        )
        .unwrap();
        let mut req = Request::new(0, vec![1], 2);
        req.config = "w99a99".to_string();
        let rx = server.submit(req);
        assert!(rx.recv_timeout(Duration::from_secs(5)).is_err());
        server.shutdown();
    }
}
