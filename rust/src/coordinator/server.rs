//! The serving frontend: one [`Frontend`] owning N worker replicas, each
//! a thread running its own batcher + continuous-batching scheduler over
//! its own engine (replicas built by `EngineBuilder::build_replicas`
//! share one weight mapping — `docs/SERVING.md` §multi-replica). Routing
//! happens synchronously inside [`Frontend::submit`] — there is no
//! dispatcher thread to hop through on the submit path.
//!
//! ```text
//! client ──submit()──► Frontend ──route──► worker[replica i]
//!                        │                   ├─ Batcher (size/deadline)
//!                        │                   ├─ Scheduler (prefill+decode)
//!                        │                   └─ responses ──► Ticket rx
//!                        ├─ Router: tag → sticky → load score
//!                        └─ Autopilot: SLO watch → rung shifts
//! ```
//!
//! When a replica retires (or is declared dead), [`Frontend::retire`]
//! drains its queued *and* in-flight work and re-homes everything to the
//! surviving replicas of the same tag: in-flight sequences ride the
//! scheduler's preempt-and-replay machinery ([`InFlight`]), so their
//! streams continue bit-identically on the adoptive replica.
//!
//! ## Ordering invariant (the ISSUE-9 race fix)
//!
//! `submit` sends its `Req` **while holding the router lock**, and
//! `retire`/`shift_to` mark a replica dead / retarget the default tag
//! and send their `Retire`/`Drain` **under the same lock**. mpsc
//! channels are FIFO, so any `Req` whose send succeeded is ordered ahead
//! of the `Retire`/`Drain` in the worker's queue and is therefore
//! drained and re-homed — never silently swallowed. (Previously the
//! send happened after the lock was released: a replica retiring in that
//! window accepted the message into a channel nobody would ever drain.)
//! A send that fails because the worker already exited bounces: the
//! message comes back in the `SendError`, the replica is marked dead,
//! and the request re-routes to a survivor.
//!
//! ## Adaptive precision ([`Frontend::start_adaptive`])
//!
//! One worker per ladder rung (`precision::Ladder`), each registered
//! under its rung name; default traffic follows the router's default
//! tag, which the autopilot retargets as it walks the ladder. A shift
//! drains the old rung's queued + in-flight work and injects it into the
//! new rung — the same drain/inject path as retirement, so continuations
//! are bit-identical under greedy decoding. See
//! `docs/SERVING.md` §adaptive precision.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, SendError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::engine::InferenceEngine;
use crate::precision::OperatingPoint;
use crate::prefix::SessionStore;
use crate::util::par;

use super::autopilot::{decide, Autopilot, AutopilotConfig, ShiftDecision};
use super::batcher::{Batcher, BatcherConfig};
use super::metrics::Metrics;
use super::request::{
    sampling_seed, Admission, QueuedRequest, Response, SubmitRequest, Ticket,
};
use super::router::{ReplicaId, ReplicaState, RequestMeta, Router};
use super::scheduler::{InFlight, Scheduler, SchedulerConfig};

enum WorkerMsg {
    Req(QueuedRequest, Sender<Response>),
    /// a sequence drained from another replica, adopted here
    Resume(InFlight, Sender<Response>),
    /// detach all queued + in-flight work, hand it back, then exit
    Retire(Sender<Drained>),
    /// detach all queued + in-flight work, hand it back, keep running —
    /// the autopilot's migration primitive (the rung stays warm as an
    /// upshift target)
    Drain(Sender<Drained>),
    Shutdown,
}

/// Everything a draining worker hands back for re-homing.
struct Drained {
    queued: Vec<(QueuedRequest, Sender<Response>)>,
    inflight: Vec<(InFlight, Sender<Response>)>,
}

/// Load signal a worker publishes after every loop iteration; the
/// frontend reads it (lock-free) to refresh the router before each
/// placement.
struct ReplicaStatus {
    /// free KV blocks (`u64::MAX` = no pool — unconstrained)
    free_blocks: AtomicU64,
    /// queued + active + preempted on the replica
    queue_depth: AtomicU64,
    alive: AtomicBool,
}

pub struct FrontendConfig {
    pub batcher: BatcherConfig,
    pub max_active: usize,
    pub default_tag: String,
    /// Enable the per-worker prefix cache (`--prefix-cache`); inert on
    /// engines without prefix support.
    pub prefix_cache: bool,
    /// Directory for persistent `.abqs` session files
    /// (`--session-dir`); each worker uses a per-tag subdirectory so
    /// replicas with different configs never collide. Implies nothing
    /// unless `prefix_cache` is on.
    pub session_dir: Option<PathBuf>,
    /// Give each worker its own dedicated compute pool of this many
    /// threads (`util::par::dedicated_pool`) so replicas never contend
    /// for the global pool's dispatch lock. `None` = all replicas share
    /// the process-global pool.
    pub pool_threads: Option<usize>,
}

impl Default for FrontendConfig {
    fn default() -> Self {
        FrontendConfig {
            batcher: BatcherConfig::default(),
            max_active: 8,
            default_tag: "fp16".to_string(),
            prefix_cache: false,
            session_dir: None,
            pool_threads: None,
        }
    }
}

/// Back-compat aliases from the single-dispatcher era: the old `Server`
/// *is* a one-replica `Frontend`.
pub type Server = Frontend;
pub type ServerConfig = FrontendConfig;

/// Per-worker slice of [`FrontendConfig`] (bundled so the worker entry
/// point keeps a short signature).
struct WorkerOpts {
    bcfg: BatcherConfig,
    max_active: usize,
    prefix_cache: bool,
    session_dir: Option<PathBuf>,
    pool_threads: Option<usize>,
}

struct Worker {
    tx: Sender<WorkerMsg>,
    status: Arc<ReplicaStatus>,
}

/// State shared between the frontend handle, the worker threads'
/// senders, and the autopilot pilot thread.
struct Shared {
    router: Mutex<Router>,
    workers: Vec<Worker>,
    next_id: AtomicU64,
    metrics: Arc<Metrics>,
    /// present only on adaptive frontends ([`Frontend::start_adaptive`])
    autopilot: Option<Mutex<Autopilot>>,
    /// replica tag per worker index (= rung names on adaptive frontends)
    tags: Vec<String>,
}

/// A running frontend over one or more engine replicas.
pub struct Frontend {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    pilot: Option<JoinHandle<()>>,
    pilot_stop: Arc<AtomicBool>,
    pub metrics: Arc<Metrics>,
}

impl Frontend {
    /// Start with `(tag, engine)` replicas — any [`InferenceEngine`]
    /// (native or PJRT), built through `engine::EngineBuilder`. Replica
    /// ids are positions in this vec.
    pub fn start(
        replicas: Vec<(String, Arc<dyn InferenceEngine>)>,
        cfg: FrontendConfig,
    ) -> Result<Self> {
        Self::start_inner(replicas, cfg, None)
    }

    /// Start an adaptive frontend: one worker per precision-ladder rung
    /// (most precise first — rung 0 is where traffic starts), with the
    /// autopilot watching `server.ttft_us` p95 and the active rung's KV
    /// occupancy against `pilot`'s SLOs. With `pilot.poll_ms == 0` no
    /// pilot thread runs; call [`Frontend::autopilot_tick`] manually
    /// (tests, benches).
    pub fn start_adaptive(
        rungs: Vec<(OperatingPoint, Arc<dyn InferenceEngine>)>,
        mut cfg: FrontendConfig,
        pilot: AutopilotConfig,
    ) -> Result<Self> {
        if rungs.is_empty() {
            bail!("start_adaptive needs at least one ladder rung");
        }
        // default traffic starts on the most precise rung
        cfg.default_tag = rungs[0].0.name.clone();
        let replicas: Vec<(String, Arc<dyn InferenceEngine>)> =
            rungs.into_iter().map(|(op, engine)| (op.name, engine)).collect();
        let mut fe = Self::start_inner(replicas, cfg, Some(pilot))?;
        fe.metrics.set_gauge("server.precision_rung", 0);
        if pilot.poll_ms > 0 {
            let shared = fe.shared.clone();
            let stop = fe.pilot_stop.clone();
            let period = Duration::from_millis(pilot.poll_ms);
            let handle = std::thread::Builder::new()
                .name("abq-autopilot".to_string())
                .spawn(move || {
                    while !stop.load(Ordering::Relaxed) {
                        std::thread::sleep(period);
                        if stop.load(Ordering::Relaxed) {
                            break;
                        }
                        shared.autopilot_tick();
                    }
                })
                .context("spawning autopilot thread")?;
            fe.pilot = Some(handle);
        }
        Ok(fe)
    }

    fn start_inner(
        replicas: Vec<(String, Arc<dyn InferenceEngine>)>,
        cfg: FrontendConfig,
        pilot: Option<AutopilotConfig>,
    ) -> Result<Self> {
        if replicas.is_empty() {
            bail!("Frontend::start needs at least one replica");
        }
        let metrics = Arc::new(Metrics::new());
        let mut router = Router::new(&cfg.default_tag);
        let mut workers = Vec::new();
        let mut handles = Vec::new();
        let mut tags = Vec::new();
        for (idx, (tag, model)) in replicas.into_iter().enumerate() {
            router.register(&tag);
            let (tx, rx) = channel::<WorkerMsg>();
            let status = Arc::new(ReplicaStatus {
                free_blocks: AtomicU64::new(u64::MAX),
                queue_depth: AtomicU64::new(0),
                alive: AtomicBool::new(true),
            });
            let m = metrics.clone();
            let st = status.clone();
            let opts = WorkerOpts {
                bcfg: cfg.batcher,
                max_active: cfg.max_active,
                prefix_cache: cfg.prefix_cache,
                session_dir: cfg.session_dir.clone(),
                pool_threads: cfg.pool_threads,
            };
            let tag_owned = tag.clone();
            let handle = std::thread::Builder::new()
                .name(format!("abq-replica{idx}"))
                .spawn(move || worker_loop(idx, model, rx, opts, m, st, &tag_owned))
                .context("spawning replica worker")?;
            workers.push(Worker { tx, status });
            handles.push(handle);
            tags.push(tag);
        }
        let shared = Arc::new(Shared {
            router: Mutex::new(router),
            workers,
            next_id: AtomicU64::new(1),
            metrics: metrics.clone(),
            autopilot: pilot.map(|c| Mutex::new(Autopilot::new(c))),
            tags,
        });
        Ok(Frontend {
            shared,
            handles,
            pilot: None,
            pilot_stop: Arc::new(AtomicBool::new(false)),
            metrics,
        })
    }

    pub fn replica_count(&self) -> usize {
        self.shared.workers.len()
    }

    /// Stamp, route and enqueue one request. Fails when no live replica
    /// serves the requested tag — the client gets the error immediately
    /// instead of a dangling channel.
    pub fn submit(&self, req: SubmitRequest) -> Result<Ticket> {
        self.shared.submit(req)
    }

    /// Where would this request land right now? Same three-tier decision
    /// as [`Frontend::submit`] (including recording the affinity
    /// placement), without enqueuing anything.
    pub fn route_preview(&self, req: &SubmitRequest) -> Result<Admission> {
        self.shared.route_preview(req)
    }

    /// Retire one replica: stop routing to it, drain its queued and
    /// in-flight work, and re-home everything to surviving replicas of
    /// the same tag (sticky fingerprints are re-pinned to the adoptive
    /// replica). Returns how many requests were re-homed. Requests whose
    /// tag no survivor serves get their channels dropped — the client
    /// sees a disconnect, never a silent precision switch.
    pub fn retire(&self, id: ReplicaId) -> Result<usize> {
        self.shared.retire(id)
    }

    /// Evaluate the autopilot policy once (the pilot thread calls this
    /// every `poll_ms`; with `poll_ms == 0` the embedder drives ticks).
    /// Returns the decision taken; `Hold` on non-adaptive frontends.
    pub fn autopilot_tick(&self) -> ShiftDecision {
        self.shared.autopilot_tick()
    }

    /// Force one rung shift (down = cheaper), bypassing the policy but
    /// using the exact same drain/inject migration — the test hook for
    /// mid-stream continuation checks. Errors off the ladder edge or on
    /// a non-adaptive frontend.
    pub fn force_shift(&self, down: bool) -> Result<usize> {
        self.shared.force_shift(down)
    }

    /// Active rung index (0 = most precise); `None` when not adaptive.
    pub fn active_rung(&self) -> Option<usize> {
        self.shared.autopilot.as_ref().map(|ap| ap.lock().unwrap().active)
    }

    /// Stop all workers after they finish their queued work.
    pub fn shutdown(mut self) {
        self.pilot_stop.store(true, Ordering::Relaxed);
        if let Some(p) = self.pilot.take() {
            let _ = p.join();
        }
        for w in &self.shared.workers {
            let _ = w.tx.send(WorkerMsg::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Shared {
    /// Refresh the router's view from the workers' published load.
    fn refresh(&self, router: &mut Router) {
        for (i, w) in self.workers.iter().enumerate() {
            let free = w.status.free_blocks.load(Ordering::Relaxed);
            router.update(
                ReplicaId(i),
                ReplicaState {
                    free_blocks: if free == u64::MAX { usize::MAX } else { free as usize },
                    queue_depth: w.status.queue_depth.load(Ordering::Relaxed) as usize,
                    alive: w.status.alive.load(Ordering::Relaxed),
                },
            );
        }
    }

    fn meta(req: &SubmitRequest) -> RequestMeta<'_> {
        RequestMeta {
            config_tag: &req.config_tag,
            session_affinity: req.session_affinity,
            prompt_len: req.prompt.len(),
        }
    }

    fn submit(&self, req: SubmitRequest) -> Result<Ticket> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.metrics.incr("server.requests", 1);
        let (resp_tx, rx) = channel();
        let mut qr = QueuedRequest::new(id, req);
        let mut resp_tx = resp_tx;
        // bounded retry: each failed send marks one replica dead, so
        // after workers.len() bounces nothing can be left to try
        for _ in 0..=self.workers.len() {
            let mut router = self.router.lock().unwrap();
            self.refresh(&mut router);
            let replica = match router.route(&Self::meta(&qr.req)) {
                Ok(r) => r,
                Err(e) => {
                    self.metrics.incr("server.unroutable", 1);
                    return Err(e);
                }
            };
            // send while still holding the router lock: retire()/
            // shift_to() mark replicas dead and send Retire/Drain under
            // this same lock, so a send that succeeds here is ordered
            // ahead of any Retire/Drain in the channel FIFO — the worker
            // either serves the request or hands it back in its drain,
            // never drops it on the floor
            match self.workers[replica.0].tx.send(WorkerMsg::Req(qr, resp_tx)) {
                Ok(()) => {
                    self.workers[replica.0].status.queue_depth.fetch_add(1, Ordering::Relaxed);
                    return Ok(Ticket { id, replica, rx });
                }
                Err(SendError(WorkerMsg::Req(q, tx))) => {
                    // the worker exited after its last status publish:
                    // the send bounced the message back — mark the
                    // replica dead and re-route to a survivor
                    self.metrics.incr("server.submit_bounced", 1);
                    self.workers[replica.0].status.alive.store(false, Ordering::Relaxed);
                    router.mark_dead(replica);
                    qr = q;
                    resp_tx = tx;
                }
                Err(_) => unreachable!("send returns the message it was given"),
            }
        }
        self.metrics.incr("server.unroutable", 1);
        bail!("no live replica accepted the request")
    }

    fn route_preview(&self, req: &SubmitRequest) -> Result<Admission> {
        let mut router = self.router.lock().unwrap();
        self.refresh(&mut router);
        Ok(Admission::Routed(router.route(&Self::meta(req))?))
    }

    fn retire(&self, id: ReplicaId) -> Result<usize> {
        let w = self.workers.get(id.0).with_context(|| format!("unknown {id}"))?;
        let (tx, rx) = channel();
        let sent = {
            // mark dead AND send Retire under the router lock — the
            // submit-side of the ordering invariant (module docs)
            let mut router = self.router.lock().unwrap();
            w.status.alive.store(false, Ordering::Relaxed);
            router.mark_dead(id);
            w.tx.send(WorkerMsg::Retire(tx)).is_ok()
        };
        if !sent {
            return Ok(0); // worker already gone; nothing to drain
        }
        let drained = rx.recv().context("retiring replica returned no drain")?;
        self.metrics.incr("server.replica_retired", 1);
        let mut moved = 0usize;
        let mut router = self.router.lock().unwrap();
        self.refresh(&mut router);
        for (qr, resp_tx) in drained.queued {
            match router.route(&Self::meta(&qr.req)) {
                Ok(to) => {
                    if self.workers[to.0].tx.send(WorkerMsg::Req(qr, resp_tx)).is_ok() {
                        self.workers[to.0].status.queue_depth.fetch_add(1, Ordering::Relaxed);
                        moved += 1;
                    }
                }
                Err(_) => self.metrics.incr("server.unroutable", 1),
            }
        }
        for (f, resp_tx) in drained.inflight {
            match router.route(&Self::meta(&f.req)) {
                Ok(to) => {
                    if let Some(fp) = f.req.session_affinity {
                        router.repin(fp, to);
                    }
                    if self.workers[to.0].tx.send(WorkerMsg::Resume(f, resp_tx)).is_ok() {
                        self.workers[to.0].status.queue_depth.fetch_add(1, Ordering::Relaxed);
                        moved += 1;
                    }
                }
                Err(_) => self.metrics.incr("server.unroutable", 1),
            }
        }
        Ok(moved)
    }

    /// One autopilot evaluation: window the TTFT histogram, read the
    /// active rung's pool occupancy, run the policy, migrate on a shift.
    fn autopilot_tick(&self) -> ShiftDecision {
        let Some(ap_mutex) = &self.autopilot else { return ShiftDecision::Hold };
        let (decision, from, to) = {
            let mut ap = ap_mutex.lock().unwrap();
            ap.ticks_since_shift = ap.ticks_since_shift.saturating_add(1);
            let dwell_ok = ap.ticks_since_shift > ap.cfg.min_dwell_ticks;
            // p95 over *this window's* completions: cumulative histograms
            // never recover from an overload spike, so upshifts would
            // otherwise be blocked forever
            let snap =
                self.metrics.histogram_snapshot("server.ttft_us").unwrap_or_default();
            let p95 = snap.delta(&ap.prev_ttft).quantile_us(0.95);
            ap.prev_ttft = snap;
            if let Some(p) = p95 {
                self.metrics.set_gauge("server.ttft_p95_window_us", p);
            }
            let active = ap.active;
            let total = self.metrics.gauge(&format!("replica.{active}.kv_blocks_total"));
            let occ = if total == 0 {
                None // no pool gauge published (yet) — no occupancy signal
            } else {
                Some(self.metrics.gauge(&format!("replica.{active}.kv_blocks_used")) * 100 / total)
            };
            let d = decide(
                &ap.cfg,
                p95,
                occ,
                active + 1 == self.workers.len(),
                active == 0,
                dwell_ok,
            );
            match d {
                ShiftDecision::Hold => (d, active, active),
                ShiftDecision::Down => {
                    ap.active = active + 1;
                    ap.ticks_since_shift = 0;
                    (d, active, active + 1)
                }
                ShiftDecision::Up => {
                    ap.active = active - 1;
                    ap.ticks_since_shift = 0;
                    (d, active, active - 1)
                }
            }
        };
        match decision {
            ShiftDecision::Hold => {}
            ShiftDecision::Down => {
                self.metrics.incr("server.downshifts", 1);
                self.metrics.set_gauge("server.precision_rung", to as u64);
                self.shift_to(ReplicaId(from), ReplicaId(to));
            }
            ShiftDecision::Up => {
                self.metrics.incr("server.upshifts", 1);
                self.metrics.set_gauge("server.precision_rung", to as u64);
                self.shift_to(ReplicaId(from), ReplicaId(to));
            }
        }
        decision
    }

    fn force_shift(&self, down: bool) -> Result<usize> {
        let Some(ap_mutex) = &self.autopilot else {
            bail!("force_shift on a non-adaptive frontend")
        };
        let (from, to) = {
            let mut ap = ap_mutex.lock().unwrap();
            let from = ap.active;
            let to = if down {
                if from + 1 >= self.workers.len() {
                    bail!("already at the cheapest rung");
                }
                from + 1
            } else {
                if from == 0 {
                    bail!("already at the most precise rung");
                }
                from - 1
            };
            ap.active = to;
            ap.ticks_since_shift = 0;
            (from, to)
        };
        self.metrics.incr(if down { "server.downshifts" } else { "server.upshifts" }, 1);
        self.metrics.set_gauge("server.precision_rung", to as u64);
        self.shift_to(ReplicaId(from), ReplicaId(to));
        Ok(to)
    }

    /// Migrate all of `from`'s work onto `to` (adjacent ladder rungs):
    /// retarget the default tag, drain `from`, inject into `to`. Unlike
    /// retirement the source worker keeps running — it stays warm for
    /// the shift back.
    fn shift_to(&self, from: ReplicaId, to: ReplicaId) {
        let (dtx, drx) = channel();
        let sent = {
            // retarget + send Drain under the router lock: every Req
            // routed to the old rung before this point is ahead of the
            // Drain in the FIFO and comes back in the drain set; every
            // submit after it routes to the new default
            let mut router = self.router.lock().unwrap();
            router.set_default_tag(&self.tags[to.0]);
            self.workers[from.0].tx.send(WorkerMsg::Drain(dtx)).is_ok()
        };
        if !sent {
            return; // rung worker dead; nothing to migrate
        }
        let Ok(drained) = drx.recv() else { return };
        let mut moved = 0u64;
        let mut router = self.router.lock().unwrap();
        for (qr, resp_tx) in drained.queued {
            if self.workers[to.0].tx.send(WorkerMsg::Req(qr, resp_tx)).is_ok() {
                self.workers[to.0].status.queue_depth.fetch_add(1, Ordering::Relaxed);
                moved += 1;
            }
        }
        for (f, resp_tx) in drained.inflight {
            if let Some(fp) = f.req.session_affinity {
                router.repin(fp, to);
            }
            if self.workers[to.0].tx.send(WorkerMsg::Resume(f, resp_tx)).is_ok() {
                self.workers[to.0].status.queue_depth.fetch_add(1, Ordering::Relaxed);
                moved += 1;
            }
        }
        self.metrics.incr("server.migrated", moved);
    }
}

fn worker_loop(
    idx: usize,
    model: Arc<dyn InferenceEngine>,
    rx: Receiver<WorkerMsg>,
    opts: WorkerOpts,
    metrics: Arc<Metrics>,
    status: Arc<ReplicaStatus>,
    tag: &str,
) {
    let pfx = format!("replica.{idx}");
    // a dedicated compute pool isolates this replica's GEMM fan-out from
    // the other replicas (and the global pool); torn down on exit so a
    // retired replica leaves no idle threads behind
    let pool = opts.pool_threads.map(|n| par::dedicated_pool(n, &format!("replica{idx}")));
    if let Some(p) = &pool {
        p.bind_current_thread();
    }
    let max_active = opts.max_active;
    let mut batcher = Batcher::new(opts.bcfg);
    // the worker keeps its own handle for pool-occupancy gauges (3b)
    let mut scheduler = Scheduler::new(
        model.clone(),
        SchedulerConfig { max_active, prefix_cache: opts.prefix_cache },
    );
    // warm the prefix index from persisted session files (per-tag
    // subdirectory: replicas with different configs never collide)
    if let Some(dir) = &opts.session_dir {
        match SessionStore::new(dir.join(tag)) {
            Ok(store) => {
                let restored = scheduler.attach_session_store(store);
                if restored > 0 {
                    println!("[{pfx}/{tag}] prefix cache warmed from {restored} session file(s)");
                }
            }
            Err(e) => eprintln!("[{pfx}/{tag}] session dir unavailable: {e:#}"),
        }
    }
    let mut pending: HashMap<u64, Sender<Response>> = HashMap::new();
    let mut shutdown = false;
    let mut retire_reply: Option<Sender<Drained>> = None;
    let mut drain_reply: Option<Sender<Drained>> = None;

    loop {
        // 1. pull new work (block briefly only when fully idle)
        loop {
            let msg = if scheduler.idle() && batcher.is_empty() {
                match rx.recv_timeout(Duration::from_millis(20)) {
                    Ok(m) => m,
                    Err(std::sync::mpsc::RecvTimeoutError::Timeout) => break,
                    Err(_) => {
                        shutdown = true;
                        break;
                    }
                }
            } else {
                match rx.try_recv() {
                    Ok(m) => m,
                    Err(std::sync::mpsc::TryRecvError::Empty) => break,
                    Err(_) => {
                        shutdown = true;
                        break;
                    }
                }
            };
            match msg {
                WorkerMsg::Req(qr, resp_tx) => {
                    pending.insert(qr.id, resp_tx);
                    batcher.push(qr);
                    metrics.incr(&format!("{pfx}.queued"), 1);
                }
                WorkerMsg::Resume(f, resp_tx) => {
                    // a sequence drained from another replica (death,
                    // retirement or a precision shift): joins the resume
                    // queue with first claim on blocks
                    pending.insert(f.id, resp_tx);
                    scheduler.inject(f);
                    metrics.incr(&format!("{pfx}.adopted"), 1);
                }
                WorkerMsg::Retire(reply) => {
                    retire_reply = Some(reply);
                    break;
                }
                WorkerMsg::Drain(reply) => {
                    drain_reply = Some(reply);
                    break;
                }
                WorkerMsg::Shutdown => {
                    shutdown = true;
                    break;
                }
            }
        }

        // precision-shift drain: hand every queued + in-flight request
        // back (with its response channel) but KEEP RUNNING — this rung
        // stays warm as a future shift target; anything already finished
        // is still delivered from here
        if let Some(reply) = drain_reply.take() {
            for resp in scheduler.take_finished() {
                deliver(&metrics, &pfx, &mut pending, resp);
            }
            let mut queued = Vec::new();
            while !batcher.is_empty() {
                for qr in batcher.drain(usize::MAX) {
                    if let Some(tx) = pending.remove(&qr.id) {
                        queued.push((qr, tx));
                    }
                }
            }
            let inflight: Vec<(InFlight, Sender<Response>)> = scheduler
                .drain_inflight()
                .into_iter()
                .filter_map(|f| pending.remove(&f.id).map(|tx| (f, tx)))
                .collect();
            // inject()-completed stragglers surface as finished
            for resp in scheduler.take_finished() {
                deliver(&metrics, &pfx, &mut pending, resp);
            }
            status.queue_depth.store(0, Ordering::Relaxed);
            metrics.incr(&format!("{pfx}.drained"), 1);
            let _ = reply.send(Drained { queued, inflight });
            continue;
        }

        // retirement: like a drain, but the worker exits afterwards —
        // the frontend re-homes the work on surviving replicas
        if let Some(reply) = retire_reply.take() {
            // anything already finished is still delivered from here
            for resp in scheduler.take_finished() {
                deliver(&metrics, &pfx, &mut pending, resp);
            }
            let mut queued = Vec::new();
            while !batcher.is_empty() {
                for qr in batcher.drain(usize::MAX) {
                    if let Some(tx) = pending.remove(&qr.id) {
                        queued.push((qr, tx));
                    }
                }
            }
            let inflight: Vec<(InFlight, Sender<Response>)> = scheduler
                .drain_inflight()
                .into_iter()
                .filter_map(|f| pending.remove(&f.id).map(|tx| (f, tx)))
                .collect();
            // inject()-completed stragglers surface as finished
            for resp in scheduler.take_finished() {
                deliver(&metrics, &pfx, &mut pending, resp);
            }
            status.alive.store(false, Ordering::Relaxed);
            status.queue_depth.store(0, Ordering::Relaxed);
            let _ = reply.send(Drained { queued, inflight });
            break;
        }
        if shutdown && scheduler.idle() && batcher.is_empty() {
            break;
        }

        // 2. admit when the batcher says ready (or we're draining);
        // requests deferred by block-aware admission go back to the head
        // of the queue and we stop admitting until blocks free up
        let now = Instant::now();
        if (batcher.ready(now) || shutdown) && scheduler.has_capacity() {
            let room = max_active - scheduler.n_active();
            let mut drained = batcher.drain(room);
            let mut deferred: Vec<_> = Vec::new();
            let mut drained_iter = drained.drain(..);
            for qr in drained_iter.by_ref() {
                let qid = qr.id;
                let t0 = Instant::now();
                // the seed derives from the id alone, so the stream is
                // independent of admission order and replica assignment
                match scheduler.admit(qr, sampling_seed(qid)) {
                    Ok(Admission::Admitted) => {
                        metrics.observe_us(
                            &format!("{pfx}.prefill_us"),
                            t0.elapsed().as_micros() as u64,
                        );
                    }
                    Ok(Admission::Deferred(qr)) => {
                        metrics.incr(&format!("{pfx}.admit_deferred"), 1);
                        deferred.push(qr);
                        break;
                    }
                    Ok(Admission::Routed(_)) => {
                        unreachable!("schedulers admit or defer; routing happened upstream")
                    }
                    Err(e) => {
                        // unadmittable (e.g. prompt larger than the whole
                        // pool): drop its channel so the client sees a
                        // disconnect instead of hanging
                        metrics.incr(&format!("{pfx}.admit_errors"), 1);
                        pending.remove(&qid);
                        eprintln!("admit error: {e}");
                    }
                }
            }
            deferred.extend(drained_iter);
            for qr in deferred.into_iter().rev() {
                batcher.requeue_front(qr);
            }
        }

        // 3. advance all active sequences one token
        if !scheduler.idle() {
            let t0 = Instant::now();
            if let Err(e) = scheduler.step() {
                eprintln!("step error: {e}");
            }
            metrics.observe_us(&format!("{pfx}.step_us"), t0.elapsed().as_micros() as u64);
        }

        // 3b. export KV pool occupancy + preemption state, and publish
        // the router's load signal
        let free = model.kv_pool_status().map_or(u64::MAX, |st| st.free_blocks as u64);
        status.free_blocks.store(free, Ordering::Relaxed);
        status.queue_depth.store(
            (batcher.len() + scheduler.n_active() + scheduler.n_preempted()) as u64,
            Ordering::Relaxed,
        );
        if let Some(st) = model.kv_pool_status() {
            metrics.set_gauge(&format!("{pfx}.kv_blocks_used"), st.used_blocks() as u64);
            metrics.set_gauge(&format!("{pfx}.kv_blocks_total"), st.total_blocks as u64);
            metrics.set_gauge(&format!("{pfx}.kv_occupancy_pct"), st.occupancy_pct());
            // extra handles onto leased blocks (prefix/fork sharing) —
            // each physical block is billed once in kv_blocks_used
            metrics.set_gauge(&format!("{pfx}.kv_blocks_shared"), st.shared_refs as u64);
            metrics.set_gauge(
                &format!("{pfx}.kv_preempted_waiting"),
                scheduler.n_preempted() as u64,
            );
            metrics.set_gauge(&format!("{pfx}.preemptions"), scheduler.preemption_count());
        }
        // 3c. speculative-decoding acceptance gauges
        if model.spec_config().is_some() {
            let (drafted, accepted) = scheduler.spec_counters();
            metrics.set_gauge(&format!("{pfx}.spec_drafted"), drafted);
            metrics.set_gauge(&format!("{pfx}.spec_accepted"), accepted);
            metrics.set_gauge(
                &format!("{pfx}.spec_accept_rate_pct"),
                if drafted > 0 { accepted * 100 / drafted } else { 0 },
            );
            if let Some(dp) = model.spec_draft_pool_status() {
                metrics.set_gauge(
                    &format!("{pfx}.spec_draft_blocks_used"),
                    dp.used_blocks() as u64,
                );
            }
        }

        // 3d. prefix-cache gauges (present only when the cache is live)
        if let Some(ps) = scheduler.prefix_stats() {
            metrics.set_gauge(&format!("{pfx}.prefix_hits"), ps.hits);
            metrics.set_gauge(&format!("{pfx}.prefix_tokens_reused"), ps.tokens_reused);
            metrics.set_gauge(&format!("{pfx}.prefix_entries"), ps.entries as u64);
            metrics.set_gauge(&format!("{pfx}.prefix_evictions"), ps.evictions);
        }

        // 4. deliver finished responses
        for resp in scheduler.take_finished() {
            deliver(&metrics, &pfx, &mut pending, resp);
        }
    }
    status.alive.store(false, Ordering::Relaxed);
    if let Some(p) = pool {
        par::unbind_current_thread();
        p.shutdown();
    }
}

/// Send one finished response to its client and record the per-replica
/// and fleet-wide ("server.") completion metrics — `server.ttft_us` is
/// the latency-SLO axis of the saturation bench and the autopilot.
fn deliver(
    metrics: &Metrics,
    pfx: &str,
    pending: &mut HashMap<u64, Sender<Response>>,
    resp: Response,
) {
    metrics.incr(&format!("{pfx}.completed"), 1);
    metrics.incr("server.completed", 1);
    metrics.observe_us(&format!("{pfx}.e2e_us"), resp.timing.total_us());
    metrics.observe_us("server.e2e_us", resp.timing.total_us());
    metrics.observe_us("server.ttft_us", resp.timing.ttft_us());
    if let Some(tx) = pending.remove(&resp.id) {
        let _ = tx.send(resp);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::autopilot::AutopilotPolicy;
    use crate::engine::EngineBuilder;
    use crate::model::{KvCacheConfig, ModelConfig};

    const MICRO: ModelConfig = ModelConfig {
        name: "micro",
        vocab: 64,
        d_model: 16,
        n_layers: 1,
        n_heads: 2,
        n_kv_heads: 2,
        d_ff: 32,
        max_seq: 32,
        rope_base: 10000.0,
        arch: crate::model::ArchVariant::LLAMA,
    };

    fn micro_engine(seed: u64) -> Arc<dyn InferenceEngine> {
        EngineBuilder::new().random_weights(MICRO, seed).backend("fp32").build_arc().unwrap()
    }

    #[test]
    fn end_to_end_serving() {
        let server = Frontend::start(
            vec![("fp16".to_string(), micro_engine(5))],
            FrontendConfig::default(),
        )
        .unwrap();
        let mut tickets = Vec::new();
        for i in 0..6 {
            let req = SubmitRequest::new(vec![1, 2, (i % 30) as u32], 4).config("fp16");
            tickets.push(server.submit(req).expect("routable"));
        }
        for t in tickets {
            let resp = t.rx.recv_timeout(Duration::from_secs(30)).expect("response");
            assert_eq!(resp.tokens.len(), 4);
        }
        assert_eq!(server.metrics.counter("replica.0.completed"), 6);
        assert_eq!(server.metrics.counter("server.completed"), 6);
        // the native engine has a KV pool, so occupancy gauges must exist
        assert!(server.metrics.gauge("replica.0.kv_blocks_total") > 0);
        assert_eq!(server.metrics.gauge("replica.0.kv_blocks_used"), 0);
        server.shutdown();
    }

    #[test]
    fn speculative_replica_serves_and_exports_acceptance_gauges() {
        let engine = EngineBuilder::new()
            .random_weights(MICRO, 9)
            .backend("fp32")
            .speculative("w2*a8:2".parse().unwrap())
            .build_arc()
            .unwrap();
        let server = Frontend::start(
            vec![("fp16".to_string(), engine)],
            FrontendConfig::default(),
        )
        .unwrap();
        let mut tickets = Vec::new();
        for i in 0..4 {
            let req = SubmitRequest::new(vec![1, 2, (i % 30) as u32], 5).config("fp16");
            tickets.push(server.submit(req).expect("routable"));
        }
        for t in tickets {
            let resp = t.rx.recv_timeout(Duration::from_secs(30)).expect("response");
            assert_eq!(resp.tokens.len(), 5);
        }
        assert_eq!(server.metrics.counter("replica.0.completed"), 4);
        assert!(server.metrics.gauge("replica.0.spec_drafted") > 0);
        assert!(
            server.metrics.gauge("replica.0.spec_accepted")
                <= server.metrics.gauge("replica.0.spec_drafted")
        );
        server.shutdown();
    }

    #[test]
    fn prefix_cache_serves_shared_system_prompts_and_exports_gauges() {
        // one system prompt shared by every request: after the first
        // prefill the rest attach its blocks, so the hit/reuse gauges
        // move and the shared-refs gauge is exported alongside occupancy
        let server = Frontend::start(
            vec![("fp16".to_string(), micro_engine(13))],
            FrontendConfig { prefix_cache: true, ..Default::default() },
        )
        .unwrap();
        // one whole block at the default 16-position block size
        let sys: Vec<u32> = (0..16u32).map(|i| i % 60).collect();
        let mut tickets = Vec::new();
        for i in 0..5u32 {
            let mut prompt = sys.clone();
            prompt.push(60 + (i % 3));
            tickets.push(server.submit(SubmitRequest::new(prompt, 4).config("fp16")).unwrap());
        }
        for t in tickets {
            let resp = t.rx.recv_timeout(Duration::from_secs(30)).expect("response");
            assert_eq!(resp.tokens.len(), 4);
        }
        assert_eq!(server.metrics.counter("replica.0.completed"), 5);
        assert!(
            server.metrics.gauge("replica.0.prefix_hits") >= 4,
            "every request after the first shares the system prompt"
        );
        assert!(server.metrics.gauge("replica.0.prefix_tokens_reused") >= 4 * 16);
        assert!(server.metrics.gauge("replica.0.prefix_entries") >= 1);
        server.shutdown();
    }

    #[test]
    fn unroutable_config_is_an_immediate_error() {
        let server = Frontend::start(
            vec![("fp16".to_string(), micro_engine(5))],
            FrontendConfig::default(),
        )
        .unwrap();
        let err = server.submit(SubmitRequest::new(vec![1], 2).config("w99a99"));
        assert!(err.is_err(), "unknown tag must fail at submit, not hang");
        assert_eq!(server.metrics.counter("server.unroutable"), 1);
        server.shutdown();
    }

    #[test]
    fn two_replicas_spread_load_and_sticky_affinity_pins() {
        // identical seeds → identical weights, so any placement gives the
        // same streams; what's under test is the routing itself
        let server = Frontend::start(
            vec![
                ("fp16".to_string(), micro_engine(5)),
                ("fp16".to_string(), micro_engine(5)),
            ],
            FrontendConfig::default(),
        )
        .unwrap();
        assert_eq!(server.replica_count(), 2);
        // same affinity fingerprint → same replica, every time
        let pinned: Vec<ReplicaId> = (0..4)
            .map(|_| {
                server
                    .submit(SubmitRequest::new(vec![1, 2, 3], 2).config("fp16").affinity(42))
                    .unwrap()
                    .replica
            })
            .collect();
        assert!(pinned.windows(2).all(|w| w[0] == w[1]), "affinity must pin: {pinned:?}");
        server.shutdown();
    }

    #[test]
    fn retire_rehomes_queued_and_inflight_work() {
        let server = Frontend::start(
            vec![
                ("fp16".to_string(), micro_engine(7)),
                ("fp16".to_string(), micro_engine(7)),
            ],
            FrontendConfig::default(),
        )
        .unwrap();
        let mut tickets = Vec::new();
        for i in 0..8 {
            let req = SubmitRequest::new(vec![1, 2, (i % 30) as u32], 6).config("fp16");
            tickets.push(server.submit(req).expect("routable"));
        }
        // kill replica 0 while requests are (likely) still moving
        server.retire(ReplicaId(0)).unwrap();
        for t in tickets {
            let resp = t.rx.recv_timeout(Duration::from_secs(30)).expect(
                "every response must still arrive after the replica died",
            );
            assert_eq!(resp.tokens.len(), 6);
        }
        assert_eq!(server.metrics.counter("server.completed"), 8);
        assert_eq!(server.metrics.counter("server.replica_retired"), 1);
        // retiring the dead replica again is a no-op, not a panic
        assert_eq!(server.retire(ReplicaId(0)).unwrap(), 0);
        server.shutdown();
    }

    #[test]
    fn adaptive_frontend_routes_default_traffic_and_force_shift_migrates() {
        // two rungs over identical fp32 engines: the routing/migration
        // machinery is under test here, not the numerics (those are the
        // business of tests/prop_autopilot.rs)
        let rung = |name: &str| OperatingPoint {
            name: name.to_string(),
            backend: "fp32".to_string(),
            kv: KvCacheConfig::FP32,
        };
        let server = Frontend::start_adaptive(
            vec![(rung("hi"), micro_engine(5)), (rung("lo"), micro_engine(5))],
            FrontendConfig::default(),
            AutopilotConfig { policy: AutopilotPolicy::Frozen, ..Default::default() },
        )
        .unwrap();
        assert_eq!(server.active_rung(), Some(0));
        // untagged traffic lands on rung 0
        let t = server.submit(SubmitRequest::new(vec![1, 2, 3], 3)).unwrap();
        assert_eq!(t.replica, ReplicaId(0));
        assert_eq!(t.rx.recv_timeout(Duration::from_secs(30)).unwrap().tokens.len(), 3);
        // a frozen autopilot never shifts on its own
        assert_eq!(server.autopilot_tick(), ShiftDecision::Hold);
        assert_eq!(server.active_rung(), Some(0));
        // forced downshift retargets the default tag; new untagged
        // traffic lands on rung 1 and still completes
        assert_eq!(server.force_shift(true).unwrap(), 1);
        assert_eq!(server.metrics.counter("server.downshifts"), 1);
        assert_eq!(server.metrics.gauge("server.precision_rung"), 1);
        let t = server.submit(SubmitRequest::new(vec![1, 2, 3], 3)).unwrap();
        assert_eq!(t.replica, ReplicaId(1));
        assert_eq!(t.rx.recv_timeout(Duration::from_secs(30)).unwrap().tokens.len(), 3);
        // shift back up; the edges error instead of walking off
        assert_eq!(server.force_shift(false).unwrap(), 0);
        assert!(server.force_shift(false).is_err(), "already at rung 0");
        assert_eq!(server.metrics.counter("server.upshifts"), 1);
        server.shutdown();
    }
}
