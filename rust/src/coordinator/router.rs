//! Request router: maps a requested quant config to the engine replica
//! serving it (the multi-precision deployment the paper's "quantization
//! freedom" enables — one binary serving fp16 and any WqAp side by side).

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Routing table: config tag → replica indices (round-robin within a tag).
#[derive(Debug, Default)]
pub struct Router {
    routes: BTreeMap<String, Vec<usize>>,
    rr: BTreeMap<String, usize>,
    default_tag: String,
}

impl Router {
    pub fn new(default_tag: &str) -> Self {
        Router { default_tag: default_tag.to_string(), ..Default::default() }
    }

    pub fn register(&mut self, tag: &str, replica: usize) {
        self.routes.entry(tag.to_string()).or_default().push(replica);
    }

    pub fn tags(&self) -> Vec<&str> {
        self.routes.keys().map(|s| s.as_str()).collect()
    }

    /// Resolve a request's config tag ("" = default) to a replica index.
    pub fn route(&mut self, tag: &str) -> Result<usize> {
        let tag = if tag.is_empty() { self.default_tag.as_str() } else { tag };
        let replicas = match self.routes.get(tag) {
            Some(r) if !r.is_empty() => r,
            _ => bail!("no replica serves config '{tag}'"),
        };
        let cursor = self.rr.entry(tag.to_string()).or_insert(0);
        let idx = replicas[*cursor % replicas.len()];
        *cursor += 1;
        Ok(idx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routes_default_and_named() {
        let mut r = Router::new("w2sa8");
        r.register("w2sa8", 0);
        r.register("fp16", 1);
        assert_eq!(r.route("").unwrap(), 0);
        assert_eq!(r.route("fp16").unwrap(), 1);
        assert!(r.route("w9a9").is_err());
    }

    #[test]
    fn round_robin_within_tag() {
        let mut r = Router::new("fp16");
        r.register("fp16", 3);
        r.register("fp16", 5);
        let picks: Vec<usize> = (0..4).map(|_| r.route("fp16").unwrap()).collect();
        assert_eq!(picks, vec![3, 5, 3, 5]);
    }
}
