//! Replica router: places each request on one of N engine replicas (the
//! multi-precision, multi-replica deployment the paper's "quantization
//! freedom" enables — one binary serving fp16 and any WqAp side by side,
//! each tag on as many replicas as traffic needs).
//!
//! Routing is three-tiered (docs/SERVING.md §multi-replica):
//! 1. **tag isolation** — only live replicas registered under the
//!    request's config tag are candidates; an unknown tag is an error,
//!    never a silent fallback to another precision;
//! 2. **stickiness** — a request carrying a session-affinity fingerprint
//!    returns to the replica that served the fingerprint before (KV /
//!    prefix-cache locality), as long as that replica is alive and
//!    serves the right tag;
//! 3. **load score** — otherwise the candidate with the best
//!    `free_blocks / (queue_depth + 1)` score wins, with the old
//!    within-tag round-robin kept as the tie-breaker (its cursor now
//!    bounded: it wraps modulo the candidate count instead of counting
//!    up forever).

use std::collections::{BTreeMap, HashMap};

use anyhow::{bail, Result};

/// Index of one worker replica (position in the frontend's replica vec).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ReplicaId(pub usize);

impl std::fmt::Display for ReplicaId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "replica{}", self.0)
    }
}

/// Live load signal for one replica, refreshed by the frontend from the
/// worker's atomics before each routing decision.
#[derive(Clone, Copy, Debug)]
pub struct ReplicaState {
    /// free KV blocks in the replica's pool (`usize::MAX` = no pool /
    /// unknown — treated as unconstrained)
    pub free_blocks: usize,
    /// queued + active + preempted requests on the replica
    pub queue_depth: usize,
    pub alive: bool,
}

impl Default for ReplicaState {
    fn default() -> Self {
        ReplicaState { free_blocks: usize::MAX, queue_depth: 0, alive: true }
    }
}

/// What the router needs to know about a request to place it.
#[derive(Clone, Copy, Debug)]
pub struct RequestMeta<'a> {
    /// requested config tag ("" = router default)
    pub config_tag: &'a str,
    pub session_affinity: Option<u64>,
    pub prompt_len: usize,
}

struct ReplicaEntry {
    tag: String,
    state: ReplicaState,
}

/// Routing table over the frontend's replicas.
pub struct Router {
    replicas: Vec<ReplicaEntry>,
    /// session fingerprint → last replica that served it
    sticky: HashMap<u64, ReplicaId>,
    /// per-tag round-robin cursor (tie-breaker); always `< candidates`
    rr: BTreeMap<String, usize>,
    default_tag: String,
}

impl Router {
    pub fn new(default_tag: &str) -> Self {
        Router {
            replicas: Vec::new(),
            sticky: HashMap::new(),
            rr: BTreeMap::new(),
            default_tag: default_tag.to_string(),
        }
    }

    /// Register the next replica under `tag`, returning its id (ids are
    /// dense and match the frontend's replica vec order).
    pub fn register(&mut self, tag: &str) -> ReplicaId {
        let id = ReplicaId(self.replicas.len());
        self.replicas
            .push(ReplicaEntry { tag: tag.to_string(), state: ReplicaState::default() });
        id
    }

    /// Refresh one replica's load signal.
    pub fn update(&mut self, id: ReplicaId, state: ReplicaState) {
        if let Some(e) = self.replicas.get_mut(id.0) {
            let alive = e.state.alive && state.alive;
            e.state = ReplicaState { alive, ..state };
        }
    }

    /// Permanently remove a replica from routing (death or retirement).
    /// Its sticky sessions fail over to the load score on their next
    /// request.
    pub fn mark_dead(&mut self, id: ReplicaId) {
        if let Some(e) = self.replicas.get_mut(id.0) {
            e.state.alive = false;
        }
    }

    pub fn tags(&self) -> Vec<&str> {
        let mut tags: Vec<&str> = self.replicas.iter().map(|e| e.tag.as_str()).collect();
        tags.sort_unstable();
        tags.dedup();
        tags
    }

    /// Live replicas currently serving `tag` ("" = default).
    pub fn live_replicas(&self, tag: &str) -> Vec<ReplicaId> {
        let tag = if tag.is_empty() { self.default_tag.as_str() } else { tag };
        self.replicas
            .iter()
            .enumerate()
            .filter(|(_, e)| e.tag == tag && e.state.alive)
            .map(|(i, _)| ReplicaId(i))
            .collect()
    }

    /// Load score — higher is better. Free blocks are the capacity a new
    /// sequence actually competes for; queue depth discounts a replica
    /// that is already committed. Clamped so the poolless sentinel
    /// cannot overflow.
    fn score(s: &ReplicaState) -> usize {
        s.free_blocks.min(1_000_000) * 1000 / (s.queue_depth + 1)
    }

    /// Place a request: tag isolation → sticky hit → best load score,
    /// round-robin among ties. Records the placement for the request's
    /// affinity fingerprint, if it carries one.
    pub fn route(&mut self, meta: &RequestMeta) -> Result<ReplicaId> {
        let tag =
            if meta.config_tag.is_empty() { self.default_tag.clone() } else { meta.config_tag.to_string() };
        let candidates: Vec<ReplicaId> = self
            .replicas
            .iter()
            .enumerate()
            .filter(|(_, e)| e.tag == tag && e.state.alive)
            .map(|(i, _)| ReplicaId(i))
            .collect();
        if candidates.is_empty() {
            bail!("no live replica serves config '{tag}'");
        }
        // sticky hit: same fingerprint goes back to its replica while
        // that replica is alive and still serves the right tag
        if let Some(fp) = meta.session_affinity {
            if let Some(&prev) = self.sticky.get(&fp) {
                if candidates.contains(&prev) {
                    return Ok(prev);
                }
            }
        }
        let best_score =
            candidates.iter().map(|id| Self::score(&self.replicas[id.0].state)).max().unwrap();
        let tied: Vec<ReplicaId> = candidates
            .iter()
            .copied()
            .filter(|id| Self::score(&self.replicas[id.0].state) == best_score)
            .collect();
        let chosen = if tied.len() == 1 {
            tied[0]
        } else {
            // bounded round-robin tie-breaker: the cursor wraps modulo
            // the tie count instead of growing forever
            let cursor = self.rr.entry(tag).or_insert(0);
            *cursor %= tied.len();
            let pick = tied[*cursor];
            *cursor = (*cursor + 1) % tied.len();
            pick
        };
        if let Some(fp) = meta.session_affinity {
            self.sticky.insert(fp, chosen);
        }
        Ok(chosen)
    }

    /// Re-pin a sticky fingerprint (the frontend calls this when it
    /// re-homes a drained request to a survivor).
    pub fn repin(&mut self, fingerprint: u64, to: ReplicaId) {
        self.sticky.insert(fingerprint, to);
    }

    /// Retarget what "" (no explicit tag) resolves to — how the precision
    /// autopilot steers default traffic onto the active rung without the
    /// clients knowing rung names.
    pub fn set_default_tag(&mut self, tag: &str) {
        self.default_tag = tag.to_string();
    }

    pub fn default_tag(&self) -> &str {
        &self.default_tag
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(tag: &str) -> RequestMeta<'_> {
        RequestMeta { config_tag: tag, session_affinity: None, prompt_len: 4 }
    }

    #[test]
    fn routes_default_and_named_with_tag_isolation() {
        let mut r = Router::new("w2sa8");
        let a = r.register("w2sa8");
        let b = r.register("fp16");
        assert_eq!(r.route(&meta("")).unwrap(), a);
        assert_eq!(r.route(&meta("fp16")).unwrap(), b);
        // unknown tag errors; it never falls back to another precision
        assert!(r.route(&meta("w9a9")).is_err());
        assert_eq!(r.tags(), vec!["fp16", "w2sa8"]);
    }

    #[test]
    fn round_robin_tie_breaker_is_bounded() {
        let mut r = Router::new("fp16");
        let a = r.register("fp16");
        let b = r.register("fp16");
        // equal load → alternate deterministically
        let picks: Vec<ReplicaId> = (0..4).map(|_| r.route(&meta("fp16")).unwrap()).collect();
        assert_eq!(picks, vec![a, b, a, b]);
        // the cursor must stay bounded by the tie count, not count up
        for _ in 0..1000 {
            r.route(&meta("fp16")).unwrap();
        }
        assert!(*r.rr.get("fp16").unwrap() < 2, "cursor must wrap, not grow");
    }

    #[test]
    fn load_score_prefers_free_blocks_and_short_queues() {
        let mut r = Router::new("fp16");
        let a = r.register("fp16");
        let b = r.register("fp16");
        r.update(a, ReplicaState { free_blocks: 10, queue_depth: 4, alive: true });
        r.update(b, ReplicaState { free_blocks: 100, queue_depth: 0, alive: true });
        for _ in 0..3 {
            assert_eq!(r.route(&meta("")).unwrap(), b, "less loaded replica must win");
        }
        // flip the load
        r.update(b, ReplicaState { free_blocks: 2, queue_depth: 9, alive: true });
        assert_eq!(r.route(&meta("")).unwrap(), a);
    }

    #[test]
    fn sticky_sessions_return_to_their_replica() {
        let mut r = Router::new("fp16");
        let a = r.register("fp16");
        let b = r.register("fp16");
        let m = RequestMeta { config_tag: "", session_affinity: Some(99), prompt_len: 4 };
        let first = r.route(&m).unwrap();
        // skew the load against the sticky replica — it must still win
        let other = if first == a { b } else { a };
        r.update(other, ReplicaState { free_blocks: 1_000_000, queue_depth: 0, alive: true });
        r.update(first, ReplicaState { free_blocks: 1, queue_depth: 50, alive: true });
        for _ in 0..3 {
            assert_eq!(r.route(&m).unwrap(), first, "affinity beats load");
        }
        // a different fingerprint follows the load instead
        let m2 = RequestMeta { config_tag: "", session_affinity: Some(100), prompt_len: 4 };
        assert_eq!(r.route(&m2).unwrap(), other);
    }

    #[test]
    fn failover_on_dead_replica() {
        let mut r = Router::new("fp16");
        let a = r.register("fp16");
        let b = r.register("fp16");
        let m = RequestMeta { config_tag: "", session_affinity: Some(7), prompt_len: 4 };
        // pin the session to a deterministic replica
        r.repin(7, a);
        assert_eq!(r.route(&m).unwrap(), a);
        r.mark_dead(a);
        // sticky target is gone: fail over to the survivor and re-pin
        assert_eq!(r.route(&m).unwrap(), b);
        r.update(a, ReplicaState { free_blocks: 1_000_000, queue_depth: 0, alive: true });
        // update() cannot resurrect a dead replica
        assert_eq!(r.route(&m).unwrap(), b);
        // killing the last replica of a tag makes the tag unroutable
        r.mark_dead(b);
        assert!(r.route(&m).is_err());
    }

    #[test]
    fn default_tag_can_be_retargeted_at_runtime() {
        let mut r = Router::new("w6a6-kv8");
        let a = r.register("w6a6-kv8");
        let b = r.register("w4a4-kv8");
        assert_eq!(r.route(&meta("")).unwrap(), a);
        // the autopilot's downshift: "" now resolves to the cheaper rung
        r.set_default_tag("w4a4-kv8");
        assert_eq!(r.default_tag(), "w4a4-kv8");
        assert_eq!(r.route(&meta("")).unwrap(), b);
        // explicit tags are unaffected by the default retarget
        assert_eq!(r.route(&meta("w6a6-kv8")).unwrap(), a);
    }

    #[test]
    fn tag_isolation_survives_death_in_other_tag() {
        let mut r = Router::new("w2sa8");
        let a = r.register("w2sa8");
        let b = r.register("fp16");
        r.mark_dead(b);
        // fp16 death must not affect w2sa8 routing
        assert_eq!(r.route(&meta("")).unwrap(), a);
        assert!(r.route(&meta("fp16")).is_err());
        assert_eq!(r.live_replicas(""), vec![a]);
        assert!(r.live_replicas("fp16").is_empty());
    }
}
