//! Dynamic batcher: groups queued requests into admission batches under a
//! size cap and a wait deadline — the standard continuous-batching
//! admission policy (vLLM/Orca-style), which is what the paper's engine
//! plugs into (its FastTransformer integration batches the same way).
//!
//! Invariants (property-tested in rust/tests/proptest_coordinator.rs):
//!   * a drained batch never exceeds `max_batch`
//!   * FIFO order is preserved
//!   * a request is never dropped or duplicated
//!   * a non-empty queue always drains once the oldest entry passes the
//!     deadline (no starvation)

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use super::request::QueuedRequest;

#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// max requests admitted per batch
    pub max_batch: usize,
    /// max time the oldest request may wait before forcing a drain
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(5) }
    }
}

pub struct Batcher {
    cfg: BatcherConfig,
    queue: VecDeque<QueuedRequest>,
}

impl Batcher {
    pub fn new(cfg: BatcherConfig) -> Self {
        Batcher { cfg, queue: VecDeque::new() }
    }

    pub fn push(&mut self, req: QueuedRequest) {
        self.queue.push_back(req);
    }

    /// Put a drained-but-not-admitted request back at the head of the
    /// queue (KV-pool deferral) so FIFO order is preserved.
    pub fn requeue_front(&mut self, req: QueuedRequest) {
        self.queue.push_front(req);
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Should the queue be drained now? True when full batch is available
    /// or the oldest entry has waited past the deadline.
    pub fn ready(&self, now: Instant) -> bool {
        if self.queue.len() >= self.cfg.max_batch {
            return true;
        }
        match self.queue.front() {
            Some(front) => now.duration_since(front.arrived) >= self.cfg.max_wait,
            None => false,
        }
    }

    /// Remove up to `capacity.min(max_batch)` requests, FIFO.
    pub fn drain(&mut self, capacity: usize) -> Vec<QueuedRequest> {
        let take = capacity.min(self.cfg.max_batch).min(self.queue.len());
        self.queue.drain(..take).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::SubmitRequest;

    fn qr(id: u64) -> QueuedRequest {
        QueuedRequest::new(id, SubmitRequest::new(vec![1, 2], 4))
    }

    #[test]
    fn drains_fifo_up_to_cap() {
        let mut b = Batcher::new(BatcherConfig { max_batch: 3, max_wait: Duration::ZERO });
        for id in 0..5 {
            b.push(qr(id));
        }
        assert!(b.ready(Instant::now()));
        let batch = b.drain(10);
        assert_eq!(batch.iter().map(|q| q.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(b.len(), 2);
    }

    #[test]
    fn respects_capacity() {
        let mut b = Batcher::new(BatcherConfig::default());
        for id in 0..5 {
            b.push(qr(id));
        }
        assert_eq!(b.drain(2).len(), 2);
    }

    #[test]
    fn not_ready_when_fresh_and_small() {
        let mut b = Batcher::new(BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_secs(10),
        });
        b.push(qr(0));
        assert!(!b.ready(Instant::now()));
    }

    #[test]
    fn deadline_forces_drain() {
        let mut b = Batcher::new(BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
        });
        b.push(QueuedRequest {
            id: 0,
            req: SubmitRequest::new(vec![1], 1),
            arrived: Instant::now() - Duration::from_millis(5),
        });
        assert!(b.ready(Instant::now()));
    }
}
