//! Request/response types for the serving coordinator.

use std::time::Instant;

use crate::model::Sampling;

/// A generation request (the unit the router/batcher/scheduler move).
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    pub sampling: Sampling,
    /// quant config tag the client asked for ("" = router default)
    pub config: String,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<u32>, max_new_tokens: usize) -> Self {
        Request {
            id,
            prompt,
            max_new_tokens,
            sampling: Sampling::Greedy,
            config: String::new(),
        }
    }
}

/// Per-request timing breakdown (the latency metrics of Fig. 6).
#[derive(Clone, Copy, Debug, Default)]
pub struct Timing {
    pub queue_us: u64,
    pub prefill_us: u64,
    pub decode_us: u64,
}

impl Timing {
    pub fn total_us(&self) -> u64 {
        self.queue_us + self.prefill_us + self.decode_us
    }
}

#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub prompt_len: usize,
    pub tokens: Vec<u32>,
    pub timing: Timing,
}

/// Internal: a request with its arrival timestamp.
#[derive(Debug)]
pub struct QueuedRequest {
    pub req: Request,
    pub arrived: Instant,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_total() {
        let t = Timing { queue_us: 10, prefill_us: 20, decode_us: 30 };
        assert_eq!(t.total_us(), 60);
    }
}
