//! The coordinator request API: one submission type ([`SubmitRequest`]),
//! one handle type ([`Ticket`]), one admission enum ([`Admission`]) —
//! shared by the server, the batcher, the scheduler and the CLI alike,
//! replacing the ad-hoc per-call argument lists the single-engine
//! coordinator grew.
//!
//! Request ids are stamped by the [`super::server::Frontend`] at submit
//! time, and every stream-visible random choice derives from the id via
//! [`sampling_seed`] — so a request's output depends only on its id and
//! content, never on admission order or which replica served it (the
//! property `tests/prop_replicas.rs` asserts across replica death).

use std::sync::mpsc::Receiver;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::model::Sampling;

use super::router::ReplicaId;

/// A generation request as submitted by a client (the unit the router,
/// batcher and scheduler move). Ids are assigned by the frontend — the
/// submitter only describes the work.
#[derive(Clone, Debug)]
pub struct SubmitRequest {
    pub prompt: Vec<u32>,
    /// cap on generated tokens (the scheduler clamps to KV capacity)
    pub max_new: usize,
    pub sampling: Sampling,
    /// quant config tag the client asked for ("" = router default)
    pub config_tag: String,
    /// session fingerprint for sticky routing: requests sharing it land
    /// on the same replica while it lives, for KV/prefix-cache locality
    pub session_affinity: Option<u64>,
}

impl SubmitRequest {
    pub fn new(prompt: Vec<u32>, max_new: usize) -> Self {
        SubmitRequest {
            prompt,
            max_new,
            sampling: Sampling::Greedy,
            config_tag: String::new(),
            session_affinity: None,
        }
    }

    /// Request a specific quant config tag (builder-chaining form).
    pub fn config(mut self, tag: impl Into<String>) -> Self {
        self.config_tag = tag.into();
        self
    }

    /// Sticky-route alongside other requests with the same fingerprint.
    pub fn affinity(mut self, fingerprint: u64) -> Self {
        self.session_affinity = Some(fingerprint);
        self
    }

    pub fn sampling(mut self, s: Sampling) -> Self {
        self.sampling = s;
        self
    }
}

/// Per-request timing breakdown (the latency metrics of Fig. 6).
#[derive(Clone, Copy, Debug, Default)]
pub struct Timing {
    pub queue_us: u64,
    pub prefill_us: u64,
    pub decode_us: u64,
}

impl Timing {
    pub fn total_us(&self) -> u64 {
        self.queue_us + self.prefill_us + self.decode_us
    }

    /// Time to first token: queueing plus prefill (the latency-SLO axis
    /// of the saturation bench).
    pub fn ttft_us(&self) -> u64 {
        self.queue_us + self.prefill_us
    }
}

#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub prompt_len: usize,
    pub tokens: Vec<u32>,
    pub timing: Timing,
}

/// An id-stamped request with its arrival timestamp — the form that
/// moves through batcher queues and scheduler admission.
#[derive(Debug)]
pub struct QueuedRequest {
    pub id: u64,
    pub req: SubmitRequest,
    pub arrived: Instant,
}

impl QueuedRequest {
    pub fn new(id: u64, req: SubmitRequest) -> Self {
        QueuedRequest { id, req, arrived: Instant::now() }
    }
}

/// What a submission returned: the stamped id, the replica the router
/// placed it on, and the channel the response arrives on. The replica
/// is informational — if that replica dies, the frontend re-homes the
/// request and the response still arrives here.
#[derive(Debug)]
pub struct Ticket {
    pub id: u64,
    pub replica: ReplicaId,
    pub rx: Receiver<Response>,
}

impl Ticket {
    /// Block until the response arrives (or every sender is gone).
    pub fn wait(self) -> Result<Response> {
        self.rx.recv().with_context(|| format!("request {}: response channel closed", self.id))
    }
}

/// The one admission enum every coordinator layer speaks:
/// * [`Admission::Routed`] — the frontend placed the request on a
///   replica (what [`super::server::Frontend::route_preview`] reports);
/// * [`Admission::Admitted`] — a replica's scheduler activated it;
/// * [`Admission::Deferred`] — no KV/slot capacity right now; the
///   request comes back to be requeued at the head of the batcher.
#[derive(Debug)]
pub enum Admission {
    Routed(ReplicaId),
    Admitted,
    Deferred(QueuedRequest),
}

/// Deterministic per-request sampling seed (splitmix64 finalizer over
/// the request id). Every replica derives a request's sampler from this,
/// so streams are independent of admission order and replica assignment
/// — the bit-identity property multi-replica drain/replay relies on.
pub fn sampling_seed(id: u64) -> u64 {
    let mut z = id.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_total_and_ttft() {
        let t = Timing { queue_us: 10, prefill_us: 20, decode_us: 30 };
        assert_eq!(t.total_us(), 60);
        assert_eq!(t.ttft_us(), 30);
    }

    #[test]
    fn submit_request_builder_chain() {
        let r = SubmitRequest::new(vec![1, 2, 3], 8).config("w2sa8").affinity(42);
        assert_eq!(r.prompt, vec![1, 2, 3]);
        assert_eq!(r.max_new, 8);
        assert_eq!(r.config_tag, "w2sa8");
        assert_eq!(r.session_affinity, Some(42));
        assert!(matches!(r.sampling, Sampling::Greedy));
    }

    #[test]
    fn sampling_seeds_are_distinct_and_stable() {
        let seeds: Vec<u64> = (0..64).map(sampling_seed).collect();
        let mut uniq = seeds.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), seeds.len(), "no collisions on small ids");
        // stable across calls — the determinism contract
        assert_eq!(sampling_seed(7), sampling_seed(7));
        assert_ne!(sampling_seed(0), 0, "id 0 must still mix");
    }
}
