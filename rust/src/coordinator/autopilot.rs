//! The serving autopilot's decision policy: when to walk the precision
//! ladder down (shed quality for capacity) and when to walk it back up
//! (restore quality when load drops). Pure decision logic — the
//! migration mechanics (drain/inject between rung workers) live in
//! [`super::server::Frontend`]; this module owns the *policy* so it can
//! be unit-tested without threads.
//!
//! Signals per tick (`docs/SERVING.md` §adaptive precision):
//!
//! * **windowed p95 TTFT** — the `server.ttft_us` histogram delta since
//!   the previous tick ([`super::metrics::Histogram::delta`]). `None`
//!   means *no completions this window* — explicitly not "SLO met"
//!   (the ISSUE-9 bugfix: a `0` sentinel here once made silence look
//!   like health).
//! * **KV pool occupancy** of the active rung (percent of pool blocks
//!   leased). `None` when the rung publishes no pool gauge yet.
//!
//! Downshift needs *positive evidence* of distress: a measured p95 over
//! the SLO, or occupancy at/over the high-water mark. Upshift needs the
//! *absence of distress*: occupancy at/below the low-water mark and no
//! measured SLO violation (an empty window counts as idle — that is the
//! "restore precision when load drops" path). A dwell counter keeps the
//! two from oscillating.

use super::metrics::Histogram;

/// How the autopilot is allowed to move.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AutopilotPolicy {
    /// Walk the ladder freely in both directions.
    Adaptive,
    /// Never shift — the differential-test mode: a frozen autopilot
    /// must be bit-identical to a fixed-config deployment
    /// (`tests/prop_autopilot.rs`).
    Frozen,
}

/// SLO knobs for the precision autopilot (`--autopilot`,
/// `--slo-ttft-ms`).
#[derive(Clone, Copy, Debug)]
pub struct AutopilotConfig {
    /// p95 time-to-first-token target, µs (the latency SLO)
    pub slo_ttft_us: u64,
    /// KV occupancy (%) at/above which the active rung downshifts
    pub high_occupancy_pct: u64,
    /// KV occupancy (%) at/below which an upshift is allowed
    pub low_occupancy_pct: u64,
    /// ticks that must pass after a shift before the next one
    pub min_dwell_ticks: u32,
    /// background evaluation period; 0 = no pilot thread, the embedder
    /// calls `Frontend::autopilot_tick()` itself (tests, benches)
    pub poll_ms: u64,
    pub policy: AutopilotPolicy,
}

impl Default for AutopilotConfig {
    fn default() -> Self {
        AutopilotConfig {
            slo_ttft_us: 250_000,
            high_occupancy_pct: 85,
            low_occupancy_pct: 30,
            min_dwell_ticks: 2,
            poll_ms: 0,
            policy: AutopilotPolicy::Adaptive,
        }
    }
}

/// One tick's verdict.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShiftDecision {
    Hold,
    /// move to the next-cheaper rung (index + 1)
    Down,
    /// move to the next-more-precise rung (index − 1)
    Up,
}

/// The pure policy function (see module docs). `p95_ttft_us` is the
/// windowed quantile (`None` = empty window), `occupancy_pct` the active
/// rung's pool occupancy (`None` = no pool gauge yet).
pub fn decide(
    cfg: &AutopilotConfig,
    p95_ttft_us: Option<u64>,
    occupancy_pct: Option<u64>,
    at_lowest: bool,
    at_highest: bool,
    dwell_ok: bool,
) -> ShiftDecision {
    if cfg.policy == AutopilotPolicy::Frozen || !dwell_ok {
        return ShiftDecision::Hold;
    }
    let slo_violated = p95_ttft_us.is_some_and(|p| p > cfg.slo_ttft_us);
    let pool_pressure = occupancy_pct.is_some_and(|o| o >= cfg.high_occupancy_pct);
    if (slo_violated || pool_pressure) && !at_lowest {
        return ShiftDecision::Down;
    }
    let idle_or_healthy = p95_ttft_us.is_none_or(|p| p <= cfg.slo_ttft_us);
    let pool_relaxed = occupancy_pct.is_none_or(|o| o <= cfg.low_occupancy_pct);
    if idle_or_healthy && pool_relaxed && !at_highest {
        return ShiftDecision::Up;
    }
    ShiftDecision::Hold
}

/// Mutable autopilot state the frontend keeps behind one mutex: the
/// active rung index plus the signal memory a windowed tick needs.
pub(crate) struct Autopilot {
    pub(crate) cfg: AutopilotConfig,
    /// index into the ladder; 0 = most precise
    pub(crate) active: usize,
    pub(crate) ticks_since_shift: u32,
    /// `server.ttft_us` snapshot at the previous tick — the next tick's
    /// [`Histogram::delta`] baseline
    pub(crate) prev_ttft: Histogram,
}

impl Autopilot {
    pub(crate) fn new(cfg: AutopilotConfig) -> Self {
        Autopilot {
            cfg,
            active: 0,
            // start dwell-eligible so the first tick may already shift
            ticks_since_shift: cfg.min_dwell_ticks,
            prev_ttft: Histogram::default(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(policy: AutopilotPolicy) -> AutopilotConfig {
        AutopilotConfig { slo_ttft_us: 1_000, policy, ..Default::default() }
    }

    #[test]
    fn frozen_policy_never_shifts() {
        let c = cfg(AutopilotPolicy::Frozen);
        for p95 in [None, Some(0), Some(1_000_000)] {
            for occ in [None, Some(0), Some(100)] {
                assert_eq!(decide(&c, p95, occ, false, false, true), ShiftDecision::Hold);
            }
        }
    }

    #[test]
    fn slo_violation_or_pool_pressure_downshifts() {
        let c = cfg(AutopilotPolicy::Adaptive);
        assert_eq!(decide(&c, Some(5_000), Some(50), false, false, true), ShiftDecision::Down);
        assert_eq!(decide(&c, Some(100), Some(90), false, false, true), ShiftDecision::Down);
        // already at the cheapest rung: nowhere further down
        assert_eq!(decide(&c, Some(5_000), Some(90), true, false, true), ShiftDecision::Hold);
        // dwell gate holds both directions
        assert_eq!(decide(&c, Some(5_000), Some(90), false, false, false), ShiftDecision::Hold);
    }

    #[test]
    fn empty_window_is_not_an_slo_violation_but_idle_restores() {
        let c = cfg(AutopilotPolicy::Adaptive);
        // the ISSUE-9 bug shape: no traffic + busy pool must NOT read as
        // "p95 = 0 → healthy → upshift", nor as a violation
        assert_eq!(decide(&c, None, Some(60), false, false, true), ShiftDecision::Hold);
        // genuinely idle (empty window + relaxed pool) restores precision
        assert_eq!(decide(&c, None, Some(10), false, false, true), ShiftDecision::Up);
        // already at the most precise rung: hold
        assert_eq!(decide(&c, None, Some(10), false, true, true), ShiftDecision::Hold);
    }

    #[test]
    fn healthy_but_busy_holds() {
        let c = cfg(AutopilotPolicy::Adaptive);
        // SLO met but the pool sits between the water marks: no shift
        assert_eq!(decide(&c, Some(500), Some(60), false, false, true), ShiftDecision::Hold);
    }
}
