//! L3 serving coordinator (the paper's deployment context): request
//! router, dynamic batcher, continuous-batching scheduler with KV-aware
//! admission, metrics. See `server.rs` for the thread topology.

pub mod batcher;
pub mod metrics;
pub mod request;
pub mod router;
pub mod scheduler;
pub mod server;

pub use batcher::{Batcher, BatcherConfig};
pub use metrics::Metrics;
pub use request::{QueuedRequest, Request, Response, Timing};
pub use router::Router;
pub use scheduler::{Admission, Scheduler, SchedulerConfig};
pub use server::{Server, ServerConfig};
