//! L3 serving coordinator (the paper's deployment context): replica
//! router, dynamic batcher, continuous-batching scheduler with KV-aware
//! admission, multi-replica frontend, metrics. See `server.rs` for the
//! thread topology and `docs/SERVING.md` §multi-replica for the design.

pub mod autopilot;
pub mod batcher;
pub mod metrics;
pub mod request;
pub mod router;
pub mod scheduler;
pub mod server;

pub use autopilot::{AutopilotConfig, AutopilotPolicy, ShiftDecision};
pub use batcher::{Batcher, BatcherConfig};
pub use metrics::{Histogram, Metrics};
pub use request::{
    sampling_seed, Admission, QueuedRequest, Response, SubmitRequest, Ticket, Timing,
};
pub use router::{ReplicaId, ReplicaState, RequestMeta, Router};
pub use scheduler::{InFlight, Scheduler, SchedulerConfig};
pub use server::{Frontend, FrontendConfig, Server, ServerConfig};
