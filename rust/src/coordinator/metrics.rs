//! Serving metrics: counters + streaming latency histograms. Lock-light
//! (one mutex, touched off the hot loop at batch granularity).

use std::collections::BTreeMap;
use std::sync::Mutex;

/// Fixed log-scale latency histogram (µs buckets, powers of two).
#[derive(Clone, Debug, Default)]
pub struct Histogram {
    pub counts: Vec<u64>, // bucket i covers [2^i, 2^(i+1)) µs
    pub total: u64,
    pub sum_us: u64,
    pub max_us: u64,
}

impl Histogram {
    pub fn record(&mut self, us: u64) {
        let bucket = 64 - us.max(1).leading_zeros() as usize;
        if self.counts.len() <= bucket {
            self.counts.resize(bucket + 1, 0);
        }
        self.counts[bucket] += 1;
        self.total += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    pub fn mean_us(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.total as f64
        }
    }

    /// Approximate quantile from bucket boundaries.
    pub fn quantile_us(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let target = (self.total as f64 * q).ceil() as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return 1u64 << i;
            }
        }
        self.max_us
    }
}

#[derive(Debug, Default)]
pub struct MetricsInner {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, u64>,
    pub histograms: BTreeMap<String, Histogram>,
}

#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<MetricsInner>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn incr(&self, name: &str, by: u64) {
        let mut g = self.inner.lock().unwrap();
        *g.counters.entry(name.to_string()).or_insert(0) += by;
    }

    pub fn observe_us(&self, name: &str, us: u64) {
        let mut g = self.inner.lock().unwrap();
        g.histograms.entry(name.to_string()).or_default().record(us);
    }

    /// Set a point-in-time gauge (KV pool occupancy, queue depths).
    pub fn set_gauge(&self, name: &str, value: u64) {
        let mut g = self.inner.lock().unwrap();
        g.gauges.insert(name.to_string(), value);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.inner.lock().unwrap().counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge(&self, name: &str) -> u64 {
        self.inner.lock().unwrap().gauges.get(name).copied().unwrap_or(0)
    }

    /// Approximate quantile of a named histogram (0 when absent) — the
    /// p95-TTFT axis of the saturation bench.
    pub fn histogram_quantile_us(&self, name: &str, q: f64) -> u64 {
        self.inner
            .lock()
            .unwrap()
            .histograms
            .get(name)
            .map_or(0, |h| h.quantile_us(q))
    }

    pub fn snapshot(&self) -> String {
        let g = self.inner.lock().unwrap();
        let mut out = String::new();
        for (k, v) in &g.counters {
            out.push_str(&format!("{k}: {v}\n"));
        }
        for (k, v) in &g.gauges {
            out.push_str(&format!("{k}: {v} (gauge)\n"));
        }
        for (k, h) in &g.histograms {
            out.push_str(&format!(
                "{k}: n={} mean={:.0}us p50={}us p95={}us max={}us\n",
                h.total,
                h.mean_us(),
                h.quantile_us(0.5),
                h.quantile_us(0.95),
                h.max_us
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.incr("req", 1);
        m.incr("req", 2);
        assert_eq!(m.counter("req"), 3);
    }

    #[test]
    fn gauges_overwrite() {
        let m = Metrics::new();
        m.set_gauge("kv_blocks_used", 5);
        m.set_gauge("kv_blocks_used", 2);
        assert_eq!(m.gauge("kv_blocks_used"), 2);
        assert_eq!(m.gauge("missing"), 0);
        assert!(m.snapshot().contains("kv_blocks_used: 2 (gauge)"));
    }

    #[test]
    fn histogram_quantiles_monotone() {
        let mut h = Histogram::default();
        for us in [10u64, 20, 40, 80, 160, 1000, 5000] {
            h.record(us);
        }
        assert!(h.quantile_us(0.5) <= h.quantile_us(0.95));
        assert!(h.mean_us() > 0.0);
        assert_eq!(h.total, 7);
    }
}
