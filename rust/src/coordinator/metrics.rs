//! Serving metrics: counters + streaming latency histograms. Lock-light
//! (one mutex, touched off the hot loop at batch granularity).

use std::collections::BTreeMap;
use std::sync::Mutex;

/// Fixed log-scale latency histogram (µs buckets, powers of two).
#[derive(Clone, Debug, Default)]
pub struct Histogram {
    pub counts: Vec<u64>, // bucket i covers [2^i, 2^(i+1)) µs
    pub total: u64,
    pub sum_us: u64,
    pub max_us: u64,
}

impl Histogram {
    pub fn record(&mut self, us: u64) {
        let bucket = 64 - us.max(1).leading_zeros() as usize;
        if self.counts.len() <= bucket {
            self.counts.resize(bucket + 1, 0);
        }
        self.counts[bucket] += 1;
        self.total += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    pub fn mean_us(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.total as f64
        }
    }

    /// Approximate quantile from bucket boundaries. `None` when the
    /// histogram is empty — an empty window is *no evidence*, not a 0µs
    /// latency (the distinction the autopilot's SLO check rides on; a
    /// `0` sentinel here once read "no traffic yet" as "SLO met").
    pub fn quantile_us(&self, q: f64) -> Option<u64> {
        if self.total == 0 {
            return None;
        }
        let target = (self.total as f64 * q).ceil() as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Some(1u64 << i);
            }
        }
        Some(self.max_us)
    }

    /// The observations recorded since `earlier` was snapshotted — the
    /// windowed view a control loop wants (`earlier` must be a previous
    /// snapshot of the *same* histogram). `max_us` keeps the all-time
    /// maximum (bucket counts, not the max, drive the quantiles).
    pub fn delta(&self, earlier: &Histogram) -> Histogram {
        let counts = self
            .counts
            .iter()
            .enumerate()
            .map(|(i, &c)| c.saturating_sub(earlier.counts.get(i).copied().unwrap_or(0)))
            .collect();
        Histogram {
            counts,
            total: self.total.saturating_sub(earlier.total),
            sum_us: self.sum_us.saturating_sub(earlier.sum_us),
            max_us: self.max_us,
        }
    }
}

#[derive(Debug, Default)]
pub struct MetricsInner {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, u64>,
    pub histograms: BTreeMap<String, Histogram>,
}

#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<MetricsInner>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn incr(&self, name: &str, by: u64) {
        let mut g = self.inner.lock().unwrap();
        *g.counters.entry(name.to_string()).or_insert(0) += by;
    }

    pub fn observe_us(&self, name: &str, us: u64) {
        let mut g = self.inner.lock().unwrap();
        g.histograms.entry(name.to_string()).or_default().record(us);
    }

    /// Set a point-in-time gauge (KV pool occupancy, queue depths).
    pub fn set_gauge(&self, name: &str, value: u64) {
        let mut g = self.inner.lock().unwrap();
        g.gauges.insert(name.to_string(), value);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.inner.lock().unwrap().counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge(&self, name: &str) -> u64 {
        self.inner.lock().unwrap().gauges.get(name).copied().unwrap_or(0)
    }

    /// Approximate quantile of a named histogram — the p95-TTFT axis of
    /// the saturation bench and the autopilot's SLO signal. `None` when
    /// the histogram is absent or empty: "no traffic yet" must stay
    /// distinguishable from a real 0µs quantile, otherwise an SLO check
    /// reads silence as health.
    pub fn histogram_quantile_us(&self, name: &str, q: f64) -> Option<u64> {
        self.inner
            .lock()
            .unwrap()
            .histograms
            .get(name)
            .and_then(|h| h.quantile_us(q))
    }

    /// Clone of a named histogram (for windowed deltas via
    /// [`Histogram::delta`]); `None` when nothing was recorded under
    /// `name` yet.
    pub fn histogram_snapshot(&self, name: &str) -> Option<Histogram> {
        self.inner.lock().unwrap().histograms.get(name).cloned()
    }

    pub fn snapshot(&self) -> String {
        let g = self.inner.lock().unwrap();
        let mut out = String::new();
        for (k, v) in &g.counters {
            out.push_str(&format!("{k}: {v}\n"));
        }
        for (k, v) in &g.gauges {
            out.push_str(&format!("{k}: {v} (gauge)\n"));
        }
        for (k, h) in &g.histograms {
            // an empty histogram renders as empty instead of fabricating
            // 0µs quantiles
            match (h.quantile_us(0.5), h.quantile_us(0.95)) {
                (Some(p50), Some(p95)) => out.push_str(&format!(
                    "{k}: n={} mean={:.0}us p50={p50}us p95={p95}us max={}us\n",
                    h.total,
                    h.mean_us(),
                    h.max_us
                )),
                _ => out.push_str(&format!("{k}: n=0 (empty)\n")),
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.incr("req", 1);
        m.incr("req", 2);
        assert_eq!(m.counter("req"), 3);
    }

    #[test]
    fn gauges_overwrite() {
        let m = Metrics::new();
        m.set_gauge("kv_blocks_used", 5);
        m.set_gauge("kv_blocks_used", 2);
        assert_eq!(m.gauge("kv_blocks_used"), 2);
        assert_eq!(m.gauge("missing"), 0);
        assert!(m.snapshot().contains("kv_blocks_used: 2 (gauge)"));
    }

    #[test]
    fn histogram_quantiles_monotone() {
        let mut h = Histogram::default();
        for us in [10u64, 20, 40, 80, 160, 1000, 5000] {
            h.record(us);
        }
        assert!(h.quantile_us(0.5).unwrap() <= h.quantile_us(0.95).unwrap());
        assert!(h.mean_us() > 0.0);
        assert_eq!(h.total, 7);
    }

    #[test]
    fn empty_histograms_are_none_not_zero() {
        // the ISSUE-9 bugfix: absent/empty must be distinguishable from
        // a real 0µs quantile, or an SLO check reads silence as health
        let m = Metrics::new();
        assert_eq!(m.histogram_quantile_us("server.ttft_us", 0.95), None);
        assert!(m.histogram_snapshot("server.ttft_us").is_none());
        assert_eq!(Histogram::default().quantile_us(0.95), None);
        m.observe_us("server.ttft_us", 120);
        assert!(m.histogram_quantile_us("server.ttft_us", 0.95).unwrap() >= 120);
        assert!(m.snapshot().contains("server.ttft_us: n=1"));
    }

    #[test]
    fn histogram_delta_windows_the_recent_observations() {
        let m = Metrics::new();
        m.observe_us("lat", 100);
        m.observe_us("lat", 100);
        let earlier = m.histogram_snapshot("lat").unwrap();
        // no traffic since the snapshot → the window is empty → None
        let idle = m.histogram_snapshot("lat").unwrap().delta(&earlier);
        assert_eq!(idle.total, 0);
        assert_eq!(idle.quantile_us(0.95), None);
        // one slow request in the window dominates its p95 even though
        // the all-time histogram is still mostly fast
        m.observe_us("lat", 64_000);
        let win = m.histogram_snapshot("lat").unwrap().delta(&earlier);
        assert_eq!(win.total, 1);
        assert!(win.quantile_us(0.95).unwrap() >= 64_000);
        assert!(m.histogram_snapshot("lat").unwrap().quantile_us(0.5).unwrap() <= 256);
    }
}
