//! Block-wise distribution-correction calibration — the paper's DLC
//! pipeline (§3.2, Eq. 4–6), the algorithm half that complements the
//! inference engine's bit-plane GEMM:
//!
//! 1. **Tap** — the calibration corpus (the deterministic synthetic
//!    stream, [`crate::eval::corpus`]) runs through the fp32 model with
//!    [`crate::model::Transformer::prefill_traced`], capturing every
//!    block's residual in/out, per-projection input activations, and
//!    pre-softmax attention logits.
//! 2. **Learn** — per projection, a deterministic coordinate descent
//!    (weight clip → balance-scale migration → shift → per-channel
//!    refinement; seeded RNG only for row subsampling, no autograd)
//!    minimizes the quantized-vs-fp32 reconstruction MSE on the tapped
//!    activations ([`optimize`]).
//! 3. **Select** — per block, a coordinate sweep over the 7 projections
//!    accepts each learned correction only if it lowers the DLC
//!    objective `‖ŷ − y‖² + λ·‖Â − A‖²` (block output MSE plus
//!    attention consistency), with a final guard that never ships a
//!    block configuration worse than the uncorrected one — so calibrated
//!    total block MSE is ≤ uncalibrated by construction.
//! 4. **Persist / apply** — the learned
//!    [`crate::quant::CorrectionSet`] round-trips through `.abqw` packs
//!    and `manifest.json` `corrections` entries
//!    ([`crate::runtime::artifacts`]) and is applied at
//!    `LinearBackend::prepare` time (`EngineBuilder::correction`,
//!    `abq-llm calibrate`). Identity-initialized corrections are
//!    bit-exact no-ops (`rust/tests/prop_calib.rs`).
//!
//! See `docs/CALIBRATION.md` for the objective, the optimizer schedule,
//! the artifact format, and a CLI walkthrough.

pub mod optimize;
pub mod synthetic;

use anyhow::{Context, Result};

use crate::engine::Fp32Backend;
use crate::eval::corpus;
use crate::model::{BlockTap, ForwardScratch, KvCache, ModelConfig, Transformer, WeightPack};
use crate::model::LINEAR_NAMES;
use crate::quant::{Correction, CorrectionSet, WAConfig};
use crate::util::rng::SplitMix;

use optimize::{block_forward, BlockWeights, RefLinear};

/// Calibration hyper-parameters. The defaults calibrate the tiny model
/// in seconds; everything is deterministic given `seed`.
#[derive(Clone, Copy, Debug)]
pub struct CalibOptions {
    /// calibration sequences drawn from the synthetic corpus
    pub seqs: usize,
    /// tokens per calibration sequence
    pub seq_len: usize,
    /// corpus + subsample seed (the only RNG the pipeline uses)
    pub seed: u64,
    /// weight of the attention-consistency term in the DLC objective
    pub lambda_attn: f64,
    /// per-channel refinement budget per projection (stage 3)
    pub refine_channels: usize,
    /// row cap for candidate scoring (full data is used for reports)
    pub max_eval_rows: usize,
    /// block-level coordinate sweeps over the 7 projections
    pub rounds: usize,
}

impl Default for CalibOptions {
    fn default() -> Self {
        CalibOptions {
            seqs: 8,
            seq_len: 32,
            seed: 0xCA11B,
            lambda_attn: 1.0,
            refine_channels: 16,
            max_eval_rows: 64,
            rounds: 2,
        }
    }
}

/// Per-projection outcome inside one block.
#[derive(Clone, Debug)]
pub struct ProjReport {
    pub name: &'static str,
    /// reconstruction MSE of the plain RTN projection on the tap data
    pub mse_identity: f64,
    /// reconstruction MSE of the learned correction
    pub mse_learned: f64,
    /// whether the block-level sweep kept the learned correction
    pub accepted: bool,
}

/// Per-block outcome: the DLC objective and its components, before
/// (identity) and after (calibrated) correction.
#[derive(Clone, Debug)]
pub struct BlockReport {
    pub block: usize,
    /// block-output MSE, uncorrected / corrected
    pub mse_identity: f64,
    pub mse_calibrated: f64,
    /// attention-logit MSE, uncorrected / corrected
    pub attn_identity: f64,
    pub attn_calibrated: f64,
    /// full objective `mse + λ·attn`, uncorrected / corrected
    pub obj_identity: f64,
    pub obj_calibrated: f64,
    pub projections: Vec<ProjReport>,
}

/// The calibration output: learned corrections plus the per-block
/// before/after evidence.
#[derive(Clone, Debug)]
pub struct CalibrationResult {
    pub set: CorrectionSet,
    pub blocks: Vec<BlockReport>,
}

impl CalibrationResult {
    /// Summed block-output MSE before correction.
    pub fn total_mse_identity(&self) -> f64 {
        self.blocks.iter().map(|b| b.mse_identity).sum()
    }

    /// Summed block-output MSE after correction (≤ identity by
    /// construction; strictly lower whenever any block improved).
    pub fn total_mse_calibrated(&self) -> f64 {
        self.blocks.iter().map(|b| b.mse_calibrated).sum()
    }

    /// Human-readable per-block table (the `calibrate` CLI report).
    pub fn report_text(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{:<6} {:>12} {:>12} {:>12} {:>12}  accepted",
            "block", "mse(id)", "mse(cal)", "attn(id)", "attn(cal)"
        );
        for b in &self.blocks {
            let acc: Vec<&str> = b
                .projections
                .iter()
                .filter(|p| p.accepted)
                .map(|p| p.name)
                .collect();
            let _ = writeln!(
                out,
                "{:<6} {:>12.6e} {:>12.6e} {:>12.6e} {:>12.6e}  [{}]",
                b.block,
                b.mse_identity,
                b.mse_calibrated,
                b.attn_identity,
                b.attn_calibrated,
                acc.join(" ")
            );
        }
        let _ = writeln!(
            out,
            "total block-output MSE: {:.6e} -> {:.6e}",
            self.total_mse_identity(),
            self.total_mse_calibrated()
        );
        out
    }
}

/// Calibration corpus for a model: the deterministic synthetic stream,
/// folded into the model's vocabulary.
pub fn calibration_tokens(vocab: usize, n_tokens: usize, seed: u64) -> Vec<u32> {
    let table = corpus::build_transition_table(corpus::TABLE_SEED);
    corpus::generate_tokens(&table, n_tokens, seed)
        .into_iter()
        .map(|t| t % vocab as u32)
        .collect()
}

/// Run the full DLC pipeline for one WqAp config against the fp32 model
/// in `pack` (see module docs). Deterministic: same pack + config +
/// options → identical corrections.
pub fn calibrate(
    pack: &WeightPack,
    cfg: &ModelConfig,
    wa: WAConfig,
    opts: &CalibOptions,
) -> Result<CalibrationResult> {
    let fp = Transformer::from_pack(pack, *cfg, &Fp32Backend)
        .context("calibration needs the fp32 weights in the pack")?;
    if opts.seq_len + 1 > cfg.max_seq {
        anyhow::bail!(
            "calibration seq_len {} exceeds max_seq {}",
            opts.seq_len,
            cfg.max_seq
        );
    }

    // ---- 1. tap the fp32 model over the calibration corpus -----------
    let tokens = calibration_tokens(cfg.vocab, opts.seqs * opts.seq_len, opts.seed);
    let mut taps: Vec<BlockTap> = Vec::with_capacity(opts.seqs);
    let mut scratch = ForwardScratch::new();
    for q in 0..opts.seqs {
        let seq = &tokens[q * opts.seq_len..(q + 1) * opts.seq_len];
        let mut cache = KvCache::new(cfg);
        let mut tap = BlockTap::new();
        fp.prefill_traced(seq, &mut cache, &mut scratch, &mut tap)?;
        taps.push(tap);
    }

    // ---- 2./3. learn + select, block by block -------------------------
    let mut set = CorrectionSet::new(wa.tag());
    let mut blocks = Vec::with_capacity(cfg.n_layers);
    for li in 0..cfg.n_layers {
        let bw = block_weights(pack, li)?;
        let mut rng = SplitMix::new(opts.seed ^ (0x9E37 + li as u64));

        // per-projection local descent on the tapped activations
        let mut learned: Vec<optimize::LearnedProjection> = Vec::with_capacity(7);
        for (pi, &name) in LINEAR_NAMES.iter().enumerate() {
            let (ref w, out_f, in_f) = bw.linears[pi];
            let xs: Vec<f32> = taps
                .iter()
                .flat_map(|t| t.blocks[li].proj_input(name).iter().copied())
                .collect();
            let rows = xs.len() / in_f;
            learned.push(optimize::learn_projection(
                w,
                out_f,
                in_f,
                wa,
                &xs,
                rows,
                opts.max_eval_rows,
                opts.refine_channels,
                &mut rng,
            ));
        }

        // block-level coordinate sweep over {identity, learned} per
        // projection, scored by the DLC objective
        let id_ops: Vec<RefLinear> = (0..7)
            .map(|pi| {
                let (ref w, out_f, in_f) = bw.linears[pi];
                RefLinear::new(w, out_f, in_f, wa, &Correction::identity(in_f))
            })
            .collect();
        let ln_ops: Vec<RefLinear> = (0..7)
            .map(|pi| {
                let (ref w, out_f, in_f) = bw.linears[pi];
                RefLinear::new(w, out_f, in_f, wa, &learned[pi].corr)
            })
            .collect();
        let eval = |choice: &[bool; 7]| -> (f64, f64, f64) {
            block_objective(cfg, &bw, &id_ops, &ln_ops, choice, &taps, li, opts.lambda_attn)
        };
        let all_id = [false; 7];
        let (id_mse, id_attn, id_obj) = eval(&all_id);
        let mut choice = [true; 7];
        let (mut mse, mut attn, mut obj) = eval(&choice);
        for _ in 0..opts.rounds {
            let mut changed = false;
            for pi in 0..7 {
                let mut cand = choice;
                cand[pi] = !cand[pi];
                let (m, a, o) = eval(&cand);
                if o < obj {
                    choice = cand;
                    mse = m;
                    attn = a;
                    obj = o;
                    changed = true;
                }
            }
            if !changed {
                break;
            }
        }
        // never ship a block worse than the uncorrected one
        if obj > id_obj {
            choice = all_id;
            mse = id_mse;
            attn = id_attn;
            obj = id_obj;
        }

        let mut projections = Vec::with_capacity(7);
        for (pi, &name) in LINEAR_NAMES.iter().enumerate() {
            let accepted = choice[pi] && !learned[pi].corr.is_identity();
            let (_, _, in_f) = bw.linears[pi];
            set.insert(
                li,
                name,
                if accepted { learned[pi].corr.clone() } else { Correction::identity(in_f) },
            );
            projections.push(ProjReport {
                name,
                mse_identity: learned[pi].mse_identity,
                mse_learned: learned[pi].mse_learned,
                accepted,
            });
        }
        blocks.push(BlockReport {
            block: li,
            mse_identity: id_mse,
            mse_calibrated: mse,
            attn_identity: id_attn,
            attn_calibrated: attn,
            obj_identity: id_obj,
            obj_calibrated: obj,
            projections,
        });
    }
    Ok(CalibrationResult { set, blocks })
}

/// Collect one block's fp32 weights from the pack (shared with the
/// precision module's sensitivity search, which scores the same blocks
/// at many candidate bit widths).
pub(crate) fn block_weights(pack: &WeightPack, li: usize) -> Result<BlockWeights> {
    let mut linears = Vec::with_capacity(7);
    for name in LINEAR_NAMES {
        let t = pack.get(&format!("blocks.{li}.{name}"))?;
        let shape = t.shape();
        anyhow::ensure!(shape.len() == 2, "linear {name} must be 2-D");
        linears.push((t.as_f32()?.to_vec(), shape[0], shape[1]));
    }
    Ok(BlockWeights {
        ln1: pack.f32(&format!("blocks.{li}.ln1"))?,
        ln2: pack.f32(&format!("blocks.{li}.ln2"))?,
        linears,
    })
}

/// DLC objective of one block under a per-projection correction choice:
/// `(block-output MSE, attention-logit MSE, mse + λ·attn)`, averaged
/// over the tapped sequences.
#[allow(clippy::too_many_arguments)]
fn block_objective(
    cfg: &ModelConfig,
    bw: &BlockWeights,
    id_ops: &[RefLinear],
    ln_ops: &[RefLinear],
    choice: &[bool; 7],
    taps: &[BlockTap],
    li: usize,
    lambda: f64,
) -> (f64, f64, f64) {
    let ops: [&RefLinear; 7] = std::array::from_fn(|pi| {
        if choice[pi] {
            &ln_ops[pi]
        } else {
            &id_ops[pi]
        }
    });
    let (mut mse_sum, mut attn_sum) = (0f64, 0f64);
    for tap in taps {
        let tr = &tap.blocks[li];
        let t_len = tap.tokens;
        let (out, attn) = block_forward(cfg, bw, &ops, &tr.input, t_len);
        mse_sum += mse64(&out, &tr.output);
        // only the causal lower triangle carries signal; both runs keep
        // the upper triangle zero so a full-buffer MSE would dilute it
        let tri = (cfg.n_heads * t_len * (t_len + 1) / 2) as f64;
        let sq: f64 = attn
            .iter()
            .zip(&tr.attn_logits)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum();
        attn_sum += sq / tri;
    }
    let n = taps.len().max(1) as f64;
    let (m, a) = (mse_sum / n, attn_sum / n);
    (m, a, m + lambda * a)
}

fn mse64(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| ((x - y) as f64).powi(2)).sum::<f64>() / a.len() as f64
}
