//! The DLC optimizer: deterministic coordinate descent over a
//! projection's correction parameters (weight clip ratio → balance-scale
//! migration strength → shift fraction → per-channel refinement), scored
//! by exact quantized-reconstruction MSE against the fp32 teacher
//! output, followed by a block-level coordinate sweep that accepts each
//! projection's learned correction only if it lowers the paper's DLC
//! objective: block-output MSE plus the attention-consistency term
//! (`docs/CALIBRATION.md`).
//!
//! Candidate scoring runs on [`RefLinear`], a scalar reference of the
//! engine's quantized linear that is **numerically identical** to
//! [`crate::abq::QuantizedLinear`] (same quantizers, same i64
//! accumulation, same dequant epilogue, same correction algebra; parity
//! is unit-tested below) but skips bit-plane packing and the kernel
//! layout search, so the optimizer can afford hundreds of candidate
//! evaluations per projection.

use crate::model::transformer::{act_gate, apply_rope, norm_into, rope_tables, softmax_inplace};
use crate::model::ModelConfig;
use crate::quant::{
    correction_output_offset, quantize_act_per_token, quantize_weight_rows, smooth_scales,
    Correction, QuantSpec, WAConfig,
};
use crate::util::rng::SplitMix;

/// Scalar reference of the corrected quantized linear (see module docs).
pub(crate) struct RefLinear {
    codes: Vec<u8>,
    zw: Vec<i32>,
    dw: Vec<f32>,
    scale: Vec<f32>,
    shift: Vec<f32>,
    offset: Vec<f32>,
    act_spec: QuantSpec,
    out_f: usize,
    in_f: usize,
    identity: bool,
}

impl RefLinear {
    pub fn new(w: &[f32], out_f: usize, in_f: usize, wa: WAConfig, corr: &Correction) -> Self {
        assert_eq!(w.len(), out_f * in_f);
        assert_eq!(corr.in_features(), in_f);
        let identity = corr.is_identity();
        let wq = if identity {
            quantize_weight_rows(w, out_f, in_f, &wa.weight, 1.0, 1.0)
        } else {
            let mut scaled = w.to_vec();
            crate::quant::apply_balance_weight(&mut scaled, in_f, &corr.scale);
            quantize_weight_rows(&scaled, out_f, in_f, &wa.weight, corr.clip, corr.clip)
        };
        let offset = if identity {
            vec![0.0; out_f]
        } else {
            correction_output_offset(w, out_f, in_f, &corr.shift)
        };
        RefLinear {
            zw: wq.zps(),
            dw: wq.deltas(),
            codes: wq.codes,
            scale: corr.scale.clone(),
            shift: corr.shift.clone(),
            offset,
            act_spec: QuantSpec::new(wa.act.bits),
            out_f,
            in_f,
            identity,
        }
    }

    /// `out[rows, out_f] = Q(x)·Q(W)ᵀ + offset` — the same numbers the
    /// engine's bit-plane path produces for this correction.
    pub fn forward(&self, x: &[f32], rows: usize, out: &mut [f32]) {
        assert_eq!(x.len(), rows * self.in_f);
        assert_eq!(out.len(), rows * self.out_f);
        let corrected: Vec<f32> = if self.identity {
            x.to_vec()
        } else {
            let mut xc = x.to_vec();
            crate::quant::apply_correction_act(&mut xc, self.in_f, &self.scale, &self.shift);
            xc
        };
        let xq = quantize_act_per_token(&corrected, rows, self.in_f, &self.act_spec);
        for r in 0..rows {
            let (zx, dx) = (xq.params[r].zp as i64, xq.params[r].delta);
            let xrow = &xq.codes[r * self.in_f..(r + 1) * self.in_f];
            for o in 0..self.out_f {
                let zw = self.zw[o] as i64;
                let wrow = &self.codes[o * self.in_f..(o + 1) * self.in_f];
                let mut acc = 0i64;
                for i in 0..self.in_f {
                    acc += (xrow[i] as i64 - zx) * (wrow[i] as i64 - zw);
                }
                out[r * self.out_f + o] = acc as f32 * dx * self.dw[o] + self.offset[o];
            }
        }
    }

    fn forward_alloc(&self, x: &[f32], rows: usize) -> Vec<f32> {
        let mut out = vec![0.0; rows * self.out_f];
        self.forward(x, rows, &mut out);
        out
    }
}

fn mse(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| ((x - y) as f64).powi(2)).sum::<f64>() / a.len() as f64
}

/// Per-channel column statistics of `x` `[rows, cols]`.
fn column_stats(x: &[f32], rows: usize, cols: usize) -> (Vec<f32>, Vec<f32>) {
    let mut absmax = vec![0f32; cols];
    let mut mean = vec![0f32; cols];
    for r in 0..rows {
        for c in 0..cols {
            let v = x[r * cols + c];
            absmax[c] = absmax[c].max(v.abs());
            mean[c] += v;
        }
    }
    for m in mean.iter_mut() {
        *m /= rows.max(1) as f32;
    }
    (absmax, mean)
}

fn w_col_absmax(w: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    let mut absmax = vec![0f32; cols];
    for r in 0..rows {
        for c in 0..cols {
            absmax[c] = absmax[c].max(w[r * cols + c].abs());
        }
    }
    absmax
}

/// Outcome of one projection's local descent.
pub(crate) struct LearnedProjection {
    pub corr: Correction,
    /// full-data reconstruction MSE of the identity (plain RTN) op
    pub mse_identity: f64,
    /// full-data reconstruction MSE of the learned correction
    pub mse_learned: f64,
}

/// Deterministic coordinate descent for one projection (see module docs
/// for the schedule). `xs` are the fp32 input activations captured by
/// the block tap, `[rows, in_f]`; the teacher is `xs · Wᵀ` computed in
/// fp32. The only RNG use is the seeded row subsample for candidate
/// scoring; the schedule itself is deterministic.
#[allow(clippy::too_many_arguments)]
pub(crate) fn learn_projection(
    w: &[f32],
    out_f: usize,
    in_f: usize,
    wa: WAConfig,
    xs: &[f32],
    rows: usize,
    max_eval_rows: usize,
    refine_channels: usize,
    rng: &mut SplitMix,
) -> LearnedProjection {
    // -- teacher + seeded row subsample for candidate scoring ----------
    let teacher = {
        let mut t = vec![0.0; rows * out_f];
        crate::baselines::gemm_fp32_into(xs, w, rows, out_f, in_f, &mut t);
        t
    };
    let eval_rows = rows.min(max_eval_rows.max(1));
    let picked: Vec<usize> = if eval_rows == rows {
        (0..rows).collect()
    } else {
        let mut idx: Vec<usize> = (0..rows).collect();
        for i in 0..eval_rows {
            let j = i + rng.next_below((rows - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(eval_rows);
        idx.sort_unstable();
        idx
    };
    let sub_x: Vec<f32> = picked
        .iter()
        .flat_map(|&r| xs[r * in_f..(r + 1) * in_f].iter().copied())
        .collect();
    let sub_t: Vec<f32> = picked
        .iter()
        .flat_map(|&r| teacher[r * out_f..(r + 1) * out_f].iter().copied())
        .collect();
    let score = |corr: &Correction| -> f64 {
        let lin = RefLinear::new(w, out_f, in_f, wa, corr);
        mse(&lin.forward_alloc(&sub_x, eval_rows), &sub_t)
    };

    let (act_absmax, act_mean) = column_stats(xs, rows, in_f);
    let w_absmax = w_col_absmax(w, out_f, in_f);

    let mut best = Correction::identity(in_f);
    let mut best_score = score(&best);

    // -- stage 0: weight clip ratio ------------------------------------
    for clip in [0.9f32, 0.8, 0.7, 0.6, 0.5] {
        let cand = Correction { clip, ..best.clone() };
        let sc = score(&cand);
        if sc < best_score {
            best = cand;
            best_score = sc;
        }
    }
    // -- stage 1: balance-scale migration strength ---------------------
    for m in [0.25f32, 0.5, 0.75, 1.0] {
        let cand = Correction {
            scale: smooth_scales(&act_absmax, &w_absmax, m),
            ..best.clone()
        };
        let sc = score(&cand);
        if sc < best_score {
            best = cand;
            best_score = sc;
        }
    }
    // -- stage 2: shift fraction toward the channel mean ---------------
    for f in [0.5f32, 1.0] {
        let cand = Correction {
            shift: act_mean.iter().map(|m| m * f).collect(),
            ..best.clone()
        };
        let sc = score(&cand);
        if sc < best_score {
            best = cand;
            best_score = sc;
        }
    }
    // -- stage 3: per-channel refinement on the heaviest channels ------
    let mut order: Vec<usize> = (0..in_f).collect();
    order.sort_by(|&a, &b| {
        let ka = act_absmax[a] * w_absmax[a];
        let kb = act_absmax[b] * w_absmax[b];
        kb.partial_cmp(&ka).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    });
    for &j in order.iter().take(refine_channels.min(in_f)) {
        for f in [0.5f32, 0.8, 1.25, 2.0] {
            let mut cand = best.clone();
            cand.scale[j] = (cand.scale[j] * f).max(1e-5);
            let sc = score(&cand);
            if sc < best_score {
                best = cand;
                best_score = sc;
            }
        }
        for z in [0.0f32, act_mean[j]] {
            if best.shift[j] == z {
                continue;
            }
            let mut cand = best.clone();
            cand.shift[j] = z;
            let sc = score(&cand);
            if sc < best_score {
                best = cand;
                best_score = sc;
            }
        }
    }

    // -- full-data report numbers --------------------------------------
    let ident = RefLinear::new(w, out_f, in_f, wa, &Correction::identity(in_f));
    let mse_identity = mse(&ident.forward_alloc(xs, rows), &teacher);
    let learned = RefLinear::new(w, out_f, in_f, wa, &best);
    let mse_learned = mse(&learned.forward_alloc(xs, rows), &teacher);
    LearnedProjection { corr: best, mse_identity, mse_learned }
}

/// Float weights + norms of one block, in [`LINEAR_NAMES`] order
/// (`wq, wk, wv, wo, gate, up, down`).
pub(crate) struct BlockWeights {
    pub ln1: Vec<f32>,
    pub ln2: Vec<f32>,
    /// `(w, out_features, in_features)` per projection
    pub linears: Vec<(Vec<f32>, usize, usize)>,
}

/// One quantized block forward from a tapped fp32 block input, mirroring
/// `Transformer::prefill` numerics (fresh sequence, positions `0..T`).
/// Returns the block output `[T, d]` and pre-softmax attention logits
/// `[H, T, T]` (zero above the causal diagonal).
pub(crate) fn block_forward(
    cfg: &ModelConfig,
    bw: &BlockWeights,
    ops: &[&RefLinear; 7],
    x_in: &[f32],
    t_len: usize,
) -> (Vec<f32>, Vec<f32>) {
    let (d, hd, nh) = (cfg.d_model, cfg.head_dim(), cfg.n_heads);
    let (kd, group) = (cfg.kv_dim(), cfg.group_size());
    let norm = cfg.arch.norm;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut x = x_in.to_vec();
    let mut h = vec![0.0; t_len * d];
    norm_into(norm, &x, &bw.ln1, &mut h);
    let [wq, wk, wv, wo, gate, up, down] = *ops;
    let mut q = wq.forward_alloc(&h, t_len);
    let mut k = wk.forward_alloc(&h, t_len);
    let v = wv.forward_alloc(&h, t_len);
    let (cos, sin) = rope_tables(cfg, 0, t_len);
    apply_rope(&mut q, cfg, &cos, &sin, t_len, nh);
    apply_rope(&mut k, cfg, &cos, &sin, t_len, cfg.n_kv_heads);
    let mut attn_logits = vec![0.0; nh * t_len * t_len];
    let mut ctx = vec![0.0; t_len * d];
    let mut scores = vec![0.0; t_len];
    for t in 0..t_len {
        let keys = t + 1;
        for hh in 0..nh {
            // same head-group broadcast as the transformer's attention
            let kvh = hh / group;
            let qv = &q[t * d + hh * hd..t * d + (hh + 1) * hd];
            let srow = &mut scores[..keys];
            for (kp, sc) in srow.iter_mut().enumerate() {
                let kv = &k[kp * kd + kvh * hd..kp * kd + (kvh + 1) * hd];
                *sc = qv.iter().zip(kv).map(|(a, b)| a * b).sum::<f32>() * scale;
            }
            let base = (hh * t_len + t) * t_len;
            attn_logits[base..base + keys].copy_from_slice(srow);
            softmax_inplace(srow);
            let crow = &mut ctx[t * d + hh * hd..t * d + (hh + 1) * hd];
            for (kp, &a) in srow.iter().enumerate() {
                let vv = &v[kp * kd + kvh * hd..kp * kd + (kvh + 1) * hd];
                for i in 0..hd {
                    crow[i] += a * vv[i];
                }
            }
        }
    }
    let proj = wo.forward_alloc(&ctx, t_len);
    for i in 0..x.len() {
        x[i] += proj[i];
    }
    norm_into(norm, &x, &bw.ln2, &mut h);
    let g = gate.forward_alloc(&h, t_len);
    let u = up.forward_alloc(&h, t_len);
    let mut act = vec![0.0; t_len * cfg.d_ff];
    for i in 0..act.len() {
        act[i] = act_gate(cfg.arch.act, g[i]) * u[i];
    }
    let dn = down.forward_alloc(&act, t_len);
    for i in 0..x.len() {
        x[i] += dn[i];
    }
    (x, attn_logits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::abq::{OptLevel, QuantizedLinear};

    fn data(n: usize, seed: u64) -> Vec<f32> {
        let mut r = SplitMix::new(seed);
        (0..n).map(|_| r.next_f32_centered() * 2.0).collect()
    }

    #[test]
    fn ref_linear_matches_engine_bitwise() {
        // the optimizer's scoring path and the served engine must agree
        // exactly — otherwise learned corrections would optimize a proxy
        for (cfg_str, corr_kind) in [
            ("w2*a8", 0usize),
            ("w4a4", 1),
            ("w8a8", 2),
        ] {
            let wa: WAConfig = cfg_str.parse().unwrap();
            let (out_f, in_f, rows) = (10usize, 24usize, 5usize);
            let w = data(out_f * in_f, 3);
            let x = data(rows * in_f, 4);
            let corr = match corr_kind {
                0 => Correction::identity(in_f),
                1 => Correction {
                    scale: (0..in_f).map(|i| 0.5 + (i % 5) as f32 / 4.0).collect(),
                    shift: vec![0.0; in_f],
                    clip: 0.8,
                },
                _ => Correction {
                    scale: (0..in_f).map(|i| 0.75 + (i % 3) as f32 / 4.0).collect(),
                    shift: (0..in_f).map(|i| ((i % 7) as f32 - 3.0) / 20.0).collect(),
                    clip: 0.9,
                },
            };
            let reference = RefLinear::new(&w, out_f, in_f, wa, &corr);
            let engine = QuantizedLinear::from_weights_corrected(&w, out_f, in_f, wa, &corr);
            let want = engine.forward(&x, rows, OptLevel::Auto);
            let got = reference.forward_alloc(&x, rows);
            for (a, b) in want.iter().zip(&got) {
                assert_eq!(a.to_bits(), b.to_bits(), "{cfg_str} corr {corr_kind}");
            }
        }
    }

    #[test]
    fn learn_projection_never_worsens_reconstruction() {
        let wa: WAConfig = "w2*a8".parse().unwrap();
        let (out_f, in_f, rows) = (12usize, 16usize, 40usize);
        let w = data(out_f * in_f, 11);
        // activations with per-channel spread + offset so scale and shift
        // both have something to learn
        let mut x = data(rows * in_f, 12);
        for r in 0..rows {
            for c in 0..in_f {
                x[r * in_f + c] = x[r * in_f + c] * (1.0 + c as f32 / 4.0) + c as f32 / 8.0;
            }
        }
        let mut rng = SplitMix::new(99);
        let lp = learn_projection(&w, out_f, in_f, wa, &x, rows, 32, 8, &mut rng);
        assert!(lp.mse_learned <= lp.mse_identity, "{} > {}", lp.mse_learned, lp.mse_identity);
        // at w2* on skewed channels the descent must find real gains
        assert!(
            lp.mse_learned < lp.mse_identity * 0.95,
            "no measurable gain: {} vs {}",
            lp.mse_learned,
            lp.mse_identity
        );
        assert!(!lp.corr.is_identity());
        // determinism: same inputs + seed → identical corrections
        let mut rng2 = SplitMix::new(99);
        let lp2 = learn_projection(&w, out_f, in_f, wa, &x, rows, 32, 8, &mut rng2);
        assert_eq!(lp.corr, lp2.corr);
    }
}
