//! A deterministic "trained-looking" synthetic model for differential
//! calibration tests. No checkpoint ships with the repo, and a purely
//! random transformer scores near-uniform NLL on any corpus — useless
//! for asserting that calibration *helps* end-to-end. This constructor
//! builds a model that is genuinely predictive on the synthetic corpus
//! by design:
//!
//! * one-hot token embeddings (`d_model == vocab`), so the residual
//!   stream carries the current token as its dominant direction;
//! * transformer blocks with small random weights — a perturbation the
//!   quantizers then damage (the quantity calibration protects);
//! * an unembedding whose rows encode the corpus' smoothed bigram
//!   log-probabilities, scaled so the final RMSNorm maps the one-hot
//!   component onto `logits ≈ log P̂(next | current)`.
//!
//! The fp32 model therefore sits well below the uniform bound, coarse
//! uncalibrated quantization measurably hurts NLL, and a correction
//! that tracks fp32 better recovers it — giving `prop_calib.rs` a
//! deterministic, assertable before/after gap. Embeddings and head stay
//! fp32 at inference (paper convention), matching this construction.

use anyhow::Result;

use crate::engine::InferenceEngine;
use crate::eval::sequence_nll;
use crate::model::{ModelConfig, Tensor, WeightPack};
use crate::util::rng::SplitMix;

use super::calibration_tokens;

/// Synthetic-model handle: the weight pack plus its config.
pub struct SyntheticModel {
    pub pack: WeightPack,
    pub cfg: ModelConfig,
}

/// Build the corpus-aligned synthetic model (see module docs).
/// Deterministic in `(vocab, n_layers, seed)`. `vocab` must be even
/// (`d_model == vocab` and heads split it in two).
pub fn synthetic_trained(vocab: usize, n_layers: usize, seed: u64) -> SyntheticModel {
    assert!(vocab >= 8 && vocab % 4 == 0, "vocab must be >= 8 and divisible by 4");
    let d = vocab;
    let cfg = ModelConfig {
        name: "synthetic",
        vocab,
        d_model: d,
        n_layers,
        n_heads: 2,
        n_kv_heads: 2,
        d_ff: 2 * d,
        max_seq: 64,
        rope_base: 10000.0,
        arch: crate::model::ArchVariant::LLAMA,
    };
    let mut rng = SplitMix::new(seed);
    let mut pack = WeightPack::default();
    let mut put = |pack: &mut WeightPack, name: String, v: Vec<f32>, shape: Vec<usize>| {
        pack.tensors.insert(name, Tensor::F32(v, shape));
    };

    // one-hot embeddings: token t → e_t
    let mut tok_emb = vec![0f32; vocab * d];
    for t in 0..vocab {
        tok_emb[t * d + t] = 1.0;
    }
    put(&mut pack, "tok_emb".into(), tok_emb, vec![vocab, d]);

    // smoothed bigram log-probabilities from a long corpus sample
    let stream = calibration_tokens(vocab, 20_000, seed ^ 0xB16A);
    let mut counts = vec![0.5f64; vocab * vocab]; // add-1/2 smoothing
    for w in stream.windows(2) {
        counts[w[0] as usize * vocab + w[1] as usize] += 1.0;
    }
    // head[u][t] = log P̂(u | t) / sqrt(d): the final RMSNorm maps the
    // one-hot residual component to ~sqrt(d), so logits ≈ log P̂
    let root_d = (d as f64).sqrt();
    let mut head = vec![0f32; vocab * d];
    for t in 0..vocab {
        let total: f64 = (0..vocab).map(|u| counts[t * vocab + u]).sum();
        for u in 0..vocab {
            head[u * d + t] = ((counts[t * vocab + u] / total).ln() / root_d) as f32;
        }
    }
    put(&mut pack, "head".into(), head, vec![vocab, d]);
    put(&mut pack, "ln_f".into(), vec![1.0; d], vec![d]);

    // blocks: small random weights — the quantization-sensitive part
    const BLOCK_SCALE: f32 = 0.3;
    for li in 0..n_layers {
        put(&mut pack, format!("blocks.{li}.ln1"), vec![1.0; d], vec![d]);
        put(&mut pack, format!("blocks.{li}.ln2"), vec![1.0; d], vec![d]);
        let mut dense = |rng: &mut SplitMix, out_f: usize, in_f: usize| -> Vec<f32> {
            let scale = BLOCK_SCALE / (in_f as f32).sqrt();
            (0..out_f * in_f).map(|_| rng.next_f32_centered() * 2.0 * scale).collect()
        };
        for (name, out_f, in_f) in [
            ("wq", d, d),
            ("wk", d, d),
            ("wv", d, d),
            ("wo", d, d),
            ("gate", cfg.d_ff, d),
            ("up", cfg.d_ff, d),
            ("down", d, cfg.d_ff),
        ] {
            let w = dense(&mut rng, out_f, in_f);
            put(&mut pack, format!("blocks.{li}.{name}"), w, vec![out_f, in_f]);
        }
    }
    SyntheticModel { pack, cfg }
}

/// Mean per-token NLL of an engine on held-out synthetic-corpus
/// sequences (tokens folded into the engine's vocab). Deterministic in
/// `(seqs, seq_len, seed)`; `exp()` of it is the perplexity the
/// differential tests compare.
pub fn eval_nll(
    engine: &dyn InferenceEngine,
    seqs: usize,
    seq_len: usize,
    seed: u64,
) -> Result<f64> {
    let vocab = engine.spec().model.vocab;
    let tokens = calibration_tokens(vocab, seqs * (seq_len + 1), seed);
    let mut total = 0f64;
    for q in 0..seqs {
        let seq = &tokens[q * (seq_len + 1)..(q + 1) * (seq_len + 1)];
        total += sequence_nll(engine, seq)?;
    }
    Ok(total / seqs as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{Fp32Backend, NativeEngine};
    use crate::model::Transformer;

    #[test]
    fn synthetic_model_is_predictive() {
        let sm = synthetic_trained(32, 2, 5);
        let model = Transformer::from_pack(&sm.pack, sm.cfg, &Fp32Backend).unwrap();
        let engine = NativeEngine::new(model);
        let nll = eval_nll(&engine, 6, 24, 4242).unwrap();
        let uniform = (32f64).ln();
        assert!(
            nll < uniform - 0.3,
            "synthetic model must beat uniform by a margin: nll {nll} vs uniform {uniform}"
        );
    }

    #[test]
    fn synthetic_model_is_deterministic() {
        let a = synthetic_trained(16, 1, 9);
        let b = synthetic_trained(16, 1, 9);
        assert_eq!(
            a.pack.get("blocks.0.wq").unwrap(),
            b.pack.get("blocks.0.wq").unwrap()
        );
        assert_eq!(a.pack.get("head").unwrap(), b.pack.get("head").unwrap());
    }
}
