//! # ABQ-LLM — Arbitrary-Bit Quantized LLM Inference (reproduction)
//!
//! Rust + JAX + Pallas three-layer reproduction of *ABQ-LLM: Arbitrary-Bit
//! Quantized Inference Acceleration for Large Language Models* (AAAI 2025).
//!
//! * [`abq`] — the arbitrary-bit engine: every WqAp GEMM decomposed into
//!   p×q 1-bit matmuls (BMMA ≙ AND+POPCNT) with Bit Reduction, GEMV
//!   elimination, pipelining and auto kernel search (paper §3.4, App. B/D)
//! * [`quant`] — quantizers, bit-balance strategy, balance vectors
//! * [`baselines`] — FP16/W8A8/W4A4 comparator engines with MMA padding
//! * [`model`] — LLaMA-family transformer on pluggable GEMM backends
//! * [`coordinator`] — serving: router, dynamic batcher, scheduler, KV cache
//! * [`runtime`] — PJRT executor for the AOT HLO artifacts (jax/pallas L2+L1)
//! * [`eval`] — synthetic corpus, perplexity, zero-shot harness
//! * [`util`] — offline substrates (thread pool, JSON, CLI, bench, proptest)
pub mod abq;
pub mod baselines;
pub mod coordinator;
pub mod eval;
pub mod model;
pub mod quant;
pub mod runtime;
pub mod util;
