//! # ABQ-LLM — Arbitrary-Bit Quantized LLM Inference (reproduction)
//!
//! Rust + JAX + Pallas three-layer reproduction of *ABQ-LLM: Arbitrary-Bit
//! Quantized Inference Acceleration for Large Language Models* (AAAI 2025).
//!
//! ## The unified engine API
//!
//! Everything is constructed through [`engine::EngineBuilder`] and consumed
//! through the object-safe [`engine::InferenceEngine`] trait — the serving
//! coordinator, the eval harnesses and the benches never touch a concrete
//! model type:
//!
//! ```no_run
//! use abq_llm::engine::{EngineBuilder, InferenceEngine, OptLevel};
//!
//! # fn main() -> anyhow::Result<()> {
//! let engine = EngineBuilder::new()
//!     .weights("artifacts")        // `make artifacts`
//!     .backend("abq:w2*a8")        // or "fp32" / "int8" / "int4" / any WqAp
//!     .opt_level(OptLevel::Auto)   // Table-4 kernel ladder position
//!     .threads(8)
//!     .build()?;
//! let mut session = engine.new_session()?;
//! let logits = engine.prefill(&[1, 2, 3], session.as_mut())?;
//! # let _ = logits;
//! # Ok(()) }
//! ```
//!
//! Precision backends live in a string-keyed registry
//! ([`engine::BackendRegistry`]); adding one is a single
//! `registry.register(...)` call — see `docs/ENGINE_API.md` for the
//! migration table from the old closed `Backend` enum and a worked
//! "add your own backend" example.
//!
//! ## Module map
//!
//! * [`engine`] — the unified API: `LinearBackend` registry,
//!   `InferenceEngine`/`EngineSession`, `EngineBuilder`; native and PJRT
//!   execution paths
//! * [`abq`] — the arbitrary-bit engine: every WqAp GEMM decomposed into
//!   p×q 1-bit matmuls (BMMA ≙ AND+POPCNT) with Bit Reduction, GEMV
//!   elimination, pipelining, SIMD bit-plane kernels behind runtime ISA
//!   dispatch ([`abq::isa`], [`abq::kernels`]; AVX2/AVX-512/NEON raced
//!   against scalar), and auto kernel search (paper §3.4, App. B/D)
//! * [`quant`] — quantizers, bit-balance strategy, balance vectors and
//!   learned distribution corrections ([`quant::Correction`])
//! * [`calib`] — the paper's distribution-correction (DLC) calibration:
//!   block taps, seeded coordinate-descent reconstruction against fp32
//!   block outputs + attention logits, correction persistence
//!   (`docs/CALIBRATION.md`)
//! * [`baselines`] — FP16/W8A8/W4A4 comparator engines with MMA padding
//! * [`model`] — LLaMA-family transformer over registry-prepared
//!   projections, with a paged arbitrary-bit KV block pool
//!   (`docs/SERVING.md`)
//! * [`spec`] — self-speculative decoding: low-bit draft + target-
//!   precision verify over one weight pack, lossless under greedy
//!   decoding (`docs/SPECULATIVE.md`)
//! * [`coordinator`] — serving: router, dynamic batcher, block-aware
//!   continuous-batching scheduler with preemption and per-sequence
//!   speculation
//! * [`prefix`] — prefix cache subsystem: radix token-trie over resident
//!   prefix KV + persistent `.abqs` session store, riding the pool's
//!   copy-on-write block sharing (`docs/SERVING.md` §prefix cache)
//! * [`runtime`] — artifact manifest grammar and `.abqs` session files
//!   (always available) plus the PJRT executor for the AOT HLO artifacts
//!   (jax/pallas L2+L1; the executor needs `--features pjrt`)
//! * [`eval`] — synthetic corpus, perplexity, zero-shot harness
//! * [`util`] — offline substrates (thread pool, JSON, CLI, bench, proptest)

pub mod abq;
pub mod baselines;
pub mod calib;
pub mod coordinator;
pub mod engine;
pub mod eval;
pub mod model;
pub mod precision;
pub mod prefix;
pub mod quant;
pub mod runtime;
pub mod spec;
pub mod util;

/// Compile-checks the code blocks in `docs/ENGINE_API.md` as doctests
/// (`cargo test --doc`), so the migration guide cannot drift from the
/// real API.
#[cfg(doctest)]
#[doc = include_str!("../../docs/ENGINE_API.md")]
pub struct EngineApiDocTests;
