//! BitPacking (paper §3.4 step ❶): decompose a p-bit code tensor into p
//! binary matrices laid out plane-major, `[M, K, p] → [p, M, K]`.
//!
//! On the GPU this layout change makes global-memory reads of each 1-bit
//! tile contiguous for the BMMA pipeline; here it makes each plane row a
//! dense `u64` slice so the AND+POPCNT inner loop streams sequentially —
//! the same memory-continuity argument, one level down the hierarchy.
//!
//! Two storage layouts are supported (see `docs/PERF.md`):
//!
//! * [`PlaneLayout::PlaneMajor`] — `[plane][row][kword]`, the paper's
//!   `[p, M, K]` BitPacking form. Default; all rows of one plane are
//!   contiguous.
//! * [`PlaneLayout::Interleaved`] — `[row][plane][kword]`, APT-LLM-style
//!   bit-level interleaving: the q plane-rows of one weight row are
//!   adjacent, so the per-row q-plane sweep in the GEMV-elimination kernel
//!   streams one contiguous block per output element. The auto kernel
//!   search picks this layout per weight shape when it wins.
//!
//! Packing is **word-sliced** and dispatched per row through the
//! `abq::kernels` ISA table (`cmpeq`+`movemask` on AVX2, `tst`+weighted
//! `addv` on NEON, branchless shift/mask accumulation on the portable
//! path) — no per-bit scatter, no data-dependent branches, bit-identical
//! across ISAs. Out-of-range codes are masked to `planes` bits (uniform
//! debug/release semantics; rowsums use the masked values so the
//! zero-point correction stays consistent).
//!
//! The packer also precomputes per-row code sums, which the Bit Reduction
//! epilogue needs for the zero-point correction
//! `Y -= zx·rowsum(Wq) + zw·rowsum(Xq) - K·zx·zw`.

/// Storage order of the packed planes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PlaneLayout {
    /// `[plane][row][kword]` — the paper's `[p, M, K]` BitPacking layout.
    PlaneMajor,
    /// `[row][plane][kword]` — plane-interleaved rows for contiguous
    /// per-row plane sweeps (weight-side option picked by auto search).
    Interleaved,
}

impl PlaneLayout {
    #[inline(always)]
    fn row_offset(
        self,
        plane: usize,
        row: usize,
        rows: usize,
        planes: usize,
        kwords: usize,
    ) -> usize {
        match self {
            PlaneLayout::PlaneMajor => (plane * rows + row) * kwords,
            PlaneLayout::Interleaved => (row * planes + plane) * kwords,
        }
    }
}

/// A p-bit unsigned code matrix packed as p bit-planes of `u64` words.
#[derive(Clone, Debug)]
pub struct BitPlanes {
    pub rows: usize,
    pub k: usize,
    pub planes: usize,
    pub kwords: usize,
    pub layout: PlaneLayout,
    pub data: Vec<u64>,
    /// per-row sum of the (masked) codes, for zero-point correction
    pub rowsum: Vec<i64>,
}

/// Borrowed view over packed planes — the form the GEMM kernels consume.
/// Lets the decode hot path pack activations into an arena
/// ([`crate::abq::AbqScratch`]) and run the kernels without owning a
/// [`BitPlanes`] (and hence without allocating one per call).
#[derive(Clone, Copy, Debug)]
pub struct PlanesRef<'a> {
    pub rows: usize,
    pub k: usize,
    pub planes: usize,
    pub kwords: usize,
    pub layout: PlaneLayout,
    pub data: &'a [u64],
    pub rowsum: &'a [i64],
}

impl<'a> PlanesRef<'a> {
    /// View over caller-owned storage (as filled by [`BitPlanes::pack_into`]).
    pub fn new(
        rows: usize,
        k: usize,
        planes: usize,
        layout: PlaneLayout,
        data: &'a [u64],
        rowsum: &'a [i64],
    ) -> Self {
        let kwords = k.div_ceil(64);
        debug_assert_eq!(data.len(), planes * rows * kwords);
        debug_assert_eq!(rowsum.len(), rows);
        PlanesRef { rows, k, planes, kwords, layout, data, rowsum }
    }

    /// Slice of one plane-row (the unit the BMMA loop consumes).
    #[inline(always)]
    pub fn plane_row(&self, plane: usize, row: usize) -> &'a [u64] {
        let data: &'a [u64] = self.data;
        let off = self.layout.row_offset(plane, row, self.rows, self.planes, self.kwords);
        &data[off..off + self.kwords]
    }

    /// `(row_step, plane_step)` word strides of this view: plane `s` of
    /// row `r` starts at `data[r*row_step + s*plane_step]`. This is the
    /// operand form the `abq::kernels` sweeps consume — it makes one sweep
    /// serve both storage layouts (and the staged pipeline buffer, whose
    /// `[mi][s][kw]` strides coincide with [`PlaneLayout::Interleaved`]).
    #[inline(always)]
    pub(crate) fn strides(&self) -> (usize, usize) {
        match self.layout {
            PlaneLayout::PlaneMajor => (self.kwords, self.rows * self.kwords),
            PlaneLayout::Interleaved => (self.planes * self.kwords, self.kwords),
        }
    }
}

impl BitPlanes {
    /// Pack `codes` (row-major `[rows, k]`) into plane-major planes.
    /// Codes are masked to `planes` bits.
    pub fn pack(codes: &[u8], rows: usize, k: usize, planes: usize) -> Self {
        Self::pack_with_layout(codes, rows, k, planes, PlaneLayout::PlaneMajor)
    }

    /// [`BitPlanes::pack`] with an explicit storage layout.
    pub fn pack_with_layout(
        codes: &[u8],
        rows: usize,
        k: usize,
        planes: usize,
        layout: PlaneLayout,
    ) -> Self {
        let mut data = Vec::new();
        let mut rowsum = Vec::new();
        Self::pack_into(codes, rows, k, planes, layout, &mut data, &mut rowsum);
        let kwords = k.div_ceil(64);
        BitPlanes { rows, k, planes, kwords, layout, data, rowsum }
    }

    /// Pack into caller-owned storage (`data`/`rowsum` are cleared and
    /// resized; with warm capacity this allocates nothing). The decode hot
    /// loop packs per-token activation planes into its scratch arena this
    /// way; wrap the buffers with [`PlanesRef::new`] to run the kernels.
    pub fn pack_into(
        codes: &[u8],
        rows: usize,
        k: usize,
        planes: usize,
        layout: PlaneLayout,
        data: &mut Vec<u64>,
        rowsum: &mut Vec<i64>,
    ) {
        assert_eq!(codes.len(), rows * k, "codes shape mismatch");
        assert!(planes >= 1 && planes <= 8);
        let kwords = k.div_ceil(64);
        data.clear();
        data.resize(planes * rows * kwords, 0);
        rowsum.clear();
        rowsum.resize(rows, 0);
        // per-row pack dispatched to the fastest kernel at the ISA ceiling
        // (scalar path: 64-code window masked once, then one u64 per plane
        // with branchless shift/or accumulation; SIMD paths in
        // `abq::kernels` are bit-identical) — this keeps m=1 decode SIMD
        // end to end, packing included
        let ks = super::kernels::active();
        let plane_step = match layout {
            PlaneLayout::PlaneMajor => rows * kwords,
            PlaneLayout::Interleaved => kwords,
        };
        for r in 0..rows {
            let off = layout.row_offset(0, r, rows, planes, kwords);
            rowsum[r] = ks.pack_row(&codes[r * k..(r + 1) * k], planes, data, off, plane_step);
        }
    }

    /// Re-pack into the other storage layout (block permutation of the
    /// plane-rows; contents identical). Used when the auto kernel search
    /// decides the interleaved weight layout wins for a shape.
    pub fn to_layout(&self, layout: PlaneLayout) -> BitPlanes {
        if layout == self.layout {
            return self.clone();
        }
        let mut data = vec![0u64; self.data.len()];
        for p in 0..self.planes {
            for r in 0..self.rows {
                let src = self.plane_row(p, r);
                let off = layout.row_offset(p, r, self.rows, self.planes, self.kwords);
                data[off..off + self.kwords].copy_from_slice(src);
            }
        }
        BitPlanes {
            rows: self.rows,
            k: self.k,
            planes: self.planes,
            kwords: self.kwords,
            layout,
            data,
            rowsum: self.rowsum.clone(),
        }
    }

    /// Borrowed view (the form the kernels consume).
    #[inline(always)]
    pub fn view(&self) -> PlanesRef<'_> {
        PlanesRef {
            rows: self.rows,
            k: self.k,
            planes: self.planes,
            kwords: self.kwords,
            layout: self.layout,
            data: &self.data,
            rowsum: &self.rowsum,
        }
    }

    /// Slice of one plane-row (the unit the BMMA loop consumes).
    #[inline(always)]
    pub fn plane_row(&self, plane: usize, row: usize) -> &[u64] {
        let off = self.layout.row_offset(plane, row, self.rows, self.planes, self.kwords);
        &self.data[off..off + self.kwords]
    }

    /// Contiguous block of all rows of one plane (plane-major layout only).
    #[inline(always)]
    pub fn plane(&self, plane: usize) -> &[u64] {
        assert_eq!(
            self.layout,
            PlaneLayout::PlaneMajor,
            "plane(): whole-plane slices exist only in the plane-major layout"
        );
        let off = plane * self.rows * self.kwords;
        &self.data[off..off + self.rows * self.kwords]
    }

    /// Reconstruct the original (masked) codes (test / debugging aid).
    pub fn unpack(&self) -> Vec<u8> {
        let mut out = vec![0u8; self.rows * self.k];
        for p in 0..self.planes {
            for r in 0..self.rows {
                let pr = self.plane_row(p, r);
                for i in 0..self.k {
                    if (pr[i / 64] >> (i % 64)) & 1 == 1 {
                        out[r * self.k + i] |= 1 << p;
                    }
                }
            }
        }
        out
    }

    /// Bytes of packed storage (memory-compression accounting).
    pub fn packed_bytes(&self) -> usize {
        self.data.len() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_unpack_roundtrip() {
        let codes: Vec<u8> = (0..7 * 100).map(|i| (i % 16) as u8).collect();
        let bp = BitPlanes::pack(&codes, 7, 100, 4);
        assert_eq!(bp.unpack(), codes);
    }

    #[test]
    fn rowsums() {
        let codes = vec![1u8, 2, 3, 0, 0, 7];
        let bp = BitPlanes::pack(&codes, 2, 3, 3);
        assert_eq!(bp.rowsum, vec![6, 7]);
    }

    #[test]
    fn plane_contents_single_bit() {
        // code 2 = plane 1 only
        let codes = vec![2u8; 64];
        let bp = BitPlanes::pack(&codes, 1, 64, 2);
        assert_eq!(bp.plane_row(0, 0), &[0u64]);
        assert_eq!(bp.plane_row(1, 0), &[u64::MAX]);
    }

    #[test]
    fn ragged_k_tail_is_zero_padded() {
        let codes = vec![1u8; 65];
        let bp = BitPlanes::pack(&codes, 1, 65, 1);
        assert_eq!(bp.kwords, 2);
        assert_eq!(bp.plane_row(0, 0)[1], 1u64); // only bit 0 of word 1
    }

    #[test]
    fn out_of_range_codes_are_masked_consistently() {
        // 9 = 0b1001 at 2 planes must behave exactly like 9 & 3 = 1, in
        // every build profile (release builds used to silently produce
        // planes containing the high bits).
        let dirty = vec![9u8, 7, 2, 255];
        let clean: Vec<u8> = dirty.iter().map(|c| c & 3).collect();
        let bpd = BitPlanes::pack(&dirty, 1, 4, 2);
        let bpc = BitPlanes::pack(&clean, 1, 4, 2);
        assert_eq!(bpd.data, bpc.data);
        assert_eq!(bpd.rowsum, bpc.rowsum);
        assert_eq!(bpd.unpack(), clean);
    }

    #[test]
    fn eight_plane_mask_keeps_all_bits() {
        let codes: Vec<u8> = (0..256).map(|i| i as u8).collect();
        let bp = BitPlanes::pack(&codes, 2, 128, 8);
        assert_eq!(bp.unpack(), codes);
    }

    #[test]
    fn interleaved_layout_same_plane_rows() {
        let codes: Vec<u8> = (0..5 * 130).map(|i| ((i * 7 + 1) % 32) as u8).collect();
        let pm = BitPlanes::pack(&codes, 5, 130, 5);
        let il = BitPlanes::pack_with_layout(&codes, 5, 130, 5, PlaneLayout::Interleaved);
        assert_eq!(il.layout, PlaneLayout::Interleaved);
        for p in 0..5 {
            for r in 0..5 {
                assert_eq!(pm.plane_row(p, r), il.plane_row(p, r), "plane {p} row {r}");
            }
        }
        assert_eq!(il.unpack(), codes);
        // conversion round-trips both ways
        assert_eq!(pm.to_layout(PlaneLayout::Interleaved).data, il.data);
        assert_eq!(il.to_layout(PlaneLayout::PlaneMajor).data, pm.data);
    }

    #[test]
    fn pack_into_reuses_storage() {
        let codes: Vec<u8> = (0..3 * 70).map(|i| (i % 8) as u8).collect();
        let mut data = Vec::new();
        let mut rowsum = Vec::new();
        BitPlanes::pack_into(&codes, 3, 70, 3, PlaneLayout::PlaneMajor, &mut data, &mut rowsum);
        let owned = BitPlanes::pack(&codes, 3, 70, 3);
        assert_eq!(data, owned.data);
        assert_eq!(rowsum, owned.rowsum);
        // refill with a smaller problem: buffers shrink logically, stay valid
        BitPlanes::pack_into(
            &codes[..64], 1, 64, 3, PlaneLayout::PlaneMajor, &mut data, &mut rowsum,
        );
        let small = BitPlanes::pack(&codes[..64], 1, 64, 3);
        assert_eq!(data, small.data);
        assert_eq!(rowsum, small.rowsum);
        let v = PlanesRef::new(1, 64, 3, PlaneLayout::PlaneMajor, &data, &rowsum);
        assert_eq!(v.plane_row(0, 0), small.plane_row(0, 0));
    }
}
