//! BitPacking (paper §3.4 step ❶): decompose a p-bit code tensor into p
//! binary matrices laid out plane-major, `[M, K, p] → [p, M, K]`.
//!
//! On the GPU this layout change makes global-memory reads of each 1-bit
//! tile contiguous for the BMMA pipeline; here it makes each plane row a
//! dense `u64` slice so the AND+POPCNT inner loop streams sequentially —
//! the same memory-continuity argument, one level down the hierarchy.
//!
//! The packer also precomputes per-row code sums, which the Bit Reduction
//! epilogue needs for the zero-point correction
//! `Y -= zx·rowsum(Wq) + zw·rowsum(Xq) - K·zx·zw`.

/// A p-bit unsigned code matrix packed as p bit-planes of `u64` words.
///
/// `data` layout: `[plane][row][kword]`, i.e. plane-major then row-major —
/// the direct analogue of the paper's `[p, M, K]` BitPacking layout.
#[derive(Clone, Debug)]
pub struct BitPlanes {
    pub rows: usize,
    pub k: usize,
    pub planes: usize,
    pub kwords: usize,
    pub data: Vec<u64>,
    /// per-row sum of the original codes (for zero-point correction)
    pub rowsum: Vec<i64>,
}

impl BitPlanes {
    /// Pack `codes` (row-major `[rows, k]`, values < 2^planes) into planes.
    pub fn pack(codes: &[u8], rows: usize, k: usize, planes: usize) -> Self {
        assert_eq!(codes.len(), rows * k, "codes shape mismatch");
        assert!(planes >= 1 && planes <= 8);
        let kwords = k.div_ceil(64);
        let mut data = vec![0u64; planes * rows * kwords];
        let mut rowsum = vec![0i64; rows];
        for r in 0..rows {
            let mut sum = 0i64;
            let row = &codes[r * k..(r + 1) * k];
            for (i, &c) in row.iter().enumerate() {
                debug_assert!((c as u32) < (1u32 << planes), "code out of range");
                sum += c as i64;
                let (w, b) = (i / 64, i % 64);
                for p in 0..planes {
                    if (c >> p) & 1 == 1 {
                        data[(p * rows + r) * kwords + w] |= 1u64 << b;
                    }
                }
            }
            rowsum[r] = sum;
        }
        BitPlanes { rows, k, planes, kwords, data, rowsum }
    }

    /// Slice of one plane-row (the unit the BMMA loop consumes).
    #[inline(always)]
    pub fn plane_row(&self, plane: usize, row: usize) -> &[u64] {
        let off = (plane * self.rows + row) * self.kwords;
        &self.data[off..off + self.kwords]
    }

    /// Contiguous block of all rows of one plane.
    #[inline(always)]
    pub fn plane(&self, plane: usize) -> &[u64] {
        let off = plane * self.rows * self.kwords;
        &self.data[off..off + self.rows * self.kwords]
    }

    /// Reconstruct the original codes (test / debugging aid).
    pub fn unpack(&self) -> Vec<u8> {
        let mut out = vec![0u8; self.rows * self.k];
        for p in 0..self.planes {
            for r in 0..self.rows {
                let pr = self.plane_row(p, r);
                for i in 0..self.k {
                    if (pr[i / 64] >> (i % 64)) & 1 == 1 {
                        out[r * self.k + i] |= 1 << p;
                    }
                }
            }
        }
        out
    }

    /// Bytes of packed storage (memory-compression accounting).
    pub fn packed_bytes(&self) -> usize {
        self.data.len() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_unpack_roundtrip() {
        let codes: Vec<u8> = (0..7 * 100).map(|i| (i % 16) as u8).collect();
        let bp = BitPlanes::pack(&codes, 7, 100, 4);
        assert_eq!(bp.unpack(), codes);
    }

    #[test]
    fn rowsums() {
        let codes = vec![1u8, 2, 3, 0, 0, 7];
        let bp = BitPlanes::pack(&codes, 2, 3, 3);
        assert_eq!(bp.rowsum, vec![6, 7]);
    }

    #[test]
    fn plane_contents_single_bit() {
        // code 2 = plane 1 only
        let codes = vec![2u8; 64];
        let bp = BitPlanes::pack(&codes, 1, 64, 2);
        assert_eq!(bp.plane_row(0, 0), &[0u64]);
        assert_eq!(bp.plane_row(1, 0), &[u64::MAX]);
    }

    #[test]
    fn ragged_k_tail_is_zero_padded() {
        let codes = vec![1u8; 65];
        let bp = BitPlanes::pack(&codes, 1, 65, 1);
        assert_eq!(bp.kwords, 2);
        assert_eq!(bp.plane_row(0, 0)[1], 1u64); // only bit 0 of word 1
    }
}
