//! The BMMA primitive (paper §3.4 step ❸): 1-bit matrix multiply-accumulate.
//!
//! A Binary TensorCore computes `popcount(AND(a, b))` over 128-bit rows in
//! one m8n8k128 instruction; the CPU equivalent is `(a & b).count_ones()`
//! over `u64` words — a 64-wide binary MAC per instruction. All GEMM
//! variants in `gemm.rs` bottom out here, so this inner loop is the hot
//! path the §Perf pass optimises.

/// Scalar (SWAR) popcount — the *unoptimised* binary MAC, used only by the
/// `Naive` kernel rung of the Table-4 ablation. A hand-written
/// Hamming-weight so the compiler does NOT substitute the vectorised
/// hardware popcount: this is the "Native_kernel" baseline, before the
/// pipeline/vectorisation optimisation is applied.
#[inline(always)]
pub fn popcount_swar(mut x: u64) -> u32 {
    x = x - ((x >> 1) & 0x5555_5555_5555_5555);
    x = (x & 0x3333_3333_3333_3333) + ((x >> 2) & 0x3333_3333_3333_3333);
    x = (x + (x >> 4)) & 0x0F0F_0F0F_0F0F_0F0F;
    ((x.wrapping_mul(0x0101_0101_0101_0101)) >> 56) as u32
}

/// Naive binary dot: word-at-a-time SWAR popcount, no SIMD.
pub fn bdot_scalar(a: &[u64], b: &[u64]) -> u32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0u32;
    for i in 0..a.len() {
        acc += popcount_swar(a[i] & b[i]);
    }
    acc
}

/// Optimised binary dot product: Σ popcount(a ∧ b). The simple loop form
/// lets LLVM vectorise to AVX-512 `vpopcntq` (with `-C target-cpu=native`),
/// processing 8 words per instruction — the CPU equivalent of keeping the
/// BMMA pipe saturated (paper Fig. 9's register double-buffering).
#[inline(always)]
pub fn bdot(a: &[u64], b: &[u64]) -> u32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0u32;
    for i in 0..a.len() {
        acc += (a[i] & b[i]).count_ones();
    }
    acc
}

/// Pipeline-optimised alias (kept for the ablation ladder naming): the
/// vectorised dot IS the pipeline optimisation on this substrate.
#[inline(always)]
pub fn bdot_unrolled(a: &[u64], b: &[u64]) -> u32 {
    bdot(a, b)
}

/// Dual-row binary dot: one A row against two B rows in one call. Each
/// sub-dot stays a simple vectorisable loop; `a` is re-read from L1.
#[inline(always)]
pub fn bdot2(a: &[u64], b0: &[u64], b1: &[u64]) -> (u32, u32) {
    (bdot(a, b0), bdot(a, b1))
}

/// Quad-row variant: one A row against four B rows.
#[inline(always)]
pub fn bdot4(a: &[u64], b0: &[u64], b1: &[u64], b2: &[u64], b3: &[u64]) -> (u32, u32, u32, u32) {
    (bdot(a, b0), bdot(a, b1), bdot(a, b2), bdot(a, b3))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive(a: &[u64], b: &[u64]) -> u32 {
        a.iter().zip(b).map(|(x, y)| (x & y).count_ones()).sum()
    }

    #[test]
    fn variants_agree() {
        let a: Vec<u64> = (0..37).map(|i| (i as u64).wrapping_mul(0x9E3779B97F4A7C15)).collect();
        let b: Vec<u64> = (0..37).map(|i| (i as u64).wrapping_mul(0xBF58476D1CE4E5B9)).collect();
        let want = naive(&a, &b);
        assert_eq!(bdot(&a, &b), want);
        assert_eq!(bdot_scalar(&a, &b), want);
        assert_eq!(bdot_unrolled(&a, &b), want);
        let (x0, x1) = bdot2(&a, &b, &a);
        assert_eq!(x0, want);
        assert_eq!(x1, naive(&a, &a));
        let (y0, y1, y2, y3) = bdot4(&a, &b, &a, &b, &a);
        assert_eq!((y0, y1, y2, y3), (want, naive(&a, &a), want, naive(&a, &a)));
    }

    #[test]
    fn all_ones_counts_k() {
        let a = vec![u64::MAX; 8];
        assert_eq!(bdot(&a, &a), 512);
    }
}
