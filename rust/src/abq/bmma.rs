//! The BMMA primitive (paper §3.4 step ❸): 1-bit matrix multiply-accumulate.
//!
//! A Binary TensorCore computes `popcount(AND(a, b))` over 128-bit rows in
//! one m8n8k128 instruction; the CPU equivalent is a wide popcount over
//! `u64` words. [`bdot`] dispatches to the fastest instruction set the
//! running CPU supports (`abq::kernels` — AVX2 shuffle-LUT, AVX-512
//! `vpopcntq`, NEON `cnt`, portable scalar), honouring the `ABQ_ISA`
//! ceiling. All variants are bit-exact; the GEMM sweeps in `gemm.rs` /
//! `pipeline.rs` dispatch whole sweeps through the same kernel tables
//! rather than per-dot, so this entry point mostly serves the ablation
//! rungs, tests, and benches.
//!
//! (The old `popcount_swar` hand-SWAR baseline lives on as a reference
//! rung inside `benches/t4_ablation.rs` only; the near-duplicate
//! `bdot_scalar`/`bdot_unrolled`/`bdot2`/`bdot4` entry points are gone —
//! scalar vs SIMD is now a dispatch-table decision, and the
//! multi-accumulator fanout chains live in the kernel modules.)

use super::kernels;

/// Binary dot product Σ popcount(a ∧ b), dispatched to the fastest kernel
/// at the current ISA ceiling.
#[inline]
pub fn bdot(a: &[u64], b: &[u64]) -> u32 {
    kernels::active().bdot(a, b) as u32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::abq::isa::{self, Isa};

    fn naive(a: &[u64], b: &[u64]) -> u32 {
        a.iter().zip(b).map(|(x, y)| (x & y).count_ones()).sum()
    }

    #[test]
    fn dispatched_bdot_matches_naive() {
        let a: Vec<u64> = (0..37).map(|i| (i as u64).wrapping_mul(0x9E3779B97F4A7C15)).collect();
        let b: Vec<u64> = (0..37).map(|i| (i as u64).wrapping_mul(0xBF58476D1CE4E5B9)).collect();
        assert_eq!(bdot(&a, &b), naive(&a, &b));
        // pinned to scalar, the same entry point runs the portable path
        isa::pinned(Isa::Scalar, || assert_eq!(bdot(&a, &b), naive(&a, &b)));
    }

    #[test]
    fn all_ones_counts_k() {
        let a = vec![u64::MAX; 8];
        assert_eq!(bdot(&a, &a), 512);
    }
}
