//! Auto Kernel Search (paper Appendix D): before launching an
//! arbitrary-precision operator on a new shape, micro-benchmark the
//! candidate tile configs and cache the winner.
//!
//! The GPU search space is (BM, BN, BK, WM, WN) under shared-memory and
//! register budgets; ours is (n-block, fanout, parallelism) under an L1/L2
//! budget (`tile::candidates`). The search runs each candidate a few times
//! on the real operands and keeps the fastest — exactly the paper's
//! "test the operators at various chunk sizes and adopt the speed-optimised
//! implementation".

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Instant;

use super::bitplane::BitPlanes;
use super::gemm::{gemm_int, OptLevel};
use super::tile::{candidates, ShapeKey, TileConfig};

/// Process-wide search cache: shape → best config.
static CACHE: Mutex<Option<HashMap<ShapeKey, TileConfig>>> = Mutex::new(None);

/// Number of timed repetitions per candidate (median taken).
const REPS: usize = 3;

pub fn lookup(key: &ShapeKey) -> Option<TileConfig> {
    CACHE.lock().unwrap().as_ref().and_then(|m| m.get(key).copied())
}

fn insert(key: ShapeKey, cfg: TileConfig) {
    let mut g = CACHE.lock().unwrap();
    g.get_or_insert_with(HashMap::new).insert(key, cfg);
}

/// Find (or recall) the best tile config for this operand pair.
pub fn best_config(x: &BitPlanes, w: &BitPlanes) -> TileConfig {
    let key = ShapeKey { m: x.rows, n: w.rows, k: x.k, p_bits: x.planes, q_bits: w.planes };
    if let Some(hit) = lookup(&key) {
        return hit;
    }
    let zx = vec![0i32; x.rows];
    let zw = vec![0i32; w.rows];
    let mut best = TileConfig::default();
    let mut best_t = f64::INFINITY;
    for cand in candidates(x.kwords, w.planes) {
        let mut times = Vec::with_capacity(REPS);
        for _ in 0..REPS {
            let t0 = Instant::now();
            let out = gemm_int(x, w, &zx, &zw, OptLevel::Auto, Some(cand));
            std::hint::black_box(&out);
            times.push(t0.elapsed().as_secs_f64());
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let t = times[REPS / 2];
        if t < best_t {
            best_t = t;
            best = cand;
        }
    }
    insert(key, best);
    best
}

/// Run with the searched config (searching on first use).
pub fn gemm_int_auto(x: &BitPlanes, w: &BitPlanes, zx: &[i32], zw: &[i32]) -> Vec<i64> {
    let cfg = best_config(x, w);
    gemm_int(x, w, zx, zw, OptLevel::Auto, Some(cfg))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::abq::gemm::gemm_int_reference;

    #[test]
    fn search_returns_correct_kernel_and_caches() {
        let m = 1;
        let n = 64;
        let k = 256;
        let xc: Vec<u8> = (0..m * k).map(|i| (i % 256) as u8).collect();
        let wc: Vec<u8> = (0..n * k).map(|i| (i % 4) as u8).collect();
        let x = BitPlanes::pack(&xc, m, k, 8);
        let w = BitPlanes::pack(&wc, n, k, 2);
        let zx = vec![3i32; m];
        let zw = vec![1i32; n];
        let got = gemm_int_auto(&x, &w, &zx, &zw);
        let want = gemm_int_reference(&xc, &wc, m, n, k, &zx, &zw);
        assert_eq!(got, want);
        let key = ShapeKey { m, n, k, p_bits: 8, q_bits: 2 };
        assert!(lookup(&key).is_some(), "search result cached");
    }
}
