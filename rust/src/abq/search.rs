//! Auto Kernel Search (paper Appendix D): before launching an
//! arbitrary-precision operator on a new shape, micro-benchmark the
//! candidate tile configs and cache the winner.
//!
//! The GPU search space is (BM, BN, BK, WM, WN) under shared-memory and
//! register budgets; ours is (n-block, fanout, parallelism, weight plane
//! layout) × **kernel ISA** under an L1/L2 budget (`tile::candidates`).
//! Every supported ISA at or below the dispatch ceiling — scalar always
//! included — is raced per shape, so a SIMD kernel only wins where it
//! actually measures faster on this machine. The search runs each
//! candidate a few times on the real operands and keeps the fastest —
//! exactly the paper's "test the operators at various chunk sizes and
//! adopt the speed-optimised implementation".
//!
//! Two process-wide caches:
//! * shape → best [`TileConfig`] (+ its measured time), consulted on every
//!   `Auto` GEMM — a hit is a mutex-guarded map lookup, no allocation;
//! * weight shape → preferred [`PlaneLayout`], consulted once per prepared
//!   linear ([`choose_weight_layout`]) so the decode GEMV streams the
//!   layout that measured fastest on this machine.

use std::collections::HashMap;
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use super::bitplane::{BitPlanes, PlaneLayout, PlanesRef};
use super::gemm::{gemm_int_into, OptLevel};
use super::isa;
use super::tile::{candidates, ShapeKey, TileConfig};

/// Process-wide search cache: shape → (best config, its median seconds).
static CACHE: Mutex<Option<HashMap<ShapeKey, (TileConfig, f64)>>> = Mutex::new(None);

/// Process-wide layout cache: weight shape → preferred plane layout.
static LAYOUT_CACHE: Mutex<Option<HashMap<LayoutKey, PlaneLayout>>> = Mutex::new(None);

/// Number of timed repetitions per candidate (median taken).
const REPS: usize = 3;

/// Below these operand sizes the layout race is skipped (decode-irrelevant
/// micro shapes; keeps unit-test model construction instant).
const LAYOUT_MIN_K: usize = 256;
const LAYOUT_MIN_N: usize = 64;

#[derive(Clone, Copy, PartialEq, Eq, Hash)]
struct LayoutKey {
    n: usize,
    k: usize,
    q_planes: usize,
    p_planes: usize,
    /// dispatch ceiling the layout race ran under (a layout picked by
    /// scalar timings need not be the right one for AVX-512 sweeps)
    isa: crate::abq::isa::Isa,
}

fn shape_key(x: &PlanesRef, w: &PlanesRef) -> ShapeKey {
    ShapeKey {
        m: x.rows,
        n: w.rows,
        k: x.k,
        p_bits: x.planes,
        q_bits: w.planes,
        interleaved: w.layout == PlaneLayout::Interleaved,
        isa: isa::ceiling(),
    }
}

pub fn lookup(key: &ShapeKey) -> Option<TileConfig> {
    CACHE.lock().unwrap().as_ref().and_then(|m| m.get(key).map(|&(c, _)| c))
}

fn lookup_timed(key: &ShapeKey) -> Option<(TileConfig, f64)> {
    CACHE.lock().unwrap().as_ref().and_then(|m| m.get(key).copied())
}

fn insert(key: ShapeKey, cfg: TileConfig, secs: f64) {
    let mut g = CACHE.lock().unwrap();
    g.get_or_insert_with(HashMap::new).insert(key, (cfg, secs));
}

/// `ABQ_WLAYOUT` override: `plane` / `interleaved` force a weight layout,
/// anything else (or unset) lets the search decide.
fn forced_layout() -> Option<PlaneLayout> {
    static FORCED: OnceLock<Option<PlaneLayout>> = OnceLock::new();
    *FORCED.get_or_init(|| match std::env::var("ABQ_WLAYOUT").ok().as_deref() {
        Some("plane") | Some("plane-major") | Some("planemajor") => Some(PlaneLayout::PlaneMajor),
        Some("interleaved") | Some("inter") => Some(PlaneLayout::Interleaved),
        _ => None,
    })
}

/// Find (or recall) the best tile config for this operand pair.
pub fn best_config(x: &BitPlanes, w: &BitPlanes) -> TileConfig {
    best_config_ref(x.view(), w.view())
}

/// [`best_config`] over borrowed plane views (cache hits allocate nothing).
pub fn best_config_ref(x: PlanesRef, w: PlanesRef) -> TileConfig {
    let key = shape_key(&x, &w);
    if let Some(hit) = lookup(&key) {
        return hit;
    }
    search_best(x, w).0
}

/// Run the candidate sweep for this operand pair, cache and return the
/// winner and its median time in seconds.
fn search_best(x: PlanesRef, w: PlanesRef) -> (TileConfig, f64) {
    let key = shape_key(&x, &w);
    if let Some(hit) = lookup_timed(&key) {
        return hit;
    }
    let zx = vec![0i32; x.rows];
    let zw = vec![0i32; w.rows];
    let mut acc = Vec::new();
    let mut best = TileConfig::default();
    let mut best_t = f64::INFINITY;
    // race every supported ISA at or below the ceiling (scalar first);
    // within each, the tile/fanout/parallelism candidate grid
    for isa in isa::race_set() {
        for cand in candidates(x.kwords, w.planes, isa) {
            let mut times = [0f64; REPS];
            for t in times.iter_mut() {
                let t0 = Instant::now();
                gemm_int_into(x, w, &zx, &zw, OptLevel::Auto, Some(cand), &mut acc);
                std::hint::black_box(&acc);
                *t = t0.elapsed().as_secs_f64();
            }
            times.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let t = times[REPS / 2];
            if t < best_t {
                best_t = t;
                best = cand;
            }
        }
    }
    insert(key, best, best_t);
    (best, best_t)
}

/// Run with the searched config (searching on first use).
pub fn gemm_int_auto(x: &BitPlanes, w: &BitPlanes, zx: &[i32], zw: &[i32]) -> Vec<i64> {
    let mut acc = Vec::new();
    gemm_int_auto_into(x.view(), w.view(), zx, zw, &mut acc);
    acc
}

/// [`gemm_int_auto`] writing into a caller-owned accumulator. After the
/// one-time search for a shape, the whole call is allocation-free — the
/// decode hot path's GEMM entry point.
pub fn gemm_int_auto_into(
    x: PlanesRef,
    w: PlanesRef,
    zx: &[i32],
    zw: &[i32],
    acc: &mut Vec<i64>,
) {
    let cfg = best_config_ref(x, w);
    gemm_int_into(x, w, zx, zw, OptLevel::Auto, Some(cfg), acc);
}

fn layout_lookup(key: &LayoutKey) -> Option<PlaneLayout> {
    LAYOUT_CACHE.lock().unwrap().as_ref().and_then(|m| m.get(key).copied())
}

fn layout_insert(key: LayoutKey, layout: PlaneLayout) {
    let mut g = LAYOUT_CACHE.lock().unwrap();
    g.get_or_insert_with(HashMap::new).insert(key, layout);
}

/// Pick the weight plane layout for a prepared linear: race the two
/// layouts' searched best configs on a synthetic single-token GEMV (the
/// decode shape) and keep the faster storage order. Decisions are cached
/// per weight shape; `ABQ_WLAYOUT` forces one layout; micro shapes skip
/// the race and keep what they have. Returns the (possibly re-packed)
/// planes.
pub fn choose_weight_layout(w: BitPlanes, act_planes: usize) -> BitPlanes {
    if let Some(forced) = forced_layout() {
        return if w.layout == forced { w } else { w.to_layout(forced) };
    }
    if w.k < LAYOUT_MIN_K || w.rows < LAYOUT_MIN_N || act_planes == 0 || act_planes > 8 {
        return w;
    }
    let key = LayoutKey {
        n: w.rows,
        k: w.k,
        q_planes: w.planes,
        p_planes: act_planes,
        isa: isa::ceiling(),
    };
    if let Some(cached) = layout_lookup(&key) {
        return if w.layout == cached { w } else { w.to_layout(cached) };
    }
    // synthetic m=1 activation at the decode shape
    let codes: Vec<u8> = (0..w.k).map(|i| (i % (1usize << act_planes)) as u8).collect();
    let x = BitPlanes::pack(&codes, 1, w.k, act_planes);
    let wp = if w.layout == PlaneLayout::PlaneMajor {
        w
    } else {
        w.to_layout(PlaneLayout::PlaneMajor)
    };
    let wi = wp.to_layout(PlaneLayout::Interleaved);
    let (_, t_plane) = search_best(x.view(), wp.view());
    let (_, t_inter) = search_best(x.view(), wi.view());
    let chosen = if t_inter < t_plane { PlaneLayout::Interleaved } else { PlaneLayout::PlaneMajor };
    layout_insert(key, chosen);
    if chosen == PlaneLayout::Interleaved {
        wi
    } else {
        wp
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::abq::gemm::gemm_int_reference;
    use crate::abq::isa::Isa;

    #[test]
    fn search_returns_correct_kernel_and_caches() {
        let m = 1;
        let n = 64;
        let k = 256;
        let xc: Vec<u8> = (0..m * k).map(|i| (i % 256) as u8).collect();
        let wc: Vec<u8> = (0..n * k).map(|i| (i % 4) as u8).collect();
        let x = BitPlanes::pack(&xc, m, k, 8);
        let w = BitPlanes::pack(&wc, n, k, 2);
        let zx = vec![3i32; m];
        let zw = vec![1i32; n];
        let got = gemm_int_auto(&x, &w, &zx, &zw);
        let want = gemm_int_reference(&xc, &wc, m, n, k, &zx, &zw);
        assert_eq!(got, want);
        let key = ShapeKey {
            m,
            n,
            k,
            p_bits: 8,
            q_bits: 2,
            interleaved: false,
            isa: isa::ceiling(),
        };
        assert!(lookup(&key).is_some(), "search result cached");
    }

    #[test]
    fn cache_entries_are_keyed_by_dispatch_ceiling() {
        // a winner raced under one ceiling must never replay under another
        let natural = isa::ceiling();
        let (m, n, k) = (1usize, 32usize, 128usize);
        let xc: Vec<u8> = (0..m * k).map(|i| (i % 64) as u8).collect();
        let wc: Vec<u8> = (0..n * k).map(|i| (i % 8) as u8).collect();
        let x = BitPlanes::pack(&xc, m, k, 6);
        let w = BitPlanes::pack(&wc, n, k, 3);
        let (scalar_key, scalar_cfg) = isa::pinned(Isa::Scalar, || {
            let key = shape_key(&x.view(), &w.view());
            let (cfg, _) = search_best(x.view(), w.view());
            (key, cfg)
        });
        assert_eq!(scalar_key.isa, Isa::Scalar);
        assert_eq!(scalar_cfg.isa, Isa::Scalar, "scalar ceiling admits only scalar kernels");
        assert!(lookup(&scalar_key).is_some());
        if natural != Isa::Scalar {
            isa::pinned(natural, || {
                let native_key = shape_key(&x.view(), &w.view());
                assert_ne!(native_key, scalar_key, "ceiling must be part of the key");
                let (native_cfg, _) = search_best(x.view(), w.view());
                // the native race may still crown scalar, but the entry
                // lives in its own ceiling-keyed slot
                assert!(lookup(&native_key).is_some());
                assert!(native_cfg.isa.supported());
            });
            assert!(lookup(&scalar_key).is_some(), "scalar-ceiling entry survives");
        }
    }

    #[test]
    fn auto_into_reuses_accumulator_and_matches_reference() {
        let (m, n, k) = (2usize, 48usize, 192usize);
        let xc: Vec<u8> = (0..m * k).map(|i| (i % 16) as u8).collect();
        let wc: Vec<u8> = (0..n * k).map(|i| (i % 8) as u8).collect();
        let x = BitPlanes::pack(&xc, m, k, 4);
        let w = BitPlanes::pack(&wc, n, k, 3);
        let zx = vec![7i32; m];
        let zw = vec![3i32; n];
        let want = gemm_int_reference(&xc, &wc, m, n, k, &zx, &zw);
        let mut acc = Vec::new();
        for _ in 0..3 {
            gemm_int_auto_into(x.view(), w.view(), &zx, &zw, &mut acc);
            assert_eq!(acc, want);
        }
        // interleaved weights go through their own cache entry, same result
        let wi = w.to_layout(PlaneLayout::Interleaved);
        gemm_int_auto_into(x.view(), wi.view(), &zx, &zw, &mut acc);
        assert_eq!(acc, want);
    }

    #[test]
    fn layout_choice_is_cached_and_preserves_contents() {
        // freeze the dispatch ceiling so the LayoutKey we probe matches the
        // one choose_weight_layout wrote (other tests pin ISAs in parallel)
        isa::pinned(isa::ceiling(), || {
            let (n, k, q, p) = (LAYOUT_MIN_N, LAYOUT_MIN_K, 2usize, 4usize);
            let wc: Vec<u8> = (0..n * k).map(|i| (i % 4) as u8).collect();
            let w = BitPlanes::pack(&wc, n, k, q);
            let chosen = choose_weight_layout(w, p);
            assert_eq!(chosen.unpack(), wc);
            let key = LayoutKey { n, k, q_planes: q, p_planes: p, isa: isa::ceiling() };
            let cached = layout_lookup(&key).expect("layout decision cached");
            assert_eq!(chosen.layout, cached);
            // second call must return the cached layout without re-searching
            let again = choose_weight_layout(BitPlanes::pack(&wc, n, k, q), p);
            assert_eq!(again.layout, cached);
        });
    }

    #[test]
    fn tiny_shapes_skip_the_layout_race() {
        let wc = vec![1u8; 8 * 32];
        let w = BitPlanes::pack(&wc, 8, 32, 1);
        let out = choose_weight_layout(w, 8);
        assert_eq!(out.layout, PlaneLayout::PlaneMajor);
    }
}
